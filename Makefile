# One-command CI surface for a clean checkout (ISSUE 1/2 satellites).
#
#   make test          tier-1 suite + repair/erasure/sim focus run
#   make tier1         exactly the ROADMAP tier-1 command
#   make repair-tests  repair subsystem + batched-coding + sim tests only
#   make batch-tests   batched state-transfer path tests only
#   make kernel-tests  GF(256) kernel + erasure + coding-backend focus run
#   make bench-repair  durability-restoration / interference benchmark
#   make bench-readpath  batched vs per-object read-path benchmark
#   make bench-multifile cross-file Session fan-out vs legacy per-file ops
#   make bench-gateway cross-client gateway merge vs direct per-client path
#   make bench-scale   10^3/10^4-session zipfian harness, fast vs legacy engine
#   make bench-smoke   every benchmark harness at its smallest point (CI);
#                      FAILS if quorum-round counts regress versus
#                      benchmarks/smoke_baseline.json (per-metric tolerance)
#   make bench-chaos   beyond-quorum crash-storm chaos bench (ISSUE 10):
#                      retry machinery armed, EVERY server crashes then
#                      recovers; FAILS if availability / stuck-op / retry-
#                      amplification floors in smoke_baseline.json are missed
#   make lint          ruff check (the CI lint job; pip install ruff)
#   make analyze       protocol-invariant AST lint pack (stdlib-only:
#                      registry drift, assert ban, determinism, set
#                      iteration, _StateMap bypass) — fails on any finding
#   make sanitize-test tier-1 suite with the runtime protocol sanitizer on
#                      (REPRO_SANITIZE=1: live quorum/tag/vocabulary checks
#                      + post-hoc Wing–Gong pass on workload histories)
#   make explore       schedule explorer (ISSUE 9): selftest (four seeded
#                      bugs must be found and replay byte-identically),
#                      then bounded-exhaustive DFS with crash+drop
#                      injection and a seeded PCT sweep on the EC-recon
#                      scenario — all must come back clean on HEAD.
#                      Violations serialize to runs/schedules/*.json
#   make replay SCHEDULE=runs/schedules/<bundle>.json
#                      re-execute a repro bundle; fails unless the
#                      violation AND trace fingerprint reproduce exactly
#   make typecheck     mypy --strict over src/repro/analysis (mypy.ini;
#                      the CI lint job pip-installs mypy like ruff)
#   make dev-deps      install optional dev extras (real hypothesis, ruff)
#
# The suite runs WITHOUT hypothesis installed (tests/_propfallback.py).

PY ?= python

.PHONY: test tier1 repair-tests batch-tests kernel-tests bench-repair \
        bench-readpath bench-multifile bench-gateway bench-scale bench-smoke \
        bench-chaos lint analyze sanitize-test explore replay typecheck dev-deps

tier1:
	PYTHONPATH=src $(PY) -m pytest -x -q

analyze:
	PYTHONPATH=src $(PY) -m repro.analysis

sanitize-test:
	REPRO_SANITIZE=1 PYTHONPATH=src $(PY) -m pytest -x -q

explore:
	PYTHONPATH=src $(PY) -m repro.analysis.explore --selftest --budget 2000
	PYTHONPATH=src $(PY) -m repro.analysis.explore --scenario wr --mode dfs \
		--budget 4000 --depth 6 --crash-budget 1 --drop-budget 1
	PYTHONPATH=src $(PY) -m repro.analysis.explore --scenario ec-recon \
		--mode pct --budget 300 --crash-budget 1 --drop-budget 1

replay:
	PYTHONPATH=src $(PY) -m repro.analysis.explore --replay $(SCHEDULE)

typecheck:
	$(PY) -m mypy

repair-tests:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_repair.py tests/test_erasure.py tests/test_sim.py

batch-tests:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_batchpath.py tests/test_dap_properties.py

kernel-tests:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_kernel_gf256.py tests/test_erasure.py \
		tests/test_coding_backend.py tests/test_batchpath.py

test: tier1 repair-tests

bench-repair:
	PYTHONPATH=src $(PY) benchmarks/bench_repair.py

bench-readpath:
	PYTHONPATH=src $(PY) benchmarks/bench_readpath.py

bench-multifile:
	PYTHONPATH=src $(PY) benchmarks/bench_multifile.py

bench-gateway:
	PYTHONPATH=src $(PY) benchmarks/bench_gateway.py

bench-scale:
	PYTHONPATH=src $(PY) benchmarks/bench_scale.py

bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.smoke --baseline benchmarks/smoke_baseline.json

bench-chaos:
	PYTHONPATH=src $(PY) -m benchmarks.bench_chaos --baseline benchmarks/smoke_baseline.json

lint:
	ruff check src benchmarks examples tests

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
