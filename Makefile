# One-command CI surface for a clean checkout (ISSUE 1 satellite).
#
#   make test          tier-1 suite + repair/erasure/sim focus run
#   make tier1         exactly the ROADMAP tier-1 command
#   make repair-tests  repair subsystem + batched-coding + sim tests only
#   make bench-repair  durability-restoration / interference benchmark
#   make dev-deps      install optional dev extras (real hypothesis)
#
# The suite runs WITHOUT hypothesis installed (tests/_propfallback.py).

PY ?= python

.PHONY: test tier1 repair-tests bench-repair dev-deps

tier1:
	PYTHONPATH=src $(PY) -m pytest -x -q

repair-tests:
	PYTHONPATH=src $(PY) -m pytest -q tests/test_repair.py tests/test_erasure.py tests/test_sim.py

test: tier1 repair-tests

bench-repair:
	PYTHONPATH=src $(PY) benchmarks/bench_repair.py

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
