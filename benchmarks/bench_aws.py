"""Paper Fig. 5 (AWS overlay testbed) — including its NEGATIVE result.

On real-network (WAN-ish) conditions the paper finds CoARESF reads do NOT
beat CoARES: every block read pays the configuration-discovery round-trips
serially ("a stable overhead for each block request", §VII-D). We reproduce
that with the AWS latency model (5-25 ms base delay), and show the
parallel-index variant recovers the win.
"""
from __future__ import annotations

from repro.core.store import DSS, DSSParams
from repro.net.sim import LatencyModel

from benchmarks.common import run_workload

AWS_LAT = LatencyModel(base_lo=5e-3, base_hi=25e-3, bandwidth=60e6)


def _dss(alg, indexed=False, seed=23):
    return DSS(DSSParams(
        algorithm=alg, n_servers=6, parity_m=4, seed=seed,
        min_block=1 << 17, avg_block=1 << 18, max_block=1 << 20,
        latency=AWS_LAT, indexed=indexed,
    ))


def run() -> list[dict]:
    rows = []
    for alg, indexed, label in [
        ("coabd", False, "coabd"),
        ("coabdf", False, "coabdf"),
        ("coaresec", False, "coaresec"),
        ("coaresecf", False, "coaresecf"),
        ("coaresecf", True, "coaresecf+pidx"),
    ]:
        for size in (1 << 21, 1 << 23):
            dss = _dss(alg, indexed=indexed)
            res = run_workload(dss, file_size=size, n_writers=1, n_readers=1,
                               ops_each=4, seed=size % 89)
            rows.append({"bench": "aws_filesize", "algorithm": label,
                         "file_size": size, **res.row()})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
