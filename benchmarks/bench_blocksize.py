"""Paper Fig. 11/12 — latency vs FM block sizes (fragmented algorithms).

Min/avg block swept with max fixed (Fig 11 analogue), then a joint
min/avg/max sweep on a larger file (Fig 12 analogue). 1:16 scale.
"""
from __future__ import annotations

from benchmarks.common import make_dss, run_workload

ALGOS = ["coabdf", "coaresabdf", "coaresecf"]


def run() -> list[dict]:
    rows = []
    size = 1 << 22  # 4 MiB (paper: 4 MB)
    for alg in ALGOS:
        for blk in (1 << 13, 1 << 15, 1 << 17, 1 << 18, 1 << 20):
            dss = make_dss(alg, n_servers=11,
                           parity=1 if "ec" in alg else 1, seed=11,
                           block=(blk // 2, blk, 1 << 21))
            res = run_workload(dss, file_size=size, n_writers=2, n_readers=2,
                               ops_each=4, seed=blk)
            rows.append({"bench": "blocksize_minavg", "algorithm": alg,
                         "avg_block": blk, **res.row()})
    big = 1 << 24  # 16 MiB (paper: 512 MB)
    for alg in ALGOS:
        for blk in (1 << 16, 1 << 18, 1 << 20, 1 << 22):
            dss = make_dss(alg, n_servers=11, parity=1, seed=13,
                           block=(blk // 2, blk, 4 * blk))
            res = run_workload(dss, file_size=big, n_writers=2, n_readers=2,
                               ops_each=3, seed=blk)
            rows.append({"bench": "blocksize_joint", "algorithm": alg,
                         "avg_block": blk, **res.row()})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
