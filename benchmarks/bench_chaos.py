"""Chaos bench (ISSUE 10): availability under a beyond-quorum crash storm.

A seeded zipfian workload runs while EVERY server crashes for a blackout
window (``beyond_quorum=True`` lifts the n - quorum cap) and then recovers.
With a :class:`RetryPolicy` armed the RPC tier retransmits through the
outage and the phase tier re-issues against the current configuration, so
the run must come back with zero stuck operations, every unrecoverable op
failing typed (``QuorumUnavailableError``) within its deadline, and post-
recovery availability at ~100%.

Rows:

* ``chaos_calm``     — retry armed, no storm (the amplification denominator)
* ``chaos_storm``    — retry armed, beyond-quorum storm; the availability /
  p99 / stuck numbers CI gates as floors (``smoke_baseline.json``)
* ``chaos_amplification`` — storm/calm ratios: retry cost in rounds & bytes
* ``chaos_ablation`` — ``retry=None``: the machinery consumes NOTHING
  (zero retransmits/timeouts/hedges, fast == legacy trace)

Every trial is asserted trace-identical across the fast and legacy engines
before its row is emitted — the retry timers, retransmissions and jitter
draws are part of the deterministic trace contract.

    make bench-chaos    # or: PYTHONPATH=src python -m benchmarks.bench_chaos
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from repro.core import DSS, DSSParams, CrashStorm, RetryPolicy
from repro.core.workload import WorkloadGen, WorkloadSpec

BLOCK = (256, 512, 2048)
STORM = CrashStorm(at=0.05, frac=1.0, duration=0.05, beyond_quorum=True)


def _trial(fast: bool, *, sessions: int, retry: RetryPolicy | None,
           storm: bool, seed: int = 23) -> dict:
    dss = DSS(DSSParams(
        algorithm="coaresecf", n_servers=5, parity_m=2, seed=7,
        min_block=BLOCK[0], avg_block=BLOCK[1], max_block=BLOCK[2],
        indexed=True, batched=True, fast_net=fast, retry=retry,
    ))
    spec = WorkloadSpec(
        sessions=sessions, files=8, file_size=512, read_fraction=0.6,
        ops_per_session=2, storms=(STORM,) if storm else (),
    )
    rep = WorkloadGen(spec, seed=seed).run(dss)
    rep["_fingerprint"] = (
        round(dss.net.now, 12), dss.net.events_processed, dss.net.rpc_rounds,
        dss.net.msg_count, dss.net.bytes_sent, dss.net.retransmits,
        dss.net.rpc_timeouts,
    )
    return rep


def _both_engines(**kw) -> dict:
    """Run fast + legacy and insist on an identical trace; return the report."""
    a = _trial(True, **kw)
    b = _trial(False, **kw)
    assert a == b, "fast/legacy trace divergence under chaos"
    return a


def _row(label: str, rep: dict) -> dict:
    ops = rep["ops"]
    return {
        "bench": label,
        "ops": ops,
        "availability": round(rep["availability"], 4),
        "availability_after_recovery": round(
            rep.get("availability_after_recovery", 1.0), 4),
        "ops_failed": rep["ops_failed"],
        "ops_stuck": rep["ops_stuck"],
        "stuck_rpcs": rep["stuck_rpcs"],
        "quorum_unavailable": rep["quorum_unavailable"],
        "op_p99_ms": round(rep.get("op_p99", 0.0) * 1e3, 3),
        "retransmits": rep["retries"]["retransmits"],
        "rpc_timeouts": rep["retries"]["rpc_timeouts"],
        "phase_retries": rep["retries"]["op_retries"],
        "rounds_per_op": round(rep["rpc_rounds"] / ops, 3),
        "kB_per_op": round(rep["bytes_sent"] / ops / 1e3, 2),
    }


def run(sessions: int = 40) -> list[dict]:
    rows = []

    calm = _both_engines(sessions=sessions, retry=RetryPolicy(), storm=False)
    rows.append(_row("chaos_calm", calm))

    storm = _both_engines(sessions=sessions, retry=RetryPolicy(), storm=True)
    # the availability gate's hard half: a beyond-quorum storm may fail ops
    # DURING the blackout, but only typed and never stuck
    assert storm["ops_stuck"] == 0 and storm["stuck_rpcs"] == 0
    assert storm["ops_failed"] == storm["quorum_unavailable"]
    rows.append(_row("chaos_storm", storm))

    rows.append({
        "bench": "chaos_amplification",
        "rounds_x": round(
            (storm["rpc_rounds"] / storm["ops"])
            / (calm["rpc_rounds"] / calm["ops"]), 3),
        "bytes_x": round(
            (storm["bytes_sent"] / storm["ops"])
            / (calm["bytes_sent"] / calm["ops"]), 3),
        "retransmits_per_op": round(
            storm["retries"]["retransmits"] / storm["ops"], 3),
    })

    off = _both_engines(sessions=sessions, retry=None, storm=False)
    assert off["retries"] == {"retransmits": 0, "rpc_timeouts": 0,
                              "hedges": 0, "op_retries": 0}
    rows.append({
        "bench": "chaos_ablation", "retry": "off",
        "retransmits": 0,
        "rounds_per_op": round(off["rpc_rounds"] / off["ops"], 3),
        "kB_per_op": round(off["bytes_sent"] / off["ops"] / 1e3, 2),
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=40)
    ap.add_argument("--json", metavar="PATH", default=None)
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="gate the chaos rows against this baseline file "
                         "(only metrics naming a bench produced here)")
    args = ap.parse_args()
    rows = run(sessions=args.sessions)
    for r in rows:
        print(r)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rows, indent=2, default=str))
        print(f"chaos: wrote {len(rows)} rows to {out}", file=sys.stderr)
    if args.baseline:
        from benchmarks.smoke import check_baseline

        failures = check_baseline(rows, args.baseline,
                                  benches={r["bench"] for r in rows})
        if failures:
            for f in failures:
                print(f"chaos: REGRESSION: {f}", file=sys.stderr)
            sys.exit(1)
        print(f"chaos: availability floor check passed ({args.baseline})",
              file=sys.stderr)
    print("chaos: beyond-quorum storm survived", file=sys.stderr)
