"""Checkpoint-at-scale: EC (CoARESECF) vs replication (CoABDF) vs
whole-object (CoARESEC), full vs incremental saves.

Reports virtual-time save/restore latency, bytes on the wire, and storage
overhead — the paper's storage-efficiency claim applied to train state.
"""
from __future__ import annotations

import numpy as np

from repro.train.checkpoint import ECCheckpointStore


def _fake_state(mb: float, seed=0):
    n = int(mb * 1e6 / 4)
    rng = np.random.default_rng(seed)
    return {"params": rng.standard_normal(n).astype(np.float32),
            "step_count": np.int32(1)}


def run() -> list[dict]:
    rows = []
    state = _fake_state(8.0)
    for alg, parity, indexed, label in [
        ("coaresecf", 4, False, "EC[12,8] fragmented (paper)"),
        ("coaresecf", 4, True, "EC[12,8] frag + parallel-index (ours)"),
        ("coaresecf-noopt", 4, False, "EC[12,8] frag (no §VI opt)"),
        ("coabdf", 0, False, "replication fragmented"),
        ("coaresec", 4, False, "EC[12,8] whole-object"),
    ]:
        store = ECCheckpointStore(
            n_hosts=12, parity=parity if parity else 1, algorithm=alg,
            seed=5, min_block=1 << 17, avg_block=1 << 18, max_block=1 << 20,
            indexed=indexed,
        )
        st1 = store.save(1, state)
        net1 = store.dss.net.bytes_sent
        # incremental: bump the step counter only
        state2 = dict(state)
        state2["step_count"] = np.int32(2)
        st2 = store.save(2, state2)
        net2 = store.dss.net.bytes_sent - net1
        t0 = store.dss.net.now
        store.restore()
        t_restore = store.dss.net.now - t0
        c = store.dss.c0
        overhead = c.n / c.k if c.dap.startswith("ec") else c.n
        rows.append({
            "bench": "checkpoint", "store": label,
            "save_full_ms": st1.virtual_seconds * 1e3,
            "save_incr_ms": st2.virtual_seconds * 1e3,
            "incr_blocks": f"{st2.blocks_written}/{st2.blocks_total}",
            "restore_ms": t_restore * 1e3,
            "wire_MB_full": net1 / 1e6,
            "wire_MB_incr": net2 / 1e6,
            "storage_overhead_x": round(overhead, 2),
        })
    # fault tolerance at restore time
    store = ECCheckpointStore(n_hosts=12, parity=4, seed=6)
    store.save(1, state)
    store.crash_hosts(["s0", "s1"])  # within (n-k)/2 = 2
    t0 = store.dss.net.now
    ok = store.restore() is not None
    rows.append({"bench": "checkpoint_faults", "store": "EC[12,8] 2 hosts dead",
                 "restore_ms": (store.dss.net.now - t0) * 1e3, "restored": ok})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
