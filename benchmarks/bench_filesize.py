"""Paper Fig. 4/5 — operation latency vs initial file size, six algorithms
(+ §VI: EC-DAP vs EC-DAPopt lines). Sizes scaled 1:64 vs the paper's
1MB-512MB (virtual network, identical trends); block sizes scaled alike.
"""
from __future__ import annotations

from benchmarks.common import make_dss, run_workload

ALGOS = ["coabd", "coabdf", "coaresabd", "coaresabdf", "coaresec", "coaresecf",
         "coaresec-noopt", "coaresecf-noopt"]
SIZES = [1 << 20, 1 << 21, 1 << 22, 1 << 23, 1 << 24]  # 1MB..16MB (1:32 of paper)


def run() -> list[dict]:
    rows = []
    for alg in ALGOS:
        for size in SIZES:
            dss = make_dss(alg, n_servers=11,
                           parity=5 if "ec" in alg else 1, seed=7)
            res = run_workload(dss, file_size=size, n_writers=2, n_readers=2,
                               ops_each=4, seed=size % 97)
            rows.append({"bench": "filesize", "algorithm": alg,
                         "file_size": size, **res.row()})
    # beyond-paper: CoARESECF with the parallel block index (§Perf storage)
    for size in SIZES:
        dss = make_dss("coaresecf", n_servers=11, parity=5, seed=7, indexed=True)
        res = run_workload(dss, file_size=size, n_writers=2, n_readers=2,
                           ops_each=4, seed=size % 97)
        rows.append({"bench": "filesize", "algorithm": "coaresecf+pidx",
                     "file_size": size, **res.row()})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
