"""ISSUE 4 acceptance — cross-client gateway aggregation.

A C-client same-file read fan-out under ``coaresecf`` with
``indexed=True, batched=True``:

* ``gateway`` — every client session attaches to one Gateway
  (``dss.session(cid, via=gw)``): all C reads of the hot file land in one
  gateway window, dedupe to ONE entry of a merged batch, and cost ONE
  quorum fan-out — total quorum rounds are FLAT in C (the result is
  multicast back and each rider's OpStats shows the shared round once).
* ``direct``  — the per-client ablation baseline: C detached sessions,
  each its own network endpoint, each paying its own fan-out. Quorum
  rounds scale O(C).

A second phase does the same for a C-client **mixed-file** fan-out (each
client reads one of two hot files) — the merge still collapses C client
fan-outs into one two-file batched round.

The gossip trial demonstrates the tier's second job: a RepairDaemon with
NO local recon callback (``auto_retarget=False``) registered with the
gateway acquires coverage of a configuration someone else installed (via
the codec-framed ``gossip-configs`` anti-entropy round) and repairs a
damaged fragment of it.

    PYTHONPATH=src python benchmarks/bench_gateway.py
"""
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import make_dss
from repro.core.api import gather

C_LIST = (1, 2, 4, 8, 16)
FILE_SIZE = 1 << 16                       # 64 KiB, ~8 blocks per file
BLOCK = (1 << 12, 1 << 13, 1 << 15)
N_SERVERS = 11
PARITY = 5
HOT_FILES = ("hot0", "hot1")


def _setup(seed: int):
    dss = make_dss("coaresecf", n_servers=N_SERVERS, parity=PARITY, seed=seed,
                   block=BLOCK, indexed=True, batched=True)
    rng = np.random.default_rng(seed)
    docs = {f: rng.integers(0, 256, FILE_SIZE, dtype=np.uint8).tobytes()
            for f in HOT_FILES}
    boot = dss.session("boot")
    assert all(s["success"] for s in gather(*[boot.write(f, d)
                                              for f, d in docs.items()]))
    dss.net.run()
    return dss, docs


def _one(C: int, mode: str, seed: int = 83) -> list[dict]:
    """One same-file and one mixed-file C-client read fan-out; two rows."""
    dss, docs = _setup(seed)
    gw = dss.gateway() if mode == "gateway" else None
    rows = []
    for phase in ("same-file", "mixed"):
        cid = f"{mode[0]}{phase[0]}{C}"
        sessions = [
            dss.session(f"{cid}_{i}", via=gw) if gw is not None
            else dss.session(f"{cid}_{i}")
            for i in range(C)
        ]
        targets = (
            [HOT_FILES[0]] * C if phase == "same-file"
            else [HOT_FILES[i % len(HOT_FILES)] for i in range(C)]
        )
        r0, m0, b0 = dss.net.rpc_rounds, dss.net.msg_count, dss.net.bytes_sent
        t0 = dss.net.now
        futs = [s.read(f) for s, f in zip(sessions, targets)]
        results = gather(*futs)
        assert results == [docs[f] for f in targets], "fan-out corrupted"
        rows.append({
            "bench": "gateway", "mode": mode, "phase": phase, "clients": C,
            "quorum_rounds": dss.net.rpc_rounds - r0,
            "msg_count": dss.net.msg_count - m0,
            "MB_sent": (dss.net.bytes_sent - b0) / 1e6,
            "fanout_ms": (dss.net.now - t0) * 1e3,
        })
    if gw is not None:
        gw.stop()
    return rows


def _gossip_trial(seed: int = 89) -> dict:
    """Config dissemination: a callback-less daemon learns a config through
    gateway gossip and restores a lost fragment of it."""
    dss = make_dss("coaresec", n_servers=6, parity=4, seed=seed, block=BLOCK)
    doc = np.random.default_rng(seed).integers(
        0, 256, 1 << 12, dtype=np.uint8).tobytes()
    dss.net.run_op(dss.client("w").update("f", doc), client="w")
    dss.net.run()
    gw = dss.gateway()
    daemon = dss.start_repair_daemon(period=0.01, objs_per_cycle=2,
                                     auto_retarget=False)
    gw.register_daemon(daemon)
    cfg1 = dss.make_config()
    fut = dss.net.spawn(dss.client("g").recon("f", cfg1), client="g")
    dss.net.run(until=dss.net.now + 0.2)
    assert fut.done and (1, cfg1.cfg_id) in daemon.targets, (
        "daemon must acquire the gossiped configuration"
    )
    lst = dss.net.servers["s3"].ec[("f", 1)]
    t_star = max(t for t, e in lst.items() if e is not None)
    del lst[t_star]
    t_damage = dss.net.now
    dss.net.run(until=dss.net.now + 0.3)
    dss.stop_repair_daemon()
    gw.stop()
    dss.net.run()
    assert dss.net.servers["s3"].ec[("f", 1)].get(t_star) is not None, (
        "gossip-covered configuration was not repaired"
    )
    restored = [r for r in dss.history
                if r.kind == "repair" and r.start >= t_damage
                and (r.extra or {}).get("applied", 0) > 0]
    return {
        "bench": "gateway_gossip",
        "gossip_applied": daemon.stats["gossip"],
        "repair_ms": (restored[0].end - t_damage) * 1e3 if restored else None,
        "repaired": True,
    }


def run() -> list[dict]:
    rows = []
    for C in C_LIST:
        for mode in ("direct", "gateway"):
            rows.extend(_one(C, mode))
    # headline checks: merged same-file fan-out rounds are flat in C,
    # the direct ablation scales with C
    by_key = {(r["mode"], r["phase"], r["clients"]): r["quorum_rounds"]
              for r in rows}
    for phase in ("same-file", "mixed"):
        flat = {c: by_key[("gateway", phase, c)] for c in C_LIST}
        assert len(set(flat.values())) == 1, f"gateway {phase} not O(1): {flat}"
    assert by_key[("direct", "same-file", C_LIST[-1])] >= (
        C_LIST[-1] * by_key[("direct", "same-file", 1)]
    ), "direct path should scale O(C)"
    rows.append(_gossip_trial())
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
