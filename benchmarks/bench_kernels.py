"""Kernel-layer benchmarks: GF(256) RS encode + gear-hash CDC.

Wall-time here is the jit'd pure-jnp path on CPU (the Pallas kernel targets
TPU; interpret mode is a correctness harness, not a perf surface). The
derived column reports the ANALYTIC v5e roofline for the bitsliced kernel:
arithmetic intensity 64*m*k/(k+m) FLOP/byte and the implied bandwidth- or
MXU-bound throughput (DESIGN.md §3).
"""
from __future__ import annotations

import time

import numpy as np

from repro.erasure import RSCode
from repro.kernels.cdc_gearhash.ops import gearhash
from repro.kernels.gf256_matmul.ref import gf256_matmul_ref
from repro.roofline.analysis import V5E


def _time(fn, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run() -> list[dict]:
    import jax

    rows = []
    for (n, k) in [(6, 4), (11, 6), (12, 10), (14, 10)]:
        m = n - k
        L = 1 << 20  # 1 MiB stripes
        rng = np.random.default_rng(0)
        data = rng.integers(0, 256, (k, L), dtype=np.uint8)
        code = RSCode(n=n, k=k)
        P = code.parity_matrix

        jit_ref = jax.jit(lambda d: gf256_matmul_ref(P, d))
        jit_ref(data).block_until_ready()
        dt = _time(lambda: jit_ref(data).block_until_ready())
        mb = k * L / 1e6
        # analytic v5e roofline for the bitsliced MXU formulation
        ai = 64.0 * m * k / (k + m)                      # FLOP per byte moved
        bytes_moved = (k + m) * L
        flops = 2.0 * (8 * m) * (8 * k) * L
        t_mxu = flops / V5E.peak_flops
        t_hbm = bytes_moved / V5E.hbm_bw
        bound = "MXU" if t_mxu > t_hbm else "HBM"
        tpu_gbps = k * L / max(t_mxu, t_hbm) / 1e9
        rows.append({
            "bench": "rs_encode", "n": n, "k": k,
            "cpu_ref_MBps": mb / dt,
            "v5e_intensity_flop_per_byte": round(ai, 1),
            "v5e_bound": bound,
            "v5e_GBps_per_chip": round(tpu_gbps, 1),
        })
    # decode (k-of-n with erasures -> inverse matmul, same kernel)
    code = RSCode(n=12, k=10)
    data = np.random.default_rng(1).integers(0, 256, (10, 1 << 20), dtype=np.uint8)
    coded = code.encode(data)
    keep = [0, 2, 3, 4, 5, 6, 7, 8, 10, 11]
    dt = _time(lambda: code.decode(coded[keep], keep), warmup=1, iters=3)
    rows.append({"bench": "rs_decode", "n": 12, "k": 10,
                 "cpu_MBps": 10 * (1 << 20) / 1e6 / dt})
    # batched-bytes coding path, LUT backend vs the kernel backend the
    # storage data path dispatches to (ISSUE 6 acceptance: >= 3x at the
    # large-block point): ragged values, one fused encode + one fused
    # non-systematic decode per call, exactly what EcDap issues.
    rng = np.random.default_rng(3)
    values = [
        rng.integers(0, 256, (1 << 18) + 1024 * i, dtype=np.uint8).tobytes()
        for i in range(8)
    ]
    total_mb = sum(len(v) for v in values) / 1e6
    sub = (1, 3, 4, 5, 6, 7, 8, 9, 11, 13)  # mixed data+parity -> real matmul
    mbps = {}
    for backend in ("numpy", "kernel"):
        bcode = RSCode(n=14, k=10, backend=backend)

        def cycle():
            enc = bcode.encode_bytes_batch(values)
            items = [({i: f[i] for i in sub}, o) for f, o in enc]
            return bcode.decode_bytes_batch(items)

        assert cycle() == values  # also the kernel jit warmup
        dt = _time(cycle, warmup=1, iters=3)
        mbps[backend] = 2 * total_mb / dt  # encode pass + decode pass
    rows.append({
        "bench": "rs_bytes_batch", "n": 14, "k": 10,
        "lut_MBps": mbps["numpy"], "kernel_MBps": mbps["kernel"],
        "speedup": mbps["kernel"] / mbps["numpy"],
    })
    # CDC gear hash
    blob = np.random.default_rng(2).integers(0, 256, 1 << 22, dtype=np.uint8)
    h, b = gearhash(blob)  # jit'd ref path on CPU
    import jax

    dt = _time(lambda: jax.block_until_ready(gearhash(blob)))
    rows.append({"bench": "cdc_gearhash", "cpu_MBps": len(blob) / 1e6 / dt,
                 "v5e_bound": "HBM",
                 "v5e_GBps_per_chip": round(V5E.hbm_bw / 6 / 1e9, 1)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
