"""ISSUE 3 acceptance — cross-FILE batched scheduling.

An F-file concurrent read/write fan-out under ``coaresecf`` with
``indexed=True, batched=True``:

* ``session`` — the Session/future API: all F ops land in one coalescing
  window and ride ONE multi-file batch through the state-transfer engine.
  The discovery/gather/put stages cost O(1) quorum rounds FLAT in F.
* ``legacy``  — the per-file ablation baseline: the old pattern of one
  generator op per file (each itself batched over its blocks, PR 2), spawned
  concurrently. Quorum rounds scale O(F).

Reported per point: quorum rounds, messages, MB moved (codec-framed wire
bytes) and virtual-time latency of the whole fan-out, for a read fan-out and
an incremental-edit write fan-out. Latency separates less dramatically than
rounds (the NIC serialization model charges the same payload bytes either
way); rounds/messages are the §VII-D-style metric this refactor targets.

    PYTHONPATH=src python benchmarks/bench_multifile.py
"""
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import make_dss
from repro.core.api import gather

F_LIST = (1, 2, 4, 8, 16)
FILE_SIZE = 1 << 16                       # 64 KiB, ~8 blocks per file
BLOCK = (1 << 12, 1 << 13, 1 << 15)
N_SERVERS = 11
PARITY = 5


def _setup(F: int, seed: int):
    dss = make_dss("coaresecf", n_servers=N_SERVERS, parity=PARITY, seed=seed,
                   block=BLOCK, indexed=True, batched=True)
    rng = np.random.default_rng(seed)
    docs = {
        f"f{i}": rng.integers(0, 256, FILE_SIZE, dtype=np.uint8).tobytes()
        for i in range(F)
    }
    boot = dss.session("boot")
    assert all(s["success"] for s in gather(*[boot.write(f, d)
                                              for f, d in docs.items()]))
    dss.net.run()
    return dss, docs


def _edits(docs: dict, seed: int) -> dict:
    rng = np.random.default_rng(seed + 1)
    out = {}
    for f, d in docs.items():
        buf = bytearray(d)
        pos = int(rng.integers(0, len(buf) - 16))
        buf[pos : pos + 16] = bytes(16)
        out[f] = bytes(buf)
    return out


def _one(F: int, mode: str, seed: int = 71) -> list[dict]:
    """One read fan-out + one write fan-out over F files; returns two rows."""
    dss, docs = _setup(F, seed)
    edits = _edits(docs, seed)
    rows = []
    for phase, payload in (("read", None), ("write", edits)):
        cid = f"{mode[0]}{phase[0]}"
        c0 = dss.net.client_totals(cid)
        t0 = dss.net.now
        if mode == "session":
            s = dss.session(cid)
            if phase == "read":
                futs = [s.read(f) for f in docs]
            else:
                futs = [s.write(f, payload[f]) for f in docs]
            results = gather(*futs)
        else:  # legacy: one generator op per file, spawned concurrently
            h = dss.client(cid)
            if phase == "read":
                futs = [dss.net.spawn(h.read(f), client=cid) for f in docs]
            else:
                futs = [dss.net.spawn(h.update(f, payload[f]), client=cid)
                        for f in docs]
            dss.net.run()
            assert all(f.done for f in futs)
            results = [f.result for f in futs]
        if phase == "read":
            assert results == list(docs.values()), "read fan-out corrupted"
        else:
            assert all(s["success"] for s in results)
        c1 = dss.net.client_totals(cid)
        rows.append({
            "bench": "multifile", "mode": mode, "phase": phase, "files": F,
            "quorum_rounds": c1[0] - c0[0],
            "msg_count": c1[1] - c0[1],
            "MB_sent": (c1[2] - c0[2]) / 1e6,
            "fanout_ms": (dss.net.now - t0) * 1e3,
        })
    return rows


def run() -> list[dict]:
    rows = []
    for F in F_LIST:
        for mode in ("legacy", "session"):
            rows.extend(_one(F, mode))
    # headline check: session-path discovery/gather rounds are flat in F
    by_key = {(r["mode"], r["phase"], r["files"]): r["quorum_rounds"]
              for r in rows}
    for phase in ("read", "write"):
        flat = {f: by_key[("session", phase, f)] for f in F_LIST}
        assert len(set(flat.values())) == 1, f"session {phase} not O(1): {flat}"
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
