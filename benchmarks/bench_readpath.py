"""§VII-D — "sequential per-block requests dominate the read overhead".

Indexed-FM EC read path, before/after the ISSUE 2 batching refactor: for a
B-block file, a cold reader either issues B independent per-block quorum ops
(``batched=False`` — the previous Join-based path) or ONE multi-object
``ec-query-batch`` round with a single fused GF(256) decode (``batched=True``).
Reported per point: quorum-round count, ``msg_count``, ``bytes_sent`` and
virtual-time read latency. Also includes the paper's own baseline — the
non-indexed linked-list walk — for scale.
"""
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import make_dss

SIZES = [1 << 20, 1 << 22, 1 << 24]   # 1, 4, 16 MB (128-256 KiB blocks)
N_SERVERS = 11
PARITY = 5


def _one(size: int, *, indexed: bool, batched: bool, seed: int = 59) -> dict:
    dss = make_dss("coaresecf", n_servers=N_SERVERS, parity=PARITY, seed=seed,
                   indexed=indexed, batched=batched)
    doc = np.random.default_rng(seed).integers(0, 256, size, dtype=np.uint8).tobytes()
    w = dss.client("w")
    stats = dss.net.run_op(w.update("f", doc), client="w")
    r = dss.client("r")   # cold reader: no local (c.tag, c.val) cache
    r0, m0, b0, t0 = (dss.net.rpc_rounds, dss.net.msg_count,
                      dss.net.bytes_sent, dss.net.now)
    got = dss.net.run_op(r.read("f"), client="r")
    assert got == doc, "read returned different bytes"
    return {
        "blocks": stats["blocks"],
        "quorum_rounds": dss.net.rpc_rounds - r0,
        "msg_count": dss.net.msg_count - m0,
        "MB_sent": (dss.net.bytes_sent - b0) / 1e6,
        "read_ms": (dss.net.now - t0) * 1e3,
    }


def run() -> list[dict]:
    rows = []
    for size in SIZES:
        for label, indexed, batched in (
            ("walk", False, True),          # paper baseline: linked-list walk
            ("indexed", True, False),       # pre-ISSUE-2: Join of B quorum ops
            ("indexed+batch", True, True),  # ISSUE 2: one batched round
        ):
            rows.append({
                "bench": "readpath", "path": label, "file_size": size,
                **_one(size, indexed=indexed, batched=batched),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
