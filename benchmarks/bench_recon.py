"""Paper Fig. 8/9/10 — R/W latency under live reconfigurations.

Scenarios: (same) recon to identical DAP; (random) DAP flips; (mixed)
DAP flips + server-count changes — with concurrent readers/writers, for both
CoARES and CoARESF variants.
"""
from __future__ import annotations

from benchmarks.common import make_dss, run_workload

SCENARIOS = {
    "same": [("ec_opt", 11)] * 3,
    "random_dap": [("abd", 11), ("ec_opt", 11), ("abd", 11)],
    "dap_and_servers": [("abd", 7), ("ec_opt", 9), ("abd", 5)],
}


def run() -> list[dict]:
    rows = []
    for alg in ("coaresec", "coaresecf", "coaresabd", "coaresabdf"):
        for scen, plan in SCENARIOS.items():
            dss = make_dss(alg, n_servers=11, parity=5 if "ec" in alg else 1,
                           seed=17)
            res = run_workload(
                dss, file_size=1 << 22, n_writers=2, n_readers=2, ops_each=4,
                recons=len(plan), recon_int=0.03, recon_plan=plan, seed=19,
            )
            rows.append({"bench": f"recon_{scen}", "algorithm": alg,
                         **res.row()})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
