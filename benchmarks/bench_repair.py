"""Self-healing repair benchmark (ISSUE 1): durability-restoration time and
foreground-latency interference vs. crash count.

For each crash count c in 0..f (f = ⌊(n-k)/2⌋):

  1. boot a file on a CoARESEC store, run a foreground read/write workload;
  2. mid-workload, crash c servers, keep writing (they fall behind), then
     recover them stale;
  3. start a RepairController pass CONCURRENTLY with more foreground traffic;
  4. report: repair-pass virtual duration (time to restored redundancy),
     bytes moved by repair, and foreground read/write latency with repair
     running vs. the no-repair baseline (interference).

Run directly (``PYTHONPATH=src python benchmarks/bench_repair.py``) or via
``python -m benchmarks.run --only repair``.
"""
from __future__ import annotations

import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent.parent))

from repro.core import DSS, DSSParams, RepairController
from repro.net.sim import LatencyModel, Sleep

N_SERVERS = 10
PARITY_M = 6           # k = 4, f = (n-k)/2 = 3
FILE_SIZE = 1 << 20
OPS_EACH = 6


def _one_trial(crash_count: int, with_repair: bool, seed: int = 23) -> dict:
    lat = LatencyModel(base_lo=0.1e-3, base_hi=0.3e-3, bandwidth=125e6)
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=N_SERVERS,
                        parity_m=PARITY_M, seed=seed, latency=lat))
    rng = np.random.default_rng(seed)
    doc = rng.integers(0, 256, FILE_SIZE, dtype=np.uint8).tobytes()
    boot = dss.client("boot")
    dss.net.run_op(boot.update("f", doc), client="boot")

    # phase 1: crash c servers, keep writing so they fall behind, recover stale
    down = [f"s{i}" for i in range(crash_count)]
    dss.crash_servers(down)
    w0 = dss.client("w0")
    for i in range(3):
        buf = bytearray(doc)
        buf[i] ^= 0xFF
        doc = bytes(buf)
        dss.net.run_op(w0.update("f", doc), client="w0")
    dss.recover_servers(down)

    # phase 2: foreground traffic racing the repair pass
    base_t = dss.net.now
    base_bytes = dss.net.bytes_sent
    futs = []
    w = dss.client("w")

    def wloop():
        nonlocal doc
        for _ in range(OPS_EACH):
            yield Sleep(float(rng.uniform(0, 5e-3)))
            cur = yield from w.read("f")
            buf = bytearray(cur)
            buf[int(rng.integers(0, len(buf)))] ^= 0xFF
            yield from w.update("f", bytes(buf))
        return True

    r = dss.client("r")

    def rloop():
        for _ in range(OPS_EACH):
            yield Sleep(float(rng.uniform(0, 5e-3)))
            yield from r.read("f")
        return True

    futs.append(dss.net.spawn(wloop(), client="w"))
    futs.append(dss.net.spawn(rloop(), client="r"))
    repair_fut = None
    if with_repair:
        rc = RepairController(dss.net, dss.c0, 0, history=dss.history)
        repair_fut = dss.net.spawn(rc.scan_and_repair(["f"]), client="repair",
                                   kind="repair-pass")
    dss.net.run()
    assert all(f.done for f in futs)

    wl = [rec.end - rec.start for rec in dss.history
          if rec.kind == "write" and rec.start >= base_t and rec.client == "w"]
    rl = [rec.end - rec.start for rec in dss.history
          if rec.kind == "read" and rec.start >= base_t and rec.client == "r"]
    out = {
        "write_ms": float(np.mean(wl)) * 1e3 if wl else 0.0,
        "read_ms": float(np.mean(rl)) * 1e3 if rl else 0.0,
        "MB_sent": (dss.net.bytes_sent - base_bytes) / 1e6,
    }
    if repair_fut is not None:
        assert repair_fut.done
        stats = repair_fut.result[0]
        out["repair_ms"] = repair_fut.latency * 1e3
        out["repaired_servers"] = stats["applied"]
    return out


def run() -> list[dict]:
    rows = []
    f_max = (N_SERVERS - (N_SERVERS - PARITY_M)) // 2
    for c in range(f_max + 1):
        base = _one_trial(c, with_repair=False)
        rep = _one_trial(c, with_repair=True)
        rows.append({
            "bench": "repair",
            "crashes": c,
            "repair_ms": rep.get("repair_ms", 0.0),
            "repaired_servers": rep.get("repaired_servers", 0),
            "write_ms": rep["write_ms"],
            "read_ms": rep["read_ms"],
            "write_ms_baseline": base["write_ms"],
            "read_ms_baseline": base["read_ms"],
            "write_interference":
                rep["write_ms"] / base["write_ms"] if base["write_ms"] else 1.0,
            "read_interference":
                rep["read_ms"] / base["read_ms"] if base["read_ms"] else 1.0,
            "repair_MB": rep["MB_sent"] - base["MB_sent"],
        })
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
