"""Paper Fig. 6/7 — latency vs number of participants.

Sweeps servers {3,5,7,9,11} with paper-matched parity (m grows with n to
hold fault tolerance), and writers/readers {1,3,5} with the other fixed.
File 256 KiB (1:16 of the paper's 4 MB).
"""
from __future__ import annotations

from benchmarks.common import make_dss, run_workload

PARITY = {3: 1, 5: 2, 7: 3, 9: 4, 11: 5}
ALGOS = ["coabd", "coabdf", "coaresabd", "coaresabdf", "coaresec", "coaresecf"]


def run() -> list[dict]:
    rows = []
    size = 1 << 22  # 4 MiB (paper uses 4 MB here)
    for alg in ALGOS:
        for n in (3, 5, 7, 9, 11):
            dss = make_dss(alg, n_servers=n,
                           parity=PARITY[n] if "ec" in alg else 1, seed=3)
            res = run_workload(dss, file_size=size, n_writers=2, n_readers=2,
                               ops_each=4, seed=n)
            rows.append({"bench": "scal_servers", "algorithm": alg,
                         "servers": n, **res.row()})
        for nw in (1, 3, 5):
            dss = make_dss(alg, n_servers=7,
                           parity=3 if "ec" in alg else 1, seed=5)
            res = run_workload(dss, file_size=size, n_writers=nw, n_readers=2,
                               ops_each=3, seed=nw)
            rows.append({"bench": "scal_writers", "algorithm": alg,
                         "writers": nw, **res.row()})
        for nr in (1, 3, 5):
            dss = make_dss(alg, n_servers=7,
                           parity=3 if "ec" in alg else 1, seed=6)
            res = run_workload(dss, file_size=size, n_writers=2, n_readers=nr,
                               ops_each=3, seed=nr)
            rows.append({"bench": "scal_readers", "algorithm": alg,
                         "readers": nr, **res.row()})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
