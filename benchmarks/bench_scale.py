"""Scale-out harness benchmark (ISSUE 7, ROADMAP 5a): 10^3..10^5 sessions.

Drives ``WorkloadGen`` — zipfian file popularity, a 95/5 read/write mix,
session arrival churn — through the Session tier at three population scales,
on both network engines:

* ``fast``   — the one-event-per-fan-out vectorised hot path (default);
* ``legacy`` — the seed's per-destination closures (``fast_net=False``),
  which replays the *same trace* (same seed ⇒ identical rounds, bytes,
  virtual times) while paying the per-message driver costs.

Because both engines execute byte-identical traces, every wall-clock delta
is pure driver overhead. Each row reports end-to-end wall time plus the
**driver / protocol** split (``Network.profile_protocol``): protocol time is
seconds inside op-generator bodies and ``Server.handle`` — storage-system
work identical on both engines — and driver time is everything else the
simulator does (heap, closures, RNG, framing, delivery bookkeeping).
``driver_events_per_sec`` = events / driver seconds is the engine-comparison
headline and the floor gated in ``make bench-smoke``.

Method notes: one small untimed warmup run absorbs one-time JIT/compile cost
(the CDC kernel path), and the collector is frozen around each timed run —
at 10^4+ sessions the live heap is large enough that gen-2 passes otherwise
dominate, more so for the allocation-heavy legacy engine.

    make bench-scale                      # 10^3 + 10^4, both engines
    PYTHONPATH=src python benchmarks/bench_scale.py --sessions 100000 \
        --legacy-at ''                    # the 10^5 run, fast engine only
"""
from __future__ import annotations

import argparse
import gc
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

from repro.core import DSS, DSSParams, WorkloadGen, WorkloadSpec  # noqa: E402
from repro.net.sim import LatencyModel  # noqa: E402


def _dss(fast: bool, seed: int) -> DSS:
    # small files / small blocks: protocol work per op stays modest, so the
    # session count — not payload coding — is what the benchmark scales.
    lat = LatencyModel(base_lo=0.1e-3, base_hi=0.3e-3, bandwidth=125e6)
    return DSS(DSSParams(
        algorithm="coaresecf", n_servers=6, parity_m=2, seed=seed,
        min_block=256, avg_block=512, max_block=2048,
        indexed=True, batched=True, latency=lat, fast_net=fast,
    ))


def scale_trial(sessions: int, fast: bool, *, seed: int = 7, files: int = 64,
                read_fraction: float = 0.95, file_size: int = 1024,
                gateway: bool = False, freeze_gc: bool = True) -> dict:
    """One timed run at ``sessions`` population on one engine; returns a
    flat row. Identical (spec, seed) on both engines replays an identical
    trace, so rounds/bytes/virtual columns must match across the pair."""
    dss = _dss(fast, seed)
    dss.net.profile_protocol = True
    gen = WorkloadGen(
        WorkloadSpec(sessions=sessions, files=files, file_size=file_size,
                     read_fraction=read_fraction, ops_per_session=1),
        seed=seed,
    )
    via = dss.gateway() if gateway else None
    gc.collect()
    if freeze_gc:
        gc.freeze()
        gc.disable()
    t0 = time.perf_counter()
    try:
        rep = gen.run(dss, via=via)
    finally:
        if freeze_gc:
            gc.enable()
            gc.unfreeze()
    if via is not None:
        via.stop()
    wall = time.perf_counter() - t0
    proto = dss.net.protocol_time
    driver = max(wall - proto, 1e-9)
    row = {
        "bench": "scale",
        "engine": "fast" if fast else "legacy",
        "sessions": sessions,
        "wall_s": round(wall, 3),
        "protocol_s": round(proto, 3),
        "driver_s": round(driver, 3),
        "events": rep["events"],
        "events_per_sec": round(rep["events"] / wall),
        "driver_events_per_sec": round(rep["events"] / driver),
        "ops_per_sec": round(rep["ops"] / wall),
        "rpc_rounds": rep["rpc_rounds"],
        "msg_count": rep["msg_count"],
        "MB_sent": round(rep["bytes_sent"] / 1e6, 3),
        "ops_done": rep["ops_done"],
        "ops_failed": rep["ops_failed"],
        "ops_stuck": rep["ops_stuck"],
        "virtual_makespan": round(rep["virtual_makespan"], 6),
    }
    for k in ("op_p50", "op_p99", "read_p50", "read_p99"):
        if k in rep:
            row[k] = round(rep[k] * 1e3, 4)  # virtual ms
    return row


def warmup() -> None:
    """Untimed mini-run: pays one-time JIT compilation (CDC/coding kernels)
    so the first timed row is not charged for it."""
    scale_trial(20, True, seed=1, files=4, freeze_gc=False)


def run(sessions: list[int], legacy_at: list[int], *,
        gateway: bool = False, seed: int = 7) -> list[dict]:
    warmup()
    rows = []
    for n in sessions:
        fast_row = scale_trial(n, True, seed=seed, gateway=gateway)
        rows.append(fast_row)
        print(fast_row)
        if n in legacy_at:
            legacy_row = scale_trial(n, False, seed=seed, gateway=gateway)
            rows.append(legacy_row)
            print(legacy_row)
            for k in ("events", "rpc_rounds", "msg_count", "MB_sent",
                      "ops_done", "virtual_makespan"):
                assert fast_row[k] == legacy_row[k], (
                    f"trace divergence at {n} sessions: "
                    f"{k} fast={fast_row[k]} legacy={legacy_row[k]}"
                )
            print({
                "bench": "scale_ratio", "sessions": n,
                "wall_ratio": round(legacy_row["wall_s"] / fast_row["wall_s"], 2),
                "driver_ratio": round(
                    legacy_row["driver_s"] / fast_row["driver_s"], 2),
            })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sessions", default="1000,10000",
                    help="comma-separated session counts (fast engine)")
    ap.add_argument("--legacy-at", default=None,
                    help="session counts to ALSO run on the legacy engine "
                         "(default: every --sessions count; '' disables)")
    ap.add_argument("--gateway", action="store_true",
                    help="attach every session through a shared Gateway")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args()
    counts = [int(x) for x in args.sessions.split(",") if x]
    legacy = (counts if args.legacy_at is None
              else [int(x) for x in args.legacy_at.split(",") if x])
    out_rows = run(counts, legacy, gateway=args.gateway, seed=args.seed)
    if args.json:
        p = Path(args.json)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(out_rows, indent=2))
        print(f"scale: wrote {len(out_rows)} rows to {p}", file=sys.stderr)
