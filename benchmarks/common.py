"""Shared workload driver for the paper-figure benchmarks.

Reproduces §VII-C's stochastic invocation scheme: each writer/reader picks a
uniform-random think time in [0, int] between ops (virtual seconds), writers
do read-modify-write edits of the shared file, readers read. All latencies
are *virtual-time* (deterministic, seeded).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import DSS, DSSParams
from repro.net.sim import LatencyModel


@dataclass
class WorkloadResult:
    write_avg: float
    read_avg: float
    recon_avg: float
    writes_ok: int
    writes_total: int
    reads: int
    bytes_sent: float
    virtual_end: float

    def row(self) -> dict:
        return {
            "write_ms": self.write_avg * 1e3,
            "read_ms": self.read_avg * 1e3,
            "recon_ms": self.recon_avg * 1e3,
            "write_success": self.writes_ok / max(1, self.writes_total),
            "GB_sent": self.bytes_sent / 1e9,
        }


def make_dss(algorithm: str, n_servers: int, parity: int, seed: int,
             block: tuple[int, int, int] = (1 << 17, 1 << 18, 1 << 20),
             indexed: bool = False, batched: bool = True) -> DSS:
    # Latency model calibrated to the paper's Emulab LAN: sub-ms base RTT,
    # 1 Gbit/s — block transfers (2 ms at 256 KiB) dominate round trips,
    # the same regime as the paper's 1 MB blocks.
    lat = LatencyModel(base_lo=0.1e-3, base_hi=0.3e-3, bandwidth=125e6)
    return DSS(DSSParams(
        algorithm=algorithm, n_servers=n_servers, parity_m=parity, seed=seed,
        min_block=block[0], avg_block=block[1], max_block=block[2],
        latency=lat, indexed=indexed, batched=batched,
    ))


def run_workload(
    dss: DSS,
    *,
    file_size: int,
    n_writers: int = 2,
    n_readers: int = 2,
    ops_each: int = 5,
    w_int: float = 0.01,
    r_int: float = 0.01,
    recons: int = 0,
    recon_int: float = 0.05,
    recon_plan=None,
    seed: int = 0,
) -> WorkloadResult:
    rng = np.random.default_rng(seed)
    doc = rng.integers(0, 256, file_size, dtype=np.uint8).tobytes()
    boot = dss.client("boot")
    dss.net.run_op(boot.update("f", doc), client="boot")
    base_t = dss.net.now
    futs = []

    for wi in range(n_writers):
        w = dss.client(f"w{wi}")

        def wloop(w=w, wi=wi):
            for op in range(ops_each):
                yield from _sleep(dss, rng.uniform(0, w_int))
                cur = yield from w.read("f")
                buf = bytearray(cur)
                if buf:
                    pos = int(rng.integers(0, len(buf)))
                    buf[pos] ^= 0xFF
                yield from w.update("f", bytes(buf))
            return True

        futs.append(dss.net.spawn(wloop(), client=f"w{wi}"))

    for ri in range(n_readers):
        r = dss.client(f"r{ri}")

        def rloop(r=r):
            for op in range(ops_each):
                yield from _sleep(dss, rng.uniform(0, r_int))
                yield from r.read("f")
            return True

        futs.append(dss.net.spawn(rloop(), client=f"r{ri}"))

    if recons:
        g = dss.client("g")

        def gloop():
            for i in range(recons):
                yield from _sleep(dss, recon_int)
                if recon_plan:
                    dap, n = recon_plan[i % len(recon_plan)]
                    cfg = dss.make_config(dap=dap, n_servers=n)
                else:
                    cfg = dss.make_config()
                yield from g.recon("f", cfg)
            return True

        futs.append(dss.net.spawn(gloop(), client="g"))

    dss.net.run()
    assert all(f.done for f in futs), "workload op failed to terminate"
    wl, rl, gl = [], [], []
    wok = wtot = nreads = 0
    for rec in dss.history:
        if rec.start < base_t:
            continue
        dur = rec.end - rec.start
        if rec.kind in ("fm-update",) or (rec.kind == "write" and "ckpt" not in rec.obj):
            if rec.kind == "fm-update" or rec.obj == "f":
                wl.append(dur)
                wtot += 1
                wok += int(rec.flag == "chg" or (rec.extra or {}).get("success", False))
        elif rec.kind in ("fm-read",) or (rec.kind == "read" and rec.obj == "f"):
            rl.append(dur)
            nreads += 1
        elif rec.kind in ("fm-recon", "recon"):
            gl.append(dur)
    # for non-fragmented algorithms both "write" (block) and nothing else
    return WorkloadResult(
        write_avg=float(np.mean(wl)) if wl else 0.0,
        read_avg=float(np.mean(rl)) if rl else 0.0,
        recon_avg=float(np.mean(gl)) if gl else 0.0,
        writes_ok=wok, writes_total=wtot, reads=nreads,
        bytes_sent=dss.net.bytes_sent, virtual_end=dss.net.now,
    )


def _sleep(dss, dt):
    from repro.net.sim import Sleep

    yield Sleep(float(dt))
    return None
