"""Benchmark driver — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only filesize,...]

Prints ``name,us_per_call,derived`` CSV rows (latencies are virtual-time;
derived carries the figure-specific extras) and writes the full table to
runs/bench_results.json.
"""
import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent))

BENCHES = ["kernels", "filesize", "aws", "scalability", "blocksize", "recon",
           "checkpoint", "repair", "readpath"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(BENCHES)
    all_rows = []
    print("name,us_per_call,derived")
    for name in BENCHES:
        if name not in only:
            continue
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        rows = mod.run()
        wall = time.time() - t0
        for r in rows:
            r = dict(r)
            bench = r.pop("bench", name)
            us = None
            for k in ("write_ms", "read_ms", "save_full_ms", "restore_ms",
                      "cpu_ref_MBps", "cpu_MBps"):
                if k in r:
                    us = r[k] * 1e3 if k.endswith("_ms") else r[k]
                    break
            derived = ";".join(f"{k}={v if not isinstance(v, float) else round(v,4)}"
                               for k, v in r.items())
            print(f"{bench},{0.0 if us is None else round(us,2)},{derived}")
            all_rows.append({"bench": bench, **r})
        print(f"# {name}: {len(rows)} rows in {wall:.1f}s wall", file=sys.stderr)
    out = Path("runs/bench_results.json")
    out.parent.mkdir(exist_ok=True)
    out.write_text(json.dumps(all_rows, indent=1, default=str))


if __name__ == "__main__":
    main()
