"""Bench-harness smoke: each benchmark family's smallest point (ISSUE 2 CI).

Runs one tiny configuration through every benchmark's machinery —
``make_dss``/``run_workload``, the Session/future API fan-out, the repair
trial, the read-path and multifile trials, the checkpoint store and the
kernel timers — so an API drift in the harness breaks CI in seconds instead
of silently rotting until the next full benchmark run. Numbers printed here
are NOT meaningful measurements.

    make bench-smoke        # or: PYTHONPATH=src python -m benchmarks.smoke
    python -m benchmarks.smoke --json runs/smoke.json   # CI artifact
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).parent.parent))

from benchmarks.common import make_dss, run_workload

BLOCK = (1 << 10, 1 << 11, 1 << 13)
SIZE = 1 << 15  # 32 KiB files


def run() -> list[dict]:
    rows = []

    # --- filesize / scalability / blocksize family: one tiny workload ------
    for alg in ("coabdf", "coaresec", "coaresecf"):
        dss = make_dss(alg, n_servers=5, parity=1, seed=1, block=BLOCK)
        res = run_workload(dss, file_size=SIZE, n_writers=1, n_readers=1,
                           ops_each=1, seed=2)
        rows.append({"bench": "smoke_workload", "algorithm": alg, **res.row()})

    # --- recon family: one live reconfiguration with DAP flip --------------
    dss = make_dss("coaresecf", n_servers=5, parity=1, seed=3, block=BLOCK,
                   indexed=True)
    res = run_workload(dss, file_size=SIZE, n_writers=1, n_readers=1,
                       ops_each=1, recons=1, recon_int=0.005,
                       recon_plan=[("abd", 5)], seed=4)
    rows.append({"bench": "smoke_recon", "algorithm": "coaresecf", **res.row()})

    # --- aws family: the WAN latency model --------------------------------
    from benchmarks.bench_aws import _dss as aws_dss

    res = run_workload(aws_dss("coaresecf", indexed=True), file_size=SIZE,
                       n_writers=1, n_readers=1, ops_each=1, seed=5)
    rows.append({"bench": "smoke_aws", "algorithm": "coaresecf+pidx", **res.row()})

    # --- readpath family: smallest size, all three paths -------------------
    from benchmarks.bench_readpath import _one as readpath_one

    for label, indexed, batched in (("walk", False, True),
                                    ("indexed+batch", True, True)):
        rows.append({"bench": "smoke_readpath", "path": label,
                     **readpath_one(1 << 18, indexed=indexed, batched=batched)})

    # --- session API / multifile family (ISSUE 3): a 2-file Workload mix ---
    from repro.core.api import Workload

    dss = make_dss("coaresecf", n_servers=5, parity=1, seed=40, block=BLOCK,
                   indexed=True)
    docs = {f"m{i}": np.random.default_rng(41 + i)
            .integers(0, 256, SIZE, dtype=np.uint8).tobytes() for i in range(2)}
    wl = Workload(dss)
    for fid, doc in docs.items():
        wl.write("w", fid, doc)           # one coalesced write fan-out...
    for fid in docs:
        wl.read("w", fid)                 # ...then one read fan-out (program
    for fid in docs:                      # order holds within a session)
        wl.stat("w", fid)
    results = wl.run()
    assert results[2] == docs["m0"] and results[3] == docs["m1"]
    st = wl.futures[0].stats
    rows.append({"bench": "smoke_session", "files": 2,
                 "write_rounds": st.rounds, "write_msgs": st.msgs,
                 "write_MB": st.bytes / 1e6, "batched_with": st.batched_with,
                 "min_margin": min(r["margin"] for r in results[4:])})

    from benchmarks.bench_multifile import _one as multifile_one

    for mode in ("legacy", "session"):
        rows.extend(multifile_one(2, mode))

    # --- gateway family (ISSUE 4): 3-client same-file merge + gossip -------
    dss = make_dss("coaresecf", n_servers=5, parity=1, seed=50, block=BLOCK,
                   indexed=True)
    doc = np.random.default_rng(51).integers(0, 256, SIZE, dtype=np.uint8).tobytes()
    assert dss.session("boot").write("hot", doc).result()["success"]
    gw = dss.gateway()
    riders = [dss.session(f"c{i}", via=gw) for i in range(3)]
    r0 = dss.net.rpc_rounds
    futs = [s.read("hot") for s in riders]
    from repro.core.api import gather

    assert gather(*futs) == [doc] * 3
    gw.stop()
    rows.append({"bench": "smoke_gateway", "clients": 3,
                 "read_rounds": dss.net.rpc_rounds - r0,
                 "dedup_saved": gw.stats["dedup_saved"],
                 "batched_with": futs[0].stats.batched_with})

    from benchmarks.bench_gateway import _gossip_trial

    rows.append({**_gossip_trial(seed=52), "bench": "smoke_gossip"})

    # --- repair family: one crash/recover/repair trial ---------------------
    from benchmarks.bench_repair import _one_trial

    rows.append({"bench": "smoke_repair", **_one_trial(1, with_repair=True)})

    # --- checkpoint family: tiny train state -------------------------------
    from benchmarks.bench_checkpoint import _fake_state
    from repro.train.checkpoint import ECCheckpointStore

    store = ECCheckpointStore(n_hosts=6, parity=1, algorithm="coaresecf",
                              seed=6, min_block=BLOCK[0], avg_block=BLOCK[1],
                              max_block=BLOCK[2], indexed=True)
    store.save(1, _fake_state(0.25, seed=7))
    store.restore()
    rows.append({"bench": "smoke_checkpoint",
                 "MB_sent": store.dss.net.bytes_sent / 1e6})

    # --- kernels family: one small RS encode + CDC pass --------------------
    from repro.erasure import RSCode
    from repro.kernels.cdc_gearhash.ops import split_chunks

    code = RSCode(n=6, k=4)
    data = np.random.default_rng(8).integers(0, 256, (3, 4, 1 << 10),
                                             dtype=np.uint8)
    assert code.decode_batch(code.encode_batch(data)[:, :4], [0, 1, 2, 3]).shape == data.shape
    chunks = split_chunks(bytes(data.reshape(-1)), min_size=256, avg_size=512,
                          max_size=2048)
    rows.append({"bench": "smoke_kernels", "chunks": len(chunks)})

    # --- scale family (ISSUE 7): zipfian session harness, both engines -----
    # A small population through WorkloadGen on the fast and legacy network
    # engines: the pair must replay an IDENTICAL trace (same rounds/bytes/
    # virtual time — the fast path's correctness contract), and the fast
    # engine's driver_events_per_sec is gated as a floor so a silent fall
    # back to per-message driver costs fails CI.
    from benchmarks.bench_scale import scale_trial, warmup as scale_warmup

    scale_warmup()
    fast_row = scale_trial(300, True, seed=9, files=16)
    legacy_row = scale_trial(300, False, seed=9, files=16)
    for key in ("events", "rpc_rounds", "msg_count", "MB_sent", "ops_done",
                "virtual_makespan"):
        assert fast_row[key] == legacy_row[key], (
            f"fast/legacy trace divergence: {key} "
            f"{fast_row[key]} != {legacy_row[key]}"
        )
    rows.append({**fast_row, "bench": "smoke_scale"})
    rows.append({**legacy_row, "bench": "smoke_scale"})

    # --- coding family (ISSUE 6): kernel-backend batched-bytes throughput --
    # The one wall-clock metric the smoke gate checks as a FLOOR: a routing
    # regression that silently drops the data path back to the byte-LUT
    # backend shows up as an order-of-magnitude throughput loss here.
    import time

    kcode = RSCode(n=12, k=10, backend="kernel")
    vals = [np.random.default_rng(60 + i)
            .integers(0, 256, 1 << 18, dtype=np.uint8).tobytes()
            for i in range(4)]
    sub = (0, 2, 3, 4, 5, 6, 7, 8, 9, 11)  # mixed subset -> real decode matmul

    def _cycle():
        enc = kcode.encode_bytes_batch(vals)
        return kcode.decode_bytes_batch(
            [({i: f[i] for i in sub}, o) for f, o in enc]
        )

    assert _cycle() == vals  # correctness + jit warmup (warmup not timed)
    iters = 3
    t0 = time.perf_counter()
    for _ in range(iters):
        _cycle()
    dt = (time.perf_counter() - t0) / iters
    rows.append({"bench": "smoke_coding", "backend": "kernel",
                 "coding_mbps": 2 * sum(len(v) for v in vals) / 1e6 / dt})

    # --- chaos family (ISSUE 10): beyond-quorum storm, smallest point ------
    # Retry machinery armed, EVERY server crashes then recovers; the rows'
    # availability / stuck / amplification floors are gated below alongside
    # the full `make bench-chaos` run (same baseline file).
    from benchmarks.bench_chaos import run as chaos_run

    rows.extend(chaos_run(sessions=16))
    return rows


def check_baseline(rows: list[dict], baseline_path,
                   benches: set[str] | None = None) -> list[str]:
    """Regression gate (ISSUE 4 satellite): compare the smoke rows against
    the checked-in quorum-round baseline. Each baseline metric names a
    ``bench`` (plus optional ``match`` row filters), a row ``field``, the
    expected ``baseline`` value and a per-metric ``tolerance``; a matching
    row whose value exceeds ``baseline + tolerance`` — or a metric whose
    rows disappeared — is a failure. Values well UNDER baseline are only
    reported (an improvement should be locked in by re-baselining).

    ``direction`` (ISSUE 6) flips the gate for bigger-is-better metrics:
    with ``"min"``, a value BELOW ``baseline - tolerance`` is the failure
    (e.g. ``coding_mbps`` collapsing back to byte-LUT speed) and a value
    above ``baseline + tolerance`` is the reported improvement. The default
    ``"max"`` keeps the original round-count semantics.

    ``benches`` (ISSUE 10) restricts the gate to metrics naming one of the
    given bench labels — ``bench_chaos`` shares this baseline file but only
    produces the chaos rows, so it must not fail the smoke-only metrics."""
    spec = json.loads(Path(baseline_path).read_text())
    failures: list[str] = []
    for m in spec["metrics"]:
        if benches is not None and m["bench"] not in benches:
            continue
        want = {"bench": m["bench"], **m.get("match", {})}
        direction = m.get("direction", "max")
        matching = [r for r in rows
                    if all(r.get(k) == v for k, v in want.items())]
        if not matching:
            failures.append(f"{want}: no smoke row matches this metric")
            continue
        for row in matching:
            got = row.get(m["field"])
            lo, hi = m["baseline"] - m["tolerance"], m["baseline"] + m["tolerance"]
            if got is None:
                failures.append(f"{want}: row lacks field {m['field']!r}")
            elif (got > hi) if direction == "max" else (got < lo):
                failures.append(
                    f"{want} {m['field']}={got} regressed past "
                    f"baseline {m['baseline']} (±{m['tolerance']} tolerance, "
                    f"direction {direction})"
                )
            elif (got < lo) if direction == "max" else (got > hi):
                print(f"smoke: {want} {m['field']}={got} improved on "
                      f"baseline {m['baseline']} — consider re-baselining",
                      file=sys.stderr)
    return failures


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as a JSON array (CI artifact)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="fail if quorum-round metrics regress versus this "
                         "checked-in baseline (benchmarks/smoke_baseline.json)")
    args = ap.parse_args()
    rows = run()
    for r in rows:
        print(r)
    if args.json:
        out = Path(args.json)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rows, indent=2, default=str))
        print(f"smoke: wrote {len(rows)} rows to {out}", file=sys.stderr)
    if args.baseline:
        failures = check_baseline(rows, args.baseline)
        if failures:
            for f in failures:
                print(f"smoke: REGRESSION: {f}", file=sys.stderr)
            sys.exit(1)
        print(f"smoke: baseline check passed ({args.baseline})", file=sys.stderr)
    print("smoke: all benchmark harnesses ran", file=sys.stderr)
