"""Quickstart: the paper's system in 60 lines — Session/future API.

Spins up a CoARESF deployment (fragmented + erasure-coded + reconfigurable),
writes a batch of large objects in ONE coalesced fan-out, reads them back,
inspects reliability margins, survives server crashes, and live-reconfigures
to a new server set — all on the deterministic virtual-time network.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DSS, DSSParams, gather

# --- deploy: 8 servers, [n=8, k=6] Reed-Solomon, EC-DAPopt, fragmented -----
dss = DSS(DSSParams(algorithm="coaresecf", n_servers=8, parity_m=2, seed=0,
                    min_block=4096, avg_block=16384, max_block=65536,
                    indexed=True))
alice = dss.session("alice")
bob = dss.session("bob")
print(f"deployed CoARESECF: n={dss.c0.n} k={dss.c0.k} "
      f"quorum={dss.c0.quorum()} tolerates {(dss.c0.n-dss.c0.k)//2} crashes")

# --- write three 1 MB files in ONE coalesced fan-out -------------------------
rng = np.random.default_rng(0)
docs = {f"report{i}.bin": rng.integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
        for i in range(3)}
futs = [alice.write(fid, doc) for fid, doc in docs.items()]
stats = gather(*futs)                       # drive the net; results in order
st = futs[0].stats                          # uniform OpStats on every future
print(f"write: {sum(s['blocks'] for s in stats)} CDC blocks across "
      f"{len(docs)} files in {st.rounds} quorum rounds total "
      f"(coalesced x{st.batched_with}; {st.bytes/1e6:.1f} MB on the wire)")

# --- read them back ----------------------------------------------------------
reads = [bob.read(fid) for fid in docs]
assert gather(*reads) == list(docs.values())
print(f"read: OK ({len(docs)} MiB-files, decoded from k-of-n fragments, "
      f"{reads[0].stats.rounds} quorum rounds for the whole fan-out)")

# --- incremental edit: only touched blocks rewrite ---------------------------
edit = bytearray(docs["report0.bin"])
edit[500_000:500_016] = b"EDITED-IN-PLACE!"
st2 = alice.write("report0.bin", bytes(edit)).result()
print(f"edit: rewrote {st2['written']}/{st2['blocks']} blocks "
      f"(rsync-style CDC — the paper's Fig.4 flat-write-latency effect)")

# --- reliability margin, before and after a crash ----------------------------
print(f"stat: margin={alice.stat('report0.bin').result()['margin']} "
      f"(fragment losses the weakest block still survives)")
dss.crash_servers(["s7"])
assert bob.read("report0.bin").result() == bytes(edit)
print(f"crash: s7 down, read still OK (EC quorum), "
      f"margin now {alice.stat('report0.bin').result()['margin']}")

# --- live reconfiguration to a fresh server set + ABD DAP --------------------
admin = dss.session("admin")
new_cfg = dss.make_config(dap="abd", n_servers=5, fresh_servers=True)
nblocks = admin.recon("report0.bin", new_cfg).result()["blocks"]
print(f"recon: migrated {nblocks} blocks to 5 fresh servers under ABD "
      f"(service stayed readable throughout)")
assert bob.read("report0.bin").result() == bytes(edit)
print("read after recon: OK — done.")

# --- legacy API (deprecated) -------------------------------------------------
# The pre-Session surface still works — one generator op per call, threaded
# through the sim runner by hand; kept as a shim for old call sites:
#   writer = dss.client("alice")
#   stats = dss.net.run_op(writer.update("report0.bin", doc), client="alice")
# Prefer dss.session(...): it coalesces concurrent ops across files into
# O(1)-round batches and returns futures carrying uniform OpStats.
