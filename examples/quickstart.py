"""Quickstart: the paper's system in 60 lines.

Spins up a CoARESF deployment (fragmented + erasure-coded + reconfigurable),
writes a large object, does an incremental edit, survives server crashes,
and live-reconfigures to a new server set — all on the deterministic
virtual-time network.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DSS, DSSParams

# --- deploy: 8 servers, [n=8, k=6] Reed-Solomon, EC-DAPopt, fragmented -----
dss = DSS(DSSParams(algorithm="coaresecf", n_servers=8, parity_m=2, seed=0,
                    min_block=4096, avg_block=16384, max_block=65536))
writer = dss.client("alice")
reader = dss.client("bob")
print(f"deployed CoARESECF: n={dss.c0.n} k={dss.c0.k} "
      f"quorum={dss.c0.quorum()} tolerates {(dss.c0.n-dss.c0.k)//2} crashes")

# --- write a 1 MB file -------------------------------------------------------
rng = np.random.default_rng(0)
doc = rng.integers(0, 256, 1 << 20, dtype=np.uint8).tobytes()
stats = dss.net.run_op(writer.update("report.bin", doc), client="alice")
print(f"write: {stats['blocks']} CDC blocks, all coded into n fragments "
      f"(virtual latency baked into dss.net.now={dss.net.now*1e3:.1f} ms)")

# --- read it back -------------------------------------------------------------
got = dss.net.run_op(reader.read("report.bin"), client="bob")
assert got == doc
print(f"read: OK ({len(got)>>20} MiB, decoded from k-of-n fragments)")

# --- incremental edit: only touched blocks rewrite ---------------------------
edit = bytearray(doc)
edit[500_000:500_016] = b"EDITED-IN-PLACE!"
stats2 = dss.net.run_op(writer.update("report.bin", bytes(edit)), client="alice")
print(f"edit: rewrote {stats2['written']}/{stats2['blocks']} blocks "
      f"(rsync-style CDC — the paper's Fig.4 flat-write-latency effect)")

# --- crash within the fault budget -------------------------------------------
dss.crash_servers(["s7"])
got2 = dss.net.run_op(reader.read("report.bin"), client="bob")
assert got2 == bytes(edit)
print("crash: s7 down, read still OK (EC quorum)")

# --- live reconfiguration to a fresh server set + ABD DAP ---------------------
g = dss.client("admin")
new_cfg = dss.make_config(dap="abd", n_servers=5, fresh_servers=True)
nblocks = dss.net.run_op(g.recon("report.bin", new_cfg), client="admin")
print(f"recon: migrated {nblocks} blocks to 5 fresh servers under ABD "
      f"(service stayed readable throughout)")
got3 = dss.net.run_op(reader.read("report.bin"), client="bob")
assert got3 == bytes(edit)
print("read after recon: OK — done.")
