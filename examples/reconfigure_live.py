"""The paper's §VII-E headline scenario (Fig. 10): the storage service keeps
serving concurrent readers/writers while a reconfigurer switches both the
DAP (ABD <-> EC) and the server set, five times.

  PYTHONPATH=src python examples/reconfigure_live.py
"""
import numpy as np

from repro.core import DSS, DSSParams

dss = DSS(DSSParams(algorithm="coaresecf", n_servers=11, parity_m=5, seed=42,
                    min_block=2048, avg_block=8192, max_block=32768))
rng = np.random.default_rng(1)
doc = rng.integers(0, 256, 256 * 1024, dtype=np.uint8).tobytes()
boot = dss.client("boot")
dss.net.run_op(boot.update("shared.bin", doc), client="boot")

writers = [dss.client(f"w{i}") for i in range(3)]
readers = [dss.client(f"r{i}") for i in range(3)]
admin = dss.client("admin")
futs = []

for wi, w in enumerate(writers):
    def wloop(w=w, wi=wi):
        n_ok = 0
        for r in range(4):
            cur = yield from w.read("shared.bin")
            buf = bytearray(cur)
            pos = (wi * 50_021 + r * 13_337) % max(1, len(buf))
            buf[pos] ^= 0xFF
            st = yield from w.update("shared.bin", bytes(buf))
            n_ok += st["success"]
        return n_ok
    futs.append(dss.net.spawn(wloop(), client=f"w{wi}", delay=0.002 * wi))

for ri, r in enumerate(readers):
    def rloop(r=r):
        sizes = []
        for _ in range(5):
            c = yield from r.read("shared.bin")
            sizes.append(len(c))
        return sizes
    futs.append(dss.net.spawn(rloop(), client=f"r{ri}", delay=0.0015 * ri))

def gloop():
    plans = [("abd", 7), ("ec_opt", 11), ("abd", 5), ("ec_opt", 9), ("ec_opt", 11)]
    for dap, n in plans:
        cfg = dss.make_config(dap=dap, n_servers=n)
        yield from admin.recon("shared.bin", cfg)
    return len(plans)

futs.append(dss.net.spawn(gloop(), client="admin", delay=0.004))
dss.net.run()

assert all(f.done for f in futs), "an operation failed to terminate"
recons = futs[-1].result
writes_ok = sum(f.result for f in futs[:3])
final = dss.net.run_op(dss.client("final").read("shared.bin"), client="final")
print(f"service uninterrupted: {recons} recons (ABD<->EC, 5-11 servers), "
      f"{writes_ok}/12 writes prevailed, {sum(len(f.result) for f in futs[3:6])} reads OK, "
      f"final file {len(final)>>10} KiB, virtual time {dss.net.now*1e3:.0f} ms")
