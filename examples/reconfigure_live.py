"""The paper's §VII-E headline scenario (Fig. 10), on the Session API: the
storage service keeps serving concurrent readers/writers while a
reconfigurer switches both the DAP (ABD <-> EC) and the server set, five
times. Scripted client loops ride ``Session.submit``; one-shot operations
use the write/read/recon futures.

  PYTHONPATH=src python examples/reconfigure_live.py
"""
import numpy as np

from repro.core import DSS, DSSParams, Workload

dss = DSS(DSSParams(algorithm="coaresecf", n_servers=11, parity_m=5, seed=42,
                    min_block=2048, avg_block=8192, max_block=32768,
                    indexed=True))
rng = np.random.default_rng(1)
doc = rng.integers(0, 256, 256 * 1024, dtype=np.uint8).tobytes()
dss.session("boot").write("shared.bin", doc).result()

wl = Workload(dss)

for wi in range(3):
    def wloop(s=wl.session(f"w{wi}"), wi=wi):
        # a scripted read-modify-write loop: drives the legacy generator
        # ops of s.handle, submitted as ONE session op with OpStats.
        n_ok = 0
        for r in range(4):
            cur = yield from s.handle.read("shared.bin")
            buf = bytearray(cur)
            pos = (wi * 50_021 + r * 13_337) % max(1, len(buf))
            buf[pos] ^= 0xFF
            st = yield from s.handle.update("shared.bin", bytes(buf))
            n_ok += st["success"]
        return n_ok
    wl.submit(f"w{wi}", wloop(), kind="writer-loop")

for ri in range(3):
    def rloop(s=wl.session(f"r{ri}")):
        sizes = []
        for _ in range(5):
            c = yield from s.handle.read("shared.bin")
            sizes.append(len(c))
        return sizes
    wl.submit(f"r{ri}", rloop(), kind="reader-loop")

def gloop(s=wl.session("admin")):
    plans = [("abd", 7), ("ec_opt", 11), ("abd", 5), ("ec_opt", 9), ("ec_opt", 11)]
    for dap, n in plans:
        cfg = dss.make_config(dap=dap, n_servers=n)
        yield from s.handle.recon("shared.bin", cfg)
    return len(plans)
wl.submit("admin", gloop(), kind="recon-loop")

results = wl.run()                # drives everything concurrently
writes_ok = sum(results[:3])
reads = sum(len(r) for r in results[3:6])
recons = results[-1]
admin_stats = wl.futures[-1].stats

final = dss.session("final").read("shared.bin")
print(f"service uninterrupted: {recons} recons (ABD<->EC, 5-11 servers, "
      f"{admin_stats.rounds} quorum rounds), {writes_ok}/12 writes prevailed, "
      f"{reads} reads OK, final file {len(final.result())>>10} KiB, "
      f"virtual time {dss.net.now*1e3:.0f} ms")

# legacy equivalent (deprecated): spawn each loop yourself and poll futures —
#   fut = dss.net.spawn(wloop(), client="w0"); dss.net.run(); fut.result
# the Workload/gather combinator above replaces that boilerplate.
