"""Batched decode serving demo (reduced config, CPU).

  PYTHONPATH=src python examples/serve_decode.py
"""
import sys

sys.argv = [sys.argv[0], "--arch", "qwen2_0_5b", "--batch", "4",
            "--cache-len", "128", "--tokens", "24", *sys.argv[1:]]
from repro.launch.serve import main

out = main()
assert out["tokens"].shape == (4, 24)
print("example OK")
