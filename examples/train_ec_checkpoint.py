"""End-to-end driver: train an LM with EC-coded quorum checkpointing,
crash the trainer AND two checkpoint hosts mid-run, restore, and finish.

  PYTHONPATH=src python examples/train_ec_checkpoint.py [--steps 60]

(Reduced gemma3-family config so it runs on CPU in ~a minute; pass
``--arch``/``--full`` per launch/train.py for cluster-scale runs.)
"""
import sys

sys.argv = [sys.argv[0], "--arch", "gemma3_1b", "--steps", "60",
            "--ckpt-every", "20", "--crash-at", "45", "--kill-hosts", "2",
            "--ckpt-hosts", "8", "--ckpt-parity", "4",
            *sys.argv[1:]]
from repro.launch.train import main

out = main()
losses = out["losses"]
assert losses[-1] < losses[0], "training must make progress"
print(f"example OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
      f"{len(out['ckpts'])} quorum checkpoints, survived trainer+2-host crash")
