"""Compose EXPERIMENTS.md: narrative + live tables from runs/."""
import json
import sys
from pathlib import Path

sys.path.insert(0, "src")
from repro.roofline.report import dryrun_table, load, roofline_table  # noqa: E402

rows = load("runs/dryrun")
base = load("runs/dryrun_baseline")
bench = json.load(open("runs/bench_results.json"))


def get(benchname, alg):
    return sorted(
        [r for r in bench if r["bench"] == benchname and r.get("algorithm") == alg],
        key=lambda r: r.get("file_size", r.get("servers", r.get("avg_block", 0))),
    )


def series(benchname, alg, key):
    return " / ".join(f"{r[key]:.0f}" for r in get(benchname, alg))


def cell(rows_, name):
    for d in rows_:
        if d["_cell"] == name:
            return d
    return None


def term(d, t):
    return d["roofline"][t]


HEAD = """# EXPERIMENTS — Fragmented ARES on a JAX/TPU-v5e framework

All distributed-storage latencies are **virtual-time** on the deterministic
network simulator (Emulab-calibrated: 1 Gbit/s, 0.1–0.3 ms base delay);
sizes are scaled 1:32 vs the paper (16 MB files / 256 KiB blocks vs 512 MB /
1 MB) keeping the transfer-vs-RTT regime. Dry-run/roofline numbers come from
`.lower().compile()` against 512 host placeholder devices (TPU v5e constants:
197 TFLOP/s bf16, 819 GB/s HBM, 4x50 GB/s ICI). Caveat everywhere: the CPU
backend emulates bf16 in f32, inflating byte counts ~2x uniformly; numbers
are comparable across configs, conservative in absolute terms.

## §Validation — paper claims reproduced

| paper claim | paper evidence | our result | verdict |
|---|---|---|---|
| Fragmented write latency ~flat vs file size; non-fragmented linear | Fig 4a | CoABD write {coabd_w} ms over 1→16 MB (15x growth) vs CoABDF {coabdf_w} (6x, flattening) | ✓ |
| Fragmented reads beat non-fragmented, gap grows | Fig 4b | CoABD {coabd_r} vs CoABDF {coabdf_r} | ✓ |
| **EC-DAPopt halves read latency vs EC-DAP on large files** | Fig 4 (§VI) | CoARESECF reads {ecf_r} vs no-opt {ecfno_r} — 1.9x at 16 MB | ✓✓ |
| CoARESEC write latency *decreases* with more servers (smaller fragments) while ABD-based grows/flat | Fig 6c | CoARESEC {ec_scal} ms over 3→11 servers vs CoABD {abd_scal} | ✓ |
| Too-small blocks hurt update latency; reads plateau with block size | Fig 11 | CoARESECF write {blk_w} ms over 8K→1M blocks | ✓ |
| k↑ (m↓) smaller fragments + bigger quorums; m↑ more fault tolerance | §VII-D | EC[12,10] vs [12,8]: quorum 11 vs 10, fragment 1/10 vs 1/8 of object; fault budget 1 vs 2 (tests) | ✓ |
| Service uninterrupted under concurrent recon + R/W; DAP switches live | Fig 8/9/10 | all recon scenarios complete; fragmented write success 1.00 vs 0.88 whole-object under contention | ✓ |
| Fragmentation boosts concurrent write success | Fig 4a text | same-block races: exactly one winner; disjoint-block races: all prevail (tests) | ✓ |
| **NEGATIVE result too**: on AWS/WAN conditions CoARESF reads do NOT beat CoARES ("stable overhead for each block request") | Fig 5b | WAN model (5-25 ms RTT): CoARESECF reads {aws_ecf_r} ms vs CoARESEC {aws_ec_r} at 2/8 MB — fragmentation loses, exactly as the paper found; the parallel index recovers it ({aws_pidx_r} ms) while still sending 2x fewer bytes | ✓✓ |

One divergence, faithfully reproduced then fixed: the paper itself observes
(AWS, Fig 5b) that CoARESF reads pay one configuration-discovery + block
round-trip *per block*, serially — our CoARESECF reads are likewise slower
than CoARESEC at 16 MB (73 vs 30 ms). The paper's future-work suggestion
("whether the multiple read block requests could be sent in parallel") is
implemented here as the **indexed genesis** (below): reads flatten to ~5 ms.

## §Beyond-paper — storage-layer optimizations

* **Parallel-index fragmented objects** (`FragmentationModule(indexed=True)`):
  the genesis block stores the ordered block-id index, so block reads/writes
  issue concurrently — O(1) quorum rounds instead of O(#blocks); connectivity
  reduces to one coverable genesis flip (supersedes the Lemma-13 walk).
  File reads 1→16 MB: {ecf_r} ms (linked list) → {pidx_r} ms (indexed).
  Checkpoint store: save 141.7→31.3 ms (4.5x), restore 83.6→10.8 ms (7.7x).
* **EC quorum checkpointing for training** (`train/checkpoint.py`): 8 MB
  train state over 12 hosts — EC[12,8] fragmented writes 12.1 MB on the wire
  vs 96.1 MB for replication (1.5x vs 12x storage overhead); *incremental*
  saves (only the data-pipeline counter changed) move **0.17 MB** vs 24.3 MB
  without the §VI optimization and 193 MB with replication; restores succeed
  with 2/12 hosts dead (k-of-n decode). Coverable meta-pointer flips make
  concurrent/stale trainer saves safe (tests: split-brain, resurrection).
* **Bitsliced GF(2) RS kernel**: arithmetic intensity 64mk/(k+m) FLOP/B
  (e.g. [12,10]: 107) — compute-bound on the MXU at ~680 GB/s/chip encode
  (analytic), vs the memory-bound byte-LUT formulation. Bit-identical to the
  LUT oracle across shapes/dtypes (tests).

## §Dry-run — every (arch x shape x mesh) cell

Summary: **{n_ok} cells compile + fit, {n_skip} documented skips
(long_500k on pure full-attention archs), 0 errors** across 10 archs x 4
shapes x {{16x16, 2x16x16}}. `memory_analysis()` / `cost_analysis()` excerpts
in runs/dryrun/*.json.

"""

TAIL = """

## §Perf — hillclimbing log (3 cells + storage layer)

Baselines (paper-faithful framework, pre-iteration) snapshotted in
`runs/dryrun_baseline/`. Terms are roofline seconds/step per chip;
"bound" = max term. MFU-ub = (MODEL_FLOPS/chips/peak) / bound.

### Cell A — whisper_base/train_4k (worst MFU 1.11%, most collective-bound 0.58)

| iteration | hypothesis | change | bound (s) | coll (s) | MFU-ub | verdict |
|---|---|---|---|---|---|---|
| baseline | — | — | 0.957 | 0.559 | 1.11% | memory-dominated |
| 1. bf16 scores | f32 softmax chains dominate attention bytes; bf16 halves them | score chain in bf16, f32-accumulated denominator | — | — | — | partially confirmed (CPU backend re-promotes to f32; on TPU this is native) |
| 2. pure-DP for tiny models | TP/SP on d=512 spends more on gathers than it saves; 70M params replicate for free | params replicated, batch sharded over all 256 chips | **0.498** | **0.041** | **2.14%** | **confirmed: bound 1.9x, collectives 13.8x** |
| 3. save dots under remat | with 0.5 GB live of 16 GB, skip backward recompute | dots_with_no_batch_dims_saveable for pure-DP models | 0.528 | 0.041 | 2.02% | **refuted**: compute term -6% but memory bound +6% — recompute is free on a memory-bound cell, saved activations cost traffic. Reverted. |

### Cell B — qwen3_0_6b/train_4k (collective fraction 0.36)

| iteration | hypothesis | change | bound (s) | MFU-ub | verdict |
|---|---|---|---|---|---|
| baseline | — | — | 3.294 | 2.26% | |
| 1. KV->H expand | mixed q(heads)/k(head_dim) sharding replicates scores | expand KV to H, uniform "model" sharding | 3.528 | 2.11% | **refuted as a universal rule**: k/v bytes xG outweigh when KV already shards; made conditional (only when KV%16!=0 and H%16==0). qwen2-vl (28H/4KV: nothing divides) additionally keeps its S-sharded attention — forcing the gather there ballooned live bytes 8.3->20.2 GB before gating |
| 2. SP gather at attn entry | partitioner's "involuntary full remat" warnings on k/v resharding | gather S once at attention entry (Megatron-SP), gated on head-shardability | 3.526 | 2.11% | confirmed mechanism (warnings gone) but bytes unchanged — scores dominate |
| profile | — | weighted per-op attribution: 950/2890 GB = softmax chains | — | — | -> flash kernel is the fix |
| 3. flash attention | fused kernel keeps (Sq,Sk) in VMEM; HBM sees only Q/K/V/O | Pallas kernel (kernels/flash_attention), validated vs oracle; CPU dry-run cannot compile TPU custom-calls, effect modeled below | (3.53 -> ~2.6 modeled) | ~2.9% | kernel validated; flash-adjusted memory term = counted bytes minus score-chain traffic |

Prefill rows (where attention bytes dominate fwd-only): qwen3_0_6b
prefill_32k bound improved **1.29x**, qwen3-moe prefill **1.24x**, olmoe
prefill 1.23x from iterations 1-2 alone (see table vs baseline).

### Cell C — qwen3_moe_30b_a3b/train_4k (paper-representative: largest EC-checkpointed state; MoE + every distribution feature)

| iteration | hypothesis | change | result | verdict |
|---|---|---|---|---|
| pre-baseline | dense-dispatch MoE cannot shard | shard_map EP: local top-k/sort, all_to_all over "model", local expert matmuls | live 457->23 GB/chip, collectives 47.5 TB->0.3 TB, HLO/model flops 0.06->0.50 | confirmed (this *is* the baseline) |
| 1. ZeRO-1 via constraints | f32 moment math at weight sharding wastes 7 GB | constrain grads/params to zero specs before f32 math | temp unchanged | **refuted — partitioner re-gathers inside the sunk update loop** |
| 2. ZeRO param *storage* | gather params once at step start (clean bf16 gathers); update never re-shards | params stored at zero layout | live 23.5->14.55 GB: **fits 16 GB HBM** | confirmed |
| 3. chunked CE + attn-chunk remat | CE logits (2.5 GB f32) + saved q-chunk scores are the big rematerialized buffers | stream CE over 128-token chunks under remat; checkpoint the attention chunk body | temp 18.7->13.4 GB | confirmed |
| 4. serve=weights-sharded | decode is weights-bound; no DP replication needed in inference | expert weights also sharded over data axes for serve | decode_32k live 17.4->7.1 GB | confirmed |

### Storage layer (the paper's own contribution)

| iteration | hypothesis | change | before | after | verdict |
|---|---|---|---|---|---|
| 1. EC-DAPopt (paper §VI) | servers resend unchanged fragments | tag-filtered Lists, decode skip, put-data skip | reads 142 ms | 73 ms | confirmed — reproduces the paper's 2x |
| 2. conditional ABD gets ([4]) | same waste in ABD baselines | tag-carrying abd-get + quorum-safe writeback skip | CoABDF reads linear | flattened | confirmed |
| 3. parallel-index FM (ours) | O(blocks) serial rounds dominate large-object ops | genesis stores block index; Join-parallel block I/O; connectivity = coverable genesis flip | reads 73 ms @16 MB | **5.5 ms** (13x); ckpt save 4.5x, restore 7.7x | confirmed |

### Roofline reading & honest limits

* Every cell is **memory-term dominated** under our byte model (operand+
  result bytes of non-fused ops, scan-weighted). Two real causes and one
  artifact: (i) remat recompute (model/HLO flops ratio ~0.4-0.6 shows the
  extra forward — the deliberate memory/compute trade of nothing_saveable);
  (ii) unfused softmax/elementwise chains — the flash kernel addresses the
  largest; (iii) CPU-backend f32 emulation of bf16 (~2x inflation), absent
  on TPU.
* MODEL_FLOPS/HLO_FLOPS ~0.45-0.7 on train cells = remat doubling fwd
  compute + attention flops excluded from 6ND; decode cells are tiny by
  construction (1 token); mamba long_500k ratio >1 flags that 6ND
  *overestimates* a 1-token SSM step (no attention over history) — noted.
* Collective terms after iteration: DP grad all-reduce + ZeRO gathers + EP
  all_to_all dominate, all within 12-15% of the (inflated) memory bound —
  on-TPU these overlap with compute via XLA's latency-hiding scheduler.

## §Reproducing

```bash
bash runs/sweep.sh                                   # 80-cell dry-run
PYTHONPATH=src python -m repro.roofline.report       # tables below
PYTHONPATH=src python -m benchmarks.run              # paper figures
python runs/make_experiments.py                      # regenerate this file
```
"""


def main():
    n_ok = sum(1 for d in rows if d["status"] == "ok" and d.get("fits_hbm"))
    n_skip = sum(1 for d in rows if d["status"] == "skipped")
    head = HEAD.format(
        coabd_w=series("filesize", "coabd", "write_ms"),
        coabdf_w=series("filesize", "coabdf", "write_ms"),
        coabd_r=series("filesize", "coabd", "read_ms"),
        coabdf_r=series("filesize", "coabdf", "read_ms"),
        ecf_r=series("filesize", "coaresecf", "read_ms"),
        ecfno_r=series("filesize", "coaresecf-noopt", "read_ms"),
        pidx_r=series("filesize", "coaresecf+pidx", "read_ms"),
        ec_scal=series("scal_servers", "coaresec", "write_ms"),
        abd_scal=series("scal_servers", "coabd", "write_ms"),
        blk_w=series("blocksize_minavg", "coaresecf", "write_ms"),
        aws_ecf_r=series("aws_filesize", "coaresecf", "read_ms"),
        aws_ec_r=series("aws_filesize", "coaresec", "read_ms"),
        aws_pidx_r=series("aws_filesize", "coaresecf+pidx", "read_ms"),
        n_ok=n_ok,
        n_skip=n_skip,
    )
    doc = [head]
    doc.append(dryrun_table(rows))
    doc.append("\n\n## §Roofline — single-pod (16x16), per chip\n")
    doc.append(roofline_table(rows, "pod1"))
    doc.append("\n\n### Multi-pod (2x16x16)\n")
    doc.append(roofline_table(rows, "pod2"))
    doc.append(TAIL)
    Path("EXPERIMENTS.md").write_text("\n".join(doc))
    print(f"EXPERIMENTS.md written ({n_ok} ok cells, {n_skip} skips)")


if __name__ == "__main__":
    main()
