"""Protocol invariant analyzers (ISSUE 8).

Static half: ``astlint`` (engine) + ``invariants`` (rule pack), run via
``python -m repro.analysis`` / ``make analyze`` — stdlib-only, imports
nothing from the protocol modules.

Runtime half: ``sanitizer`` (quorum/tag/vocabulary checks on live
``Network`` traffic) + ``linearize`` (post-hoc Wing–Gong-style tag-order
linearizability over recorded histories), enabled with
``DSSParams.sanitize=True`` or ``REPRO_SANITIZE=1``.

This ``__init__`` intentionally imports neither half: the lint CLI must
stay importable without numpy, and the sanitizer pulls the core package.
"""
