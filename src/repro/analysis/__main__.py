"""``python -m repro.analysis`` — run the protocol-invariant lint pack."""
import sys

from repro.analysis.invariants import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
