"""Tiny AST lint engine for the protocol-invariant rule pack (ISSUE 8).

``ruff`` keeps the general Python hygiene; this engine exists for the rules
ruff cannot express — repo-specific protocol invariants like "every server
message type has a codec registry entry" or "no unordered set iteration in
fan-out construction". It is deliberately stdlib-only (``ast`` + ``pathlib``)
so the CI lint job needs zero third-party installs, and it never *imports*
the code under analysis — everything is read from source, so a module with a
side-effectful import (or a missing optional dep) still lints.

Two rule shapes:

* :class:`ModuleRule` — gets each in-scope module's AST and source lines;
  yields :class:`Finding`s. Scope is a tuple of path prefixes relative to
  the package root (e.g. ``("core", "net")``).
* :class:`RepoRule` — gets the package root once; for cross-file invariants
  (the registry-drift detector reads ``core/server.py`` against
  ``net/codec.py``).

Waivers: a finding is suppressed when its source line (or, for multi-line
statements, the statement's first line) carries the comment marker
``protocol-lint: allow-<rule-name>`` — always with a reason, e.g.::

    from time import perf_counter  # protocol-lint: allow-wallclock (profiling)

Waivers are per-line and per-rule, so a blanket opt-out is impossible.

Stale waivers (ISSUE 9): a waiver that stops suppressing anything — the
code it excused was fixed or moved, but the comment stayed — is itself a
finding (rule ``stale-waiver``). ``run_rules`` collects every waiver
comment (via ``tokenize``, so a docstring *mentioning* a marker, like the
example above, doesn't count) and reports each one no module-rule finding
consumed. A stale waiver is latent rot: it silently re-opens the line to
the exact regression the rule guards against. Stale-waiver findings are
not themselves waivable — delete the comment instead.
"""
from __future__ import annotations

import ast
import io
import re
import sys
import tokenize
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Iterator


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # path relative to the package root
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class ModuleRule:
    """Per-module rule: override ``check`` (and ``scope`` / ``name``)."""

    name = "module-rule"
    #: path prefixes (relative to the package root, "/"-separated) this rule
    #: applies to; () = every module.
    scope: tuple[str, ...] = ()

    def applies(self, relpath: str) -> bool:
        return not self.scope or any(
            relpath == s or relpath.startswith(s + "/") for s in self.scope
        )

    def check(
        self, relpath: str, tree: ast.Module, lines: list[str]
    ) -> Iterator[Finding]:  # pragma: no cover - interface
        raise NotImplementedError


class RepoRule:
    """Whole-repo rule: override ``check_repo``."""

    name = "repo-rule"

    def check_repo(self, root: Path) -> Iterator[Finding]:  # pragma: no cover
        raise NotImplementedError


def parse_module(path: Path) -> tuple[ast.Module, list[str]]:
    source = path.read_text(encoding="utf-8")
    return ast.parse(source, filename=str(path)), source.splitlines()


def iter_py_files(root: Path) -> Iterator[Path]:
    for p in sorted(root.rglob("*.py")):
        if "__pycache__" not in p.parts:
            yield p


def waived(lines: list[str], lineno: int, rule: str) -> bool:
    """True when the finding's line carries ``protocol-lint: allow-<rule>``."""
    if 1 <= lineno <= len(lines):
        return f"protocol-lint: allow-{rule}" in lines[lineno - 1]
    return False


STALE_WAIVER_RULE = "stale-waiver"

_WAIVER_RE = re.compile(r"protocol-lint:\s*allow-([A-Za-z0-9_-]+)")


def iter_waivers(lines: list[str]) -> Iterator[tuple[int, str]]:
    """``(lineno, rule)`` for every waiver marker in a COMMENT token.
    Tokenizing (rather than substring-scanning every line) keeps docstrings
    and string literals that merely *mention* a marker from counting as
    waivers of anything."""
    src = "\n".join(lines) + "\n"
    try:
        for tok in tokenize.generate_tokens(io.StringIO(src).readline):
            if tok.type == tokenize.COMMENT:
                for m in _WAIVER_RE.finditer(tok.string):
                    yield tok.start[0], m.group(1)
    except tokenize.TokenError:  # pragma: no cover - file already parsed
        return


def run_rules(
    root: Path,
    module_rules: Iterable[ModuleRule],
    repo_rules: Iterable[RepoRule] = (),
    check_waivers: bool = True,
) -> list[Finding]:
    """Run every rule over the package rooted at ``root``; returns findings
    (waived ones already removed), sorted by path/line. With
    ``check_waivers`` (the default), every waiver comment that suppressed
    no finding is reported under the ``stale-waiver`` rule — including
    waivers naming unknown rules and waivers in files outside every rule's
    scope, where nothing could ever fire."""
    findings: list[Finding] = []
    module_rules = list(module_rules)
    used: set[tuple[str, int, str]] = set()
    waivers: list[tuple[str, int, str]] = []
    for path in iter_py_files(root):
        relpath = path.relative_to(root).as_posix()
        active = [r for r in module_rules if r.applies(relpath)]
        if not active and not check_waivers:
            continue
        tree, lines = parse_module(path)
        if check_waivers:
            for lineno, rname in iter_waivers(lines):
                waivers.append((relpath, lineno, rname))
        for rule in active:
            for f in rule.check(relpath, tree, lines):
                if waived(lines, f.line, f.rule):
                    used.add((f.path, f.line, f.rule))
                else:
                    findings.append(f)
    for rule in repo_rules:
        findings.extend(rule.check_repo(root))
    for relpath, lineno, rname in waivers:
        if (relpath, lineno, rname) not in used:
            findings.append(Finding(
                STALE_WAIVER_RULE, relpath, lineno,
                f"waiver 'allow-{rname}' suppresses nothing here: rule "
                f"{rname!r} does not fire on this line — remove the "
                "comment (a stale waiver silently re-opens the line to "
                "the regression the rule guards against)",
            ))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def main_with(
    root: Path,
    module_rules: Iterable[ModuleRule],
    repo_rules: Iterable[RepoRule],
    argv: list[str] | None = None,
) -> int:
    """CLI driver: print findings, return 1 when any survive (CI gate)."""
    del argv  # no options yet; the rule pack IS the configuration
    findings = run_rules(root, module_rules, repo_rules)
    for f in findings:
        print(f)
    n_files = sum(1 for _ in iter_py_files(root))
    if findings:
        print(
            f"analyze: {len(findings)} finding(s) across {n_files} files",
            file=sys.stderr,
        )
        return 1
    print(f"analyze: {n_files} files clean")
    return 0


# --------------------------------------------------------------- AST helpers
def const_str(node: ast.AST) -> str | None:
    """The literal string value of a Constant-str node, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def dict_str_keys(node: ast.AST) -> list[tuple[str, int]] | None:
    """(key, lineno) pairs of a dict display whose keys are all str
    constants; None when ``node`` is not such a dict."""
    if not isinstance(node, ast.Dict):
        return None
    out = []
    for k in node.keys:
        s = const_str(k) if k is not None else None
        if s is None:
            return None
        out.append((s, k.lineno))
    return out


def frozenset_str_items(node: ast.AST) -> set[str] | None:
    """Items of a ``frozenset({...})`` / ``frozenset((...))`` literal of str
    constants; None when the node has a different shape."""
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id == "frozenset"
        and len(node.args) == 1
    ):
        arg = node.args[0]
        if isinstance(arg, (ast.Set, ast.Tuple, ast.List)):
            items = set()
            for e in arg.elts:
                s = const_str(e)
                if s is None:
                    return None
                items.add(s)
            return items
    return None


def is_set_expr(node: ast.AST) -> bool:
    """Syntactically set-valued: a set display/comprehension or a direct
    ``set(...)`` / ``frozenset(...)`` call."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    return False
