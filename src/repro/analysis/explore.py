"""Systematic schedule exploration over the protocol sim (ISSUE 9).

The PR-7 analyzer only ever observes the single schedule a fixed seed
produces; ARES's safety argument is about *all* interleavings. This module
adds the missing half, in the CHESS/dPOR tradition:

* :class:`ScheduleController` — hooks ``Network``'s event heap (both the
  ``_FanOut`` cursor path and the legacy per-destination path) and turns
  "which near-simultaneous pending event fires next" into an explicit,
  replayable decision, with crash/recover and message drops as additional
  schedulable choices (drawn from no RNG stream). A controller running the
  default ``fifo`` policy with no plan replays the exact uncontrolled
  trace — pinned by ``tests/test_explore.py``.

* :func:`explore` — bounded exhaustive DFS over decision prefixes with
  sleep-set-style (DPOR-lite) pruning on tiny configs, and seeded
  PCT / random-walk priority schedules for larger ones. Every explored
  schedule runs with the runtime sanitizer AND the vector-clock race
  tracker (:mod:`repro.analysis.races`) attached, and closes with the
  Wing–Gong history check.

* repro bundles — any violating schedule serializes to JSON under
  ``runs/schedules/`` with the full ``(seed, params, engine, decisions)``
  stamp; ``make replay SCHEDULE=…`` (:func:`replay_bundle`) re-executes it
  byte-identically and verifies the same violation at the same trace
  fingerprint.

The pruning is the classic independence argument: an alternative "run
event *e* now instead" is skipped when *e* was executed later in the
observed schedule and every step between commutes with it (disjoint
server/endpoint, no RNG draw) — the reordering reaches the same state, so
the child schedule is Mazurkiewicz-equivalent to the one already run.
``--no-prune`` disables it for a ground-truth sweep.

Test-only fault hooks (positive controls, satellite of ISSUE 9):

* ``early-read-resume`` — ops whose kind starts with ``race:`` wait for
  one reply fewer than they asked for. The PR-7 static ``on_rpc`` check
  cannot see it (the honest need is checked at issue; the client resumes
  early), and most schedules still read fresh data — only the narrow
  interleaving where a lagging server answers first returns a stale read,
  which the Wing–Gong pass flags. The explorer must find it.
* ``ack-rollback`` — a server acks an ``abd-put``, but if that ack is
  dropped in flight it rolls the put back *bypassing its tracked maps*
  (so nothing forgives the regression). Found via a dropped-ack schedule
  plus the sanitizer's reply-monotonicity floor.
* ``unguarded-put`` — drops the ``tag > cur`` guard on ``abd-put``: two
  concurrent writers' puts landing out of tag order regress the register,
  which the race tracker reports as an UNORDERED write-write race.
* ``retry-dup-write`` (ISSUE 10) — applies a *retransmitted* ``abd-put``
  blindly instead of suppressing the duplicate: with the retry machinery
  armed, a crash plus a dropped ack force a retransmission whose replay
  can land after a rival's newer tag and regress the register. The real
  servers' ``tag > cur`` guard is exactly the suppression this control
  removes.
"""
from __future__ import annotations

import argparse
import hashlib
import heapq
import json
import os
import random
from dataclasses import asdict, dataclass, field as dc_field
from typing import Any, Callable, Generator, Iterable

from repro.analysis.sanitizer import SanitizerError

Action = tuple[Any, ...]          # ("ev", seq) | ("drop", seq) | ("crash", sid) | ("recover", sid)
Key = tuple[str, Any, str]        # (kind, server-or-None, client-endpoint)

_DROPPABLE = ("srv", "rpl")       # event kinds a controller may lose in flight


class ScheduleDivergence(RuntimeError):
    """A replayed plan no longer matches the schedule's decision points."""


def conflicts(k1: Key | None, k2: Key | None) -> bool:
    """May these two events' effects fail to commute? Conservative: unkeyed
    events and RNG-drawing fan-out sends conflict with everything; otherwise
    events conflict when they touch the same server or the same client
    endpoint (state, NIC rows, op bookkeeping)."""
    if k1 is None or k2 is None:
        return True
    if k1[0] == "snd" or k2[0] == "snd":
        return True
    if k1[1] is not None and k1[1] == k2[1]:
        return True
    return bool(k1[2] == k2[2])


class ScheduleController:
    """Event-loop pop policy for ``Network.controller`` (see net/sim.py).

    Each step it computes the *ready set* — the ``width`` earliest pending
    events within ``horizon`` virtual seconds of the earliest one — plus
    any budgeted crash/recover/drop choices; more than one candidate makes
    a decision point. Decisions are taken from ``plan`` while it lasts
    (replay), then from ``policy``:

    * ``fifo`` — always the earliest ``(t, seq)``: the uncontrolled trace.
    * ``random`` — seeded uniform walk (occasional injection when budgeted).
    * ``pct`` — seeded priorities per endpoint key with ``pct_changes``
      demotion points, à la probabilistic concurrency testing.

    The executed decision log (``decisions``) and full step trace
    (``trace``) are what the explorer branches on and what bundles record.
    """

    def __init__(
        self,
        plan: Iterable[Action] = (),
        policy: str = "fifo",
        seed: int = 0,
        width: int = 4,
        horizon: float = 1.0e-3,
        crash_budget: int = 0,
        drop_budget: int = 0,
        crashable: tuple[str, ...] = (),
        pct_changes: int = 3,
    ) -> None:
        self.plan: list[Action] = [tuple(a) for a in plan]
        self.pos = 0
        self.policy = policy
        self.width = width
        self.horizon = horizon
        self.crash_budget = crash_budget
        self.recover_budget = crash_budget
        self.drop_budget = drop_budget
        self.crashable = tuple(crashable)
        self.keys: dict[int, Key | None] = {}
        # decision log: {"actions": [...], "chosen": ..., "at": trace index}
        self.decisions: list[dict[str, Any]] = []
        # every executed step: ("ev"|"drop", seq, key) | ("crash"|"recover", sid, None)
        self.trace: list[tuple[str, Any, Key | None]] = []
        self.injections = 0
        self.steps = 0
        self._drop_pending = False
        self._rng = random.Random(seed)
        self._prio: dict[Any, float] = {}
        self._pct_left = pct_changes
        # optional fault-hook callback for dropped replies
        self.on_reply_dropped: Callable[[str, Any], None] | None = None

    # ---------------------------------------------------- Network-facing API
    def note(self, seq: int, key: Key | None) -> None:
        """``Network.schedule`` reports every scheduled event's key here."""
        self.keys[seq] = key

    def consume_drop(self) -> bool:
        """True exactly once for the event the controller chose to drop."""
        if self._drop_pending:
            self._drop_pending = False
            return True
        return False

    def reply_dropped(self, sid: str, reply: Any) -> None:
        cb = self.on_reply_dropped
        if cb is not None:
            cb(sid, reply)

    def step(self, net: Any) -> bool:
        events = net._events
        if not events:
            return False
        ready = self._ready(events)
        actions = self._actions(net, ready)
        if len(actions) > 1:
            chosen = self._choose(actions, ready)
            self.decisions.append(
                {"actions": actions, "chosen": chosen, "at": len(self.trace)}
            )
        else:
            chosen = actions[0]
        return self._apply(net, chosen, ready)

    # ------------------------------------------------------------- internals
    def _ready(self, events: list) -> list:
        w = self.width if self.width < len(events) else len(events)
        smallest = heapq.nsmallest(w, events)
        lim = smallest[0][0] + self.horizon
        return [e for e in smallest if e[0] <= lim]

    def _actions(self, net: Any, ready: list) -> list[Action]:
        acts: list[Action] = [("ev", e[1]) for e in ready]
        if self.drop_budget > 0:
            for e in ready:
                k = self.keys.get(e[1])
                if k is not None and k[0] in _DROPPABLE:
                    acts.append(("drop", e[1]))
        if self.crash_budget > 0:
            for sid in self.crashable:
                srv = net.servers.get(sid)
                if srv is not None and not srv.crashed:
                    acts.append(("crash", sid))
        if self.recover_budget > 0:
            for sid in self.crashable:
                srv = net.servers.get(sid)
                if srv is not None and srv.crashed:
                    acts.append(("recover", sid))
        return acts

    def _choose(self, actions: list[Action], ready: list) -> Action:
        if self.pos < len(self.plan):
            want = self.plan[self.pos]
            self.pos += 1
            if want not in actions:
                raise ScheduleDivergence(
                    f"plan step {self.pos - 1} wants {want!r} but the "
                    f"schedule offers {actions!r} — the bundle does not "
                    "match this build/config"
                )
            return want
        if self.policy == "fifo":
            return ("ev", ready[0][1])
        if self.policy == "random":
            injections = [a for a in actions if a[0] != "ev"]
            if injections and self._rng.random() < 0.25:
                return injections[self._rng.randrange(len(injections))]
            evs = [a for a in actions if a[0] == "ev"]
            return evs[self._rng.randrange(len(evs))]
        if self.policy == "pct":
            injections = [a for a in actions if a[0] != "ev"]
            if injections and self._rng.random() < 0.15:
                return injections[self._rng.randrange(len(injections))]
            best: Action | None = None
            best_pk: Key | None = None
            best_p = -1.0
            for e in ready:
                k = self.keys.get(e[1])
                pk = k if k is not None else ("?", e[1], "")
                p = self._prio.get(pk)
                if p is None:
                    p = self._prio[pk] = self._rng.random()
                if p > best_p:
                    best_p = p
                    best = ("ev", e[1])
                    best_pk = pk
            if (best_pk is not None and self._pct_left > 0
                    and self._rng.random() < 0.1):
                # change point: demote the currently-preferred endpoint
                self._prio[best_pk] = self._rng.random() - 1.0
                self._pct_left -= 1
            assert best is not None  # actions non-empty  # noqa: S101
            return best
        raise ValueError(f"unknown policy {self.policy!r}")

    def _apply(self, net: Any, chosen: Action, ready: list) -> bool:
        kind = chosen[0]
        self.steps += 1
        if kind == "crash":
            net.crash(chosen[1])
            self.crash_budget -= 1
            self.injections += 1
            self.trace.append(("crash", chosen[1], None))
            return True
        if kind == "recover":
            net.recover(chosen[1])
            self.recover_budget -= 1
            self.injections += 1
            self.trace.append(("recover", chosen[1], None))
            return True
        seq = chosen[1]
        entry = None
        for e in ready:
            if e[1] == seq:
                entry = e
                break
        if entry is None:  # pragma: no cover - _choose guarantees membership
            raise ScheduleDivergence(f"chosen event seq {seq} not ready")
        events = net._events
        events.remove(entry)
        heapq.heapify(events)
        t = entry[0]
        if t > net.now:
            net.now = t
        net.events_processed += 1
        self.trace.append((kind, seq, self.keys.get(seq)))
        if kind == "drop":
            self.drop_budget -= 1
            self.injections += 1
            self._drop_pending = True
        entry[2]()
        self._drop_pending = False  # defensive: droppable events consume it
        return True


# --------------------------------------------------------------- scenarios

def _scn_wr(dss: Any) -> list[tuple[str, str, Generator]]:
    """Two clients on one block: each writes then reads back — the tiny
    (3 servers / 2 clients / 1 block) exhaustive-DFS config."""
    h1, h2 = dss.client("c1"), dss.client("c2")

    def wseq(h: Any, payload: bytes) -> Generator:
        st = yield from h.update("f", payload)
        val = yield from h.read("f")
        return (bool(st["success"]), len(val))

    return [
        ("c1", "race:wr1", wseq(h1, b"A" * 48)),
        ("c2", "race:wr2", wseq(h2, b"B" * 48)),
    ]


def _scn_ww(dss: Any) -> list[tuple[str, str, Generator]]:
    """Two concurrent writers + a reader on one block: the write-write
    interleaving config the unguarded-put control races on."""
    h1, h2, h3 = dss.client("c1"), dss.client("c2"), dss.client("c3")

    def w(h: Any, payload: bytes) -> Generator:
        st = yield from h.update("f", payload)
        return bool(st["success"])

    def r(h: Any) -> Generator:
        val = yield from h.read("f")
        return len(val)

    return [
        ("c1", "race:w1", w(h1, b"A" * 48)),
        ("c2", "race:w2", w(h2, b"B" * 48)),
        ("c3", "race:r", r(h3)),
    ]


def _scn_ec_recon(dss: Any) -> list[tuple[str, str, Generator]]:
    """Larger config for the seeded PCT / random-walk modes: EC-coded
    writes racing a reader and a concurrent reconfiguration."""
    h1, h2, h3 = dss.client("c1"), dss.client("c2"), dss.client("c3")
    target = dss.make_config()

    def w(h: Any) -> Generator:
        st = yield from h.update("f", b"X" * 256)
        return bool(st["success"])

    def r(h: Any) -> Generator:
        val = yield from h.read("f")
        return len(val)

    def rc(h: Any) -> Generator:
        n = yield from h.recon("f", target)
        return int(n)

    return [
        ("c1", "race:w", w(h1)),
        ("c2", "race:r", r(h2)),
        ("c3", "recon", rc(h3)),
    ]


SCENARIOS: dict[str, Callable[[Any], list[tuple[str, str, Generator]]]] = {
    "wr": _scn_wr,
    "ww": _scn_ww,
    "ec-recon": _scn_ec_recon,
}

# per-scenario store shape (overridable from ExploreConfig/CLI)
SCENARIO_PARAMS: dict[str, dict[str, Any]] = {
    "wr": {"algorithm": "coabd", "n_servers": 3},
    "ww": {"algorithm": "coabd", "n_servers": 3},
    "ec-recon": {"algorithm": "coaresec", "n_servers": 5, "parity_m": 2},
}


# ------------------------------------------------------------- fault hooks

class _FaultHook:
    """Context manager base: install on __enter__, restore on __exit__."""

    def __init__(self, net: Any, ctrl: ScheduleController) -> None:
        self.net = net
        self.ctrl = ctrl

    def __enter__(self) -> "_FaultHook":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None


class _EarlyReadResume(_FaultHook):
    """Seeded quorum off-by-one the static ``on_rpc`` check CANNOT see:
    ``_RpcState`` is built with one reply fewer than the (honest, already
    sanitizer-checked) need, for ops whose kind starts with ``race:``. The
    fan-out still goes to every server — only a schedule where a lagging
    server answers first surfaces the stale read (Wing–Gong)."""

    def __enter__(self) -> "_EarlyReadResume":
        from repro.net import sim

        self._orig = sim._RpcState.__init__

        orig = self._orig

        def patched(s: Any, net: Any, gen: Any, fut: Any, on_done: Any,
                    acct: Any, src_i: Any, need: Any, alive: Any,
                    counted: Any) -> None:
            if (not alive and isinstance(need, int) and need > 1
                    and fut.kind.startswith("race:")):
                need -= 1
            orig(s, net, gen, fut, on_done, acct, src_i, need, alive, counted)

        sim._RpcState.__init__ = patched  # type: ignore[method-assign]
        return self

    def __exit__(self, *exc: Any) -> None:
        from repro.net import sim

        sim._RpcState.__init__ = self._orig  # type: ignore[method-assign]


class _HandlerPatch(_FaultHook):
    """Base for faults that replace a StorageServer handler: patches BOTH
    the class attribute and the ``_DISPATCH`` entry (dispatch holds the raw
    function, not a bound lookup)."""

    op = ""

    def _install(self, fn: Callable[..., Any]) -> None:
        from repro.core.server import StorageServer

        self._orig = StorageServer._DISPATCH[self.op]
        self._orig_attr = getattr(StorageServer, "_h_" + self.op.replace("-", "_"))
        StorageServer._DISPATCH[self.op] = fn
        setattr(StorageServer, "_h_" + self.op.replace("-", "_"), fn)

    def __exit__(self, *exc: Any) -> None:
        from repro.core.server import StorageServer

        StorageServer._DISPATCH[self.op] = self._orig
        setattr(StorageServer, "_h_" + self.op.replace("-", "_"), self._orig_attr)


class _AckRollback(_HandlerPatch):
    """Dropped-ack tag regression: the server applies an ``abd-put`` (plain
    or batch) and acks — but if that ack is lost in flight it rolls the put
    back, through raw ``dict`` access so the tracked maps never report
    (= never forgive) the regression. Reply shapes are untouched; pending
    rollbacks are keyed by ack-object identity (the sim delivers the exact
    object the handler returned, and this table pins it alive). Caught by
    the sanitizer's reply-monotonicity floor on the next get this server
    answers."""

    def __enter__(self) -> "_AckRollback":
        from repro.core.server import StorageServer

        # id(ack) -> (ack ref, server, [(key, prev_state), ...])
        self.pending: dict[int, tuple[Any, Any, list[tuple[tuple, Any]]]] = {}
        pending = self.pending
        self._saved = {
            op: StorageServer._DISPATCH[op]
            for op in ("abd-put", "abd-put-batch")
        }
        orig_put = self._saved["abd-put"]

        def put1(srv: Any, sender: str, msg: tuple) -> Any:
            key = (msg[1], msg[2])
            prev = dict.get(srv.abd, key)
            orig_put(srv, sender, msg)
            reply = tuple(["ack"])  # fresh object: identity keys the undo
            pending[id(reply)] = (reply, srv, [(key, prev)])
            return reply

        def putb(srv: Any, sender: str, msg: tuple) -> Any:
            _, items, idx = msg
            undo = []
            for obj, tag, val in items:
                key = (obj, idx)
                undo.append((key, dict.get(srv.abd, key)))
                orig_put(srv, sender, ("abd-put", obj, idx, tag, val))
            reply = ("ack", len(items))
            pending[id(reply)] = (reply, srv, undo)
            return reply

        StorageServer._DISPATCH["abd-put"] = put1
        StorageServer._DISPATCH["abd-put-batch"] = putb
        self.ctrl.on_reply_dropped = self._on_drop
        return self

    def __exit__(self, *exc: Any) -> None:
        from repro.core.server import StorageServer

        for op, fn in self._saved.items():
            StorageServer._DISPATCH[op] = fn

    def _on_drop(self, sid: str, reply: Any) -> None:
        ent = self.pending.pop(id(reply), None)
        if ent is None:
            return
        _reply, srv, undo = ent
        from repro.core.tags import TAG0

        for key, prev in reversed(undo):
            dict.__setitem__(
                srv.abd, key, prev if prev is not None else (TAG0, None)
            )
        dict.clear(srv._rcache)
        dict.clear(srv._rkeys)


class _UnguardedPut(_HandlerPatch):
    """Drops the ``tag > cur`` guard on ``abd-put``: last-arrival-wins.
    Two concurrent writers' puts landing out of tag order regress the
    register — an UNORDERED write-write race the vector-clock tracker
    reports at mutation time, before any reply could reveal it."""

    op = "abd-put"

    def __enter__(self) -> "_UnguardedPut":
        def patched(srv: Any, sender: str, msg: tuple) -> Any:
            _, obj, idx, tag, val = msg
            srv._abd_state((obj, idx))
            srv.abd[(obj, idx)] = (tag, val)  # guard dropped!
            return ("ack",)

        self._install(patched)
        return self


class _RetryDupWrite(_HandlerPatch):
    """Retry-duplicate write WITHOUT suppression (ISSUE 10 positive
    control): the first delivery of each distinct ``abd-put`` request runs
    the honest guarded handler, but a RE-delivery — the deadline machinery's
    retransmission of the same request after its ack was dropped — is
    applied blindly, last-write-wins. A schedule where a rival writer's
    newer tag lands between the original delivery and the retransmitted
    duplicate regresses the register: exactly the corruption duplicate
    suppression (tag guards + sid-keyed replies) exists to prevent. Needs
    ``retry=True`` plus a drop (and a crash to thin the quorum) so a
    retransmission actually fires."""

    op = "abd-put"

    def __enter__(self) -> "_RetryDupWrite":
        seen: set = set()

        def patched(srv: Any, sender: str, msg: tuple) -> Any:
            _, obj, idx, tag, val = msg
            key = (srv.sid, sender, obj, idx, tag)
            if key in seen:  # retransmitted duplicate: suppression dropped!
                srv._abd_state((obj, idx))
                srv.abd[(obj, idx)] = (tag, val)
                return ("ack",)
            seen.add(key)
            return self._orig(srv, sender, msg)

        self._install(patched)
        return self


FAULTS: dict[str, type[_FaultHook]] = {
    "early-read-resume": _EarlyReadResume,
    "ack-rollback": _AckRollback,
    "unguarded-put": _UnguardedPut,
    "retry-dup-write": _RetryDupWrite,
}


# ------------------------------------------------------------ one schedule

@dataclass
class ExploreConfig:
    """One exploration target: scenario + store shape + controller knobs +
    explorer budgets. Everything here is stamped into repro bundles."""

    scenario: str = "wr"
    algorithm: str = "coabd"
    n_servers: int = 3
    parity_m: int = 1
    delta: int = 8
    seed: int = 0
    fast_net: bool = True
    fault: str | None = None
    # arm the ISSUE 10 deadline/retransmit machinery (jitter pinned to 0 so
    # the retry stream draws nothing and replays stay byte-identical); ops
    # that exhaust the budget fail typed and count as incomplete.
    retry: bool = False
    # controller
    width: int = 4
    horizon: float = 1.0e-3
    crash_budget: int = 0
    drop_budget: int = 0
    # explorer
    mode: str = "dfs"           # dfs | pct | random
    budget: int = 1000          # max schedules
    branch_depth: int = 6       # DFS: decisions eligible for branching
    prune: bool = True
    policy_seed: int = 0
    stop_on_first: bool = True
    max_events: int = 200_000

    @classmethod
    def for_scenario(cls, scenario: str, **kw: Any) -> "ExploreConfig":
        base = dict(SCENARIO_PARAMS.get(scenario, {}))
        base.update(kw)
        return cls(scenario=scenario, **base)


@dataclass
class Outcome:
    violation: dict[str, str] | None
    decisions: list[dict[str, Any]]
    trace: list[tuple[str, Any, Key | None]]
    fingerprint: dict[str, Any]
    report: dict[str, Any] = dc_field(default_factory=dict)


def _fingerprint(dss: Any) -> dict[str, Any]:
    net = dss.net
    hist = repr([
        (r.kind, r.obj, r.client, r.tag, r.flag, r.start, r.end)
        for r in dss.history
    ])
    return {
        "now": net.now,
        "events": net.events_processed,
        "msgs": net.msg_count,
        "bytes": net.bytes_sent,
        "rounds": net.rpc_rounds,
        "history_sha": hashlib.sha256(hist.encode()).hexdigest(),
    }


def run_schedule(
    cfg: ExploreConfig,
    plan: Iterable[Action] = (),
    policy: str = "fifo",
    policy_seed: int = 0,
) -> Outcome:
    """Run one scenario instance under one controlled schedule: sanitizer +
    race tracker live, Wing–Gong post-hoc. Returns the decision log and
    trace fingerprint; protocol violations land in ``Outcome.violation``
    (schedule divergence and genuine crashes still raise)."""
    from repro.core.store import DSS, DSSParams
    from repro.net.sim import QuorumUnavailableError, RetryPolicy

    params = DSSParams(
        algorithm=cfg.algorithm, n_servers=cfg.n_servers,
        parity_m=cfg.parity_m, delta=cfg.delta, seed=cfg.seed,
        fast_net=cfg.fast_net, sanitize=True, racecheck=True,
        retry=RetryPolicy(rpc_timeout=5e-3, jitter=0.0, max_attempts=2,
                          phase_retries=1, phase_backoff=1e-3)
        if cfg.retry else None,
    )
    dss = DSS(params)
    ctrl = ScheduleController(
        plan=plan, policy=policy, seed=policy_seed,
        width=cfg.width, horizon=cfg.horizon,
        crash_budget=cfg.crash_budget, drop_budget=cfg.drop_budget,
        crashable=tuple(f"s{i}" for i in range(cfg.n_servers)),
    )
    dss.net.controller = ctrl
    hook = FAULTS[cfg.fault](dss.net, ctrl) if cfg.fault else _FaultHook(dss.net, ctrl)
    violation: dict[str, str] | None = None
    futs: list[Any] = []
    unavailable: list[str] = []

    def _shield(kind: str, gen: Generator) -> Generator:
        # a retry budget exhausting mid-exploration is a LIVENESS outcome,
        # not a safety violation: record it (the op stays out of the strict
        # reads-from gate) instead of crashing the event loop.
        try:
            return (yield from gen)
        except QuorumUnavailableError:
            unavailable.append(kind)
            return None

    with hook:
        ops = SCENARIOS[cfg.scenario](dss)
        for cid, kind, gen in ops:
            futs.append(dss.net.spawn(_shield(kind, gen), kind=kind, client=cid))
        try:
            dss.net.run(max_events=cfg.max_events)
        except SanitizerError as e:  # includes RaceError / linearize errors
            violation = {"type": type(e).__name__, "message": str(e)}
    incomplete = sum(1 for f in futs if not f.done)
    if violation is None:
        strict = (incomplete == 0 and ctrl.injections == 0
                  and not unavailable and dss.net.op_retries == 0)
        try:
            dss.check_history(strict_reads=strict)
        except SanitizerError as e:
            violation = {"type": type(e).__name__, "message": str(e)}
    report = {
        "ops": len(futs),
        "ops_incomplete": incomplete,
        "ops_unavailable": len(unavailable),
        "injections": ctrl.injections,
        "retransmits": dss.net.retransmits,
        "sanitizer": dss.net.sanitizer.report(),
        "races": dss.net.race_tracker.report(),
    }
    return Outcome(
        violation=violation,
        decisions=ctrl.decisions,
        trace=ctrl.trace,
        fingerprint=_fingerprint(dss),
        report=report,
    )


# ---------------------------------------------------------------- explorer

def _prunable(alt: Action, d: int, out: Outcome) -> bool:
    """Sleep-set-style check: running ``alt`` at decision ``d`` instead is
    redundant when the observed schedule executed that same event later
    with only commuting steps in between (the reordering reaches the same
    state — Mazurkiewicz equivalence)."""
    if alt[0] != "ev":
        return False  # injections are never pruned
    seq = alt[1]
    start = out.decisions[d]["at"]
    alt_key: Key | None = None
    hit = -1
    for i in range(start, len(out.trace)):
        kind, ident, key = out.trace[i]
        if kind == "ev" and ident == seq:
            hit = i
            alt_key = key
            break
        if kind == "drop" and ident == seq:
            return False  # executed, but as a different action
    if hit < 0:
        return False  # never executed (crash swallowed it): must explore
    for i in range(start, hit):
        _kind, _ident, key = out.trace[i]
        if _kind in ("crash", "recover") or conflicts(key, alt_key):
            return False
    return True


@dataclass
class ExploreResult:
    schedules: int
    violations: list[dict[str, Any]]   # full bundles, in memory
    pruned: int
    exhausted: bool                    # DFS only: frontier drained

    @property
    def found(self) -> bool:
        return bool(self.violations)


def _bundle(cfg: ExploreConfig, out: Outcome, policy: str,
            policy_seed: int) -> dict[str, Any]:
    return {
        "version": 1,
        "config": asdict(cfg),
        "engine": "fast" if cfg.fast_net else "legacy",
        "seed_params": {
            "seed": cfg.seed, "algorithm": cfg.algorithm,
            "n_servers": cfg.n_servers, "parity_m": cfg.parity_m,
            "delta": cfg.delta, "fast_net": cfg.fast_net,
        },
        "policy": policy,
        "policy_seed": policy_seed,
        "schedule": [list(d["chosen"]) for d in out.decisions],
        "violation": out.violation,
        "fingerprint": out.fingerprint,
        "report": out.report,
    }


def explore(cfg: ExploreConfig,
            log: Callable[[str], None] = lambda s: None) -> ExploreResult:
    """Drive :func:`run_schedule` per ``cfg.mode``; collect violating
    schedules as repro bundles (see :func:`write_bundle`)."""
    violations: list[dict[str, Any]] = []
    pruned = 0
    schedules = 0
    if cfg.mode in ("pct", "random"):
        for i in range(cfg.budget):
            out = run_schedule(cfg, (), policy=cfg.mode,
                               policy_seed=cfg.policy_seed + i)
            schedules += 1
            if out.violation is not None:
                violations.append(
                    _bundle(cfg, out, cfg.mode, cfg.policy_seed + i))
                if cfg.stop_on_first:
                    break
        return ExploreResult(schedules, violations, pruned, False)
    if cfg.mode != "dfs":
        raise ValueError(f"unknown mode {cfg.mode!r}")
    frontier: list[tuple[Action, ...]] = [()]
    seen: set[tuple[Action, ...]] = {()}
    while frontier and schedules < cfg.budget:
        prefix = frontier.pop()
        out = run_schedule(cfg, prefix)
        schedules += 1
        if schedules % 500 == 0:
            log(f"  … {schedules} schedules, frontier {len(frontier)}")
        if out.violation is not None:
            violations.append(_bundle(cfg, out, "fifo", 0))
            if cfg.stop_on_first:
                return ExploreResult(schedules, violations, pruned, False)
            continue  # don't expand past a violating schedule
        chosen = [d["chosen"] for d in out.decisions]
        hi = min(len(out.decisions), cfg.branch_depth)
        for d in range(len(prefix), hi):
            for a in out.decisions[d]["actions"]:
                if a == out.decisions[d]["chosen"]:
                    continue
                if cfg.prune and _prunable(a, d, out):
                    pruned += 1
                    continue
                child = tuple(chosen[:d]) + (a,)
                if child not in seen:
                    seen.add(child)
                    frontier.append(child)
    return ExploreResult(schedules, violations, pruned, not frontier)


# ----------------------------------------------------------------- bundles

def write_bundle(bundle: dict[str, Any], out_dir: str, idx: int = 0) -> str:
    os.makedirs(out_dir, exist_ok=True)
    cfg = bundle["config"]
    name = (
        f"{cfg['scenario']}-{cfg['fault'] or 'clean'}-"
        f"{bundle['policy']}-{bundle['policy_seed']}-{idx:04d}.json"
    )
    path = os.path.join(out_dir, name)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(bundle, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def load_bundle(path: str) -> dict[str, Any]:
    with open(path, encoding="utf-8") as fh:
        bundle = json.load(fh)
    if bundle.get("version") != 1:
        raise ValueError(f"unknown bundle version in {path}")
    return bundle


def replay_bundle(bundle: dict[str, Any]) -> dict[str, Any]:
    """Re-execute a bundle's schedule and verify byte-identical outcome:
    same violation (type + message) at the same trace fingerprint. Returns
    ``{"reproduced": bool, ...}`` with both sides for diagnosis."""
    cfg = ExploreConfig(**bundle["config"])
    plan = [tuple(a) for a in bundle["schedule"]]
    out = run_schedule(cfg, plan, policy=bundle["policy"],
                       policy_seed=bundle["policy_seed"])
    same_violation = out.violation == bundle["violation"]
    same_fp = out.fingerprint == bundle["fingerprint"]
    return {
        "reproduced": same_violation and same_fp,
        "violation_matches": same_violation,
        "fingerprint_matches": same_fp,
        "violation": out.violation,
        "expected_violation": bundle["violation"],
        "fingerprint": out.fingerprint,
        "expected_fingerprint": bundle["fingerprint"],
    }


# --------------------------------------------------------------------- CLI

def _print(s: str) -> None:
    print(s)


def _run_explore(cfg: ExploreConfig, out_dir: str) -> int:
    res = explore(cfg, log=_print)
    tag = f"[{cfg.scenario}/{cfg.fault or 'clean'}/{cfg.mode}]"
    for i, b in enumerate(res.violations):
        path = write_bundle(b, out_dir, i)
        v = b["violation"]
        _print(f"{tag} VIOLATION ({v['type']}): {v['message']}")
        _print(f"{tag} repro bundle: {path}  (make replay SCHEDULE={path})")
    _print(
        f"{tag} {res.schedules} schedules explored, {res.pruned} pruned, "
        f"{len(res.violations)} violation(s)"
        + (", frontier exhausted" if res.exhausted else "")
    )
    return 1 if res.violations else 0


def _selftest(out_dir: str, budget: int) -> int:
    """Positive controls: each seeded fault MUST be found within budget
    (and its bundle must replay byte-identically); the detector is broken
    otherwise. Returns 0 on success."""
    controls: list[tuple[str, dict[str, Any]]] = [
        # the two deep interleaving bugs need the priority schedules (the
        # bounded DFS frontier can't reach decision ~30 within budget);
        # the write-write race falls out of the exhaustive pass directly
        ("early-read-resume", {"scenario": "wr", "mode": "pct"}),
        ("ack-rollback", {"scenario": "wr", "mode": "pct", "drop_budget": 1}),
        ("unguarded-put", {"scenario": "ww", "mode": "dfs"}),
        # ISSUE 10: a retransmitted write applied without duplicate
        # suppression — needs the retry machinery armed plus a crash (thins
        # the quorum) and a dropped ack (forces the retransmission)
        ("retry-dup-write", {"scenario": "ww", "mode": "pct",
                             "crash_budget": 1, "drop_budget": 1,
                             "retry": True}),
    ]
    ok = True
    for i, (fault, kw) in enumerate(controls):
        cfg = ExploreConfig.for_scenario(fault=fault, budget=budget, **kw)
        res = explore(cfg)
        if not res.found:
            _print(f"[selftest] FAIL: fault {fault!r} NOT found in "
                   f"{res.schedules} schedules")
            ok = False
            continue
        rep = replay_bundle(res.violations[0])
        if not rep["reproduced"]:
            _print(f"[selftest] FAIL: fault {fault!r} bundle does not "
                   f"replay byte-identically: {rep}")
            ok = False
            continue
        path = write_bundle(res.violations[0], out_dir, i)
        _print(f"[selftest] ok: {fault!r} found in {res.schedules} "
               f"schedule(s), bundle replays byte-identically -> {path}")
    return 0 if ok else 1


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.explore",
        description="systematic schedule exploration + race detection",
    )
    ap.add_argument("--replay", metavar="BUNDLE", default=None,
                    help="re-execute a repro bundle and verify byte-identity")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded positive-control faults")
    ap.add_argument("--scenario", default="wr", choices=sorted(SCENARIOS))
    ap.add_argument("--mode", default="dfs", choices=("dfs", "pct", "random"))
    ap.add_argument("--fault", default=None, choices=sorted(FAULTS))
    ap.add_argument("--budget", type=int, default=1000)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--crash-budget", type=int, default=0)
    ap.add_argument("--drop-budget", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy-seed", type=int, default=0)
    ap.add_argument("--legacy-net", action="store_true",
                    help="explore the legacy per-destination engine")
    ap.add_argument("--no-prune", action="store_true")
    ap.add_argument("--keep-going", action="store_true",
                    help="collect every violation instead of stopping at one")
    ap.add_argument("--out", default=os.path.join("runs", "schedules"))
    args = ap.parse_args(argv)
    if args.replay:
        rep = replay_bundle(load_bundle(args.replay))
        if rep["reproduced"]:
            _print(f"replay ok: byte-identical ({args.replay})")
            return 0
        _print(f"replay MISMATCH: {json.dumps(rep, indent=1, default=str)}")
        return 2
    if args.selftest:
        return _selftest(args.out, args.budget)
    cfg = ExploreConfig.for_scenario(
        args.scenario, mode=args.mode, fault=args.fault,
        budget=args.budget, branch_depth=args.depth,
        crash_budget=args.crash_budget, drop_budget=args.drop_budget,
        seed=args.seed, policy_seed=args.policy_seed,
        fast_net=not args.legacy_net, prune=not args.no_prune,
        stop_on_first=not args.keep_going,
    )
    return _run_explore(cfg, args.out)


if __name__ == "__main__":
    raise SystemExit(main())
