"""The repo-specific protocol-invariant rule pack (``make analyze``).

Five rules, each guarding an invariant one of the protocol tiers rests on:

``registry-drift``
    ``core/server.py``'s ``_DISPATCH`` table, ``net/codec.py``'s
    ``MESSAGE_TYPES``/``REPLY_TYPES`` registries, and the gateway's gossip
    vocabulary must agree bidirectionally. A handler without a registry
    entry (or vice versa) means a message type the wire codec was never
    audited against — exactly how byte accounting and the runtime
    sanitizer's vocabulary check silently rot.

``assert-ban``
    No ``assert`` in ``core/``, ``net/`` or ``erasure/``: asserts vanish
    under ``python -O``, so a load-bearing protocol check becomes a no-op
    in optimized deployments. Raise ``ValueError``/``RuntimeError``.

``determinism``
    No wall-clock (``time`` module) or unseeded randomness (stdlib
    ``random``, legacy ``np.random.*`` globals) in ``core/``/``net/``.
    Virtual time and the fast/legacy trace-identity contract (ROADMAP:
    "determinism is the contract") both die the moment protocol code reads
    the host clock or an unseeded stream. Seeded ``np.random.default_rng``
    / ``Generator`` / ``SeedSequence`` remain allowed.

``set-iteration``
    No iterating a ``set``/``frozenset`` (or materialising one via
    ``tuple()``/``list()``, or passing one as RPC ``dests=``) in
    ``core/``/``net/``: set iteration order is salted per process, so a
    fan-out built from a set replays a different trace per run. Membership
    tests and ``sorted(...)`` are fine — that's the sanctioned idiom.

``statemap-bypass``
    No rebinding a server's tracked state maps (``.abd``/``.ec``/
    ``.next_c``) or its reply-cache internals (``._rcache``/``._rkeys``)
    outside ``StorageServer.__init__``: replacing a ``_StateMap`` with a
    plain dict silently disconnects the PR-6 read-reply cache's
    invalidation (and the runtime sanitizer's external-mutation hook) —
    the exact cache-coherence race the tracked maps exist to prevent.

The engine additionally self-checks the waiver mechanism (``stale-waiver``,
ISSUE 9): every ``protocol-lint: allow-<rule>`` comment that no longer
suppresses a finding of ``<rule>`` on its line is itself reported — a stale
waiver silently re-opens the line to the exact regression the rule guards
against. See ``repro.analysis.astlint.run_rules``.

Run as ``python -m repro.analysis`` (what ``make analyze`` does). The whole
path is stdlib-only: nothing here imports numpy or the protocol modules.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterator

from repro.analysis.astlint import (
    Finding,
    ModuleRule,
    RepoRule,
    const_str,
    dict_str_keys,
    frozenset_str_items,
    is_set_expr,
    main_with,
    parse_module,
    run_rules,
)

PROTOCOL_SCOPE = ("core", "net")
ASSERT_SCOPE = ("core", "net", "erasure")

# legacy np.random globals draw from the process-wide unseeded state; the
# Generator API (seeded construction) is the only sanctioned randomness.
_NP_RANDOM_ALLOWED = frozenset({
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "Philox",
})


class AssertBanRule(ModuleRule):
    name = "assert-ban"
    scope = ASSERT_SCOPE

    def check(
        self, relpath: str, tree: ast.Module, lines: list[str]
    ) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Assert):
                yield Finding(
                    self.name, relpath, node.lineno,
                    "assert vanishes under python -O; raise "
                    "ValueError/RuntimeError instead",
                )


class DeterminismRule(ModuleRule):
    name = "determinism"
    scope = PROTOCOL_SCOPE

    def check(
        self, relpath: str, tree: ast.Module, lines: list[str]
    ) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    top = alias.name.split(".")[0]
                    if top in ("time", "random"):
                        yield Finding(
                            self.name, relpath, node.lineno,
                            f"import of {top!r}: wall-clock/unseeded "
                            "randomness breaks virtual-time determinism",
                        )
            elif isinstance(node, ast.ImportFrom):
                top = (node.module or "").split(".")[0]
                if top in ("time", "random"):
                    yield Finding(
                        self.name, relpath, node.lineno,
                        f"import from {top!r}: wall-clock/unseeded "
                        "randomness breaks virtual-time determinism",
                    )
            elif isinstance(node, ast.Attribute):
                # np.random.<legacy-global> (e.g. np.random.random): draws
                # from the unseeded process-wide state
                v = node.value
                if (
                    isinstance(v, ast.Attribute)
                    and v.attr == "random"
                    and isinstance(v.value, ast.Name)
                    and v.value.id in ("np", "numpy")
                    and node.attr not in _NP_RANDOM_ALLOWED
                ):
                    yield Finding(
                        self.name, relpath, node.lineno,
                        f"np.random.{node.attr}: legacy global RNG is "
                        "unseeded; use np.random.default_rng(seed)",
                    )


class SetIterationRule(ModuleRule):
    name = "set-iteration"
    scope = PROTOCOL_SCOPE

    @staticmethod
    def _set_names(tree: ast.Module) -> set[str]:
        """Names that are ONLY ever assigned set-valued expressions."""
        yes: set[str] = set()
        no: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                value, targets = node.value, [node.target]
            else:
                continue
            if value is None:
                continue
            bucket = yes if is_set_expr(value) else no
            for t in targets:
                if isinstance(t, ast.Name):
                    bucket.add(t.id)
        return yes - no

    def check(
        self, relpath: str, tree: ast.Module, lines: list[str]
    ) -> Iterator[Finding]:
        tracked = self._set_names(tree)

        def bad(node: ast.AST) -> bool:
            return is_set_expr(node) or (
                isinstance(node, ast.Name) and node.id in tracked
            )

        for node in ast.walk(tree):
            if isinstance(node, (ast.For, ast.AsyncFor)) and bad(node.iter):
                yield Finding(
                    self.name, relpath, node.lineno,
                    "iterating a set: order is salted per process; "
                    "iterate sorted(...) instead",
                )
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    if bad(gen.iter):
                        yield Finding(
                            self.name, relpath, node.lineno,
                            "comprehension over a set: order is salted per "
                            "process; iterate sorted(...) instead",
                        )
            elif isinstance(node, ast.Call):
                if (
                    isinstance(node.func, ast.Name)
                    and node.func.id in ("tuple", "list")
                    and node.args
                    and bad(node.args[0])
                ):
                    yield Finding(
                        self.name, relpath, node.lineno,
                        f"{node.func.id}() over a set bakes salted order "
                        "into a sequence; use sorted(...)",
                    )
                for kw in node.keywords:
                    if kw.arg == "dests" and bad(kw.value):
                        yield Finding(
                            self.name, relpath, node.lineno,
                            "RPC dests= built from a set: fan-out order "
                            "(and the trace) becomes nondeterministic",
                        )


class StateMapBypassRule(ModuleRule):
    name = "statemap-bypass"
    scope = PROTOCOL_SCOPE

    _TRACKED = frozenset({"abd", "ec", "next_c", "_rcache", "_rkeys"})

    def check(
        self, relpath: str, tree: ast.Module, lines: list[str]
    ) -> Iterator[Finding]:
        yield from self._visit(relpath, tree, in_init=False)

    def _visit(
        self, relpath: str, node: ast.AST, in_init: bool
    ) -> Iterator[Finding]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._visit(
                    relpath, child,
                    in_init=(
                        child.name == "__init__"
                        and relpath == "core/server.py"
                    ),
                )
                continue
            if isinstance(child, (ast.Assign, ast.AugAssign)) and not in_init:
                targets = (
                    child.targets
                    if isinstance(child, ast.Assign)
                    else [child.target]
                )
                for t in targets:
                    if (
                        isinstance(t, ast.Attribute)
                        and t.attr in self._TRACKED
                    ):
                        yield Finding(
                            self.name, relpath, child.lineno,
                            f"rebinding .{t.attr} replaces the tracked "
                            "_StateMap and disconnects reply-cache "
                            "invalidation (mutate it in place instead)",
                        )
            yield from self._visit(relpath, child, in_init)


class RegistryDriftRule(RepoRule):
    """server ``_DISPATCH``/reply tags ↔ codec registries ↔ gateway gossip."""

    name = "registry-drift"

    # ------------------------------------------------------------- extract
    @staticmethod
    def _class(tree: ast.Module, name: str) -> ast.ClassDef | None:
        for node in tree.body:
            if isinstance(node, ast.ClassDef) and node.name == name:
                return node
        return None

    @staticmethod
    def _return_tags(fn: ast.AST) -> set[str]:
        """First-element string constants of literal tuple returns."""
        tags: set[str] = set()
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.Return)
                and isinstance(node.value, ast.Tuple)
                and node.value.elts
            ):
                s = const_str(node.value.elts[0])
                if s is not None:
                    tags.add(s)
        return tags

    def _server_vocab(
        self, tree: ast.Module
    ) -> tuple[dict[str, int], dict[str, int], set[str]]:
        """(dispatch {op: line}, read_only {op: line}, reply tags)."""
        dispatch: dict[str, int] = {}
        read_only: dict[str, int] = {}
        replies: set[str] = set()
        cls = self._class(tree, "StorageServer")
        if cls is None:
            return dispatch, read_only, replies
        for stmt in cls.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                t = stmt.targets[0]
                if isinstance(t, ast.Name) and t.id in (
                    "_DISPATCH", "_READ_ONLY"
                ):
                    keys = dict_str_keys(stmt.value) or []
                    dest = dispatch if t.id == "_DISPATCH" else read_only
                    for k, line in keys:
                        dest[k] = line
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if stmt.name.startswith("_h_"):
                    replies |= self._return_tags(stmt)
        return dispatch, read_only, replies

    def _gossip_vocab(self, tree: ast.Module) -> tuple[set[str], set[str]]:
        """(handled ops, reply tags) of ``GossipListener.handle``."""
        ops: set[str] = set()
        replies: set[str] = set()
        cls = self._class(tree, "GossipListener")
        if cls is None:
            return ops, replies
        for stmt in cls.body:
            if (
                isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                and stmt.name == "handle"
            ):
                for node in ast.walk(stmt):
                    if (
                        isinstance(node, ast.Compare)
                        and isinstance(node.left, ast.Name)
                        and node.left.id == "op"
                        and len(node.ops) == 1
                        and isinstance(node.ops[0], ast.Eq)
                    ):
                        s = const_str(node.comparators[0])
                        if s is not None:
                            ops.add(s)
                replies |= self._return_tags(stmt)
        return ops, replies

    @staticmethod
    def _registries(tree: ast.Module) -> dict[str, tuple[set[str], int]]:
        out: dict[str, tuple[set[str], int]] = {}
        for stmt in tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                target, value = stmt.targets[0], stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                target, value = stmt.target, stmt.value
            else:
                continue
            if isinstance(target, ast.Name) and target.id.endswith("_TYPES"):
                items = frozenset_str_items(value)
                if items is not None:
                    out[target.id] = (items, stmt.lineno)
        return out

    # --------------------------------------------------------------- check
    def check_repo(self, root: Path) -> Iterator[Finding]:
        server_p = root / "core" / "server.py"
        codec_p = root / "net" / "codec.py"
        gateway_p = root / "core" / "gateway.py"
        for p in (server_p, codec_p, gateway_p):
            if not p.exists():
                yield Finding(
                    self.name, p.name, 1, f"expected module missing: {p}"
                )
                return
        dispatch, read_only, replies = self._server_vocab(
            parse_module(server_p)[0]
        )
        regs = self._registries(parse_module(codec_p)[0])
        gossip_ops, gossip_replies = self._gossip_vocab(
            parse_module(gateway_p)[0]
        )

        def reg(regname: str) -> tuple[set[str], int]:
            ent = regs.get(regname)
            if ent is None:
                return set(), 1
            return ent

        msg_types, msg_line = reg("MESSAGE_TYPES")
        reply_types, reply_line = reg("REPLY_TYPES")
        g_types, g_line = reg("GOSSIP_TYPES")
        g_reply_types, gr_line = reg("GOSSIP_REPLY_TYPES")
        for regname in (
            "MESSAGE_TYPES", "REPLY_TYPES", "GOSSIP_TYPES",
            "GOSSIP_REPLY_TYPES",
        ):
            if regname not in regs:
                yield Finding(
                    self.name, "net/codec.py", 1,
                    f"registry {regname} missing (expected a frozenset "
                    "literal of message tags)",
                )
        # server handlers <-> codec MESSAGE_TYPES, both directions
        for op in sorted(set(dispatch) - msg_types):
            yield Finding(
                self.name, "core/server.py", dispatch[op],
                f"server handles {op!r} but net/codec.py MESSAGE_TYPES has "
                "no entry (registry drift)",
            )
        for op in sorted(msg_types - set(dispatch)):
            yield Finding(
                self.name, "net/codec.py", msg_line,
                f"MESSAGE_TYPES lists {op!r} but core/server.py _DISPATCH "
                "has no handler (registry drift)",
            )
        # server reply tags <-> codec REPLY_TYPES, both directions
        for tag in sorted(replies - reply_types):
            yield Finding(
                self.name, "net/codec.py", reply_line,
                f"server replies with {tag!r} but REPLY_TYPES has no entry "
                "(registry drift)",
            )
        for tag in sorted(reply_types - replies):
            yield Finding(
                self.name, "net/codec.py", reply_line,
                f"REPLY_TYPES lists {tag!r} but no server handler returns "
                "it (registry drift)",
            )
        # cacheable ops must be dispatchable
        for op in sorted(set(read_only) - set(dispatch)):
            yield Finding(
                self.name, "core/server.py", read_only[op],
                f"_READ_ONLY caches {op!r} but _DISPATCH has no handler",
            )
        # gateway gossip vocabulary <-> codec, both directions
        for op in sorted(gossip_ops.symmetric_difference(g_types)):
            yield Finding(
                self.name, "net/codec.py", g_line,
                f"gossip op {op!r} differs between GossipListener.handle "
                "and GOSSIP_TYPES (registry drift)",
            )
        for tag in sorted(gossip_replies.symmetric_difference(g_reply_types)):
            yield Finding(
                self.name, "net/codec.py", gr_line,
                f"gossip reply {tag!r} differs between GossipListener."
                "handle and GOSSIP_REPLY_TYPES (registry drift)",
            )


MODULE_RULES = (
    AssertBanRule(),
    DeterminismRule(),
    SetIterationRule(),
    StateMapBypassRule(),
)
REPO_RULES = (RegistryDriftRule(),)


def package_root() -> Path:
    """``src/repro`` — the package this pack lints."""
    return Path(__file__).resolve().parents[1]


def collect_findings(root: Path | None = None) -> list[Finding]:
    """All findings over ``root`` (default: this repo's ``src/repro``)."""
    return run_rules(root or package_root(), MODULE_RULES, REPO_RULES)


def main(argv: list[str] | None = None) -> int:
    return main_with(package_root(), MODULE_RULES, REPO_RULES, argv)
