"""Post-hoc linearizability over recorded histories, Wing–Gong style.

A Wing–Gong linearizability search is exponential in general; for a tagged
read/write register it collapses to three linear-time conditions, because
tags ``(ts, wid)`` totally order the writes and every operation reports the
tag it observed (the reduction ARES's atomicity proof builds on, and the
same one ``tests/checkers.py`` uses — this module is the library form the
runtime sanitizer raises through, with exceptions instead of ``assert``):

1. **Write-tag uniqueness** — two version-changing writes never share a
   tag (so tag order IS a total order over writes).
2. **Real-time tag monotonicity** — an operation never returns a tag
   smaller than one returned by any operation that completed before it
   started. With (1) this yields a legal linearization: order all ops by
   (tag, kind) with each read after its write.
3. **Reads-from** — every read's tag was produced by some write (or is
   the initial ``TAG0``), i.e. reads never invent values.

Violations raise :class:`LinearizabilityError` (a ``SanitizerError``)
carrying the object and the offending operation pair.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Iterable

from repro.analysis.sanitizer import SanitizerError
from repro.core.tags import TAG0


class LinearizabilityError(SanitizerError):
    """A recorded history admits no legal linearization under tag order."""


def check_tag_linearizable(
    history: Iterable[Any], *, strict_reads: bool = True
) -> dict[str, int]:
    """Check every read/write ``OpRecord`` in ``history``; returns
    ``{"objects": ..., "ops": ...}`` counters on success and raises
    :class:`LinearizabilityError` on the first violated condition.

    Records with other kinds (``recon``, ``fm-*``) or without a tag are
    outside the register model and are skipped. ``strict_reads=False``
    relaxes condition (3) only: histories taken under crash storms may
    contain reads that observed a write which never completed (failed or
    stuck ops record nothing), so their tags legitimately have no recorded
    producer. Conditions (1) and (2) — the atomicity core — always apply.
    """
    by_obj: dict[str, list] = defaultdict(list)
    n_ops = 0
    for r in history:
        if r.kind in ("read", "write") and r.tag is not None:
            by_obj[r.obj].append(r)
            n_ops += 1
    for obj, ops in by_obj.items():
        # (1) uniqueness over version-changing writes
        wtags = [r.tag for r in ops if r.kind == "write" and r.flag == "chg"]
        if len(wtags) != len(set(wtags)):
            dup = sorted(t for t in set(wtags) if wtags.count(t) > 1)
            raise LinearizabilityError(
                f"{obj}: duplicate chg-write tags {dup} — tag order is not "
                "a total order over writes"
            )
        # (2) real-time monotonicity: sweep start/end events in virtual-time
        # order (ends before starts at equal times: a read starting exactly
        # when a write ends must already see it)
        events = sorted(
            [(r.start, 1, i) for i, r in enumerate(ops)]
            + [(r.end, 0, i) for i, r in enumerate(ops)],
            key=lambda e: (e[0], e[1]),
        )
        floor_of = [TAG0] * len(ops)
        max_done = TAG0
        for _t, is_start, i in events:
            if is_start:
                floor_of[i] = max_done
            else:
                r = ops[i]
                if r.tag < floor_of[i]:
                    raise LinearizabilityError(
                        f"{obj}: {r.kind} by {r.client} returned tag "
                        f"{r.tag} < {floor_of[i]}, the tag of an operation "
                        "that completed before it started (real-time order "
                        "violated)"
                    )
                if r.tag > max_done:
                    max_done = r.tag
        # (3) reads-from: read tags must come from some write (chg or the
        # degraded unchg form, which reports the tag it adopted) or TAG0
        if strict_reads:
            produced = {r.tag for r in ops if r.kind == "write"} | {TAG0}
            for r in ops:
                if r.kind == "read" and r.tag not in produced:
                    raise LinearizabilityError(
                        f"{obj}: read by {r.client} returned tag {r.tag} "
                        "that no recorded write produced"
                    )
    return {"objects": len(by_obj), "ops": n_ops}
