"""Vector-clock happens-before race tracker for the protocol sim (ISSUE 9).

The PR-7 sanitizer checks what servers *say* (reply monotonicity); this
module checks what servers *do*: every in-handle mutation of per-object
server state — observed through the tracked ``_StateMap``/``_ObjState``
maps' invalidation hook (``StorageServer._race_observer``) — is attributed
to the operation whose message is being handled and ordered against the
operation that last wrote that ``(server, object)``.

Happens-before is tracked with vector clocks indexed by **operation id**
(deliberately no per-server component: the server's serialization order is
exactly what a schedule explorer perturbs, so it must not be allowed to
order the clocks by itself):

* each RPC round an operation issues ticks its own clock component and
  snapshots the clock into the round (``on_issue``);
* handling an arrival joins that snapshot into the server's knowledge
  (``before_handle``);
* a *counted* reply delivery joins the server's knowledge back into the
  operation's clock (``on_reply``) — the only inter-operation edges, which
  is exactly the quorum protocol's real communication structure.

What is *checked* is not raw access overlap — quorum protocols see
concurrent same-object traffic constantly and that is fine — but the
monotone **semantic summary** of the object's state on that server: the
ABD tag, the EC List's maximum tag, and the next-config status. A handler
whose mutations make any of those regress has lost a write; the vector
clocks then classify the witness pair as an *ordered* regression (plain
bug) or an *unordered race* (two concurrent ops whose effects do not
commute), and the run fails with :class:`RaceError`. Mutations outside
``handle`` are deliberate fault injection and are forgiven, mirroring the
sanitizer's ``forget``.

Like the sanitizer the tracker is a pure observer: it draws no randomness
and schedules nothing. Enable with ``DSSParams.racecheck=True`` /
``REPRO_RACECHECK=1``, or attach directly; the schedule explorer
(:mod:`repro.analysis.explore`) turns it on for every explored schedule.
(The plain-dict Paxos acceptor state ``StorageServer.cons`` has no
mutation hook and is covered only by the sanitizer's ballot checks.)
"""
from __future__ import annotations

from typing import Any

from repro.analysis.sanitizer import SanitizerError

Clock = dict[int, int]


class RaceError(SanitizerError):
    """Conflicting (unordered or order-violating) state mutation detected."""


def _join(dst: Clock, src: Clock) -> None:
    for k, v in src.items():
        if dst.get(k, -1) < v:
            dst[k] = v


class RaceTracker:
    """Happens-before observer for live ``Network`` traffic; raises
    :class:`RaceError` on the first non-monotone in-handle mutation. See
    the module docstring."""

    def __init__(self) -> None:
        self.net: Any = None
        # op_id -> vector clock {op_id: tick}
        self._vc: dict[int, Clock] = {}
        # RPC round (identity-keyed _RpcState) -> issue-time clock snapshot
        self._issue: dict[Any, Clock] = {}
        # sid -> joined knowledge of every snapshot this server handled
        self._know: dict[str, Clock] = {}
        # sid -> (op_id, issue snapshot) of the arrival being handled
        self._cur: dict[str, tuple[int, Clock]] = {}
        # sid -> objects mutated during the current handle (checked after
        # the handler returns — the tracked maps fire BEFORE the write
        # lands, so summaries must be read post-handle)
        self._pending: dict[str, list[Any]] = {}
        # (sid, obj) -> monotone semantic summary of the object's state
        self._base: dict[tuple[str, Any], dict[tuple[str, Any], Any]] = {}
        # (sid, obj) -> (op_id, issue tick, issue snapshot) of last writer
        self._wlast: dict[tuple[str, Any], tuple[int, int, Clock]] = {}
        self.mutations = 0           # in-handle mutation events observed
        self.checks = 0              # post-handle summary checks
        self.forgets = 0             # external-surgery resets
        self.concurrent_writes = 0   # benign unordered write-after-write
        self.unattributed = 0        # mutations outside a sim handle bracket

    # ------------------------------------------------------------ wiring
    def attach(self, net: Any) -> "RaceTracker":
        """Install on a Network: hook the issue/handle/reply observation
        points and the mutation observer of every (current and future)
        server."""
        net.race_tracker = self
        self.net = net
        for srv in net.servers.values():
            if hasattr(srv, "_race_observer"):
                srv._race_observer = self.on_mutation
        return self

    # ------------------------------------------------------- sim hook points
    def on_issue(self, state: Any, rpc: Any) -> None:
        """An operation issued an RPC round: tick its clock and snapshot it
        into the round (``state`` is the round's ``_RpcState``)."""
        op = int(state.fut.op_id)
        vc = self._vc.get(op)
        if vc is None:
            vc = self._vc[op] = {op: 0}
        vc[op] += 1
        self._issue[state] = dict(vc)

    def before_handle(self, sid: str, state: Any) -> None:
        """An arrival of ``state``'s round is about to be handled by
        ``sid``: the server learns the round's issue-time snapshot."""
        snap = self._issue.get(state)
        if snap is None:  # round issued before the tracker attached
            snap = {}
        know = self._know.get(sid)
        if know is None:
            know = self._know[sid] = {}
        _join(know, snap)
        self._cur[sid] = (int(state.fut.op_id), snap)
        pend = self._pending.get(sid)
        if pend:
            # mutations recorded outside a bracket (direct handle() calls
            # in tests): check them now, unattributed
            self._flush(sid, None)

    def after_handle(self, sid: str) -> None:
        """The handler returned: check every object it mutated against the
        monotone summary baseline, attributing to the handled op."""
        ctx = self._cur.pop(sid, None)
        if self._pending.get(sid):
            self._flush(sid, ctx)

    def on_reply(self, sid: str, state: Any) -> None:
        """A *counted* reply delivery: the issuing operation learns the
        server's knowledge — the only edges that order distinct ops."""
        op = int(state.fut.op_id)
        vc = self._vc.get(op)
        if vc is None:
            vc = self._vc[op] = {op: 0}
        know = self._know.get(sid)
        if know:
            _join(vc, know)

    def on_mutation(self, sid: str, obj: Any, in_handle: bool) -> None:
        """``StorageServer._race_observer``: per-object state on ``sid``
        is being mutated. In-handle mutations queue for the post-handle
        summary check; out-of-handle ones are external surgery — forgiven,
        like the sanitizer's ``forget``."""
        if not in_handle:
            if self._base.pop((sid, obj), None) is not None:
                self.forgets += 1
            self._wlast.pop((sid, obj), None)
            return
        self.mutations += 1
        pend = self._pending.get(sid)
        if pend is None:
            pend = self._pending[sid] = []
        pend.append(obj)

    # ------------------------------------------------------------ checking
    def _summary(self, sid: str, obj: Any) -> dict[tuple[str, Any], Any]:
        """Monotone semantic summary of ``obj``'s state on ``sid``: per
        config index, the ABD tag, the EC List max tag, and the successor-
        config status. Healthy handlers only ever move these forward."""
        srv = self.net.servers[sid]
        out: dict[tuple[str, Any], Any] = {}
        for (o, idx), (tag, _val) in srv.abd.items():
            if o == obj:
                out[("abd", idx)] = tag
        for (o, idx), lst in srv.ec.items():
            if o == obj and lst:
                out[("ec", idx)] = max(lst)
        for (o, idx), ent in srv.next_c.items():
            if o == obj and ent is not None:
                # F=1 > P=0; the config itself must stay fixed once F
                cfg, status = ent
                cid = getattr(cfg, "cfg_id", cfg)
                out[("next", idx)] = (1 if status == "F" else 0, cid)
        return out

    def _flush(self, sid: str, ctx: tuple[int, Clock] | None) -> None:
        objs = self._pending.get(sid)
        if not objs:
            return
        self._pending[sid] = []
        for obj in dict.fromkeys(objs):
            self._check(sid, obj, ctx)

    def _check(self, sid: str, obj: Any, ctx: tuple[int, Clock] | None) -> None:
        self.checks += 1
        key = (sid, obj)
        new = self._summary(sid, obj)
        base = self._base.get(key)
        if base is not None:
            for k, old in base.items():
                cur = new.get(k)
                if k[0] == "next":
                    regressed = cur is None or cur[0] < old[0] or (
                        old[0] == 1 and cur[0] == 1 and cur[1] != old[1]
                    )
                else:
                    regressed = cur is None or cur < old
                if regressed:
                    self._raise(sid, obj, k, old, cur, ctx)
        last = self._wlast.get(key)
        if ctx is not None:
            op, snap = ctx
            if last is not None and last[0] != op:
                # unordered with the previous writer? (its issue event is
                # not in our snapshot) — benign while summaries stay
                # monotone, but worth counting: these are the real
                # concurrent write-write interleavings explored
                if snap.get(last[0], -1) < last[1]:
                    self.concurrent_writes += 1
            self._wlast[key] = (op, snap.get(op, 0), snap)
        else:
            self.unattributed += 1
        self._base[key] = new

    def _raise(
        self,
        sid: str,
        obj: Any,
        k: tuple[str, Any],
        old: Any,
        cur: Any,
        ctx: tuple[int, Clock] | None,
    ) -> None:
        last = self._wlast.get((sid, obj))
        if ctx is None:
            who = "an unattributed handler"
            rel = "unknown ordering"
        else:
            op, snap = ctx
            who = f"op {op}"
            if last is None:
                rel = "no prior writer tracked"
            elif snap.get(last[0], -1) >= last[1]:
                rel = (
                    f"ordered AFTER the writing op {last[0]} (happens-"
                    "before established): plain lost-update bug"
                )
            else:
                rel = (
                    f"UNORDERED with the writing op {last[0]} (no happens-"
                    "before path): write-write race"
                )
        raise RaceError(
            f"server {sid}: handling {who} regressed {k[0]} state of "
            f"{obj!r}@cfg{k[1]} from {old!r} to {cur!r}; {rel}"
        )

    # ------------------------------------------------------------- report
    def report(self) -> dict[str, int]:
        return {
            "mutations": self.mutations,
            "checks": self.checks,
            "forgets": self.forgets,
            "concurrent_writes": self.concurrent_writes,
            "unattributed": self.unattributed,
            "tracked": len(self._base),
            "ops": len(self._vc),
        }
