"""Runtime protocol sanitizer: live quorum/tag/vocabulary checks (ISSUE 8).

:class:`ProtocolSanitizer` attaches to a :class:`repro.net.sim.Network`
(``DSSParams.sanitize=True`` or ``REPRO_SANITIZE=1``) and observes three
points the engine already passes through — it never draws randomness, never
schedules events, and never mutates protocol state, so a sanitized run
replays the *same* virtual-time trace as an unsanitized one:

* **every RPC fan-out** (``Network._run_rpc``): the quorum-intersection
  check. Any two quorums of one configuration must intersect — for
  majority-quorum ops that means ``need >= floor(B/2)+1`` over the ``B``
  destinations, and for EC data ops ``need >= ceil((n+k)/2)`` (the paper's
  §VII-A quorum). ``k`` is learned per server-set from every ``Config``
  that flows past (genesis, ``make_config``, decided recon values, gossip);
  when a server set is unknown the majority floor still applies. Ops
  addressed to *whoever is alive* (``need="alive"``: repair pulls, health
  probes, gossip) are reads of best-effort state, not quorum rounds, and
  are skipped.

* **every reply** (both fan-out engines, including replies arriving after
  the quorum resumed): per-``(server, object, index)`` tag monotonicity.
  A server's ABD tag and EC List maximum only ever grow (the List trims
  *values*, never tag keys), a finalized next-config announcement never
  regresses to proposed/none and never changes its config, and a Paxos
  acceptor's nack ballot never shrinks. Reply and request tags must come
  from the codec registries (``MESSAGE_TYPES``/``REPLY_TYPES``/gossip) —
  the live half of the registry-drift lint.

* **external state surgery** (``StorageServer._invalidate`` → the
  ``_mut_observer`` hook): tests and fault-injection harnesses mutate
  server state directly (deleting fragments, wiping disks, rotting bytes).
  Those writes go through the PR-6 tracked ``_StateMap``/``_ObjState``
  maps, which already fire per-object invalidation — outside ``handle``
  the sanitizer treats that as "this (server, object) legitimately lost
  state" and forgets its high-water marks, so deliberate fault injection
  is not reported as a protocol bug. A *buggy server* that loses state
  without going through its own tracked maps (or a seeded
  ``dict.__setitem__`` bypass in the sanitizer's own tests) IS caught.

Violations raise :class:`SanitizerError` immediately, failing the run at
the first bad fan-out/reply. Post-hoc history checking (Wing–Gong tag
order) lives in :mod:`repro.analysis.linearize`; ``DSS.check_history`` and
the workload harness call it after a sanitized run.
"""
from __future__ import annotations

from typing import Any

from repro.net.codec import (
    GOSSIP_REPLY_TYPES,
    GOSSIP_TYPES,
    MESSAGE_TYPES,
    REPLY_TYPES,
)


class SanitizerError(RuntimeError):
    """A protocol invariant was violated on live traffic."""


# ops whose fan-out must reach a majority of its destinations (any two
# majorities intersect; read-next/write-next/consensus use cfg.majority(),
# ABD data ops use the ABD quorum == majority)
_MAJORITY_OPS = frozenset({
    "abd-get", "abd-get-tag", "abd-put", "abd-get-batch", "abd-put-batch",
    "read-next", "write-next", "read-next-batch", "write-next-batch",
    "cons-p1", "cons-p2", "cons-p1-batch", "cons-p2-batch",
})
# EC data ops additionally need the §VII-A quorum ceil((n+k)/2) — checked
# when k is known for the destination server-set
_EC_DATA_OPS = frozenset({
    "ec-query", "ec-put", "ec-query-batch", "ec-put-batch",
})

_KNOWN_TAGS = MESSAGE_TYPES | GOSSIP_TYPES
_KNOWN_REPLIES = REPLY_TYPES | GOSSIP_REPLY_TYPES


def _max_tag(entries: Any) -> Any:
    """Max tag of an ``ec-list``-shaped ``((tag, elem), ...)``; None when
    empty (a filtered reply that shipped nothing proves no maximum)."""
    best = None
    for t, _e in entries:
        if best is None or t > best:
            best = t
    return best


class ProtocolSanitizer:
    """Observer for live ``Network`` traffic; raises :class:`SanitizerError`
    on the first violated invariant. See the module docstring."""

    def __init__(self) -> None:
        # EC parameter registry: frozenset(servers) -> smallest k seen.
        # Smallest k => smallest legal quorum, so an ambiguous server set
        # (two configs, same servers, different k) stays conservative:
        # a fan-out legal under EITHER config passes.
        self.known_k: dict[frozenset[str], int] = {}
        # (sid, obj) -> {("abd", idx): tag, ("ec", idx): tag,
        #                ("next", idx): (cfg_id, status),
        #                ("ballot", idx): ballot}
        self._hw: dict[tuple[str, Any], dict[Any, Any]] = {}
        self.checks = 0       # fan-outs + replies inspected
        self.forgets = 0      # external-mutation resets observed

    # ------------------------------------------------------------ wiring
    def attach(self, net: Any) -> "ProtocolSanitizer":
        """Install on a Network: hook the RPC/reply observation points and
        the external-mutation observer of every (current and future)
        server."""
        net.sanitizer = self
        for srv in net.servers.values():
            if hasattr(srv, "_mut_observer"):
                srv._mut_observer = self.forget
        return self

    def register_config(self, cfg: Any) -> None:
        """Learn a configuration's EC parameters (idempotent; non-EC and
        malformed values are ignored — the sanitizer only ever *observes*)."""
        servers = getattr(cfg, "servers", None)
        if not servers or getattr(cfg, "dap", "abd") not in ("ec", "ec_opt"):
            return
        key = frozenset(servers)
        k = int(cfg.k)
        cur = self.known_k.get(key)
        if cur is None or k < cur:
            self.known_k[key] = k

    def forget(self, sid: str, obj: Any) -> None:
        """External-mutation observer (``StorageServer._mut_observer``):
        state of ``obj`` on ``sid`` changed outside ``handle`` — fault
        injection, wipes — so its high-water marks no longer bind."""
        if self._hw.pop((sid, obj), None) is not None:
            self.forgets += 1

    # ------------------------------------------------------------ fan-out
    def on_rpc(self, rpc: Any, need: int | None) -> None:
        """Quorum-intersection check at issue time. ``need`` is the resolved
        numeric requirement (post ``min(need, len(dests))`` clamp); alive-
        mode fan-outs pass ``None`` and are skipped."""
        self.checks += 1
        msg = rpc.msg
        if msg is None and rpc.per_dest:
            msg = next(iter(rpc.per_dest.values()))
        if not (isinstance(msg, tuple) and msg and isinstance(msg[0], str)):
            return  # outside the protocol vocabulary (e.g. toy test servers)
        op = msg[0]
        if op not in _KNOWN_TAGS:
            raise SanitizerError(
                f"unknown message type {op!r} on the wire — handler/codec "
                "registry drift (see net/codec.py MESSAGE_TYPES)"
            )
        if need is None:
            return  # "alive"-addressed: not a quorum round
        B = len(rpc.dests)
        if B == 0:
            return
        if op in _MAJORITY_OPS or op in _EC_DATA_OPS:
            majority = B // 2 + 1
            if need < majority:
                raise SanitizerError(
                    f"{op} fan-out to {B} servers waits for only {need} "
                    f"replies < majority {majority}: two such quorums need "
                    "not intersect"
                )
        if op in _EC_DATA_OPS:
            k = self.known_k.get(frozenset(rpc.dests))
            if k is not None:
                q = -((B + k) // -2)  # ceil((n + k) / 2)
                if need < q:
                    raise SanitizerError(
                        f"{op} fan-out to n={B} servers (k={k}) waits for "
                        f"only {need} replies < EC quorum ceil((n+k)/2)="
                        f"{q}: two quorums need not intersect in k servers"
                    )

    # ------------------------------------------------------------- replies
    def on_reply(self, sid: str, msg: Any, reply: Any) -> None:
        """Per-reply monotonicity checks (called for EVERY processed
        arrival, including replies past the quorum)."""
        self.checks += 1
        if not (isinstance(reply, tuple) and reply
                and isinstance(reply[0], str)):
            return
        tag = reply[0]
        if tag not in _KNOWN_REPLIES:
            raise SanitizerError(
                f"unknown reply type {tag!r} from {sid} — handler/codec "
                "registry drift (see net/codec.py REPLY_TYPES)"
            )
        if not (isinstance(msg, tuple) and msg):
            return
        op = msg[0]
        if op == "abd-get" or op == "abd-get-tag":
            # ("abd-val", tag, val) / ("abd-tag", tag): the server's current
            # tag rides every reply, even conditional-transfer ones
            self._tag_floor(sid, msg[1], "abd", msg[2], reply[1])
        elif op == "abd-get-batch":
            # ("abd-val-batch", ((tag, val), ...)) in item order
            idx = msg[2]
            for (obj, _ctag), (t, _v) in zip(msg[1], reply[1]):
                self._tag_floor(sid, obj, "abd", idx, t)
        elif op == "abd-put":
            # ("ack",): the server now stores at least this tag
            self._raise_floor(sid, msg[1], "abd", msg[2], msg[3])
        elif op == "abd-put-batch":
            idx = msg[2]
            for obj, t, _v in msg[1]:
                self._raise_floor(sid, obj, "abd", idx, t)
        elif op == "ec-query":
            # ("ec-list", ((tag, elem), ...)): a non-empty (or unfiltered)
            # List reply reports the server's true max tag — trims keep tag
            # keys, and the DAPopt filter only hides tags below the client's
            obs = _max_tag(reply[1])
            if obs is not None:
                self._tag_floor(sid, msg[1], "ec", msg[2], obs)
        elif op == "ec-query-batch":
            idx = msg[2]
            for (obj, _ctag), entries in zip(msg[1], reply[1]):
                obs = _max_tag(entries)
                if obs is not None:
                    self._tag_floor(sid, obj, "ec", idx, obs)
        elif op == "ec-put":
            self._raise_floor(sid, msg[1], "ec", msg[2], msg[3])
        elif op == "ec-put-batch":
            idx = msg[2]
            for obj, t, _e in msg[1]:
                self._raise_floor(sid, obj, "ec", idx, t)
        elif op == "ec-repair-pull":
            # full snapshot — same floor logic as an unfiltered query
            obs = _max_tag(reply[1])
            if obs is not None:
                self._tag_floor(sid, msg[1], "ec", msg[2], obs)
        elif op == "margin-batch":
            idx = msg[2]
            for obj, (abd_tag, ec_items, _status) in zip(msg[1], reply[1]):
                if abd_tag is not None:
                    self._tag_floor(sid, obj, "abd", idx, abd_tag)
                if ec_items:
                    self._tag_floor(
                        sid, obj, "ec", idx,
                        max(t for t, _holds in ec_items),
                    )
        elif op == "read-next":
            self._next_c(sid, msg[1], msg[2], reply[1])
        elif op == "read-next-batch":
            for (obj, idx), ent in zip(msg[1], reply[1]):
                self._next_c(sid, obj, idx, ent)
        elif op == "write-next":
            self._next_c(sid, msg[1], msg[2], (msg[3], msg[4]), announced=True)
        elif op == "write-next-batch":
            for obj, idx, cfg, status in msg[1]:
                self._next_c(sid, obj, idx, (cfg, status), announced=True)
        elif op == "cons-p1" or op == "cons-p2":
            self._ballot(sid, msg[1], msg[2], reply)
        elif op == "cons-p1-batch":
            idx, objs = msg[2], msg[1]
            for obj, r in zip(objs, reply[1]):
                self._ballot(sid, obj, idx, r)
        elif op == "cons-p2-batch":
            idx = msg[2]
            for (obj, _val), r in zip(msg[1], reply[1]):
                self._ballot(sid, obj, idx, r)

    # ------------------------------------------------------- state tracking
    def _rec(self, sid: str, obj: Any) -> dict[Any, Any]:
        rec = self._hw.get((sid, obj))
        if rec is None:
            rec = self._hw[(sid, obj)] = {}
        return rec

    def _tag_floor(self, sid: str, obj: Any, kind: str, idx: Any, observed: Any) -> None:
        """Observed tag must not regress below the high-water; then raises
        the high-water to it."""
        rec = self._rec(sid, obj)
        key = (kind, idx)
        hw = rec.get(key)
        if hw is not None and observed < hw:
            raise SanitizerError(
                f"server {sid} reported {kind} tag {observed} for "
                f"{obj!r}@cfg{idx} after previously proving tag {hw}: "
                "per-server tag monotonicity violated"
            )
        if hw is None or observed > hw:
            rec[key] = observed

    def _raise_floor(self, sid: str, obj: Any, kind: str, idx: Any, tag: Any) -> None:
        """An acked put: the server stores >= tag from now on (no check —
        acks never reveal a regression, they only raise the floor)."""
        rec = self._rec(sid, obj)
        key = (kind, idx)
        hw = rec.get(key)
        if hw is None or tag > hw:
            rec[key] = tag

    def _next_c(self, sid: str, obj: Any, idx: Any, entry: Any, announced: bool = False) -> None:
        """Successor-config stickiness: once a server proves ⟨c, F⟩ at an
        index, later observations must stay exactly ⟨c, F⟩ (consensus makes
        the config unique; F never demotes). ``announced=True`` records an
        acked write-next without reading the reply (acks carry no state)."""
        if entry is None:
            cfg_id, status = None, None
        else:
            cfg, status = entry
            cfg_id = getattr(cfg, "cfg_id", cfg)
        rec = self._rec(sid, obj)
        key = ("next", idx)
        hw = rec.get(key)
        if hw is not None and hw[1] == "F":
            if not announced and (status != "F" or cfg_id != hw[0]):
                raise SanitizerError(
                    f"server {sid} reported next-config {entry!r} for "
                    f"{obj!r}@cfg{idx} after finalizing "
                    f"⟨{hw[0]}, F⟩: finalized successor regressed"
                )
            if announced and status == "F" and cfg_id != hw[0]:
                raise SanitizerError(
                    f"two different configs finalized at {obj!r}@cfg{idx} "
                    f"on {sid}: {hw[0]} then {cfg_id} (consensus uniqueness "
                    "violated)"
                )
            return
        if status is not None and (hw is None or status == "F"):
            rec[key] = (cfg_id, status)
        if entry is not None:
            self.register_config(entry[0])

    def _ballot(self, sid: str, obj: Any, idx: Any, r: Any) -> None:
        """Acceptor promise monotonicity: the ballot a nack reports is the
        server's current promise, which only ever grows."""
        if not (isinstance(r, tuple) and r and r[0] in ("p1-nack", "p2-nack")):
            return
        ballot = r[1]
        rec = self._rec(sid, obj)
        key = ("ballot", idx)
        hw = rec.get(key)
        if hw is not None and ballot < hw:
            raise SanitizerError(
                f"server {sid} nacked {obj!r}@cfg{idx} with ballot "
                f"{ballot} after promising {hw}: acceptor promise regressed"
            )
        if hw is None or ballot > hw:
            rec[key] = ballot

    # ------------------------------------------------------------- report
    def report(self) -> dict[str, int]:
        return {
            "checks": self.checks,
            "forgets": self.forgets,
            "tracked": len(self._hw),
            "known_server_sets": len(self.known_k),
        }
