"""Assigned architecture configs (--arch <id>). Sources per config file."""
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shapes_for

_ARCH_IDS = [
    "qwen2_vl_7b", "olmoe_1b_7b", "qwen3_moe_30b_a3b", "gemma3_1b",
    "chatglm3_6b", "qwen3_0_6b", "qwen2_0_5b", "mamba2_2_7b",
    "whisper_base", "zamba2_7b",
]


def get_arch(name: str) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in _ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {_ARCH_IDS}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_archs() -> dict[str, ArchConfig]:
    return {a: get_arch(a) for a in _ARCH_IDS}
