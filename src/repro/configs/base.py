"""Architecture + shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # --- attention flavour ---
    rope_theta: float = 1e4
    rope_style: str = "standard"     # standard | partial (chatglm 2d) | mrope
    rope_fraction: float = 1.0       # chatglm3: rotary on half the dims
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl (t, h, w) splits of hd/2
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen2
    sliding_window: int = 0          # gemma3 local layers
    global_every: int = 0            # gemma3: layer i is global iff i % this == this-1
    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_kernel: int = 4
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0       # shared transformer block after every N mamba layers
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    # --- modality stubs ---
    embeddings_input: bool = False   # vlm/audio: inputs are precomputed embeddings
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid") or (
            self.sliding_window > 0 and self.global_every > 0
        )

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab=256,
            head_dim=16,
            encoder_layers=min(self.encoder_layers, 2),
        )
        if self.moe_experts:
            small.update(moe_experts=4, moe_top_k=2, moe_d_ff=32)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16, ssm_expand=2)
        if self.sliding_window:
            small.update(sliding_window=32, global_every=2)
        if self.shared_attn_every:
            small.update(shared_attn_every=2, n_layers=5)
        if self.mrope_sections:
            small.update(mrope_sections=(2, 3, 3))
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg: ArchConfig) -> list[ShapeConfig]:
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out
