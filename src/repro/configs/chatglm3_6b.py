"""ChatGLM3-6B [arXiv:2406.12793; hf]. 2D/partial RoPE (half dims), GQA kv=2."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab=65024, head_dim=128, rope_theta=1e4,
    rope_style="partial", rope_fraction=0.5, qkv_bias=True,
)
