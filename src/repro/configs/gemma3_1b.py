"""Gemma3-1B [hf:google/gemma-3-1b-pt; unverified]. 5:1 local:global,
sliding window 512, 128k-class context, tied embeddings, huge vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, head_dim=256, rope_theta=1e6, qk_norm=True,
    sliding_window=512, global_every=6, tie_embeddings=True,
)
