"""OLMoE-1B-7B [arXiv:2409.02060; hf]. 64 experts, top-8, d_ff=1024/expert."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50304, head_dim=128, rope_theta=1e4, qk_norm=True,
    moe_experts=64, moe_top_k=8, moe_d_ff=1024,
)
