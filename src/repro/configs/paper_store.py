"""The paper's OWN system configuration (§VII-A Emulab setup) — deployment
descriptors for the storage service itself, selectable like an arch config.

    from repro.configs.paper_store import EMULAB, AWS, make_dss
"""
from dataclasses import dataclass

from repro.core.store import DSS, DSSParams
from repro.net.sim import LatencyModel


@dataclass(frozen=True)
class StoreConfig:
    name: str
    n_servers: int
    parity_m: int
    algorithm: str = "coaresecf"
    min_block: int = 1 << 17          # paper: min 512 kB (1:4 scale)
    avg_block: int = 1 << 17
    max_block: int = 1 << 18          # paper: max 1 MB
    base_lo: float = 0.1e-3           # Emulab LAN
    base_hi: float = 0.3e-3
    bandwidth: float = 125e6          # 1 Gbit/s


# §VII-D Emulab: 11 servers, m=5 (k=6) / m=1 (k=10); 5 writers, 5 readers
EMULAB = StoreConfig("emulab", n_servers=11, parity_m=5)
EMULAB_M1 = StoreConfig("emulab_m1", n_servers=11, parity_m=1)
# §VII-D AWS: 6 servers, m=4 (k=2) / m=1 (k=5); WAN-ish latencies
AWS = StoreConfig("aws", n_servers=6, parity_m=4, base_lo=5e-3, base_hi=25e-3)


def make_dss(cfg: StoreConfig, seed: int = 0) -> DSS:
    return DSS(DSSParams(
        algorithm=cfg.algorithm, n_servers=cfg.n_servers, parity_m=cfg.parity_m,
        seed=seed, min_block=cfg.min_block, avg_block=cfg.avg_block,
        max_block=cfg.max_block,
        latency=LatencyModel(base_lo=cfg.base_lo, base_hi=cfg.base_hi,
                             bandwidth=cfg.bandwidth),
    ))
