"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf]. M-RoPE; vision frontend is a
stub (input_specs provides patch embeddings + 3D positions)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, head_dim=128,
    rope_theta=1e6, rope_style="mrope", mrope_sections=(16, 24, 24),
    qkv_bias=True, embeddings_input=True,
)
