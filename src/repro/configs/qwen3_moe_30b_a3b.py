"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]. 128 experts, top-8, d_ff=768/expert."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab=151936, head_dim=128, rope_theta=1e6, qk_norm=True,
    moe_experts=128, moe_top_k=8, moe_d_ff=768,
)
