"""Whisper-base [arXiv:2212.04356; unverified]. Enc-dec; conv frontend stub
(input_specs provides frame embeddings at seq_len/2 frames)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec",
    n_layers=6, encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64, rope_style="none", tie_embeddings=True,
)
