"""Zamba2-7B [arXiv:2411.15242; unverified]. Mamba2 backbone + ONE shared
attention+MLP transformer block applied after every 6 Mamba2 layers
(capacity-faithful approximation of the Zamba2 shared-block scheme)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, head_dim=112, ssm_state=64, ssm_headdim=64, ssm_expand=2,
    ssm_chunk=128, shared_attn_every=6,
)
