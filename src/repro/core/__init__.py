"""The paper's contribution: CoARES, CoARESF, EC-DAP/EC-DAPopt (+ checkers),
plus the beyond-paper self-healing repair subsystem (``repro.core.repair``)."""
from repro.core.coares import CoAresClient, StaticCoverableClient
from repro.core.fragment import (
    FragmentationModule,
    decode_block_value,
    encode_block_value,
    encode_genesis_meta,
    genesis_id,
    parse_genesis_meta,
)
from repro.core.repair import RepairController, RepairDaemon
from repro.core.server import StorageServer
from repro.core.store import ALGORITHMS, DSS, ClientHandle, DSSParams
from repro.core.tags import TAG0, Config, CSeqEntry, OpRecord, Tag, next_tag

__all__ = [
    "CoAresClient",
    "StaticCoverableClient",
    "FragmentationModule",
    "RepairController",
    "RepairDaemon",
    "StorageServer",
    "DSS",
    "DSSParams",
    "ClientHandle",
    "ALGORITHMS",
    "Config",
    "CSeqEntry",
    "OpRecord",
    "Tag",
    "TAG0",
    "next_tag",
    "genesis_id",
    "encode_block_value",
    "decode_block_value",
    "encode_genesis_meta",
    "parse_genesis_meta",
]
