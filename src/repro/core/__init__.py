"""The paper's contribution: CoARES, CoARESF, EC-DAP/EC-DAPopt (+ checkers),
plus the beyond-paper self-healing repair subsystem (``repro.core.repair``)
and the Session/future client API (``repro.core.api``)."""
from repro.core.api import OpStats, Session, Workload, gather
from repro.core.coares import CoAresClient, StaticCoverableClient
from repro.core.gateway import Gateway, GossipListener
from repro.core.fragment import (
    FragmentationModule,
    decode_block_value,
    encode_block_value,
    encode_genesis_meta,
    genesis_id,
    parse_genesis_meta,
)
from repro.core.repair import ObjectHealth, RepairController, RepairDaemon, probe_health
from repro.core.server import StorageServer
from repro.core.store import ALGORITHMS, DSS, ClientHandle, DSSParams
from repro.core.tags import TAG0, Config, CSeqEntry, OpRecord, Tag, next_tag
from repro.core.workload import CrashStorm, WorkloadGen, WorkloadSpec
from repro.net.sim import (
    DeadlineExceeded,
    FaultEvent,
    FaultPlan,
    QuorumUnavailableError,
    RetryPolicy,
    RpcTimeout,
)

__all__ = [
    "Session",
    "WorkloadGen",
    "WorkloadSpec",
    "CrashStorm",
    "Gateway",
    "GossipListener",
    "Workload",
    "OpStats",
    "gather",
    "CoAresClient",
    "StaticCoverableClient",
    "ObjectHealth",
    "probe_health",
    "FragmentationModule",
    "RepairController",
    "RepairDaemon",
    "StorageServer",
    "DSS",
    "DSSParams",
    "ClientHandle",
    "ALGORITHMS",
    "Config",
    "CSeqEntry",
    "OpRecord",
    "Tag",
    "TAG0",
    "next_tag",
    "genesis_id",
    "encode_block_value",
    "decode_block_value",
    "encode_genesis_meta",
    "parse_genesis_meta",
    "RetryPolicy",
    "FaultPlan",
    "FaultEvent",
    "QuorumUnavailableError",
    "RpcTimeout",
    "DeadlineExceeded",
]
