"""Session/future client API with cross-file batched scheduling (ISSUE 3).

The public surface used to be "build a generator op, thread it through
``dss.net.run_op``, scrape whatever dict it returns". That drives ONE
operation at a time, so the PR-2 state-transfer engine — which batches all
blocks of one file into single quorum rounds — still pays O(F) quorum
rounds when a workload touches F files. This module replaces that surface:

* :class:`Session` — the per-client handle. ``submit(op)`` runs any raw
  generator op; ``write``/``read``/``recon``/``stat`` are the conveniences.
  Every call returns immediately with an :class:`OpFuture`.
* **Cross-file aggregation**: convenience ops do NOT dispatch one generator
  each. They queue as intents, and a per-session scheduler drains the queue
  after ``window`` virtual seconds: consecutive same-kind intents coalesce
  into ONE multi-file batch op (``ClientHandle.read_batch`` etc.), which
  rides the engine's multi-object RPCs. Config discovery, max-tag gathers
  and put-until-stable rounds for different FILES thus share the same
  ``read-next-batch``/``ec-query-batch``/``ec-put-batch`` fan-outs — an
  F-file fan-out completes in O(1) quorum rounds instead of O(F)
  (``benchmarks/bench_multifile.py`` measures exactly this).
* :class:`OpStats` — every future carries uniform stats (quorum rounds,
  messages, bytes, virtual-time latency, blocks) measured from the
  network's per-client counters, so benchmarks and tests stop scraping
  heterogeneous result dicts. Coalesced ops share their batch's totals
  (``batched_with`` says how many rode along).
* :class:`Workload` / :func:`gather` — run any mix of operations from any
  number of clients concurrently on the virtual-time network and collect
  results in submission order.

The old surface (``dss.client(cid)`` + ``dss.net.run_op``) keeps working as
a deprecation shim — the Session drives those same ``ClientHandle``
generator ops underneath — but new code and the examples use this API.

Program order note: intents of ONE session coalesce only within a same-kind
run, so ``write(f); read(f)`` from the same session still executes the
write group before the read group. Ops from different sessions are
concurrent, exactly like the paper's independent clients.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Generator

from repro.core.tags import Config
from repro.net.sim import DeadlineExceeded


@dataclass
class OpStats:
    """Uniform per-operation accounting (ISSUE 3).

    ``rounds``/``msgs``/``bytes`` come from the network's per-client
    counters — under a coalesced batch they are the BATCH's totals, shared
    by all ``batched_with`` riders (charging each rider the full fan-out
    would multi-count shared rounds; dividing would hide them). The same
    interval semantics apply to any ops of ONE client that overlap in
    virtual time (e.g. two concurrent ``submit`` loops): each op's stats
    include the client's traffic during its lifetime, so summing stats
    across overlapping same-client futures over-counts — sum
    ``Network.client_totals`` deltas instead for whole-workload totals."""

    rounds: int = 0
    msgs: int = 0
    bytes: int = 0
    latency: float = 0.0
    blocks: int = 0
    batched_with: int = 1
    # RPC retransmissions observed network-wide during this op's lifetime
    # (ISSUE 10). Coarse under concurrency — like rounds/msgs it is an
    # interval delta, so overlapping ops share amplification — but exact in
    # the common single-op-probe case and always 0 with retries disabled.
    retries: int = 0


class OpFuture:
    """Handle to an in-flight Session operation (concurrent.futures style:
    ``done()`` / ``result()``; ``result`` drives the event loop only as far
    as needed, so background daemons never block completion)."""

    def __init__(self, session: "Session", kind: str, fid: str | None):
        self.session = session
        self.kind = kind
        self.fid = fid
        self.client = session.cid
        self.stats: OpStats | None = None
        self._done = False
        self._result: Any = None
        self._error: BaseException | None = None

    # virtual-time deadline for ``result()`` when neither the caller nor an
    # active RetryPolicy (``op_deadline``) supplies one (ISSUE 10 — replaces
    # the old magic 50M-event budget with a real deadline error).
    DEFAULT_DEADLINE = 60.0

    def done(self) -> bool:
        return self._done

    def exception(self) -> BaseException | None:
        """What the operation raised, if it failed — ``None`` while pending
        or on success (concurrent.futures parity; lets a workload tally
        failures without re-raising through ``result``)."""
        return self._error

    def result(self, deadline: float | None = None) -> Any:
        """Step the virtual-time network until this operation completes,
        then return its result (or raise what the operation raised).

        ``deadline`` bounds how far VIRTUAL time may advance past the call
        (default: the active ``RetryPolicy.op_deadline``, else
        ``DEFAULT_DEADLINE``). A blown deadline — quorum lost with retries
        disabled, or only background traffic left — raises
        :class:`DeadlineExceeded` carrying ``Network.stuck_ops()``
        diagnostics instead of spinning on an event budget."""
        net = self.session.net
        if deadline is None:
            policy = getattr(net, "retry", None)
            deadline = policy.op_deadline if policy is not None \
                else self.DEFAULT_DEADLINE
        t0 = net.now
        while not self._done and net.step():
            if net.now - t0 > deadline:
                raise DeadlineExceeded(
                    f"{self.kind}({self.fid!r}) missed its {deadline}s "
                    f"virtual deadline; stuck rounds: {net.stuck_ops()!r}"
                )
        if not self._done:
            raise DeadlineExceeded(
                f"{self.kind}({self.fid!r}): network quiesced without "
                f"completing it; stuck rounds: {net.stuck_ops()!r}"
            )
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result: Any, stats: OpStats) -> None:
        self._result = result
        self.stats = stats
        self._done = True

    def _fail(self, err: BaseException, stats: OpStats | None = None) -> None:
        self._error = err
        self.stats = stats
        self._done = True

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = "done" if self._done else "pending"
        return f"OpFuture({self.kind}, {self.fid!r}, {state})"


@dataclass
class _Intent:
    kind: str
    fid: str | None
    arg: Any
    fut: OpFuture


def _dispatch_group(handle, group: list[_Intent]) -> Generator:
    """Issue ONE merged batch operation for a same-kind intent group and
    return ``(payload, blocks)`` — ``payload[fid]`` is what each future
    resolves to, ``blocks[fid]`` feeds ``OpStats.blocks``. Shared by the
    Session scheduler and the gateway tier so the per-kind payload shapes
    can never diverge between the direct and aggregated paths. Repeated
    fids (a gateway merging same-file intents from several clients)
    dedupe here; the result is multicast by resolving every intent from
    the one payload entry."""
    kind = group[0].kind
    fids = list(dict.fromkeys(it.fid for it in group))
    if kind == "read":
        res = yield from handle.read_batch(fids)
        return ({f: content for f, (content, _n) in res.items()},
                {f: n for f, (_c, n) in res.items()})
    if kind == "write":
        res = yield from handle.update_batch({it.fid: it.arg for it in group})
        return res, {f: s["blocks"] for f, s in res.items()}
    if kind == "recon":
        # recon futures resolve to a real result dict; the raw {fid: n}
        # map feeds OpStats.blocks only (it used to be BOTH the payload
        # and the stats source, so the future's "result" was a bare
        # aliased int — ISSUE 4)
        res = yield from handle.recon_batch(fids, group[0].arg)
        payload = {
            f: {"blocks": n, "config": group[0].arg.cfg_id, "success": True}
            for f, n in res.items()
        }
        return payload, dict(res)
    res = yield from handle.stat_batch(fids)  # stat
    return res, {f: s["blocks"] for f, s in res.items()}


class Session:
    """Per-client handle of the submit/future API.

    ``window`` is the virtual-time coalescing window: convenience ops
    submitted within one window drain together and same-kind runs ride one
    multi-file batch. The default (0.5 ms virtual) sits under the sim's base
    RTT, so batching never costs a visible latency hit; ``window=0.0``
    still coalesces ops submitted back-to-back from ordinary Python code
    (virtual time only advances inside ``net.run``/``step``).

    ``via`` attaches the session to a :class:`repro.core.gateway.Gateway`
    (ISSUE 4): convenience ops are then forwarded to the gateway, which
    coalesces them with in-flight intents from OTHER clients and issues one
    merged storage round on everyone's behalf (same-file reads from C
    clients dedupe to a single quorum fan-out). Raw ``submit`` ops always
    run directly under this session's own endpoint."""

    def __init__(self, dss, cid: str, *, window: float = 0.5e-3, via=None):
        self.dss = dss
        self.cid = cid
        self.net = dss.net
        self._handle = None  # built on first use (ISSUE 7): a gateway-
        # attached session that only issues convenience ops never needs its
        # own protocol client, and at 10^5 sessions eager construction is
        # most of the setup cost.
        self.window = window
        self.via = via
        if via is not None and via.net is not self.net:
            raise ValueError(
                f"gateway {via.gid!r} lives on a different Network than "
                f"session {cid!r}"
            )
        self._pending: list[_Intent] = []
        self._drain_scheduled = False

    @property
    def handle(self):
        """This session's own protocol client (lazily constructed)."""
        if self._handle is None:
            self._handle = self.dss.client(self.cid)
        return self._handle

    # ------------------------------------------------------------- raw ops
    def submit(self, op: Generator, *, kind: str = "op",
               fid: str | None = None) -> OpFuture:
        """Run an arbitrary generator op (e.g. a scripted loop driving
        ``self.handle``) under this session; returns its OpFuture. Raw
        submissions are NOT coalesced — they run as their own op."""
        fut = OpFuture(self, kind, fid)
        self.net.spawn(
            self._instrumented(op, fut, None), kind=kind, client=self.cid
        )
        return fut

    def _instrumented(self, op: Generator, fut: OpFuture,
                      blocks: int | None) -> Generator:
        r0, m0, b0 = self.net.client_totals(self.cid)
        t0 = self.net.now
        x0 = self.net.retransmits
        try:
            res = yield from op
        except Exception as err:  # noqa: BLE001 - delivered via the future
            fut._fail(err, self._delta(r0, m0, b0, t0, 0, 1, x0))
            return None
        fut._resolve(res, self._delta(r0, m0, b0, t0, blocks or 0, 1, x0))
        return res

    def _delta(self, r0, m0, b0, t0, blocks, width, x0=0) -> OpStats:
        r1, m1, b1 = self.net.client_totals(self.cid)
        return OpStats(rounds=r1 - r0, msgs=m1 - m0, bytes=b1 - b0,
                       latency=self.net.now - t0, blocks=blocks,
                       batched_with=width,
                       retries=self.net.retransmits - x0)

    # ------------------------------------------------------- convenience ops
    def write(self, fid: str, content: bytes) -> OpFuture:
        return self._enqueue("write", fid, content)

    def read(self, fid: str) -> OpFuture:
        return self._enqueue("read", fid, None)

    def recon(self, fid: str, new_config: Config) -> OpFuture:
        return self._enqueue("recon", fid, new_config)

    def stat(self, fid: str) -> OpFuture:
        """Per-object reliability: resolves to a dict with the surviving-
        fragment ``margin`` of the file's weakest block (see
        ``ClientHandle.stat_batch``)."""
        return self._enqueue("stat", fid, None)

    def _enqueue(self, kind: str, fid: str, arg: Any) -> OpFuture:
        fut = OpFuture(self, kind, fid)
        if self.via is not None:
            self.via._enqueue(_Intent(kind, fid, arg, fut))
            return fut
        self._pending.append(_Intent(kind, fid, arg, fut))
        if not self._drain_scheduled:
            self._drain_scheduled = True
            self.net.spawn(
                self._drain(), kind="session-drain", client=self.cid,
                delay=self.window,
            )
        return fut

    # ---------------------------------------------------------- the scheduler
    def _groups(self, batch: list[_Intent]) -> list[list[_Intent]]:
        """Maximal runs of consecutive same-kind intents — program order is
        preserved across kind changes. A run also breaks on a repeated fid
        (two writes to one file must stay two operations) and, for recons,
        on a different target configuration."""
        groups: list[list[_Intent]] = []
        fids: set = set()  # fids of the current (last) group, O(1) break check
        for it in batch:
            g = groups[-1] if groups else None
            if (
                g is None
                or g[0].kind != it.kind
                or it.fid in fids
                or (it.kind == "recon" and g[0].arg.cfg_id != it.arg.cfg_id)
            ):
                groups.append([it])
                fids = {it.fid}
            else:
                g.append(it)
                fids.add(it.fid)
        return groups

    def _drain(self) -> Generator:
        # NOTE ``_drain_scheduled`` stays armed for the whole drain: an op
        # enqueued while this generator is mid-flight (e.g. from code
        # reacting to an earlier future of the same batch) must NOT spawn a
        # CONCURRENT drain — it would race ahead of this drain's remaining
        # groups and break per-fid program order. The finally block re-arms
        # a fresh drain for anything that arrived meanwhile, so mid-flight
        # enqueues are never stranded either (the old code reset the flag on
        # entry, opening exactly that reorder/reschedule hazard — ISSUE 4).
        try:
            batch, self._pending = self._pending, []
            for group in self._groups(batch):
                r0, m0, b0 = self.net.client_totals(self.cid)
                t0 = self.net.now
                x0 = self.net.retransmits
                try:
                    payload, blocks = yield from _dispatch_group(
                        self.handle, group
                    )
                except Exception as err:  # noqa: BLE001 - delivered via futures
                    stats = self._delta(r0, m0, b0, t0, 0, len(group), x0)
                    for it in group:
                        it.fut._fail(err, stats)
                    continue
                for it in group:
                    it.fut._resolve(
                        payload[it.fid],
                        self._delta(r0, m0, b0, t0, blocks[it.fid],
                                    len(group), x0),
                    )
        finally:
            self._drain_scheduled = False
            if self._pending:
                self._drain_scheduled = True
                self.net.spawn(
                    self._drain(), kind="session-drain", client=self.cid,
                    delay=self.window,
                )
        return None


def gather(*futures: OpFuture) -> list:
    """Drive the (shared) virtual-time network until every future completes;
    returns their results in argument order. Raises the first failure.

    Every future must live on the SAME ``Network``: mixing futures of
    different ``DSS`` instances used to spin one store's event loop waiting
    for an operation that only the *other* store's loop could ever complete
    (burning the event budget before failing obscurely) — detected up front
    now (ISSUE 4)."""
    nets = {id(f.session.net) for f in futures}
    if len(nets) > 1:
        owners = sorted({f"{f.client}:{f.kind}" for f in futures})
        raise ValueError(
            "gather() futures span multiple DSS/Network instances "
            f"({len(nets)} networks across {owners}); gather each store's "
            "futures separately"
        )
    return [f.result() for f in futures]


class Workload:
    """Combinator for a mixed multi-client operation fan-out: one Session
    per client id (lazily created, all on the store's network), every
    convenience call recorded, ``run()`` == ``gather`` over everything
    submitted so far.

        wl = Workload(dss)
        for i, fid in enumerate(files):
            wl.write(f"w{i % 3}", fid, payloads[fid])
        results = wl.run()          # one O(1)-round fan-out per client
    """

    def __init__(self, dss, *, window: float = 0.5e-3, via=None):
        self.dss = dss
        self.window = window
        self.via = via  # optional Gateway: every session attaches through it
        self._sessions: dict[str, Session] = {}
        self.futures: list[OpFuture] = []

    def session(self, cid: str) -> Session:
        s = self._sessions.get(cid)
        if s is None:
            s = self._sessions[cid] = Session(
                self.dss, cid, window=self.window, via=self.via
            )
        return s

    def _track(self, fut: OpFuture) -> OpFuture:
        self.futures.append(fut)
        return fut

    def write(self, cid: str, fid: str, content: bytes) -> OpFuture:
        return self._track(self.session(cid).write(fid, content))

    def read(self, cid: str, fid: str) -> OpFuture:
        return self._track(self.session(cid).read(fid))

    def recon(self, cid: str, fid: str, new_config: Config) -> OpFuture:
        return self._track(self.session(cid).recon(fid, new_config))

    def stat(self, cid: str, fid: str) -> OpFuture:
        return self._track(self.session(cid).stat(fid))

    def submit(self, cid: str, op: Generator, **kw) -> OpFuture:
        return self._track(self.session(cid).submit(op, **kw))

    def run(self) -> list:
        """Complete every tracked future; results in submission order."""
        return gather(*self.futures)
