"""CoARES (§IV, Algorithm 1) + ARES reconfiguration (§III) + static CoABD.

All client operations are sim generators. ``CoAresClient`` maintains, per
object: the configuration sequence ``cseq`` (list of CSeqEntry), the writer's
``version`` tag (coverability state), and the EC-DAPopt local (c.tag, c.val)
pairs (inside ``dap_state``).
"""
from __future__ import annotations

from typing import Any, Generator

from repro.core.dap.base import make_dap
from repro.core.tags import TAG0, Config, CSeqEntry, F, OpRecord, P, Tag, digest, next_tag
from repro.net.sim import RPC, Sleep


def _register_precode(dap_state: dict, values) -> None:
    """Replace the client's pending batch-encode set: drop stale caches from
    the previous update, then register the new values (singletons and empty
    sets gain nothing from batching and are skipped)."""
    dap_state.pop("_batch_values", None)
    for key in [k for k in dap_state if isinstance(k, tuple) and k[:1] == ("_ecache",)]:
        del dap_state[key]
    vals = {v for v in values if v}
    if len(vals) > 1:
        dap_state["_batch_values"] = vals


class CoAresClient:
    """A client process (reader / writer / reconfigurer) of CoARES."""

    def __init__(self, net, client_id: str, initial_config: Config, history: list | None = None):
        self.net = net
        self.client_id = client_id
        self.c0 = initial_config
        self.cseq: dict[str, list[CSeqEntry]] = {}
        self.version: dict[str, Tag] = {}   # writer coverability state
        self.dap_state: dict = {}            # EC-DAPopt (c.tag, c.val) per (obj, cfg)
        self.history = history if history is not None else []

    # ------------------------------------------------------------- plumbing
    def _cseq(self, obj: str) -> list[CSeqEntry]:
        return self.cseq.setdefault(obj, [CSeqEntry(self.c0, F)])

    def _dap(self, cfg: Config, idx: int):
        return make_dap(self.net, self.client_id, cfg, idx, self.dap_state)

    def _record(self, **kw) -> None:
        self.history.append(OpRecord(**kw))

    def precode(self, values) -> None:
        """Register the byte values an imminent multi-block update will write.
        EC DAPs batch-encode the whole set with one fused GF(256) matmul on
        first use (bit-identical to per-value encoding, see
        ``RSCode.encode_bytes_batch``); ABD DAPs ignore the hint."""
        _register_precode(self.dap_state, values)

    # ---------------------------------------------------- config discovery
    def read_config(self, obj: str) -> Generator:
        """Sequence traversal: follow nextC pointers from the last finalized
        configuration until no successor is announced (§III)."""
        cseq = self._cseq(obj)
        i = max(j for j, e in enumerate(cseq) if e.status == F)
        while True:
            entry = cseq[i]
            replies = yield RPC(
                dests=entry.config.servers,
                msg=("read-next", obj, i),
                need=entry.config.majority(),
            )
            nxt = None
            for r in replies.values():
                cand = r[1]
                if cand is None:
                    continue
                cfg, status = cand
                if nxt is None or (status == F and nxt[1] == P):
                    nxt = (cfg, status)
            if nxt is None:
                break
            cfg, status = nxt
            if i + 1 < len(cseq):
                # configuration uniqueness: same config; maybe upgrade status
                if status == F and cseq[i + 1].status == P:
                    cseq[i + 1].status = F
            else:
                cseq.append(CSeqEntry(cfg, status))
            i += 1
        return cseq

    # ------------------------------------------------------------ consensus
    def _propose(self, obj: str, idx: int, cfg_here: Config, value: Config) -> Generator:
        """Single-decree Paxos on the servers of ``cfg_here`` deciding the
        configuration that follows index ``idx`` (c.Con of §II)."""
        maj = cfg_here.majority()
        n_attempt = 0
        while True:
            n_attempt += 1
            ballot = (n_attempt, self.client_id)
            replies = yield RPC(
                dests=cfg_here.servers,
                msg=("cons-p1", obj, idx, ballot),
                need=maj,
            )
            oks = [r for r in replies.values() if r[0] == "p1-ok"]
            if len(oks) < maj:
                seen = max((r[1][0] for r in replies.values() if r[0] == "p1-nack"), default=0)
                n_attempt = max(n_attempt, seen)
                yield Sleep(float(self.net.rng.uniform(0.5e-3, 3e-3)) * n_attempt)
                continue
            # adopt the highest previously-accepted value, else our own
            accepted = [(r[1], r[2]) for r in oks if r[1] is not None]
            val = max(accepted, key=lambda bv: bv[0])[1] if accepted else value
            replies2 = yield RPC(
                dests=cfg_here.servers,
                msg=("cons-p2", obj, idx, ballot, val),
                need=maj,
            )
            if sum(1 for r in replies2.values() if r[0] == "p2-ok") >= maj:
                return val
            yield Sleep(float(self.net.rng.uniform(0.5e-3, 3e-3)) * n_attempt)

    # ---------------------------------------------------------------- recon
    def recon(self, obj: str, new_config: Config) -> Generator:
        """ARES reconfiguration (§III): traverse, propose, transfer, finalize."""
        t0 = self.net.now
        cseq = yield from self.read_config(obj)
        nu = len(cseq) - 1
        last = cseq[nu]
        # 1) agree on the successor of the last configuration
        decided = yield from self._propose(obj, nu, last.config, new_config)
        # 2) announce ⟨decided, P⟩ on a quorum of the last configuration
        yield RPC(
            dests=last.config.servers,
            msg=("write-next", obj, nu, decided, P),
            need=last.config.majority(),
        )
        if len(cseq) == nu + 1:
            cseq.append(CSeqEntry(decided, P))
        # 3) transfer the maximum tag-value pair into the new configuration
        mu = max(j for j, e in enumerate(cseq) if e.status == F)
        tag, val = TAG0, None
        for j in range(mu, nu + 1):
            t, v = yield from self._dap(cseq[j].config, j).get_data(obj)
            if t >= tag:
                tag, val = t, v
        yield from self._dap(decided, nu + 1).put_data(obj, tag, val)
        # 4) finalize on a quorum of the last old configuration
        yield RPC(
            dests=last.config.servers,
            msg=("write-next", obj, nu, decided, F),
            need=last.config.majority(),
        )
        cseq[nu + 1].status = F
        self._record(
            kind="recon", obj=obj, client=self.client_id, start=t0, end=self.net.now,
            tag=tag, extra={"config": decided.cfg_id},
        )
        return decided

    # ---------------------------------------------------------------- write
    def cvr_write(self, obj: str, value: Any) -> Generator:
        """Alg 1:10-32 — coverable write; degrades to a read when stale."""
        t0 = self.net.now
        cseq = yield from self.read_config(obj)                      # l.11
        mu = max(j for j, e in enumerate(cseq) if e.status == F)     # l.12
        nu = len(cseq) - 1                                           # l.13
        tag, val = TAG0, None
        for j in range(mu, nu + 1):                                  # l.14-15
            t, v = yield from self._dap(cseq[j].config, j).get_data(obj)
            if t >= tag:
                tag, val = t, v
        if self.version.get(obj, TAG0) == tag:                       # l.16
            flag = "chg"
            tag = next_tag(tag, self.client_id)                      # l.18
            val = value
        else:
            flag = "unchg"                                           # l.20
        self.version[obj] = tag                                      # l.21
        # propagate until the configuration sequence is stable (l.22-30)
        while True:
            nu = len(cseq) - 1
            yield from self._dap(cseq[nu].config, nu).put_data(obj, tag, val)
            cseq = yield from self.read_config(obj)
            if len(cseq) - 1 == nu:
                break
        self._record(
            kind="write", obj=obj, client=self.client_id, start=t0, end=self.net.now,
            tag=tag, flag=flag, value_digest=digest(val),
        )
        return (tag, val), flag

    # ----------------------------------------------------------------- read
    def cvr_read(self, obj: str) -> Generator:
        """Alg 1:39-55."""
        t0 = self.net.now
        cseq = yield from self.read_config(obj)
        mu = max(j for j, e in enumerate(cseq) if e.status == F)
        nu = len(cseq) - 1
        tag, val = TAG0, None
        for j in range(mu, nu + 1):
            t, v = yield from self._dap(cseq[j].config, j).get_data(obj)
            if t >= tag:
                tag, val = t, v
        while True:
            nu = len(cseq) - 1
            yield from self._dap(cseq[nu].config, nu).put_data(obj, tag, val)
            cseq = yield from self.read_config(obj)
            if len(cseq) - 1 == nu:
                break
        self._record(
            kind="read", obj=obj, client=self.client_id, start=t0, end=self.net.now,
            tag=tag, value_digest=digest(val),
        )
        return tag, val


class StaticCoverableClient:
    """CoABD [21] (and a static-EC ablation): coverable reads/writes over one
    fixed configuration — the paper's non-reconfigurable baselines."""

    def __init__(self, net, client_id: str, config: Config, history: list | None = None):
        self.net = net
        self.client_id = client_id
        self.config = config
        self.version: dict[str, Tag] = {}
        self.dap_state: dict = {}
        self.history = history if history is not None else []

    def _dap(self):
        return make_dap(self.net, self.client_id, self.config, 0, self.dap_state)

    def _record(self, **kw) -> None:
        self.history.append(OpRecord(**kw))

    def precode(self, values) -> None:
        """See ``CoAresClient.precode``."""
        _register_precode(self.dap_state, values)

    def cvr_write(self, obj: str, value: Any) -> Generator:
        t0 = self.net.now
        dap = self._dap()
        tag, val = yield from dap.get_data(obj)
        if self.version.get(obj, TAG0) == tag:
            flag = "chg"
            tag = next_tag(tag, self.client_id)
            val = value
        else:
            flag = "unchg"
        self.version[obj] = tag
        yield from dap.put_data(obj, tag, val)
        self._record(
            kind="write", obj=obj, client=self.client_id, start=t0, end=self.net.now,
            tag=tag, flag=flag, value_digest=digest(val),
        )
        return (tag, val), flag

    def cvr_read(self, obj: str) -> Generator:
        t0 = self.net.now
        dap = self._dap()
        tag, val = yield from dap.get_data(obj)
        yield from dap.put_data(obj, tag, val)
        self._record(
            kind="read", obj=obj, client=self.client_id, start=t0, end=self.net.now,
            tag=tag, value_digest=digest(val),
        )
        return tag, val

    def recon(self, obj: str, new_config: Config) -> Generator:
        raise NotImplementedError("static algorithms do not reconfigure")
        yield  # pragma: no cover
