"""CoARES (§IV, Algorithm 1) + ARES reconfiguration (§III) + static CoABD.

All client operations are sim generators. ``CoAresClient`` maintains, per
object: the configuration sequence ``cseq`` (list of CSeqEntry), the writer's
``version`` tag (coverability state), and the EC-DAPopt local (c.tag, c.val)
pairs (inside ``dap_state``).

State-transfer engine (ISSUE 2)
-------------------------------
CoARES read, write and recon all run the same loop: discover the
configuration sequence, max-tag get-data over the configs μ..ν, put the
winner into the latest configuration, repeat until the sequence is stable.
That loop lives HERE, once, in multi-object batch form:

* ``read_config_batch``  — sequence traversal for N objects; each round one
  ``read-next-batch`` quorum RPC per distinct frontier configuration.
* ``gather_max``         — the μ..ν max-tag sweep, one ``get_data_batch``
  per configuration window entry (module function; the static clients drive
  it over their single fixed configuration).
* ``_put_until_stable``  — batched put-data into the newest configuration,
  re-traversing until no object's sequence grows (Alg 1:22-30).

``cvr_read`` / ``cvr_write`` / ``recon`` are one-element wrappers over the
batch forms, so the fragmented (FM) paths issue O(1) quorum rounds for a
B-block file instead of O(B). ``recon_batch`` finalization also spawns a
background repair pass of the newly installed configuration (the missing
redundancy-restoration step — see ``repro.core.repair``).

Coding backend (ISSUE 6): every DAP this engine builds via ``make_dap``
receives the network handle, and EC DAPs read ``net.coding_backend``
("numpy" | "kernel" | "auto", set by ``DSS`` from
``DSSParams.coding_backend``) — so recon state transfer between
configurations decodes/re-encodes on the same GF(256) backend as foreground
reads and writes, with no extra plumbing through the engine.
"""
from __future__ import annotations

from typing import Any, Generator, Iterable, Mapping

from repro.core.dap.base import DapClient, make_dap
from repro.core.tags import TAG0, Config, CSeqEntry, F, OpRecord, P, Tag, digest, next_tag
from repro.net.sim import RPC, QuorumUnavailableError, RpcTimeout, Sleep


def _with_phase_retry(net, kind: str, factory) -> Generator:
    """Phase-level retry (ISSUE 10): run ``factory()`` — a fresh generator
    per attempt — re-issuing the WHOLE operation against the then-current
    configuration whenever an RPC round times out, and surface a typed
    :class:`QuorumUnavailableError` (never a hang) once the budget runs dry.

    Re-execution is safe: discover/gather are read-only, and a repeated
    coverable put re-gathers first, so it either re-covers its own surviving
    tag (versions match → a fresh higher tag over the same value) or degrades
    to a read — the per-attempt generator is abandoned mid-flight only at a
    ``yield``, before its history record is written. Retried runs are flagged
    on ``net.op_retries`` so the workload harness relaxes the Wing–Gong
    strict reads-from check (orphan intermediate tags are expected)."""
    policy = net.retry
    if policy is None:
        return (yield from factory())
    attempts = max(1, policy.phase_retries + 1)
    last: Exception | None = None
    for attempt in range(attempts):
        try:
            return (yield from factory())
        except RpcTimeout as err:
            last = err
            if attempt + 1 >= attempts:
                break
            net.op_retries += 1
            # linear backoff with seeded jitter from the retry stream (the
            # RPC tier already did exponential per-attempt backoff; phase
            # re-issues mostly wait out crash-recovery windows).
            backoff = policy.phase_backoff * (attempt + 1)
            backoff *= 1.0 + float(net._retry_rng.random())
            yield Sleep(backoff)
    raise QuorumUnavailableError(
        f"{kind}: no quorum after {attempts} phase attempt(s); last: {last}"
    ) from last


def _register_precode(dap_state: dict, values) -> None:
    """Replace the client's pending batch-encode set: drop stale caches from
    the previous update, then register the new values (singletons and empty
    sets gain nothing from batching and are skipped)."""
    dap_state.pop("_batch_values", None)
    for key in [k for k in dap_state if isinstance(k, tuple) and k[:1] == ("_ecache",)]:
        del dap_state[key]
    vals = {v for v in values if v}
    if len(vals) > 1:
        dap_state["_batch_values"] = vals


def gather_max(daps: list[DapClient], objs: list[str]) -> Generator:
    """State-transfer gather: max-tag get-data over a window of DAPs (one per
    configuration μ..ν; a single-element window for the static algorithms).
    One batched quorum round per configuration, every object riding along.
    Returns ``{obj: (tag, val)}`` — the per-object maximum across the window.
    """
    best: dict[str, tuple[Tag, Any]] = {o: (TAG0, None) for o in objs}
    for dap in daps:
        res = yield from dap.get_data_batch(objs)
        for o, (t, v) in res.items():
            if t >= best[o][0]:
                best[o] = (t, v)
    return best


def apply_coverable(
    version: dict, client_id: str, gathered: Mapping[str, tuple[Tag, Any]],
    updates: Mapping[str, Any],
) -> tuple[dict, dict]:
    """Alg 1:16-21 per object: a writer holding the current version bumps the
    tag and installs its value (chg); a stale writer degrades to a read
    (unchg). Returns ``(results {obj: ((tag, val), flag)}, puts)``."""
    results: dict[str, tuple[tuple[Tag, Any], str]] = {}
    puts: dict[str, tuple[Tag, Any]] = {}
    for o, (tag, val) in gathered.items():
        if version.get(o, TAG0) == tag:
            flag = "chg"
            tag = next_tag(tag, client_id)
            val = updates[o]
        else:
            flag = "unchg"
        version[o] = tag
        puts[o] = (tag, val)
        results[o] = ((tag, val), flag)
    return results, puts


class CoAresClient:
    """A client process (reader / writer / reconfigurer) of CoARES."""

    def __init__(
        self,
        net,
        client_id: str,
        initial_config: Config,
        history: list | None = None,
        *,
        repair_on_recon: bool = True,
        recon_repair_delay: float = 0.0,
        on_recon=None,
    ):
        self.net = net
        self.client_id = client_id
        self.c0 = initial_config
        self.cseq: dict[str, list[CSeqEntry]] = {}
        self.version: dict[str, Tag] = {}   # writer coverability state
        self.dap_state: dict = {}            # EC-DAPopt (c.tag, c.val) per (obj, cfg)
        self.history = history if history is not None else []
        # recon finalization spawns a background repair of the newly installed
        # configuration (after ``recon_repair_delay`` virtual seconds).
        self.repair_on_recon = repair_on_recon
        self.recon_repair_delay = recon_repair_delay
        # recon-finalization callback ``(config, cfg_idx, objs) -> None``:
        # lets observers (the auto-retargeting RepairDaemon, via the DSS
        # notifier) follow reconfigurations without polling the history log.
        self.on_recon = on_recon

    # ------------------------------------------------------------- plumbing
    def _cseq(self, obj: str) -> list[CSeqEntry]:
        return self.cseq.setdefault(obj, [CSeqEntry(self.c0, F)])

    def _dap(self, cfg: Config, idx: int):
        return make_dap(self.net, self.client_id, cfg, idx, self.dap_state)

    def _record(self, **kw) -> None:
        self.history.append(OpRecord(**kw))

    def precode(self, values) -> None:
        """Register the byte values an imminent multi-block update will write.
        EC DAPs batch-encode the whole set with one fused GF(256) matmul on
        first use (bit-identical to per-value encoding, see
        ``RSCode.encode_bytes_batch``); ABD DAPs ignore the hint."""
        _register_precode(self.dap_state, values)

    @staticmethod
    def _groups(objs: list[str], cseqs: dict[str, list[CSeqEntry]]):
        """Group objects whose configuration sequences coincide (the common
        case: every block of a file), so each group shares one DAP window."""
        groups: dict[tuple, list[str]] = {}
        for o in objs:
            key = tuple((e.config.cfg_id, e.status) for e in cseqs[o])
            groups.setdefault(key, []).append(o)
        return list(groups.values())

    # ---------------------------------------------------- config discovery
    def read_config_batch(self, objs: Iterable[str]) -> Generator:
        """Sequence traversal for many objects at once: follow nextC pointers
        from each object's last finalized configuration until no successor is
        announced (§III). Objects sharing a frontier configuration share one
        ``read-next-batch`` quorum RPC per traversal step, so a whole file
        advances in O(len(cseq)) rounds, not O(#blocks · len(cseq)).
        Returns ``{obj: cseq}`` (the same mutable lists ``self.cseq`` holds).
        """
        objs = list(dict.fromkeys(objs))
        cseqs = {o: self._cseq(o) for o in objs}
        frontier = {
            o: max(j for j, e in enumerate(cseqs[o]) if e.status == F) for o in objs
        }
        active = objs
        while active:
            by_cfg: dict[str, list[str]] = {}
            cfg_of: dict[str, Config] = {}
            for o in active:
                cfg = cseqs[o][frontier[o]].config
                by_cfg.setdefault(cfg.cfg_id, []).append(o)
                cfg_of[cfg.cfg_id] = cfg
            advanced: list[str] = []
            for cfg_id, members in by_cfg.items():
                cfg = cfg_of[cfg_id]
                replies = yield RPC(
                    dests=cfg.servers,
                    msg=("read-next-batch", tuple((o, frontier[o]) for o in members)),
                    need=cfg.majority(),
                )
                for pos, o in enumerate(members):
                    nxt = None
                    for r in replies.values():
                        cand = r[1][pos]
                        if cand is None:
                            continue
                        c, status = cand
                        if nxt is None or (status == F and nxt[1] == P):
                            nxt = (c, status)
                    if nxt is None:
                        continue  # traversal done for o
                    cseq, i = cseqs[o], frontier[o]
                    c, status = nxt
                    if i + 1 < len(cseq):
                        # configuration uniqueness: same config; maybe upgrade
                        if status == F and cseq[i + 1].status == P:
                            cseq[i + 1].status = F
                    else:
                        cseq.append(CSeqEntry(c, status))
                    frontier[o] = i + 1
                    advanced.append(o)
            active = advanced
        return cseqs

    def read_config(self, obj: str) -> Generator:
        cseqs = yield from self.read_config_batch((obj,))
        return cseqs[obj]

    # ------------------------------------------------------------ consensus
    def _propose_batch(
        self, objs: list[str], idx: int, cfg_here: Config, value: Config
    ) -> Generator:
        """Single-decree Paxos per object (same index, same deciding
        configuration — c.Con of §II), rounds batched: one ``cons-p1-batch``
        / ``cons-p2-batch`` RPC carries every still-undecided object.
        Returns ``{obj: decided_config}``."""
        maj = cfg_here.majority()
        decided: dict[str, Config] = {}
        todo = list(objs)
        n_attempt = 0
        while todo:
            n_attempt += 1
            ballot = (n_attempt, self.client_id)
            replies = yield RPC(
                dests=cfg_here.servers,
                msg=("cons-p1-batch", tuple(todo), idx, ballot),
                need=maj,
            )
            vals: dict[str, Config] = {}
            ready: list[str] = []
            seen_ballot = 0
            for pos, o in enumerate(todo):
                oks = []
                for r in replies.values():
                    rr = r[1][pos]
                    if rr[0] == "p1-ok":
                        oks.append(rr)
                    else:
                        seen_ballot = max(seen_ballot, rr[1][0])
                if len(oks) >= maj:
                    # adopt the highest previously-accepted value, else our own
                    accepted = [(rr[1], rr[2]) for rr in oks if rr[1] is not None]
                    vals[o] = (
                        max(accepted, key=lambda bv: bv[0])[1] if accepted else value
                    )
                    ready.append(o)
            if ready:
                replies2 = yield RPC(
                    dests=cfg_here.servers,
                    msg=(
                        "cons-p2-batch",
                        tuple((o, vals[o]) for o in ready),
                        idx,
                        ballot,
                    ),
                    need=maj,
                )
                for pos, o in enumerate(ready):
                    acks = sum(
                        1 for r in replies2.values() if r[1][pos][0] == "p2-ok"
                    )
                    if acks >= maj:
                        decided[o] = vals[o]
            todo = [o for o in todo if o not in decided]
            if todo:
                n_attempt = max(n_attempt, seen_ballot)
                yield Sleep(float(self.net.rng.uniform(0.5e-3, 3e-3)) * n_attempt)
        return decided

    def _propose(self, obj: str, idx: int, cfg_here: Config, value: Config) -> Generator:
        decided = yield from self._propose_batch([obj], idx, cfg_here, value)
        return decided[obj]

    # --------------------------------------------------- transfer internals
    def _gather_grouped(self, objs: list[str], cseqs: dict) -> Generator:
        """μ..ν max-tag sweep per cseq-group (Alg 1:12-15): groups objects by
        configuration sequence, builds each group's DAP window, and drives the
        plain ``gather_max`` over it — always call THIS inside the client, not
        the module-level function (which knows nothing about cseq windows)."""
        best: dict[str, tuple[Tag, Any]] = {}
        for members in self._groups(objs, cseqs):
            cseq = cseqs[members[0]]
            mu = max(j for j, e in enumerate(cseq) if e.status == F)
            nu = len(cseq) - 1
            daps = [self._dap(cseq[j].config, j) for j in range(mu, nu + 1)]
            best.update((yield from gather_max(daps, members)))
        return best

    def _put_until_stable(
        self, objs: list[str], cseqs: dict, puts: Mapping[str, tuple[Tag, Any]]
    ) -> Generator:
        """Propagate (tag, val) into each object's newest configuration until
        its sequence stops growing (Alg 1:22-30)."""
        pending = list(objs)
        while pending:
            mark: dict[str, int] = {}
            for members in self._groups(pending, cseqs):
                cseq = cseqs[members[0]]
                nu = len(cseq) - 1
                dap = self._dap(cseq[nu].config, nu)
                yield from dap.put_data_batch(
                    [(o, puts[o][0], puts[o][1]) for o in members]
                )
                for o in members:
                    mark[o] = nu
            cseqs.update((yield from self.read_config_batch(pending)))
            pending = [o for o in pending if len(cseqs[o]) - 1 != mark[o]]
        return cseqs

    # ---------------------------------------------------------------- recon
    def recon_batch(self, objs: Iterable[str], new_config: Config) -> Generator:
        """ARES reconfiguration (§III), phase-retried — see
        :meth:`_recon_batch_impl`."""
        objs = list(dict.fromkeys(objs))  # materialize: retries re-iterate
        return (yield from _with_phase_retry(
            self.net, "recon", lambda: self._recon_batch_impl(objs, new_config)
        ))

    def _recon_batch_impl(self, objs: Iterable[str], new_config: Config) -> Generator:
        """ARES reconfiguration (§III) for many objects: traverse, propose
        (batched consensus), transfer (batched μ..ν sweep + one batched put
        into the decided configuration), finalize — then spawn a background
        repair of the newly installed configuration.
        Returns ``{obj: (decided_config, tag, val)}`` — the transferred pair
        rides along so callers (the FM walk) need not re-read each object."""
        t0 = self.net.now
        objs = list(dict.fromkeys(objs))
        out: dict[str, tuple[Config, Tag, Any]] = {}
        if not objs:
            return out
        cseqs = yield from self.read_config_batch(objs)
        for members in self._groups(objs, cseqs):
            cseq = cseqs[members[0]]
            nu = len(cseq) - 1
            last = cseq[nu]
            # 1) agree on the successor of the last configuration
            decided = yield from self._propose_batch(
                members, nu, last.config, new_config
            )
            san = getattr(self.net, "sanitizer", None)
            if san is not None:
                # consensus may have decided a rival proposer's config —
                # register whatever won so the EC-quorum registry stays
                # complete before traffic hits the new configuration
                for o in members:
                    san.register_config(decided[o])
            # 2) announce ⟨decided, P⟩ on a quorum of the last configuration
            yield RPC(
                dests=last.config.servers,
                msg=(
                    "write-next-batch",
                    tuple((o, nu, decided[o], P) for o in members),
                ),
                need=last.config.majority(),
            )
            for o in members:
                if len(cseqs[o]) == nu + 1:
                    cseqs[o].append(CSeqEntry(decided[o], P))
            # 3) transfer the maximum tag-value pair into the new configuration
            mu = max(j for j, e in enumerate(cseq) if e.status == F)
            daps = [self._dap(cseq[j].config, j) for j in range(mu, nu + 1)]
            best = yield from gather_max(daps, members)
            by_cfg: dict[str, list[str]] = {}
            for o in members:
                by_cfg.setdefault(decided[o].cfg_id, []).append(o)
            for group in by_cfg.values():
                dap = self._dap(decided[group[0]], nu + 1)
                yield from dap.put_data_batch(
                    [(o, best[o][0], best[o][1]) for o in group]
                )
            # 4) finalize on a quorum of the last old configuration
            yield RPC(
                dests=last.config.servers,
                msg=(
                    "write-next-batch",
                    tuple((o, nu, decided[o], F) for o in members),
                ),
                need=last.config.majority(),
            )
            for o in members:
                cseqs[o][nu + 1].status = F
                tag, val = best[o]
                out[o] = (decided[o], tag, val)
                self._record(
                    kind="recon", obj=o, client=self.client_id,
                    start=t0, end=self.net.now, tag=tag,
                    extra={"config": decided[o].cfg_id},
                )
            # 5) repair the configuration just installed (background): the
            # transfer put only waited for a quorum, so restore full
            # redundancy for these objects without blocking the recon.
            if self.repair_on_recon:
                for group in by_cfg.values():
                    self._spawn_repair(decided[group[0]], nu + 1, group)
            if self.on_recon is not None:
                for group in by_cfg.values():
                    self.on_recon(decided[group[0]], nu + 1, tuple(group))
        return out

    def recon(self, obj: str, new_config: Config) -> Generator:
        """Single-object reconfiguration; returns the decided configuration."""
        res = yield from self.recon_batch((obj,), new_config)
        return res[obj][0]

    def _spawn_repair(self, cfg: Config, cfg_idx: int, objs: list[str]) -> None:
        if cfg.dap not in ("ec", "ec_opt"):
            return  # ABD replicates whole values; nothing coded to rebuild
        from repro.core.repair import RepairController

        rc = RepairController(
            self.net, cfg, cfg_idx,
            client_id=f"{self.client_id}:recon-repair", history=self.history,
        )
        # charged to its OWN client id: background repair traffic must not
        # pollute the reconfiguring client's per-op accounting (nor, through
        # the gateway's attribution map, every rider of a merged recon).
        self.net.spawn(
            rc.scan_and_repair(list(objs)),
            kind="recon-repair", client=f"{self.client_id}:recon-repair",
            delay=self.recon_repair_delay,
        )

    # ---------------------------------------------------------------- write
    def cvr_write_batch(self, updates: Mapping[str, Any]) -> Generator:
        """Alg 1:10-32, phase-retried — see :meth:`_cvr_write_batch_impl`."""
        return (yield from _with_phase_retry(
            self.net, "write", lambda: self._cvr_write_batch_impl(updates)
        ))

    def _cvr_write_batch_impl(self, updates: Mapping[str, Any]) -> Generator:
        """Alg 1:10-32 for many objects in one batched pass — coverable
        writes; each object independently degrades to a read when stale.
        Returns ``{obj: ((tag, val), flag)}``."""
        t0 = self.net.now
        objs = list(updates)
        if not objs:
            return {}
        cseqs = yield from self.read_config_batch(objs)            # l.11
        gathered = yield from self._gather_grouped(objs, cseqs)    # l.12-15
        results, puts = apply_coverable(                           # l.16-21
            self.version, self.client_id, gathered, updates
        )
        yield from self._put_until_stable(objs, cseqs, puts)       # l.22-30
        for o in objs:
            (tag, val), flag = results[o]
            self._record(
                kind="write", obj=o, client=self.client_id, start=t0,
                end=self.net.now, tag=tag, flag=flag, value_digest=digest(val),
            )
        return results

    def cvr_write(self, obj: str, value: Any) -> Generator:
        """Alg 1:10-32 — coverable write; degrades to a read when stale."""
        results = yield from self.cvr_write_batch({obj: value})
        return results[obj]

    # ----------------------------------------------------------------- read
    def cvr_read_batch(self, objs: Iterable[str]) -> Generator:
        """Alg 1:39-55, phase-retried — see :meth:`_cvr_read_batch_impl`."""
        objs = list(dict.fromkeys(objs))  # materialize: retries re-iterate
        return (yield from _with_phase_retry(
            self.net, "read", lambda: self._cvr_read_batch_impl(objs)
        ))

    def _cvr_read_batch_impl(self, objs: Iterable[str]) -> Generator:
        """Alg 1:39-55 for many objects in one batched pass.
        Returns ``{obj: (tag, val)}``."""
        t0 = self.net.now
        objs = list(dict.fromkeys(objs))
        if not objs:
            return {}
        cseqs = yield from self.read_config_batch(objs)
        best = yield from self._gather_grouped(objs, cseqs)
        yield from self._put_until_stable(objs, cseqs, best)
        for o in objs:
            self._record(
                kind="read", obj=o, client=self.client_id, start=t0,
                end=self.net.now, tag=best[o][0], value_digest=digest(best[o][1]),
            )
        return best

    def cvr_read(self, obj: str) -> Generator:
        """Alg 1:39-55."""
        best = yield from self.cvr_read_batch((obj,))
        return best[obj]


class StaticCoverableClient:
    """CoABD [21] (and a static-EC ablation): coverable reads/writes over one
    fixed configuration — the paper's non-reconfigurable baselines. Drives
    the same state-transfer engine (``gather_max`` / ``apply_coverable``)
    over a single-configuration window."""

    def __init__(self, net, client_id: str, config: Config, history: list | None = None):
        self.net = net
        self.client_id = client_id
        self.config = config
        self.version: dict[str, Tag] = {}
        self.dap_state: dict = {}
        self.history = history if history is not None else []

    def _dap(self):
        return make_dap(self.net, self.client_id, self.config, 0, self.dap_state)

    def _record(self, **kw) -> None:
        self.history.append(OpRecord(**kw))

    def precode(self, values) -> None:
        """See ``CoAresClient.precode``."""
        _register_precode(self.dap_state, values)

    def cvr_write_batch(self, updates: Mapping[str, Any]) -> Generator:
        return (yield from _with_phase_retry(
            self.net, "write", lambda: self._cvr_write_batch_impl(updates)
        ))

    def _cvr_write_batch_impl(self, updates: Mapping[str, Any]) -> Generator:
        t0 = self.net.now
        objs = list(updates)
        if not objs:
            return {}
        dap = self._dap()
        gathered = yield from gather_max([dap], objs)
        results, puts = apply_coverable(
            self.version, self.client_id, gathered, updates
        )
        yield from dap.put_data_batch([(o, puts[o][0], puts[o][1]) for o in objs])
        for o in objs:
            (tag, val), flag = results[o]
            self._record(
                kind="write", obj=o, client=self.client_id, start=t0,
                end=self.net.now, tag=tag, flag=flag, value_digest=digest(val),
            )
        return results

    def cvr_write(self, obj: str, value: Any) -> Generator:
        results = yield from self.cvr_write_batch({obj: value})
        return results[obj]

    def cvr_read_batch(self, objs: Iterable[str]) -> Generator:
        objs = list(dict.fromkeys(objs))  # materialize: retries re-iterate
        return (yield from _with_phase_retry(
            self.net, "read", lambda: self._cvr_read_batch_impl(objs)
        ))

    def _cvr_read_batch_impl(self, objs: Iterable[str]) -> Generator:
        t0 = self.net.now
        objs = list(dict.fromkeys(objs))
        if not objs:
            return {}
        dap = self._dap()
        best = yield from gather_max([dap], objs)
        yield from dap.put_data_batch([(o, best[o][0], best[o][1]) for o in objs])
        for o in objs:
            self._record(
                kind="read", obj=o, client=self.client_id, start=t0,
                end=self.net.now, tag=best[o][0], value_digest=digest(best[o][1]),
            )
        return best

    def cvr_read(self, obj: str) -> Generator:
        best = yield from self.cvr_read_batch((obj,))
        return best[obj]

    def recon_batch(self, objs, new_config: Config) -> Generator:
        raise NotImplementedError("static algorithms do not reconfigure")
        yield  # pragma: no cover

    def recon(self, obj: str, new_config: Config) -> Generator:
        raise NotImplementedError("static algorithms do not reconfigure")
        yield  # pragma: no cover
