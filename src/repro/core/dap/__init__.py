from repro.core.dap.base import DapClient, make_dap
from repro.core.dap.abd import AbdDap
from repro.core.dap.ec import EcDap

__all__ = ["DapClient", "make_dap", "AbdDap", "EcDap"]
