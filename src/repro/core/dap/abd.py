"""ABD-DAP [6], [22] with the CoBFS [4] conditional-transfer optimization.

get-data: read (tag, value) from a majority, return the max. Clients send
their last-known tag; a server whose stored tag is not newer replies with
``(tag, None)`` (tag-only) — "avoids unnecessary object transmissions
between the clients and the servers" ([4], adopted by the paper's §VI as the
inspiration for EC-DAPopt). The client serves repeated reads of unchanged
blocks from its local copy, which is what makes CoABDF/CoARESABDF reads
O(changed blocks) instead of O(file).

put-data: write (tag, value) to a majority (servers keep the max).

Both primitives are implemented in their multi-object batch form (ISSUE 2):
one ``abd-get-batch`` / ``abd-put-batch`` fan-out carries N objects, and the
single-object calls ride a one-element batch (see ``dap/base.py``).
"""
from __future__ import annotations

from typing import Any, Generator, Iterable, Sequence

from repro.core.dap.base import DapClient
from repro.core.tags import TAG0, Tag
from repro.net.sim import RPC


class AbdDap(DapClient):
    kind = "abd"

    # client-local (tag, value) cache per (obj, config) — same state the
    # EC-DAPopt keeps (Alg 4's c.tag/c.val)
    def _local(self, obj: str) -> tuple[Tag, Any]:
        return self.client_state.setdefault(
            ("abd", obj, self.config.cfg_id), (TAG0, None)
        )

    def _set_local(self, obj: str, tag: Tag, val: Any) -> None:
        self.client_state[("abd", obj, self.config.cfg_id)] = (tag, val)

    def get_tag(self, obj: str) -> Generator:
        replies = yield RPC(
            dests=self.config.servers,
            msg=("abd-get-tag", obj, self.cfg_idx),
            need=self.config.quorum(),
        )
        return max((r[1] for r in replies.values()), default=TAG0)

    def get_data_batch(self, objs: Iterable[str]) -> Generator:
        objs = list(objs)
        if not objs:
            return {}
        local = {o: self._local(o) for o in objs}
        replies = yield RPC(
            dests=self.config.servers,
            msg=("abd-get-batch", tuple((o, local[o][0]) for o in objs), self.cfg_idx),
            need=self.config.quorum(),
        )
        out: dict[str, tuple[Tag, Any]] = {}
        for pos, obj in enumerate(objs):
            pairs = [r[1][pos] for r in replies.values()]
            tag, val = max(pairs, key=lambda tv: tv[0])
            # If EVERY quorum reply already holds the max tag, a full quorum
            # stores it -> the read's propagation phase may be skipped soundly
            # (any later quorum intersects this one). Classic fast-read rule.
            if all(p[0] >= tag for p in pairs):
                self.client_state[("abd_safe", obj, self.config.cfg_id)] = tag
            local_tag, local_val = local[obj]
            if tag <= local_tag:
                out[obj] = (local_tag, local_val)  # nothing newer anywhere
            else:
                # tag > local_tag: that server shipped the value
                self._set_local(obj, tag, val)
                out[obj] = (tag, val)
        return out

    def put_data_batch(self, items: Sequence[tuple[str, Tag, Any]]) -> Generator:
        todo = []
        for obj, tag, value in items:
            safe = self.client_state.get(("abd_safe", obj, self.config.cfg_id), None)
            if safe is not None and tag <= safe:
                continue  # already quorum-stored; skip the write-back round
            todo.append((obj, tag, value))
        if todo:
            yield RPC(
                dests=self.config.servers,
                msg=("abd-put-batch", tuple(todo), self.cfg_idx),
                need=self.config.quorum(),
            )
        for obj, tag, value in todo:
            local_tag, _ = self._local(obj)
            if tag >= local_tag:
                self._set_local(obj, tag, value)
            safe = self.client_state.get(("abd_safe", obj, self.config.cfg_id), None)
            if safe is None or tag > safe:
                self.client_state[("abd_safe", obj, self.config.cfg_id)] = tag
        return None
