"""ABD-DAP [6], [22] with the CoBFS [4] conditional-transfer optimization.

get-data: read (tag, value) from a majority, return the max. Clients send
their last-known tag; a server whose stored tag is not newer replies with
``(tag, None)`` (tag-only) — "avoids unnecessary object transmissions
between the clients and the servers" ([4], adopted by the paper's §VI as the
inspiration for EC-DAPopt). The client serves repeated reads of unchanged
blocks from its local copy, which is what makes CoABDF/CoARESABDF reads
O(changed blocks) instead of O(file).

put-data: write (tag, value) to a majority (servers keep the max).
"""
from __future__ import annotations

from typing import Any, Generator

from repro.core.dap.base import DapClient
from repro.core.tags import TAG0, Tag
from repro.net.sim import RPC


class AbdDap(DapClient):
    kind = "abd"

    # client-local (tag, value) cache per (obj, config) — same state the
    # EC-DAPopt keeps (Alg 4's c.tag/c.val)
    def _local(self, obj: str) -> tuple[Tag, Any]:
        return self.client_state.setdefault(
            ("abd", obj, self.config.cfg_id), (TAG0, None)
        )

    def _set_local(self, obj: str, tag: Tag, val: Any) -> None:
        self.client_state[("abd", obj, self.config.cfg_id)] = (tag, val)

    def get_tag(self, obj: str) -> Generator:
        replies = yield RPC(
            dests=self.config.servers,
            msg=("abd-get-tag", obj, self.cfg_idx),
            need=self.config.quorum(),
        )
        return max((r[1] for r in replies.values()), default=TAG0)

    def get_data(self, obj: str) -> Generator:
        local_tag, local_val = self._local(obj)
        replies = yield RPC(
            dests=self.config.servers,
            msg=("abd-get", obj, self.cfg_idx, local_tag),
            need=self.config.quorum(),
        )
        tag, val = max(((r[1], r[2]) for r in replies.values()), key=lambda tv: tv[0])
        # If EVERY quorum reply already holds the max tag, a full quorum
        # stores it -> the read's propagation phase may be skipped soundly
        # (any later quorum intersects this one). Classic fast-read rule.
        if all(r[1] >= tag for r in replies.values()):
            self.client_state[("abd_safe", obj, self.config.cfg_id)] = tag
        if tag <= local_tag:
            return local_tag, local_val        # nothing newer anywhere
        # tag > local_tag: that server shipped the value
        self._set_local(obj, tag, val)
        return tag, val

    def put_data(self, obj: str, tag: Tag, value: Any) -> Generator:
        safe = self.client_state.get(("abd_safe", obj, self.config.cfg_id), None)
        if safe is not None and tag <= safe:
            return None  # already quorum-stored; skip the write-back round
        yield RPC(
            dests=self.config.servers,
            msg=("abd-put", obj, self.cfg_idx, tag, value),
            need=self.config.quorum(),
        )
        local_tag, _ = self._local(obj)
        if tag >= local_tag:
            self._set_local(obj, tag, value)
        if safe is None or tag > safe:
            self.client_state[("abd_safe", obj, self.config.cfg_id)] = tag
        return None
