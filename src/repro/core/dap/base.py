"""Data Access Primitives (§III): get-tag / get-data / put-data.

A DAP instance is bound to (network, client id, configuration). All
primitives are generators driven by the sim runner. Implementations must
satisfy Property 1 (C1/C2) — empirically validated by the history checkers in
``tests/checkers.py`` and the hypothesis suites.

Multi-object extension (ISSUE 2): ``get_data_batch`` / ``put_data_batch``
carry N objects in ONE quorum fan-out. The List protocol is agnostic to how
many objects ride in a round (Konwar et al.'s storage-optimized EC-DAP), so
each object's result is exactly what its single-object call would return —
batching changes framing and round count, never per-object semantics. The
single-object primitives are thin wrappers over the batch forms.
"""
from __future__ import annotations

from typing import Any, Generator, Iterable, Sequence

from repro.core.tags import Config, Tag


class DapClient:
    kind = "?"

    def __init__(self, net, client_id: str, config: Config, cfg_idx: int, client_state):
        self.net = net
        self.client_id = client_id
        self.config = config
        self.cfg_idx = cfg_idx
        # shared mutable per-(client) state — EC-DAPopt keeps (c.tag, c.val)
        # per (object, configuration) here (paper Alg 4 state variables).
        self.client_state = client_state

    # generators:
    def get_tag(self, obj: str) -> Generator:  # pragma: no cover
        raise NotImplementedError

    def get_data(self, obj: str) -> Generator:
        """Single-object form — one round of the batch engine."""
        res = yield from self.get_data_batch((obj,))
        return res[obj]

    def put_data(self, obj: str, tag: Tag, value: Any) -> Generator:
        yield from self.put_data_batch(((obj, tag, value),))
        return None

    # batch generators (the primitives subclasses actually implement):
    def get_data_batch(self, objs: Iterable[str]) -> Generator:
        """Fetch ``{obj: (tag, value)}`` for every object in one fan-out."""
        raise NotImplementedError

    def put_data_batch(self, items: Sequence[tuple[str, Tag, Any]]) -> Generator:
        """Store every ``(obj, tag, value)`` in one fan-out."""
        raise NotImplementedError


def make_dap(net, client_id: str, config: Config, cfg_idx: int, client_state) -> DapClient:
    from repro.core.dap.abd import AbdDap
    from repro.core.dap.ec import EcDap

    if config.dap == "abd":
        return AbdDap(net, client_id, config, cfg_idx, client_state)
    if config.dap in ("ec", "ec_opt"):
        return EcDap(
            net, client_id, config, cfg_idx, client_state,
            optimized=(config.dap == "ec_opt"),
        )
    raise ValueError(f"unknown DAP {config.dap!r}")
