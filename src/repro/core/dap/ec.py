"""EC-DAP [10] and EC-DAPopt (paper §VI, Algorithms 4 & 5).

[n, k]-MDS Reed-Solomon over the configuration's servers: put-data encodes
the value into n coded fragments (one per server); get-data collects Lists
from ⌈(n+k)/2⌉ servers and returns the maximum tag that is (i) present in at
least k Lists and (ii) decodable from >= k coded elements.

EC-DAPopt changes (blue text in Alg 4/5):
  * queries carry the client's local tag ``c.tag``; servers reply only with
    pairs newer than it ((tag, ⊥) for the equal tag) — Alg 5:6-9;
  * the client skips decoding when ``c.tag == t_max_dec`` (it already holds
    the value) — Alg 4:10;
  * put-data is a no-op when the incoming tag is not newer than ``c.tag``
    (the servers are already up to date) — Alg 4:20;
  * put-data updates ``(c.tag, c.val)`` on completion — Alg 4:23-24.

Liveness (Thm 18) holds for <= (n-k)/2 crashes and <= δ concurrent put-data;
a get-data round that races more writers than δ re-queries (bounded retries).
"""
from __future__ import annotations

from typing import Any, Generator

from repro.core.dap.base import DapClient
from repro.core.tags import TAG0, Tag
from repro.erasure.rs import RSCode
from repro.net.sim import RPC, Sleep

_MAX_RETRIES = 200


class EcDap(DapClient):
    def __init__(self, net, client_id, config, cfg_idx, client_state, optimized: bool):
        super().__init__(net, client_id, config, cfg_idx, client_state)
        self.optimized = optimized
        self.kind = "ec_opt" if optimized else "ec"
        self.code = RSCode(n=config.n, k=config.k)

    # -- client-local (c.tag, c.val) state (Alg 4) ---------------------------
    def _local(self, obj: str) -> tuple[Tag, Any]:
        return self.client_state.setdefault(("ec", obj, self.config.cfg_id), (TAG0, None))

    def _set_local(self, obj: str, tag: Tag, val: Any) -> None:
        self.client_state[("ec", obj, self.config.cfg_id)] = (tag, val)

    # -- primitives -----------------------------------------------------------
    def get_tag(self, obj: str) -> Generator:
        replies = yield RPC(
            dests=self.config.servers,
            msg=("ec-query", obj, self.cfg_idx, None),
            need=self.config.quorum(),
        )
        counts: dict[Tag, int] = {}
        for _, lst in replies.values():
            for t, _e in lst:
                counts[t] = counts.get(t, 0) + 1
        good = [t for t, c in counts.items() if c >= self.config.k]
        return max(good, default=TAG0)

    def get_data(self, obj: str) -> Generator:
        k = self.config.k
        local_tag, local_val = self._local(obj)
        query_tag = local_tag if self.optimized else None
        for attempt in range(_MAX_RETRIES):
            replies = yield RPC(
                dests=self.config.servers,
                msg=("ec-query", obj, self.cfg_idx, query_tag),
                need=self.config.quorum(),
            )
            # tag -> #Lists containing it; tag -> {frag_idx: element}
            seen: dict[Tag, int] = {}
            frags: dict[Tag, dict[int, Any]] = {}
            for sid, (_kindtok, lst) in replies.items():
                fidx = self.config.frag_index(sid)
                for t, e in lst:
                    seen[t] = seen.get(t, 0) + 1
                    if e is not None:
                        frags.setdefault(t, {})[fidx] = e
            if self.optimized:
                # the client's own (c.tag, c.val) counts as decodable
                seen[local_tag] = max(seen.get(local_tag, 0), k)
                frags.setdefault(local_tag, {})
            t_max = max(seen, default=TAG0)
            dec = {
                t
                for t in seen
                if len(frags.get(t, {})) >= k or (self.optimized and t == local_tag)
                or t == TAG0
            }
            if dec:
                t_dec = max(dec)
                if t_dec == t_max:
                    if self.optimized and t_dec == local_tag:
                        return local_tag, local_val  # Alg 4:10 — no decode
                    if t_dec == TAG0:
                        return TAG0, None
                    value = self._decode(t_dec, frags[t_dec])
                    yield Sleep(self.net.latency.dec_per_byte * len(value))
                    return t_dec, value
            # liveness retry: a concurrent writer's tag was visible but not
            # yet decodable; re-query (paper: the read "does not complete" —
            # operationally we re-poll).
            yield Sleep(float(self.net.rng.uniform(0.5e-3, 2e-3)))
        raise RuntimeError(f"ec get-data exceeded {_MAX_RETRIES} retries on {obj}")

    # -- batched encode (ISSUE 1): FM pre-registers a multi-block update's
    # values via client.precode(); the FIRST block write then encodes the
    # whole batch through one fused GF(256) matmul (RSCode.encode_bytes_batch,
    # bit-identical to per-value encoding) and later writes hit the cache.
    def _encode_value(self, value_b: bytes) -> tuple[list[bytes], int]:
        ckey = ("_ecache", self.config.n, self.config.k)
        cache = self.client_state.get(ckey)
        if cache is not None and value_b in cache:
            return cache[value_b]
        pending = self.client_state.get("_batch_values")
        if pending and value_b in pending and len(pending) > 1:
            batch = sorted(pending)  # deterministic encode order
            coded = dict(zip(batch, self.code.encode_bytes_batch(batch)))
            if cache is None:
                cache = coded
            else:
                cache.update(coded)
            self.client_state[ckey] = cache
            return cache[value_b]
        return self.code.encode_bytes(value_b)

    def put_data(self, obj: str, tag: Tag, value: Any) -> Generator:
        local_tag, _ = self._local(obj)
        if self.optimized and tag <= local_tag:
            return None  # Alg 4:20 — servers already up to date
        value_b = b"" if value is None else value
        frag_rows, orig = self._encode_value(value_b)
        per_dest = {
            sid: (
                "ec-put",
                obj,
                self.cfg_idx,
                tag,
                (frag_rows[self.config.frag_index(sid)], orig),
                self.config.delta,
            )
            for sid in self.config.servers
        }
        yield RPC(
            dests=self.config.servers,
            msg=None,
            per_dest=per_dest,
            need=self.config.quorum(),
            pre_delay=self.net.latency.enc_per_byte * len(value_b),
        )
        if self.optimized:
            self._set_local(obj, tag, value)  # Alg 4:23-24
        return None

    # -- decode ----------------------------------------------------------------
    def _decode(self, tag: Tag, frag_map: dict[int, Any]) -> bytes:
        idxs = sorted(frag_map.keys())[: self.config.k]
        orig_len = frag_map[idxs[0]][1]
        return self.code.decode_bytes(
            {i: frag_map[i][0] for i in idxs}, orig_len
        )
