"""EC-DAP [10] and EC-DAPopt (paper §VI, Algorithms 4 & 5).

[n, k]-MDS Reed-Solomon over the configuration's servers: put-data encodes
the value into n coded fragments (one per server); get-data collects Lists
from ⌈(n+k)/2⌉ servers and returns the maximum tag that is (i) present in at
least k Lists and (ii) decodable from >= k coded elements.

EC-DAPopt changes (blue text in Alg 4/5):
  * queries carry the client's local tag ``c.tag``; servers reply only with
    pairs newer than it ((tag, ⊥) for the equal tag) — Alg 5:6-9;
  * the client skips decoding when ``c.tag == t_max_dec`` (it already holds
    the value) — Alg 4:10;
  * put-data is a no-op when the incoming tag is not newer than ``c.tag``
    (the servers are already up to date) — Alg 4:20;
  * put-data updates ``(c.tag, c.val)`` on completion — Alg 4:23-24.

Multi-object batching (ISSUE 2): the primitives are implemented in their
batch form — one ``ec-query-batch`` fan-out carries N objects' Lists and all
objects that become decodable in a round are decoded by ONE fused GF(256)
matmul (``RSCode.decode_bytes_batch``) instead of N kernel launches; one
``ec-put-batch`` ships each server its coded fragment of every object, with
the whole batch encoded by one ``encode_bytes_batch`` matmul. Single-object
``get_data``/``put_data`` ride a one-element batch (see ``dap/base.py``).

Liveness (Thm 18) holds for <= (n-k)/2 crashes and <= δ concurrent put-data;
a get-data round that races more writers than δ re-queries (bounded retries,
per object — objects already resolved are not re-sent).
"""
from __future__ import annotations

from typing import Any, Generator, Iterable, Sequence

from repro.core.dap.base import DapClient
from repro.core.tags import TAG0, Tag
from repro.erasure.rs import RSCode, element_crc_ok
from repro.net.sim import RPC, Sleep

_MAX_RETRIES = 200


class EcDap(DapClient):
    def __init__(self, net, client_id, config, cfg_idx, client_state, optimized: bool):
        super().__init__(net, client_id, config, cfg_idx, client_state)
        self.optimized = optimized
        self.kind = "ec_opt" if optimized else "ec"
        # coding_backend rides ambiently on the network handle (set from
        # DSSParams.coding_backend by DSS.__init__) so every DAP a client or
        # the recon engine builds — here, coares.py, repair — codes on the
        # same backend without threading a parameter through make_dap.
        self.code = RSCode(
            n=config.n, k=config.k,
            backend=getattr(net, "coding_backend", "numpy"),
        )

    # -- client-local (c.tag, c.val) state (Alg 4) ---------------------------
    def _local(self, obj: str) -> tuple[Tag, Any]:
        return self.client_state.setdefault(("ec", obj, self.config.cfg_id), (TAG0, None))

    def _set_local(self, obj: str, tag: Tag, val: Any) -> None:
        self.client_state[("ec", obj, self.config.cfg_id)] = (tag, val)

    # -- primitives -----------------------------------------------------------
    def get_tag(self, obj: str) -> Generator:
        # The optimized client's local (c.tag, c.val) is itself a witnessed,
        # decodable version (Alg 4 state) — without counting it, get_tag could
        # return a tag OLDER than the value the client already holds (e.g.
        # after δ-trimming), inconsistent with get_data's Alg 4:10 shortcut.
        local_tag, _ = self._local(obj)
        query_tag = local_tag if self.optimized else None
        replies = yield RPC(
            dests=self.config.servers,
            msg=("ec-query", obj, self.cfg_idx, query_tag),
            need=self.config.quorum(),
        )
        counts: dict[Tag, int] = {}
        for _, lst in replies.values():
            for t, _e in lst:
                counts[t] = counts.get(t, 0) + 1
        if self.optimized:
            counts[local_tag] = max(counts.get(local_tag, 0), self.config.k)
        good = [t for t, c in counts.items() if c >= self.config.k]
        return max(good, default=TAG0)

    def get_data_batch(self, objs: Iterable[str]) -> Generator:
        objs = list(objs)
        out: dict[str, tuple[Tag, Any]] = {}
        if not objs:
            return out
        k = self.config.k
        local = {o: self._local(o) for o in objs}
        pending = objs
        for _attempt in range(_MAX_RETRIES):
            items = tuple(
                (o, local[o][0] if self.optimized else None) for o in pending
            )
            replies = yield RPC(
                dests=self.config.servers,
                msg=("ec-query-batch", items, self.cfg_idx),
                need=self.config.quorum(),
            )
            decode_jobs: list[tuple[str, Tag, dict[int, Any]]] = []
            unresolved: list[str] = []
            for pos, obj in enumerate(pending):
                # tag -> #Lists containing it; tag -> {frag_idx: element}
                seen: dict[Tag, int] = {}
                frags: dict[Tag, dict[int, Any]] = {}
                for sid, (_kindtok, lists) in replies.items():
                    fidx = self.config.frag_index(sid)
                    for t, e in lists[pos]:
                        seen[t] = seen.get(t, 0) + 1
                        # verify the element's stored CRC in the same pass
                        # that gathers it: a bit-rotted fragment is treated
                        # as absent (the tag stays visible), so the decode
                        # below never sees corrupt rows and the repair loop
                        # later restores the holder.
                        if e is not None and element_crc_ok(e):
                            frags.setdefault(t, {})[fidx] = e
                local_tag, local_val = local[obj]
                if self.optimized:
                    # the client's own (c.tag, c.val) counts as decodable
                    seen[local_tag] = max(seen.get(local_tag, 0), k)
                    frags.setdefault(local_tag, {})
                t_max = max(seen, default=TAG0)
                # EC fast-read rule (mirror of the ABD one): if EVERY reply
                # in this quorum lists t_max with a coded element, a full
                # quorum durably stores it — any later quorum intersects this
                # one in >= k element-holders, so the read's put-back phase
                # may be skipped soundly (see ``put_data_batch``).
                if t_max > TAG0 and len(frags.get(t_max, {})) >= len(replies):
                    safe_key = ("ec_safe", obj, self.config.cfg_id)
                    if t_max > self.client_state.get(safe_key, TAG0):
                        self.client_state[safe_key] = t_max
                dec = {
                    t
                    for t in seen
                    if len(frags.get(t, {})) >= k
                    or (self.optimized and t == local_tag)
                    or t == TAG0
                }
                resolved = False
                if dec:
                    t_dec = max(dec)
                    if t_dec == t_max:
                        resolved = True
                        if self.optimized and t_dec == local_tag:
                            out[obj] = (local_tag, local_val)  # Alg 4:10 — no decode
                        elif t_dec == TAG0:
                            out[obj] = (TAG0, None)
                        else:
                            decode_jobs.append((obj, t_dec, frags[t_dec]))
                if not resolved:
                    unresolved.append(obj)
            if decode_jobs:
                # ONE fused GF(256) matmul for every object that resolved this
                # round (grouped by surviving-fragment index set inside).
                # hand RSCode every surviving fragment — it prefers the
                # all-systematic subset (no matmul) and groups the rest by
                # index set over one cached inverted generator each.
                values = self.code.decode_bytes_batch(
                    [
                        ({i: e[0] for i, e in fm.items()}, fm[min(fm)][1])
                        for _obj, _t, fm in decode_jobs
                    ]
                )
                for (obj, t_dec, _fm), value in zip(decode_jobs, values):
                    out[obj] = (t_dec, value)
                    # Alg 4:23-24 analogue for the skipped put-back: adopt the
                    # decoded pair as (c.tag, c.val) ONLY when the fast-read
                    # rule proved a full quorum stores it — the same durability
                    # a completed put-data would have established.
                    if (
                        self.optimized
                        and t_dec > local[obj][0]
                        and self.client_state.get(
                            ("ec_safe", obj, self.config.cfg_id), TAG0
                        ) >= t_dec
                    ):
                        self._set_local(obj, t_dec, value)
                yield Sleep(
                    self.net.latency.dec_per_byte * sum(len(v) for v in values)
                )
            if not unresolved:
                return out
            # liveness retry: a concurrent writer's tag was visible but not
            # yet decodable; re-query (paper: the read "does not complete" —
            # operationally we re-poll) for the unresolved objects only.
            pending = unresolved
            yield Sleep(float(self.net.rng.uniform(0.5e-3, 2e-3)))
        raise RuntimeError(
            f"ec get-data exceeded {_MAX_RETRIES} retries on {pending}"
        )

    # -- batched encode: a put batch encodes every uncached value with one
    # fused GF(256) matmul (RSCode.encode_bytes_batch, bit-identical to
    # per-value encoding). The FM can also pre-register an update's values
    # via client.precode() (ISSUE 1) so a SEQUENTIAL multi-block write —
    # one put_data at a time, non-indexed walk — still encodes the whole
    # update on its first block write and serves the rest from the cache.
    def _encode_values(
        self, values: Sequence[bytes]
    ) -> list[tuple[list[bytes], int, list[int]]]:
        ckey = ("_ecache", self.config.n, self.config.k)
        cache = self.client_state.get(ckey) or {}
        pending = self.client_state.get("_batch_values") or ()
        missing = sorted((set(values) | set(pending)) - cache.keys())
        # with_crc: per-fragment CRC-32s come out of the same traversal that
        # slices the coded rows into fragment bytes — shipped inside each
        # element so readers/repair can detect bit-rot without a second pass.
        if len(missing) == 1:
            fresh = {missing[0]: self.code.encode_bytes(missing[0], with_crc=True)}
        elif missing:
            fresh = dict(
                zip(missing, self.code.encode_bytes_batch(missing, with_crc=True))
            )
        else:
            fresh = {}
        if fresh and pending:
            # Persist ONLY the pre-registered update's values (the precode
            # contract: evicted by the next precode call). Ad-hoc values stay
            # local to this call, so long-lived clients that never precode
            # don't accumulate an unbounded plaintext->fragments cache.
            keep = {v: fresh[v] for v in pending if v in fresh}
            if keep:
                self.client_state[ckey] = {**cache, **keep}
        lookup = {**cache, **fresh}
        return [lookup[v] for v in values]

    def put_data_batch(self, items: Sequence[tuple[str, Tag, Any]]) -> Generator:
        todo = []
        for obj, tag, value in items:
            local_tag, _ = self._local(obj)
            if self.optimized and tag <= local_tag:
                continue  # Alg 4:20 — servers already up to date
            safe = self.client_state.get(("ec_safe", obj, self.config.cfg_id), TAG0)
            if tag <= safe:
                continue  # a full quorum already holds this tag's elements
            todo.append((obj, tag, value))
        if not todo:
            return None
        encoded = self._encode_values(
            [b"" if v is None else v for _o, _t, v in todo]
        )
        per_dest = {
            sid: (
                "ec-put-batch",
                tuple(
                    (
                        obj,
                        tag,
                        (
                            frags[self.config.frag_index(sid)],
                            orig,
                            crcs[self.config.frag_index(sid)],
                        ),
                    )
                    for (obj, tag, _v), (frags, orig, crcs) in zip(todo, encoded)
                ),
                self.cfg_idx,
                self.config.delta,
            )
            for sid in self.config.servers
        }
        yield RPC(
            dests=self.config.servers,
            msg=None,
            per_dest=per_dest,
            need=self.config.quorum(),
            pre_delay=self.net.latency.enc_per_byte
            * sum(0 if v is None else len(v) for _o, _t, v in todo),
        )
        for obj, tag, _value in todo:
            # the put waited for a quorum of acks -> a full quorum now holds
            # this tag's coded elements (same rule as the fast read above)
            safe_key = ("ec_safe", obj, self.config.cfg_id)
            if tag > self.client_state.get(safe_key, TAG0):
                self.client_state[safe_key] = tag
        if self.optimized:
            for obj, tag, value in todo:
                local_tag, _ = self._local(obj)
                if tag >= local_tag:
                    self._set_local(obj, tag, value)  # Alg 4:23-24
        return None
