"""Fragmentation Module (§V, CoBFS [4]) — CoARESF = FM ∘ CoARES.

A file f is a linked list of coverable blocks: genesis block b0 (file
metadata + head pointer) followed by data blocks. The Block Identification
(BI) pipeline (paper Fig. 2):

  1. *Block Division* — content-defined chunking (gear-hash CDC standing in
     for rabin fingerprints; ``repro.kernels.cdc_gearhash``).
  2. *Block Matching* — Ratcliff/Obershelp sequence matching on block hashes
     (``difflib.SequenceMatcher`` — literally the paper's citation [9]) giving
     equality / modified / inserted / deleted statuses.
  3. *Block Updates* — coverable writes on only the affected blocks; inserted
     chains are written **back-to-front** so the list is always connected
     (Lemma 13); deletes write an empty value (blocks are never unlinked).

``fm_reconfig`` (Alg 3) walks the list and issues dsmm-reconfig (Alg 2) on
every block, genesis included (§V text).

Genesis metadata (unified schema, ISSUE 2): BOTH modes store the pickled
ordered block-id index in the genesis block, so indexed readers can batch
block I/O over files written by either mode. ``parse_genesis_meta`` stays
tolerant of the legacy non-indexed schema (a raw 4-byte block count), for
which readers fall back to the linked-list walk.

Batched block I/O (ISSUE 2): with an index present, ``fm_read``/``fm_update``
/``fm_reconfig`` ride the DSM's multi-object batch operations — ONE quorum
fan-out carries every block (O(1) quorum rounds instead of O(#blocks)), and
the EC DAP decodes/encodes the whole file with one fused GF(256) matmul.
``batched=False`` keeps the per-object path (a Join of independent quorum
ops) for ablation benchmarks.
"""
from __future__ import annotations

import hashlib
import pickle
from difflib import SequenceMatcher
from typing import Generator

from repro.core.tags import Config, OpRecord
from repro.kernels.cdc_gearhash.ops import split_chunks
from repro.net.sim import Join, Sleep

SEP = "\x01"


def genesis_id(fid: str) -> str:
    return f"{fid}{SEP}g"


def encode_block_value(ptr: str | None, data: bytes) -> bytes:
    pb = (ptr or "").encode()
    return len(pb).to_bytes(2, "big") + pb + data


def decode_block_value(raw: bytes | None) -> tuple[str | None, bytes]:
    if raw in (None, b""):
        return None, b""
    plen = int.from_bytes(raw[:2], "big")
    ptr = raw[2 : 2 + plen].decode() or None
    return ptr, raw[2 + plen :]


def encode_genesis_meta(index: list[str]) -> bytes:
    """Unified genesis metadata: the full ordered block-id index."""
    return pickle.dumps(list(index), protocol=2)


def parse_genesis_meta(meta: bytes) -> list[str] | None:
    """Return the block index, or None for the legacy schema (the non-indexed
    mode used to store a raw 4-byte block count; pickle protocol-2 streams
    start with 0x80, a count < 2^24 cannot)."""
    if not meta or meta[:1] != b"\x80":
        return None
    try:
        index = pickle.loads(meta)
    except Exception:
        return None
    if isinstance(index, (list, tuple)) and all(isinstance(b, str) for b in index):
        return list(index)
    return None


def _h(data: bytes) -> bytes:
    return hashlib.sha1(data).digest()


class FragmentationModule:
    """Binds a DSM client (CoARES or static) to the fragmented-object logic.

    ``dsm`` must expose generator methods ``cvr_read(obj)``,
    ``cvr_write(obj, value)``, their multi-object batch forms
    ``cvr_read_batch``/``cvr_write_batch``, ``recon``/``recon_batch`` and a
    ``version`` dict (coverability state, updated from reads per CoBFS).
    """

    def __init__(
        self,
        net,
        dsm,
        *,
        min_block: int = 512,
        avg_block: int = 1024,
        max_block: int = 4096,
        history: list | None = None,
        indexed: bool = False,
        batched: bool = True,
    ):
        self.net = net
        self.dsm = dsm
        self.min_block = min_block
        self.avg_block = avg_block
        self.max_block = max_block
        self.history = history if history is not None else []
        self.clseq: dict[str, int] = {}
        # ``indexed`` (beyond-paper, EXPERIMENTS.md §Perf storage iteration):
        # the genesis block stores the full ordered block-id index, so block
        # reads/writes issue in PARALLEL instead of walking the linked
        # list — O(1) quorum rounds instead of O(#blocks). Connectivity
        # reduces to the single coverable genesis flip. The paper itself
        # flags sequential block requests as its main read overhead (§VII-D).
        self.indexed = indexed
        # ``batched``: route indexed block I/O through the DSM's multi-object
        # batch ops (one RPC fan-out, fused EC coding). False = per-object
        # concurrent ops (Join), kept for the before/after ablation.
        self.batched = batched

    def _precode(self, writes: list[tuple[str, bytes]]) -> None:
        """Hand the update's block values to the DSM so EC DAPs batch-encode
        them in one fused GF(256) matmul (ISSUE 1; no-op for ABD). Only the
        SEQUENTIAL write paths need the hint — ``cvr_write_batch`` sees the
        whole batch and encodes it in one shot by itself."""
        precode = getattr(self.dsm, "precode", None)
        if precode is not None and writes:
            precode([raw for _bid, raw in writes])

    # ------------------------------------------------------------------ ids
    def _new_block_id(self, fid: str) -> str:
        seq = self.clseq.get(fid, 0) + 1
        self.clseq[fid] = seq
        return f"{fid}{SEP}{self.dsm.client_id}{SEP}{seq}"

    # ----------------------------------------------------------------- read
    def _read_block_op(self, bid: str):
        tag, raw = yield from self.dsm.cvr_read(bid)
        return bid, tag, raw

    def _read_blocks(self, bids: list[str]) -> Generator:
        """Read many blocks: ONE batched quorum round (default), or a Join of
        independent per-block quorum ops (``batched=False`` ablation)."""
        if self.batched:
            res = yield from self.dsm.cvr_read_batch(bids)
            out = []
            for bid in bids:
                tag, raw = res[bid]
                self.dsm.version[bid] = tag
                out.append((bid, raw))
            return out

        results = yield Join([self._read_block_op(b) for b in bids])
        out = []
        for bid, btag, braw in results:
            self.dsm.version[bid] = btag
            out.append((bid, braw))
        return out

    def _walk_chain(self, ptr: str | None) -> Generator:
        """The linked-list walk from a head pointer (non-indexed mode, or a
        legacy count-only genesis). Returns ``[(bid, ptr, data)]``."""
        blocks: list[tuple[str, str | None, bytes]] = []
        seen: set[str] = set()
        while ptr is not None and ptr not in seen:
            seen.add(ptr)
            tag, raw = yield from self.dsm.cvr_read(ptr)
            self.dsm.version[ptr] = tag
            nxt, data = decode_block_value(raw)
            blocks.append((ptr, nxt, data))
            ptr = nxt
        return blocks

    def _read_chain_ex(self, fid: str) -> Generator:
        """``(blocks, had_index)`` — one genesis read + ALL block reads in one
        batched round (indexed mode with an index present), else the walk.
        ``had_index`` tells update paths whether the genesis must be upgraded
        to the indexed schema even when the block list is unchanged."""
        g = genesis_id(fid)
        tag, raw = yield from self.dsm.cvr_read(g)
        self.dsm.version[g] = tag
        ptr, meta = decode_block_value(raw)
        index = parse_genesis_meta(meta)
        if self.indexed and index is not None:
            results = yield from self._read_blocks(index)
            blocks = []
            for bid, braw in results:
                nxt, data = decode_block_value(braw)
                blocks.append((bid, nxt, data))
            return blocks, True
        blocks = yield from self._walk_chain(ptr)
        return blocks, index is not None

    def _read_chain(self, fid: str) -> Generator:
        """Returns [(bid, ptr, data)]; see ``_read_chain_ex``."""
        blocks, _had_index = yield from self._read_chain_ex(fid)
        return blocks

    def _read_chain_batch(self, fids: list[str]) -> Generator:
        """Cross-FILE aggregation of ``_read_chain`` (ISSUE 3): ONE batched
        engine pass for every file's genesis block, then ONE batched pass for
        ALL data blocks of every indexed file — an F-file read costs the same
        quorum rounds as a one-file read. Files whose genesis carries no
        index (legacy schema) fall back to the per-file linked-list walk.
        Returns ``({fid: [(bid, ptr, data)]}, {fid: had_index})``."""
        gids = {fid: genesis_id(fid) for fid in fids}
        gres = yield from self.dsm.cvr_read_batch([gids[f] for f in fids])
        index_of: dict[str, list[str]] = {}
        heads: dict[str, str | None] = {}
        all_blocks: list[str] = []
        for fid in fids:
            tag, raw = gres[gids[fid]]
            self.dsm.version[gids[fid]] = tag
            ptr, meta = decode_block_value(raw)
            index = parse_genesis_meta(meta)
            if index is not None:
                index_of[fid] = index
                all_blocks.extend(index)
            else:
                heads[fid] = ptr
        chains: dict[str, list[tuple[str, str | None, bytes]]] = {}
        # one deduped block round for every indexed file. NB an indexed file
        # whose index is EMPTY (an empty-content write) must still land in
        # ``chains`` — gating the whole loop on ``all_blocks`` used to drop
        # such files from the result entirely (KeyError downstream) whenever
        # the merged batch carried no data blocks at all (ISSUE 4).
        all_blocks = list(dict.fromkeys(all_blocks))
        res = (yield from self.dsm.cvr_read_batch(all_blocks)) if all_blocks else {}
        for fid, index in index_of.items():
            blocks = []
            for bid in index:
                tag, raw = res[bid]
                self.dsm.version[bid] = tag
                nxt, data = decode_block_value(raw)
                blocks.append((bid, nxt, data))
            chains[fid] = blocks
        for fid, ptr in heads.items():
            chains[fid] = yield from self._walk_chain(ptr)
        return chains, {fid: fid in index_of for fid in fids}

    def fm_read(self, fid: str) -> Generator:
        t0 = self.net.now
        blocks = yield from self._read_chain(fid)
        content = b"".join(d for _, _, d in blocks)
        self.history.append(
            OpRecord(
                kind="fm-read", obj=fid, client=self.dsm.client_id,
                start=t0, end=self.net.now,
                extra={"n_blocks": len(blocks), "size": len(content)},
            )
        )
        return content, blocks

    def fm_read_batch(self, fids) -> Generator:
        """Read many FILES in one batched pass (ISSUE 3): with the indexed
        batched FM every file's blocks ride the same two engine passes
        (genesis round + block round), so the quorum-round count is flat in
        the number of files. Without index/batching this degrades gracefully
        to a ``Join`` of independent per-file reads (the ablation baseline).
        Returns ``{fid: (content, blocks)}``."""
        fids = list(dict.fromkeys(fids))
        if not fids:
            return {}
        if not (self.indexed and self.batched):
            results = yield Join([self.fm_read(f) for f in fids])
            return dict(zip(fids, results))
        t0 = self.net.now
        chains, _had_index = yield from self._read_chain_batch(fids)
        out: dict[str, tuple[bytes, list]] = {}
        for fid in fids:
            blocks = chains[fid]
            content = b"".join(d for _, _, d in blocks)
            self.history.append(
                OpRecord(
                    kind="fm-read", obj=fid, client=self.dsm.client_id,
                    start=t0, end=self.net.now,
                    extra={"n_blocks": len(blocks), "size": len(content),
                           "coalesced": len(fids)},
                )
            )
            out[fid] = (content, blocks)
        return out

    # --------------------------------------------------------------- update
    def _plan_blocks(
        self, fid: str, old_blocks: list, content: bytes
    ) -> tuple[list[tuple[str, bytes]], list[bytes]]:
        """Block Division (kernel CDC) + Matching (Ratcliff [9]) + new-block
        id assignment: the target block list ``[(bid, data)]`` for an update.
        Pure computation — shared by ``fm_update`` and ``fm_update_batch``;
        the caller charges the BI latency (``bi_per_byte``)."""
        live = [(bid, data) for bid, _, data in old_blocks if data != b""]
        chunks = split_chunks(
            content, min_size=self.min_block, avg_size=self.avg_block,
            max_size=self.max_block,
        )
        if chunks == [b""]:
            chunks = []
        old_hashes = [_h(d) for _, d in live]
        new_hashes = [_h(c) for c in chunks]
        ops = SequenceMatcher(None, old_hashes, new_hashes, autojunk=False).get_opcodes()
        # --- build the target block list -----------------------------------
        target: list[tuple[str | None, bytes]] = []  # (bid | None=new, data)
        for op, i1, i2, j1, j2 in ops:
            if op == "equal":
                target.extend((live[i][0], live[i][1]) for i in range(i1, i2))
            elif op == "delete":
                target.extend((live[i][0], b"") for i in range(i1, i2))
            elif op == "insert":
                target.extend((None, chunks[j]) for j in range(j1, j2))
            elif op == "replace":
                n_pair = min(i2 - i1, j2 - j1)
                target.extend((live[i1 + t][0], chunks[j1 + t]) for t in range(n_pair))
                target.extend((None, chunks[j]) for j in range(j1 + n_pair, j2))
                target.extend((live[i][0], b"") for i in range(i1 + n_pair, i2))
        # keep tombstoned (already-empty) blocks in the chain where they were:
        # they are invisible to matching but must stay linked. We splice them
        # back right after their old predecessor.
        if any(d == b"" for _, _, d in old_blocks):
            merged: list[tuple[str | None, bytes]] = []
            live_ids = {bid for bid, _ in live}
            tomb_after: dict[str | None, list[str]] = {}
            prev_live: str | None = None
            for bid, _, d in old_blocks:
                if d == b"":
                    tomb_after.setdefault(prev_live, []).append(bid)
                else:
                    prev_live = bid
            merged.extend((b, b"") for b in tomb_after.get(None, []))
            for bid, data in target:
                merged.append((bid, data))
                if bid in live_ids:
                    merged.extend((b, b"") for b in tomb_after.get(bid, []))
            target = merged
        # --- assign ids to new blocks ---------------------------------------
        final: list[tuple[str, bytes]] = []
        for bid, data in target:
            final.append((bid if bid is not None else self._new_block_id(fid), data))
        return final, chunks

    def fm_update(self, fid: str, content: bytes) -> Generator:
        """BI + block updates. Returns stats dict (written/collided/...).

        The indexed+batched path IS ``fm_update_batch`` with one file —
        one code path for the changed-block diff, flag accounting and the
        legacy-genesis upgrade rule, single-file or coalesced."""
        if self.indexed and self.batched:
            res = yield from self.fm_update_batch({fid: content})
            return res[fid]
        t0 = self.net.now
        old_blocks, had_index = yield from self._read_chain_ex(fid)
        yield Sleep(self.net.latency.bi_per_byte * (len(content) + 1))
        final, chunks = self._plan_blocks(fid, old_blocks, content)
        # --- diff against old state; write the changed blocks ---------------
        old_state = {bid: (nxt, data) for bid, nxt, data in old_blocks}
        stats = {"written": 0, "collided": 0, "created": 0, "blocks": len(final),
                 "chunks": len(chunks)}
        g = genesis_id(fid)
        new_index = [bid for bid, _ in final]
        old_index = [bid for bid, _n, _d in old_blocks]
        if self.indexed:
            # per-block Join ablation (``batched=False``): same diff and
            # genesis-upgrade rules as fm_update_batch, concurrent quorum
            # ops instead of one batched fan-out
            old_data = {bid: data for bid, _n, data in old_blocks}
            writes = [
                (bid, encode_block_value(None, data))
                for bid, data in final
                if bid not in old_data or old_data[bid] != data
            ]
            self._precode(writes)

            def write_op(bid, raw):
                res = yield from self.dsm.cvr_write(bid, raw)
                return bid, res

            items = yield Join([write_op(b, r) for b, r in writes])
            for bid, ((tag, _v), flag) in items:
                self.dsm.version[bid] = tag
                if flag == "chg":
                    stats["written"] += 1
                    stats["created"] += int(bid not in old_state)
                else:
                    stats["collided"] += 1
            # A legacy count-only genesis MUST be upgraded to the indexed
            # schema even when the block list is unchanged: the data blocks
            # above were written with ptr=None, so without an index the
            # linked-list walk would be severed (silent truncation).
            if new_index != old_index or not had_index:
                head = final[0][0] if final else None
                (tag, _v), flag = yield from self.dsm.cvr_write(
                    g, encode_block_value(head, encode_genesis_meta(new_index))
                )
                self.dsm.version[g] = tag
                if flag == "chg":
                    stats["written"] += 1
                else:
                    stats["collided"] += 1
        else:
            writes: list[tuple[str, bytes]] = []
            for pos in range(len(final)):
                bid, data = final[pos]
                nxt = final[pos + 1][0] if pos + 1 < len(final) else None
                if bid not in old_state or old_state[bid] != (nxt, data):
                    writes.append((bid, encode_block_value(nxt, data)))
            self._precode(writes)
            # write back-to-front so the list is always connected (Lemma 13)
            for bid, raw in reversed(writes):
                is_new = bid not in old_state
                (tag, _v), flag = yield from self.dsm.cvr_write(bid, raw)
                self.dsm.version[bid] = tag
                if flag == "chg":
                    stats["written"] += 1
                    stats["created"] += int(is_new)
                else:
                    stats["collided"] += 1
            # --- genesis: repoint head / refresh the index if changed --------
            if new_index != old_index:
                new_head = final[0][0] if final else None
                (tag, _v), flag = yield from self.dsm.cvr_write(
                    g, encode_block_value(new_head, encode_genesis_meta(new_index))
                )
                self.dsm.version[g] = tag
                if flag == "chg":
                    stats["written"] += 1
                else:
                    stats["collided"] += 1
        stats["success"] = stats["collided"] == 0
        self.history.append(
            OpRecord(
                kind="fm-update", obj=fid, client=self.dsm.client_id,
                start=t0, end=self.net.now, flag="chg" if stats["success"] else "unchg",
                extra=stats,
            )
        )
        return stats

    def fm_update_batch(self, updates: dict) -> Generator:
        """Update many FILES in one batched pass (ISSUE 3): read every file's
        chain (two batched engine passes via ``_read_chain_batch``), run BI
        per file, then write ALL changed data blocks of ALL files in ONE
        batched coverable write — one fused GF(256) encode for the whole
        fan-out — followed by one batched write of every changed genesis
        block (data before genesis keeps Lemma 13's connectivity argument:
        a head flip is the last thing a reader can observe). Files fall back
        to a ``Join`` of per-file updates when the indexed batched path is
        off. Returns ``{fid: stats}``."""
        fids = list(dict.fromkeys(updates))
        if not fids:
            return {}
        if not (self.indexed and self.batched):
            results = yield Join([self.fm_update(f, updates[f]) for f in fids])
            return dict(zip(fids, results))
        t0 = self.net.now
        chains, had_index = yield from self._read_chain_batch(fids)
        yield Sleep(
            self.net.latency.bi_per_byte
            * (sum(len(updates[f]) for f in fids) + len(fids))
        )
        all_writes: dict[str, bytes] = {}
        writes_of: dict[str, list[str]] = {}
        genesis_writes: dict[str, bytes] = {}
        g_of: dict[str, str] = {}
        stats_of: dict[str, dict] = {}
        old_state_of: dict[str, dict] = {}
        for fid in fids:
            old_blocks = chains[fid]
            final, chunks = self._plan_blocks(fid, old_blocks, updates[fid])
            old_state_of[fid] = {bid: (nxt, data) for bid, nxt, data in old_blocks}
            stats_of[fid] = {"written": 0, "collided": 0, "created": 0,
                             "blocks": len(final), "chunks": len(chunks)}
            old_data = {bid: data for bid, _n, data in old_blocks}
            writes_of[fid] = []
            for bid, data in final:
                if bid not in old_data or old_data[bid] != data:
                    all_writes[bid] = encode_block_value(None, data)
                    writes_of[fid].append(bid)
            new_index = [bid for bid, _ in final]
            # rewrite the genesis when the index changed — or when it held
            # the legacy count-only schema (the blocks above were written
            # with ptr=None; without an index the walk would sever).
            if new_index != [bid for bid, _n, _d in old_blocks] or not had_index[fid]:
                head = final[0][0] if final else None
                g = genesis_id(fid)
                g_of[fid] = g
                genesis_writes[g] = encode_block_value(
                    head, encode_genesis_meta(new_index)
                )
        results = yield from self.dsm.cvr_write_batch(all_writes)
        for fid in fids:
            for bid in writes_of[fid]:
                (tag, _v), flag = results[bid]
                self.dsm.version[bid] = tag
                if flag == "chg":
                    stats_of[fid]["written"] += 1
                    stats_of[fid]["created"] += int(bid not in old_state_of[fid])
                else:
                    stats_of[fid]["collided"] += 1
        gresults = yield from self.dsm.cvr_write_batch(genesis_writes)
        for fid, g in g_of.items():
            (tag, _v), flag = gresults[g]
            self.dsm.version[g] = tag
            if flag == "chg":
                stats_of[fid]["written"] += 1
            else:
                stats_of[fid]["collided"] += 1
        for fid in fids:
            stats = stats_of[fid]
            stats["success"] = stats["collided"] == 0
            self.history.append(
                OpRecord(
                    kind="fm-update", obj=fid, client=self.dsm.client_id,
                    start=t0, end=self.net.now,
                    flag="chg" if stats["success"] else "unchg",
                    extra={**stats, "coalesced": len(fids)},
                )
            )
        return stats_of

    # --------------------------------------------------------------- recon
    def _recon_walk(self, ptr: str | None, new_config: Config) -> Generator:
        """Legacy count-only genesis: reconfigure block by block along the
        chain, reusing the (tag, value) each recon already transferred
        instead of re-reading every block. Returns #blocks moved."""
        n = 0
        seen: set[str] = set()
        while ptr is not None and ptr not in seen:
            seen.add(ptr)
            bres = yield from self.dsm.recon_batch((ptr,), new_config)
            _bcfg, btag, braw = bres[ptr]
            self.dsm.version[ptr] = btag
            ptr, _ = decode_block_value(braw)
            n += 1
        return n

    def fm_reconfig(self, fid: str, new_config: Config) -> Generator:
        """Alg 3: issue dsmm-reconfig (Alg 2) on every block, genesis
        included. With an index present all data blocks ride ONE batched
        recon (batched consensus + one batched state transfer); a legacy
        count-only genesis falls back to the linked-list walk, reusing the
        (tag, value) each recon already transferred instead of re-reading
        every block."""
        t0 = self.net.now
        g = genesis_id(fid)
        res = yield from self.dsm.recon_batch((g,), new_config)
        _cfg, gtag, graw = res[g]
        self.dsm.version[g] = gtag
        ptr, meta = decode_block_value(graw)
        index = parse_genesis_meta(meta)
        if index is not None:
            if self.batched:
                yield from self.dsm.recon_batch(index, new_config)
            else:

                def recon_op(bid):
                    yield from self.dsm.recon(bid, new_config)
                    return bid

                yield Join([recon_op(b) for b in index])
            n = 1 + len(index)
        else:
            n = 1 + (yield from self._recon_walk(ptr, new_config))
        self.history.append(
            OpRecord(
                kind="fm-recon", obj=fid, client=self.dsm.client_id,
                start=t0, end=self.net.now,
                extra={"n_blocks": n, "config": new_config.cfg_id},
            )
        )
        return n

    def fm_reconfig_batch(self, fids, new_config: Config) -> Generator:
        """Reconfigure many FILES to one target configuration in one batched
        pass (ISSUE 3): every file's genesis rides ONE batched recon (batched
        consensus + one batched state transfer), then ALL indexed data blocks
        of ALL files ride a second one — O(1) quorum rounds however many
        files move. Legacy count-only genesis files fall back to the per-file
        walk; ``batched=False`` degrades to a ``Join`` of per-file recons.
        Returns ``{fid: n_blocks_moved}``."""
        fids = list(dict.fromkeys(fids))
        if not fids:
            return {}
        if not self.batched:
            results = yield Join([self.fm_reconfig(f, new_config) for f in fids])
            return dict(zip(fids, results))
        t0 = self.net.now
        gids = {fid: genesis_id(fid) for fid in fids}
        res = yield from self.dsm.recon_batch(
            [gids[f] for f in fids], new_config
        )
        all_blocks: list[str] = []
        nblocks: dict[str, int] = {}
        walk_heads: dict[str, str | None] = {}
        for fid in fids:
            _cfg, gtag, graw = res[gids[fid]]
            self.dsm.version[gids[fid]] = gtag
            ptr, meta = decode_block_value(graw)
            index = parse_genesis_meta(meta)
            if index is not None:
                all_blocks.extend(index)
                nblocks[fid] = 1 + len(index)
            else:
                walk_heads[fid] = ptr
        if all_blocks:
            yield from self.dsm.recon_batch(
                list(dict.fromkeys(all_blocks)), new_config
            )
        for fid, ptr in walk_heads.items():
            nblocks[fid] = 1 + (yield from self._recon_walk(ptr, new_config))
        for fid in fids:
            self.history.append(
                OpRecord(
                    kind="fm-recon", obj=fid, client=self.dsm.client_id,
                    start=t0, end=self.net.now,
                    extra={"n_blocks": nblocks[fid], "config": new_config.cfg_id,
                           "coalesced": len(fids)},
                )
            )
        return nblocks
