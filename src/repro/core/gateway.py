"""Cross-client gateway aggregation tier (ISSUE 4 tentpole).

PR 3 made an F-file fan-out O(1) quorum rounds *within* one session, but
every client is still its own network endpoint: C clients hammering the
same hot files pay C independent quorum fan-outs. DynoStore-style
deployments put a gateway/proxy tier in front of wide-area clients so
their traffic merges into shared storage rounds; this module brings that
tier to the ARES/COBFS reproduction.

:class:`Gateway` is a coordinator endpoint sessions attach to
(``gw = dss.gateway()``; ``dss.session(cid, via=gw)``). Attached sessions
forward their convenience-op intents to the gateway, which coalesces
in-flight same-kind intents from *multiple clients* within one
virtual-time window and issues ONE merged ``fm_read_batch`` /
``fm_update_batch`` / ``fm_reconfig_batch`` / ``stat_batch`` round on
their behalf:

* same-file reads (or stats, or same-target recons) from C clients dedupe
  to one entry of the merged batch — a single quorum fan-out — and the
  result is multicast back to every rider's future;
* per-client :class:`~repro.core.api.OpStats` stay meaningful through the
  network's attribution map (``Network.attribute``): while the gateway's
  merged round is in flight, each rider client's counters advance with
  the gateway's, so a rider's stats show the shared round once (the same
  sharing semantics a coalesced Session batch already has);
* per-client program order is preserved: intents drain in arrival order
  and a kind change always breaks the merged run, so ``c1.write(f)``
  followed by anyone's ``read(f)`` executes write-then-read. Writes to
  the SAME fid from different clients never merge into one round (the
  second write needs the first one's tag to be a proper successor).

The gateway is also the natural host for configuration dissemination: it
subscribes to the store's recon-finalization notifications (so it sees
every configuration ANY client installs, plus the ones it installs
itself) and runs a lightweight anti-entropy loop gossiping its
``(cfg_idx, cfg_id, Config)`` coverage to registered
:class:`~repro.core.repair.RepairDaemon`\\ s over a codec-framed
``gossip-configs`` message. Daemons ingest the entries additively
(``RepairDaemon.ingest_coverage``) and reply with their OWN coverage, so
knowledge flows both ways — a daemon whose local client never observed a
reconfiguration still acquires the new configuration and repairs it
(the ROADMAP's gossip/membership open item, in the spirit of D-Rex's
global reliability view).

Like the repair daemon, a gateway with registered listeners keeps a
periodic loop on the simulator: call :meth:`Gateway.stop` before
expecting ``net.run()`` to quiesce.
"""
from __future__ import annotations

from typing import Generator

from repro.core.api import OpStats, _dispatch_group, _Intent
from repro.core.tags import Config
from repro.net.sim import RPC, Server, Sleep


class GossipListener(Server):
    """Network endpoint a RepairDaemon registers with a gateway: receives
    codec-framed ``gossip-configs`` pushes, feeds them to the daemon, and
    replies with the daemon's own coverage (symmetric anti-entropy)."""

    def __init__(self, sid: str, daemon):
        super().__init__(sid)
        self.daemon = daemon

    def handle(self, sender: str, msg: tuple):
        op = msg[0]
        if op == "gossip-configs":
            # ("gossip-configs", ((cfg_idx, cfg_id, Config), ...))
            _, entries = msg
            applied = self.daemon.ingest_coverage(
                [(idx, cfg) for idx, _cid, cfg in entries]
            )
            known = tuple(
                (idx, cid, cfg)
                for (idx, cid), cfg in sorted(self.daemon.targets.items())
            )
            return ("gossip-ack", applied, known)
        raise ValueError(f"unknown gossip message {op!r}")


class Gateway:
    """Coordinator endpoint merging many clients' ops into shared rounds.

    ``window`` is the cross-client coalescing window (virtual seconds);
    ``gossip_period`` paces the anti-entropy loop once a daemon is
    registered. The gateway drives a regular :class:`ClientHandle` under
    its own client id, so merged traffic rides the PR-2/PR-3 batched
    state-transfer engine unchanged — coverability writes through the
    gateway use the GATEWAY's version tags (it acts as one writer on the
    attached clients' behalf).
    """

    def __init__(self, dss, gid: str = "gw", *, window: float = 0.5e-3,
                 gossip_period: float = 0.02):
        self.dss = dss
        self.gid = gid
        self.net = dss.net
        self.handle = dss.client(gid)
        self.window = window
        self.gossip_period = gossip_period
        self._pending: list[_Intent] = []
        self._drain_scheduled = False
        # configuration coverage: (cfg_idx, cfg_id) -> Config. Seeded with
        # the genesis configuration; grows via recon-finalization
        # notifications (any client of this store) and gossip acks.
        self.coverage: dict[tuple[int, str], Config] = {(0, dss.c0.cfg_id): dss.c0}
        self._listeners: list[str] = []
        self._stopped = False
        self._gossip_fut = None
        self.stats = {"merged": 0, "groups": 0, "dedup_saved": 0,
                      "gossip_rounds": 0, "gossip_applied": 0,
                      "gossip_learned": 0}
        dss._recon_subs.append(self.observe_recon)

    # ------------------------------------------------------------- sessions
    def session(self, cid: str, **kw):
        """Open a Session attached to this gateway (``dss.session(cid,
        via=self)``)."""
        return self.dss.session(cid, via=self, **kw)

    def _enqueue(self, intent: _Intent) -> None:
        self._pending.append(intent)
        if not self._drain_scheduled:
            self._drain_scheduled = True
            self.net.spawn(
                self._drain(), kind="gateway-drain", client=self.gid,
                delay=self.window,
            )

    # ------------------------------------------------------------ scheduler
    @staticmethod
    def _groups(batch: list[_Intent]) -> list[list[_Intent]]:
        """Maximal runs of consecutive same-kind intents, like the Session
        scheduler — but a repeated fid only breaks a WRITE run (same-fid
        reads/stats dedupe and multicast; same-fid writes must stay two
        rounds). Recon runs still break on a different target config."""
        groups: list[list[_Intent]] = []
        fids: set = set()  # fids of the current (last) group, O(1) break check
        for it in batch:
            g = groups[-1] if groups else None
            if (
                g is None
                or g[0].kind != it.kind
                or (it.kind == "write" and it.fid in fids)
                or (it.kind == "recon" and g[0].arg.cfg_id != it.arg.cfg_id)
            ):
                groups.append([it])
                fids = {it.fid}
            else:
                g.append(it)
                fids.add(it.fid)
        return groups

    def _rider_stats(self, it: _Intent, snaps: dict, t0: float, blocks: int,
                     width: int, x0: int = 0) -> OpStats:
        r0, m0, b0 = snaps[it.fut.client]
        r1, m1, b1 = self.net.client_totals(it.fut.client)
        return OpStats(rounds=r1 - r0, msgs=m1 - m0, bytes=b1 - b0,
                       latency=self.net.now - t0, blocks=blocks,
                       batched_with=width,
                       retries=self.net.retransmits - x0)

    def _drain(self) -> Generator:
        # same reschedule discipline as the (fixed) Session drain: the flag
        # stays armed while this generator is mid-flight so late enqueues
        # never spawn a concurrent drain, and the exit path re-arms for them.
        try:
            batch, self._pending = self._pending, []
            for group in self._groups(batch):
                n_fids = len({it.fid for it in group})
                riders = list(dict.fromkeys(it.fut.client for it in group))
                snaps = {c: self.net.client_totals(c) for c in riders}
                t0 = self.net.now
                x0 = self.net.retransmits
                self.stats["groups"] += 1
                self.stats["merged"] += len(group)
                self.stats["dedup_saved"] += len(group) - n_fids
                self.net.attribute(self.gid, riders)
                try:
                    payload, blocks = yield from _dispatch_group(
                        self.handle, group
                    )
                except Exception as err:  # noqa: BLE001 - delivered via futures
                    for it in group:
                        it.fut._fail(
                            err,
                            self._rider_stats(it, snaps, t0, 0, len(group), x0),
                        )
                    continue
                finally:
                    self.net.attribute(self.gid, None)
                for it in group:
                    it.fut._resolve(
                        payload[it.fid],
                        self._rider_stats(
                            it, snaps, t0, blocks[it.fid], len(group), x0
                        ),
                    )
        finally:
            self._drain_scheduled = False
            if self._pending:
                self._drain_scheduled = True
                self.net.spawn(
                    self._drain(), kind="gateway-drain", client=self.gid,
                    delay=self.window,
                )
        return None

    # ---------------------------------------------------- config dissemination
    def observe_recon(self, config: Config, cfg_idx: int, objs=None) -> None:
        """Recon-finalization callback (subscribed on the DSS): every
        configuration ANY client of this store installs joins the gateway's
        gossip coverage."""
        if self._stopped:
            return
        self.coverage.setdefault((cfg_idx, config.cfg_id), config)
        san = getattr(self.net, "sanitizer", None)
        if san is not None:
            san.register_config(config)

    def register_daemon(self, daemon, sid: str | None = None) -> str:
        """Register a RepairDaemon for config gossip: a
        :class:`GossipListener` endpoint joins the network and the
        anti-entropy loop starts (if not already running). Returns the
        listener's server id."""
        sid = sid or f"{self.gid}:{daemon.client_id}"
        if sid in self.net.servers:
            raise ValueError(f"gossip listener {sid!r} already registered")
        self.net.add_server(GossipListener(sid, daemon))
        self._listeners.append(sid)
        if self._gossip_fut is None and not self._stopped:
            # NB its own client id: gossip rounds that interleave with an
            # in-flight merged round must never be attributed to that
            # round's riders (attribution keys on the issuing client).
            self._gossip_fut = self.net.spawn(
                self._gossip_loop(), kind="gateway-gossip",
                client=f"{self.gid}:gossip",
            )
        return sid

    def _gossip_loop(self) -> Generator:
        while not self._stopped:
            yield Sleep(self.gossip_period)
            if self._stopped:
                break
            if not self._listeners:
                continue
            entries = tuple(
                (idx, cid, cfg)
                for (idx, cid), cfg in sorted(self.coverage.items())
            )
            replies = yield RPC(
                dests=tuple(self._listeners),
                msg=("gossip-configs", entries),
                need="alive",
            )
            self.stats["gossip_rounds"] += 1
            san = getattr(self.net, "sanitizer", None)
            for _sid, (_tok, applied, known) in replies.items():
                self.stats["gossip_applied"] += applied
                for idx, cid, cfg in known:
                    if (idx, cid) not in self.coverage:
                        self.coverage[(idx, cid)] = cfg
                        self.stats["gossip_learned"] += 1
                        if san is not None:
                            san.register_config(cfg)
        return dict(self.stats)

    def stop(self) -> None:
        """End the anti-entropy loop (at its next wake-up) and detach from
        recon notifications, so ``net.run()`` can quiesce."""
        self._stopped = True
        if self.observe_recon in self.dss._recon_subs:
            self.dss._recon_subs.remove(self.observe_recon)
