"""Self-healing fragment repair & rebalance (beyond-paper, ISSUE 1 tentpole).

The paper's liveness guarantee for EC-DAPopt (Thm 18) holds only while
<= (n-k)/2 servers of a configuration have crashed — but nothing in ARES
ever *restores* redundancy: a server that recovers with a stale or wiped
List keeps serving old state until a full reconfiguration rewrites the
object. Liquid Cloud Storage (Luby et al., PAPERS.md) shows that lazy
background repair is what keeps erasure-coded stores durable at scale;
this module adds that missing loop.

``RepairController`` scans one configuration's servers for missing or
stale coded fragments (per object, per tag), pulls any k surviving
fragments with the ``ec-repair-pull`` server message, rebuilds the lost
rows (one decode + one fused GF(256) matmul via
``RSCode.reconstruct_fragments``) and pushes them back with
``ec-repair-push``. Everything is a sim generator: repair traffic rides
the same virtual-time latency model as foreground reads/writes, so the
benchmarks can measure interference (``benchmarks/bench_repair.py``).

Safety under concurrent writes
------------------------------
Repair never regresses a server's List to an older tag:

* ``ec-repair-push`` only *adds* an element for a tag the server has never
  seen; it never overwrites an element and never resurrects a trimmed
  ``(tag, ⊥)`` placeholder. Inserting cannot remove newer tags, and the
  handler re-applies the same δ+1 trim as ``ec-put``, so the List-length
  invariant (Alg 5) is preserved.
* The pushed element is the *bit-identical* coded row the writer would
  have sent (MDS determinism), so a reader that decodes with repaired
  fragments obtains exactly the written value — C2 is untouched.
* Repair writes no tags of its own, so tag uniqueness / monotonicity
  (the atomicity checkers in ``tests/checkers.py``) are unaffected.

A racing put-data can at worst make the repaired tag obsolete, in which
case the trim quietly drops it again — wasted work, never lost safety.
"""
from __future__ import annotations

from typing import Any, Generator

import numpy as np

from repro.core.tags import TAG0, Config, OpRecord, Tag
from repro.erasure.rs import RSCode
from repro.net.sim import Join, RPC, Sleep


class RepairController:
    """Scans an erasure-coded configuration and restores lost redundancy.

    All public methods are sim generators (drive them with ``Network.spawn``
    / ``run_op``); ``DSS.repair`` wraps the common whole-store pass.
    """

    def __init__(
        self,
        net,
        config: Config,
        cfg_idx: int = 0,
        *,
        client_id: str = "repair",
        history: list | None = None,
        backend: str = "numpy",
    ):
        if config.dap not in ("ec", "ec_opt"):
            raise ValueError(
                f"repair applies to erasure-coded configurations, not {config.dap!r}"
            )
        self.net = net
        self.config = config
        self.cfg_idx = cfg_idx
        self.client_id = client_id
        self.history = history if history is not None else []
        self.code = RSCode(n=config.n, k=config.k, backend=backend)

    # ------------------------------------------------------------------ scan
    def scan(self, obj: str) -> Generator:
        """Pull List snapshots from every live server of the configuration.

        Returns ``(replies, frags, holders, t_star)`` where ``frags`` maps
        tag -> {fragment index: element}, ``holders`` maps tag -> {sid}, and
        ``t_star`` is the maximum tag decodable from >= k surviving coded
        elements (TAG0 when nothing real is stored)."""
        replies = yield RPC(
            dests=self.config.servers,
            msg=("ec-repair-pull", obj, self.cfg_idx),
            need="alive",
        )
        frags: dict[Tag, dict[int, Any]] = {}
        holders: dict[Tag, set[str]] = {}
        for sid, (_kindtok, lst) in replies.items():
            fidx = self.config.frag_index(sid)
            for t, e in lst:
                if e is not None:
                    frags.setdefault(t, {})[fidx] = e
                    holders.setdefault(t, set()).add(sid)
        decodable = [t for t, m in frags.items() if len(m) >= self.config.k]
        t_star = max(decodable, default=TAG0)
        return replies, frags, holders, t_star

    # ---------------------------------------------------------------- repair
    def repair_object(self, obj: str) -> Generator:
        """Restore every live server's coded element at the newest decodable
        tag. Returns a stats dict (scanned / missing / pushed / applied)."""
        t0 = self.net.now
        replies, frags, holders, t_star = yield from self.scan(obj)
        stats = {
            "obj": obj,
            "tag": t_star,
            "scanned": len(replies),
            "missing": 0,
            "pushed": 0,
            "applied": 0,
        }
        if t_star == TAG0:
            # only the initial sentinel (t0, Φ_i(v0)) exists — nothing real
            # was ever written (or too few fragments survive to rebuild).
            self._record(t0, stats)
            return stats
        missing = [s for s in replies if s not in holders.get(t_star, set())]
        stats["missing"] = len(missing)
        if not missing:
            self._record(t0, stats)
            return stats
        fmap = frags[t_star]
        idxs = sorted(fmap)[: self.config.k]
        orig = fmap[idxs[0]][1]
        mat = np.stack(
            [np.frombuffer(fmap[i][0], dtype=np.uint8) for i in idxs], axis=0
        )
        targets = [self.config.frag_index(s) for s in missing]
        rows = self.code.reconstruct_fragments(targets, mat, idxs)
        # charge the rebuild at the model's client-side coding rates
        yield Sleep(
            self.net.latency.dec_per_byte * mat.size
            + self.net.latency.enc_per_byte * rows.size
        )
        per_dest = {
            sid: (
                "ec-repair-push",
                obj,
                self.cfg_idx,
                t_star,
                (rows[j].tobytes(), orig),
                self.config.delta,
            )
            for j, sid in enumerate(missing)
        }
        acks = yield RPC(
            dests=tuple(missing), msg=None, per_dest=per_dest, need="alive"
        )
        stats["pushed"] = len(missing)
        stats["applied"] = sum(1 for a in acks.values() if a[1])
        self._record(t0, stats)
        return stats

    def scan_and_repair(self, objs, *, parallel: bool = False) -> Generator:
        """Repair a set of objects; ``parallel=True`` overlaps them (Join),
        the default walks them sequentially (gentler on foreground traffic)."""
        objs = list(objs)
        if parallel:
            results = yield Join([self.repair_object(o) for o in objs])
            return results
        out = []
        for obj in objs:
            out.append((yield from self.repair_object(obj)))
        return out

    # --------------------------------------------------------------- record
    def _record(self, t0: float, stats: dict) -> None:
        self.history.append(
            OpRecord(
                kind="repair",
                obj=stats["obj"],
                client=self.client_id,
                start=t0,
                end=self.net.now,
                tag=stats["tag"],
                extra=dict(stats),
            )
        )


class RepairDaemon:
    """Rate-limited background repair loop (ISSUE 2) — the steady-state
    companion to the recon-triggered repair in ``CoAresClient.recon_batch``,
    replacing explicitly invoked ``DSS.repair`` passes.

    A periodic self-rescheduling generator on the sim: every ``period``
    virtual seconds one cycle repairs at most ``objs_per_cycle`` objects
    (round-robin over whatever ``discover(cfg_idx)`` currently returns), so
    repair traffic is RATE-LIMITED and interferes boundedly with foreground
    reads/writes (Liquid Cloud Storage's lazy-repair argument: a slow steady
    repair flow is enough to keep MDS redundancy ahead of failures).

    ``retarget(config, cfg_idx)`` points the daemon at a newly installed
    configuration after a reconfiguration. The loop runs until ``stop()`` (or
    ``max_cycles``); remember that ``Network.run()`` drives the event loop to
    quiescence, so either bound the cycles, stop the daemon, or run with
    ``until=``.
    """

    def __init__(
        self,
        net,
        config: Config,
        cfg_idx: int = 0,
        *,
        discover,
        period: float = 0.05,
        objs_per_cycle: int = 4,
        max_cycles: int | None = None,
        client_id: str = "repaird",
        history: list | None = None,
    ):
        self.net = net
        self.config = config
        self.cfg_idx = cfg_idx
        self.discover = discover          # cfg_idx -> iterable of object names
        self.period = period
        self.objs_per_cycle = max(1, objs_per_cycle)
        self.max_cycles = max_cycles
        self.client_id = client_id
        self.history = history if history is not None else []
        self.stats = {"cycles": 0, "objects": 0, "pushed": 0, "applied": 0}
        self._stopped = False
        self._cursor = 0
        self._fut = None

    def start(self):
        """Spawn the loop onto the sim; returns the daemon's OpFuture."""
        self._fut = self.net.spawn(
            self._loop(), kind="repair-daemon", client=self.client_id
        )
        return self._fut

    def stop(self) -> None:
        """Ask the loop to exit at its next wake-up."""
        self._stopped = True

    def retarget(self, config: Config, cfg_idx: int) -> None:
        """Follow a reconfiguration: scan/repair the new configuration from
        the next cycle on."""
        self.config = config
        self.cfg_idx = cfg_idx
        self._cursor = 0

    def _loop(self) -> Generator:
        while not self._stopped and (
            self.max_cycles is None or self.stats["cycles"] < self.max_cycles
        ):
            yield Sleep(self.period)
            if self._stopped:
                break
            objs = list(self.discover(self.cfg_idx))
            if objs:
                # round-robin window: at most objs_per_cycle objects per wake
                start = self._cursor % len(objs)
                take = (objs[start:] + objs[:start])[: self.objs_per_cycle]
                self._cursor = (start + len(take)) % len(objs)
                rc = RepairController(
                    self.net, self.config, self.cfg_idx,
                    client_id=self.client_id, history=self.history,
                )
                results = yield from rc.scan_and_repair(take)
                self.stats["objects"] += len(results)
                self.stats["pushed"] += sum(r["pushed"] for r in results)
                self.stats["applied"] += sum(r["applied"] for r in results)
            self.stats["cycles"] += 1
        return dict(self.stats)
