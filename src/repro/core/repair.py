"""Self-healing fragment repair & rebalance (beyond-paper, ISSUE 1 tentpole).

The paper's liveness guarantee for EC-DAPopt (Thm 18) holds only while
<= (n-k)/2 servers of a configuration have crashed — but nothing in ARES
ever *restores* redundancy: a server that recovers with a stale or wiped
List keeps serving old state until a full reconfiguration rewrites the
object. Liquid Cloud Storage (Luby et al., PAPERS.md) shows that lazy
background repair is what keeps erasure-coded stores durable at scale;
this module adds that missing loop.

``RepairController`` scans one configuration's servers for missing or
stale coded fragments (per object, per tag), pulls any k surviving
fragments with the ``ec-repair-pull`` server message, rebuilds the lost
rows (one decode + one fused GF(256) matmul via
``RSCode.reconstruct_fragments``) and pushes them back with
``ec-repair-push``. Everything is a sim generator: repair traffic rides
the same virtual-time latency model as foreground reads/writes, so the
benchmarks can measure interference (``benchmarks/bench_repair.py``).

Safety under concurrent writes
------------------------------
Repair never regresses a server's List to an older tag:

* ``ec-repair-push`` only *adds* an element for a tag the server has never
  seen; it never resurrects a trimmed ``(tag, ⊥)`` placeholder, and the
  only element it may overwrite is one whose bytes FAIL their own stored
  checksum (bit-rot healing, ISSUE 6) — the replacement is the bit-identical
  row the writer would have stored, so this is a pure restore, not a state
  change. Inserting cannot remove newer tags, and the handler re-applies
  the same δ+1 trim as ``ec-put``, so the List-length invariant (Alg 5) is
  preserved.
* The pushed element is the *bit-identical* coded row the writer would
  have sent (MDS determinism), so a reader that decodes with repaired
  fragments obtains exactly the written value — C2 is untouched.
* Repair writes no tags of its own, so tag uniqueness / monotonicity
  (the atomicity checkers in ``tests/checkers.py``) are unaffected.

A racing put-data can at worst make the repaired tag obsolete, in which
case the trim quietly drops it again — wasted work, never lost safety.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Generator
import zlib

import numpy as np

from repro.core.tags import TAG0, Config, OpRecord, Tag
from repro.erasure.rs import RSCode, element_crc_ok
from repro.net.sim import Join, RPC, Sleep


@dataclass
class ObjectHealth:
    """Surviving-fragment margin of one object at one configuration (D-Rex's
    reliability signal, ISSUE 3): how many more server losses the newest
    written version survives before it becomes undecodable/unreadable.

    ``margin = holders - k`` for EC configurations (holders = live servers
    whose List still carries a coded element at the newest decodable tag),
    ``holders - 1`` for ABD (live replicas storing the max tag). Data that
    WAS written but no longer reaches k live holders reports a NEGATIVE
    margin with ``unreadable=True`` (repair cannot rebuild it from this
    configuration, so ``needs_repair`` stays False — but it must never be
    confused with a healthy object). Only when nothing real was ever stored
    (``tag == TAG0``, no real tag seen anywhere) does the object report
    full margin. ``superseded`` means a quorum already finalized a successor
    configuration at this index — the state here is historical and repair
    effort belongs to the successor."""

    obj: str
    tag: Tag
    holders: int      # live servers holding the newest version
    alive: int        # live servers that answered the probe
    margin: int
    needs_repair: bool
    unreadable: bool = False
    superseded: bool = False


def probe_health(config: Config, cfg_idx: int, objs) -> Generator:
    """ONE tag-only ``margin-batch`` fan-out over the configuration's live
    servers; returns ``{obj: ObjectHealth}``. No values or coded elements
    move, so probing a whole store costs a few KB — cheap enough to run
    every daemon cycle (and per ``Session.stat`` call)."""
    objs = list(dict.fromkeys(objs))
    out: dict[str, ObjectHealth] = {}
    if not objs:
        return out
    ec = config.dap in ("ec", "ec_opt")
    k = config.k if ec else 1
    replies = yield RPC(
        dests=config.servers,
        msg=("margin-batch", tuple(objs), cfg_idx),
        need="alive",
    )
    alive = len(replies)
    for pos, obj in enumerate(objs):
        counts: dict[Tag, int] = {}
        seen: set[Tag] = set()
        superseded = False
        for _sid, (_kindtok, items) in replies.items():
            abd_tag, ec_items, next_status = items[pos]
            if next_status == "F":
                superseded = True
            if ec:
                for t, holds in ec_items or ():
                    if t > TAG0:
                        seen.add(t)
                    if holds:
                        counts[t] = counts.get(t, 0) + 1
            elif abd_tag is not None:
                if abd_tag > TAG0:
                    seen.add(abd_tag)
                counts[abd_tag] = counts.get(abd_tag, 0) + 1
        decodable = [t for t, c in counts.items() if c >= k and t > TAG0]
        if decodable:
            t_star = max(decodable)
            holders = counts[t_star]
            health = ObjectHealth(
                obj=obj, tag=t_star, holders=holders, alive=alive,
                margin=holders - k, needs_repair=holders < alive,
                superseded=superseded,
            )
        elif seen:
            # data WAS written here but fewer than k live holders remain:
            # unreadable from this configuration, margin is negative, and
            # repair cannot rebuild it — never report it healthy.
            best = max(
                ((counts.get(t, 0), t) for t in sorted(seen)), default=(0, TAG0)
            )
            health = ObjectHealth(
                obj=obj, tag=best[1], holders=best[0], alive=alive,
                margin=best[0] - k, needs_repair=False, unreadable=True,
                superseded=superseded,
            )
        else:
            health = ObjectHealth(
                obj=obj, tag=TAG0, holders=alive, alive=alive,
                margin=alive - k, needs_repair=False, superseded=superseded,
            )
        out[obj] = health
    return out


class RepairController:
    """Scans an erasure-coded configuration and restores lost redundancy.

    All public methods are sim generators (drive them with ``Network.spawn``
    / ``run_op``); ``DSS.repair`` wraps the common whole-store pass.
    """

    def __init__(
        self,
        net,
        config: Config,
        cfg_idx: int = 0,
        *,
        client_id: str = "repair",
        history: list | None = None,
        backend: str | None = None,
    ):
        if config.dap not in ("ec", "ec_opt"):
            raise ValueError(
                f"repair applies to erasure-coded configurations, not {config.dap!r}"
            )
        self.net = net
        self.config = config
        self.cfg_idx = cfg_idx
        self.client_id = client_id
        self.history = history if history is not None else []
        # None = inherit the store-wide coding backend riding on the network
        # handle (DSSParams.coding_backend), same as EcDap.
        if backend is None:
            backend = getattr(net, "coding_backend", "numpy")
        self.code = RSCode(n=config.n, k=config.k, backend=backend)

    # ----------------------------------------------------------------- probe
    def probe_health(self, objs) -> Generator:
        """Tag-only margin probe of this configuration (one fan-out for ALL
        objects); see module-level ``probe_health``."""
        return (yield from probe_health(self.config, self.cfg_idx, objs))

    # ------------------------------------------------------------------ scan
    def scan(self, obj: str) -> Generator:
        """Pull List snapshots from every live server of the configuration.

        Returns ``(replies, frags, holders, t_star)`` where ``frags`` maps
        tag -> {fragment index: element}, ``holders`` maps tag -> {sid}, and
        ``t_star`` is the maximum tag decodable from >= k surviving coded
        elements (TAG0 when nothing real is stored)."""
        replies = yield RPC(
            dests=self.config.servers,
            msg=("ec-repair-pull", obj, self.cfg_idx),
            need="alive",
        )
        frags: dict[Tag, dict[int, Any]] = {}
        holders: dict[Tag, set[str]] = {}
        for sid, (_kindtok, lst) in replies.items():
            fidx = self.config.frag_index(sid)
            for t, e in lst:
                # a stored element whose bytes fail their own CRC is treated
                # as lost: its server is NOT a holder, so it lands in
                # ``missing`` below and the push replaces the rotted element
                # (the server side overwrites only on a failed self-check).
                if e is not None and element_crc_ok(e):
                    frags.setdefault(t, {})[fidx] = e
                    holders.setdefault(t, set()).add(sid)
        decodable = [t for t, m in frags.items() if len(m) >= self.config.k]
        t_star = max(decodable, default=TAG0)
        return replies, frags, holders, t_star

    # ---------------------------------------------------------------- repair
    def repair_object(self, obj: str) -> Generator:
        """Restore every live server's coded element at the newest decodable
        tag. Returns a stats dict (scanned / missing / pushed / applied)."""
        t0 = self.net.now
        replies, frags, holders, t_star = yield from self.scan(obj)
        stats = {
            "obj": obj,
            "tag": t_star,
            "scanned": len(replies),
            "missing": 0,
            "pushed": 0,
            "applied": 0,
        }
        if t_star == TAG0:
            # only the initial sentinel (t0, Φ_i(v0)) exists — nothing real
            # was ever written (or too few fragments survive to rebuild).
            self._record(t0, stats)
            return stats
        missing = [s for s in replies if s not in holders.get(t_star, set())]
        stats["missing"] = len(missing)
        if not missing:
            self._record(t0, stats)
            return stats
        fmap = frags[t_star]
        idxs = sorted(fmap)[: self.config.k]
        orig = fmap[idxs[0]][1]
        mat = np.stack(
            [np.frombuffer(fmap[i][0], dtype=np.uint8) for i in idxs], axis=0
        )
        targets = [self.config.frag_index(s) for s in missing]
        rows = self.code.reconstruct_fragments(targets, mat, idxs)
        # charge the rebuild at the model's client-side coding rates
        yield Sleep(
            self.net.latency.dec_per_byte * mat.size
            + self.net.latency.enc_per_byte * rows.size
        )
        frag_bytes = [rows[j].tobytes() for j in range(len(missing))]
        per_dest = {
            sid: (
                "ec-repair-push",
                obj,
                self.cfg_idx,
                t_star,
                (fb, orig, zlib.crc32(fb)),
                self.config.delta,
            )
            for sid, fb in zip(missing, frag_bytes)
        }
        acks = yield RPC(
            dests=tuple(missing), msg=None, per_dest=per_dest, need="alive"
        )
        stats["pushed"] = len(missing)
        stats["applied"] = sum(1 for a in acks.values() if a[1])
        self._record(t0, stats)
        return stats

    def scan_and_repair(self, objs, *, parallel: bool = False) -> Generator:
        """Repair a set of objects; ``parallel=True`` overlaps them (Join),
        the default walks them sequentially (gentler on foreground traffic)."""
        objs = list(objs)
        if parallel:
            results = yield Join([self.repair_object(o) for o in objs])
            return results
        out = []
        for obj in objs:
            out.append((yield from self.repair_object(obj)))
        return out

    # --------------------------------------------------------------- record
    def _record(self, t0: float, stats: dict) -> None:
        self.history.append(
            OpRecord(
                kind="repair",
                obj=stats["obj"],
                client=self.client_id,
                start=t0,
                end=self.net.now,
                tag=stats["tag"],
                extra=dict(stats),
            )
        )


class RepairDaemon:
    """Rate-limited background repair loop (ISSUE 2) — the steady-state
    companion to the recon-triggered repair in ``CoAresClient.recon_batch``,
    replacing explicitly invoked ``DSS.repair`` passes.

    A periodic self-rescheduling generator on the sim: every ``period``
    virtual seconds one cycle repairs at most ``objs_per_cycle`` objects, so
    repair traffic is RATE-LIMITED and interferes boundedly with foreground
    reads/writes (Liquid Cloud Storage's lazy-repair argument: a slow steady
    repair flow is enough to keep MDS redundancy ahead of failures).

    Scheduling order (ISSUE 3, à la D-Rex): with ``order="margin"`` (the
    default) each cycle first runs ONE tag-only ``probe_health`` fan-out over
    everything ``discover(cfg_idx)`` returns, then repairs the objects with
    the SMALLEST surviving-fragment margin first — the most endangered data
    regains redundancy before comfortably-degraded data, and healthy objects
    are skipped entirely instead of wastefully re-scanned. ``order="rr"``
    keeps the old blind round-robin (the ablation baseline).

    The daemon covers a SET of configurations (``targets``): with
    ``auto_retarget=True`` its ``observe_recon`` callback (wired to the
    recon-finalization notifications by ``DSS.start_repair_daemon``) ADDS
    every newly finalized configuration it sees, while the configurations it
    already covers stay covered — a partial reconfiguration (some files
    moved, some not) never silently ends repair coverage for the objects
    left behind. Objects whose servers report a FINALIZED successor at an
    index (``ObjectHealth.superseded``) are historical state and are
    skipped. Non-EC targets idle (nothing coded to rebuild). An explicit
    ``retarget(config, cfg_idx)`` narrows coverage to exactly that one
    configuration (the pre-ISSUE-3 owner-driven contract). The loop runs
    until ``stop()`` (or ``max_cycles``); remember that ``Network.run()``
    drives the event loop to quiescence, so either bound the cycles, stop
    the daemon, or run with ``until=``.
    """

    def __init__(
        self,
        net,
        config: Config,
        cfg_idx: int = 0,
        *,
        discover,
        period: float = 0.05,
        objs_per_cycle: int = 4,
        max_cycles: int | None = None,
        client_id: str = "repaird",
        history: list | None = None,
        order: str = "margin",
        auto_retarget: bool = True,
    ):
        if order not in ("margin", "rr"):
            raise ValueError(f"unknown repair order {order!r}")
        self.net = net
        # configurations under repair coverage: (cfg_idx, cfg_id) -> Config.
        # Keyed by BOTH index and id — independent recons of different files
        # can install DIFFERENT configurations at the same sequence index,
        # and each must be probed against its own server set. The
        # ``config``/``cfg_idx`` properties expose the NEWEST target.
        self.targets: dict[tuple[int, str], Config] = {
            (cfg_idx, config.cfg_id): config
        }
        self.discover = discover          # cfg_idx -> iterable of object names
        self.period = period
        self.objs_per_cycle = max(1, objs_per_cycle)
        self.max_cycles = max_cycles
        self.client_id = client_id
        self.history = history if history is not None else []
        self.order = order
        self.auto_retarget = auto_retarget
        self.stats = {"cycles": 0, "objects": 0, "pushed": 0, "applied": 0,
                      "probed": 0, "retargets": 0, "pruned": 0, "gossip": 0}
        # targets pruned as fully superseded stay retired: config gossip
        # re-advertises old configurations forever (anti-entropy has no
        # tombstones), and re-ingesting one would start a prune/re-add
        # tug-of-war every cycle (ISSUE 4).
        self._retired: set[tuple[int, str]] = set()
        self._stopped = False
        self._cursor = 0
        self._fut = None

    @property
    def cfg_idx(self) -> int:
        return max(self.targets)[0]

    @property
    def config(self) -> Config:
        return self.targets[max(self.targets)]

    def covered_indices(self) -> list[int]:
        return sorted({idx for idx, _cid in self.targets})

    def start(self):
        """Spawn the loop onto the sim; returns the daemon's OpFuture."""
        self._fut = self.net.spawn(
            self._loop(), kind="repair-daemon", client=self.client_id
        )
        return self._fut

    def stop(self) -> None:
        """Ask the loop to exit at its next wake-up."""
        self._stopped = True

    def retarget(self, config: Config, cfg_idx: int) -> None:
        """Owner-driven narrowing: scan/repair exactly this configuration
        from the next cycle on (drops coverage of every other target; use
        ``observe_recon``/auto-retarget to ADD coverage instead)."""
        self.targets = {(cfg_idx, config.cfg_id): config}
        self._retired.discard((cfg_idx, config.cfg_id))  # explicit owner intent
        self._cursor = 0

    def observe_recon(self, config: Config, cfg_idx: int, objs=None) -> None:
        """Recon-finalization callback (``CoAresClient.on_recon`` shape): the
        daemon ADDS every newly installed configuration it sees to its
        coverage — the owner never has to call ``retarget`` (ISSUE 3). The
        configurations already covered stay covered: objects a partial recon
        left behind keep being repaired, and two files reconfigured to
        DIFFERENT configurations at the same index are both covered. Ignored
        once the daemon stopped or its loop completed (a stale subscription
        must not mutate it)."""
        if not self.auto_retarget or self._stopped:
            return
        if self._fut is not None and self._fut.done:
            return
        key = (cfg_idx, config.cfg_id)
        if key not in self.targets and key not in self._retired:
            self.targets[key] = config
            self.stats["retargets"] += 1

    def ingest_coverage(self, entries) -> int:
        """Gossip ingestion (ISSUE 4): ADD every ``(cfg_idx, Config)``
        coverage entry this daemon has not seen — how a daemon whose local
        client never ran (or observed) a reconfiguration still learns the
        configurations it should be repairing. Fed by the gateway tier's
        anti-entropy loop (``Gateway.register_daemon`` →
        ``gossip-configs``). Deliberately NOT gated on ``auto_retarget``:
        gossip is the membership channel that replaces the local recon
        callback, not an extension of it. Same staleness guards as
        ``observe_recon``; returns how many entries were new."""
        if self._stopped or (self._fut is not None and self._fut.done):
            return 0
        applied = 0
        for cfg_idx, config in entries:
            key = (cfg_idx, config.cfg_id)
            if key not in self.targets and key not in self._retired:
                self.targets[key] = config
                applied += 1
        self.stats["gossip"] += applied
        return applied

    def _ec_targets(self) -> list[tuple[int, Config]]:
        return [
            (idx, cfg)
            for (idx, _cid), cfg in sorted(self.targets.items())
            if cfg.dap in ("ec", "ec_opt")
        ]

    def _pick(self) -> Generator:
        """The (cfg_idx, config, obj) triples this cycle repairs — across
        ALL covered EC configurations, most endangered first (``margin``),
        or blind round-robin over the concatenated object lists (``rr``).

        An object probed under a same-index target it was never stored in
        simply reports nothing (tag TAG0) and is skipped there; its real
        health comes from its own configuration's probe. Margin mode also
        PRUNES stale targets: when every object a non-newest target
        discovers is superseded (a finalized successor exists), the target
        is dropped, so per-cycle probe traffic stays bounded as the store
        reconfigures over time."""
        if self.order == "rr":
            items = [
                (idx, cfg, obj)
                for idx, cfg in self._ec_targets()
                for obj in self.discover(idx)
            ]
            if not items:
                return []
            start = self._cursor % len(items)
            take = (items[start:] + items[:start])[: self.objs_per_cycle]
            self._cursor = (start + len(take)) % len(items)
            return take
        cands: list[tuple[int, str, int, Config]] = []
        newest = max(self.targets)
        for idx, cfg in self._ec_targets():
            objs = list(self.discover(idx))
            if not objs:
                continue
            health = yield from probe_health(cfg, idx, objs)
            self.stats["probed"] += len(health)
            if (idx, cfg.cfg_id) != newest and all(
                h.superseded for h in health.values()
            ):
                # everything here moved on to a finalized successor: stop
                # probing this configuration from the next cycle on (and
                # keep it retired — gossip re-advertises it forever)
                self.targets.pop((idx, cfg.cfg_id), None)
                self._retired.add((idx, cfg.cfg_id))
                self.stats["pruned"] += 1
                continue
            for h in health.values():
                # superseded state is historical (a finalized successor
                # exists at this index) — effort belongs to the successor
                if h.needs_repair and not h.superseded:
                    cands.append((h.margin, h.obj, idx, cfg))
        cands.sort(key=lambda c: (c[0], c[1], c[2]))
        return [(idx, cfg, obj) for _m, obj, idx, cfg in
                cands[: self.objs_per_cycle]]

    def _loop(self) -> Generator:
        while not self._stopped and (
            self.max_cycles is None or self.stats["cycles"] < self.max_cycles
        ):
            yield Sleep(self.period)
            if self._stopped:
                break
            take = yield from self._pick()
            by_target: dict[int, tuple[Config, list[str]]] = {}
            for idx, cfg, obj in take:
                by_target.setdefault(idx, (cfg, []))[1].append(obj)
            for idx, (cfg, objs) in by_target.items():
                rc = RepairController(
                    self.net, cfg, idx,
                    client_id=self.client_id, history=self.history,
                )
                results = yield from rc.scan_and_repair(objs)
                self.stats["objects"] += len(results)
                self.stats["pushed"] += sum(r["pushed"] for r in results)
                self.stats["applied"] += sum(r["applied"] for r in results)
            self.stats["cycles"] += 1
        return dict(self.stats)
