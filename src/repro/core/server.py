"""Replica server: ABD store, EC fragment Lists (Alg 5), nextC, consensus.

One server object hosts state for *every* (object, configuration-index) pair —
exactly the paper's model where a physical server participates in many
configurations and stores many blocks. State is created lazily with the
initial value ``(t0, v0 = None)`` / ``{(t0, Φ_i(v0))}``.
"""
from __future__ import annotations

from typing import Any

from repro.net.sim import Server
from repro.core.tags import TAG0, Tag
from repro.erasure.rs import element_crc_ok


class StorageServer(Server):
    def __init__(self, sid: str):
        super().__init__(sid)
        # ABD-DAP: (obj, cfg_idx) -> (tag, value)
        self.abd: dict[tuple, tuple[Tag, Any]] = {}
        # EC-DAP: (obj, cfg_idx) -> {tag: element | None}; None = trimmed ⊥
        self.ec: dict[tuple, dict[Tag, Any]] = {}
        # reconfiguration: (obj, cfg_idx) -> (config, status)
        self.next_c: dict[tuple, tuple[Any, str]] = {}
        # consensus acceptor: (obj, cfg_idx) -> [promised, accepted_ballot, accepted_val]
        self.cons: dict[tuple, list] = {}

    # ------------------------------------------------------------------ state
    def _abd_state(self, key: tuple) -> tuple[Tag, Any]:
        return self.abd.setdefault(key, (TAG0, None))

    def _ec_list(self, key: tuple) -> dict[Tag, Any]:
        # initial List = {(t0, Φ_i(v0))}; v0 = None encoded as the sentinel
        return self.ec.setdefault(key, {TAG0: ("", 0)})

    @staticmethod
    def _trim_list(lst: dict[Tag, Any], delta: int) -> None:
        # Alg 5:15-18: trim the *coded value* of the minimum tags while more
        # than δ+1 hold one (the (τ_min, ⊥) placeholders remain).
        full = [t for t, e in lst.items() if e is not None]
        while len(full) > delta + 1:
            tmin = min(full)
            lst[tmin] = None
            full.remove(tmin)

    # ---------------------------------------------------------------- handler
    def handle(self, sender: str, msg: tuple) -> Any:
        op = msg[0]
        # ---- multi-object batch messages (ISSUE 2): one RPC fan-out carries
        # N objects' payloads; each item is handled exactly as its single-
        # object form, so batching changes framing, never semantics.
        if op == "ec-query-batch":
            # ("ec-query-batch", ((obj, client_tag), ...), idx)
            _, items, idx = msg
            return ("ec-list-batch", tuple(
                self.handle(sender, ("ec-query", obj, idx, ctag))[1]
                for obj, ctag in items
            ))
        if op == "ec-put-batch":
            # ("ec-put-batch", ((obj, tag, elem), ...), idx, delta) — elem
            # differs per destination server (its own coded fragment).
            _, items, idx, delta = msg
            for obj, tag, elem in items:
                self.handle(sender, ("ec-put", obj, idx, tag, elem, delta))
            return ("ack", len(items))
        if op == "abd-get-batch":
            # ("abd-get-batch", ((obj, client_tag), ...), idx)
            _, items, idx = msg
            return ("abd-val-batch", tuple(
                self.handle(sender, ("abd-get", obj, idx, ctag))[1:]
                for obj, ctag in items
            ))
        if op == "abd-put-batch":
            _, items, idx = msg
            for obj, tag, val in items:
                self.handle(sender, ("abd-put", obj, idx, tag, val))
            return ("ack", len(items))
        if op == "read-next-batch":
            # ("read-next-batch", ((obj, idx), ...)) — indices may differ per
            # object (objects of one file can sit at different frontiers).
            _, items = msg
            return ("next-c-batch", tuple(
                self.next_c.get((obj, idx)) for obj, idx in items
            ))
        if op == "write-next-batch":
            _, items = msg
            for obj, idx, cfg, status in items:
                self.handle(sender, ("write-next", obj, idx, cfg, status))
            return ("ack", len(items))
        if op == "cons-p1-batch":
            # One Paxos acceptor instance per (obj, idx); the ballot is shared
            # by the batch but promises are tracked per object.
            _, objs, idx, ballot = msg
            return ("p1-batch", tuple(
                self.handle(sender, ("cons-p1", obj, idx, ballot))
                for obj in objs
            ))
        if op == "cons-p2-batch":
            _, items, idx, ballot = msg
            return ("p2-batch", tuple(
                self.handle(sender, ("cons-p2", obj, idx, ballot, value))
                for obj, value in items
            ))
        if op == "margin-batch":
            # ("margin-batch", (obj, ...), idx) — tag-only health snapshot for
            # the reliability probes (ISSUE 3): per object, the ABD tag this
            # server stores (None when it never stored one), the EC List as
            # (tag, holds_element) pairs (None when no List exists), and the
            # status of any announced successor configuration at this index
            # ("P"/"F"/None) so probes can tell historical state from live
            # state. Never ships values/elements: probing N objects costs
            # O(N tags).
            _, objs, idx = msg
            out = []
            for obj in objs:
                ab = self.abd.get((obj, idx))
                lst = self.ec.get((obj, idx))
                nxt = self.next_c.get((obj, idx))
                out.append((
                    ab[0] if ab is not None else None,
                    tuple((t, e is not None) for t, e in lst.items())
                    if lst is not None else None,
                    nxt[1] if nxt is not None else None,
                ))
            return ("margin-batch", tuple(out))
        if op == "abd-get":
            # CoBFS [4] conditional transfer: ship the value only when newer
            # than the client's tag (tag-only reply otherwise).
            _, obj, idx, client_tag = msg
            tag, val = self._abd_state((obj, idx))
            if client_tag is not None and tag <= client_tag:
                return ("abd-val", tag, None)
            return ("abd-val", tag, val)
        if op == "abd-get-tag":
            _, obj, idx = msg
            tag, _ = self._abd_state((obj, idx))
            return ("abd-tag", tag)
        if op == "abd-put":
            _, obj, idx, tag, val = msg
            cur, _ = self._abd_state((obj, idx))
            if tag > cur:
                self.abd[(obj, idx)] = (tag, val)
            return ("ack",)
        if op == "ec-query":
            # Alg 5:4-11. client_tag None => original EC-DAP (full List);
            # otherwise EC-DAPopt filtering: (> tag_b -> with element,
            # == tag_b -> (tag, ⊥), < tag_b -> omitted).
            _, obj, idx, client_tag = msg
            lst = self._ec_list((obj, idx))
            if client_tag is None:
                out = [(t, e) for t, e in lst.items()]
            else:
                out = []
                for t, e in lst.items():
                    if t > client_tag:
                        out.append((t, e))
                    elif t == client_tag:
                        out.append((t, None))
            return ("ec-list", out)
        if op == "ec-put":
            # Alg 5:12-18: insert, then trim the *coded value* of the minimum
            # tag when |List| > δ+1 (the (τ_min, ⊥) placeholder remains).
            _, obj, idx, tag, elem, delta = msg
            lst = self._ec_list((obj, idx))
            lst[tag] = elem
            self._trim_list(lst, delta)
            return ("ack",)
        if op == "ec-repair-pull":
            # Repair scan (beyond-paper, ISSUE 1): full List snapshot — every
            # tag this server knows, with its coded element where one is still
            # held (None = trimmed ⊥ / placeholder). Unlike ec-query this
            # never filters by a client tag: the repair controller needs to
            # see exactly what is missing or stale.
            _, obj, idx = msg
            lst = self._ec_list((obj, idx))
            return ("ec-repair-list", [(t, e) for t, e in lst.items()])
        if op == "ec-repair-push":
            # Monotone repair insert: only ADDS a coded element for a tag this
            # server has never seen. It never resurrects a trimmed (tag, ⊥)
            # placeholder (the server already moved past that tag), and
            # re-applies the δ+1 trim so the List bound holds. The one
            # overwrite allowed (ISSUE 6) is an element whose bytes FAIL
            # their own stored checksum — bit-rot on this server; the pushed
            # replacement is the bit-identical coded row the writer would
            # have stored (MDS determinism), so healing is a pure restore.
            # A racing ec-put therefore can never be regressed by repair
            # traffic: newer tags stay, and a pushed tag older than the trim
            # window is trimmed right back out.
            _, obj, idx, tag, elem, delta = msg
            lst = self._ec_list((obj, idx))
            applied = False
            if tag not in lst:
                lst[tag] = elem
                applied = True
                self._trim_list(lst, delta)
            elif lst[tag] is not None and not element_crc_ok(lst[tag]):
                lst[tag] = elem
                applied = True
            return ("repair-ack", applied)
        if op == "read-next":
            _, obj, idx = msg
            return ("next-c", self.next_c.get((obj, idx)))
        if op == "write-next":
            # F overrides P; P never demotes F. Config value is unique per
            # index (consensus), so overwriting the config is idempotent.
            _, obj, idx, cfg, status = msg
            cur = self.next_c.get((obj, idx))
            if cur is None or (cur[1] == "P" and status == "F") or status == "F":
                self.next_c[(obj, idx)] = (cfg, status)
            return ("ack",)
        if op == "cons-p1":
            _, obj, idx, ballot = msg
            st = self.cons.setdefault((obj, idx), [None, None, None])
            if st[0] is None or ballot > st[0]:
                st[0] = ballot
                return ("p1-ok", st[1], st[2])
            return ("p1-nack", st[0])
        if op == "cons-p2":
            _, obj, idx, ballot, value = msg
            st = self.cons.setdefault((obj, idx), [None, None, None])
            if st[0] is None or ballot >= st[0]:
                st[0] = ballot
                st[1] = ballot
                st[2] = value
                return ("p2-ok",)
            return ("p2-nack", st[0])
        raise ValueError(f"unknown message {op!r}")
