"""Replica server: ABD store, EC fragment Lists (Alg 5), nextC, consensus.

One server object hosts state for *every* (object, configuration-index) pair —
exactly the paper's model where a physical server participates in many
configurations and stores many blocks. State is created lazily with the
initial value ``(t0, v0 = None)`` / ``{(t0, Φ_i(v0))}``.

Message dispatch is a single dict lookup (ISSUE 7): ``handle`` used to scan a
~28-branch if/elif chain per message, a per-message cost that dominated at
10^5-session scale. Each op is a method; the ``_DISPATCH`` table maps the op
tag to it. Batch envelopes call the single-object methods directly — batching
still changes framing, never semantics.

Read-only requests (queries, gets, next-c reads, margin probes) are answered
from a per-server reply cache keyed on the request tuple itself, invalidated
whenever the state they read mutates. A zipfian read-heavy fleet asks every
server the same hot questions over and over; returning the *same reply
object* makes those answers identity-stable, which is what lets the
network's ``SizingMemo`` frame a repeated ec-list/tag-set reply once instead
of walking it per message (ISSUE 7). Values are unchanged — a cache hit is
byte-identical to recomputing — so fast/legacy traces are unaffected.
"""
from __future__ import annotations

from typing import Any

from repro.net.sim import Server
from repro.core.tags import TAG0, Tag
from repro.erasure.rs import element_crc_ok


class _ObjState(dict):
    """Per-object mutable state that invalidates the owning server's cached
    read replies on ANY write — including direct fault injection from tests
    and benchmarks that bypass ``handle`` (deleting a fragment to simulate
    loss must evict the cached ec-list that still advertises it). Reads are
    plain ``dict`` reads (no override), so the hot path pays nothing."""

    __slots__ = ("_inval", "_obj")

    def __init__(self, inval, obj, *args):
        super().__init__(*args)
        self._inval = inval
        self._obj = obj

    def __setitem__(self, k, v):
        self._inval(self._obj)
        dict.__setitem__(self, k, v)

    def __delitem__(self, k):
        self._inval(self._obj)
        dict.__delitem__(self, k)

    def pop(self, *args):
        self._inval(self._obj)
        return dict.pop(self, *args)

    def popitem(self):
        self._inval(self._obj)
        return dict.popitem(self)

    def clear(self):
        self._inval(self._obj)
        dict.clear(self)

    def update(self, *args, **kw):
        self._inval(self._obj)
        dict.update(self, *args, **kw)

    def setdefault(self, k, default=None):
        self._inval(self._obj)
        return dict.setdefault(self, k, default)


class _StateMap(dict):
    """``(obj, idx) -> state`` map with the same write-invalidation contract
    as :class:`_ObjState`; plain-dict values assigned in are wrapped so
    their own later mutations keep invalidating."""

    __slots__ = ("_inval",)

    def __init__(self, inval):
        super().__init__()
        self._inval = inval

    def __setitem__(self, key, value):
        self._inval(key[0])
        if type(value) is dict:
            value = _ObjState(self._inval, key[0], value)
        dict.__setitem__(self, key, value)

    def __delitem__(self, key):
        self._inval(key[0])
        dict.__delitem__(self, key)

    def pop(self, key, *default):
        self._inval(key[0])
        return dict.pop(self, key, *default)

    def clear(self):
        for key in self:
            self._inval(key[0])
        dict.clear(self)

    def update(self, *args, **kw):
        for key, value in dict(*args, **kw).items():
            self[key] = value

    def setdefault(self, key, default=None):
        if key not in self:
            self[key] = default
        return dict.__getitem__(self, key)


class StorageServer(Server):
    # Runtime-sanitizer hook (repro.analysis.sanitizer): when set, every
    # per-object invalidation that fires OUTSIDE ``handle`` — i.e. direct
    # state surgery by tests/fault injection through the tracked maps — is
    # reported as ``_mut_observer(sid, obj)`` so the sanitizer can drop its
    # high-water marks for that (server, object) instead of flagging the
    # injected loss as a protocol bug. None (the default) costs one
    # attribute read per handle() call and nothing per mutation.
    _mut_observer = None
    # Happens-before race-tracker hook (repro.analysis.races): when set,
    # EVERY per-object invalidation is reported as
    # ``_race_observer(sid, obj, in_handle)`` — in-handle mutations are the
    # writes the vector-clock tracker orders and checks; out-of-handle ones
    # are external surgery it forgives (mirroring ``_mut_observer``).
    _race_observer = None
    _in_handle = False

    def __init__(self, sid: str):
        super().__init__(sid)
        # ABD-DAP: (obj, cfg_idx) -> (tag, value)
        self.abd: dict[tuple, tuple[Tag, Any]] = _StateMap(self._invalidate)
        # EC-DAP: (obj, cfg_idx) -> {tag: element | None}; None = trimmed ⊥
        self.ec: dict[tuple, dict[Tag, Any]] = _StateMap(self._invalidate)
        # reconfiguration: (obj, cfg_idx) -> (config, status)
        self.next_c: dict[tuple, tuple[Any, str]] = _StateMap(self._invalidate)
        # consensus acceptor: (obj, cfg_idx) -> [promised, accepted_ballot, accepted_val]
        self.cons: dict[tuple, list] = {}
        # read-reply cache: request tuple -> reply object, with a per-object
        # key index so a mutation of one object only evicts the cached
        # answers that mention it (see module docstring).
        self._rcache: dict[tuple, Any] = {}
        self._rkeys: dict[Any, list[tuple]] = {}

    def on_recover(self) -> None:
        # Crash-recovery (ISSUE 10): the reply/identity cache is volatile —
        # it memoizes answers computed BEFORE the crash and must not survive
        # it (a wiped-then-restored replica serving a stale cached reply is
        # exactly the gray failure the satellite regression test pins).
        # Durable protocol state (abd/ec/next_c/cons) stays. In-place
        # ``clear()`` — rebinding the maps would bypass _StateMap tracking.
        self._rcache.clear()
        self._rkeys.clear()

    def _invalidate(self, obj: Any) -> None:
        keys = self._rkeys.pop(obj, None)
        if keys:
            cache = self._rcache
            for k in keys:
                cache.pop(k, None)
        obs = self._mut_observer
        if obs is not None and not self._in_handle:
            obs(self.sid, obj)
        robs = self._race_observer
        if robs is not None:
            robs(self.sid, obj, self._in_handle)

    # ------------------------------------------------------------------ state
    def _abd_state(self, key: tuple) -> tuple[Tag, Any]:
        if key not in self.abd:
            # lazy state creation is a mutation margin probes can observe;
            # the tracked map invalidates cached replies on the write
            self.abd[key] = (TAG0, None)
        return self.abd[key]

    def _ec_list(self, key: tuple) -> dict[Tag, Any]:
        # initial List = {(t0, Φ_i(v0))}; v0 = None encoded as the sentinel
        if key not in self.ec:
            self.ec[key] = {TAG0: ("", 0)}
        return self.ec[key]

    @staticmethod
    def _trim_list(lst: dict[Tag, Any], delta: int) -> None:
        # Alg 5:15-18: trim the *coded value* of the minimum tags while more
        # than δ+1 hold one (the (τ_min, ⊥) placeholders remain).
        full = [t for t, e in lst.items() if e is not None]
        while len(full) > delta + 1:
            tmin = min(full)
            lst[tmin] = None
            full.remove(tmin)

    # ---------------------------------------------------------------- handler
    def handle(self, sender: str, msg: tuple) -> Any:
        if self._mut_observer is None and self._race_observer is None:
            return self._handle(sender, msg)
        # sanitized/race-checked run: protocol-driven mutations inside the
        # handler must NOT be reported as external surgery
        self._in_handle = True
        try:
            return self._handle(sender, msg)
        finally:
            self._in_handle = False

    def _handle(self, sender: str, msg: tuple) -> Any:
        op = msg[0]
        objs = self._READ_ONLY.get(op)
        if objs is not None:
            try:
                reply = self._rcache.get(msg)
            except TypeError:  # unhashable payload: answer uncached
                return self._DISPATCH[op](self, sender, msg)
            if reply is not None:
                return reply
            reply = self._DISPATCH[op](self, sender, msg)
            if len(self._rcache) >= 4096:
                self._rcache.clear()
                self._rkeys.clear()
            self._rcache[msg] = reply
            rkeys = self._rkeys
            for o in objs(msg):
                rkeys.setdefault(o, []).append(msg)
            return reply
        fn = self._DISPATCH.get(op)
        if fn is None:
            raise ValueError(f"unknown message {op!r}")
        return fn(self, sender, msg)

    # ---- multi-object batch messages (ISSUE 2): one RPC fan-out carries
    # N objects' payloads; each item is handled exactly as its single-
    # object form, so batching changes framing, never semantics.
    def _h_ec_query_batch(self, sender: str, msg: tuple) -> Any:
        # ("ec-query-batch", ((obj, client_tag), ...), idx)
        _, items, idx = msg
        return ("ec-list-batch", tuple(
            self._h_ec_query(sender, ("ec-query", obj, idx, ctag))[1]
            for obj, ctag in items
        ))

    def _h_ec_put_batch(self, sender: str, msg: tuple) -> Any:
        # ("ec-put-batch", ((obj, tag, elem), ...), idx, delta) — elem
        # differs per destination server (its own coded fragment).
        _, items, idx, delta = msg
        for obj, tag, elem in items:
            self._h_ec_put(sender, ("ec-put", obj, idx, tag, elem, delta))
        return ("ack", len(items))

    def _h_abd_get_batch(self, sender: str, msg: tuple) -> Any:
        # ("abd-get-batch", ((obj, client_tag), ...), idx)
        _, items, idx = msg
        return ("abd-val-batch", tuple(
            self._h_abd_get(sender, ("abd-get", obj, idx, ctag))[1:]
            for obj, ctag in items
        ))

    def _h_abd_put_batch(self, sender: str, msg: tuple) -> Any:
        _, items, idx = msg
        for obj, tag, val in items:
            self._h_abd_put(sender, ("abd-put", obj, idx, tag, val))
        return ("ack", len(items))

    def _h_read_next_batch(self, sender: str, msg: tuple) -> Any:
        # ("read-next-batch", ((obj, idx), ...)) — indices may differ per
        # object (objects of one file can sit at different frontiers).
        _, items = msg
        return ("next-c-batch", tuple(
            self.next_c.get((obj, idx)) for obj, idx in items
        ))

    def _h_write_next_batch(self, sender: str, msg: tuple) -> Any:
        _, items = msg
        for obj, idx, cfg, status in items:
            self._h_write_next(sender, ("write-next", obj, idx, cfg, status))
        return ("ack", len(items))

    def _h_cons_p1_batch(self, sender: str, msg: tuple) -> Any:
        # One Paxos acceptor instance per (obj, idx); the ballot is shared
        # by the batch but promises are tracked per object.
        _, objs, idx, ballot = msg
        return ("p1-batch", tuple(
            self._h_cons_p1(sender, ("cons-p1", obj, idx, ballot))
            for obj in objs
        ))

    def _h_cons_p2_batch(self, sender: str, msg: tuple) -> Any:
        _, items, idx, ballot = msg
        return ("p2-batch", tuple(
            self._h_cons_p2(sender, ("cons-p2", obj, idx, ballot, value))
            for obj, value in items
        ))

    def _h_margin_batch(self, sender: str, msg: tuple) -> Any:
        # ("margin-batch", (obj, ...), idx) — tag-only health snapshot for
        # the reliability probes (ISSUE 3): per object, the ABD tag this
        # server stores (None when it never stored one), the EC List as
        # (tag, holds_element) pairs (None when no List exists), and the
        # status of any announced successor configuration at this index
        # ("P"/"F"/None) so probes can tell historical state from live
        # state. Never ships values/elements: probing N objects costs
        # O(N tags).
        _, objs, idx = msg
        out = []
        for obj in objs:
            ab = self.abd.get((obj, idx))
            lst = self.ec.get((obj, idx))
            nxt = self.next_c.get((obj, idx))
            out.append((
                ab[0] if ab is not None else None,
                tuple((t, e is not None) for t, e in lst.items())
                if lst is not None else None,
                nxt[1] if nxt is not None else None,
            ))
        return ("margin-batch", tuple(out))

    # ---- single-object messages
    def _h_abd_get(self, sender: str, msg: tuple) -> Any:
        # CoBFS [4] conditional transfer: ship the value only when newer
        # than the client's tag (tag-only reply otherwise).
        _, obj, idx, client_tag = msg
        tag, val = self._abd_state((obj, idx))
        if client_tag is not None and tag <= client_tag:
            return ("abd-val", tag, None)
        return ("abd-val", tag, val)

    def _h_abd_get_tag(self, sender: str, msg: tuple) -> Any:
        _, obj, idx = msg
        tag, _ = self._abd_state((obj, idx))
        return ("abd-tag", tag)

    def _h_abd_put(self, sender: str, msg: tuple) -> Any:
        _, obj, idx, tag, val = msg
        cur, _ = self._abd_state((obj, idx))
        if tag > cur:
            self.abd[(obj, idx)] = (tag, val)
        return ("ack",)

    def _h_ec_query(self, sender: str, msg: tuple) -> Any:
        # Alg 5:4-11. client_tag None => original EC-DAP (full List);
        # otherwise EC-DAPopt filtering: (> tag_b -> with element,
        # == tag_b -> (tag, ⊥), < tag_b -> omitted).
        _, obj, idx, client_tag = msg
        lst = self._ec_list((obj, idx))
        if client_tag is None:
            out = tuple(lst.items())
        else:
            acc = []
            for t, e in lst.items():
                if t > client_tag:
                    acc.append((t, e))
                elif t == client_tag:
                    acc.append((t, None))
            out = tuple(acc)
        return ("ec-list", out)

    def _h_ec_put(self, sender: str, msg: tuple) -> Any:
        # Alg 5:12-18: insert, then trim the *coded value* of the minimum
        # tag when |List| > δ+1 (the (τ_min, ⊥) placeholder remains).
        _, obj, idx, tag, elem, delta = msg
        lst = self._ec_list((obj, idx))
        lst[tag] = elem
        self._trim_list(lst, delta)
        return ("ack",)

    def _h_ec_repair_pull(self, sender: str, msg: tuple) -> Any:
        # Repair scan (beyond-paper, ISSUE 1): full List snapshot — every
        # tag this server knows, with its coded element where one is still
        # held (None = trimmed ⊥ / placeholder). Unlike ec-query this
        # never filters by a client tag: the repair controller needs to
        # see exactly what is missing or stale.
        _, obj, idx = msg
        lst = self._ec_list((obj, idx))
        return ("ec-repair-list", [(t, e) for t, e in lst.items()])

    def _h_ec_repair_push(self, sender: str, msg: tuple) -> Any:
        # Monotone repair insert: only ADDS a coded element for a tag this
        # server has never seen. It never resurrects a trimmed (tag, ⊥)
        # placeholder (the server already moved past that tag), and
        # re-applies the δ+1 trim so the List bound holds. The one
        # overwrite allowed (ISSUE 6) is an element whose bytes FAIL
        # their own stored checksum — bit-rot on this server; the pushed
        # replacement is the bit-identical coded row the writer would
        # have stored (MDS determinism), so healing is a pure restore.
        # A racing ec-put therefore can never be regressed by repair
        # traffic: newer tags stay, and a pushed tag older than the trim
        # window is trimmed right back out.
        _, obj, idx, tag, elem, delta = msg
        lst = self._ec_list((obj, idx))
        applied = False
        if tag not in lst:
            lst[tag] = elem
            applied = True
            self._trim_list(lst, delta)
        elif lst[tag] is not None and not element_crc_ok(lst[tag]):
            lst[tag] = elem
            applied = True
        return ("repair-ack", applied)

    def _h_read_next(self, sender: str, msg: tuple) -> Any:
        _, obj, idx = msg
        return ("next-c", self.next_c.get((obj, idx)))

    def _h_write_next(self, sender: str, msg: tuple) -> Any:
        # F overrides P; P never demotes F. Config value is unique per
        # index (consensus), so overwriting the config is idempotent.
        _, obj, idx, cfg, status = msg
        cur = self.next_c.get((obj, idx))
        if cur is None or (cur[1] == "P" and status == "F") or status == "F":
            self.next_c[(obj, idx)] = (cfg, status)
        return ("ack",)

    def _h_cons_p1(self, sender: str, msg: tuple) -> Any:
        _, obj, idx, ballot = msg
        st = self.cons.setdefault((obj, idx), [None, None, None])
        if st[0] is None or ballot > st[0]:
            st[0] = ballot
            return ("p1-ok", st[1], st[2])
        return ("p1-nack", st[0])

    def _h_cons_p2(self, sender: str, msg: tuple) -> Any:
        _, obj, idx, ballot, value = msg
        st = self.cons.setdefault((obj, idx), [None, None, None])
        if st[0] is None or ballot >= st[0]:
            st[0] = ballot
            st[1] = ballot
            st[2] = value
            return ("p2-ok",)
        return ("p2-nack", st[0])

    # requests answerable from the reply cache: they read server state but
    # never change it (lazy state creation inside counts as a mutation and
    # evicts through _invalidate, like every real mutation). Each entry maps
    # the op tag to an extractor of the object names the request reads, so
    # cached answers are indexed — and evicted — per object.
    _READ_ONLY = {
        "ec-query-batch": lambda m: (o for o, _t in m[1]),
        "abd-get-batch": lambda m: (o for o, _t in m[1]),
        "read-next-batch": lambda m: (o for o, _i in m[1]),
        "margin-batch": lambda m: m[1],
        "ec-query": lambda m: (m[1],),
        "abd-get": lambda m: (m[1],),
        "abd-get-tag": lambda m: (m[1],),
        "read-next": lambda m: (m[1],),
        "ec-repair-pull": lambda m: (m[1],),
    }

    _DISPATCH = {
        "ec-query-batch": _h_ec_query_batch,
        "ec-put-batch": _h_ec_put_batch,
        "abd-get-batch": _h_abd_get_batch,
        "abd-put-batch": _h_abd_put_batch,
        "read-next-batch": _h_read_next_batch,
        "write-next-batch": _h_write_next_batch,
        "cons-p1-batch": _h_cons_p1_batch,
        "cons-p2-batch": _h_cons_p2_batch,
        "margin-batch": _h_margin_batch,
        "abd-get": _h_abd_get,
        "abd-get-tag": _h_abd_get_tag,
        "abd-put": _h_abd_put,
        "ec-query": _h_ec_query,
        "ec-put": _h_ec_put,
        "ec-repair-pull": _h_ec_repair_pull,
        "ec-repair-push": _h_ec_repair_push,
        "read-next": _h_read_next,
        "write-next": _h_write_next,
        "cons-p1": _h_cons_p1,
        "cons-p2": _h_cons_p2,
    }
