"""DSS facade — builds the six evaluated algorithms (§VII-A) on the sim.

    CoABD        static, ABD replication, whole-object
    CoABDF       static, ABD replication, fragmented
    CoARESABD    ARES (reconfigurable), ABD-DAP, whole-object
    CoARESABDF   ARES, ABD-DAP, fragmented
    CoARESEC     ARES, EC-DAPopt, whole-object
    CoARESECF    ARES, EC-DAPopt, fragmented
  (+ *-noopt variants running the original EC-DAP, for the §VI comparison)
"""
from __future__ import annotations

import itertools
import os
from dataclasses import dataclass, field as dc_field
from typing import Generator

from repro.core.coares import CoAresClient, StaticCoverableClient
from repro.core.fragment import FragmentationModule
from repro.core.server import StorageServer
from repro.core.tags import Config
from repro.erasure.rs import BACKENDS as CODING_BACKENDS
from repro.net.sim import LatencyModel, Network, RetryPolicy

ALGORITHMS = {
    # name: (reconfigurable, dap, fragmented)
    "coabd": (False, "abd", False),
    "coabdf": (False, "abd", True),
    "coaresabd": (True, "abd", False),
    "coaresabdf": (True, "abd", True),
    "coaresec": (True, "ec_opt", False),
    "coaresecf": (True, "ec_opt", True),
    "coaresec-noopt": (True, "ec", False),
    "coaresecf-noopt": (True, "ec", True),
}


@dataclass
class DSSParams:
    algorithm: str = "coaresecf"
    n_servers: int = 6
    parity_m: int = 1          # m = n - k (EC); ignored for ABD
    delta: int = 8             # δ: max concurrent writers (EC List bound)
    seed: int = 0
    min_block: int = 512
    avg_block: int = 1024
    max_block: int = 4096
    indexed: bool = False  # beyond-paper: genesis holds the block index -> parallel block I/O
    # ISSUE 2 — unified state-transfer engine knobs:
    batched: bool = True       # multi-object batch RPCs on the indexed FM path
    recon_repair: bool = True  # recon finalization spawns repair of the new config
    recon_repair_delay: float = 0.0
    # ISSUE 6 — GF(256) coding backend for every EC code this store builds
    # (EcDap, repair, recon state transfer): "numpy" (byte-LUT), "kernel"
    # (Pallas on TPU / jit'd XLA on CPU), or "auto" (size-based dispatch at
    # the measured crossover). See repro.erasure.rs.
    coding_backend: str = "auto"
    # ISSUE 7 — vectorised one-event-per-fan-out network engine (trace-
    # identical to the per-destination legacy path; False = ablation).
    fast_net: bool = True
    # ISSUE 8 — runtime protocol sanitizer (repro.analysis.sanitizer): live
    # quorum-intersection + per-server tag-monotonicity + wire-vocabulary
    # checks on every fan-out/reply. Also enabled by REPRO_SANITIZE=1 in the
    # environment (how CI runs a sanitized tier-1 pass). Pure observer —
    # sanitized traces are bit-identical to unsanitized ones.
    sanitize: bool = False
    # ISSUE 9 — vector-clock happens-before race tracker
    # (repro.analysis.races): orders every in-handle mutation of per-object
    # server state against the issuing operations' vector clocks and fails
    # the run on a conflicting unordered regression. Also enabled by
    # REPRO_RACECHECK=1. Pure observer like the sanitizer.
    racecheck: bool = False
    # ISSUE 10 — failure-survival layer: per-RPC deadlines with retransmit /
    # backoff / optional hedging at the network tier, plus phase-level retry
    # in the protocol tier surfacing QuorumUnavailableError when the budget
    # is exhausted. None (default) disables it all — traces bit-identical to
    # a build without the feature (the ablation the acceptance criteria pin).
    retry: RetryPolicy | None = None
    latency: LatencyModel = dc_field(default_factory=LatencyModel)


class ClientHandle:
    """Uniform client API over all algorithm variants (generator methods)."""

    def __init__(self, dss: "DSS", cid: str):
        self.dss = dss
        self.cid = cid
        reconf, dap, frag = ALGORITHMS[dss.params.algorithm]
        if reconf:
            self.dsm = CoAresClient(
                dss.net, cid, dss.c0, history=dss.history,
                repair_on_recon=dss.params.recon_repair,
                recon_repair_delay=dss.params.recon_repair_delay,
                on_recon=dss._notify_recon,
            )
        else:
            self.dsm = StaticCoverableClient(dss.net, cid, dss.c0, history=dss.history)
        self.fragmented = frag
        self.fm = (
            FragmentationModule(
                dss.net, self.dsm,
                min_block=dss.params.min_block,
                avg_block=dss.params.avg_block,
                max_block=dss.params.max_block,
                history=dss.history,
                indexed=dss.params.indexed,
                batched=dss.params.batched,
            )
            if frag
            else None
        )

    # --- uniform generator ops ------------------------------------------------
    @staticmethod
    def _whole_stats(tag, flag) -> dict:
        # a chg write whose new version is 1 created the object — the
        # gathered tag was TAG0, i.e. nothing was ever written before
        # (fixes the hardwired ``created: 0`` of the non-fragmented path).
        return {"written": int(flag == "chg"), "collided": int(flag != "chg"),
                "created": int(flag == "chg" and tag[0] == 1),
                "blocks": 1, "chunks": 1, "success": flag == "chg"}

    def update(self, fid: str, content: bytes) -> Generator:
        if self.fm is not None:
            return (yield from self.fm.fm_update(fid, content))
        (tag, _v), flag = yield from self.dsm.cvr_write(fid, content)
        self.dsm.version[fid] = tag
        return self._whole_stats(tag, flag)

    def read(self, fid: str) -> Generator:
        if self.fm is not None:
            content, _blocks = yield from self.fm.fm_read(fid)
            return content
        tag, val = yield from self.dsm.cvr_read(fid)
        self.dsm.version[fid] = tag
        return val if val is not None else b""

    def recon(self, fid: str, new_config: Config) -> Generator:
        if self.fm is not None:
            return (yield from self.fm.fm_reconfig(fid, new_config))
        yield from self.dsm.recon(fid, new_config)
        return 1

    # --- multi-FILE batch ops (ISSUE 3) ---------------------------------------
    # The Session scheduler lands coalesced same-kind operations here; each
    # returns a per-fid dict and rides the engine's multi-object batch RPCs,
    # so an F-file fan-out costs O(1) quorum rounds (see ``repro.core.api``).
    def read_batch(self, fids) -> Generator:
        """``{fid: (content, n_blocks)}`` for many files in one batched pass."""
        fids = list(dict.fromkeys(fids))
        if self.fm is not None:
            res = yield from self.fm.fm_read_batch(fids)
            return {f: (content, len(blocks)) for f, (content, blocks) in res.items()}
        res = yield from self.dsm.cvr_read_batch(fids)
        out = {}
        for fid in fids:
            tag, val = res[fid]
            self.dsm.version[fid] = tag
            out[fid] = (val if val is not None else b"", 1)
        return out

    def update_batch(self, updates) -> Generator:
        """``{fid: stats}`` for many files written in one batched pass."""
        if self.fm is not None:
            return (yield from self.fm.fm_update_batch(dict(updates)))
        results = yield from self.dsm.cvr_write_batch(dict(updates))
        out = {}
        for fid, ((tag, _v), flag) in results.items():
            self.dsm.version[fid] = tag
            out[fid] = self._whole_stats(tag, flag)
        return out

    def recon_batch(self, fids, new_config: Config) -> Generator:
        """``{fid: n_blocks_moved}`` — many files to one new configuration."""
        fids = list(dict.fromkeys(fids))
        if self.fm is not None:
            return (yield from self.fm.fm_reconfig_batch(fids, new_config))
        yield from self.dsm.recon_batch(fids, new_config)
        return {f: 1 for f in fids}

    # --- reliability stat (ISSUE 3, à la D-Rex) --------------------------------
    def stat_batch(self, fids) -> Generator:
        """Surviving-fragment margin per file: ``{fid: stat}`` where ``stat``
        has ``margin`` (min over the file's genesis + data blocks; how many
        more server losses the newest version of the weakest block survives),
        ``blocks``, ``config``, ``tag`` (genesis) and ``worst`` (the weakest
        object). Costs one batched genesis read + one tag-only probe fan-out
        per distinct configuration — no data moves."""
        from repro.core.fragment import genesis_id
        from repro.core.repair import probe_health

        fids = list(dict.fromkeys(fids))
        if not fids:
            return {}
        # objects of each file: the fid itself (whole-object algorithms) or
        # genesis + indexed data blocks (fragmented ones; legacy files
        # without an index report the genesis margin only).
        objs_of: dict[str, list[str]] = {}
        if self.fm is not None:
            gids = [genesis_id(f) for f in fids]
            gres = yield from self.dsm.cvr_read_batch(gids)
            from repro.core.fragment import decode_block_value, parse_genesis_meta

            for fid, g in zip(fids, gids):
                tag, raw = gres[g]
                self.dsm.version[g] = tag
                _ptr, meta = decode_block_value(raw)
                index = parse_genesis_meta(meta)
                objs_of[fid] = [g] + list(index or ())
        else:
            objs_of = {f: [f] for f in fids}
        all_objs = [o for objs in objs_of.values() for o in objs]
        # locate each object's current configuration: the latest finalized
        # entry of its sequence (static algorithms have one fixed config).
        read_cfg = getattr(self.dsm, "read_config_batch", None)
        placement: dict[tuple[str, int], tuple[Config, list[str]]] = {}
        if read_cfg is not None:
            cseqs = yield from read_cfg(all_objs)
            for o in all_objs:
                cseq = cseqs[o]
                idx = max(j for j, e in enumerate(cseq) if e.status == "F")
                cfg = cseq[idx].config
                placement.setdefault((cfg.cfg_id, idx), (cfg, []))[1].append(o)
        else:
            placement[(self.dsm.config.cfg_id, 0)] = (self.dsm.config, all_objs)
        health = {}
        cfg_of: dict[str, str] = {}
        for (cid, idx), (cfg, objs) in placement.items():
            health.update((yield from probe_health(cfg, idx, objs)))
            for o in objs:
                cfg_of[o] = cid
        out = {}
        for fid in fids:
            objs = objs_of[fid]
            worst = min(objs, key=lambda o: health[o].margin)
            out[fid] = {
                "margin": health[worst].margin,
                "worst": worst,
                "blocks": max(0, len(objs) - 1) if self.fm is not None else 1,
                "config": cfg_of[worst],
                "tag": health[objs[0]].tag,
                # data was written but some block no longer reaches k live
                # holders — the file cannot currently be read back in full
                "unreadable": any(health[o].unreadable for o in objs),
                "per_object": {o: health[o] for o in objs},
            }
        return out

    def stat(self, fid: str) -> Generator:
        res = yield from self.stat_batch((fid,))
        return res[fid]


class DSS:
    """One deployed storage service instance."""

    def __init__(self, params: DSSParams | None = None, **kw):
        self.params = params or DSSParams(**kw)
        p = self.params
        if p.algorithm not in ALGORITHMS:
            raise ValueError(f"unknown algorithm {p.algorithm!r}")
        if p.coding_backend not in CODING_BACKENDS:
            raise ValueError(
                f"unknown coding backend {p.coding_backend!r}; "
                f"expected one of {CODING_BACKENDS}"
            )
        self.net = Network(seed=p.seed, latency=p.latency, fast=p.fast_net)
        # ambient store-wide coding backend: every RSCode built against this
        # network (DAPs, repair controllers/daemons, recon transfers) reads it
        self.net.coding_backend = p.coding_backend
        self.net.retry = p.retry
        self.history: list = []
        sids = tuple(f"s{i}" for i in range(p.n_servers))
        for s in sids:
            self.net.add_server(StorageServer(s))
        _, dap, _ = ALGORITHMS[p.algorithm]
        k = max(1, p.n_servers - p.parity_m) if dap in ("ec", "ec_opt") else 1
        self.c0 = Config("c0", sids, dap=dap, k=k, delta=p.delta)
        self._cfg_counter = itertools.count(1)
        self._extra_servers = itertools.count(p.n_servers)
        # recon-finalization subscribers ``(config, cfg_idx, objs) -> None``
        # (e.g. the auto-retargeting RepairDaemon); every CoAresClient this
        # store hands out notifies them via ``_notify_recon``.
        self._recon_subs: list = []
        if p.sanitize or os.environ.get("REPRO_SANITIZE") == "1":
            from repro.analysis.sanitizer import ProtocolSanitizer

            san = ProtocolSanitizer().attach(self.net)
            san.register_config(self.c0)
            # decided recon targets keep the EC-quorum registry complete
            self._recon_subs.append(
                lambda cfg, idx, objs: san.register_config(cfg)
            )
        if p.racecheck or os.environ.get("REPRO_RACECHECK") == "1":
            from repro.analysis.races import RaceTracker

            RaceTracker().attach(self.net)

    def _notify_recon(self, config: Config, cfg_idx: int, objs) -> None:
        for sub in list(self._recon_subs):
            sub(config, cfg_idx, objs)

    # --- clients ---------------------------------------------------------------
    def client(self, cid: str) -> ClientHandle:
        """Build the LEGACY generator-op client handle. Application code
        should prefer ``session(cid)`` — the Session/future API coalesces
        concurrent operations across files and reports uniform OpStats; this
        handle remains as the deprecation shim (and as the engine the
        Session drives underneath)."""
        return ClientHandle(self, cid)

    def session(self, cid: str, **kw) -> "Session":
        """Open a :class:`repro.core.api.Session` for client ``cid`` — the
        submit/future client API (ISSUE 3). Keyword args (e.g. ``window``,
        ``via=gateway``) pass through to the Session constructor."""
        from repro.core.api import Session

        return Session(self, cid, **kw)

    def gateway(self, gid: str = "gw", **kw) -> "Gateway":
        """Build a cross-client aggregation gateway (ISSUE 4): sessions
        opened with ``dss.session(cid, via=gw)`` (or ``gw.session(cid)``)
        have their ops merged with other attached clients' into shared
        quorum rounds, and registered RepairDaemons receive config coverage
        via the gateway's gossip loop. Keyword args (``window``,
        ``gossip_period``) pass through to the Gateway constructor."""
        from repro.core.gateway import Gateway

        return Gateway(self, gid, **kw)

    # --- config construction (recon targets) -----------------------------------
    def make_config(
        self,
        dap: str | None = None,
        n_servers: int | None = None,
        parity_m: int | None = None,
        fresh_servers: bool = False,
    ) -> Config:
        """Build a recon target: switch DAP and/or change the server set
        (the paper's §VII-E scenarios)."""
        p = self.params
        dap = dap or self.c0.dap
        n = n_servers or p.n_servers
        if fresh_servers:
            sids = []
            for _ in range(n):
                s = f"s{next(self._extra_servers)}"
                self.net.add_server(StorageServer(s))
                sids.append(s)
            sids = tuple(sids)
        else:
            # only STORAGE servers are recon targets — the network may also
            # host gossip-listener endpoints (gateway tier) whose ids don't
            # follow the ``sN`` scheme and which store no replica state.
            have = sorted(
                (s for s, srv in self.net.servers.items()
                 if isinstance(srv, StorageServer)),
                key=lambda s: int(s[1:]),
            )
            while len(have) < n:
                s = f"s{next(self._extra_servers)}"
                self.net.add_server(StorageServer(s))
                have.append(s)
            sids = tuple(have[:n])
        m = parity_m if parity_m is not None else p.parity_m
        k = max(1, n - m) if dap in ("ec", "ec_opt") else 1
        cfg = Config(f"c{next(self._cfg_counter)}", sids, dap=dap, k=k, delta=p.delta)
        if self.net.sanitizer is not None:
            self.net.sanitizer.register_config(cfg)
        return cfg

    # --- crash injection ---------------------------------------------------------
    def crash_servers(self, ids: list[str]) -> None:
        for s in ids:
            self.net.crash(s)

    def recover_servers(self, ids: list[str], wipe: bool = True) -> None:
        """Crash-recovery: the server rejoins with whatever durable List
        state it had when it crashed — i.e. stale; run ``repair`` to restore
        redundancy. ``wipe=True`` (ISSUE 10) also clears volatile state —
        the per-server reply/identity cache — so a recovered replica never
        serves an answer memoized before the crash; ``wipe=False`` keeps the
        legacy flag-flip behavior."""
        for s in ids:
            self.net.recover(s, wipe=wipe)

    def wipe_servers(self, ids: list[str]) -> None:
        """Disk-loss recovery: drop all EC fragment state (the ABD register
        and config state survive — the interesting loss is the coded rows)."""
        for s in ids:
            self.net.servers[s].ec.clear()

    # --- repair -----------------------------------------------------------------
    def ec_objects(self, cfg_idx: int = 0) -> list[str]:
        """Names of every object holding EC state at configuration ``cfg_idx``
        (for fragmented algorithms these are the genesis + data blocks)."""
        objs: set[str] = set()
        for srv in self.net.servers.values():
            for obj, idx in getattr(srv, "ec", {}):
                if idx == cfg_idx:
                    objs.add(obj)
        return sorted(objs)

    def repair(self, objs=None, config: Config | None = None, cfg_idx: int = 0,
               client_id: str = "repair") -> list[dict]:
        """Run a full repair pass to quiescence and return per-object stats.
        Defaults to every EC object of the initial configuration; pass
        ``config``/``cfg_idx`` after a reconfiguration."""
        from repro.core.repair import RepairController

        cfg = config or self.c0
        rc = RepairController(
            self.net, cfg, cfg_idx, client_id=client_id, history=self.history
        )
        todo = self.ec_objects(cfg_idx) if objs is None else list(objs)
        return self.net.run_op(
            rc.scan_and_repair(todo), kind="repair-pass", client=client_id
        )

    def start_repair_daemon(
        self,
        *,
        config: Config | None = None,
        cfg_idx: int = 0,
        period: float = 0.05,
        objs_per_cycle: int = 4,
        max_cycles: int | None = None,
        client_id: str = "repaird",
        order: str = "margin",
        auto_retarget: bool = True,
    ):
        """Launch the rate-limited background repair loop (``RepairDaemon``)
        over this store's EC objects. By default the daemon repairs the
        objects with the SMALLEST surviving-fragment margin first
        (``order="margin"``; ``"rr"`` = the old blind round-robin) and
        follows reconfigurations by itself (``auto_retarget``: it subscribes
        to this store's recon-finalization notifications, so the owner never
        calls ``retarget``). Returns the daemon; call ``stop_repair_daemon()``
        (or pass ``max_cycles``) before expecting ``net.run()`` to quiesce."""
        from repro.core.repair import RepairDaemon

        daemon = RepairDaemon(
            self.net, config or self.c0, cfg_idx,
            discover=self.ec_objects, period=period,
            objs_per_cycle=objs_per_cycle, max_cycles=max_cycles,
            client_id=client_id, history=self.history,
            order=order, auto_retarget=auto_retarget,
        )
        # one managed daemon at a time: drop the previous daemon's
        # subscription so a replaced (or completed) daemon is no longer
        # notified — its observe_recon also self-guards once done.
        prev = getattr(self, "repair_daemon", None)
        if prev is not None and prev.observe_recon in self._recon_subs:
            self._recon_subs.remove(prev.observe_recon)
        daemon.start()
        if auto_retarget:
            self._recon_subs.append(daemon.observe_recon)
        self.repair_daemon = daemon
        return daemon

    def stop_repair_daemon(self) -> None:
        daemon = getattr(self, "repair_daemon", None)
        if daemon is not None:
            daemon.stop()
            if daemon.observe_recon in self._recon_subs:
                self._recon_subs.remove(daemon.observe_recon)

    def run(self, **kw) -> None:
        self.net.run(**kw)

    # --- post-hoc history checking (ISSUE 8) -------------------------------------
    def check_history(self, *, strict_reads: bool = True) -> dict:
        """Wing–Gong tag-order linearizability over this store's recorded
        history (see ``repro.analysis.linearize``); raises
        ``LinearizabilityError`` on a violation, returns counters otherwise.
        ``strict_reads=False`` relaxes only the reads-from condition — use it
        for histories taken under crash storms, where a read may observe a
        write that failed before recording itself."""
        from repro.analysis.linearize import check_tag_linearizable

        return check_tag_linearizable(self.history, strict_reads=strict_reads)
