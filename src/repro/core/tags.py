"""Tags, versions, configurations — the paper's §II model objects.

A *tag* is ``(ts, wid)`` ordered lexicographically (timestamp, writer id) —
ARES's operation-ordering token, which CoARES reuses as the coverable
*version* of the object (§IV: "we use tags to denote the version").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

Tag = tuple[int, str]
TAG0: Tag = (0, "")

P, F = "P", "F"  # configuration status: proposed / finalized


def next_tag(t: Tag, wid: str) -> Tag:
    """Alg 1:18 — new version from the discovered maximum."""
    return (t[0] + 1, wid)


@dataclass(frozen=True)
class Config:
    """A configuration c ∈ C (§II): server set + DAP choice (+ EC params).

    ``dap``: "abd" | "ec" | "ec_opt". For EC DAPs, ``k`` data fragments out
    of ``n = len(servers)`` coded fragments, and ``delta`` = max concurrent
    put-data operations (List length bound δ+1, Alg 5).
    """

    cfg_id: str
    servers: tuple[str, ...]
    dap: str = "abd"
    k: int = 1
    delta: int = 8

    @property
    def n(self) -> int:
        return len(self.servers)

    def quorum(self) -> int:
        """ABD: majority ⌊n/2⌋+1. EC: ⌈(n+k)/2⌉ (paper §VII-A)."""
        if self.dap == "abd":
            return self.n // 2 + 1
        return -((self.n + self.k) // -2)  # ceil

    def majority(self) -> int:
        return self.n // 2 + 1

    def frag_index(self, sid: str) -> int:
        return self.servers.index(sid)

    def wire_size(self) -> int:
        return 16 + sum(len(s) for s in self.servers)


@dataclass
class CSeqEntry:
    config: Config
    status: str  # P | F


@dataclass
class OpRecord:
    """History record for the linearizability / coverability checkers."""

    kind: str            # "read" | "write" | "recon"
    obj: str
    client: str
    start: float
    end: float
    tag: Optional[Tag] = None
    flag: Optional[str] = None   # chg | unchg (writes)
    value_digest: Optional[int] = None
    extra: dict = field(default_factory=dict)


def digest(value: Any) -> int:
    if value is None:
        return 0
    if isinstance(value, (bytes, bytearray)):
        return hash(bytes(value))
    return hash(value)
