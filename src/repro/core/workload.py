"""YCSB-style scale-out workload harness (ISSUE 7, ROADMAP item 5a).

The paper's evaluation drives tens of clients by hand (§VII); the ROADMAP
north star is heavy traffic from *millions*. ``WorkloadGen`` closes the gap
between those scales in the simulator: it plans a deterministic population of
lightweight sessions — zipfian file popularity, a read/write mix, arrival
churn over a virtual window, optional crash/recover storms landing mid-run —
and drives the plan through the existing ``Session``/``Gateway`` tiers, so a
10^5-session run exercises exactly the production surface (coalescing
windows, gateway merging, per-client accounting), not a side door.

Everything is drawn from one seeded ``numpy.random.Generator`` *before* the
clock starts, so a plan is a pure function of ``(spec, seed)`` and replays
identically on the fast and legacy network engines (``DSSParams.fast_net``).

    gen = WorkloadGen(WorkloadSpec(sessions=100_000, read_fraction=0.95))
    report = gen.run(dss)           # dss.net.run() to quiescence inside
    report["ops_done"], report["read_p99"], ...
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core.api import Session


@dataclass(frozen=True)
class CrashStorm:
    """Crash a slice of the server fleet at virtual time ``at`` (seconds
    after the workload's sessions start arriving), recover it ``duration``
    later. By default the crash count is capped at ``n - quorum``
    live-tolerable failures so the storm degrades service without wedging
    every quorum — the churn-during-recon scenario ROADMAP 5a asks for, not
    a blackout. ``beyond_quorum=True`` (ISSUE 10) lifts the cap: with a
    ``DSSParams.retry`` policy armed, ops ride out the outage via
    deadline/retransmit and complete after recovery (or fail typed with
    ``QuorumUnavailableError``) — never hang. ``wipe`` selects
    crash-recovery (volatile caches cleared on rejoin) vs the legacy
    flag-flip."""

    at: float
    frac: float = 0.25          # fraction of servers to crash
    duration: float = 0.05      # virtual seconds until recovery
    beyond_quorum: bool = False  # lift the n - quorum crash cap
    wipe: bool = True           # crash-recovery: wipe volatile state on rejoin


@dataclass
class WorkloadSpec:
    sessions: int = 1000
    files: int = 64
    file_size: int = 1024       # bytes per pre-populated file
    read_fraction: float = 0.95
    zipf_s: float = 0.99        # zipf exponent (YCSB default skew)
    ops_per_session: int = 1
    think: float = 2e-3         # mean virtual think time between a session's ops
    span: float = 0.25          # session arrival window (virtual seconds)
    storms: tuple[CrashStorm, ...] = ()
    payload_variants: int = 8   # distinct write payloads cycled by writers
    collect_latencies: bool = True
    extra: dict = field(default_factory=dict)  # free-form, for bench labels


class WorkloadGen:
    """Deterministic zipfian workload planner + driver."""

    def __init__(self, spec: WorkloadSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed

    # ------------------------------------------------------------- planning
    def zipf_weights(self) -> np.ndarray:
        """P(file i) ∝ 1 / (i+1)^s — file 0 is the hottest."""
        ranks = np.arange(1, self.spec.files + 1, dtype=float)
        w = ranks ** -self.spec.zipf_s
        return w / w.sum()

    def plan(self) -> dict[str, np.ndarray]:
        """Pre-draw every random choice the run will make: per-op file ids,
        read/write flags, per-session arrival offsets and think times. All
        vector draws, all before virtual time starts — the run itself never
        touches this generator."""
        spec = self.spec
        rng = np.random.default_rng(self.seed)
        n_ops = spec.sessions * spec.ops_per_session
        fids = rng.choice(spec.files, size=n_ops, p=self.zipf_weights())
        is_read = rng.random(n_ops) < spec.read_fraction
        arrivals = rng.uniform(0.0, spec.span, spec.sessions)
        thinks = (
            rng.exponential(spec.think, n_ops)
            if spec.think > 0
            else np.zeros(n_ops)
        )
        payloads_seed = int(rng.integers(0, 2**31))
        return {
            "fids": fids,
            "is_read": is_read,
            "arrivals": arrivals,
            "thinks": thinks,
            "payloads_seed": payloads_seed,
        }

    def payloads(self, payloads_seed: int) -> list[bytes]:
        prng = np.random.default_rng(payloads_seed)
        return [
            prng.integers(0, 256, self.spec.file_size, dtype=np.uint8).tobytes()
            for _ in range(max(1, self.spec.payload_variants))
        ]

    # -------------------------------------------------------------- driving
    def _storm_plan(self, dss) -> list[tuple[CrashStorm, list[str]]]:
        """Resolve each storm to a concrete crash set, capped at the number
        of failures the initial configuration's quorum tolerates."""
        sids = sorted(
            (s for s in dss.net.servers if s.startswith("s")),
            key=lambda s: int(s[1:]),
        )[: dss.params.n_servers]
        out = []
        rng = np.random.default_rng([self.seed, 0x570])
        for storm in self.spec.storms:
            tolerable = (
                len(sids) if storm.beyond_quorum
                else max(0, len(sids) - dss.c0.quorum())
            )
            want = int(round(storm.frac * len(sids)))
            count = min(max(want, 1), tolerable)
            picks = sorted(rng.choice(len(sids), size=count, replace=False).tolist())
            out.append((storm, [sids[i] for i in picks]))
        return out

    def run(self, dss, *, via=None, window: float | None = None) -> dict[str, Any]:
        """Populate the files, launch every session on its arrival schedule,
        run the network to quiescence, and tally. ``via`` attaches every
        session through a Gateway; ``window`` overrides the Session
        coalescing window. Returns a flat metrics dict (all plain Python
        scalars, JSON-ready)."""
        spec = self.spec
        net = dss.net
        plan = self.plan()
        fnames = [f"f{i}" for i in range(spec.files)]
        payloads = self.payloads(plan["payloads_seed"])

        # pre-populate: distinct fids coalesce into one multi-file batch
        boot = dss.session("boot")
        for i, fname in enumerate(fnames):
            boot.write(fname, payloads[i % len(payloads)])
        net.run()

        kw: dict[str, Any] = {"via": via}
        if window is not None:
            kw["window"] = window
        base = net.now
        futures: list = []
        issue_times: list[float] = []  # per-future issue offset from base

        def launch(s: int) -> None:
            sess = Session(dss, f"u{s}", **kw)
            lo = s * spec.ops_per_session
            t = 0.0
            for o in range(spec.ops_per_session):
                i = lo + o
                fname = fnames[int(plan["fids"][i])]
                read = bool(plan["is_read"][i])
                pay = None if read else payloads[i % len(payloads)]

                def issue(sess=sess, fname=fname, read=read, pay=pay) -> None:
                    issue_times.append(net.now - base)
                    futures.append(
                        sess.read(fname) if read else sess.write(fname, pay)
                    )

                if spec.ops_per_session == 1:
                    issue()
                else:
                    net.schedule(t, issue)
                    t += float(plan["thinks"][i])

        for s in range(spec.sessions):
            net.schedule(float(plan["arrivals"][s]), lambda s=s: launch(s))
        for storm, crash_ids in self._storm_plan(dss):
            if not crash_ids:
                continue
            net.schedule(storm.at, lambda ids=crash_ids: dss.crash_servers(ids))
            net.schedule(
                storm.at + storm.duration,
                lambda ids=crash_ids, w=storm.wipe:
                    dss.recover_servers(ids, wipe=w),
            )
        net.run()

        ops = len(futures)
        ops_done = sum(1 for f in futures if f.done())
        ops_failed = sum(
            1 for f in futures if f.done() and f.exception() is not None
        )
        ops_ok = ops_done - ops_failed
        makespan = float(net.now - base)
        from repro.net.sim import QuorumUnavailableError

        report: dict[str, Any] = {
            "sessions": spec.sessions,
            "ops": ops,
            "ops_done": ops_done,
            "ops_failed": ops_failed,
            "ops_stuck": ops - ops_done,
            "virtual_makespan": makespan,
            "rpc_rounds": net.rpc_rounds,
            "msg_count": net.msg_count,
            "bytes_sent": net.bytes_sent,
            "events": net.events_processed,
            # availability/goodput as first-class metrics (ISSUE 10): the
            # fraction of issued ops that completed successfully, and the
            # successful-op rate over the virtual makespan.
            "availability": ops_ok / ops if ops else 1.0,
            "goodput_ops_per_s": ops_ok / makespan if makespan > 0 else 0.0,
            # failure typing: with retries on, EVERY failure must be the
            # typed liveness error, never a hang or a stray exception.
            "quorum_unavailable": sum(
                1 for f in futures
                if f.done() and isinstance(f.exception(), QuorumUnavailableError)
            ),
            "stuck_rpcs": len(net.stuck_ops()),
            "retries": {
                "retransmits": net.retransmits,
                "rpc_timeouts": net.rpc_timeouts,
                "hedges": net.hedges,
                "op_retries": net.op_retries,
            },
        }
        if self.spec.storms:
            # post-recovery availability: ops issued after the LAST storm's
            # recovery point must essentially all succeed (the ≥99% gate the
            # chaos bench holds CI to).
            recovery_end = max(s.at + s.duration for s in self.spec.storms)
            after = [
                f for f, t in zip(futures, issue_times) if t >= recovery_end
            ]
            ok_after = sum(
                1 for f in after if f.done() and f.exception() is None
            )
            report["ops_after_recovery"] = len(after)
            report["availability_after_recovery"] = (
                ok_after / len(after) if after else 1.0
            )
        if getattr(net, "sanitizer", None) is not None:
            # sanitized run (ISSUE 8): every fan-out/reply was checked live;
            # close with the post-hoc Wing–Gong pass over the recorded
            # history. Reads-from is only provable when every op recorded
            # itself — crash storms leave failed/stuck writers whose tags
            # reads may legitimately observe.
            from repro.analysis.linearize import check_tag_linearizable

            # phase retries leave orphan intermediate tags (an abandoned
            # attempt's put may land without its history record), so strict
            # reads-from is only provable on retry-free runs.
            strict = (
                ops_failed == 0 and ops - ops_done == 0
                and net.op_retries == 0
            )
            lin = check_tag_linearizable(dss.history, strict_reads=strict)
            report["sanitizer"] = dict(net.sanitizer.report(), **{
                "linearized_objects": lin["objects"],
                "linearized_ops": lin["ops"],
                "strict_reads": strict,
            })
        if getattr(net, "race_tracker", None) is not None:
            # race-checked run (ISSUE 9): every in-handle mutation was
            # ordered and summary-checked live; surface the counters.
            report["races"] = net.race_tracker.report()
        if spec.collect_latencies:
            lats = [
                f.stats.latency
                for f in futures
                if f.done() and f.exception() is None and f.stats is not None
            ]
            reads = [
                f.stats.latency
                for f in futures
                if f.kind == "read" and f.done() and f.exception() is None
                and f.stats is not None
            ]
            for label, xs in (("op", lats), ("read", reads)):
                if xs:
                    report[f"{label}_p50"] = float(np.percentile(xs, 50))
                    report[f"{label}_p99"] = float(np.percentile(xs, 99))
        return report
