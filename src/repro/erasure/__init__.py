"""Erasure-coding substrate: GF(2^8) arithmetic and [n,k] Reed-Solomon codes.

The compute hot path (GF(256) matrix multiply) is served by the Pallas
bitsliced-GF(2) MXU kernel in ``repro.kernels.gf256_matmul``; this package
provides the field/matrix algebra and the systematic-code plumbing around it.
"""
from repro.erasure.gf import (
    EXP_TABLE,
    LOG_TABLE,
    gf_add,
    gf_const_to_bitmatrix,
    gf_inv,
    gf_matmul_np,
    gf_matrix_to_bitmatrix,
    gf_mul,
    gf_mul_np,
)
from repro.erasure.matrix import cauchy_parity_matrix, gf_invert_matrix, vandermonde_matrix
from repro.erasure.rs import RSCode, bytes_to_rows, rows_to_bytes

__all__ = [
    "EXP_TABLE",
    "LOG_TABLE",
    "gf_add",
    "gf_mul",
    "gf_inv",
    "gf_mul_np",
    "gf_matmul_np",
    "gf_const_to_bitmatrix",
    "gf_matrix_to_bitmatrix",
    "cauchy_parity_matrix",
    "vandermonde_matrix",
    "gf_invert_matrix",
    "RSCode",
    "bytes_to_rows",
    "rows_to_bytes",
]
