"""GF(2^8) arithmetic.

Field: GF(2)[x] / (x^8 + x^4 + x^3 + x^2 + 1)  (0x11D, the AES-adjacent
polynomial used by most RS implementations, e.g. ISA-L, jerasure).

Two execution models are provided:

* **byte/LUT model** (`gf_mul_np`, `gf_matmul_np`): classical log/antilog
  tables — the reference semantics, used host-side for small matrices
  (generator construction, k x k inversions).
* **bitsliced GF(2) model** (`gf_const_to_bitmatrix`, `gf_matrix_to_bitmatrix`):
  every multiply-by-constant is an 8x8 bit matrix, so a GF(256) matmul becomes
  a 0/1 matmul mod 2 — the TPU-native formulation consumed by the Pallas
  kernel (see DESIGN.md §3, Adaptation 1).
"""
from __future__ import annotations

import numpy as np

POLY = 0x11D  # x^8 + x^4 + x^3 + x^2 + 1


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    exp[255:510] = exp[:255]
    # exp[510], exp[511] unused (log sums max at 254+254=508)
    return exp, log


EXP_TABLE, LOG_TABLE = _build_tables()


def gf_add(a: int, b: int) -> int:
    """Addition == subtraction == XOR in characteristic 2."""
    return a ^ b


def gf_mul(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(EXP_TABLE[int(LOG_TABLE[a]) + int(LOG_TABLE[b])])


def gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("0 has no inverse in GF(256)")
    return int(EXP_TABLE[255 - int(LOG_TABLE[a])])


def gf_div(a: int, b: int) -> int:
    if b == 0:
        raise ZeroDivisionError
    if a == 0:
        return 0
    return int(EXP_TABLE[(int(LOG_TABLE[a]) - int(LOG_TABLE[b])) % 255])


def gf_mul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise GF(256) product of uint8 arrays (broadcasting)."""
    a = np.asarray(a, dtype=np.uint8)
    b = np.asarray(b, dtype=np.uint8)
    nz = (a != 0) & (b != 0)
    la = LOG_TABLE[a]
    lb = LOG_TABLE[b]
    prod = EXP_TABLE[la + lb]
    return np.where(nz, prod, np.uint8(0)).astype(np.uint8)


def gf_matmul_np(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """GF(256) matrix product: C[i,j] = XOR_k A[i,k]*B[k,j] (uint8).

    Host-side reference (numpy). The hot-path equivalent lives in
    ``repro.kernels.gf256_matmul``.
    """
    A = np.asarray(A, dtype=np.uint8)
    B = np.asarray(B, dtype=np.uint8)
    if A.ndim != 2 or B.ndim != 2 or A.shape[1] != B.shape[0]:
        raise ValueError(
            f"gf_matmul_np needs (m,k)@(k,n) matrices, got {A.shape}@{B.shape}"
        )
    # (m, k, j) products, XOR-folded over k.
    terms = gf_mul_np(A[:, :, None], B[None, :, :])
    return np.bitwise_xor.reduce(terms, axis=1)


def gf_poly_eval(coeffs: list[int], x: int) -> int:
    """Horner evaluation of a polynomial over GF(256)."""
    acc = 0
    for c in coeffs:
        acc = gf_mul(acc, x) ^ c
    return acc


# ---------------------------------------------------------------------------
# Bitsliced (GF(2)) representation
# ---------------------------------------------------------------------------

def gf_const_to_bitmatrix(c: int) -> np.ndarray:
    """8x8 GF(2) matrix M s.t. bits(c*d) = M @ bits(d) (mod 2) for all d.

    Column j is the bit decomposition of c * x^j (multiplication by a field
    constant is linear over GF(2)).
    """
    M = np.zeros((8, 8), dtype=np.uint8)
    for j in range(8):
        p = gf_mul(c, 1 << j)
        for i in range(8):
            M[i, j] = (p >> i) & 1
    return M


def gf_matrix_to_bitmatrix(A: np.ndarray) -> np.ndarray:
    """Expand a (m, k) GF(256) matrix to its (8m, 8k) GF(2) bit matrix.

    Block (r, c) of the result is ``gf_const_to_bitmatrix(A[r, c])``; with
    data bytes unpacked little-endian along the k axis this turns the GF(256)
    matmul into an ordinary 0/1 matmul mod 2 (MXU-friendly).
    """
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    out = np.zeros((8 * m, 8 * k), dtype=np.uint8)
    for r in range(m):
        for c in range(k):
            out[8 * r : 8 * r + 8, 8 * c : 8 * c + 8] = gf_const_to_bitmatrix(int(A[r, c]))
    return out


def bytes_to_bits_np(D: np.ndarray) -> np.ndarray:
    """(k, L) uint8 -> (8k, L) 0/1, row 8r+j = bit j of row r (little-endian)."""
    D = np.asarray(D, dtype=np.uint8)
    k, L = D.shape
    shifts = np.arange(8, dtype=np.uint8)
    bits = (D[:, None, :] >> shifts[None, :, None]) & 1  # (k, 8, L)
    return bits.reshape(8 * k, L)


def bits_to_bytes_np(Pbits: np.ndarray) -> np.ndarray:
    """(8m, L) 0/1 -> (m, L) uint8 (little-endian pack)."""
    Pbits = np.asarray(Pbits, dtype=np.uint8)
    m8, L = Pbits.shape
    if m8 % 8 != 0:
        raise ValueError(f"bit-plane row count {m8} is not a multiple of 8")
    b = Pbits.reshape(m8 // 8, 8, L)
    weights = (1 << np.arange(8, dtype=np.uint16))[None, :, None]
    return (b.astype(np.uint16) * weights).sum(axis=1).astype(np.uint8)
