"""Generator matrices and Gaussian elimination over GF(256).

Systematic [n, k] codes: codeword = [data (k rows) ; parity (m = n-k rows)],
parity = P @ data with P an MDS parity matrix. We default to **Cauchy**
parity matrices (every square submatrix of a Cauchy matrix is invertible, so
the stacked generator [I; P] is MDS — jerasure's construction). A classical
Vandermonde construction is provided for cross-checking.
"""
from __future__ import annotations

import numpy as np

from repro.erasure.gf import gf_inv, gf_matmul_np, gf_mul, gf_mul_np


def cauchy_parity_matrix(n: int, k: int) -> np.ndarray:
    """(n-k, k) Cauchy matrix C[i, j] = 1 / (x_i ^ y_j), x_i = i, y_j = m + j.

    x's and y's are distinct elements of GF(256), so all entries are defined
    and every square submatrix of [I; C] built from <= k rows is invertible.
    Requires n <= 256.
    """
    m = n - k
    if n > 256:
        raise ValueError("GF(256) Cauchy construction requires n <= 256")
    C = np.zeros((m, k), dtype=np.uint8)
    for i in range(m):
        for j in range(k):
            C[i, j] = gf_inv(i ^ (m + j))
    return C


def vandermonde_matrix(n: int, k: int) -> np.ndarray:
    """Systematic (n-k, k) parity rows derived from a Vandermonde matrix.

    Build V (n, k) with V[i, j] = alpha_i^j (alpha_i = i), then right-multiply
    by inv(V[:k]) so the top square becomes identity; the bottom m rows are
    the parity matrix. MDS because column ops preserve submatrix rank.
    """
    if n > 256:
        raise ValueError("n <= 256 required")
    V = np.zeros((n, k), dtype=np.uint8)
    for i in range(n):
        acc = 1
        for j in range(k):
            V[i, j] = acc
            acc = gf_mul(acc, i)
    top_inv = gf_invert_matrix(V[:k])
    Vs = gf_matmul_np(V, top_inv)
    if not np.array_equal(Vs[:k], np.eye(k, dtype=np.uint8)):
        raise RuntimeError("Vandermonde systematization failed")
    return Vs[k:]


def gf_invert_matrix(A: np.ndarray) -> np.ndarray:
    """Invert a square GF(256) matrix by Gauss-Jordan elimination (uint8)."""
    A = np.asarray(A, dtype=np.uint8).copy()
    k = A.shape[0]
    if A.shape != (k, k):
        raise ValueError(f"square matrix required, got shape {A.shape}")
    aug = np.concatenate([A, np.eye(k, dtype=np.uint8)], axis=1)
    for col in range(k):
        # pivot
        piv = None
        for r in range(col, k):
            if aug[r, col] != 0:
                piv = r
                break
        if piv is None:
            raise np.linalg.LinAlgError("singular matrix over GF(256)")
        if piv != col:
            aug[[col, piv]] = aug[[piv, col]]
        # normalize pivot row
        inv_p = gf_inv(int(aug[col, col]))
        aug[col] = gf_mul_np(aug[col], np.uint8(inv_p))
        # eliminate
        for r in range(k):
            if r != col and aug[r, col] != 0:
                factor = aug[r, col]
                aug[r] = aug[r] ^ gf_mul_np(np.uint8(factor), aug[col])
    return aug[:, k:].copy()


def gf_solve_decode_matrix(generator_rows: np.ndarray) -> np.ndarray:
    """Given the k generator rows of the surviving fragments (each row is the
    GF(256) linear combination producing that fragment from the k data rows),
    return the (k, k) matrix mapping surviving fragments back to data."""
    return gf_invert_matrix(generator_rows)
