"""Systematic [n, k] Reed-Solomon (Cauchy) codes over GF(256).

``RSCode`` is the object-level API used by the EC DAPs (``repro.core.dap.ec*``),
the repair subsystem (``repro.core.repair``) and the EC checkpoint store
(``repro.train.checkpoint``):

* ``encode(data)``      — (k, L) uint8 -> (n, L) coded fragments (systematic:
                          fragments [0, k) are the data rows themselves).
* ``decode(frs, idxs)`` — any k fragments (+ their indices) -> (k, L) data.

Coding backends (ISSUE 6)
-------------------------
``backend`` selects where the GF(256) matmul runs:

* ``"numpy"``  — the byte-LUT reference (``erasure.gf.gf_matmul_np``).
* ``"kernel"`` — the hardware path (``repro.kernels.gf256_matmul.ops.
  gf256_coding_matmul``): the Pallas bitsliced kernel where it compiles
  natively (TPU), the jit'd XLA LUT formulation on CPU.
* ``"auto"``   — size-based dispatch: operands at or above
  ``AUTO_KERNEL_MIN_BYTES`` (measured crossover on the reference container,
  see ``benchmarks/bench_kernels.py``) take the kernel path; tiny
  single-block products stay on the LUT path, whose fixed overhead is lower.

All backends are bit-identical (property-tested in
``tests/test_coding_backend.py``).

Batched byte paths
------------------
``encode_bytes_batch`` / ``decode_bytes_batch`` fuse many ragged byte values
into as few matmuls as possible: values are laid side by side column-wise
(GF(256) matmul acts per column, so no per-value padding is needed), decode
groups sharing a surviving-fragment index set share one cached inverted
generator (``_decoder_cached``), and on the native kernel multiple groups
fuse into ONE block-diagonal launch. Fragments carry an optional CRC-32
computed/verified in the same traversal that materialises the bytes
(``with_crc=True`` / a per-item crc dict), so integrity checking never costs
a second pass over the data.
"""
from __future__ import annotations

import functools
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.erasure.gf import gf_matmul_np
from repro.erasure.matrix import cauchy_parity_matrix, gf_invert_matrix

BACKENDS = ("numpy", "kernel", "auto")

# "auto" crossover: operand (B) bytes at which the kernel backend overtakes
# the numpy LUT path. Measured on the reference container (CPU/XLA): the
# jit'd formulation wins from ~16 KiB; 64 KiB leaves headroom for dispatch
# and shape-bucket recompiles. See benchmarks/bench_kernels.py.
AUTO_KERNEL_MIN_BYTES = 1 << 16

# Block-diagonal group fusion bound: G groups of a k-row code fuse into one
# (G*k, G*k) launch only while the expanded bit-matrix stays VMEM-friendly.
_FUSE_MAX_ROWS = 128


def element_crc_ok(elem) -> bool:
    """Integrity check for a stored/shipped coded element.

    Elements are ``(fragment_bytes, orig_len)`` or, since ISSUE 6,
    ``(fragment_bytes, orig_len, crc32)``. Returns False only when a carried
    checksum does not match the fragment bytes — legacy 2-tuples (and the
    server's ``("", 0)`` sentinel) always pass.
    """
    if not isinstance(elem, tuple) or len(elem) < 3 or elem[2] is None:
        return True
    return zlib.crc32(elem[0]) == elem[2]


def bytes_to_rows(data: bytes, k: int) -> tuple[np.ndarray, int]:
    """Pad ``data`` to a multiple of k and reshape to (k, L). Returns the
    original length so ``rows_to_bytes`` can strip the padding."""
    orig = len(data)
    L = (orig + k - 1) // k if orig else 1
    buf = np.zeros(k * L, dtype=np.uint8)
    buf[:orig] = np.frombuffer(data, dtype=np.uint8)
    return buf.reshape(k, L), orig


def rows_to_bytes(rows: np.ndarray, orig_len: int) -> bytes:
    return rows.reshape(-1).tobytes()[:orig_len]


@functools.lru_cache(maxsize=128)
def _parity_cached(n: int, k: int) -> np.ndarray:
    P = cauchy_parity_matrix(n, k)
    P.setflags(write=False)
    return P


@functools.lru_cache(maxsize=4096)
def _decoder_cached(n: int, k: int, idxs: tuple[int, ...]) -> np.ndarray:
    """Inverted generator for fragment index-set ``idxs`` of the [n, k] code.

    Cached per index-set the way ``ops._abits_cached`` caches bit-matrices:
    batched reads keep hitting the same few surviving-quorum subsets, so the
    k x k Gauss-Jordan runs once per subset, not once per decode."""
    P = _parity_cached(n, k)
    gen = np.zeros((k, k), dtype=np.uint8)
    for r, idx in enumerate(idxs):
        if idx < k:
            gen[r, idx] = 1
        else:
            gen[r] = P[idx - k]
    D = gf_invert_matrix(gen)
    D.setflags(write=False)
    return D


@dataclass
class RSCode:
    """Systematic Cauchy-RS erasure code over GF(256)."""

    n: int
    k: int
    backend: str = "numpy"  # "numpy" | "kernel" | "auto"
    # Block-diagonal fusion of multi-group decode_bytes_batch calls into one
    # kernel launch: None = only where the Pallas kernel is native (the MXU
    # eats the zero blocks at full rate; the CPU LUT path would pay G x the
    # dense work). Tests force True/False.
    fuse_groups: bool | None = None
    _parity: np.ndarray = field(init=False, repr=False)

    def __post_init__(self) -> None:
        if not (0 < self.k <= self.n <= 256):
            raise ValueError(f"need 0 < k <= n <= 256, got n={self.n} k={self.k}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown coding backend {self.backend!r}; expected one of {BACKENDS}"
            )
        self._parity = _parity_cached(self.n, self.k)

    # -- properties ---------------------------------------------------------
    @property
    def m(self) -> int:
        return self.n - self.k

    @property
    def parity_matrix(self) -> np.ndarray:
        return self._parity

    def generator_row(self, idx: int) -> np.ndarray:
        """Row of the full systematic generator [I; P] for fragment ``idx``."""
        if idx < self.k:
            row = np.zeros(self.k, dtype=np.uint8)
            row[idx] = 1
            return row
        return self._parity[idx - self.k].copy()

    # -- core ops ------------------------------------------------------------
    def _use_kernel(self, A: np.ndarray, B: np.ndarray) -> bool:
        if self.backend == "numpy" or A.size == 0 or B.size == 0:
            return False
        if self.backend == "kernel":
            return B.shape[1] >= 8
        return B.size >= AUTO_KERNEL_MIN_BYTES  # "auto"

    def _matmul(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        if self._use_kernel(np.asarray(A), np.asarray(B)):
            from repro.kernels.gf256_matmul import ops as gf_ops

            return np.asarray(gf_ops.gf256_coding_matmul(A, B))
        return gf_matmul_np(A, B)

    @staticmethod
    def _systematic_rows(indices, nrows: int, k: int) -> list[int] | None:
        """Row positions holding fragments 0..k-1 (in that order), or None
        when the supplied indices don't cover the full systematic set."""
        pos: dict[int, int] = {}
        for p, idx in enumerate(list(indices)[:nrows]):
            pos.setdefault(int(idx), p)
        if all(i in pos for i in range(k)):
            return [pos[i] for i in range(k)]
        return None

    def encode(self, data: np.ndarray) -> np.ndarray:
        """(k, L) uint8 -> (n, L) uint8 coded fragments (systematic)."""
        data = np.asarray(data, dtype=np.uint8)
        if data.shape[0] != self.k:
            raise ValueError(f"expected {self.k} data rows, got {data.shape}")
        if self.m == 0:
            return data.copy()
        parity = self._matmul(self._parity, data)
        return np.concatenate([data, parity], axis=0)

    def decode(self, fragments: np.ndarray, indices: list[int]) -> np.ndarray:
        """Reconstruct (k, L) data from any k fragments.

        ``fragments``: (k, L) uint8 rows; ``indices``: their fragment ids in
        [0, n). Raises if fewer than k distinct fragments are supplied. When
        the supplied rows cover all k systematic fragments — in any order,
        at any position — they are returned directly (no inversion, no
        matmul); otherwise the first k rows decode through the cached
        inverted generator.
        """
        fragments = np.asarray(fragments, dtype=np.uint8)
        if len(indices) != len(set(indices)):
            raise ValueError("duplicate fragment indices")
        if fragments.shape[0] < self.k or len(indices) < self.k:
            raise ValueError(
                f"need {self.k} fragments to decode, got {fragments.shape[0]}"
            )
        rows = self._systematic_rows(indices, fragments.shape[0], self.k)
        if rows is not None:
            return np.ascontiguousarray(fragments[rows])
        idxs = [int(i) for i in list(indices)[: self.k]]
        frs = fragments[: self.k]
        dec = _decoder_cached(self.n, self.k, tuple(idxs))
        return np.asarray(self._matmul(dec, frs))

    def reconstruct_fragment(
        self, target_idx: int, fragments: np.ndarray, indices: list[int]
    ) -> np.ndarray:
        """Rebuild a single lost fragment (server repair path)."""
        data = self.decode(fragments, indices)
        if target_idx < self.k:
            return data[target_idx]
        return self._matmul(self._parity[target_idx - self.k : target_idx - self.k + 1], data)[0]

    def reconstruct_fragments(
        self, target_idxs: list[int], fragments: np.ndarray, indices: list[int]
    ) -> np.ndarray:
        """Rebuild several lost fragments with one decode + one fused matmul.

        Returns (len(target_idxs), L) rows in target order. Used by the
        repair controller, which typically replaces every fragment a set of
        recovered servers lost at once."""
        data = self.decode(fragments, indices)
        if not target_idxs:
            return np.zeros((0, data.shape[1]), dtype=np.uint8)
        gen = np.stack([self.generator_row(i) for i in target_idxs], axis=0)
        return np.asarray(self._matmul(gen, data))

    # -- batched coding (single fused GF(256) matmul over many blocks) -------
    def encode_batch(self, data: np.ndarray) -> np.ndarray:
        """(B, k, L) uint8 -> (B, n, L) coded blocks via ONE matmul.

        GF(256) matmul acts column-wise, so the B blocks are laid side by
        side as one (k, B*L) operand; the product splits back into per-block
        parity bit-identically to B separate ``encode`` calls. On the kernel
        backend this is one launch instead of B."""
        data = np.asarray(data, dtype=np.uint8)
        if data.ndim != 3 or data.shape[1] != self.k:
            raise ValueError(f"expected (B, {self.k}, L) blocks, got {data.shape}")
        B, _, L = data.shape
        if B == 0:
            return np.zeros((0, self.n, L), dtype=np.uint8)
        if self.m == 0:
            return data.copy()
        flat = np.ascontiguousarray(data.transpose(1, 0, 2)).reshape(self.k, B * L)
        parity = np.asarray(self._matmul(self._parity, flat))
        parity = parity.reshape(self.m, B, L).transpose(1, 0, 2)
        return np.concatenate([data, parity], axis=1)

    def decode_batch(self, fragments: np.ndarray, indices: list[int]) -> np.ndarray:
        """(B, k, L) fragment blocks sharing ONE index set -> (B, k, L) data.

        The common case for batched reads: every block lost the same servers,
        so one inverted generator serves the whole batch in a single matmul."""
        fragments = np.asarray(fragments, dtype=np.uint8)
        if fragments.ndim != 3:
            raise ValueError(f"expected (B, k, L) fragment blocks, got {fragments.shape}")
        if len(indices) != len(set(indices)):
            raise ValueError("duplicate fragment indices")
        if fragments.shape[1] < self.k or len(indices) < self.k:
            raise ValueError(
                f"need {self.k} fragments per block to decode, got {fragments.shape[1]}"
            )
        B, R, L = fragments.shape
        if B == 0:
            return fragments[:, : self.k, :].copy()
        rows = self._systematic_rows(indices, R, self.k)
        if rows is not None:
            return np.ascontiguousarray(fragments[:, rows, :])
        idxs = [int(i) for i in list(indices)[: self.k]]
        frs = fragments[:, : self.k, :]
        dec = _decoder_cached(self.n, self.k, tuple(idxs))
        flat = np.ascontiguousarray(frs.transpose(1, 0, 2)).reshape(self.k, B * L)
        out = np.asarray(self._matmul(dec, flat))
        return np.ascontiguousarray(out.reshape(self.k, B, L).transpose(1, 0, 2))

    # -- bytes-level convenience (object values in the DAPs) -----------------
    def encode_bytes(self, value: bytes, *, with_crc: bool = False):
        """``([fragment bytes] * n, orig_len)``; with ``with_crc`` also a
        parallel list of per-fragment CRC-32s (one-element batch)."""
        return self.encode_bytes_batch([value], with_crc=with_crc)[0]

    def encode_bytes_batch(self, values: list[bytes], *, with_crc: bool = False):
        """Batch ``encode_bytes`` over many byte strings with ONE fused matmul.

        The values' (k, L_b) row blocks are laid side by side column-wise —
        the GF matmul acts per column, so ragged lengths fuse with NO
        padding and the result is bit-identical to per-value encoding.
        Returns ``[(fragments, orig_len)]`` aligned with ``values``, or
        ``[(fragments, orig_len, crcs)]`` with ``with_crc=True`` — the CRC-32
        of each fragment, computed in the same pass that materialises its
        bytes (the integrity tags the EC DAP ships inside coded elements)."""
        if not values:
            return []
        rows: list[np.ndarray] = []
        origs: list[int] = []
        for v in values:
            r, o = bytes_to_rows(v, self.k)
            rows.append(r)
            origs.append(o)
        if self.m:
            flat = rows[0] if len(rows) == 1 else np.concatenate(rows, axis=1)
            parity = np.asarray(self._matmul(self._parity, flat))
        out = []
        off = 0
        for b, r in enumerate(rows):
            lb = r.shape[1]
            frags = [r[i].tobytes() for i in range(self.k)]
            if self.m:
                frags += [parity[j, off : off + lb].tobytes() for j in range(self.m)]
                off += lb
            if with_crc:
                out.append((frags, origs[b], [zlib.crc32(f) for f in frags]))
            else:
                out.append((frags, origs[b]))
        return out

    def _choose_idxs(self, fragments: dict) -> tuple[int, ...]:
        """The k-subset of fragment indices to decode from: the all-systematic
        subset whenever every data fragment is present (the no-matmul fast
        path), the lowest k indices otherwise."""
        if len(fragments) < self.k:
            raise ValueError(f"need {self.k} fragments, have {len(fragments)}")
        if all(i in fragments for i in range(self.k)):
            return tuple(range(self.k))
        return tuple(int(i) for i in sorted(fragments)[: self.k])

    def _decode_flats(
        self, jobs: list[tuple[np.ndarray, np.ndarray]]
    ) -> list[np.ndarray]:
        """Run each (decoder, (k, W) operand) job; on the native kernel,
        multiple jobs fuse into ONE block-diagonal launch (zero blocks are
        free on the MXU; the CPU LUT path keeps one matmul per job, where a
        block-diagonal product would cost G x the dense work)."""
        fuse = self.fuse_groups
        if fuse is None and self.backend != "numpy" and len(jobs) > 1:
            from repro.kernels.gf256_matmul import ops as gf_ops

            fuse = gf_ops.kernel_is_native()
        if (
            not fuse
            or len(jobs) <= 1
            or self.backend == "numpy"
            or len(jobs) * self.k > _FUSE_MAX_ROWS
        ):
            return [np.asarray(self._matmul(dec, flat)) for dec, flat in jobs]
        k, G = self.k, len(jobs)
        wmax = max(flat.shape[1] for _, flat in jobs)
        A = np.zeros((G * k, G * k), dtype=np.uint8)
        B = np.zeros((G * k, wmax), dtype=np.uint8)
        for g, (dec, flat) in enumerate(jobs):
            A[g * k : (g + 1) * k, g * k : (g + 1) * k] = dec
            B[g * k : (g + 1) * k, : flat.shape[1]] = flat
        out = np.asarray(self._matmul(A, B))
        return [
            np.ascontiguousarray(out[g * k : (g + 1) * k, : flat.shape[1]])
            for g, (_, flat) in enumerate(jobs)
        ]

    def decode_bytes_batch(self, items: list[tuple]) -> list[bytes]:
        """Decode many byte values with as few GF(256) matmuls as possible.

        Each item is ``(fragments, orig_len)`` or ``(fragments, orig_len,
        crcs)`` — ``fragments`` maps fragment index -> fragment bytes (any
        number >= k; the decode subset is chosen here, preferring the
        all-systematic one), ``crcs`` optionally maps index -> CRC-32 to
        verify while the rows are gathered. Items whose chosen index subset
        coincides (the common case for a batched read: every block heard
        from the same quorum) share one cached inverted generator and fuse
        column-wise into ONE matmul regardless of ragged lengths; distinct
        subsets additionally fuse block-diagonally into a single launch on
        the native kernel. Raises ``ValueError`` when an item's chosen
        fragments disagree in length (a short/truncated fragment would
        otherwise silently decode to garbage) or fail their checksum.
        Returns the decoded bytes aligned with ``items``."""
        out: list[bytes | None] = [None] * len(items)
        sys_idxs = tuple(range(self.k))
        groups: dict[tuple[int, ...], list[tuple[int, dict, int, int]]] = {}
        for pos, item in enumerate(items):
            fragments, orig = item[0], item[1]
            crcs = item[2] if len(item) > 2 else None
            idxs = self._choose_idxs(fragments)
            L = len(fragments[idxs[0]])
            for i in idxs:
                if len(fragments[i]) != L:
                    raise ValueError(
                        f"fragment length mismatch in item {pos}: index {i} "
                        f"has {len(fragments[i])} bytes, index {idxs[0]} has {L}"
                    )
                if (
                    crcs is not None
                    and crcs.get(i) is not None
                    and zlib.crc32(fragments[i]) != crcs[i]
                ):
                    raise ValueError(
                        f"fragment {i} of item {pos} failed its checksum"
                    )
            if self.k * L < orig:
                raise ValueError(
                    f"item {pos}: {self.k} fragments of {L} bytes cannot hold "
                    f"a {orig}-byte value"
                )
            if idxs == sys_idxs:
                # systematic fast path: the data rows ARE the fragments
                out[pos] = b"".join(bytes(fragments[i]) for i in idxs)[:orig]
            else:
                groups.setdefault(idxs, []).append((pos, fragments, L, orig))
        jobs: list[tuple[np.ndarray, np.ndarray]] = []
        metas: list[list[tuple[int, int, int, int]]] = []
        for idxs, members in groups.items():
            W = sum(L for _, _, L, _ in members)
            flat = np.zeros((self.k, W), dtype=np.uint8)
            meta: list[tuple[int, int, int, int]] = []
            off = 0
            for pos, fragments, L, orig in members:
                for r, i in enumerate(idxs):
                    flat[r, off : off + L] = np.frombuffer(
                        fragments[i], dtype=np.uint8
                    )
                meta.append((pos, off, L, orig))
                off += L
            jobs.append((_decoder_cached(self.n, self.k, idxs), flat))
            metas.append(meta)
        for data, meta in zip(self._decode_flats(jobs), metas):
            for pos, off, L, orig in meta:
                rows = np.ascontiguousarray(data[:, off : off + L])
                out[pos] = rows_to_bytes(rows, orig)
        return out  # type: ignore[return-value]

    def decode_bytes(
        self, fragments: dict[int, bytes], orig_len: int, crcs: dict | None = None
    ) -> bytes:
        item = (fragments, orig_len) if crcs is None else (fragments, orig_len, crcs)
        return self.decode_bytes_batch([item])[0]
