"""Systematic [n, k] Reed-Solomon (Cauchy) codes over GF(256).

``RSCode`` is the object-level API used by the EC DAPs (``repro.core.dap.ec*``)
and the EC checkpoint store (``repro.train.checkpoint``):

* ``encode(data)``      — (k, L) uint8 -> (n, L) coded fragments (systematic:
                          fragments [0, k) are the data rows themselves).
* ``decode(frs, idxs)`` — any k fragments (+ their indices) -> (k, L) data.

The GF(256) matmul runs through the Pallas bitsliced kernel
(``repro.kernels.gf256_matmul.ops``) when fragments are jnp arrays / the
`backend="kernel"` path is selected; numpy LUT math otherwise. Both paths are
bit-identical (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.erasure.gf import gf_matmul_np
from repro.erasure.matrix import cauchy_parity_matrix, gf_invert_matrix


def bytes_to_rows(data: bytes, k: int) -> tuple[np.ndarray, int]:
    """Pad ``data`` to a multiple of k and reshape to (k, L). Returns the
    original length so ``rows_to_bytes`` can strip the padding."""
    orig = len(data)
    L = (orig + k - 1) // k if orig else 1
    buf = np.zeros(k * L, dtype=np.uint8)
    buf[:orig] = np.frombuffer(data, dtype=np.uint8)
    return buf.reshape(k, L), orig


def rows_to_bytes(rows: np.ndarray, orig_len: int) -> bytes:
    return rows.reshape(-1).tobytes()[:orig_len]


@dataclass
class RSCode:
    """Systematic Cauchy-RS erasure code over GF(256)."""

    n: int
    k: int
    backend: str = "numpy"  # "numpy" | "kernel"
    _parity: np.ndarray = field(init=False, repr=False)

    def __post_init__(self) -> None:
        if not (0 < self.k <= self.n <= 256):
            raise ValueError(f"need 0 < k <= n <= 256, got n={self.n} k={self.k}")
        self._parity = cauchy_parity_matrix(self.n, self.k)

    # -- properties ---------------------------------------------------------
    @property
    def m(self) -> int:
        return self.n - self.k

    @property
    def parity_matrix(self) -> np.ndarray:
        return self._parity

    def generator_row(self, idx: int) -> np.ndarray:
        """Row of the full systematic generator [I; P] for fragment ``idx``."""
        if idx < self.k:
            row = np.zeros(self.k, dtype=np.uint8)
            row[idx] = 1
            return row
        return self._parity[idx - self.k].copy()

    # -- core ops ------------------------------------------------------------
    def _matmul(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        if self.backend == "kernel" and A.size and B.shape[1] >= 8:
            from repro.kernels.gf256_matmul import ops as gf_ops

            return np.asarray(gf_ops.gf256_matmul(A, B))
        return gf_matmul_np(A, B)

    def encode(self, data: np.ndarray) -> np.ndarray:
        """(k, L) uint8 -> (n, L) uint8 coded fragments (systematic)."""
        data = np.asarray(data, dtype=np.uint8)
        if data.shape[0] != self.k:
            raise ValueError(f"expected {self.k} data rows, got {data.shape}")
        if self.m == 0:
            return data.copy()
        parity = self._matmul(self._parity, data)
        return np.concatenate([data, parity], axis=0)

    def decode(self, fragments: np.ndarray, indices: list[int]) -> np.ndarray:
        """Reconstruct (k, L) data from any k fragments.

        ``fragments``: (k, L) uint8 rows; ``indices``: their fragment ids in
        [0, n). Raises if fewer than k distinct fragments are supplied.
        """
        fragments = np.asarray(fragments, dtype=np.uint8)
        if len(indices) != len(set(indices)):
            raise ValueError("duplicate fragment indices")
        if fragments.shape[0] < self.k or len(indices) < self.k:
            raise ValueError(
                f"need {self.k} fragments to decode, got {fragments.shape[0]}"
            )
        idxs = list(indices)[: self.k]
        frs = fragments[: self.k]
        if idxs == list(range(self.k)):
            return frs.copy()  # all-systematic fast path
        gen = np.stack([self.generator_row(i) for i in idxs], axis=0)
        dec = gf_invert_matrix(gen)
        return self._matmul(dec, frs)

    def reconstruct_fragment(
        self, target_idx: int, fragments: np.ndarray, indices: list[int]
    ) -> np.ndarray:
        """Rebuild a single lost fragment (server repair path)."""
        data = self.decode(fragments, indices)
        if target_idx < self.k:
            return data[target_idx]
        return self._matmul(self._parity[target_idx - self.k : target_idx - self.k + 1], data)[0]

    def reconstruct_fragments(
        self, target_idxs: list[int], fragments: np.ndarray, indices: list[int]
    ) -> np.ndarray:
        """Rebuild several lost fragments with one decode + one fused matmul.

        Returns (len(target_idxs), L) rows in target order. Used by the
        repair controller, which typically replaces every fragment a set of
        recovered servers lost at once."""
        data = self.decode(fragments, indices)
        if not target_idxs:
            return np.zeros((0, data.shape[1]), dtype=np.uint8)
        gen = np.stack([self.generator_row(i) for i in target_idxs], axis=0)
        return np.asarray(self._matmul(gen, data))

    # -- batched coding (single fused GF(256) matmul over many blocks) -------
    def encode_batch(self, data: np.ndarray) -> np.ndarray:
        """(B, k, L) uint8 -> (B, n, L) coded blocks via ONE matmul.

        GF(256) matmul acts column-wise, so the B blocks are laid side by
        side as one (k, B*L) operand; the product splits back into per-block
        parity bit-identically to B separate ``encode`` calls. On the kernel
        backend this is one Pallas launch instead of B."""
        data = np.asarray(data, dtype=np.uint8)
        if data.ndim != 3 or data.shape[1] != self.k:
            raise ValueError(f"expected (B, {self.k}, L) blocks, got {data.shape}")
        B, _, L = data.shape
        if B == 0:
            return np.zeros((0, self.n, L), dtype=np.uint8)
        if self.m == 0:
            return data.copy()
        flat = np.ascontiguousarray(data.transpose(1, 0, 2)).reshape(self.k, B * L)
        parity = np.asarray(self._matmul(self._parity, flat))
        parity = parity.reshape(self.m, B, L).transpose(1, 0, 2)
        return np.concatenate([data, parity], axis=1)

    def decode_batch(self, fragments: np.ndarray, indices: list[int]) -> np.ndarray:
        """(B, k, L) fragment blocks sharing ONE index set -> (B, k, L) data.

        The common case for batched reads: every block lost the same servers,
        so one inverted generator serves the whole batch in a single matmul."""
        fragments = np.asarray(fragments, dtype=np.uint8)
        if fragments.ndim != 3:
            raise ValueError(f"expected (B, k, L) fragment blocks, got {fragments.shape}")
        if len(indices) != len(set(indices)):
            raise ValueError("duplicate fragment indices")
        if fragments.shape[1] < self.k or len(indices) < self.k:
            raise ValueError(
                f"need {self.k} fragments per block to decode, got {fragments.shape[1]}"
            )
        B, _, L = fragments.shape
        idxs = list(indices)[: self.k]
        frs = fragments[:, : self.k, :]
        if B == 0 or idxs == list(range(self.k)):
            return frs.copy()  # all-systematic fast path
        gen = np.stack([self.generator_row(i) for i in idxs], axis=0)
        dec = gf_invert_matrix(gen)
        flat = np.ascontiguousarray(frs.transpose(1, 0, 2)).reshape(self.k, B * L)
        out = np.asarray(self._matmul(dec, flat))
        return np.ascontiguousarray(out.reshape(self.k, B, L).transpose(1, 0, 2))

    # -- bytes-level convenience (object values in the DAPs) -----------------
    def encode_bytes(self, value: bytes) -> tuple[list[bytes], int]:
        rows, orig = bytes_to_rows(value, self.k)
        coded = self.encode(rows)
        return [coded[i].tobytes() for i in range(self.n)], orig

    def encode_bytes_batch(self, values: list[bytes]) -> list[tuple[list[bytes], int]]:
        """Batch ``encode_bytes`` over many byte strings with ONE fused matmul.

        Blocks are zero-padded to the longest row length before the shared
        product; because the GF matmul is column-wise, truncating each
        block's fragments back to its own length is bit-identical to calling
        ``encode_bytes`` per value. Returns [(fragments, orig_len)] aligned
        with ``values``."""
        if not values:
            return []
        rows: list[np.ndarray] = []
        origs: list[int] = []
        for v in values:
            r, o = bytes_to_rows(v, self.k)
            rows.append(r)
            origs.append(o)
        lmax = max(r.shape[1] for r in rows)
        batch = np.zeros((len(values), self.k, lmax), dtype=np.uint8)
        for b, r in enumerate(rows):
            batch[b, :, : r.shape[1]] = r
        coded = self.encode_batch(batch)
        out: list[tuple[list[bytes], int]] = []
        for b, r in enumerate(rows):
            lb = r.shape[1]
            out.append(
                ([coded[b, i, :lb].tobytes() for i in range(self.n)], origs[b])
            )
        return out

    def decode_bytes_batch(
        self, items: list[tuple[dict[int, bytes], int]]
    ) -> list[bytes]:
        """Decode many byte values with as few GF(256) matmuls as possible.

        ``items`` is ``[(fragments, orig_len)]`` per value (same shape as the
        ``decode_bytes`` arguments). Values whose chosen k-subset of fragment
        indices coincides (the common case for a batched read: every block
        heard from the same quorum) are fused into ONE ``decode_batch``
        matmul, zero-padded to the group's longest row. Because the GF matmul
        acts column-wise, padded columns decode to zero and truncating each
        value back to its own length is bit-identical to per-value
        ``decode_bytes``. Returns the decoded bytes aligned with ``items``."""
        out: list[bytes | None] = [None] * len(items)
        groups: dict[tuple[int, ...], list[int]] = {}
        for pos, (fragments, _orig) in enumerate(items):
            idxs = tuple(sorted(fragments.keys())[: self.k])
            if len(idxs) < self.k:
                raise ValueError(f"need {self.k} fragments, have {len(idxs)}")
            groups.setdefault(idxs, []).append(pos)
        for idxs, positions in groups.items():
            lens = [len(items[p][0][idxs[0]]) for p in positions]
            lmax = max(lens)
            batch = np.zeros((len(positions), self.k, lmax), dtype=np.uint8)
            for b, p in enumerate(positions):
                fragments = items[p][0]
                for r, i in enumerate(idxs):
                    row = np.frombuffer(fragments[i], dtype=np.uint8)
                    batch[b, r, : row.size] = row
            data = self.decode_batch(batch, list(idxs))
            for b, p in enumerate(positions):
                rows = np.ascontiguousarray(data[b][:, : lens[b]])
                out[p] = rows_to_bytes(rows, items[p][1])
        return out  # type: ignore[return-value]

    def decode_bytes(
        self, fragments: dict[int, bytes], orig_len: int
    ) -> bytes:
        idxs = sorted(fragments.keys())[: self.k]
        if len(idxs) < self.k:
            raise ValueError(f"need {self.k} fragments, have {len(idxs)}")
        L = len(fragments[idxs[0]])
        frs = np.stack(
            [np.frombuffer(fragments[i], dtype=np.uint8) for i in idxs], axis=0
        )
        assert frs.shape == (self.k, L)
        data = self.decode(frs, idxs)
        return rows_to_bytes(data, orig_len)
