"""Systematic [n, k] Reed-Solomon (Cauchy) codes over GF(256).

``RSCode`` is the object-level API used by the EC DAPs (``repro.core.dap.ec*``)
and the EC checkpoint store (``repro.train.checkpoint``):

* ``encode(data)``      — (k, L) uint8 -> (n, L) coded fragments (systematic:
                          fragments [0, k) are the data rows themselves).
* ``decode(frs, idxs)`` — any k fragments (+ their indices) -> (k, L) data.

The GF(256) matmul runs through the Pallas bitsliced kernel
(``repro.kernels.gf256_matmul.ops``) when fragments are jnp arrays / the
`backend="kernel"` path is selected; numpy LUT math otherwise. Both paths are
bit-identical (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.erasure.gf import gf_matmul_np
from repro.erasure.matrix import cauchy_parity_matrix, gf_invert_matrix


def bytes_to_rows(data: bytes, k: int) -> tuple[np.ndarray, int]:
    """Pad ``data`` to a multiple of k and reshape to (k, L). Returns the
    original length so ``rows_to_bytes`` can strip the padding."""
    orig = len(data)
    L = (orig + k - 1) // k if orig else 1
    buf = np.zeros(k * L, dtype=np.uint8)
    buf[:orig] = np.frombuffer(data, dtype=np.uint8)
    return buf.reshape(k, L), orig


def rows_to_bytes(rows: np.ndarray, orig_len: int) -> bytes:
    return rows.reshape(-1).tobytes()[:orig_len]


@dataclass
class RSCode:
    """Systematic Cauchy-RS erasure code over GF(256)."""

    n: int
    k: int
    backend: str = "numpy"  # "numpy" | "kernel"
    _parity: np.ndarray = field(init=False, repr=False)

    def __post_init__(self) -> None:
        if not (0 < self.k <= self.n <= 256):
            raise ValueError(f"need 0 < k <= n <= 256, got n={self.n} k={self.k}")
        self._parity = cauchy_parity_matrix(self.n, self.k)

    # -- properties ---------------------------------------------------------
    @property
    def m(self) -> int:
        return self.n - self.k

    @property
    def parity_matrix(self) -> np.ndarray:
        return self._parity

    def generator_row(self, idx: int) -> np.ndarray:
        """Row of the full systematic generator [I; P] for fragment ``idx``."""
        if idx < self.k:
            row = np.zeros(self.k, dtype=np.uint8)
            row[idx] = 1
            return row
        return self._parity[idx - self.k].copy()

    # -- core ops ------------------------------------------------------------
    def _matmul(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        if self.backend == "kernel" and A.size and B.shape[1] >= 8:
            from repro.kernels.gf256_matmul import ops as gf_ops

            return np.asarray(gf_ops.gf256_matmul(A, B))
        return gf_matmul_np(A, B)

    def encode(self, data: np.ndarray) -> np.ndarray:
        """(k, L) uint8 -> (n, L) uint8 coded fragments (systematic)."""
        data = np.asarray(data, dtype=np.uint8)
        if data.shape[0] != self.k:
            raise ValueError(f"expected {self.k} data rows, got {data.shape}")
        if self.m == 0:
            return data.copy()
        parity = self._matmul(self._parity, data)
        return np.concatenate([data, parity], axis=0)

    def decode(self, fragments: np.ndarray, indices: list[int]) -> np.ndarray:
        """Reconstruct (k, L) data from any k fragments.

        ``fragments``: (k, L) uint8 rows; ``indices``: their fragment ids in
        [0, n). Raises if fewer than k distinct fragments are supplied.
        """
        fragments = np.asarray(fragments, dtype=np.uint8)
        if len(indices) != len(set(indices)):
            raise ValueError("duplicate fragment indices")
        if fragments.shape[0] < self.k or len(indices) < self.k:
            raise ValueError(
                f"need {self.k} fragments to decode, got {fragments.shape[0]}"
            )
        idxs = list(indices)[: self.k]
        frs = fragments[: self.k]
        if idxs == list(range(self.k)):
            return frs.copy()  # all-systematic fast path
        gen = np.stack([self.generator_row(i) for i in idxs], axis=0)
        dec = gf_invert_matrix(gen)
        return self._matmul(dec, frs)

    def reconstruct_fragment(
        self, target_idx: int, fragments: np.ndarray, indices: list[int]
    ) -> np.ndarray:
        """Rebuild a single lost fragment (server repair path)."""
        data = self.decode(fragments, indices)
        if target_idx < self.k:
            return data[target_idx]
        return self._matmul(self._parity[target_idx - self.k : target_idx - self.k + 1], data)[0]

    # -- bytes-level convenience (object values in the DAPs) -----------------
    def encode_bytes(self, value: bytes) -> tuple[list[bytes], int]:
        rows, orig = bytes_to_rows(value, self.k)
        coded = self.encode(rows)
        return [coded[i].tobytes() for i in range(self.n)], orig

    def decode_bytes(
        self, fragments: dict[int, bytes], orig_len: int
    ) -> bytes:
        idxs = sorted(fragments.keys())[: self.k]
        if len(idxs) < self.k:
            raise ValueError(f"need {self.k} fragments, have {len(idxs)}")
        L = len(fragments[idxs[0]])
        frs = np.stack(
            [np.frombuffer(fragments[i], dtype=np.uint8) for i in idxs], axis=0
        )
        assert frs.shape == (self.k, L)
        data = self.decode(frs, idxs)
        return rows_to_bytes(data, orig_len)
