"""Pallas TPU kernels for the paper's compute hot-spots.

- ``gf256_matmul``: RS encode/decode as a bitsliced GF(2) matmul on the MXU
  (DESIGN.md §3, Adaptation 1). This is the EC-DAP encode/decode hot path the
  paper optimizes in §VI.
- ``cdc_gearhash``: content-defined-chunking rolling hash + boundary bitmap
  as a data-parallel windowed reduction (DESIGN.md §3, Adaptation 2). This is
  the Fragmentation-Module Block-Division hot path (paper §V, BI step 1).

Each kernel package ships ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper) and ``ref.py`` (pure-jnp oracle).
"""
