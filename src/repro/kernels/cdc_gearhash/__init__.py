from repro.kernels.cdc_gearhash.ops import gearhash, boundary_bitmap

__all__ = ["gearhash", "boundary_bitmap"]
