"""Pallas TPU kernel: content-defined-chunking gear hash + boundary bitmap.

The paper's Fragmentation Module splits files with Rabin fingerprints — a
rolling hash that looks sequential. We use the gear/FastCDC form

    h_i = sum_{j=0..W-1} gear(x_{i-j}) << j      (mod 2^32, W = 32)

where left-shifted-out bits vanish, so h_i depends on a *fixed 32-byte
window*: a windowed weighted sum, data-parallel over every position i.
``gear()`` is an arithmetic byte mixer (no LUT — TPU-friendly).

Tiling. Grid over L in blocks of BL. Each step needs bytes
[i*BL - (W-1), (i+1)*BL); Pallas blocks cannot overlap, so the input is
passed twice with different index maps (previous block + current block) and
the kernel stitches the W-1-byte tail. Output: the uint32 hash stream and a
uint8 boundary bitmap (h & mask == 0).

The W shifted adds are vector ALU work: ~W ops/byte with zero HBM
re-reads — memory-bound at 1 byte/position in, 5 bytes/position out
(bitmap-only variant: 1 byte out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WINDOW = 32


def gear_mix(x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic byte -> uint32 mixer (splitmix-ish; no table lookup)."""
    v = x.astype(jnp.uint32)
    v = (v + jnp.uint32(0x9E3779B9)) * jnp.uint32(0x85EBCA6B)
    v = v ^ (v >> 15)
    v = v * jnp.uint32(0xC2B2AE35)
    v = v ^ (v >> 13)
    return v


def _gearhash_kernel(prev_ref, cur_ref, h_ref, b_ref, *, mask: int):
    prev_tail = prev_ref[0, -(WINDOW - 1):]       # (W-1,) bytes of block i-1
    cur = cur_ref[0]                              # (BL,)
    ext = jnp.concatenate([prev_tail, cur])       # (BL + W - 1,)
    g = gear_mix(ext)                             # (BL + W - 1,) uint32
    bl = cur.shape[0]
    h = jnp.zeros((bl,), dtype=jnp.uint32)
    # h[i] = sum_j g_ext[i + (W-1) - j] << j ; j static -> unrolled adds.
    for j in range(WINDOW):
        h = h + (jax.lax.dynamic_slice_in_dim(g, WINDOW - 1 - j, bl) << jnp.uint32(j))
    h_ref[0, :] = h
    b_ref[0, :] = ((h & jnp.uint32(mask)) == 0).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("block_l", "mask", "interpret"))
def gearhash_pallas(
    data: jax.Array, *, block_l: int = 4096, mask: int = 0xFFFF, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """data: (L,) uint8, L % block_l == 0. Returns (hash (L,) uint32,
    boundary bitmap (L,) uint8). Positions < W-1 hash a zero-padded window
    (first block's "previous block" is the first block itself with its tail
    masked to zero via index_map clamping — see below)."""
    L = data.shape[0]
    assert L % block_l == 0, (L, block_l)
    nblk = L // block_l
    # Reshape to (nblk, BL) so block i-1 / block i are plain row indices.
    d2 = data.reshape(nblk, block_l)
    # A zero row is prepended so block 0's "previous" is all-zero padding.
    d2p = jnp.concatenate([jnp.zeros((1, block_l), jnp.uint8), d2], axis=0)
    h, b = pl.pallas_call(
        functools.partial(_gearhash_kernel, mask=mask),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, block_l), lambda i: (i, 0)),      # previous row of d2p
            pl.BlockSpec((1, block_l), lambda i: (i + 1, 0)),  # current row of d2p
        ],
        out_specs=[
            pl.BlockSpec((1, block_l), lambda i: (i, 0)),
            pl.BlockSpec((1, block_l), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, block_l), jnp.uint32),
            jax.ShapeDtypeStruct((nblk, block_l), jnp.uint8),
        ],
        interpret=interpret,
    )(d2p, d2p)
    return h.reshape(L), b.reshape(L)
