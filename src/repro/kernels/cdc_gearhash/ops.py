"""Public wrappers: gear-hash stream, boundary bitmap, and chunk splitting.

``split_chunks`` is what the Fragmentation Module calls: kernel-computed
boundary candidates + a cheap host pass enforcing min/avg/max chunk sizes
(the paper's rabin-fingerprint parameters)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cdc_gearhash.kernel import gearhash_pallas


def _default_backend() -> str:
    # On TPU the Pallas kernel compiles natively; on CPU the jit'd pure-jnp
    # oracle is the fast path (interpret-mode Pallas is for validation only).
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def _mask_for_avg(avg_size: int) -> int:
    """Boundary mask with P(boundary) = 1/avg -> expected chunk ~= avg."""
    bits = max(1, int(np.log2(max(2, avg_size))))
    return (1 << bits) - 1


@functools.partial(jax.jit, static_argnames=("mask",))
def _ref_jit(data, *, mask):
    from repro.kernels.cdc_gearhash.ref import gearhash_ref

    return gearhash_ref(data, mask=mask)


def gearhash(
    data: np.ndarray | bytes, *, mask: int = 0xFFFF, block_l: int = 4096,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Rolling gear hash + boundary bitmap for a byte stream.

    ``interpret=True`` forces the Pallas kernel in interpret mode (test path);
    ``interpret=None`` auto-selects: native kernel on TPU, jit'd ref on CPU.
    """
    if isinstance(data, (bytes, bytearray)):
        data = np.frombuffer(bytes(data), dtype=np.uint8)
    data = jnp.asarray(data, dtype=jnp.uint8)
    L = data.shape[0]
    if interpret is None and _default_backend() == "ref":
        return _ref_jit(data, mask=mask)
    interpret = bool(interpret) if interpret is not None else False
    bl = min(block_l, max(128, 1 << int(np.ceil(np.log2(max(L, 1))))))
    Lp = (L + bl - 1) // bl * bl
    padded = jnp.pad(data, (0, Lp - L))
    h, b = gearhash_pallas(padded, block_l=bl, mask=mask, interpret=interpret)
    return h[:L], b[:L]


def boundary_bitmap(data: np.ndarray | bytes, avg_size: int, **kw) -> np.ndarray:
    h, b = gearhash(data, mask=_mask_for_avg(avg_size), **kw)
    return np.asarray(b)


def split_chunks(
    data: bytes,
    *,
    min_size: int,
    avg_size: int,
    max_size: int,
    interpret: bool | None = None,
) -> list[bytes]:
    """Content-defined chunking with min/avg/max enforcement.

    Kernel emits boundary candidates in parallel; the host pass walks only
    the candidate positions (|candidates| ~= L/avg) applying min/max rules —
    O(L) on device, O(L/avg) on host.
    """
    if not data:
        return [b""]
    bitmap = boundary_bitmap(data, avg_size, interpret=interpret)
    cand = np.nonzero(bitmap)[0]
    chunks: list[bytes] = []
    start = 0
    L = len(data)
    ci = 0
    while start < L:
        lo = start + min_size
        hi = start + max_size
        # first candidate >= lo (strictly inside the chunk) and < hi
        while ci < len(cand) and cand[ci] < lo:
            ci += 1
        if ci < len(cand) and cand[ci] < hi and cand[ci] + 1 < L:
            end = int(cand[ci]) + 1  # boundary position is *inclusive* end
            ci += 1
        else:
            end = min(hi, L)
        chunks.append(data[start:end])
        start = end
    return chunks
