"""Pure-jnp oracle for the gear-hash CDC kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cdc_gearhash.kernel import WINDOW, gear_mix


def gearhash_ref(data: jnp.ndarray, mask: int = 0xFFFF) -> tuple[jnp.ndarray, jnp.ndarray]:
    """data: (L,) uint8 -> (hash (L,) uint32, boundary (L,) uint8).

    h[i] = sum_{j<W} gear(x[i-j]) << j with x[<0] treated as 0-pad.
    """
    data = data.astype(jnp.uint8)
    L = data.shape[0]
    # x[<0] are zero *bytes* (matching the kernel's zero-row padding); note
    # gear(0) != 0, so padding happens in byte space before mixing.
    padded = jnp.concatenate([jnp.zeros((WINDOW - 1,), jnp.uint8), data])
    gp = gear_mix(padded)
    h = jnp.zeros((L,), dtype=jnp.uint32)
    for j in range(WINDOW):
        # gear(x[i-j]) lives at gp[i + W-1 - j]
        h = h + (jax.lax.dynamic_slice_in_dim(gp, WINDOW - 1 - j, L) << jnp.uint32(j))
    b = ((h & jnp.uint32(mask)) == 0).astype(jnp.uint8)
    return h, b
