"""Pallas TPU kernel: fused flash attention (online softmax, scores in VMEM).

Why (EXPERIMENTS.md §Perf): the dry-run byte profile shows materialized
softmax chains are the #1 memory-term contributor on every attention arch
(e.g. 950 GB of qwen3-0.6b's 2.9 TB/step). A fused kernel streams Q/K/V once
and never writes the (Sq, Sk) score matrix to HBM: the attention memory term
collapses from O(Sq*Sk) to O(Sq*hd + Sk*hd) per head.

Layout. Grid (B, H, Sq/BQ). Per step: the q block (BQ, hd) and the FULL
per-head K/V (Sk, hd) are resident in VMEM (v5e ~16 MB: Sk=8k, hd=128 bf16
-> 2 x 2 MB; longer Sk would add a KV grid axis with output revisiting).
The kernel runs the classic online-softmax recurrence over KV tiles with an
f32 accumulator in registers/VMEM scratch:

    m' = max(m, rowmax(S));  l' = l*e^(m-m') + rowsum(e^(S-m'))
    acc' = acc*e^(m-m') + e^(S-m') @ V

Causality/window masking is applied per tile from the absolute positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, sk: int,
                  causal: bool, window: int, scale: float):
    iq = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (BQ, hd)
    q_pos = iq * bq + jax.lax.iota(jnp.int32, bq)
    nk = sk // bk

    def body(j, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], j * bk, bk, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], j * bk, bk, 0)
        s = q @ k.astype(jnp.float32).T                   # (BQ, BK) in VMEM
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        ok = jnp.ones((bq, bk), bool)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            ok &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(ok, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, q.shape[1]), jnp.float32)
    m0 = jnp.full((bq,), NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "causal", "window", "interpret")
)
def flash_attention_pallas(
    q, k, v, *, bq: int = 128, bk: int = 128, causal: bool = True,
    window: int = 0, interpret: bool = False,
):
    """q (B, H, Sq, hd); k/v (B, H, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = float(1.0 / (hd ** 0.5))
    grid = (B, H, Sq // bq)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, bq=bq, bk=bk, sk=Sk, causal=causal,
            window=window, scale=scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
