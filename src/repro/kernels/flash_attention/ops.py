"""Public wrapper: fused flash attention.

TPU: native Pallas kernel. CPU (this container): the kernel runs under
interpret=True for validation; production CPU/dry-run paths use the blocked
jnp attention in ``repro.models.layers.gqa_attention`` (the dry-run cannot
compile TPU Pallas custom-calls — the roofline's flash-adjusted memory term
is derived analytically in EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(
        q, k, v, bq=bq, bk=bk, causal=causal, window=window, interpret=interpret
    )
