"""Pure-jnp oracle for the flash attention kernel (f32 softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B, H, Sq, hd); k/v (B, H, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(ok[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)
