from repro.kernels.gf256_matmul.ops import gf256_matmul, rs_encode_parity

__all__ = ["gf256_matmul", "rs_encode_parity"]
