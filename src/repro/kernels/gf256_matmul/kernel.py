"""Pallas TPU kernel: GF(256) matmul as a bitsliced GF(2) MXU matmul.

Problem. RS encode/decode is ``C[m, L] = A[m, k] (x) B[k, L]`` over GF(256)
(XOR-accumulate of LUT products). Per-byte LUTs are hostile to the TPU vector
unit (no fast gather); instead we exploit that GF(256) is an 8-dim GF(2)
vector space: multiplication by each constant ``A[r, c]`` is an 8x8 bit
matrix, so

    bits(C)[8m, L] = ( Abits[8m, 8k] @ bits(B)[8k, L] ) mod 2,

an ordinary 0/1 f32 matmul (exact: row sums <= 8k << 2^24) followed by a
parity extraction — which the MXU eats at full rate.

Layout / tiling.
 * ``Abits`` is tiny (8m x 8k, m,k <= 32) and precomputed host-side
   (``erasure.gf.gf_matrix_to_bitmatrix``); it is padded up to the sublane
   tile (8,128 for f32) and kept whole in VMEM for every grid step.
 * ``B`` (uint8, k x L) is blocked along L only: block (k, BL). Bits are
   unpacked *in-kernel* (shift+mask, 8x expansion along the tiny k axis —
   never along L), so HBM traffic is 1 byte per input byte, not 8.
 * Output block (m, BL) uint8 is packed in-kernel.

Grid: (L // BL,). VMEM per step ~= BL*(k + 8k*4 + 8m*4 + m) bytes; with
BL=2048, k=n-k=16: ~1.3 MB — comfortably inside the ~16 MB v5e VMEM budget,
leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gf2_matmul_kernel(abits_ref, b_ref, out_ref, *, m: int, k: int, kpad: int):
    """One (k, BL) -> (m, BL) block of the bitsliced product."""
    b = b_ref[...].astype(jnp.int32)  # (k, BL) bytes as int32
    bl = b.shape[-1]
    # Unpack bits little-endian along a new axis folded into the k axis:
    # Dbits[8r + j, :] = (B[r, :] >> j) & 1   -> (8k, BL)
    shifts = jnp.arange(8, dtype=jnp.int32).reshape(1, 8, 1)
    dbits = ((b[:, None, :] >> shifts) & 1).reshape(8 * k, bl).astype(jnp.float32)
    if kpad > 8 * k:
        dbits = jnp.pad(dbits, ((0, kpad - 8 * k), (0, 0)))
    # MXU matmul; f32 accumulation is exact for 0/1 operands at these depths.
    acc = jax.lax.dot_general(
        abits_ref[...],
        dbits,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (8m_pad, BL)
    # mod-2 parity of the integer-valued accumulator.
    par = acc.astype(jnp.int32) & 1  # (8m_pad, BL)
    par = par[: 8 * m]
    # Pack bits back to bytes: C[r, :] = sum_j par[8r + j, :] << j
    weights = (jnp.int32(1) << jnp.arange(8, dtype=jnp.int32)).reshape(1, 8, 1)
    packed = (par.reshape(m, 8, bl) * weights).sum(axis=1)
    out_ref[...] = packed.astype(jnp.uint8)


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


@functools.partial(jax.jit, static_argnames=("m", "k", "block_l", "interpret"))
def gf2_bitsliced_matmul(
    abits_padded: jax.Array,
    b: jax.Array,
    *,
    m: int,
    k: int,
    block_l: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """C = A (x) B over GF(256), with A given as its padded GF(2) bit matrix.

    abits_padded: (8m_pad, 8k_pad) f32 0/1 (pad rows/cols zero).
    b:            (k, L) uint8, L % block_l == 0 (caller pads).
    returns:      (m, L) uint8.
    """
    kL = b.shape[1]
    assert kL % block_l == 0, (kL, block_l)
    mpad8, kpad8 = abits_padded.shape
    grid = (kL // block_l,)
    return pl.pallas_call(
        functools.partial(_gf2_matmul_kernel, m=m, k=k, kpad=kpad8),
        grid=grid,
        in_specs=[
            # A-bits: whole matrix every step (tiny, stays resident in VMEM).
            pl.BlockSpec((mpad8, kpad8), lambda i: (0, 0)),
            # B: one (k, BL) stripe per step.
            pl.BlockSpec((b.shape[0], block_l), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_l), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, kL), jnp.uint8),
        interpret=interpret,
    )(abits_padded, b)
