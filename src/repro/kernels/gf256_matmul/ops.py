"""Public jit'd wrappers for the bitsliced GF(256) matmul kernel.

``gf256_matmul(A, B)`` — drop-in GF(256) matrix product; host-side prep
(bit-matrix expansion of the tiny A, L padding) + the Pallas kernel.
``rs_encode_parity(parity_matrix, data)`` — the RS encode hot path.
``gf256_coding_matmul(A, B)`` — what the storage data path's "kernel"/"auto"
coding backend dispatches to (see ``repro.erasure.rs``): the Pallas kernel
where it compiles natively (TPU), the jit'd XLA LUT formulation on CPU —
``interpret=True`` Pallas is a correctness harness, orders of magnitude
slower than either, and never a production path.

All paths are bit-identical to ``ref.gf256_matmul_ref`` (and to the numpy
LUT reference ``erasure.gf.gf_matmul_np``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.erasure.gf import gf_matrix_to_bitmatrix
from repro.kernels.gf256_matmul.kernel import _round_up, gf2_bitsliced_matmul

# f32 VMEM tile is (8, 128); pad the bit-matrix to it.
_SUBLANE, _LANE = 8, 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def kernel_is_native() -> bool:
    """True when the Pallas kernel compiles for real hardware (TPU). Gates
    production dispatch and the block-diagonal group fusion in RSCode."""
    return jax.default_backend() == "tpu"


def _validate_shapes(A: np.ndarray, B) -> None:
    # ValueError, not assert: shape bugs must not vanish under ``python -O``
    # and surface later as wrong-shaped kernel output.
    if A.ndim != 2:
        raise ValueError(f"A must be a 2-D (m, k) matrix, got shape {A.shape}")
    if getattr(B, "ndim", None) != 2:
        raise ValueError(f"B must be a 2-D (k, L) matrix, got shape {getattr(B, 'shape', None)}")
    if B.shape[0] != A.shape[1]:
        raise ValueError(
            f"inner dimensions disagree: A is {A.shape}, B is {tuple(B.shape)}"
        )


@functools.lru_cache(maxsize=128)
def _abits_cached(a_bytes: bytes, m: int, k: int) -> np.ndarray:
    A = np.frombuffer(a_bytes, dtype=np.uint8).reshape(m, k)
    bits = gf_matrix_to_bitmatrix(A).astype(np.float32)  # (8m, 8k)
    mp = _round_up(8 * m, _SUBLANE)
    kp = _round_up(8 * k, _LANE)
    out = np.zeros((mp, kp), dtype=np.float32)
    out[: 8 * m, : 8 * k] = bits
    return out


def gf256_matmul(
    A: np.ndarray,
    B: np.ndarray | jax.Array,
    *,
    block_l: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """GF(256) matrix product C = A (x) B. A: (m, k) uint8 (host, small);
    B: (k, L) uint8 (device, large). Returns (m, L) uint8."""
    if interpret is None:
        interpret = _default_interpret()
    A = np.asarray(A, dtype=np.uint8)
    B = jnp.asarray(B, dtype=jnp.uint8)
    _validate_shapes(A, B)
    m, k = A.shape
    L = B.shape[1]
    if m == 0 or L == 0 or k == 0:
        # degenerate shapes the storage path can produce (m == 0 codes,
        # empty values): the product is an empty/zero matrix — don't hand
        # a zero-sized grid to Pallas.
        return jnp.zeros((m, L), dtype=jnp.uint8)
    # Block size: shrink for small inputs (interpret-mode tests), keep
    # lane-aligned where possible.
    bl = min(block_l, _round_up(L, _LANE))
    Lp = _round_up(L, bl)
    if Lp != L:
        B = jnp.pad(B, ((0, 0), (0, Lp - L)))
    abits = jnp.asarray(_abits_cached(A.tobytes(), m, k))
    out = gf2_bitsliced_matmul(abits, B, m=m, k=k, block_l=bl, interpret=interpret)
    return out[:, :L]


@functools.lru_cache(maxsize=1)
def _jit_ref():
    from repro.kernels.gf256_matmul.ref import gf256_matmul_ref

    return jax.jit(gf256_matmul_ref)


def gf256_coding_matmul(A: np.ndarray, B: np.ndarray, *, block_l: int = 2048) -> jax.Array:
    """GF(256) matmul as dispatched by the storage data path (RSCode
    backend "kernel"/"auto").

    TPU: the native Pallas bitsliced kernel. CPU: the jit'd XLA LUT
    formulation — measured 3-10x the numpy byte-LUT from ~16 KiB operands on
    the reference container (``benchmarks/bench_kernels.py``). L is bucketed
    to powers of two (zero-pad, slice after — GF matmul is column-wise, so
    padding columns is bit-identical) to bound jit retraces across ragged
    batch widths to O(log L) compilations per (m, k).
    """
    A = np.asarray(A, dtype=np.uint8)
    B = np.asarray(B, dtype=np.uint8)
    _validate_shapes(A, B)
    m, k = A.shape
    L = B.shape[1]
    if m == 0 or L == 0 or k == 0:
        return jnp.zeros((m, L), dtype=jnp.uint8)
    if kernel_is_native():
        return gf256_matmul(A, B, block_l=block_l, interpret=False)
    Lp = max(_LANE, 1 << (L - 1).bit_length())
    if Lp != L:
        Bp = np.zeros((k, Lp), dtype=np.uint8)
        Bp[:, :L] = B
        B = Bp
    out = _jit_ref()(jnp.asarray(A), jnp.asarray(B))
    return out[:, :L]


def rs_encode_parity(
    parity_matrix: np.ndarray, data: np.ndarray | jax.Array, **kw
) -> jax.Array:
    """Parity rows for a systematic RS code: P = parity_matrix (x) data."""
    return gf256_matmul(parity_matrix, data, **kw)
