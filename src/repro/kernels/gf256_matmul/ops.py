"""Public jit'd wrappers for the bitsliced GF(256) matmul kernel.

``gf256_matmul(A, B)`` — drop-in GF(256) matrix product; host-side prep
(bit-matrix expansion of the tiny A, L padding) + the Pallas kernel.
``rs_encode_parity(parity_matrix, data)`` — the RS encode hot path.

On CPU (this container) the kernel runs in ``interpret=True`` mode; on TPU it
compiles natively. Both are bit-identical to ``ref.gf256_matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.erasure.gf import gf_matrix_to_bitmatrix
from repro.kernels.gf256_matmul.kernel import _round_up, gf2_bitsliced_matmul

# f32 VMEM tile is (8, 128); pad the bit-matrix to it.
_SUBLANE, _LANE = 8, 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.lru_cache(maxsize=128)
def _abits_cached(a_bytes: bytes, m: int, k: int) -> np.ndarray:
    A = np.frombuffer(a_bytes, dtype=np.uint8).reshape(m, k)
    bits = gf_matrix_to_bitmatrix(A).astype(np.float32)  # (8m, 8k)
    mp = _round_up(8 * m, _SUBLANE)
    kp = _round_up(8 * k, _LANE)
    out = np.zeros((mp, kp), dtype=np.float32)
    out[: 8 * m, : 8 * k] = bits
    return out


def gf256_matmul(
    A: np.ndarray,
    B: np.ndarray | jax.Array,
    *,
    block_l: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """GF(256) matrix product C = A (x) B. A: (m, k) uint8 (host, small);
    B: (k, L) uint8 (device, large). Returns (m, L) uint8."""
    if interpret is None:
        interpret = _default_interpret()
    A = np.asarray(A, dtype=np.uint8)
    m, k = A.shape
    B = jnp.asarray(B, dtype=jnp.uint8)
    assert B.shape[0] == k, (A.shape, B.shape)
    L = B.shape[1]
    # Block size: shrink for small inputs (interpret-mode tests), keep
    # lane-aligned where possible.
    bl = min(block_l, _round_up(L, _LANE))
    Lp = _round_up(L, bl)
    if Lp != L:
        B = jnp.pad(B, ((0, 0), (0, Lp - L)))
    abits = jnp.asarray(_abits_cached(A.tobytes(), m, k))
    out = gf2_bitsliced_matmul(abits, B, m=m, k=k, block_l=bl, interpret=interpret)
    return out[:, :L]


def rs_encode_parity(
    parity_matrix: np.ndarray, data: np.ndarray | jax.Array, **kw
) -> jax.Array:
    """Parity rows for a systematic RS code: P = parity_matrix (x) data."""
    return gf256_matmul(parity_matrix, data, **kw)
