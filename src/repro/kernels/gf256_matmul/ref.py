"""Pure-jnp oracle for the GF(256) matmul kernel (log/antilog LUT model)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.erasure.gf import EXP_TABLE, LOG_TABLE

_EXP_J = jnp.asarray(EXP_TABLE)  # (512,) uint8
_LOG_J = jnp.asarray(LOG_TABLE)  # (256,) int32


def gf_mul_jnp(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Elementwise GF(256) product (uint8 in/out, broadcasting)."""
    a = a.astype(jnp.uint8)
    b = b.astype(jnp.uint8)
    nz = (a != 0) & (b != 0)
    prod = _EXP_J[_LOG_J[a.astype(jnp.int32)] + _LOG_J[b.astype(jnp.int32)]]
    return jnp.where(nz, prod, jnp.uint8(0))


def gf256_matmul_ref(A: np.ndarray | jnp.ndarray, B: np.ndarray | jnp.ndarray) -> jnp.ndarray:
    """C[i, j] = XOR_k A[i, k] * B[k, j] over GF(256). A: (m, k), B: (k, L)."""
    A = jnp.asarray(A, dtype=jnp.uint8)
    B = jnp.asarray(B, dtype=jnp.uint8)
    m, k = A.shape
    out = jnp.zeros((m, B.shape[1]), dtype=jnp.uint8)
    for i in range(k):  # k is small & static: unrolled XOR fold
        out = out ^ gf_mul_jnp(A[:, i][:, None], B[i][None, :])
    return out
