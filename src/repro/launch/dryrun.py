import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we jit the right step function (train_step for train shapes,
prefill_step for prefill, serve_step for decode/long) against the production
mesh with full in/out shardings, ``.lower().compile()`` it on 512 host
placeholder devices, and record:

  * memory_analysis()  — proves the step fits per-chip HBM,
  * cost_analysis()    — FLOPs / bytes for the §Roofline terms,
  * collective bytes   — parsed from the optimized HLO (scan-weighted),
  * the roofline report (compute/memory/collective seconds, dominant term).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_0_5b \
      --shape train_4k [--multi-pod] [--out runs/dryrun]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_arch
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model, input_specs
from repro.models.sharding import MeshCtx
from repro.roofline.analysis import V5E, roofline_report
from repro.roofline.hlo_parse import analyze as analyze_hlo
from repro.train.steps import (
    batch_shardings,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    training_state_shapes,
    training_state_specs,
)


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"status": "skipped", "reason": "pure full-attention arch (DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = MeshCtx(mesh)
    model = build_model(cfg, max_pos=shape.seq_len)
    ispecs = input_specs(cfg, shape)
    bshard = batch_shardings(cfg, shape, ctx)
    t0 = time.time()
    if shape.kind == "train":
        pshapes, oshapes = training_state_shapes(model)
        pspecs, ospecs = training_state_specs(model, ctx)
        step = make_train_step(model, ctx)
        jitted = jax.jit(
            step,
            in_shardings=(pspecs, ospecs, bshard),
            out_shardings=(pspecs, ospecs, ctx.replicated()),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(pshapes, oshapes, ispecs)
    elif shape.kind == "prefill":
        pshapes = model.param_shapes()
        pspecs = model.param_specs(ctx, serve=True)
        step = make_prefill_step(model, ctx)
        jitted = jax.jit(
            step,
            in_shardings=(pspecs, bshard),
            out_shardings=ctx.ns(*(ctx.token_spec(shape.global_batch)[0:1]), None)
            if shape.global_batch % ctx.n_batch == 0
            else ctx.replicated(),
        )
        lowered = jitted.lower(pshapes, ispecs)
    else:  # decode
        pshapes = model.param_shapes()
        pspecs = model.param_specs(ctx, serve=True)
        B, S = shape.global_batch, shape.seq_len
        ctmpl = model.cache_template(B, S)
        cshapes = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in ctmpl.items()}
        cspecs = model.cache_specs(B, S, ctx)
        step = make_serve_step(model, ctx)
        logits_spec = (
            ctx.ns(ctx.batch_axes, None)
            if B % ctx.n_batch == 0 and B >= ctx.n_batch
            else ctx.replicated()
        )
        jitted = jax.jit(
            step,
            in_shardings=(pspecs, cspecs, bshard),
            out_shardings=(logits_spec, cspecs),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(pshapes, cshapes, ispecs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # ---- analyses --------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # cost_analysis counts while bodies once; use the scan-weighted HLO
    # analysis for the roofline terms (see roofline/hlo_parse.py).
    weighted = analyze_hlo(hlo)
    flops = float(weighted["flops"])
    bytes_accessed = float(weighted["hbm_bytes"])
    coll = weighted["collective_bytes"]
    coll_total = float(weighted["collective_bytes_total"])
    n_chips = int(np.prod(list(mesh.shape.values())))
    nmodel = model.n_active_params()
    # MODEL_FLOPS: 6·N·D tokens for train; 2·N·D for forward-only
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    factor = 6 if shape.kind == "train" else 2
    model_flops = factor * nmodel * tokens
    report = roofline_report(
        flops=flops, bytes_accessed=bytes_accessed, collective_bytes=coll_total,
        n_chips=n_chips, model_flops=model_flops,
    )
    per_chip_hbm = (
        mem_d.get("argument_size_in_bytes", 0)
        + mem_d.get("temp_size_in_bytes", 0)
        + mem_d.get("output_size_in_bytes", 0)
        - mem_d.get("alias_size_in_bytes", 0)
    )
    return {
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_d,
        "per_chip_live_bytes": int(per_chip_hbm),
        "fits_hbm": bool(per_chip_hbm <= V5E.hbm_bytes),
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_accessed,
        "collective_bytes": coll,
        "collective_bytes_total": coll_total,
        "cost_analysis_raw": {
            "flops_unweighted": float(cost.get("flops", 0.0)),
            "bytes_unweighted": float(cost.get("bytes accessed", 0.0)),
        },
        "unknown_trip_whiles": weighted["unknown_trip_whiles"],
        "model_flops": model_flops,
        "n_active_params": nmodel,
        "roofline": report,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    mesh_tag = "pod2" if args.multi_pod else "pod1"
    path = outdir / f"{args.arch}__{args.shape}__{mesh_tag}.json"
    try:
        res = lower_cell(args.arch, args.shape, args.multi_pod)
    except Exception as e:
        res = {
            "status": "error",
            "arch": args.arch,
            "shape": args.shape,
            "mesh": mesh_tag,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    path.write_text(json.dumps(res, indent=2, default=str))
    ok = res["status"]
    print(f"[{ok}] {args.arch} {args.shape} {mesh_tag}")
    if ok == "ok":
        print(json.dumps({k: res[k] for k in ("per_chip_live_bytes", "fits_hbm",
                                              "flops_per_chip", "collective_bytes_total")},
                         indent=2))
        print("memory_analysis:", json.dumps(res["memory"]))
        print("roofline:", json.dumps(res["roofline"]))
    elif ok == "error":
        print(res["error"])
        print(res["traceback"][-1500:])


if __name__ == "__main__":
    main()
