import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Debug tool: compile one cell and list the largest HLO buffers.

  PYTHONPATH=src python -m repro.launch.hlo_buffers --arch X --shape Y [--multi-pod]
"""
import argparse
import re

import jax

from repro.configs import get_arch
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models.registry import build_model, input_specs
from repro.models.sharding import MeshCtx
from repro.roofline.hlo_parse import _DTYPE_BYTES, _SHAPE_RE
from repro.train.steps import (
    batch_shardings,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    training_state_shapes,
    training_state_specs,
)


def compile_cell(arch: str, shape_name: str, multi_pod: bool = False):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = MeshCtx(mesh)
    model = build_model(cfg, max_pos=shape.seq_len)
    ispecs = input_specs(cfg, shape)
    bshard = batch_shardings(cfg, shape, ctx)
    if shape.kind == "train":
        pshapes, oshapes = training_state_shapes(model)
        pspecs, ospecs = training_state_specs(model, ctx)
        step = make_train_step(model, ctx)
        jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bshard),
                         out_shardings=(pspecs, ospecs, ctx.replicated()),
                         donate_argnums=(0, 1))
        return jitted.lower(pshapes, oshapes, ispecs).compile(), model, ctx
    if shape.kind == "prefill":
        pshapes = model.param_shapes()
        pspecs = model.param_specs(ctx, serve=True)
        step = make_prefill_step(model, ctx)
        jitted = jax.jit(step, in_shardings=(pspecs, bshard))
        return jitted.lower(pshapes, ispecs).compile(), model, ctx
    pshapes = model.param_shapes()
    pspecs = model.param_specs(ctx, serve=True)
    B, S = shape.global_batch, shape.seq_len
    ctmpl = model.cache_template(B, S)
    cshapes = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in ctmpl.items()}
    cspecs = model.cache_specs(B, S, ctx)
    step = make_serve_step(model, ctx)
    jitted = jax.jit(step, in_shardings=(pspecs, cspecs, bshard),
                     out_shardings=(ctx.replicated() if B % ctx.n_batch else
                                    ctx.ns(ctx.batch_axes, None), cspecs),
                     donate_argnums=(1,))
    return jitted.lower(pshapes, cshapes, ispecs).compile(), model, ctx


def list_buffers(hlo_text: str, top: int = 20, min_gb: float = 0.2):
    best: dict[str, tuple[int, int, str]] = {}
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT )?%[\w.\-]+ = ", line)
        if not m:
            continue
        head = line.split(" = ", 1)[1]
        shape_txt = head.split("(")[0]
        tot = 0
        for dt, dims in _SHAPE_RE.findall(shape_txt):
            if dt in _DTYPE_BYTES:
                n = 1
                for d in dims.split(",") if dims else []:
                    n *= int(d)
                tot += n * _DTYPE_BYTES[dt]
        if tot < min_gb * 1e9:
            continue
        key = shape_txt.strip()[:64]
        md = re.search(r'op_name="([^"]*)"', line)
        cnt = best.get(key, (0, 0, ""))[1]
        best[key] = (tot, cnt + 1, (md.group(1) if md else "")[:110])
    rows = sorted(best.items(), key=lambda kv: -kv[1][0])[:top]
    for k, (t, c, src) in rows:
        print(f"{t/1e9:7.2f} GB x{c:3d}  {k}\n                   {src}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=16)
    args = ap.parse_args()
    compiled, model, ctx = compile_cell(args.arch, args.shape, args.multi_pod)
    mem = compiled.memory_analysis()
    print("temp bytes:", getattr(mem, "temp_size_in_bytes", "?"))
    list_buffers(compiled.as_text(), top=args.top)
