"""Production mesh construction (function, not module-level constant — meshes
must never touch jax device state at import time)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod). Multi-pod:
    (pod=2, data=16, model=16) = 512 chips; "pod" is the outermost
    data-parallel axis (gradients reduce hierarchically: in-pod ICI first,
    then cross-pod DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke usage of mesh-parameterized code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
