"""Batched serving driver: prefill-free decode demo with a KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.registry import build_model
from repro.train.steps import make_serve_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg, max_pos=args.cache_len)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = args.batch, args.cache_len
    tmpl = model.cache_template(B, S)
    cache = {k: jnp.zeros(shape, dtype) for k, (shape, dtype) in tmpl.items()}
    step = jax.jit(make_serve_step(model, None))
    rng = np.random.default_rng(0)
    if cfg.embeddings_input:
        batch = {"embed": jnp.asarray(rng.standard_normal((B, cfg.d_model)) * 0.02,
                                      jnp.bfloat16)}
    else:
        batch = {"token": jnp.asarray(rng.integers(0, cfg.vocab, B), jnp.int32)}
    out_tokens = []
    t0 = time.time()
    for i in range(args.tokens):
        batch["cur_len"] = jnp.asarray(i, jnp.int32)
        logits, cache = step(params, cache, batch)
        nxt = jnp.argmax(logits, axis=-1)
        out_tokens.append(np.asarray(nxt))
        if not cfg.embeddings_input:
            batch["token"] = nxt.astype(jnp.int32)
    dt = time.time() - t0
    toks = np.stack(out_tokens, axis=1)
    print(f"[serve] {cfg.name}: {args.tokens} tokens x batch {B} in {dt:.2f}s "
          f"({args.tokens*B/dt:.1f} tok/s on CPU, reduced config)")
    print("[serve] sample:", toks[0][:16].tolist())
    return {"tokens": toks, "seconds": dt}


if __name__ == "__main__":
    main()
