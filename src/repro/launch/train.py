"""End-to-end training driver with EC-coded quorum checkpointing.

CPU-scale by default (reduced config) so it is runnable here:

  PYTHONPATH=src python -m repro.launch.train --arch gemma3_1b --steps 50 \
      --ckpt-every 20 [--crash-at 30] [--compress-grads] [--full]

``--full`` uses the full architecture config (for real clusters).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.models.registry import build_model
from repro.train.checkpoint import ECCheckpointStore
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-hosts", type=int, default=8)
    ap.add_argument("--ckpt-parity", type=int, default=2)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate trainer crash+restore at this step")
    ap.add_argument("--kill-hosts", type=int, default=0,
                    help="crash this many checkpoint hosts before restore")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg, max_pos=args.seq)
    shape = ShapeConfig("drv", args.seq, args.batch, "train")
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    opt_cfg = AdamWConfig(lr=args.lr)
    if args.compress_grads:
        # error-feedback int8 gradient compression around the DP reduction
        # (here: demonstrated on the single-host loop; at scale the compress
        # wraps the cross-pod all-reduce — see train/compress.py).
        from repro.train import compress as gc_mod
        from repro.train.optimizer import adamw_update as _upd

        def step_raw(params, opt_state, residuals, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch))(params)
            qs, scales, residuals = gc_mod.compress_tree(grads, residuals)
            grads = gc_mod.decompress_tree(qs, scales, grads)
            params, opt_state = _upd(params, grads, opt_state, opt_cfg)
            return params, opt_state, residuals, loss

        residuals = None
        _jit = jax.jit(step_raw)

        def step_fn(params, opt_state, batch):
            nonlocal residuals
            if residuals is None:
                _, g0 = jax.value_and_grad(lambda p: model.loss_fn(p, batch))(params)
                residuals = gc_mod.init_residuals(g0)
            params, opt_state, residuals, loss = _jit(params, opt_state,
                                                      residuals, batch)
            return params, opt_state, loss
    else:
        step_fn = jax.jit(make_train_step(model, None, opt_cfg))
    store = ECCheckpointStore(n_hosts=args.ckpt_hosts, parity=args.ckpt_parity)
    print(f"[train] {cfg.name} reduced={not args.full} params="
          f"{model.n_params()/1e6:.1f}M fault_budget={store.fault_budget()} hosts")

    losses = []
    ckpt_stats = []
    step = 0
    t0 = time.time()
    while step < args.steps:
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        step += 1
        if args.ckpt_every and step % args.ckpt_every == 0:
            st = store.save(step, {"params": params, "opt": opt_state,
                                   "data": data.state()})
            ckpt_stats.append(st)
            print(f"[ckpt] step={step} {st.bytes_written/1e6:.2f} MB in "
                  f"{st.virtual_seconds*1e3:.1f} virtual-ms, "
                  f"{st.blocks_written}/{st.blocks_total} blocks rewritten")
        if args.crash_at and step == args.crash_at:
            print(f"[crash] trainer dies at step {step}; "
                  f"{args.kill_hosts} checkpoint hosts die too")
            if args.kill_hosts:
                store.crash_hosts([f"s{i}" for i in range(args.kill_hosts)])
            restored = store.restore()
            assert restored is not None, "restore failed"
            rstep, st2 = restored
            params = jax.tree.map(jnp.asarray, st2["params"])
            opt_state = jax.tree.map(jnp.asarray, st2["opt"])
            opt_state["step"] = jnp.asarray(opt_state["step"])
            data.restore(st2["data"])
            print(f"[restore] resumed from step {rstep} (k-of-n decode OK)")
            step = rstep
            args.crash_at = 0  # once
    dt = time.time() - t0
    print(f"[done] {args.steps} steps in {dt:.1f}s wall; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return {"losses": losses, "ckpts": ckpt_stats}


if __name__ == "__main__":
    main()
