"""Pure-JAX model zoo for the 10 assigned architectures.

Functional style: params are pytrees of jnp arrays; layer weights are stacked
along a leading ``n_layers`` axis and consumed by ``jax.lax.scan`` (small HLO,
fast compiles, remat-friendly). Sharding is expressed as best-effort
``NamedSharding`` constraints computed per (config, mesh) by
``repro.models.sharding.ShardingPlan``.
"""
from repro.models.registry import build_model

__all__ = ["build_model"]
