"""Shared layer library: norms, RoPE variants, GQA attention, MLP, MoE.

Conventions: activations bf16, reductions/softmax/norms in f32. Weight trees
are plain dicts; stacked-layer weights carry a leading L axis for lax.scan.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.sharding import shard_map_compat


def dt(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------- norms
def rms_norm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * inv) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- RoPE
def rope_cos_sin(positions, n_freq: int, theta: float):
    """positions (..., S) int32 -> cos/sin (..., S, n_freq) f32."""
    freqs = 1.0 / (theta ** (jnp.arange(n_freq, dtype=jnp.float32) / n_freq))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions, sections: tuple[int, ...], theta: float):
    """Qwen2-VL M-RoPE: positions (3, B, S) — temporal/height/width streams.

    The hd/2 frequency slots are split into ``sections``; slot group g takes
    its rotation angle from position stream g. [arXiv:2409.12191]
    """
    n_freq = sum(sections)
    freqs = 1.0 / (theta ** (jnp.arange(n_freq, dtype=jnp.float32) / n_freq))
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (3, B, S, n_freq)
    parts = []
    start = 0
    for g, width in enumerate(sections):
        parts.append(ang_all[g, ..., start : start + width])
        start += width
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, n_freq)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, fraction: float = 1.0):
    """x (B, S, H, hd); cos/sin (B, S, hd_rot/2). Half-split (LLaMA) style.

    ``fraction < 1`` (chatglm3 "RoPE 2d"): rotate only the first
    ``hd * fraction`` dims, pass the rest through.
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    x1, x2 = xr[..., :half], xr[..., half:]
    c = cos[:, :, None, :].astype(jnp.float32)
    s = sin[:, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    y1 = x1f * c - x2f * s
    y2 = x2f * c + x1f * s
    out = jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
    if rot < hd:
        out = jnp.concatenate([out, xp], axis=-1)
    return out


# ------------------------------------------------------------- attention
def _mask_bias(q_pos, k_pos, window, causal: bool):
    """Additive f32 bias (…, Sq, Sk): 0 where attendable, -1e30 elsewhere.

    ``window`` may be a *traced* scalar (gemma3 alternates local/global
    windows across scanned layers, so it is data, not Python control flow).
    """
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def gqa_attention(
    q, k, v, *, q_pos, k_pos, causal: bool = True, window=None,
    q_chunk: int = 1024, ctx=None, score_dtype=jnp.bfloat16,
):
    """Grouped-query attention. q (B,Sq,H,hd); k/v (B,Sk,KV,hd).

    Perf-iterated (see EXPERIMENTS.md §Perf):
      * KV heads are expanded to H up front so q/k/v/scores all shard
        uniformly on the heads axis — mixed head/head_dim shardings
        otherwise leave the (B,H,Sq,Sk) scores replicated per chip
        (observed: 34 GB/layer on qwen3-moe);
      * the score chain runs in bf16 (max is exact; exp elementwise; the
        softmax DENOMINATOR accumulates in f32), dots carry
        preferred_element_type — on TPU the MXU accumulates f32 internally
        and rounds the output, so this is the native bf16-matmul behaviour
        at half the HBM traffic of f32 scores;
      * q-chunked with remat: score buffers are bounded to
        (B, H, q_chunk, Sk) and recomputed in the backward (flash-attention
        memory behaviour, in pure JAX).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    # KV->H expansion ONLY when the kv heads cannot shard over "model" but
    # the full heads can: mixed q/k shardings otherwise leave the score
    # tensor replicated. When KV itself divides (or nothing does), the
    # grouped einsum stays — expansion would multiply k/v bytes by G for no
    # sharding benefit (refuted-hypothesis record in EXPERIMENTS.md §Perf).
    expand = False
    if ctx is not None and G > 1:
        expand = (KV % ctx.n_model != 0) and (H % ctx.n_model == 0)
    if expand:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        KV_eff, G_eff = H, 1
    else:
        KV_eff, G_eff = KV, G
    if ctx is not None and KV_eff % ctx.n_model == 0 and Sq > 1:
        spec = (ctx.batch_axes, None, "model", None)
        k = ctx.constrain(k, *spec)
        v = ctx.constrain(v, *spec)
    scale = np.float32(1.0 / np.sqrt(hd))

    def attend(q_blk, qp_blk):
        # q_blk (B, Sc, KV_eff, G_eff, hd)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", q_blk, k, preferred_element_type=score_dtype,
            optimize=True,
        )
        bias = _mask_bias(qp_blk, k_pos, window, causal).astype(score_dtype)
        s = s * score_dtype(scale) + bias[None, None, None]
        m = jnp.max(s, axis=-1, keepdims=True)          # exact in bf16
        e = jnp.exp(s - m)
        den = jnp.sum(e, axis=-1, keepdims=True, dtype=jnp.float32)  # f32 acc
        w = e / den.astype(score_dtype)
        out = jnp.einsum(
            "bkgqs,bskd->bqkgd", w, v, preferred_element_type=jnp.float32,
            optimize=True,
        )
        return out.astype(q.dtype)

    qg = q.reshape(B, Sq, KV_eff, G_eff, hd)
    if Sq <= q_chunk:
        out = attend(qg, q_pos)
    else:
        n = Sq // q_chunk
        assert Sq % q_chunk == 0, (Sq, q_chunk)
        qs = qg.reshape(B, n, q_chunk, KV_eff, G_eff, hd).transpose(1, 0, 2, 3, 4, 5)
        ps = q_pos.reshape(n, q_chunk)
        body = jax.checkpoint(
            lambda args: attend(*args),
            policy=jax.checkpoint_policies.nothing_saveable,
        )
        out = jax.lax.map(body, (qs, ps))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV_eff, G_eff, hd)
    return out.reshape(B, Sq, H, hd)


# ------------------------------------------------------------------ MLP
def swiglu_mlp(x, wi_gate, wi_up, wo):
    g = jnp.einsum("bsd,df->bsf", x, wi_gate)
    u = jnp.einsum("bsd,df->bsf", x, wi_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, wo)


def gelu_mlp(x, wi, bi, wo, bo):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wi) + bi, approximate=True)
    return jnp.einsum("bsf,fd->bsd", h.astype(x.dtype), wo) + bo


# ------------------------------------------------------------------ MoE
def _moe_tokens(xt, wr, w_gate, w_up, w_down, *, top_k: int, capacity: int):
    """Sort-based dispatch over a flat token block (T, D). Runs either on the
    whole array (reference / decode path) or per-shard inside the EP
    shard_map. Returns (y (T, D), per-expert load stats for the aux loss)."""
    T, D = xt.shape
    E = wr.shape[1]
    C = capacity
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)           # (T, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((T * top_k,), jnp.float32)
    ) / (T * top_k)
    flat_e = gate_idx.reshape(-1)                                # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    group_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype), side="left")
    pos = jnp.arange(T * top_k, dtype=jnp.int32) - group_start[se].astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)  # E*C = drop bin
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(xt[st])
    buf = buf[:-1].reshape(E, C, D)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
    yb = jnp.einsum("ecf,efd->ecd", h, w_down)
    ybf = jnp.concatenate([yb.reshape(E * C, D), jnp.zeros((1, D), xt.dtype)], axis=0)
    contrib = ybf[slot] * sw[:, None].astype(xt.dtype)
    y = jnp.zeros((T, D), xt.dtype).at[st].add(jnp.where(keep[:, None], contrib, 0))
    return y, (me, ce)


def _capacity(T: int, top_k: int, E: int, cf: float) -> int:
    C = int(np.ceil(T * top_k / E * cf))
    return max(8, -(-C // 8) * 8)


def moe_layer(x, wr, w_gate, w_up, w_down, *, top_k: int, capacity_factor: float,
              ctx=None):
    """Top-k MoE with capacity + dropping (GShard-style).

    x (B, S, D); wr (D, E); w_gate/w_up (E, D, F); w_down (E, F, D).
    With a mesh ctx and S > 1 this runs as **expert parallelism** via
    shard_map: routing/sort stay local to each chip (T_loc tokens), coded
    buffers (E, C_loc, D) exchange via all_to_all over "model" (experts live
    E/n_model per chip), expert FFNs run as local batched matmuls, and a
    second all_to_all returns the outputs. Without ctx (CPU smoke / decode)
    the reference whole-array path runs instead. Returns (y, aux_loss).
    """
    B, S, D = x.shape
    E = wr.shape[1]
    if ctx is None or S == 1 or E % ctx.n_model != 0:
        y, (me, ce) = _moe_tokens(
            x.reshape(B * S, D), wr, w_gate, w_up, w_down,
            top_k=top_k, capacity=_capacity(B * S, top_k, E, capacity_factor),
        )
        aux = E * jnp.sum(me * ce)
        return y.reshape(B, S, D), aux

    mesh = ctx.mesh
    from jax.sharding import PartitionSpec as P

    n_model = ctx.n_model
    E_loc = E // n_model
    n_batch = ctx.n_batch
    B_loc = B // n_batch if B % n_batch == 0 and B >= n_batch else B
    S_loc = S // n_model if S % n_model == 0 else S
    T_loc = B_loc * S_loc
    C = _capacity(T_loc, top_k, E, capacity_factor)
    batch_spec = ctx.batch_axes if B_loc != B else None
    seq_spec = "model" if S_loc != S else None
    all_axes = tuple(mesh.axis_names)

    def body(xs, wr_, wg_, wu_, wd_):
        xt = xs.reshape(-1, D)
        Tl = xt.shape[0]
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), wr_.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
            jnp.ones((Tl * top_k,), jnp.float32)
        ) / (Tl * top_k)
        flat_e = gate_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), top_k)
        flat_w = gate_vals.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        group_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype), side="left")
        pos = jnp.arange(Tl * top_k, dtype=jnp.int32) - group_start[se].astype(jnp.int32)
        keep = pos < C
        slot = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)
        buf = jnp.zeros((E * C + 1, D), xt.dtype).at[slot].set(xt[st])
        buf = buf[:-1].reshape(n_model, E_loc * C, D)
        # EP dispatch: peer p gets my contributions for ITS experts
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0, tiled=True)
        toks = recv.reshape(E_loc, n_model * C, D)               # my experts' tokens
        g = jnp.einsum("ecd,edf->ecf", toks, wg_)
        u = jnp.einsum("ecd,edf->ecf", toks, wu_)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
        yb = jnp.einsum("ecf,efd->ecd", h, wd_)
        send = yb.reshape(n_model, E_loc * C, D)
        back = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0, tiled=True)
        ybf = jnp.concatenate(
            [back.reshape(E * C, D), jnp.zeros((1, D), xt.dtype)], axis=0
        )
        contrib = ybf[slot] * sw[:, None].astype(xt.dtype)
        y = jnp.zeros((Tl, D), xt.dtype).at[st].add(jnp.where(keep[:, None], contrib, 0))
        aux_loc = E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux_loc, axis_name=all_axes)
        return y.reshape(xs.shape), aux

    y, aux = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_spec, seq_spec, None), P(None, None),
            P("model", None, None), P("model", None, None), P("model", None, None),
        ),
        out_specs=(P(batch_spec, seq_spec, None), P()),
    )(x, wr, w_gate, w_up, w_down)
    return y, aux


# ----------------------------------------------------------- init helpers
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
