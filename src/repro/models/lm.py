"""Unified LM covering all assigned families.

Families:
  dense / vlm     — pre-norm GQA attention + SwiGLU MLP
  moe             — GQA attention + sort-based top-k MoE (EP over "model")
  ssm             — Mamba2 (SSD) mixer layers
  hybrid          — Zamba2: groups of Mamba2 layers + ONE shared attention+MLP
                    block applied after every group (weights reused)
  encdec          — Whisper: bidirectional encoder (stub audio embeddings) +
                    causal decoder with cross-attention

All layer stacks run as ``lax.scan`` over stacked weights with
``jax.checkpoint`` (nothing_saveable) — layer-boundary activations only.
Residual streams carry a Megatron-style sequence-parallel sharding
(batch, "model", None) between blocks; see DESIGN.md §5.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import ssd
from repro.models.layers import (
    apply_rope,
    dense_init,
    gelu_mlp,
    gqa_attention,
    layer_norm,
    moe_layer,
    mrope_cos_sin,
    rms_norm,
    rope_cos_sin,
    swiglu_mlp,
)
from repro.models.sharding import MeshCtx, spec_with_model_on

Pytree = Any


# =========================================================================
# parameter templates
# =========================================================================
def _attn_shapes(cfg: ArchConfig, stacked: int | None) -> dict:
    H, KV, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    bf = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def s(*shape):
        return ((stacked, *shape) if stacked else shape, bf)

    out = {
        "wq": s(D, H, hd), "wk": s(D, KV, hd), "wv": s(D, KV, hd),
        "wo": s(H, hd, D),
    }
    if cfg.qkv_bias:
        out.update({"bq": s(H, hd), "bk": s(KV, hd), "bv": s(KV, hd)})
    if cfg.qk_norm:
        out.update({"qn": s(hd), "kn": s(hd)})
    return out


def _mlp_shapes(cfg: ArchConfig, stacked: int | None, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    bf = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def s(*shape):
        return ((stacked, *shape) if stacked else shape, bf)

    return {"wg": s(D, F), "wu": s(D, F), "wd": s(F, D)}


def _moe_shapes(cfg: ArchConfig, stacked: int | None) -> dict:
    D, E, F = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    bf = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def s(*shape):
        return ((stacked, *shape) if stacked else shape, bf)

    return {
        "wr": s(D, E), "w_gate": s(E, D, F), "w_up": s(E, D, F), "w_down": s(E, F, D),
    }


def _norm_shapes(cfg: ArchConfig, stacked: int | None, names=("ln1", "ln2")) -> dict:
    f32 = jnp.float32
    D = cfg.d_model

    def s(*shape):
        return ((stacked, *shape) if stacked else shape, f32)

    return {n: s(D) for n in names}


PURE_DP_MAX_PARAMS = 2.5e8  # below this, TP wastes the mesh: replicate


def _remat_policy(model: "LM"):
    # Hypothesis tested and REFUTED (EXPERIMENTS.md §Perf cell A, iter 3):
    # saving dot outputs on memory-headroom models (dots_with_no_batch_dims_
    # saveable) cut the compute term 6% but RAISED the memory bound 6%
    # (0.498 -> 0.528 s on whisper train) — on memory-bound cells the
    # backward recompute is free while the saved activations cost traffic.
    # nothing_saveable everywhere.
    return jax.checkpoint_policies.nothing_saveable


class LM:
    def __init__(self, cfg: ArchConfig, max_pos: int = 4096):
        self.cfg = cfg
        self.max_pos = max_pos  # whisper decoder learned-position table size
        # Tiny models (whisper-base: 72M) are pure-DP: weights replicated,
        # batch sharded over EVERY mesh axis. TP/SP on a d=512 model spends
        # more on gathers than it saves (EXPERIMENTS.md §Perf iteration 3).
        self.pure_dp = self.n_params() <= PURE_DP_MAX_PARAMS

    def _tok_spec(self, ctx) -> tuple:
        if self.pure_dp:
            return ((*ctx.batch_axes, "model"), None, None)
        return (ctx.batch_axes, "model", None)

    # ------------------------------------------------------------- template
    def param_template(self) -> dict:
        cfg = self.cfg
        bf = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        L = cfg.n_layers
        t: dict = {"final_ln": ((cfg.d_model,), jnp.float32)}
        if cfg.family == "encdec":
            Le, Ld = cfg.encoder_layers, cfg.n_layers
            t["embed"] = ((cfg.vocab, cfg.d_model), bf)
            t["dec_pos"] = ((self.max_pos, cfg.d_model), bf)
            t["enc"] = {
                **_attn_shapes(cfg, Le), **_mlp_shapes(cfg, Le),
                **_norm_shapes(cfg, Le),
                "b1": ((Le, cfg.d_model), jnp.float32),
                "b2": ((Le, cfg.d_model), jnp.float32),
            }
            t["enc_final_ln"] = ((cfg.d_model,), jnp.float32)
            t["enc_final_b"] = ((cfg.d_model,), jnp.float32)
            t["final_b"] = ((cfg.d_model,), jnp.float32)
            dec = {
                **_attn_shapes(cfg, Ld), **_mlp_shapes(cfg, Ld),
                **_norm_shapes(cfg, Ld, ("ln1", "ln2", "ln3")),
                "b1": ((Ld, cfg.d_model), jnp.float32),
                "b2": ((Ld, cfg.d_model), jnp.float32),
                "b3": ((Ld, cfg.d_model), jnp.float32),
            }
            # cross-attention
            for k, v in _attn_shapes(cfg, Ld).items():
                dec["x" + k] = v
            t["dec"] = dec
            return t
        if cfg.family == "ssm":
            t["embed"] = ((cfg.vocab, cfg.d_model), bf)
            blk = {k: ((L, *shp), dtype) for k, (shp, dtype) in ssd.mamba2_param_shapes(cfg).items()}
            blk["ln"] = ((L, cfg.d_model), jnp.float32)
            t["layers"] = blk
            if not cfg.tie_embeddings:
                t["head"] = ((cfg.d_model, cfg.vocab), bf)
            return t
        if cfg.family == "hybrid":
            t["embed"] = ((cfg.vocab, cfg.d_model), bf)
            blk = {k: ((L, *shp), dtype) for k, (shp, dtype) in ssd.mamba2_param_shapes(cfg).items()}
            blk["ln"] = ((L, cfg.d_model), jnp.float32)
            t["layers"] = blk
            t["shared"] = {
                **_attn_shapes(cfg, None), **_mlp_shapes(cfg, None),
                **_norm_shapes(cfg, None),
            }
            if not cfg.tie_embeddings:
                t["head"] = ((cfg.d_model, cfg.vocab), bf)
            return t
        # dense / moe / vlm decoder
        blk = {**_attn_shapes(cfg, L), **_norm_shapes(cfg, L)}
        if cfg.family == "moe":
            blk.update(_moe_shapes(cfg, L))
        else:
            blk.update(_mlp_shapes(cfg, L))
        t["layers"] = blk
        if not cfg.embeddings_input:
            t["embed"] = ((cfg.vocab, cfg.d_model), bf)
        if not cfg.tie_embeddings:
            t["head"] = ((cfg.d_model, cfg.vocab), bf)
        return t

    def param_shapes(self) -> Pytree:
        return jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]),
            self.param_template(),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
        )

    def init_params(self, key) -> Pytree:
        tmpl = self.param_template()
        leaves, treedef = jax.tree.flatten(
            tmpl,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
        )
        keys = jax.random.split(key, len(leaves))
        outs = []
        for (shape, dtype), k in zip(leaves, keys):
            if len(shape) == 1 or shape[-1] in ():
                outs.append(jnp.zeros(shape, dtype))
            else:
                outs.append(dense_init(k, shape, dtype))
        return jax.tree.unflatten(treedef, outs)

    def n_params(self) -> int:
        leaves, _ = jax.tree.flatten(
            self.param_template(),
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple),
        )
        return int(sum(np.prod(s) for s, _ in leaves))

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of E experts)."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.family != "moe":
            return total
        tmpl = self.param_template()["layers"]
        expert = sum(
            int(np.prod(tmpl[k][0])) for k in ("w_gate", "w_up", "w_down")
        )
        active = expert // cfg.moe_experts * cfg.moe_top_k
        return total - expert + active

    # ------------------------------------------------------------- specs
    def param_specs(self, ctx: MeshCtx, serve: bool = False) -> Pytree:
        """Weight shardings. ``serve=True`` additionally shards the MoE
        expert tensors over the data axes (there is no DP gradient in
        inference, so nothing needs weight replication): 16x less HBM per
        chip for expert weights — decode is weights-bound — and it kills the
        CPU-backend's hoisted f32 weight copies in the dry-run."""
        cfg = self.cfg
        if self.pure_dp and not serve:
            return jax.tree.map(
                lambda sd: ctx.replicated(), self.param_shapes(),
            )

        def leaf_spec(path: tuple, shape: tuple) -> tuple:
            name = path[-1]
            stacked = len(path) >= 2 and path[0] in ("layers", "enc", "dec")
            off = 1 if stacked else 0
            body = shape[off:]
            if name in ("embed", "dec_pos"):
                return spec_with_model_on(shape, ctx, [0, 1])
            if name == "head":
                return spec_with_model_on(shape, ctx, [1, 0])
            base: tuple
            if name.lstrip("x") in ("wq", "wo", "bq", "qn", "kn"):
                # heads dim (or head_dim fallback)
                if name.lstrip("x") == "wq":
                    base = spec_with_model_on(body, ctx, [1, 2])
                elif name.lstrip("x") == "wo":
                    base = spec_with_model_on(body, ctx, [0, 1])
                elif name.lstrip("x") == "bq":
                    base = spec_with_model_on(body, ctx, [0, 1])
                else:
                    base = (None,) * len(body)
            elif name.lstrip("x") in ("wk", "wv", "bk", "bv"):
                base = spec_with_model_on(body, ctx, [1, 2])
            elif name in ("wg", "wu"):
                base = spec_with_model_on(body, ctx, [1])
            elif name == "wd":
                base = spec_with_model_on(body, ctx, [0])
            elif name in ("w_gate", "w_up", "w_down"):
                base = spec_with_model_on(body, ctx, [0])      # EP on experts
                if serve:
                    b2 = list(base)
                    for d in (1, 2):
                        if b2[d] is None and body[d] % ctx.n_batch == 0:
                            b2[d] = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
                            break
                    base = tuple(b2)
            elif name == "wr":
                base = (None,) * len(body)
            elif name in ("wz", "wx"):
                base = spec_with_model_on(body, ctx, [1])
            elif name == "wo_ssm":
                base = spec_with_model_on(body, ctx, [0])
            elif name == "wdt":
                base = spec_with_model_on(body, ctx, [1])
            elif name == "conv_w":
                base = (None,) * len(body)
            elif name == "norm":
                base = spec_with_model_on(body, ctx, [0])
            else:
                base = (None,) * len(body)
            return ((None,) * off) + base if stacked else base

        def walk(tree, path=()):  # build spec tree
            if isinstance(tree, dict):
                return {k: walk(v, path + (k,)) for k, v in tree.items()}
            shape, _dtype = tree
            # mamba wo is (d_inner, D): model on dim0
            name = path[-1]
            if name == "wo" and path[0] == "layers" and self.cfg.is_ssm:
                body = shape[1:]
                return ctx.ns(None, *spec_with_model_on(body, ctx, [0]))
            return ctx.ns(*leaf_spec(path, shape))

        return walk(self.param_template())

    # ------------------------------------------------------------- forward
    def _rope(self, positions, S):
        cfg = self.cfg
        hd = cfg.hd
        if cfg.rope_style == "mrope":
            return mrope_cos_sin(positions, cfg.mrope_sections, cfg.rope_theta)
        n_freq = int(hd * cfg.rope_fraction) // 2
        return rope_cos_sin(positions, n_freq, cfg.rope_theta)

    def _attn(self, lp, x, *, cos, sin, q_pos, k_pos, window, prefix="",
              kv_override=None, causal=True, ctx=None):
        cfg = self.cfg
        g = lambda n: lp[prefix + n]
        heads_shardable = ctx is not None and (
            cfg.n_heads % ctx.n_model == 0 or cfg.n_kv_heads % ctx.n_model == 0
        )
        if heads_shardable and not self.pure_dp and x.shape[1] > 1:
            # Megatron-SP: gather the sequence dim ONCE at the attention
            # entry so q/k/v project straight into head-sharded layouts.
            # (Constraining k/v after projection makes the partitioner
            # resort to "involuntary full rematerialization" replication.)
            # When NO head dim divides the model axis (qwen2-vl: 28H/4KV on
            # 16) the S-sharded layout IS the parallelism — keep it.
            x = ctx.constrain(x, ctx.batch_axes, None, None)
        q = jnp.einsum("bsd,dhk->bshk", x, g("wq"))
        src = x if kv_override is None else kv_override
        k = jnp.einsum("bsd,dhk->bshk", src, g("wk"))
        v = jnp.einsum("bsd,dhk->bshk", src, g("wv"))
        if cfg.qkv_bias:
            q = q + g("bq"); k = k + g("bk"); v = v + g("bv")
        if cfg.qk_norm:
            q = rms_norm(q, g("qn"), cfg.norm_eps)
            k = rms_norm(k, g("kn"), cfg.norm_eps)
        if cos is not None:
            q = apply_rope(q, cos, sin, cfg.rope_fraction)
            k = apply_rope(k, cos, sin, cfg.rope_fraction)
        o = gqa_attention(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                          window=window, ctx=None if self.pure_dp else ctx)
        return jnp.einsum("bshk,hkd->bsd", o, g("wo"))

    def _dense_block(self, lp, h, *, cos, sin, q_pos, k_pos, window, ctx, tok_spec):
        cfg = self.cfg
        x = rms_norm(h, lp["ln1"], cfg.norm_eps)
        h = h + self._attn(lp, x, cos=cos, sin=sin, q_pos=q_pos, k_pos=k_pos,
                           window=window, ctx=ctx)
        if ctx is not None:
            h = ctx.constrain(h, *tok_spec)
        x2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "moe":
            y, aux = moe_layer(
                x2, lp["wr"], lp["w_gate"], lp["w_up"], lp["w_down"],
                top_k=cfg.moe_top_k, capacity_factor=cfg.capacity_factor, ctx=ctx,
            )
        else:
            y = swiglu_mlp(x2, lp["wg"], lp["wu"], lp["wd"])
        h = h + y
        if ctx is not None:
            h = ctx.constrain(h, *tok_spec)
        return h, aux

    def _run_decoder_stack(self, params, h, *, positions, ctx, shape_kind="train"):
        """dense/moe/vlm stacks (scan over layers)."""
        cfg = self.cfg
        B, S, D = h.shape
        cos, sin = self._rope(positions, S)
        q_pos = positions[0, 0] if cfg.rope_style == "mrope" else positions[0]
        k_pos = q_pos
        tok_spec = self._tok_spec(ctx) if ctx is not None else None
        L = cfg.n_layers
        idxs = jnp.arange(L, dtype=jnp.int32)
        if cfg.global_every:
            is_global = (idxs % cfg.global_every) == (cfg.global_every - 1)
            windows = jnp.where(is_global, jnp.int32(S + 1), jnp.int32(cfg.sliding_window))
        else:
            windows = jnp.full((L,), jnp.int32(S + 1))

        def body(carry, xs):
            h, aux = carry
            lp, w = xs
            h, a = self._dense_block(
                lp, h, cos=cos, sin=sin, q_pos=q_pos, k_pos=k_pos,
                window=w, ctx=ctx, tok_spec=tok_spec,
            )
            return (h, aux + a), None

        body = jax.checkpoint(body, policy=_remat_policy(self))
        (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                   (params["layers"], windows))
        return h, aux

    def _run_ssm_stack(self, params, h, ctx):
        cfg = self.cfg
        tok_spec = self._tok_spec(ctx) if ctx is not None else None

        def body(carry, lp):
            h = carry
            x = rms_norm(h, lp["ln"], cfg.norm_eps)
            h = h + ssd.mamba2_mixer(lp, x, cfg, ctx)
            if ctx is not None:
                h = ctx.constrain(h, *tok_spec)
            return h, None

        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        h, _ = jax.lax.scan(body, h, params["layers"])
        return h, jnp.zeros((), jnp.float32)

    def _run_hybrid_stack(self, params, h, *, positions, ctx):
        """Zamba2: (shared attn block after every ``shared_attn_every`` Mamba2
        layers); trailing layers run without a shared block."""
        cfg = self.cfg
        E = cfg.shared_attn_every
        L = cfg.n_layers
        n_groups, rem = divmod(L, E)
        tok_spec = self._tok_spec(ctx) if ctx is not None else None
        S = h.shape[1]
        cos, sin = self._rope(positions, S)
        q_pos = positions[0]
        shared = params["shared"]

        def mamba_body(carry, lp):
            hh = carry
            x = rms_norm(hh, lp["ln"], cfg.norm_eps)
            hh = hh + ssd.mamba2_mixer(lp, x, cfg, ctx)
            if ctx is not None:
                hh = ctx.constrain(hh, *tok_spec)
            return hh, None

        mamba_body = jax.checkpoint(mamba_body, policy=jax.checkpoint_policies.nothing_saveable)

        def shared_block(hh):
            x = rms_norm(hh, shared["ln1"], cfg.norm_eps)
            hh = hh + self._attn(shared, x, cos=cos, sin=sin, q_pos=q_pos,
                                 k_pos=q_pos, window=None, ctx=ctx)
            x2 = rms_norm(hh, shared["ln2"], cfg.norm_eps)
            hh = hh + swiglu_mlp(x2, shared["wg"], shared["wu"], shared["wd"])
            if ctx is not None:
                hh = ctx.constrain(hh, *tok_spec)
            return hh

        grouped = jax.tree.map(
            lambda a: a[: n_groups * E].reshape(n_groups, E, *a.shape[1:]),
            params["layers"],
        )

        def group_body(carry, gp):
            hh = carry
            hh, _ = jax.lax.scan(mamba_body, hh, gp)
            hh = shared_block(hh)
            return hh, None

        # checkpoint at GROUP granularity: only the 13 group-boundary
        # activations are saved; the 6 inner mamba layers + shared block
        # recompute in the backward (the inner per-layer saves would
        # otherwise stack across groups -> 81 full residual saves).
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable
        )
        h, _ = jax.lax.scan(group_body, h, grouped)
        if rem:
            tail = jax.tree.map(lambda a: a[n_groups * E :], params["layers"])
            h, _ = jax.lax.scan(mamba_body, h, tail)
        return h, jnp.zeros((), jnp.float32)

    def _run_encdec(self, params, batch, ctx):
        cfg = self.cfg
        audio = batch["audio_embeds"].astype(jnp.bfloat16)
        tokens = batch["tokens"]
        B, Sa, D = audio.shape
        St = tokens.shape[1]
        tok_spec = self._tok_spec(ctx) if ctx is not None else None
        # ---- encoder (bidirectional, sinusoidal positions baked in stub) ----
        pos_a = jnp.arange(Sa, dtype=jnp.int32)[None].repeat(B, 0)
        h = audio

        def enc_body(carry, lp):
            hh = carry
            x = layer_norm(hh, lp["ln1"], lp["b1"], cfg.norm_eps)
            hh = hh + self._attn(lp, x, cos=None, sin=None, q_pos=pos_a[0],
                                 k_pos=pos_a[0], window=None, causal=False, ctx=ctx)
            x2 = layer_norm(hh, lp["ln2"], lp["b2"], cfg.norm_eps)
            hh = hh + gelu_mlp(x2, lp["wg"], jnp.zeros((), hh.dtype), lp["wd"],
                               jnp.zeros((), hh.dtype))
            if ctx is not None:
                hh = ctx.constrain(hh, *tok_spec)
            return hh, None

        enc_body = jax.checkpoint(enc_body, policy=_remat_policy(self))
        h, _ = jax.lax.scan(enc_body, h, params["enc"])
        enc_out = layer_norm(h, params["enc_final_ln"], params["enc_final_b"], cfg.norm_eps)
        # ---- decoder ----
        pos_t = jnp.arange(St, dtype=jnp.int32)
        emb = params["embed"][tokens] + params["dec_pos"][pos_t][None]
        hd_ = emb.astype(jnp.bfloat16)

        def dec_body(carry, lp):
            hh = carry
            x = layer_norm(hh, lp["ln1"], lp["b1"], cfg.norm_eps)
            hh = hh + self._attn(lp, x, cos=None, sin=None, q_pos=pos_t,
                                 k_pos=pos_t, window=None, causal=True, ctx=ctx)
            x2 = layer_norm(hh, lp["ln2"], lp["b2"], cfg.norm_eps)
            hh = hh + self._attn(lp, x2, cos=None, sin=None, q_pos=pos_t,
                                 k_pos=pos_a[0], window=None, causal=False,
                                 prefix="x", kv_override=enc_out, ctx=ctx)
            x3 = layer_norm(hh, lp["ln3"], lp["b3"], cfg.norm_eps)
            hh = hh + gelu_mlp(x3, lp["wg"], jnp.zeros((), hh.dtype), lp["wd"],
                               jnp.zeros((), hh.dtype))
            if ctx is not None:
                hh = ctx.constrain(hh, *tok_spec)
            return hh, None

        dec_body = jax.checkpoint(dec_body, policy=_remat_policy(self))
        hd_, _ = jax.lax.scan(dec_body, hd_, params["dec"])
        return hd_, jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------- loss
    def _head(self, params, h):
        cfg = self.cfg
        if cfg.family == "encdec":
            h = layer_norm(h, params["final_ln"], params["final_b"], cfg.norm_eps)
        else:
            h = rms_norm(h, params["final_ln"], cfg.norm_eps)
        if cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", h, params["embed"])
        return jnp.einsum("bsd,dv->bsv", h, params["head"])

    def loss_fn(self, params, batch, ctx: MeshCtx | None = None):
        cfg = self.cfg
        if cfg.family == "encdec":
            h, aux = self._run_encdec(params, batch, ctx)
        else:
            if cfg.embeddings_input:
                h = batch["embeds"].astype(jnp.bfloat16)
                positions = batch["positions"]
            else:
                tokens = batch["tokens"]
                h = params["embed"][tokens].astype(jnp.bfloat16)
                B, S = tokens.shape
                positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
                if cfg.rope_style == "mrope":
                    positions = jnp.stack([positions] * 3, axis=0)
            if ctx is not None:
                h = ctx.constrain(h, *self._tok_spec(ctx))
            if cfg.family == "ssm":
                h, aux = self._run_ssm_stack(params, h, ctx)
            elif cfg.family == "hybrid":
                h, aux = self._run_hybrid_stack(params, h, positions=positions, ctx=ctx)
            else:
                h, aux = self._run_decoder_stack(params, h, positions=positions, ctx=ctx)
        labels = batch["labels"]
        ce = self._cross_entropy(params, h, labels, ctx)
        return ce + 0.01 * aux

    def _cross_entropy(self, params, h, labels, ctx, chunk: int = 128):
        """CE over the vocab. For production shapes the (B, S, V) f32 logits
        are the single largest live buffer (2.5 GB/chip at V=152k), so we
        stream the loss over sequence chunks under remat: peak = one chunk's
        logits; the head matmul is recomputed chunkwise in the backward."""
        B, S, D = h.shape
        if ctx is None or S <= chunk:
            logits = self._head(params, h).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
            return (lse - ll).mean()
        n = S // chunk
        assert S % chunk == 0, (S, chunk)
        baxes = (*ctx.batch_axes, "model") if self.pure_dp else ctx.batch_axes
        h = ctx.constrain(h, baxes, None, None)
        hs = jnp.moveaxis(h.reshape(B, n, chunk, D), 1, 0)
        ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

        def body(tot, xs):
            hc, lc = xs
            logits = self._head(params, hc).astype(jnp.float32)
            if ctx is not None:
                logits = ctx.constrain(
                    logits, baxes, None, None if self.pure_dp else "model")
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            return tot + (lse - ll).sum(), None

        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
        return tot / (B * S)

    # ------------------------------------------------------------- serving
    def cache_template(self, B: int, S: int) -> dict:
        cfg = self.cfg
        bf = jnp.bfloat16
        KV, hd = cfg.n_kv_heads, cfg.hd
        if cfg.family in ("dense", "vlm", "moe"):
            L = cfg.n_layers
            return {
                "k": ((L, B, S, KV, hd), bf),
                "v": ((L, B, S, KV, hd), bf),
            }
        if cfg.family == "ssm":
            L = cfg.n_layers
            conv_dim = cfg.d_inner + 2 * ssd.G * cfg.ssm_state
            return {
                "conv": ((L, B, cfg.conv_kernel - 1, conv_dim), bf),
                "ssm": ((L, B, ssd.G, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim), jnp.float32),
            }
        if cfg.family == "hybrid":
            L = cfg.n_layers
            n_groups = L // cfg.shared_attn_every
            conv_dim = cfg.d_inner + 2 * ssd.G * cfg.ssm_state
            return {
                "conv": ((L, B, cfg.conv_kernel - 1, conv_dim), bf),
                "ssm": ((L, B, ssd.G, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim), jnp.float32),
                "k": ((n_groups, B, S, KV, hd), bf),
                "v": ((n_groups, B, S, KV, hd), bf),
            }
        if cfg.family == "encdec":
            L = cfg.n_layers
            Sa = S // 2
            return {
                "k": ((L, B, S, KV, hd), bf),
                "v": ((L, B, S, KV, hd), bf),
                "xk": ((L, B, Sa, KV, hd), bf),
                "xv": ((L, B, Sa, KV, hd), bf),
            }
        raise ValueError(cfg.family)

    def cache_specs(self, B: int, S: int, ctx: MeshCtx) -> dict:
        cfg = self.cfg
        tmpl = self.cache_template(B, S)
        out = {}
        batch_ok = B >= ctx.n_batch and B % ctx.n_batch == 0
        for name, (shape, _dt) in tmpl.items():
            spec: list = [None] * len(shape)
            if batch_ok:
                spec[1] = ctx.batch_axes
            if name in ("k", "v", "xk", "xv"):
                # model axis: kv-heads if divisible, else head_dim, else seq
                if shape[3] % ctx.n_model == 0:
                    spec[3] = "model"
                elif shape[4] % ctx.n_model == 0:
                    spec[4] = "model"
                if not batch_ok:
                    spec[2] = ctx.batch_axes  # sequence sharding (long_500k)
            else:
                # ssm/conv states: shard heads / channels on model
                if name == "ssm" and shape[3] % ctx.n_model == 0:
                    spec[3] = "model"
                if name == "conv" and shape[3] % ctx.n_model == 0:
                    spec[3] = "model"
            out[name] = ctx.ns(*spec)
        return out

    def decode_step(self, params, cache, batch, ctx: MeshCtx | None = None):
        """One token for the whole batch against a seq_len-long cache.

        batch: {"token": (B,) int32 (or "embed": (B, D)), "cur_len": ()} —
        returns (logits (B, V), new cache).
        """
        cfg = self.cfg
        cur = batch["cur_len"]
        if cfg.embeddings_input:
            x = batch["embed"].astype(jnp.bfloat16)
        else:
            x = params["embed"][batch["token"]].astype(jnp.bfloat16)
        B = x.shape[0]
        if cfg.family == "encdec":
            x = x + params["dec_pos"][cur][None]
        h = x[:, None, :]  # (B, 1, D)
        if cfg.family in ("dense", "vlm", "moe"):
            h, cache = self._decode_dense(params, cache, h, cur, ctx)
        elif cfg.family == "ssm":
            h, cache = self._decode_ssm(params, cache, h, ctx)
        elif cfg.family == "hybrid":
            h, cache = self._decode_hybrid(params, cache, h, cur, ctx)
        elif cfg.family == "encdec":
            h, cache = self._decode_encdec(params, cache, h, cur, ctx)
        logits = self._head(params, h)[:, 0].astype(jnp.float32)
        return logits, cache

    # --- decode stacks ----------------------------------------------------
    def _decode_attn(self, lp, h, k_cache, v_cache, cur, *, window, prefix=""):
        cfg = self.cfg
        S = k_cache.shape[1]  # per-layer cache is (B, S, KV, hd)
        B = h.shape[0]
        pos1 = jnp.full((1,), cur, jnp.int32)
        cos, sin = (None, None)
        if cfg.rope_style != "none" and cfg.family != "encdec":
            if cfg.rope_style == "mrope":
                p3 = jnp.full((3, B, 1), cur, jnp.int32)
                cos, sin = mrope_cos_sin(p3, cfg.mrope_sections, cfg.rope_theta)
            else:
                n_freq = int(cfg.hd * cfg.rope_fraction) // 2
                cos, sin = rope_cos_sin(pos1[None].repeat(B, 0), n_freq, cfg.rope_theta)
        x = h
        g = lambda n: lp[prefix + n]
        q = jnp.einsum("bsd,dhk->bshk", x, g("wq"))
        k_new = jnp.einsum("bsd,dhk->bshk", x, g("wk"))
        v_new = jnp.einsum("bsd,dhk->bshk", x, g("wv"))
        if cfg.qkv_bias:
            q = q + g("bq"); k_new = k_new + g("bk"); v_new = v_new + g("bv")
        if cfg.qk_norm:
            q = rms_norm(q, g("qn"), cfg.norm_eps)
            k_new = rms_norm(k_new, g("kn"), cfg.norm_eps)
        if cos is not None:
            q = apply_rope(q, cos, sin, cfg.rope_fraction)
            k_new = apply_rope(k_new, cos, sin, cfg.rope_fraction)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, cur, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, cur, axis=1)
        k_pos = jnp.arange(S, dtype=jnp.int32)
        o = gqa_attention(q, k_cache, v_cache, q_pos=pos1, k_pos=k_pos,
                          causal=True, window=window)
        return jnp.einsum("bshk,hkd->bsd", o, g("wo")), k_cache, v_cache

    def _decode_dense(self, params, cache, h, cur, ctx):
        cfg = self.cfg
        L = cfg.n_layers
        idxs = jnp.arange(L, dtype=jnp.int32)
        S = cache["k"].shape[2]
        if cfg.global_every:
            is_global = (idxs % cfg.global_every) == (cfg.global_every - 1)
            windows = jnp.where(is_global, jnp.int32(S + 1), jnp.int32(cfg.sliding_window))
        else:
            windows = jnp.full((L,), jnp.int32(S + 1))

        def body(carry, xs):
            hh = carry
            lp, kc, vc, w = xs
            x = rms_norm(hh, lp["ln1"], cfg.norm_eps)
            a, kc, vc = self._decode_attn(lp, x, kc, vc, cur, window=w)
            hh = hh + a
            x2 = rms_norm(hh, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = moe_layer(
                    x2, lp["wr"], lp["w_gate"], lp["w_up"], lp["w_down"],
                    top_k=cfg.moe_top_k, capacity_factor=cfg.capacity_factor, ctx=ctx,
                )
            else:
                y = swiglu_mlp(x2, lp["wg"], lp["wu"], lp["wd"])
            return hh + y, (kc, vc)

        h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"], windows))
        return h, {"k": ks, "v": vs}

    def _decode_ssm(self, params, cache, h, ctx):
        cfg = self.cfg

        def body(carry, xs):
            hh = carry
            lp, conv, ssm_st = xs
            x = rms_norm(hh, lp["ln"], cfg.norm_eps)
            y, conv, ssm_st = ssd.mamba2_decode_step(lp, x[:, 0], conv, ssm_st, cfg)
            return hh + y[:, None], (conv, ssm_st)

        h, (convs, ssms) = jax.lax.scan(body, h, (params["layers"], cache["conv"], cache["ssm"]))
        return h, {"conv": convs, "ssm": ssms}

    def _decode_hybrid(self, params, cache, h, cur, ctx):
        cfg = self.cfg
        E = cfg.shared_attn_every
        L = cfg.n_layers
        n_groups, rem = divmod(L, E)
        shared = params["shared"]

        def mamba_step(hh, lp, conv, ssm_st):
            x = rms_norm(hh, lp["ln"], cfg.norm_eps)
            y, conv, ssm_st = ssd.mamba2_decode_step(lp, x[:, 0], conv, ssm_st, cfg)
            return hh + y[:, None], conv, ssm_st

        grouped = jax.tree.map(
            lambda a: a[: n_groups * E].reshape(n_groups, E, *a.shape[1:]),
            params["layers"],
        )
        gconv = cache["conv"][: n_groups * E].reshape(n_groups, E, *cache["conv"].shape[1:])
        gssm = cache["ssm"][: n_groups * E].reshape(n_groups, E, *cache["ssm"].shape[1:])

        def group_body(carry, xs):
            hh = carry
            gp, cv, sm, kc, vc = xs

            def inner(c2, xs2):
                h2 = c2
                lp, cv2, sm2 = xs2
                h2, cv2, sm2 = mamba_step(h2, lp, cv2, sm2)
                return h2, (cv2, sm2)

            hh, (cv, sm) = jax.lax.scan(inner, hh, (gp, cv, sm))
            x = rms_norm(hh, shared["ln1"], cfg.norm_eps)
            a, kc, vc = self._decode_attn(shared, x, kc, vc, cur, window=None)
            hh = hh + a
            x2 = rms_norm(hh, shared["ln2"], cfg.norm_eps)
            hh = hh + swiglu_mlp(x2, shared["wg"], shared["wu"], shared["wd"])
            return hh, (cv, sm, kc, vc)

        h, (cv, sm, ks, vs) = jax.lax.scan(
            group_body, h, (grouped, gconv, gssm, cache["k"], cache["v"])
        )
        new_conv = cv.reshape(n_groups * E, *cache["conv"].shape[1:])
        new_ssm = sm.reshape(n_groups * E, *cache["ssm"].shape[1:])
        if rem:
            tail = jax.tree.map(lambda a: a[n_groups * E :], params["layers"])

            def inner(c2, xs2):
                h2 = c2
                lp, cv2, sm2 = xs2
                h2, cv2, sm2 = mamba_step(h2, lp, cv2, sm2)
                return h2, (cv2, sm2)

            h, (cvt, smt) = jax.lax.scan(
                inner, h, (tail, cache["conv"][n_groups * E :], cache["ssm"][n_groups * E :])
            )
            new_conv = jnp.concatenate([new_conv, cvt], axis=0)
            new_ssm = jnp.concatenate([new_ssm, smt], axis=0)
        return h, {"conv": new_conv, "ssm": new_ssm, "k": ks, "v": vs}

    def _decode_encdec(self, params, cache, h, cur, ctx):
        cfg = self.cfg

        def body(carry, xs):
            hh = carry
            lp, kc, vc, xk, xv = xs
            x = layer_norm(hh, lp["ln1"], lp["b1"], cfg.norm_eps)
            a, kc, vc = self._decode_attn(lp, x, kc, vc, cur, window=None)
            hh = hh + a
            # cross attention against the (precomputed) encoder KV
            x2 = layer_norm(hh, lp["ln2"], lp["b2"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", x2, lp["xwq"])
            Sa = xk.shape[1]
            o = gqa_attention(q, xk, xv, q_pos=jnp.zeros((1,), jnp.int32),
                              k_pos=jnp.arange(Sa, dtype=jnp.int32), causal=False,
                              window=None)
            hh = hh + jnp.einsum("bshk,hkd->bsd", o, lp["xwo"])
            x3 = layer_norm(hh, lp["ln3"], lp["b3"], cfg.norm_eps)
            hh = hh + gelu_mlp(x3, lp["wg"], jnp.zeros((), hh.dtype), lp["wd"],
                               jnp.zeros((), hh.dtype))
            return hh, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        return h, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"]}
