"""Model construction + input specs per (arch, shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.lm import LM


def build_model(cfg: ArchConfig, max_pos: int = 4096) -> LM:
    return LM(cfg, max_pos=max_pos)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run; no device
    allocation). Modality frontends are stubs: VLM/audio provide precomputed
    patch/frame embeddings (see assignment note)."""
    B, S = shape.global_batch, shape.seq_len
    i32, bf = jnp.int32, jnp.bfloat16
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "audio_embeds": jax.ShapeDtypeStruct((B, S // 2, cfg.d_model), bf),
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if cfg.embeddings_input:
            return {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf),
                "positions": jax.ShapeDtypeStruct((3, B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    # decode: one new token against a seq_len cache
    out = {"cur_len": jax.ShapeDtypeStruct((), i32)}
    if cfg.embeddings_input:
        out["embed"] = jax.ShapeDtypeStruct((B, cfg.d_model), bf)
    else:
        out["token"] = jax.ShapeDtypeStruct((B,), i32)
    return out


def make_inputs(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Real (random) arrays matching input_specs — smoke tests / examples."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for k, sd in specs.items():
        if sd.dtype == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels", "token") else max(2, shape.seq_len)
            if k == "cur_len":
                out[k] = jnp.asarray(shape.seq_len // 2, jnp.int32)
            else:
                out[k] = jnp.asarray(
                    rng.integers(0, hi, sd.shape, dtype=np.int32)
                )
        else:
            out[k] = jnp.asarray(
                rng.standard_normal(sd.shape).astype(np.float32) * 0.02
            ).astype(sd.dtype)
    return out
