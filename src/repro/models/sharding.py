"""Mesh context + best-effort sharding plans.

Production meshes (DESIGN.md §5): single-pod (data=16, model=16) and
multi-pod (pod=2, data=16, model=16). Logical axes:

  batch  -> ("pod", "data") or ("data",)     activations' batch dim
  seq    -> the batch axes, used instead of batch when global_batch is too
            small to fill them (long_500k: batch=1 -> shard sequence)
  model  -> "model"                           TP/EP axis

Dims not divisible by the model-axis size are handled by *axis fallback*
(shard a different dim that is divisible) rather than XLA padding wherever
possible; the chosen plan is recorded for the dry-run report.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass
class MeshCtx:
    mesh: Mesh
    notes: list = field(default_factory=list)

    @property
    def has_pod(self) -> bool:
        return "pod" in self.mesh.axis_names

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return ("pod", "data") if self.has_pod else ("data",)

    @property
    def n_batch(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    @property
    def n_model(self) -> int:
        return int(self.mesh.shape["model"])

    # ----------------------------------------------------------- specs
    def ns(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def token_spec(self, global_batch: int, extra_dims: int = 0) -> tuple:
        """(B, S, ...) activation spec: shard batch if it fills the batch
        axes, otherwise shard the sequence dim (context/sequence parallel)."""
        if global_batch >= self.n_batch and global_batch % self.n_batch == 0:
            return (self.batch_axes, None) + (None,) * extra_dims
        return (None, self.batch_axes) + (None,) * extra_dims

    def constrain(self, x, *spec):
        return jax.lax.with_sharding_constraint(x, self.ns(*spec))

    def model_dim_choice(self, *dim_sizes: int) -> int:
        """Index of the first dim divisible by the model axis, else -1."""
        for i, d in enumerate(dim_sizes):
            if d % self.n_model == 0:
                return i
        return -1


def shard_map_compat(f, *, mesh, in_specs, out_specs, **kw):
    """``jax.shard_map`` across jax versions: new jax exposes it at top level
    with ``check_vma``; 0.4.x has ``jax.experimental.shard_map.shard_map``
    with the ``check_rep`` spelling of the same flag."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    if "check_vma" in kw:
        kw["check_rep"] = kw.pop("check_vma")
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def spec_with_model_on(shape: tuple[int, ...], ctx: MeshCtx, candidates: list[int]) -> tuple:
    """Build a spec placing "model" on the first candidate dim divisible by
    the model-axis size (fallback: replicated)."""
    spec: list = [None] * len(shape)
    for dim in candidates:
        if shape[dim] % ctx.n_model == 0:
            spec[dim] = "model"
            return tuple(spec)
    return tuple(spec)
