"""Mamba2 / SSD mixer (state-space duality, arXiv:2405.21060).

Chunked SSD: sequence is split into Q-sized chunks; the quadratic intra-chunk
term runs on the MXU (einsums), inter-chunk state flows through a sequential
``lax.scan`` carrying the (B, H, N, P) state — O(L·Q) compute, O(L/Q) scan
steps. Decode is the pure SSM recurrence (O(1) state update per token).

Parameter layout per layer (stacked leading L axis handled by the caller):
  wz, wx (D, d_inner) | wB, wC (D, G*N) | wdt (D, H) | dt_bias (H,)
  A_log (H,) | Dskip (H,) | conv_w (K, conv_dim) | norm (d_inner,)
  wo (d_inner, D)        with conv_dim = d_inner + 2*G*N, G = 1 group.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

G = 1  # B/C groups (mamba2 default ngroups=1)


def _causal_conv(u, w):
    """Depthwise causal conv1d: u (B, L, C), w (K, C) -> (B, L, C)."""
    K = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for j in range(K):  # K=4: unrolled shifted adds
        out = out + pad[:, j : j + u.shape[1], :].astype(jnp.float32) * w[j].astype(jnp.float32)
    return out.astype(u.dtype)


def _split_heads(t, G_, rest):
    B, L = t.shape[:2]
    return t.reshape(B, L, G_, *rest)


def mamba2_mixer(p, x, cfg: ArchConfig, ctx=None):
    """x (B, L, D) -> (B, L, D). Chunked SSD over the full sequence."""
    B, L, D = x.shape
    H = cfg.ssm_heads
    P = cfg.ssm_headdim
    N = cfg.ssm_state
    Q = min(cfg.ssm_chunk, L)
    assert L % Q == 0, (L, Q)
    nC = L // Q

    z = jnp.einsum("bld,de->ble", x, p["wz"])
    xin = jnp.einsum("bld,de->ble", x, p["wx"])
    Bp = jnp.einsum("bld,dn->bln", x, p["wB"])
    Cp = jnp.einsum("bld,dn->bln", x, p["wC"])
    dt_raw = jnp.einsum("bld,dh->blh", x, p["wdt"])
    xBC = jnp.concatenate([xin, Bp, Cp], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"]).astype(jnp.float32)).astype(x.dtype)
    d_in = cfg.d_inner
    xin = xBC[..., :d_in]
    Bp = xBC[..., d_in : d_in + G * N]
    Cp = xBC[..., d_in + G * N :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    xh = xin.reshape(B, L, H, P)
    if ctx is not None:
        # head parallelism: every SSD tensor shards over heads on "model"
        # (B/C are head-shared and stay replicated) — keeps the per-chunk
        # state residuals the backward saves at 1/n_model size.
        xh = ctx.constrain(xh, ctx.batch_axes, None, "model", None)
        dt = ctx.constrain(dt, ctx.batch_axes, None, "model")
    y = _ssd_chunked(xh, dt, A, Bp.reshape(B, L, G, N), Cp.reshape(B, L, G, N), Q,
                     ctx=ctx)
    y = y + xh.astype(jnp.float32) * p["Dskip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, L, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    # group RMSNorm over d_inner
    y32 = y.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(y32 * y32, axis=-1, keepdims=True) + cfg.norm_eps)
    y = (y32 * inv * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("ble,ed->bld", y, p["wo"])


def _ssd_chunked(x, dt, A, Bm, Cm, Q, ctx=None):
    """Minimal-SSD. x (B,L,H,P) f*, dt (B,L,H) f32, A (H,), Bm/Cm (B,L,G,N).

    Returns y (B, L, H, P) f32.
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    nC = L // Q
    Hg = H // G
    state_spec = None
    if ctx is not None and Hg % ctx.n_model == 0:
        state_spec = (ctx.batch_axes, None, "model", None, None)  # (B,G,Hg,N,P)
    # chunked views
    xc = x.reshape(B, nC, Q, G, Hg, P).astype(jnp.float32)
    dtc = dt.reshape(B, nC, Q, G, Hg)
    Bc = Bm.reshape(B, nC, Q, G, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nC, Q, G, N).astype(jnp.float32)
    a = dtc * A.reshape(1, 1, 1, G, Hg)                    # (B,C,Q,G,Hg) <= 0
    cum = jnp.cumsum(a, axis=2)                            # running log-decay
    # move chunk axis first for scan
    xs = (
        jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0), jnp.moveaxis(Bc, 1, 0),
        jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(cum, 1, 0),
    )
    state0 = jnp.zeros((B, G, Hg, N, P), jnp.float32)

    def body(state, chunk):
        xq, dtq, Bq, Cq, cumq = chunk
        if state_spec is not None:
            state = jax.lax.with_sharding_constraint(
                state, ctx.ns(*state_spec)
            )
            xq = jax.lax.with_sharding_constraint(
                xq, ctx.ns(ctx.batch_axes, None, None, "model", None)
            )  # (B,Q,G,Hg,P)
        # intra-chunk (quadratic in Q)
        scores = jnp.einsum("bqgn,bkgn->bgqk", Cq, Bq)
        # seg[b,q,k,g,h] = cum[q] - cum[k]  (log-decay between positions).
        # Mask BEFORE exp: upper-triangle seg is large-positive and exp would
        # overflow, leaking NaN through where()'s gradient.
        seg = cumq[:, :, None, :, :] - cumq[:, None, :, :, :]
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        seg = jnp.where(causal[None, :, :, None, None], seg, -1e30)
        Lmat = jnp.exp(seg)
        M = scores.transpose(0, 2, 3, 1)[..., :, None] * Lmat  # (B,Q,K,G,Hg)
        y_intra = jnp.einsum("bqkgh,bkgh,bkghp->bqghp", M, dtq, xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqgn,bghnp,bqgh->bqghp", Cq, state, jnp.exp(cumq))
        # state update
        decay_to_end = jnp.exp(cumq[:, -1:, :, :] - cumq)      # (B,Q,G,Hg)
        s_new = jnp.einsum("bkgn,bkgh,bkghp->bghnp", Bq, dtq * decay_to_end, xq)
        state = jnp.exp(cumq[:, -1])[:, :, :, None, None] * state + s_new
        return state, y_intra + y_inter

    _, ys = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, L, G * Hg, P)
    return y


def mamba2_decode_step(p, x, conv_state, ssm_state, cfg: ArchConfig):
    """Single-token recurrence. x (B, D); conv_state (B, K-1, conv_dim);
    ssm_state (B, G, Hg, N, P). Returns (y (B, D), conv_state', ssm_state')."""
    B, D = x.shape
    H, Pd, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    Hg = H // G
    d_in = cfg.d_inner
    z = jnp.einsum("bd,de->be", x, p["wz"])
    xin = jnp.einsum("bd,de->be", x, p["wx"])
    Bp = jnp.einsum("bd,dn->bn", x, p["wB"])
    Cp = jnp.einsum("bd,dn->bn", x, p["wC"])
    dt_raw = jnp.einsum("bd,dh->bh", x, p["wdt"])
    xBC = jnp.concatenate([xin, Bp, Cp], axis=-1)              # (B, conv_dim)
    # conv over [state ; new]
    K = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:, :]
    xin = xBC[:, :d_in]
    Bp = xBC[:, d_in : d_in + G * N].reshape(B, G, N)
    Cp = xBC[:, d_in + G * N :].reshape(B, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dth = dt.reshape(B, G, Hg)
    xh = xin.reshape(B, G, Hg, Pd).astype(jnp.float32)
    decay = jnp.exp(dth * A.reshape(1, G, Hg))                 # (B,G,Hg)
    upd = jnp.einsum("bgn,bgh,bghp->bghnp", Bp.astype(jnp.float32), dth, xh)
    ssm_state = decay[..., None, None] * ssm_state + upd
    y = jnp.einsum("bgn,bghnp->bghp", Cp.astype(jnp.float32), ssm_state)
    y = y + xh * p["Dskip"].astype(jnp.float32).reshape(1, G, Hg, 1)
    y = y.reshape(B, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    inv = jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + cfg.norm_eps)
    y = (y * inv * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("be,ed->bd", y, p["wo"]), new_conv, ssm_state


def mamba2_param_shapes(cfg: ArchConfig) -> dict:
    D, d_in, H, N = cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    K = cfg.conv_kernel
    f32, bf = jnp.float32, jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "wz": ((D, d_in), bf), "wx": ((D, d_in), bf),
        "wB": ((D, G * N), bf), "wC": ((D, G * N), bf),
        "wdt": ((D, H), bf), "dt_bias": ((H,), f32),
        "A_log": ((H,), f32), "Dskip": ((H,), f32),
        "conv_w": ((K, conv_dim), bf), "norm": ((d_in,), f32),
        "wo": ((d_in, D), bf),
    }
