from repro.net.sim import (
    RPC,
    Join,
    LatencyModel,
    Network,
    OpFuture,
    Server,
    Sleep,
    msg_wire_size,
    nbytes,
)

__all__ = [
    "Network",
    "Server",
    "RPC",
    "Join",
    "Sleep",
    "OpFuture",
    "LatencyModel",
    "nbytes",
    "msg_wire_size",
]
