from repro.net.sim import (
    RPC,
    Join,
    LatencyModel,
    Network,
    OpFuture,
    Server,
    Sleep,
    nbytes,
)

__all__ = ["Network", "Server", "RPC", "Join", "Sleep", "OpFuture", "LatencyModel", "nbytes"]
