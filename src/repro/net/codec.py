"""Wire codec: length-prefixed binary framing for server messages (ISSUE 3).

The simulator used to charge message latency by a per-Python-object heuristic
(``nbytes``: 16 bytes per tuple, 8 per int, ...), which over-charges small
control messages and ignores real framing costs — the ROADMAP's "wire-level
framing" open item. This module defines an actual wire format for the
protocol's message vocabulary and the ``Network`` now charges
``len(encode_frame(msg))`` for every message it can frame (anything else
falls back to the heuristic).

Format
------
A frame is ``uvarint(len(body)) || body``. A body is a one-byte type tag
followed by the payload:

    N                       None
    T / F                   True / False
    i  zigzag-uvarint       int (arbitrary precision, small ints 1 byte)
    d  8 bytes big-endian   float (IEEE-754 double)
    s  uvarint n, n bytes   str (UTF-8)
    b  uvarint n, n bytes   bytes / bytearray / memoryview
    t  uvarint n, n bodies  tuple
    l  uvarint n, n bodies  list
    m  uvarint n, n k/v     dict (insertion order preserved)
    C  5 bodies             Config (cfg_id, servers, dap, k, delta)
    a  dtype,shape,raw      numpy ndarray (C-contiguous copy)

Everything the storage servers send or receive — tags ``(ts, wid)``, coded
elements ``(bytes, orig_len)`` and their checksummed ``(bytes, orig_len,
crc32)`` form (ISSUE 6; the CRC is a plain uvarint int, so integrity tags
cost <= 6 wire bytes per fragment), ``Config`` objects inside ``read-next``
replies, the ``*_batch`` envelopes — round-trips exactly (``decode_frame(encode_frame
(m)) == m``; property-tested in ``tests/test_codec.py``). ``wire_size``
computes the framed size *without* materialising the frame, so per-message
accounting stays O(structure) with no big-payload copies.
"""
from __future__ import annotations

import struct
from typing import Any

import numpy as np


class CodecError(ValueError):
    """Object is outside the wire vocabulary (caller should fall back)."""


# --------------------------------------------------------- message registry
# The protocol's message vocabulary, declared next to the wire format it
# rides on. ``repro.analysis``'s registry-drift lint parses these literals
# and cross-checks them against ``core/server.py``'s ``_DISPATCH`` table and
# handler reply tags (and the gateway's gossip vocabulary) in BOTH
# directions, so adding a handler without auditing its framing — or
# retiring one and leaving a stale registry entry — fails ``make analyze``.
# The runtime sanitizer uses the same sets to flag unknown tags on live
# traffic, and ``tests/test_codec.py`` round-trips one exemplar per entry.

#: request tags the storage servers dispatch (``StorageServer._DISPATCH``)
MESSAGE_TYPES: frozenset = frozenset({
    "ec-query-batch", "ec-put-batch", "abd-get-batch", "abd-put-batch",
    "read-next-batch", "write-next-batch", "cons-p1-batch", "cons-p2-batch",
    "margin-batch",
    "abd-get", "abd-get-tag", "abd-put",
    "ec-query", "ec-put", "ec-repair-pull", "ec-repair-push",
    "read-next", "write-next", "cons-p1", "cons-p2",
})

#: reply tags the storage-server handlers return
REPLY_TYPES: frozenset = frozenset({
    "ec-list-batch", "abd-val-batch", "next-c-batch", "p1-batch", "p2-batch",
    "margin-batch",
    "abd-val", "abd-tag", "ec-list", "ec-repair-list",
    "next-c", "ack", "repair-ack",
    "p1-ok", "p1-nack", "p2-ok", "p2-nack",
})

#: gateway anti-entropy vocabulary (``GossipListener.handle``)
GOSSIP_TYPES: frozenset = frozenset({"gossip-configs"})
GOSSIP_REPLY_TYPES: frozenset = frozenset({"gossip-ack"})


_CONFIG_CLS = None


def _config_cls():
    """``repro.core.tags.Config``, imported lazily: ``repro.net.sim`` imports
    this module, and importing ``repro.core.tags`` at module load would run
    ``repro.core.__init__`` → ``coares`` → ``repro.net.sim`` mid-init. The
    codec is only exercised at runtime, when everything is loaded."""
    global _CONFIG_CLS
    if _CONFIG_CLS is None:
        from repro.core.tags import Config

        _CONFIG_CLS = Config
    return _CONFIG_CLS


# ----------------------------------------------------------------- varints
def _uvarint(n: int) -> bytes:
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        out.append(b | (0x80 if n else 0))
        if not n:
            return bytes(out)


def _uvarint_size(n: int) -> int:
    size = 1
    while n > 0x7F:
        n >>= 7
        size += 1
    return size


def _read_uvarint(buf, pos: int) -> tuple[int, int]:
    n = shift = 0
    while True:
        b = buf[pos]
        pos += 1
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            return n, pos
        shift += 7


def _zigzag(n: int) -> int:
    return n << 1 if n >= 0 else ((-n) << 1) - 1


def _unzigzag(z: int) -> int:
    return z >> 1 if not z & 1 else -((z + 1) >> 1)


# ------------------------------------------------------------------ encode
def _encode_into(obj: Any, out: bytearray) -> None:
    if obj is None:
        out += b"N"
    elif obj is True:
        out += b"T"
    elif obj is False:
        out += b"F"
    elif isinstance(obj, int) and not isinstance(obj, bool):
        out += b"i"
        out += _uvarint(_zigzag(obj))
    elif isinstance(obj, float):
        out += b"d"
        out += struct.pack(">d", obj)
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out += b"s"
        out += _uvarint(len(raw))
        out += raw
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        raw = bytes(obj)
        out += b"b"
        out += _uvarint(len(raw))
        out += raw
    elif isinstance(obj, tuple):
        out += b"t"
        out += _uvarint(len(obj))
        for x in obj:
            _encode_into(x, out)
    elif isinstance(obj, list):
        out += b"l"
        out += _uvarint(len(obj))
        for x in obj:
            _encode_into(x, out)
    elif isinstance(obj, dict):
        out += b"m"
        out += _uvarint(len(obj))
        for k, v in obj.items():
            _encode_into(k, out)
            _encode_into(v, out)
    elif isinstance(obj, _config_cls()):
        out += b"C"
        _encode_into(obj.cfg_id, out)
        _encode_into(obj.servers, out)
        _encode_into(obj.dap, out)
        _encode_into(obj.k, out)
        _encode_into(obj.delta, out)
    elif isinstance(obj, np.ndarray):
        if obj.dtype.hasobject:
            # pointer bytes would frame (and size) but never round-trip
            raise CodecError("object-dtype ndarray is not wire-encodable")
        arr = np.ascontiguousarray(obj)
        out += b"a"
        _encode_into(arr.dtype.str, out)
        _encode_into(tuple(int(d) for d in arr.shape), out)
        raw = arr.tobytes()
        out += _uvarint(len(raw))
        out += raw
    elif isinstance(obj, np.integer):
        _encode_into(int(obj), out)
    elif isinstance(obj, np.floating):
        _encode_into(float(obj), out)
    else:
        raise CodecError(f"not wire-encodable: {type(obj).__name__}")


def encode(obj: Any) -> bytes:
    """Encode one body (no length prefix)."""
    out = bytearray()
    _encode_into(obj, out)
    return bytes(out)


def encode_frame(obj: Any) -> bytes:
    """Length-prefixed frame: ``uvarint(len(body)) || body``."""
    body = encode(obj)
    return _uvarint(len(body)) + body


# ------------------------------------------------------------------ decode
def _decode_at(buf, pos: int) -> tuple[Any, int]:
    tag = buf[pos : pos + 1]
    pos += 1
    if tag == b"N":
        return None, pos
    if tag == b"T":
        return True, pos
    if tag == b"F":
        return False, pos
    if tag == b"i":
        z, pos = _read_uvarint(buf, pos)
        return _unzigzag(z), pos
    if tag == b"d":
        return struct.unpack(">d", buf[pos : pos + 8])[0], pos + 8
    if tag == b"s":
        n, pos = _read_uvarint(buf, pos)
        return bytes(buf[pos : pos + n]).decode("utf-8"), pos + n
    if tag == b"b":
        n, pos = _read_uvarint(buf, pos)
        return bytes(buf[pos : pos + n]), pos + n
    if tag in (b"t", b"l"):
        n, pos = _read_uvarint(buf, pos)
        items = []
        for _ in range(n):
            x, pos = _decode_at(buf, pos)
            items.append(x)
        return (tuple(items) if tag == b"t" else items), pos
    if tag == b"m":
        n, pos = _read_uvarint(buf, pos)
        d = {}
        for _ in range(n):
            k, pos = _decode_at(buf, pos)
            v, pos = _decode_at(buf, pos)
            d[k] = v
        return d, pos
    if tag == b"C":
        cfg_id, pos = _decode_at(buf, pos)
        servers, pos = _decode_at(buf, pos)
        dap, pos = _decode_at(buf, pos)
        k, pos = _decode_at(buf, pos)
        delta, pos = _decode_at(buf, pos)
        return _config_cls()(cfg_id, servers, dap=dap, k=k, delta=delta), pos
    if tag == b"a":
        dtype, pos = _decode_at(buf, pos)
        shape, pos = _decode_at(buf, pos)
        n, pos = _read_uvarint(buf, pos)
        arr = np.frombuffer(bytes(buf[pos : pos + n]), dtype=np.dtype(dtype))
        return arr.reshape(shape), pos + n
    raise CodecError(f"bad wire tag {tag!r} at {pos - 1}")


def decode(body: bytes) -> Any:
    obj, pos = _decode_at(body, 0)
    if pos != len(body):
        raise CodecError(f"{len(body) - pos} trailing bytes after body")
    return obj


def decode_frame(frame: bytes) -> Any:
    n, pos = _read_uvarint(frame, 0)
    if len(frame) - pos != n:
        raise CodecError(f"frame length {n} != {len(frame) - pos} body bytes")
    return decode(frame[pos:])


# --------------------------------------------------------------- wire size
def _body_size(obj: Any) -> int:
    if obj is None or obj is True or obj is False:
        return 1
    if isinstance(obj, int) and not isinstance(obj, bool):
        return 1 + _uvarint_size(_zigzag(obj))
    if isinstance(obj, float):
        return 9
    if isinstance(obj, str):
        n = len(obj) if obj.isascii() else len(obj.encode("utf-8"))
        return 1 + _uvarint_size(n) + n
    if isinstance(obj, (bytes, bytearray, memoryview)):
        # memoryview len() counts ELEMENTS; nbytes is the encoded length
        n = obj.nbytes if isinstance(obj, memoryview) else len(obj)
        return 1 + _uvarint_size(n) + n
    if isinstance(obj, (tuple, list)):
        return 1 + _uvarint_size(len(obj)) + sum(_body_size(x) for x in obj)
    if isinstance(obj, dict):
        return (
            1
            + _uvarint_size(len(obj))
            + sum(_body_size(k) + _body_size(v) for k, v in obj.items())
        )
    if isinstance(obj, _config_cls()):
        return (
            1
            + _body_size(obj.cfg_id)
            + _body_size(obj.servers)
            + _body_size(obj.dap)
            + _body_size(obj.k)
            + _body_size(obj.delta)
        )
    if isinstance(obj, np.ndarray):
        if obj.dtype.hasobject:
            raise CodecError("object-dtype ndarray is not wire-encodable")
        n = int(obj.nbytes)
        return (
            1
            + _body_size(obj.dtype.str)
            + _body_size(tuple(int(d) for d in obj.shape))
            + _uvarint_size(n)
            + n
        )
    if isinstance(obj, np.integer):
        return _body_size(int(obj))
    if isinstance(obj, np.floating):
        return 9
    raise CodecError(f"not wire-encodable: {type(obj).__name__}")


def wire_size(obj: Any) -> int:
    """``len(encode_frame(obj))`` without building the frame."""
    body = _body_size(obj)
    return _uvarint_size(body) + body


def try_wire_size(obj: Any) -> int | None:
    """Framed size, or None when the object is outside the vocabulary."""
    try:
        return wire_size(obj)
    except CodecError:
        return None


# ----------------------------------------------------- identity size memo
class SizingMemo:
    """Wire-size computation with an identity-keyed memo (ISSUE 7).

    ``wire_size`` walks a message's whole structure; on the simulator's hot
    path the SAME objects recur constantly — a broadcast payload framed once
    per fan-out but re-framed on every retry round, stored tags / coded
    elements / ``Config`` objects embedded in every ``ec-list`` and
    ``read-next`` reply, a gateway's multicast entries. This memo caches the
    body size of every *transitively immutable* node it walks (tuples of
    immutables, ``Config``, and the leaf scalars they contain), keyed on
    ``id(obj)`` with the object pinned in the memo so the id cannot be
    recycled while the entry lives. Mutable containers (list / dict /
    bytearray / memoryview / ndarray) are never cached — their size is
    re-walked on every call — so in-place mutation can never yield a stale
    size. The memo is bounded: it is cleared wholesale past ``max_entries``
    entries or ``max_pinned_bytes`` of cumulative wire size (an identity
    cache has no useful eviction order, and pinning keeps payload bytes
    alive — the byte budget stops a long run from retaining every payload
    it ever framed).

    On top of the identity memo sits a *content* cache for whole messages:
    protocol requests are built fresh every round, so they never identity-hit,
    yet under a zipfian workload the same message **values** recur across
    thousands of sessions. ``wire_size`` therefore also keys finished frames
    by the message object itself (dict hash, C speed) — guarded by a
    ``repr`` fingerprint, because Python equality is coarser than the wire
    format: ``0 == False == 0.0`` yet the three frame differently. Two
    objects that are ``==`` *and* share a ``repr`` have pairwise-equal leaves
    of identical types, hence identical frames, so a fingerprint-verified hit
    is exact; a mismatch just falls back to the walk. Only hashable,
    transitively-immutable values with frames ≤ ``content_max_frame`` are
    cached (big payload frames would make the repr check itself expensive).

    Sizes are exactly ``wire_size``'s — the memo changes cost, never the
    charged bytes (property-tested in ``tests/test_scalepath.py``).
    """

    __slots__ = (
        "_memo", "_frame", "_pinned",
        "max_entries", "max_pinned_bytes", "content_max_frame",
    )

    def __init__(self, max_entries: int = 1 << 18, max_pinned_bytes: int = 64 << 20,
                 content_max_frame: int = 4096):
        self._memo: dict[int, tuple[Any, int]] = {}
        self._frame: dict[Any, tuple[str, int, int]] = {}
        self._pinned = 0
        self.max_entries = max_entries
        self.max_pinned_bytes = max_pinned_bytes
        self.content_max_frame = content_max_frame

    def wire_size(self, obj: Any) -> int:
        """``len(encode_frame(obj))`` without building the frame (memoized).
        Raises :class:`CodecError` outside the vocabulary, like
        :func:`wire_size`."""
        hit = self._memo.get(id(obj))
        if hit is not None and hit[0] is obj:
            body = hit[1]
            return _uvarint_size(body) + body
        try:
            ent = self._frame.get(obj)
        except TypeError:  # unhashable content (list/dict/bytearray inside)
            hashable = False
        else:
            hashable = True
            if ent is not None and ent[0] == repr(obj):
                # promote: repeated calls with this very object id-hit above
                # instead of paying the repr fingerprint every time
                self._remember(obj, ent[2])
                return ent[1]
        body, pure = self._size(obj)
        total = _uvarint_size(body) + body
        if hashable and pure and total <= self.content_max_frame:
            frame = self._frame
            if len(frame) >= self.max_entries:
                frame.clear()
            frame[obj] = (repr(obj), total, body)
        return total

    def _remember(self, obj: Any, size: int) -> None:
        memo = self._memo
        if len(memo) >= self.max_entries or self._pinned > self.max_pinned_bytes:
            memo.clear()
            self._pinned = 0
        memo[id(obj)] = (obj, size)
        self._pinned += size

    def _size(self, obj: Any) -> tuple[int, bool]:
        """(body size, transitively-immutable?) — only pure nodes are cached."""
        if obj is None or obj is True or obj is False:
            return 1, True
        cls = type(obj)
        if cls is int:
            return 1 + _uvarint_size(_zigzag(obj)), True
        if cls is float:
            return 9, True
        if cls is str:
            n = len(obj) if obj.isascii() else len(obj.encode("utf-8"))
            return 1 + _uvarint_size(n) + n, True
        if cls is bytes:
            n = len(obj)
            return 1 + _uvarint_size(n) + n, True
        if cls is tuple:
            hit = self._memo.get(id(obj))
            if hit is not None and hit[0] is obj:
                return hit[1], True
            size = 1 + _uvarint_size(len(obj))
            pure = True
            for x in obj:
                s, p = self._size(x)
                size += s
                pure = pure and p
            if pure:
                self._remember(obj, size)
            return size, pure
        if cls is list:
            size = 1 + _uvarint_size(len(obj))
            for x in obj:
                size += self._size(x)[0]
            return size, False
        if cls is dict:
            size = 1 + _uvarint_size(len(obj))
            for k, v in obj.items():
                size += self._size(k)[0] + self._size(v)[0]
            return size, False
        if isinstance(obj, _config_cls()):
            # frozen dataclass over immutable fields: always cacheable
            hit = self._memo.get(id(obj))
            if hit is not None and hit[0] is obj:
                return hit[1], True
            size = (
                1
                + self._size(obj.cfg_id)[0]
                + self._size(obj.servers)[0]
                + self._size(obj.dap)[0]
                + self._size(obj.k)[0]
                + self._size(obj.delta)[0]
            )
            self._remember(obj, size)
            return size, True
        # uncommon/mutable leaves: defer to the plain walk, never cache
        if isinstance(obj, (bytearray, memoryview, np.ndarray)):
            return _body_size(obj), False
        if isinstance(obj, (int, bool)):  # bool/int subclasses
            return _body_size(obj), True
        if isinstance(obj, (float, str, bytes, np.integer, np.floating)):
            return _body_size(obj), True
        if isinstance(obj, (tuple, list)):  # subclasses: size, don't cache
            return _body_size(obj), False
        if isinstance(obj, dict):
            return _body_size(obj), False
        raise CodecError(f"not wire-encodable: {type(obj).__name__}")
