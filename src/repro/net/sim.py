"""Deterministic discrete-event asynchronous network simulator.

The paper evaluates on Emulab (emulated LAN) and AWS EC2 (real WAN). This
module provides the third option used throughout this repo: a **virtual-time
event simulator** with per-message latency = base ~ U[lo, hi] + size/bandwidth
(+ optional jitter/drops), crash/recover injection, and size-aware payload
accounting. Virtual time makes every benchmark deterministic and lets the
test-suite check linearizability/coverability against recorded histories —
something a live testbed cannot do.

Programming model
-----------------
*Servers* are objects with a synchronous ``handle(sender, msg) -> reply``.
*Client operations* are Python generators that ``yield`` effects:

    replies = yield RPC(dests=[...], msg=(...), need=q)   # quorum round-trip
    yield Sleep(0.01)                                     # backoff

``yield from`` composes sub-protocols (a CoARES write yields from read-config,
which yields from per-config RPCs, ...). ``Network.spawn`` turns a generator
into an ``OpFuture``; ``Network.run`` drives the event loop to quiescence.
Replies arriving after a quorum resumed the generator are delivered to the
runner and ignored — exactly the paper's "wait for a quorum, ignore the rest".

Scale-out hot path (ISSUE 7)
----------------------------
At 10^5 sessions the driver itself — not the storage protocol — used to be
the bottleneck: every message paid a heapq push, a Python closure, a scalar
RNG draw and a codec walk. The engine now runs an allocation-light fan-out
path by default (``Network(fast=True)``, ``DSSParams.fast_net``):

* **one scheduled event per RPC fan-out** — a ``_FanOut`` cursor walks its
  pre-computed arrival schedule, inline-draining consecutive arrivals while
  they precede everything else in the heap, instead of one closure + heap
  entry per destination;
* **pooled RNG draws** — one ``rng.uniform(size=2B)`` per fan-out (outbound
  props then reply props, in destination order). Drop flags come from a
  dedicated ``_drop_rng`` stream and are *only drawn when ``drop_prob > 0``*,
  so toggling drops no longer perturbs every latency sample;
* **interned endpoints** — per-client [rounds, msgs, bytes] accounting and
  NIC busy-until tracking live in flat rows indexed by interned endpoint id
  (``client_counters`` survives as a read-only dict view), and fan-out
  destination tuples resolve to interned server lists once, not per round;
* **wire-size memo** — ``codec.SizingMemo`` frames immutable message
  subtrees once, not once per recipient/retry.

Determinism is the contract: for a fixed seed the fast path replays
*byte- and event-identical* traces versus the per-destination legacy path
(``fast=False``), which draws the same canonical per-fan-out stream but pays
the seed implementation's per-message costs. ``tests/test_scalepath.py``
pins trace identity on mixed workloads.

Schedule control (ISSUE 9)
--------------------------
``Network.controller`` (default ``None``) hands the event loop's pop policy
to an external scheduler — ``repro.analysis.explore.ScheduleController`` —
so a model checker can turn "which pending delivery lands next" into an
explicit, replayable decision. With a controller attached:

* ``run``/``step`` call ``controller.step(net)`` instead of popping the
  heap min, and every ``schedule`` call reports its ``(seq, key)`` so the
  controller can reason about which events commute (``key`` labels the
  event's target endpoint: ``("srv", sid)`` for message arrivals,
  ``("rpl", client)`` for reply deliveries, ``("cli", client)`` for op
  resumes/timers);
* ``_FanOut`` stops inline-draining — every arrival re-enters the heap as
  its own event, so each delivery is its own decision point (the cursor's
  reserved sequence numbers are unchanged, so a controller that always
  picks the heap minimum replays the exact uncontrolled trace);
* the controller may mark the event it executes as *dropped*
  (``consume_drop``): a dropped arrival never reaches ``handle`` and a
  dropped reply never reaches the op — message loss as a schedulable
  choice, drawn from no RNG stream.

``Network.race_tracker`` (default ``None``) is a second pure observer —
``repro.analysis.races.RaceTracker`` — fed from the same three points as
the sanitizer (RPC issue, arrival processing, counted reply delivery) plus
the tracked-map mutation hooks in ``core/server.py``; it maintains
vector clocks per operation and flags conflicting unordered writes to
per-object server state. Both attributes cost one ``is not None`` per
event when unset, and neither draws randomness nor schedules events.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass
from functools import partial
from time import perf_counter  # protocol-lint: allow-determinism (profile_protocol wall split only; virtual time never reads it)
from typing import Any, Callable, Generator

import numpy as np

from repro.net.codec import CodecError, SizingMemo, try_wire_size


def nbytes(obj: Any) -> int:
    """Approximate wire size of a message payload (drives latency model).

    This is the legacy per-Python-object heuristic, kept as the FALLBACK for
    payloads outside the wire codec's vocabulary — protocol messages are
    charged their real framed size via ``msg_wire_size`` (ISSUE 3)."""
    if obj is None:
        return 1
    if isinstance(obj, (bytes, bytearray, memoryview)):
        return len(obj)
    if isinstance(obj, str):
        # UTF-8 byte length, not code-point count (ISSUE 7): "héllo" is six
        # bytes on any real wire; len() undercounted every non-ASCII string.
        return len(obj) if obj.isascii() else len(obj.encode("utf-8"))
    if isinstance(obj, bool):  # before int: bool is an int subclass
        return 1
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, np.ndarray):
        # An ndarray nested inside an out-of-vocabulary container used to be
        # charged the legacy ``16 + nbytes`` guess; route it through the
        # codec's real ndarray framing instead (ISSUE 4) — the codec knows
        # the exact dtype/shape/payload frame, so containers that mix arrays
        # with un-frameable objects stop being over-charged per array.
        size = try_wire_size(obj)
        return 16 + int(obj.nbytes) if size is None else size
    if isinstance(obj, np.generic):
        return int(obj.nbytes)
    if isinstance(obj, (tuple, list, set, frozenset)):
        return 16 + sum(nbytes(x) for x in obj)
    if isinstance(obj, dict):
        return 16 + sum(nbytes(k) + nbytes(v) for k, v in obj.items())
    if hasattr(obj, "wire_size"):
        return int(obj.wire_size())
    return 64


def msg_wire_size(obj: Any) -> int:
    """Bytes charged for one message on the wire: the codec's length-prefixed
    frame size when the payload is wire-encodable (every protocol message
    is — see ``repro.net.codec``), else the ``nbytes`` heuristic."""
    size = try_wire_size(obj)
    return nbytes(obj) if size is None else size


@dataclass
class LatencyModel:
    """Virtual-time cost model (defaults roughly calibrated to a 1 GbE LAN —
    the paper's Emulab setup; see benchmarks for the AWS-ish WAN variant)."""

    base_lo: float = 0.2e-3          # per-message propagation floor (s)
    base_hi: float = 0.8e-3
    bandwidth: float = 125e6         # bytes/s (1 Gbit/s)
    drop_prob: float = 0.0
    # duplicate delivery (ISSUE 10): with probability ``dup_prob`` a request
    # message arrives TWICE — the handler runs again on the same payload
    # (at-least-once delivery), the duplicate's wire bytes are charged, and
    # its reply is discarded client-side. Draws come from a dedicated
    # ``_dup_rng`` stream only when > 0, so the default consumes nothing.
    dup_prob: float = 0.0
    server_compute: float = 20e-6    # per-message server handling (s)
    # client-side compute models (per byte, s):
    enc_per_byte: float = 0.6e-9     # RS encode  (§VI: encode faster ...)
    dec_per_byte: float = 1.2e-9     # RS decode  (... than decode)
    bi_per_byte: float = 1.0e-9      # FM block identification (rabin/gear+match)
    # Serialize transmissions per endpoint NIC (ISSUE 2): concurrent messages
    # share an endpoint's bandwidth instead of each enjoying the full line
    # rate. Without this, a B-way parallel fan-out of B·L bytes finishes as
    # fast as one L-byte message — physically impossible, and it hid exactly
    # the per-message overhead the paper's §VII-D read argument is about.
    serialize_links: bool = True

    def msg_delay(self, rng: np.random.Generator, size: int) -> float:
        return float(rng.uniform(self.base_lo, self.base_hi)) + size / self.bandwidth


class QuorumUnavailableError(RuntimeError):
    """Typed liveness failure (ISSUE 10): an operation could not assemble a
    quorum within its retry budget. Safety is unaffected — the op performed
    no externally visible partial effect a retry would not have been allowed
    to repeat — but the service was UNAVAILABLE for this op. Protocol phase
    wrappers raise this after exhausting ``RetryPolicy.phase_retries``."""


class RpcTimeout(QuorumUnavailableError):
    """One RPC round missed its per-attempt deadline chain: ``need`` distinct
    replies never arrived within ``RetryPolicy.max_attempts`` retransmissions.
    Thrown INTO the op generator at the pending ``yield RPC`` so protocol
    code can catch it and re-issue the phase against the current config."""


class DeadlineExceeded(RuntimeError):
    """``OpFuture.result(deadline=...)``: the op did not complete within the
    virtual-time deadline (or the network quiesced with the op still pending
    — a lost quorum with retries disabled). Carries ``Network.stuck_ops()``
    diagnostics in the message."""


@dataclass(frozen=True)
class RetryPolicy:
    """Failure-survival knobs (ISSUE 10), plumbed via ``DSSParams.retry``.

    ``None`` (the default everywhere) disables the whole machinery: no RNG
    stream is consumed, no timer events are scheduled, no sequence numbers
    are reserved — traces are bit-identical to a build without the feature.

    With a policy set, every quorum-mode RPC round arms a deterministic
    virtual-time deadline timer: on expiry the round retransmits to the
    destinations that have not replied (handlers are idempotent / guarded,
    and client-side replies are keyed by server id, so duplicates cannot
    double-count toward the quorum), with exponential backoff and seeded
    jitter from the dedicated ``_retry_rng`` stream. After ``max_attempts``
    the round throws :class:`RpcTimeout` into the op generator; the protocol
    tier retries whole phases ``phase_retries`` times against the current
    configuration before surfacing :class:`QuorumUnavailableError`."""

    rpc_timeout: float = 10e-3       # attempt 1 deadline (virtual s)
    backoff: float = 2.0             # per-attempt timeout multiplier
    jitter: float = 0.25             # timeout *= 1 + jitter*U[0,1) when > 0
    max_attempts: int = 4            # send attempts per RPC round
    # hedged duplicate send (tail-latency): ``hedge_after`` virtual seconds
    # into attempt 1, re-send to the laggards WITHOUT burning an attempt.
    hedge_after: float | None = None
    phase_retries: int = 2           # protocol-phase re-issues on RpcTimeout
    phase_backoff: float = 5e-3      # base phase backoff (linear x attempt)
    op_deadline: float = 60.0        # OpFuture.result default deadline


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` in crash | recover | partition | heal |
    heal-all | slow | unslow. ``peer`` is the partition/heal destination
    endpoint, ``extra`` the gray-failure added latency (s), ``wipe`` the
    crash-recovery volatile-state wipe flag."""

    at: float
    kind: str
    target: str = ""
    peer: str = ""
    extra: float = 0.0
    wipe: bool = True


@dataclass(frozen=True)
class FaultPlan:
    """Declarative fault schedule (ISSUE 10): crash-stop, crash-recovery,
    asymmetric link partitions and gray failures as timed events, applied
    relative to ``net.now`` at :meth:`apply` time. Deterministic — no RNG."""

    events: tuple = ()

    def apply(self, net: "Network") -> None:
        for ev in self.events:
            net.schedule(ev.at, partial(self._fire, net, ev))

    @staticmethod
    def _fire(net: "Network", ev: FaultEvent) -> None:
        kind = ev.kind
        if kind == "crash":
            net.crash(ev.target)
        elif kind == "recover":
            net.recover(ev.target, wipe=ev.wipe)
        elif kind == "partition":
            net.partition(ev.target, ev.peer)
        elif kind == "heal":
            net.heal(ev.target, ev.peer)
        elif kind == "heal-all":
            net.heal()
        elif kind == "slow":
            net.slow(ev.target, ev.extra)
        elif kind == "unslow":
            net.unslow(ev.target)
        else:
            raise ValueError(f"unknown fault kind {kind!r}")


@dataclass
class RPC:
    """Send ``msg`` to every server in ``dests``; resume the op generator once
    ``need`` distinct servers replied. The generator receives ``{sid: reply}``.

    ``need`` may be the string ``"alive"``: it resolves to the number of
    destinations whose server is live at issue time (resuming immediately
    with ``{}`` when none are). This is the server-addressed pull the repair
    subsystem uses — "everyone who can answer", without hanging on crashed
    servers. A destination counted at issue that can no longer reply — it
    crashed before the message landed, declined to answer, or its message /
    reply was dropped — is *abandoned*: the required count shrinks so the op
    resumes with whatever the remaining live servers return instead of
    hanging (ISSUE 7; numeric ``need`` keeps the strict quorum-wait
    semantics).

    ``per_dest`` (optional) overrides ``msg`` per server — used by the EC
    put-data, which ships a *different coded fragment* to each server."""

    dests: tuple
    msg: Any
    need: int | str
    # extra client-side compute charged before sending (e.g. encode cost)
    pre_delay: float = 0.0
    per_dest: dict | None = None


@dataclass
class Sleep:
    duration: float


@dataclass
class Join:
    """Run child operation generators CONCURRENTLY; resume the parent with
    the list of their results (in order). Used by the indexed Fragmentation
    Module to issue block reads/writes in parallel (EXPERIMENTS.md §Perf,
    storage iteration)."""

    children: list


@dataclass
class OpFuture:
    op_id: int
    kind: str = ""
    client: str = ""
    start: float = 0.0
    end: float = 0.0
    done: bool = False
    result: Any = None

    @property
    def latency(self) -> float:
        """Virtual seconds from spawn to completion; ``nan`` while the op is
        still in flight (``end`` is not meaningful before ``done`` — the old
        ``end - start`` returned a nonsense negative value, ISSUE 7)."""
        return self.end - self.start if self.done else math.nan


class Server:
    """Base class: subclasses implement ``handle``; crash state lives here."""

    def __init__(self, sid: str):
        self.sid = sid
        self.crashed = False

    def handle(self, sender: str, msg: Any) -> Any:  # pragma: no cover
        raise NotImplementedError

    def on_recover(self) -> None:
        """Crash-recovery hook (ISSUE 10): wipe volatile state that must not
        survive a crash (reply/identity caches, in-flight handler scratch).
        Durable protocol state (tags, blocks, configs) stays. Base: no-op."""


class _RpcState:
    """Shared per-RPC bookkeeping for both send paths: reply collection,
    quorum resume, and ``need="alive"`` abandonment."""

    __slots__ = (
        "net", "gen", "fut", "on_done", "acct", "src_i",
        "need", "alive", "counted", "replies", "resumed",
        "rpc", "attempt", "hedged",
    )

    def __init__(self, net, gen, fut, on_done, acct, src_i, need, alive, counted):
        self.net = net
        self.gen = gen
        self.fut = fut
        self.on_done = on_done
        self.acct = acct
        self.src_i = src_i
        self.need = need
        self.alive = alive
        # alive mode only: destinations that were live at ISSUE time — only
        # these contributed to ``need``, so only these may abandon it.
        self.counted = counted
        self.replies: dict[str, Any] = {}
        self.resumed = False
        # retry machinery (ISSUE 10): set by _run_rpc only when a RetryPolicy
        # is active and the round is quorum-mode. ``attempt == 0`` means no
        # timer was armed (feature off / alive mode) — deadline callbacks
        # check the attempt generation, so stale timers are no-ops.
        self.rpc = None
        self.attempt = 0
        self.hedged = False

    def _resume(self, payload) -> None:
        self.resumed = True
        self.net._waiting.pop(id(self), None)
        self.net._step(self.gen, self.fut, payload, self.on_done)

    def deliver(self, sid: str, reply: Any) -> None:
        net = self.net
        ctrl = net.controller
        if ctrl is not None and ctrl.consume_drop():
            # the controller chose to lose this reply in flight: the op never
            # sees it (alive-mode needs shrink so the op cannot hang).
            ctrl.reply_dropped(sid, reply)
            if not self.resumed:
                self.abandon(sid)
            return
        if self.resumed:
            return  # late reply past the quorum: ignored
        rt = net.race_tracker
        if rt is not None:
            rt.on_reply(sid, self)
        # keyed by server id: a retransmission's duplicate reply OVERWRITES
        # the original instead of double-counting toward the quorum (ISSUE 10
        # duplicate suppression — ``len(replies)`` counts distinct servers).
        self.replies[sid] = reply
        if len(self.replies) >= self.need:
            self._resume(dict(self.replies))

    def abandon(self, sid: str) -> None:
        """A destination counted into an ``"alive"`` need can no longer
        reply; shrink the requirement so the op cannot hang (ISSUE 7)."""
        if self.resumed or not self.alive or sid not in self.counted:
            return
        self.need -= 1
        if len(self.replies) >= self.need:
            self._resume(dict(self.replies))

    def resume_empty(self) -> None:
        if not self.resumed:
            self._resume({})


class _FanOut:
    """One fan-out's pre-computed arrival schedule, processed by a single
    cursor event instead of one heap entry per destination (ISSUE 7).

    ``seq0 .. seq0+nd-1`` were reserved at send time, one per delivered
    arrival *in destination order* — exactly the sequence numbers the legacy
    path's per-destination ``schedule`` calls would have consumed — so heap
    tie-breaking (and therefore the whole trace) is identical. After
    processing an arrival the cursor inline-drains the next one while it
    still precedes every other pending event, advancing virtual time
    directly; otherwise it re-enters the heap at the next arrival's reserved
    (time, seq) slot."""

    __slots__ = (
        "net", "state", "sids", "srvs", "msgs", "shared_msg", "didx",
        "rprops", "rdrop", "dups", "arr", "order", "seq0", "pos", "nd",
    )

    def __init__(self, net, state, sids, srvs, msgs, shared_msg, didx,
                 rprops, rdrop, dups, arr, order, seq0):
        self.net = net
        self.state = state
        self.sids = sids
        self.srvs = srvs
        self.msgs = msgs            # per-dest payloads, or None when shared
        self.shared_msg = shared_msg
        self.didx = didx            # interned dest endpoint ids
        self.rprops = rprops        # reply propagation draws (pooled)
        self.rdrop = rdrop          # reply drop flags, or None when p == 0
        self.dups = dups            # duplicate-delivery flags, or None
        self.arr = arr              # arrival times, destination order
        self.order = order          # arrival processing order (stable sort)
        self.seq0 = seq0
        self.pos = 0
        self.nd = len(order)

    def fire(self) -> None:
        net = self.net
        if net.controller is not None:
            # Controlled mode: one arrival per heap event — no inline drain,
            # so every delivery is its own decision point. The cursor still
            # walks arrivals in arrival-time order under the reserved seqs;
            # since a fan-out's arrivals target DISTINCT servers, any
            # interleaving of them is Mazurkiewicz-equivalent to a
            # cursor-respecting one, so no schedules are lost to this.
            pos = self.pos
            j = self.order[pos]
            self.pos = pos + 1
            self._process(j)
            if self.pos < self.nd:
                nj = self.order[self.pos]
                heapq.heappush(
                    net._events, (self.arr[nj], self.seq0 + nj, self.fire)
                )
            return
        arr = self.arr
        order = self.order
        seq0 = self.seq0
        nd = self.nd
        events = net._events
        pos = self.pos
        while True:
            j = order[pos]
            pos += 1
            self.pos = pos
            self._process(j)
            if pos >= nd:
                return
            nj = order[pos]
            t = arr[nj]
            s = seq0 + nj
            if t > net._run_limit:
                heapq.heappush(events, (t, s, self.fire))
                return
            if events:
                top = events[0]
                if top[0] < t or (top[0] == t and top[1] < s):
                    heapq.heappush(events, (t, s, self.fire))
                    return
            net.now = t
            net.events_processed += 1

    def _process(self, j: int) -> None:
        net = self.net
        state = self.state
        srv = self.srvs[j]
        sid = self.sids[j]
        ctrl = net.controller
        if ctrl is not None and ctrl.consume_drop():
            # schedulable message loss: the arrival never reaches handle()
            state.abandon(sid)
            return
        if srv.crashed:
            state.abandon(sid)
            return
        msg = self.shared_msg if self.msgs is None else self.msgs[j]
        rt = net.race_tracker
        if rt is not None:
            rt.before_handle(sid, state)
        if net.profile_protocol:
            t0 = perf_counter()
            reply = srv.handle(state.fut.client, msg)
            net.protocol_time += perf_counter() - t0
        else:
            reply = srv.handle(state.fut.client, msg)
        if rt is not None:
            rt.after_handle(sid)
        if self.dups is not None and self.dups[j]:
            # at-least-once delivery (dup_prob): the SAME request frame
            # arrives twice, so the handler runs again on it; the duplicate's
            # reply is discarded client-side (its request bytes were charged
            # at send time). Idempotent handlers make this a no-op; buggy
            # ones corrupt state right here — visible to the race tracker.
            if rt is not None:
                rt.before_handle(sid, state)
            srv.handle(state.fut.client, msg)
            if rt is not None:
                rt.after_handle(sid)
        if reply is None:
            state.abandon(sid)
            return
        if net.sanitizer is not None:
            net.sanitizer.on_reply(sid, msg, reply)
        rsize = net._wire(reply)
        net.msg_count += 1
        net.bytes_sent += rsize
        net._acct_add(state.acct, 0, 1, rsize)
        client = state.fut.client
        deliver = self.rdrop is None or not self.rdrop[j]
        if deliver and net._partitions and net._blocked(sid, client):
            deliver = False  # reply direction of an asymmetric partition
        rdelay = net.latency.server_compute + net._transmit_prop(
            self.didx[j], state.src_i, rsize, self.rprops[j], deliver
        )
        if not deliver:
            state.abandon(sid)
            return
        gray = net._gray
        if gray:
            rdelay += gray.get(sid, 0.0) + gray.get(client, 0.0)
        net.schedule(
            rdelay, partial(state.deliver, sid, reply),
            ("rpl", None, client),
        )


class Network:
    def __init__(self, seed: int = 0, latency: LatencyModel | None = None,
                 fast: bool = True):
        self.rng = np.random.default_rng(seed)
        # Drop decisions draw from their OWN stream so that drop_prob == 0
        # consumes nothing and toggling drops never perturbs a latency sample
        # (ISSUE 7 — the old path burned one rng.random() per message even
        # with drops disabled).
        self._drop_rng = np.random.default_rng([int(seed), 0x5EED])
        # ISSUE 10 streams, same discipline as _drop_rng: constructed eagerly
        # (construction draws nothing) but consumed ONLY when the feature is
        # on, so the disabled ablation stays bit-identical. _retry_rng feeds
        # backoff jitter; _dup_rng feeds dup_prob duplicate-delivery flags.
        self._retry_rng = np.random.default_rng([int(seed), 0x7E7])
        self._dup_rng = np.random.default_rng([int(seed), 0xD0B])
        # active retry policy; DSS.__init__ copies DSSParams.retry here.
        # None (default) = timers/retransmits/hedges fully disabled.
        self.retry: RetryPolicy | None = None
        # asymmetric link partitions: set of (src, dst) directed pairs, "*"
        # wildcard on either side. Outbound messages are silently lost at
        # send time, replies at handle time — both at the same virtual
        # timestamps on either engine. Empty set = zero-cost checks.
        self._partitions: set = set()
        # gray failures: endpoint -> extra one-way propagation latency (s),
        # added deterministically (no RNG) to every message the endpoint
        # sends or receives while set.
        self._gray: dict[str, float] = {}
        # in-flight quorum bookkeeping for stuck_ops() diagnostics: every
        # un-resumed _RpcState, keyed by id. Pure bookkeeping — no events.
        self._waiting: dict = {}
        self.retransmits = 0
        self.hedges = 0
        self.rpc_timeouts = 0
        # protocol-phase re-issues (coares retry wrapper bumps this); the
        # workload harness gates Wing–Gong strict reads-from on it staying 0.
        self.op_retries = 0
        self.latency = latency or LatencyModel()
        # fast=True (default): vectorised one-event-per-fan-out engine.
        # fast=False: the seed implementation's per-destination closures —
        # the ablation baseline (DSSParams.fast_net). Both replay identical
        # traces for a fixed seed.
        self.fast_rpc = fast
        # store-wide GF(256) coding backend, read ambiently by every RSCode
        # consumer built against this network (EcDap, repair, recon
        # transfers). DSS.__init__ overrides it from DSSParams.coding_backend.
        self.coding_backend = "auto"
        self.now = 0.0
        self._events: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = 0
        self._run_limit = math.inf
        self.servers: dict[str, Server] = {}
        self.futures: list[OpFuture] = []
        self._op_ids = itertools.count()
        self.msg_count = 0
        self.bytes_sent = 0
        # driver-side work: every event the engine executed (heap pops plus
        # the fast path's inline-drained arrivals — identical totals on both
        # paths, so events/s is an honest cross-path throughput metric).
        self.events_processed = 0
        # quorum rounds: one per RPC effect issued (a fan-out + wait-for-need
        # counts once, however many servers it touches) — the unit the paper's
        # §VII-D read-overhead argument is about.
        self.rpc_rounds = 0
        # per-client [rounds, msgs, bytes] accounting lives in flat rows
        # indexed by interned endpoint id; both directions of an op's RPCs
        # are attributed to the issuing client, so the Session API can report
        # per-operation OpStats under concurrent multi-client workloads.
        # Plain int lists, not an ndarray: the hot path bumps one row's
        # scalars per message, where numpy element access costs ~1µs a touch.
        # ``client_counters`` exposes the legacy dict-of-list view.
        self._ep_idx: dict[str, int] = {}
        self._acct: list[list[int]] = [[0, 0, 0] for _ in range(64)]
        # NIC busy-until times, indexed by interned endpoint id. Plain lists,
        # not ndarrays: the hot path reads/writes them one scalar at a time.
        self._busy_out: list[float] = [0.0] * 64
        self._busy_in: list[float] = [0.0] * 64
        self._known_clients: dict[str, None] = {}  # insertion-ordered set
        # per-client resolved accounting target, invalidated by attribute():
        # ("s", row_index) for a lone client, ("m", index_array) with riders.
        self._rows_cache: dict[str, tuple[str, Any]] = {}
        # attribution map (ISSUE 4): endpoint -> rider clients. While set,
        # every RPC the endpoint issues ALSO advances each rider's counters —
        # how a gateway's merged round is attributed to the clients it serves
        # (each rider sees the shared round once, same semantics as OpStats
        # sharing under a coalesced Session batch).
        self.client_attribution: dict[str, tuple[str, ...]] = {}
        self._sizer = SizingMemo()
        # fan-out destination cache: cfg.servers tuples are reused across
        # thousands of rounds, so the existence filter + endpoint interning
        # is resolved once per distinct tuple (identity-keyed, tuple pinned;
        # invalidated when topology grows). Lists are never cached.
        self._dest_cache: dict[int, tuple] = {}
        # opt-in wall-clock split for benchmarks: with ``profile_protocol``
        # set, seconds spent inside protocol code — op-generator bodies and
        # ``Server.handle`` — accumulate here, so a driver can report
        # *driver* time (wall minus protocol) for the engine comparison
        # ISSUE 7 is about. Off by default: two perf_counter() calls per
        # event are noise the normal path shouldn't pay.
        self.profile_protocol = False
        self.protocol_time = 0.0
        # optional runtime invariant observer (repro.analysis.sanitizer),
        # attached via ProtocolSanitizer.attach() behind DSSParams.sanitize /
        # REPRO_SANITIZE=1. Pure observer: it draws no randomness and
        # schedules nothing, so sanitized traces stay bit-identical. Cost
        # when unset is one ``is not None`` per fan-out/reply.
        self.sanitizer = None
        # optional schedule controller (repro.analysis.explore) — see the
        # "Schedule control" section of the module docstring. While set, the
        # event loop's pop policy (and optional message loss) is the
        # controller's decision; unset, behavior is bit-identical to before.
        self.controller = None
        # optional happens-before race tracker (repro.analysis.races): a pure
        # observer fed at RPC issue / arrival handle / counted reply delivery
        # plus the tracked-map mutation hooks in core/server.py.
        self.race_tracker = None

    # -- topology ------------------------------------------------------------
    def add_server(self, server: Server) -> None:
        self.servers[server.sid] = server
        self._dest_cache.clear()  # cached fan-outs may now resolve more dests
        if self.sanitizer is not None and hasattr(server, "_mut_observer"):
            server._mut_observer = self.sanitizer.forget
        if self.race_tracker is not None and hasattr(server, "_race_observer"):
            server._race_observer = self.race_tracker.on_mutation

    def crash(self, sid: str) -> None:
        self.servers[sid].crashed = True

    def recover(self, sid: str, wipe: bool = True) -> None:
        """Bring a crashed server back. ``wipe=True`` (crash-recovery, ISSUE
        10) invokes :meth:`Server.on_recover` so volatile state — reply /
        identity caches, handler scratch — does not survive the crash;
        ``wipe=False`` is the legacy flag-flip (server resumes with whatever
        it had, caches included)."""
        srv = self.servers[sid]
        srv.crashed = False
        if wipe:
            srv.on_recover()

    def alive(self) -> list[str]:
        return [s for s, srv in self.servers.items() if not srv.crashed]

    # -- fault surface (ISSUE 10) ---------------------------------------------
    def partition(self, src: str, dst: str, *, bidir: bool = False) -> None:
        """Block messages src -> dst (asymmetric by default). ``"*"`` on
        either side is a wildcard. Partitioned messages are lost silently —
        no drop-RNG draws, so traces without partitions are unperturbed."""
        self._partitions.add((src, dst))
        if bidir:
            self._partitions.add((dst, src))

    def heal(self, src: str | None = None, dst: str | None = None,
             *, bidir: bool = False) -> None:
        """Remove one directed partition (or, with no arguments, all)."""
        if src is None and dst is None:
            self._partitions.clear()
            return
        self._partitions.discard((src, dst))
        if bidir:
            self._partitions.discard((dst, src))

    def _blocked(self, src: str, dst: str) -> bool:
        p = self._partitions
        return (src, dst) in p or (src, "*") in p or ("*", dst) in p

    def slow(self, endpoint: str, extra: float) -> None:
        """Gray failure: add ``extra`` seconds of one-way latency to every
        message ``endpoint`` sends or receives, until :meth:`unslow`."""
        self._gray[endpoint] = float(extra)

    def unslow(self, endpoint: str) -> None:
        self._gray.pop(endpoint, None)

    def stuck_ops(self) -> list[dict]:
        """Diagnostics for the forever-pending-future leak (ISSUE 10
        satellite): every quorum/alive round still waiting for replies.
        Non-empty after the event queue drains means an op is stranded."""
        out = []
        for state in self._waiting.values():
            if state.resumed:
                continue
            fut = state.fut
            out.append({
                "op_id": fut.op_id,
                "kind": fut.kind,
                "client": fut.client,
                "need": state.need,
                "have": sorted(state.replies),
                "alive_mode": state.alive,
            })
        return out

    # -- event loop ------------------------------------------------------------
    def schedule(
        self, delay: float, fn: Callable[[], None], key: tuple | None = None
    ) -> None:
        # clamp: a negative (or NaN) delay must not reorder virtual time —
        # events fire no earlier than now (ISSUE 7).
        t = self.now + delay if delay > 0.0 else self.now
        s = self._seq
        self._seq = s + 1
        ctrl = self.controller
        if ctrl is not None:
            # ``key`` labels what the event touches — ("srv", sid, client)
            # for arrivals, ("rpl", None, client) for reply deliveries,
            # ("cli", None, client) for op resumes/timers, ("snd", None,
            # client) for RNG-drawing fan-out sends, None = conservative
            # "conflicts with everything".
            ctrl.note(s, key)
        heapq.heappush(self._events, (t, s, fn))

    def run(self, until: float | None = None, max_events: int = 50_000_000) -> None:
        limit = math.inf if until is None else until
        prev = self._run_limit
        self._run_limit = limit
        events = self._events
        n = 0
        ctrl = self.controller
        try:
            if ctrl is not None:
                # controlled mode: the controller picks which pending event
                # fires (and may drop it); the ``until`` window is judged on
                # the earliest pending time, same as the uncontrolled loop.
                while events and n < max_events:
                    if events[0][0] > limit:
                        break
                    if not ctrl.step(self):
                        break
                    n += 1
            else:
                while events and n < max_events:
                    t, _, fn = events[0]
                    if t > limit:
                        break
                    heapq.heappop(events)
                    self.now = t
                    self.events_processed += 1
                    fn()
                    n += 1
        finally:
            self._run_limit = prev
        if n >= max_events:  # pragma: no cover
            raise RuntimeError("simulator event budget exhausted (livelock?)")

    def step(self) -> bool:
        """Pop and run ONE event; False when the queue is empty. Lets callers
        (``api.OpFuture.result``) drive the loop until a condition holds
        without running unrelated traffic — e.g. a repair daemon — to
        quiescence."""
        ctrl = self.controller
        if ctrl is not None:
            return bool(self._events) and ctrl.step(self)
        if not self._events:
            return False
        t, _, fn = heapq.heappop(self._events)
        self.now = t
        self.events_processed += 1
        fn()
        return True

    # -- accounting ------------------------------------------------------------
    def _intern(self, endpoint: str) -> int:
        """Stable small-int id for an endpoint name; grows the flat
        accounting/busy arrays on demand (indices stay valid across growth)."""
        idx = self._ep_idx.get(endpoint)
        if idx is None:
            idx = len(self._ep_idx)
            self._ep_idx[endpoint] = idx
            if idx >= len(self._acct):
                self._acct.extend([0, 0, 0] for _ in range(len(self._acct)))
                self._busy_out.extend([0.0] * len(self._busy_out))
                self._busy_in.extend([0.0] * len(self._busy_in))
        return idx

    def _acct_rows(self, client: str) -> tuple[str, Any]:
        """Resolved accounting target for RPCs issued by ``client``: its own
        row plus any attributed riders' rows (captured at issue time, like
        the legacy path's ``setdefault`` list — late replies keep crediting
        the riders of the round that sent them)."""
        entry = self._rows_cache.get(client)
        if entry is None:
            i = self._intern(client)
            self._known_clients[client] = None
            riders = self.client_attribution.get(client)
            if riders:
                for r in riders:
                    self._known_clients[r] = None
                entry = ("m", (i, *(self._intern(r) for r in riders)))
            else:
                entry = ("s", i)
            self._rows_cache[client] = entry
        return entry

    def _acct_add(self, rows: tuple[str, Any], dr: int, dm: int, db: int) -> None:
        kind, v = rows
        a = self._acct
        if kind == "s":
            row = a[v]
            if dr:
                row[0] += dr
            if dm:
                row[1] += dm
                row[2] += db
        else:
            for i in v:
                row = a[i]
                if dr:
                    row[0] += dr
                if dm:
                    row[1] += dm
                    row[2] += db

    @property
    def client_counters(self) -> dict[str, list[int]]:
        """Read-only snapshot of per-client [rounds, msgs, bytes] — the
        legacy dict view over the flat accounting array. Mutations to the
        returned dict are NOT written back; use ``client_totals``."""
        a = self._acct
        out = {}
        for c in self._known_clients:
            out[c] = list(a[self._ep_idx[c]])
        return out

    def client_totals(self, client: str) -> tuple[int, int, int]:
        """(quorum rounds, messages, bytes) attributed to ``client`` so far."""
        i = self._ep_idx.get(client)
        if i is None:
            return (0, 0, 0)
        row = self._acct[i]
        return (row[0], row[1], row[2])

    def attribute(self, endpoint: str, riders=None) -> None:
        """Set (or clear, with ``riders=None``/empty) the attribution map for
        ``endpoint``: while set, counters of every listed rider advance with
        the endpoint's own on each RPC it issues. The gateway tier brackets
        each merged round with this so per-client OpStats stay meaningful."""
        riders = tuple(dict.fromkeys(r for r in (riders or ()) if r != endpoint))
        if riders:
            self.client_attribution[endpoint] = riders
        else:
            self.client_attribution.pop(endpoint, None)
        self._rows_cache.pop(endpoint, None)

    # -- message timing --------------------------------------------------------
    def transmit_delay(self, src: str, dst: str, size: int, deliver: bool = True) -> float:
        """Delay until a message sent NOW from ``src`` is delivered at ``dst``.

        Cut-through at the sender, store-and-forward bookkeeping at both
        NICs: the message occupies ``src``'s uplink and ``dst``'s downlink
        for size/bandwidth each, queuing behind earlier traffic on the same
        endpoint (``serialize_links``). On idle links this reduces exactly to
        the classic ``base + size/bandwidth``. ``deliver=False`` models a
        message lost in flight: the sender's uplink was still consumed, but
        nothing queues at (or arrives to) the receiver."""
        lat = self.latency
        prop = float(self.rng.uniform(lat.base_lo, lat.base_hi))
        return self._transmit_prop(
            self._intern(src), self._intern(dst), size, prop, deliver
        )

    def _transmit_prop(
        self, src_i: int, dst_i: int, size: int, prop: float, deliver: bool
    ) -> float:
        """``transmit_delay`` over interned endpoint ids with the propagation
        draw supplied by the caller (the fan-out paths pool their draws)."""
        lat = self.latency
        tx = size / lat.bandwidth
        if not lat.serialize_links:
            return prop + tx
        bo = self._busy_out
        now = self.now
        b = bo[src_i]
        t_send = now if now > b else b
        bo[src_i] = t_send + tx
        if not deliver:
            return 0.0
        bi = self._busy_in
        t0 = t_send + prop
        b2 = bi[dst_i]
        t_recv = t0 if t0 > b2 else b2
        bi[dst_i] = t_recv + tx
        return (t_recv + tx) - now

    def _wire(self, obj: Any) -> int:
        """Memoized ``msg_wire_size`` (fast path): codec frame size with
        immutable subtrees cached, ``nbytes`` heuristic outside the
        vocabulary."""
        try:
            return self._sizer.wire_size(obj)
        except CodecError:
            return nbytes(obj)

    # -- op driving ------------------------------------------------------------
    def spawn(
        self,
        gen: Generator,
        kind: str = "",
        client: str = "",
        delay: float = 0.0,
        on_done: Callable[[OpFuture], None] | None = None,
    ) -> OpFuture:
        fut = OpFuture(op_id=next(self._op_ids), kind=kind, client=client)
        self.futures.append(fut)

        def start() -> None:
            fut.start = self.now
            self._step(gen, fut, None, on_done)

        self.schedule(delay, start, ("cli", None, client))
        return fut

    def run_op(self, gen: Generator, **kw) -> Any:
        """Convenience: spawn one op, run to quiescence, return its result."""
        fut = self.spawn(gen, **kw)
        self.run()
        if not fut.done:
            raise RuntimeError(f"operation {fut.kind or fut.op_id} did not terminate")
        return fut.result

    # -- internals ------------------------------------------------------------
    def _step(
        self,
        gen: Generator,
        fut: OpFuture,
        send_value: Any,
        on_done: Callable[[OpFuture], None] | None,
        exc: BaseException | None = None,
    ) -> None:
        prof = self.profile_protocol
        if prof:
            t0 = perf_counter()
        try:
            # ``exc`` (ISSUE 10): a typed failure — RpcTimeout from the
            # deadline machinery — is THROWN into the generator at its
            # pending ``yield RPC``. Protocol phase wrappers catch it and
            # yield again (backoff Sleep, then a fresh attempt); the Session
            # tier's _instrumented wrapper catches whatever escapes and fails
            # the OpFuture typed instead of letting it crash the event loop.
            effect = gen.throw(exc) if exc is not None else gen.send(send_value)
        except StopIteration as stop:
            if prof:
                self.protocol_time += perf_counter() - t0
            fut.done = True
            fut.end = self.now
            fut.result = stop.value
            if on_done is not None:
                on_done(fut)
            return
        if prof:
            self.protocol_time += perf_counter() - t0
        if isinstance(effect, Sleep):
            self.schedule(
                effect.duration,
                lambda: self._step(gen, fut, None, on_done),
                ("cli", None, fut.client),
            )
        elif isinstance(effect, RPC):
            self._run_rpc(effect, gen, fut, on_done)
        elif isinstance(effect, Join):
            n = len(effect.children)
            if n == 0:
                self.schedule(
                    0.0,
                    lambda: self._step(gen, fut, [], on_done),
                    ("cli", None, fut.client),
                )
                return
            results = [None] * n
            state = {"left": n}

            def make_done(i):
                def done(child_fut):
                    results[i] = child_fut.result
                    state["left"] -= 1
                    if state["left"] == 0:
                        self._step(gen, fut, results, on_done)
                return done

            for i, child in enumerate(effect.children):
                self.spawn(child, client=fut.client, on_done=make_done(i))
        else:  # pragma: no cover
            raise TypeError(f"unknown effect {effect!r}")

    def _run_rpc(
        self,
        rpc: RPC,
        gen: Generator,
        fut: OpFuture,
        on_done: Callable[[OpFuture], None] | None,
    ) -> None:
        self.rpc_rounds += 1
        # the issuing client's account, plus any riders attributed to it
        # (``attribute``): a gateway's merged round counts once per rider.
        acct = self._acct_rows(fut.client)
        self._acct_add(acct, 1, 0, 0)
        if rpc.need == "alive":
            alive_mode = True
            need = sum(
                1
                for sid in rpc.dests
                if (srv := self.servers.get(sid)) is not None and not srv.crashed
            )
            counted = frozenset(
                sid
                for sid in rpc.dests
                if (srv := self.servers.get(sid)) is not None and not srv.crashed
            )
        else:
            alive_mode = False
            need = rpc.need
            counted = frozenset()
        need = min(need, len(rpc.dests))
        san = self.sanitizer
        if san is not None:
            san.on_rpc(rpc, None if alive_mode else need)
        state = _RpcState(
            self, gen, fut, on_done, acct, self._intern(fut.client),
            need, alive_mode, counted,
        )
        rt = self.race_tracker
        if rt is not None:
            rt.on_issue(state, rpc)
        # stuck-op bookkeeping (ISSUE 10): every round registers here and
        # deregisters on resume; whatever remains after the queue drains is a
        # stranded op — see stuck_ops(). Dict insert/pop only, no events.
        self._waiting[id(state)] = state
        send = self._fast_send if self.fast_rpc else self._legacy_send
        # "snd" events draw pooled RNG and touch shared NIC state: the
        # controller treats them as conflicting with everything.
        self.schedule(rpc.pre_delay, partial(send, rpc, state),
                      ("snd", None, fut.client))
        if need <= 0:
            # nothing can (or needs to) reply — messages still go out, but the
            # op resumes immediately with no replies (guarded against a
            # straggler reply re-resuming the generator).
            self.schedule(rpc.pre_delay, state.resume_empty,
                          ("cli", None, fut.client))
            return
        policy = self.retry
        if policy is not None and not alive_mode:
            # arm the per-attempt deadline chain. Quorum mode only: alive
            # mode structurally cannot hang (crashes/drops shrink ``need``),
            # and its rounds are fire-and-mostly-forget daemon traffic.
            state.rpc = rpc
            state.attempt = 1
            self._arm_timer(state, policy, rpc.pre_delay)

    # -- retry / deadline machinery (ISSUE 10) --------------------------------
    def _arm_timer(self, state: _RpcState, policy: RetryPolicy,
                   extra: float) -> None:
        att = state.attempt
        timeout = policy.rpc_timeout * (policy.backoff ** (att - 1))
        if policy.jitter > 0.0:
            # seeded jitter from the dedicated stream: deterministic, and
            # drawn only when a policy is armed (ablation draws nothing).
            timeout *= 1.0 + policy.jitter * float(self._retry_rng.random())
        self.schedule(extra + timeout, partial(self._rpc_deadline, state, att),
                      ("cli", None, state.fut.client))
        if att == 1 and policy.hedge_after is not None:
            self.schedule(extra + policy.hedge_after,
                          partial(self._rpc_hedge, state),
                          ("cli", None, state.fut.client))

    def _rpc_deadline(self, state: _RpcState, att: int) -> None:
        # stale-timer guard: the round resumed, or a retransmission already
        # superseded this attempt generation — this timer is a no-op.
        if state.resumed or state.attempt != att:
            return
        policy = self.retry
        if policy is None or att >= policy.max_attempts:
            self.rpc_timeouts += 1
            self._waiting.pop(id(state), None)
            state.resumed = True
            fut = state.fut
            missing = [s for s in state.rpc.dests if s not in state.replies]
            err = RpcTimeout(
                f"{fut.kind or 'op'}({fut.client}): {len(state.replies)}/"
                f"{state.need} replies after {att} attempt(s); "
                f"no reply from {missing}"
            )
            self._step(state.gen, fut, None, state.on_done, exc=err)
            return
        state.attempt = att + 1
        self.retransmits += 1
        self._resend(state)
        self._arm_timer(state, policy, 0.0)

    def _rpc_hedge(self, state: _RpcState) -> None:
        # hedged duplicate send: still in attempt 1, not yet resumed, fire
        # once — re-send to the laggards without burning a retry attempt.
        if state.resumed or state.attempt != 1 or state.hedged:
            return
        state.hedged = True
        self.hedges += 1
        self._resend(state)

    def _resend(self, state: _RpcState) -> None:
        """Idempotent retransmission: re-send the ORIGINAL payload to the
        destinations that have not replied. Replies are keyed by server id
        client-side and handlers are guarded server-side, so a duplicate
        cannot double-count a quorum or regress protocol state."""
        rpc = state.rpc
        missing = tuple(s for s in rpc.dests if s not in state.replies)
        if not missing:
            return
        per = None if rpc.per_dest is None else {
            s: rpc.per_dest[s] for s in missing
        }
        dup = RPC(dests=missing, msg=rpc.msg, need=state.need, per_dest=per)
        send = self._fast_send if self.fast_rpc else self._legacy_send
        # same _RpcState: no new sanitizer round, no rpc_rounds bump — this
        # is wire-level amplification of the SAME protocol round (it shows
        # up in msg_count/bytes_sent and the retransmits counter).
        self.schedule(0.0, partial(send, dup, state),
                      ("snd", None, state.fut.client))

    # Both send paths share one canonical RNG schedule per fan-out over the B
    # destinations that exist: 2B latency props from ``rng`` (outbound then
    # reply, destination order), then — only when drop_prob > 0 — 2B drop
    # draws from ``_drop_rng`` in the same layout. The fast path draws them
    # as two vectors; the legacy path draws the SAME values as 2B scalars
    # (numpy Generator streams are bit-identical either way), so the two
    # engines replay identical traces while paying very different driver
    # costs.

    def _fast_send(self, rpc: RPC, state: _RpcState) -> None:
        lat = self.latency
        dests = rpc.dests
        cache = self._dest_cache
        ent = cache.get(id(dests))
        if ent is not None and ent[0] is dests:
            sids, srvs, didx = ent[1], ent[2], ent[3]
        else:
            servers = self.servers
            sids = []
            srvs = []
            for sid in dests:
                srv = servers.get(sid)
                if srv is not None:
                    sids.append(sid)
                    srvs.append(srv)
            didx = [self._intern(s) for s in sids]
            if type(dests) is tuple:  # lists may mutate: never cache them
                if len(cache) >= 4096:
                    cache.clear()
                cache[id(dests)] = (dests, sids, srvs, didx)
        B = len(sids)
        if B == 0:
            return
        # frame sizes (broadcasts sized once) + bulk accounting
        if rpc.per_dest is None:
            msgs = None
            sizes = None
            shared = self._wire(rpc.msg)
            total = shared * B
        else:
            msgs = [rpc.per_dest[sid] for sid in sids]
            sizes = [self._wire(m) for m in msgs]
            shared = 0
            total = sum(sizes)
        self.msg_count += B
        self.bytes_sent += total
        self._acct_add(state.acct, 0, B, total)
        # pooled draws (canonical stream, see above); everything downstream is
        # scalar arithmetic — at quorum-sized fan-outs (B ~ 5-15) a Python
        # loop over the pooled values beats vector ops, and it replays the
        # legacy path's per-message float sequence *by construction*.
        props = self.rng.uniform(lat.base_lo, lat.base_hi, 2 * B).tolist()
        p = lat.drop_prob
        flags = (self._drop_rng.random(2 * B) < p).tolist() if p > 0.0 else None
        dp = lat.dup_prob
        dups = (self._dup_rng.random(B) < dp).tolist() if dp > 0.0 else None
        client_ep = state.fut.client
        # gray failures (deterministic, no draws): pad the outbound
        # propagation samples; the reply direction pads rdelay in _process.
        gray = self._gray
        if gray:
            gc = gray.get(client_ep, 0.0)
            for j in range(B):
                g = gc + gray.get(sids[j], 0.0)
                if g:
                    props[j] += g
        # outbound loss = drop-RNG flag OR asymmetric partition block. The
        # merged ``lost`` view drives filtering; ``flags`` keeps feeding the
        # reply-drop half so the canonical draw layout never changes.
        if self._partitions:
            blk = [self._blocked(client_ep, s) for s in sids]
            if True not in blk:
                blk = None
        else:
            blk = None
        if blk is None:
            lost = flags  # 2B when drops on (first half read), else None
        elif flags is None:
            lost = blk
        else:
            lost = [flags[j] or blk[j] for j in range(B)]
        now = self.now
        bw = lat.bandwidth
        serialize = lat.serialize_links
        bi = self._busy_in
        if serialize:
            # sender uplink: each message queues behind the previous one;
            # ``busy`` never falls below ``now`` after the first max, so
            # hoisting the max out of the loop is exact.
            bo = self._busy_out
            src_i = state.src_i
            busy = bo[src_i]
            if now > busy:
                busy = now
        arr: list[float] = []
        if lost is None:
            # no losses (the common case): every message is delivered, so the
            # destination views ARE the originals — only arrivals to compute
            for j in range(B):
                tx = (shared if sizes is None else sizes[j]) / bw
                if serialize:
                    t_send = busy
                    busy = t_send + tx
                    t0 = t_send + props[j]
                    di = didx[j]
                    b2 = bi[di]
                    t_recv = t0 if t0 > b2 else b2
                    done = t_recv + tx
                    bi[di] = done
                    delay = done - now
                else:
                    delay = props[j] + tx
                arr.append(now + delay if delay > 0.0 else now)
            d_sids, d_srvs, d_msgs, d_didx = sids, srvs, msgs, didx
            d_rprops = props[B:]
            d_rdrop = None
            d_dups = dups
        else:
            # delivered arrivals (outbound losses still consume the uplink)
            d_sids = []
            d_srvs = []
            d_msgs = None if msgs is None else []
            d_didx = []
            d_rprops = []
            d_rdrop = None if flags is None else []
            d_dups = None if dups is None else []
            for j in range(B):
                tx = (shared if sizes is None else sizes[j]) / bw
                if serialize:
                    t_send = busy
                    busy = t_send + tx
                if lost[j]:
                    continue
                if serialize:
                    t0 = t_send + props[j]
                    di = didx[j]
                    b2 = bi[di]
                    t_recv = t0 if t0 > b2 else b2
                    done = t_recv + tx
                    bi[di] = done
                    delay = done - now
                else:
                    delay = props[j] + tx
                arr.append(now + delay if delay > 0.0 else now)
                d_sids.append(sids[j])
                d_srvs.append(srvs[j])
                if d_msgs is not None:
                    d_msgs.append(msgs[j])
                d_didx.append(didx[j])
                d_rprops.append(props[B + j])
                if d_rdrop is not None:
                    d_rdrop.append(flags[B + j])
                if d_dups is not None:
                    d_dups.append(dups[j])
        if serialize:
            bo[src_i] = busy
        # duplicated request frames (dup_prob): the extra copy of each
        # delivered, dup-flagged message is charged on the wire here; the
        # handler re-runs at arrival time and its reply is discarded.
        if dups is not None:
            ndup = 0
            dbytes = 0
            for j in range(B):
                if dups[j] and (lost is None or not lost[j]):
                    ndup += 1
                    dbytes += shared if sizes is None else sizes[j]
            if ndup:
                self.msg_count += ndup
                self.bytes_sent += dbytes
                self._acct_add(state.acct, 0, ndup, dbytes)
        nd = len(arr)
        if nd == 0:
            self._abandon_drops(state, sids, lost)
            return
        # reserve the arrival sequence numbers the legacy path would have
        # consumed (contiguous, destination order) and enter the heap at the
        # earliest arrival only.
        seq0 = self._seq
        self._seq = seq0 + nd
        ctrl = self.controller
        if ctrl is not None:
            client = state.fut.client
            for j in range(nd):
                ctrl.note(seq0 + j, ("srv", d_sids[j], client))
        order = [0] if nd == 1 else sorted(range(nd), key=arr.__getitem__)
        fan = _FanOut(
            self, state, d_sids, d_srvs, d_msgs,
            rpc.msg if msgs is None else None,
            d_didx, d_rprops, d_rdrop, d_dups, arr, order, seq0,
        )
        j0 = order[0]
        heapq.heappush(self._events, (arr[j0], seq0 + j0, fan.fire))
        self._abandon_drops(state, sids, lost)

    def _abandon_drops(self, state: _RpcState, sids: list[str], lost) -> None:
        """alive-mode bookkeeping for outbound losses — drops or partition
        blocks (after arrival seqs are reserved, so resume-triggered
        schedules order identically on both paths)."""
        if lost is None or not state.alive:
            return
        for j, sid in enumerate(sids):
            if lost[j]:
                state.abandon(sid)

    def _legacy_send(self, rpc: RPC, state: _RpcState) -> None:
        """Seed-style per-destination send: one closure + heap entry + scalar
        RNG draws + un-memoized codec walk per message. Kept as the ablation
        baseline (``fast=False`` / ``DSSParams.fast_net=False``); draws the
        same canonical per-fan-out stream as the fast path so traces are
        bit-identical — it just pays the seed implementation's per-message
        costs to earn them."""
        lat = self.latency
        pairs = [
            (sid, srv)
            for sid in rpc.dests
            if (srv := self.servers.get(sid)) is not None
        ]
        B = len(pairs)
        if B == 0:
            return
        lo, hi = lat.base_lo, lat.base_hi
        oprops = [float(self.rng.uniform(lo, hi)) for _ in range(B)]
        rprops = [float(self.rng.uniform(lo, hi)) for _ in range(B)]
        p = lat.drop_prob
        if p > 0.0:
            odrop = [bool(self._drop_rng.random() < p) for _ in range(B)]
            rdrop = [bool(self._drop_rng.random() < p) for _ in range(B)]
        else:
            odrop = rdrop = None
        dp = lat.dup_prob
        if dp > 0.0:
            dup = [bool(self._dup_rng.random() < dp) for _ in range(B)]
        else:
            dup = None
        shared = msg_wire_size(rpc.msg) if rpc.per_dest is None else None
        client = state.fut.client
        src_i = state.src_i
        gray = self._gray
        parted = bool(self._partitions)
        dropped_sids: list[str] = []
        for j, (sid, srv) in enumerate(pairs):
            msg = rpc.msg if rpc.per_dest is None else rpc.per_dest[sid]
            size = shared if shared is not None else msg_wire_size(msg)
            self.msg_count += 1
            self.bytes_sent += size
            self._acct_add(state.acct, 0, 1, size)
            lost = (odrop is not None and odrop[j]) or (
                parted and self._blocked(client, sid)
            )
            oprop = oprops[j]
            if gray:
                oprop += gray.get(client, 0.0) + gray.get(sid, 0.0)
            delay = self._transmit_prop(
                src_i, self._intern(sid), size, oprop, not lost
            )
            if lost:
                dropped_sids.append(sid)
                continue
            if dup is not None and dup[j]:
                self.msg_count += 1
                self.bytes_sent += size
                self._acct_add(state.acct, 0, 1, size)

            def arrive(
                srv=srv,
                sid=sid,
                msg=msg,
                rprop=rprops[j],
                rlost=rdrop is not None and rdrop[j],
                dupped=dup is not None and dup[j],
            ) -> None:
                ctrl = self.controller
                if ctrl is not None and ctrl.consume_drop():
                    state.abandon(sid)
                    return
                if srv.crashed:
                    state.abandon(sid)
                    return
                rt = self.race_tracker
                if rt is not None:
                    rt.before_handle(sid, state)
                if self.profile_protocol:
                    t0 = perf_counter()
                    reply = srv.handle(client, msg)
                    self.protocol_time += perf_counter() - t0
                else:
                    reply = srv.handle(client, msg)
                if rt is not None:
                    rt.after_handle(sid)
                if dupped:
                    # duplicate delivery — see _FanOut._process
                    if rt is not None:
                        rt.before_handle(sid, state)
                    srv.handle(client, msg)
                    if rt is not None:
                        rt.after_handle(sid)
                if reply is None:
                    state.abandon(sid)
                    return
                if self.sanitizer is not None:
                    self.sanitizer.on_reply(sid, msg, reply)
                rsize = msg_wire_size(reply)
                self.msg_count += 1
                self.bytes_sent += rsize
                self._acct_add(state.acct, 0, 1, rsize)
                rdeliver = not rlost and not (
                    self._partitions and self._blocked(sid, client)
                )
                rdelay = lat.server_compute + self._transmit_prop(
                    self._intern(sid), src_i, rsize, rprop, rdeliver
                )
                if not rdeliver:
                    state.abandon(sid)
                    return
                g = self._gray
                if g:
                    rdelay += g.get(sid, 0.0) + g.get(client, 0.0)
                self.schedule(rdelay, lambda: state.deliver(sid, reply),
                              ("rpl", None, client))

            self.schedule(delay, arrive, ("srv", sid, client))
        for sid in dropped_sids:
            state.abandon(sid)
