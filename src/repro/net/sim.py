"""Deterministic discrete-event asynchronous network simulator.

The paper evaluates on Emulab (emulated LAN) and AWS EC2 (real WAN). This
module provides the third option used throughout this repo: a **virtual-time
event simulator** with per-message latency = base ~ U[lo, hi] + size/bandwidth
(+ optional jitter/drops), crash/recover injection, and size-aware payload
accounting. Virtual time makes every benchmark deterministic and lets the
test-suite check linearizability/coverability against recorded histories —
something a live testbed cannot do.

Programming model
-----------------
*Servers* are objects with a synchronous ``handle(sender, msg) -> reply``.
*Client operations* are Python generators that ``yield`` effects:

    replies = yield RPC(dests=[...], msg=(...), need=q)   # quorum round-trip
    yield Sleep(0.01)                                     # backoff

``yield from`` composes sub-protocols (a CoARES write yields from read-config,
which yields from per-config RPCs, ...). ``Network.spawn`` turns a generator
into an ``OpFuture``; ``Network.run`` drives the event loop to quiescence.
Replies arriving after a quorum resumed the generator are delivered to the
runner and ignored — exactly the paper's "wait for a quorum, ignore the rest".
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Any, Callable, Generator

import numpy as np

from repro.net.codec import try_wire_size


def nbytes(obj: Any) -> int:
    """Approximate wire size of a message payload (drives latency model).

    This is the legacy per-Python-object heuristic, kept as the FALLBACK for
    payloads outside the wire codec's vocabulary — protocol messages are
    charged their real framed size via ``msg_wire_size`` (ISSUE 3)."""
    if obj is None:
        return 1
    if isinstance(obj, (bytes, bytearray, memoryview)):
        return len(obj)
    if isinstance(obj, str):
        return len(obj)
    if isinstance(obj, bool):  # before int: bool is an int subclass
        return 1
    if isinstance(obj, (int, float)):
        return 8
    if isinstance(obj, np.ndarray):
        # An ndarray nested inside an out-of-vocabulary container used to be
        # charged the legacy ``16 + nbytes`` guess; route it through the
        # codec's real ndarray framing instead (ISSUE 4) — the codec knows
        # the exact dtype/shape/payload frame, so containers that mix arrays
        # with un-frameable objects stop being over-charged per array.
        size = try_wire_size(obj)
        return 16 + int(obj.nbytes) if size is None else size
    if isinstance(obj, np.generic):
        return int(obj.nbytes)
    if isinstance(obj, (tuple, list, set, frozenset)):
        return 16 + sum(nbytes(x) for x in obj)
    if isinstance(obj, dict):
        return 16 + sum(nbytes(k) + nbytes(v) for k, v in obj.items())
    if hasattr(obj, "wire_size"):
        return int(obj.wire_size())
    return 64


def msg_wire_size(obj: Any) -> int:
    """Bytes charged for one message on the wire: the codec's length-prefixed
    frame size when the payload is wire-encodable (every protocol message
    is — see ``repro.net.codec``), else the ``nbytes`` heuristic."""
    size = try_wire_size(obj)
    return nbytes(obj) if size is None else size


@dataclass
class LatencyModel:
    """Virtual-time cost model (defaults roughly calibrated to a 1 GbE LAN —
    the paper's Emulab setup; see benchmarks for the AWS-ish WAN variant)."""

    base_lo: float = 0.2e-3          # per-message propagation floor (s)
    base_hi: float = 0.8e-3
    bandwidth: float = 125e6         # bytes/s (1 Gbit/s)
    drop_prob: float = 0.0
    server_compute: float = 20e-6    # per-message server handling (s)
    # client-side compute models (per byte, s):
    enc_per_byte: float = 0.6e-9     # RS encode  (§VI: encode faster ...)
    dec_per_byte: float = 1.2e-9     # RS decode  (... than decode)
    bi_per_byte: float = 1.0e-9      # FM block identification (rabin/gear+match)
    # Serialize transmissions per endpoint NIC (ISSUE 2): concurrent messages
    # share an endpoint's bandwidth instead of each enjoying the full line
    # rate. Without this, a B-way parallel fan-out of B·L bytes finishes as
    # fast as one L-byte message — physically impossible, and it hid exactly
    # the per-message overhead the paper's §VII-D read argument is about.
    serialize_links: bool = True

    def msg_delay(self, rng: np.random.Generator, size: int) -> float:
        return float(rng.uniform(self.base_lo, self.base_hi)) + size / self.bandwidth


@dataclass
class RPC:
    """Send ``msg`` to every server in ``dests``; resume the op generator once
    ``need`` distinct servers replied. The generator receives ``{sid: reply}``.

    ``need`` may be the string ``"alive"``: it resolves to the number of
    destinations whose server is live at issue time (resuming immediately
    with ``{}`` when none are). This is the server-addressed pull the repair
    subsystem uses — "everyone who can answer", without hanging on crashed
    servers. It assumes no crashes land between issue and reply (true for
    the crash-injection tests; lossy nets should stick to quorum counts).

    ``per_dest`` (optional) overrides ``msg`` per server — used by the EC
    put-data, which ships a *different coded fragment* to each server."""

    dests: tuple
    msg: Any
    need: int | str
    # extra client-side compute charged before sending (e.g. encode cost)
    pre_delay: float = 0.0
    per_dest: dict | None = None


@dataclass
class Sleep:
    duration: float


@dataclass
class Join:
    """Run child operation generators CONCURRENTLY; resume the parent with
    the list of their results (in order). Used by the indexed Fragmentation
    Module to issue block reads/writes in parallel (EXPERIMENTS.md §Perf,
    storage iteration)."""

    children: list


@dataclass
class OpFuture:
    op_id: int
    kind: str = ""
    client: str = ""
    start: float = 0.0
    end: float = 0.0
    done: bool = False
    result: Any = None

    @property
    def latency(self) -> float:
        return self.end - self.start


class Server:
    """Base class: subclasses implement ``handle``; crash state lives here."""

    def __init__(self, sid: str):
        self.sid = sid
        self.crashed = False

    def handle(self, sender: str, msg: Any) -> Any:  # pragma: no cover
        raise NotImplementedError


class Network:
    def __init__(self, seed: int = 0, latency: LatencyModel | None = None):
        self.rng = np.random.default_rng(seed)
        self.latency = latency or LatencyModel()
        # store-wide GF(256) coding backend, read ambiently by every RSCode
        # consumer built against this network (EcDap, repair, recon
        # transfers). DSS.__init__ overrides it from DSSParams.coding_backend.
        self.coding_backend = "auto"
        self.now = 0.0
        self._events: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.servers: dict[str, Server] = {}
        self.futures: list[OpFuture] = []
        self._op_ids = itertools.count()
        self.msg_count = 0
        self.bytes_sent = 0
        # quorum rounds: one per RPC effect issued (a fan-out + wait-for-need
        # counts once, however many servers it touches) — the unit the paper's
        # §VII-D read-overhead argument is about.
        self.rpc_rounds = 0
        # per-client [rounds, msgs, bytes] — both directions of an op's RPCs
        # are attributed to the issuing client, so the Session API can report
        # per-operation OpStats under concurrent multi-client workloads.
        self.client_counters: dict[str, list[int]] = {}
        # attribution map (ISSUE 4): endpoint -> rider clients. While set,
        # every RPC the endpoint issues ALSO advances each rider's counters —
        # how a gateway's merged round is attributed to the clients it serves
        # (each rider sees the shared round once, same semantics as OpStats
        # sharing under a coalesced Session batch).
        self.client_attribution: dict[str, tuple[str, ...]] = {}
        # per-endpoint NIC occupancy: (endpoint, "out"|"in") -> busy-until
        self._busy: dict[tuple[str, str], float] = {}

    # -- topology ------------------------------------------------------------
    def add_server(self, server: Server) -> None:
        self.servers[server.sid] = server

    def crash(self, sid: str) -> None:
        self.servers[sid].crashed = True

    def recover(self, sid: str) -> None:
        self.servers[sid].crashed = False

    def alive(self) -> list[str]:
        return [s for s, srv in self.servers.items() if not srv.crashed]

    # -- event loop ------------------------------------------------------------
    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._events, (self.now + delay, next(self._seq), fn))

    def run(self, until: float | None = None, max_events: int = 50_000_000) -> None:
        n = 0
        while self._events and n < max_events:
            t, _, fn = self._events[0]
            if until is not None and t > until:
                break
            heapq.heappop(self._events)
            self.now = t
            fn()
            n += 1
        if n >= max_events:  # pragma: no cover
            raise RuntimeError("simulator event budget exhausted (livelock?)")

    def step(self) -> bool:
        """Pop and run ONE event; False when the queue is empty. Lets callers
        (``api.OpFuture.result``) drive the loop until a condition holds
        without running unrelated traffic — e.g. a repair daemon — to
        quiescence."""
        if not self._events:
            return False
        t, _, fn = heapq.heappop(self._events)
        self.now = t
        fn()
        return True

    def client_totals(self, client: str) -> tuple[int, int, int]:
        """(quorum rounds, messages, bytes) attributed to ``client`` so far."""
        acct = self.client_counters.get(client)
        return (0, 0, 0) if acct is None else (acct[0], acct[1], acct[2])

    def attribute(self, endpoint: str, riders=None) -> None:
        """Set (or clear, with ``riders=None``/empty) the attribution map for
        ``endpoint``: while set, counters of every listed rider advance with
        the endpoint's own on each RPC it issues. The gateway tier brackets
        each merged round with this so per-client OpStats stay meaningful."""
        riders = tuple(dict.fromkeys(r for r in (riders or ()) if r != endpoint))
        if riders:
            self.client_attribution[endpoint] = riders
        else:
            self.client_attribution.pop(endpoint, None)

    # -- message timing --------------------------------------------------------
    def transmit_delay(self, src: str, dst: str, size: int, deliver: bool = True) -> float:
        """Delay until a message sent NOW from ``src`` is delivered at ``dst``.

        Cut-through at the sender, store-and-forward bookkeeping at both
        NICs: the message occupies ``src``'s uplink and ``dst``'s downlink
        for size/bandwidth each, queuing behind earlier traffic on the same
        endpoint (``serialize_links``). On idle links this reduces exactly to
        the classic ``base + size/bandwidth``. ``deliver=False`` models a
        message lost in flight: the sender's uplink was still consumed, but
        nothing queues at (or arrives to) the receiver."""
        lat = self.latency
        tx = size / lat.bandwidth
        prop = float(self.rng.uniform(lat.base_lo, lat.base_hi))
        if not lat.serialize_links:
            return prop + tx
        t_send = max(self.now, self._busy.get((src, "out"), 0.0))
        self._busy[(src, "out")] = t_send + tx
        if not deliver:
            return 0.0
        t_recv = max(t_send + prop, self._busy.get((dst, "in"), 0.0))
        self._busy[(dst, "in")] = t_recv + tx
        return (t_recv + tx) - self.now

    # -- op driving ------------------------------------------------------------
    def spawn(
        self,
        gen: Generator,
        kind: str = "",
        client: str = "",
        delay: float = 0.0,
        on_done: Callable[[OpFuture], None] | None = None,
    ) -> OpFuture:
        fut = OpFuture(op_id=next(self._op_ids), kind=kind, client=client)
        self.futures.append(fut)

        def start() -> None:
            fut.start = self.now
            self._step(gen, fut, None, on_done)

        self.schedule(delay, start)
        return fut

    def run_op(self, gen: Generator, **kw) -> Any:
        """Convenience: spawn one op, run to quiescence, return its result."""
        fut = self.spawn(gen, **kw)
        self.run()
        if not fut.done:
            raise RuntimeError(f"operation {fut.kind or fut.op_id} did not terminate")
        return fut.result

    # -- internals ------------------------------------------------------------
    def _step(
        self,
        gen: Generator,
        fut: OpFuture,
        send_value: Any,
        on_done: Callable[[OpFuture], None] | None,
    ) -> None:
        try:
            effect = gen.send(send_value)
        except StopIteration as stop:
            fut.done = True
            fut.end = self.now
            fut.result = stop.value
            if on_done is not None:
                on_done(fut)
            return
        if isinstance(effect, Sleep):
            self.schedule(effect.duration, lambda: self._step(gen, fut, None, on_done))
        elif isinstance(effect, RPC):
            self._run_rpc(effect, gen, fut, on_done)
        elif isinstance(effect, Join):
            n = len(effect.children)
            if n == 0:
                self.schedule(0.0, lambda: self._step(gen, fut, [], on_done))
                return
            results = [None] * n
            state = {"left": n}

            def make_done(i):
                def done(child_fut):
                    results[i] = child_fut.result
                    state["left"] -= 1
                    if state["left"] == 0:
                        self._step(gen, fut, results, on_done)
                return done

            for i, child in enumerate(effect.children):
                self.spawn(child, client=fut.client, on_done=make_done(i))
        else:  # pragma: no cover
            raise TypeError(f"unknown effect {effect!r}")

    def _run_rpc(
        self,
        rpc: RPC,
        gen: Generator,
        fut: OpFuture,
        on_done: Callable[[OpFuture], None] | None,
    ) -> None:
        self.rpc_rounds += 1
        # the issuing client's account, plus any riders attributed to it
        # (``attribute``): a gateway's merged round counts once per rider.
        accts = [self.client_counters.setdefault(fut.client, [0, 0, 0])]
        for rider in self.client_attribution.get(fut.client, ()):
            accts.append(self.client_counters.setdefault(rider, [0, 0, 0]))
        for a in accts:
            a[0] += 1
        replies: dict[str, Any] = {}
        state = {"resumed": False}
        if rpc.need == "alive":
            need = sum(
                1
                for sid in rpc.dests
                if (srv := self.servers.get(sid)) is not None and not srv.crashed
            )
        else:
            need = rpc.need
        need = min(need, len(rpc.dests))

        def deliver_reply(sid: str, reply: Any) -> None:
            if state["resumed"]:
                return  # late reply past the quorum: ignored
            replies[sid] = reply
            if len(replies) >= need:
                state["resumed"] = True
                self._step(gen, fut, dict(replies), on_done)

        def send_all() -> None:
            # broadcast fan-outs ship ONE payload to every server — size it
            # once, not once per destination (it's the sim's hottest path)
            shared_size = msg_wire_size(rpc.msg) if rpc.per_dest is None else None
            for sid in rpc.dests:
                srv = self.servers.get(sid)
                if srv is None:
                    continue
                msg = rpc.msg if rpc.per_dest is None else rpc.per_dest[sid]
                self.msg_count += 1
                size = shared_size if shared_size is not None else msg_wire_size(msg)
                self.bytes_sent += size
                for a in accts:
                    a[1] += 1
                    a[2] += size
                dropped = self.rng.random() < self.latency.drop_prob
                delay = self.transmit_delay(fut.client, sid, size, deliver=not dropped)
                if dropped:
                    continue

                def arrive(srv=srv, sid=sid, msg=msg) -> None:
                    if srv.crashed:
                        return
                    reply = srv.handle(fut.client, msg)
                    if reply is None:
                        return
                    rsize = msg_wire_size(reply)
                    self.msg_count += 1
                    self.bytes_sent += rsize
                    for a in accts:
                        a[1] += 1
                        a[2] += rsize
                    rdropped = self.rng.random() < self.latency.drop_prob
                    rdelay = self.latency.server_compute + self.transmit_delay(
                        sid, fut.client, rsize, deliver=not rdropped
                    )
                    if rdropped:
                        return
                    self.schedule(rdelay, lambda: deliver_reply(sid, reply))

                self.schedule(delay, arrive)

        self.schedule(rpc.pre_delay, send_all)
        if need <= 0:
            # nothing can (or needs to) reply — messages still go out, but the
            # op resumes immediately with no replies (guarded against a
            # straggler reply re-resuming the generator).
            def resume_empty() -> None:
                if not state["resumed"]:
                    state["resumed"] = True
                    self._step(gen, fut, {}, on_done)

            self.schedule(rpc.pre_delay, resume_empty)
