from repro.roofline.analysis import (
    V5E,
    HardwareSpec,
    collective_bytes_from_hlo,
    roofline_report,
)

__all__ = ["V5E", "HardwareSpec", "collective_bytes_from_hlo", "roofline_report"]
