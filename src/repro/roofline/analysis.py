"""Three-term roofline from the compiled dry-run artifact.

  compute    = HLO_FLOPs / peak_FLOPs            (per chip: SPMD module)
  memory     = HLO_bytes / HBM_bw
  collective = Σ collective result bytes / link_bw

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are *not* in
cost_analysis, so we parse the optimized HLO text and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Hardware: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI).
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12      # bf16 per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per ICI link
    hbm_bytes: float = 16e9


V5E = HardwareSpec()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# one HLO instruction result: "%name = <shape-or-tuple> <op>(" ; shapes like
# f32[16,128]{1,0} or tuples (f32[2]{0}, bf16[4,4]{1,0})
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w]+\[[\d,]*\][^\s]*)\s+([\w-]+)(?:-start|-done)?\("
)


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective op kind over the (optimized) HLO.

    Async pairs (``-start``/``-done``) are counted once (on -start; -done
    results alias). ``while``-loop bodies are static text — a collective
    inside a scanned loop body appears once; multiply by trip count is NOT
    attempted (XLA hoists per-layer collectives into the unrolled/scanned
    body exactly once per step), so figures are per-executed-iteration lower
    bounds plus top-level ops. For roofline ranking this is the comparable
    quantity across configs; trip-count weighting is applied upstream where
    the scan length is known.
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:  # async completion: result aliases the -start
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        base = op
        for c in _COLLECTIVES:
            if base == c or base == c + "-start":
                out[c] = out.get(c, 0) + _shape_bytes(shape_txt)
                break
    return out


def scan_weighted_collective_bytes(hlo_text: str) -> tuple[dict[str, int], dict]:
    """Weight collectives inside `while` bodies by their trip count.

    XLA compiles a lax.scan to a while-loop whose body text appears once; a
    collective there executes trip_count times. We detect computations used
    as while bodies, extract trip counts from the canonical induction-
    variable pattern, and weight accordingly.
    """
    # map computation name -> its text block
    blocks: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"\s*%?([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if line.startswith("ENTRY") :
            cur = "__entry__"
            blocks[cur] = []
            continue
        if m and ("{" in line or line.rstrip().endswith("{")):
            cur = m.group(1)
            blocks[cur] = []
            continue
        if cur is not None:
            blocks[cur].append(line)
    # find while instructions: body=%name, and trip counts from constants
    weights: dict[str, int] = {}
    for name, lines in blocks.items():
        for line in lines:
            wm = re.search(r"while\(.*\).*body=%?([\w.\-]+)", line)
            if wm:
                tc = 1
                tm = re.search(r'trip_count["\s:=]+(\d+)', line)
                if tm:
                    tc = int(tm.group(1))
                weights[wm.group(1)] = max(weights.get(wm.group(1), 1), tc)
    totals: dict[str, int] = {}
    details = {"while_bodies": weights}
    for name, lines in blocks.items():
        w = weights.get(name, 1)
        text = "\n".join(lines)
        for op, b in collective_bytes_from_hlo(text).items():
            totals[op] = totals.get(op, 0) + b * w
    return totals, details


def roofline_report(
    *,
    flops: float,
    bytes_accessed: float,
    collective_bytes: float,
    n_chips: int,
    model_flops: float,
    hw: HardwareSpec = V5E,
    links_per_chip: int = 4,
) -> dict:
    """All terms in seconds-per-step, per chip (SPMD module == one chip)."""
    t_compute = flops / hw.peak_flops
    t_memory = bytes_accessed / hw.hbm_bw
    t_coll = collective_bytes / (hw.link_bw * links_per_chip)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = model_flops / max(1.0, flops * n_chips)
    return {
        **terms,
        "dominant": dom,
        "step_time_lower_bound": bound,
        "mfu_upper_bound": (model_flops / n_chips / hw.peak_flops) / bound if bound else 0.0,
        "model_flops_ratio": useful,
    }
