"""Scan-weighted HLO analysis (FLOPs / HBM bytes / collective bytes).

``compiled.cost_analysis()`` counts a while-loop body ONCE, so a lax.scan
over 48 layers under-reports FLOPs by ~48x. We therefore parse the optimized
HLO text ourselves:

  1. split into computation blocks; find `while` instrs, their `body=`/
     `condition=` computations and `known_trip_count` backend configs;
  2. propagate multiplicative weights through the (body) call graph
     (nested scans multiply);
  3. count, per block and weighted:
       * dot FLOPs        = 2 * prod(result dims) * prod(lhs contracting dims)
       * HBM bytes        = result + operand bytes of every instruction in
                            non-fusion computations (fusion call sites count
                            their external operands/results — fusion
                            internals never touch HBM, which makes this a
                            *better* memory model than per-op cost_analysis)
       * collective bytes = per-kind wire-traffic model:
           all-gather / all-to-all / collective-permute -> result bytes
           reduce-scatter                               -> operand bytes
           all-reduce                                   -> 2 x result bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(txt: str) -> list[list[int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


@dataclass
class Instr:
    name: str
    result_txt: str
    op: str
    line: str


@dataclass
class Block:
    name: str
    instrs: list = field(default_factory=list)


_OP_RE = re.compile(
    r"^(?:\([^=]*\)|[\w\[\]{},:\/\* ]+?)\s+([\w\-]+)\(")


def parse_blocks(hlo: str) -> tuple[dict[str, Block], str]:
    blocks: dict[str, Block] = {}
    entry = None
    cur: Block | None = None
    for line in hlo.splitlines():
        if not line:
            continue
        if not line[0].isspace():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
                name = m.group(1) if m else "__entry__"
                entry = name
                cur = blocks.setdefault(name, Block(name))
            elif line.startswith("%"):
                m = re.match(r"%([\w.\-]+)", line)
                cur = blocks.setdefault(m.group(1), Block(m.group(1)))
            else:
                cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # op name: token immediately before the first '(' after the result
        # shape(s). Strip a leading tuple-or-shape.
        op = None
        rest2 = rest
        if rest2.startswith("("):
            depth = 0
            for i, ch in enumerate(rest2):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    rest2 = rest2[i + 1 :].strip()
                    break
        else:
            sp = rest2.find(" ")
            rest2 = rest2[sp + 1 :] if sp >= 0 else ""
        om = re.match(r"([\w\-]+)\(", rest2.strip())
        op = om.group(1) if om else ""
        result_txt = rest[: len(rest) - len(rest2)] if rest2 else rest
        cur.instrs.append(Instr(name, result_txt, op, line))
    return blocks, entry


def analyze(hlo: str) -> dict:
    blocks, entry = parse_blocks(hlo)
    name2result: dict[str, str] = {}
    fusion_called: set[str] = set()
    body_edges: dict[str, list[tuple[str, int]]] = {}
    for b in blocks.values():
        for ins in b.instrs:
            name2result[ins.name] = ins.result_txt
            if ins.op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if fm:
                    fusion_called.add(fm.group(1))
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                tm = re.search(r'known_trip_count[^0-9]*(\d+)', ins.line)
                trip = int(tm.group(1)) if tm else 1
                body_edges.setdefault(b.name, []).append((bm.group(1), trip))
                if cm:
                    body_edges.setdefault(b.name, []).append((cm.group(1), trip))
            if ins.op == "conditional":
                for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))", ins.line):
                    for part in br:
                        for nm in re.findall(r"%?([\w.\-]+)", part or ""):
                            if nm in blocks:
                                body_edges.setdefault(b.name, []).append((nm, 1))
            if ins.op == "call":
                cm = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                if cm:
                    body_edges.setdefault(b.name, []).append((cm.group(1), 1))

    # propagate weights from entry through control-flow edges
    weights: dict[str, int] = {entry: 1}
    frontier = [entry]
    seen_edges = set()
    while frontier:
        src = frontier.pop()
        for dst, trip in body_edges.get(src, []):
            key = (src, dst)
            if key in seen_edges:
                continue
            seen_edges.add(key)
            w = weights.get(src, 1) * max(trip, 1)
            if weights.get(dst, 0) < w:
                weights[dst] = w
                frontier.append(dst)

    counted = {n for n in weights if n not in fusion_called}

    flops = 0.0
    hbm_bytes = 0.0
    coll: dict[str, float] = {}
    unknown_trip_whiles = 0
    for bname in counted:
        w = weights.get(bname, 1)
        for ins in blocks[bname].instrs:
            if ins.op == "while" and "known_trip_count" not in ins.line:
                unknown_trip_whiles += 1
            # ---- memory bytes: result + resolved operand bytes -------------
            if ins.op in ("parameter", "constant", "get-tuple-element", "tuple",
                          "bitcast"):
                continue
            rb = _shape_bytes(ins.result_txt)
            ob = 0
            # operand names: inside the op's parens
            pm = re.search(re.escape(ins.op) + r"\((.*?)\)(?:,|$)", ins.line)
            if pm:
                for opnd in _OPND_RE.findall(pm.group(1)):
                    ob += _shape_bytes(name2result.get(opnd, ""))
            if ins.op not in ("while",):  # while results alias its carry
                hbm_bytes += w * (rb + ob)
            # ---- dot flops --------------------------------------------------
            if ins.op == "dot":
                dims = _shape_dims(ins.result_txt)
                res_elems = 1
                for d in (dims[0] if dims else []):
                    res_elems *= d
                lm = re.search(r"dot\((.*?)\)(?:,|$)", ins.line)
                contr = 1
                if lm:
                    opnds = _OPND_RE.findall(lm.group(1))
                    if opnds:
                        lhs_shape = _shape_dims(name2result.get(opnds[0], ""))
                        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
                        if lhs_shape and cm and cm.group(1):
                            for ci in cm.group(1).split(","):
                                idx = int(ci)
                                if idx < len(lhs_shape[0]):
                                    contr *= lhs_shape[0][idx]
                flops += w * 2.0 * res_elems * contr
            # ---- collectives ------------------------------------------------
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in _COLLECTIVES and not ins.op.endswith("-done"):
                rb_c = _shape_bytes(ins.result_txt)
                if base == "all-reduce":
                    traffic = 2 * rb_c
                elif base == "reduce-scatter":
                    traffic = ob or rb_c
                else:
                    traffic = rb_c
                coll[base] = coll.get(base, 0.0) + w * traffic

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll,
        "collective_bytes_total": float(sum(coll.values())),
        "n_computations_counted": len(counted),
        "unknown_trip_whiles": unknown_trip_whiles,
        "weights": {k: v for k, v in sorted(weights.items()) if v > 1},
    }


def top_byte_contributors(hlo: str, top: int = 14) -> list[tuple[str, float, int]]:
    """(op kind + shape, weighted GB, count) — where the memory term goes."""
    blocks, entry = parse_blocks(hlo)
    name2result: dict[str, str] = {}
    fusion_called: set[str] = set()
    body_edges: dict[str, list[tuple[str, int]]] = {}
    for b in blocks.values():
        for ins in b.instrs:
            name2result[ins.name] = ins.result_txt
            if ins.op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if fm:
                    fusion_called.add(fm.group(1))
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                tm = re.search(r"known_trip_count[^0-9]*(\d+)", ins.line)
                trip = int(tm.group(1)) if tm else 1
                if bm:
                    body_edges.setdefault(b.name, []).append((bm.group(1), trip))
                if cm:
                    body_edges.setdefault(b.name, []).append((cm.group(1), trip))
    weights = {entry: 1}
    frontier = [entry]
    seen = set()
    while frontier:
        src = frontier.pop()
        for dst, trip in body_edges.get(src, []):
            if (src, dst) in seen:
                continue
            seen.add((src, dst))
            w = weights.get(src, 1) * max(trip, 1)
            if weights.get(dst, 0) < w:
                weights[dst] = w
                frontier.append(dst)
    agg: dict[str, list] = {}
    for bname in weights:
        if bname in fusion_called:
            continue
        w = weights.get(bname, 1)
        for ins in blocks[bname].instrs:
            if ins.op in ("parameter", "constant", "get-tuple-element", "tuple",
                          "bitcast", "while"):
                continue
            rb = _shape_bytes(ins.result_txt)
            ob = 0
            pm = re.search(re.escape(ins.op) + r"\((.*?)\)(?:,|$)", ins.line)
            if pm:
                for opnd in _OPND_RE.findall(pm.group(1)):
                    ob += _shape_bytes(name2result.get(opnd, ""))
            md = re.search(r'op_name="jit\([\w_]+\)/([^"]{0,60})', ins.line)
            src = (md.group(1).split(" ")[0] if md else "?")
            key = f"{ins.op:<18} {src}"
            a = agg.setdefault(key, [0.0, 0])
            a[0] += w * (rb + ob)
            a[1] += 1
    rows = sorted(agg.items(), key=lambda kv: -kv[1][0])[:top]
    return [(k, v[0] / 1e9, v[1]) for k, v in rows]
