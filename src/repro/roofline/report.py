"""Render the §Dry-run / §Roofline tables from runs/dryrun/*.json.

  PYTHONPATH=src python -m repro.roofline.report [--dir runs/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path


def load(dirname: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(f"{dirname}/*.json")):
        d = json.load(open(f))
        d["_cell"] = Path(f).stem
        out.append(d)
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(rows: list[dict], mesh: str = "pod1") -> str:
    lines = [
        "| arch | shape | comp (s) | mem (s) | coll (s) | dominant | "
        "MODEL/HLO flops | MFU ub | live GB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        if not d["_cell"].endswith(mesh):
            continue
        arch, shape, _ = d["_cell"].split("__")
        if d["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | *skip: full attention* | — | — | — | — |")
            continue
        if d["status"] != "ok":
            lines.append(f"| {arch} | {shape} | ERROR {d.get('error','')[:40]} |" + " — |" * 8)
            continue
        r = d["roofline"]
        lines.append(
            f"| {arch} | {shape} | {fmt_s(r['compute'])} | {fmt_s(r['memory'])} | "
            f"{fmt_s(r['collective'])} | **{r['dominant']}** | "
            f"{r['model_flops_ratio']:.2f} | {r['mfu_upper_bound']*100:.2f}% | "
            f"{d['per_chip_live_bytes']/1e9:.1f} | {'✓' if d['fits_hbm'] else 'OOM'} |"
        )
    return "\n".join(lines)


def dryrun_table(rows: list[dict]) -> str:
    lines = [
        "| cell | mesh | chips | lower+compile (s) | per-chip live (GB) | fits "
        "| per-chip HLO GFLOPs | collective GB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        if d["status"] == "skipped":
            continue
        arch, shape, mesh = d["_cell"].split("__")
        if d["status"] != "ok":
            lines.append(f"| {arch}/{shape} | {mesh} | ERROR |" + " — |" * 5)
            continue
        lines.append(
            f"| {arch}/{shape} | {d['mesh']} | {d['n_chips']} | "
            f"{d['lower_s']+d['compile_s']:.0f} | "
            f"{d['per_chip_live_bytes']/1e9:.2f} | {'✓' if d['fits_hbm'] else '✗'} | "
            f"{d['flops_per_chip']/1e9:.0f} | {d['collective_bytes_total']/1e9:.2f} |"
        )
    return "\n".join(lines)


def pick_hillclimb(rows: list[dict]) -> list[str]:
    """worst roofline fraction / most collective-bound / most paper-
    representative (largest EC-checkpointable state = biggest model train)."""
    ok = [d for d in rows if d["status"] == "ok" and d["_cell"].endswith("pod1")]
    trains = [d for d in ok if "train" in d["_cell"]]
    worst = min(trains, key=lambda d: d["roofline"]["mfu_upper_bound"])
    coll = max(ok, key=lambda d: d["roofline"]["collective"] /
               max(1e-9, d["roofline"]["step_time_lower_bound"]))
    rep = max(trains, key=lambda d: d["n_active_params"])
    return [worst["_cell"], coll["_cell"], rep["_cell"]]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    args = ap.parse_args()
    rows = load(args.dir)
    print("## Roofline (single-pod 16x16)\n")
    print(roofline_table(rows, "pod1"))
    print("\n## Dry-run all cells\n")
    print(dryrun_table(rows))
    print("\nhillclimb candidates:", pick_hillclimb(rows))
