"""Serving layer. The decode/prefill model paths live in
repro.models.lm.LM.decode_step / cache_template / cache_specs (shared with
training for one source of truth); the batched driver is
repro.launch.serve. This package re-exports the public surface."""
from repro.launch.serve import main as serve_main
from repro.train.steps import make_prefill_step, make_serve_step

__all__ = ["make_serve_step", "make_prefill_step", "serve_main"]
