"""Training substrate: optimizer, data pipeline, steps, EC checkpointing."""
