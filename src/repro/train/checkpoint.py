"""EC-coded, coverable, fragmented distributed checkpointing — the paper's
technique (CoARESF + EC-DAPopt) as the training stack's fault-tolerance layer.

Mapping (DESIGN.md §3, Adaptation 3):

  * each *host shard* of the train state serializes to one fragmented object
    (a "file") in a CoARESF store whose servers are the checkpoint hosts;
  * writes are **quorum** operations: the save completes once ⌈(n+k)/2⌉
    hosts ack per block — dead/straggling hosts do not block the train loop;
  * writes are **coverable**: tags are versions; a resurrected pre-empted
    trainer whose version is stale has its write degrade to a read (no
    clobber, no external lock service);
  * blocks are **content-defined** (gear CDC): unchanged state (frozen
    layers, optimizer hyperparams, data-pipeline state) re-writes nothing;
  * **recon** migrates all blocks to a new host set / DAP (elastic resize)
    while reads and writes continue.

The control plane runs on the deterministic sim network (virtual time), so
checkpoint latency/traffic are measurable and reproducible; the data plane
(serialization, RS encode via the Pallas-backed kernel path) is real compute
on real bytes.
"""
from __future__ import annotations

import io
import pickle
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from repro.core.store import DSS, DSSParams
from repro.net.sim import LatencyModel

Pytree = Any


# ---------------------------------------------------------------- serialization
def serialize_tree(tree: Pytree) -> bytes:
    """Pytree -> bytes: pickled structure header + raw little-endian arrays."""
    leaves, treedef = jax.tree.flatten(tree)
    arrs = [np.asarray(x) for x in leaves]
    header = pickle.dumps(
        {
            "treedef": treedef,
            "shapes": [a.shape for a in arrs],
            # dtype NAMES: ml_dtypes types (bfloat16, ...) stringify to void
            # under .str and would not round-trip
            "dtypes": [a.dtype.name for a in arrs],
        }
    )
    out = io.BytesIO()
    out.write(len(header).to_bytes(8, "big"))
    out.write(header)
    for a in arrs:
        out.write(np.ascontiguousarray(a).tobytes())
    return out.getvalue()


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def deserialize_tree(blob: bytes) -> Pytree:
    hlen = int.from_bytes(blob[:8], "big")
    header = pickle.loads(blob[8 : 8 + hlen])
    off = 8 + hlen
    leaves = []
    for shape, name in zip(header["shapes"], header["dtypes"]):
        dt = _np_dtype(name)
        n = int(np.prod(shape)) * dt.itemsize
        leaves.append(np.frombuffer(blob[off : off + n], dtype=dt).reshape(shape))
        off += n
    return jax.tree.unflatten(header["treedef"], leaves)


# ---------------------------------------------------------------- the store
@dataclass
class CheckpointStats:
    step: int
    bytes_written: int
    blocks_total: int
    blocks_written: int
    virtual_seconds: float
    success: bool


class ECCheckpointStore:
    """Checkpoint store for one logical trainer over n checkpoint hosts.

    algorithm: any of repro.core.store.ALGORITHMS — the paper's CoARESECF
    (fragmented + EC-DAPopt, the default) gives quorum writes, k-of-n
    restores, incremental block updates and live reconfiguration.
    coding_backend: GF(256) backend for the RS data plane ("numpy" |
    "kernel" | "auto"; see repro.erasure.rs) — checkpoint shards are exactly
    the large-operand regime where the kernel path pays off.
    """

    def __init__(
        self,
        n_hosts: int = 8,
        parity: int = 2,
        algorithm: str = "coaresecf",
        client_id: str = "trainer0",
        seed: int = 0,
        min_block: int = 1 << 16,
        avg_block: int = 1 << 18,
        max_block: int = 1 << 20,
        latency: LatencyModel | None = None,
        indexed: bool = True,
        coding_backend: str = "auto",
    ):
        self.dss = DSS(
            DSSParams(
                algorithm=algorithm,
                n_servers=n_hosts,
                parity_m=parity,
                seed=seed,
                min_block=min_block,
                avg_block=avg_block,
                max_block=max_block,
                latency=latency or LatencyModel(),
                indexed=indexed,
                coding_backend=coding_backend,
            )
        )
        self.client = self.dss.client(client_id)
        self.client_id = client_id

    # --- save / restore ------------------------------------------------------
    # Checkpoint protocol: copy-on-write per trainer + atomic coverable
    # pointer flip. Each trainer writes its own fragmented object (keeps the
    # CDC incremental-dedup within a trainer), then flips a tiny meta object
    # (step, fid) with a coverable write — concurrent/stale flips degrade to
    # reads (paper §IV), so exactly one checkpoint wins and none tear.
    def _meta_id(self, shard_id: str) -> str:
        return f"ckptmeta/{shard_id}"

    def _read_meta(self, shard_id: str) -> tuple[int, str] | None:
        tag, raw = self.dss.net.run_op(
            self.client.dsm.cvr_read(self._meta_id(shard_id)), client=self.client_id
        )
        self.client.dsm.version[self._meta_id(shard_id)] = tag
        if not raw:
            return None
        obj = pickle.loads(bytes(raw))
        return int(obj["step"]), obj["fid"]

    def save(self, step: int, state: Pytree, shard_id: str = "shard0") -> CheckpointStats:
        blob = serialize_tree({"step": step, "state": state})
        t0 = self.dss.net.now
        meta = self._read_meta(shard_id)
        if meta is not None and meta[0] >= step:
            # stale trainer: a newer checkpoint exists — degrade to no-op
            return CheckpointStats(step=step, bytes_written=0, blocks_total=0,
                                   blocks_written=0,
                                   virtual_seconds=self.dss.net.now - t0,
                                   success=False)
        fid = f"ckpt/{shard_id}/{self.client_id}"
        stats = self.dss.net.run_op(self.client.update(fid, blob),
                                    client=self.client_id)
        meta_raw = pickle.dumps({"step": step, "fid": fid})
        (_tag, _v), flag = self.dss.net.run_op(
            self.client.dsm.cvr_write(self._meta_id(shard_id), meta_raw),
            client=self.client_id,
        )
        ok = stats.get("success", False) and flag == "chg"
        return CheckpointStats(
            step=step,
            bytes_written=len(blob),
            blocks_total=stats.get("blocks", 1),
            blocks_written=stats.get("written", 1),
            virtual_seconds=self.dss.net.now - t0,
            success=ok,
        )

    def restore(self, shard_id: str = "shard0") -> tuple[int, Pytree] | None:
        meta = self._read_meta(shard_id)
        if meta is None:
            return None
        _step, fid = meta
        blob = self.dss.net.run_op(self.client.read(fid), client=self.client_id)
        if not blob:
            return None
        obj = deserialize_tree(bytes(blob))
        return int(obj["step"]), obj["state"]

    # --- fault tolerance -------------------------------------------------------
    def crash_hosts(self, host_ids: list[str]) -> None:
        self.dss.crash_servers(host_ids)

    def fault_budget(self) -> int:
        """Max simultaneous host crashes the store tolerates: ⌊(n-k)/2⌋ for
        EC, ⌊(n-1)/2⌋ for replication."""
        c = self.dss.c0
        if c.dap.startswith("ec"):
            return (c.n - c.k) // 2
        return (c.n - 1) // 2

    # --- elasticity -----------------------------------------------------------
    def reconfigure(
        self, shard_id: str = "shard0", *, n_hosts: int | None = None,
        parity: int | None = None, dap: str | None = None, fresh: bool = False,
    ) -> int:
        """ARES recon on every block of the checkpoint object (Alg 3)."""
        cfg = self.dss.make_config(
            dap=dap, n_servers=n_hosts, parity_m=parity, fresh_servers=fresh
        )
        return self.dss.net.run_op(
            self.client.recon(f"ckpt/{shard_id}", cfg), client=self.client_id
        )

    def new_trainer(self, client_id: str) -> "ECCheckpointStore":
        """A second (elastic / resurrected) trainer over the same hosts —
        coverability arbitrates concurrent saves."""
        twin = object.__new__(ECCheckpointStore)
        twin.dss = self.dss
        twin.client = self.dss.client(client_id)
        twin.client_id = client_id
        return twin
