"""Error-feedback int8 gradient compression (DP traffic reduction).

For explicit-DP reductions (e.g. cross-pod DCN all-reduce where 4x fewer
bytes matter most), gradients are quantized to int8 with a per-tensor scale;
the quantization residual is fed back into the next step (EF-SGD/1-bit Adam
style), keeping convergence unbiased in practice.

Usage (see launch/train.py --compress-grads): compress -> (all-reduce int8)
-> decompress. The roofline collective term scales accordingly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def compress_leaf(g: jax.Array, residual: jax.Array | None = None):
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_residual = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress_leaf(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_residuals(grads: Pytree) -> Pytree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_tree(grads: Pytree, residuals: Pytree):
    out = jax.tree.map(compress_leaf, grads, residuals)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    qs = jax.tree.unflatten(treedef, [t[0] for t in flat])
    scales = jax.tree.unflatten(treedef, [t[1] for t in flat])
    res = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return qs, scales, res


def decompress_tree(qs: Pytree, scales: Pytree, like: Pytree) -> Pytree:
    return jax.tree.map(
        lambda q, s, g: decompress_leaf(q, s, g.dtype), qs, scales, like
    )


def compressed_bytes(grads: Pytree) -> tuple[int, int]:
    """(raw bytes, compressed bytes) for the DP reduction."""
    raw = sum(g.size * g.dtype.itemsize for g in jax.tree.leaves(grads))
    comp = sum(g.size + 4 for g in jax.tree.leaves(grads))  # int8 + scale
    return raw, comp
