"""Synthetic LM data pipeline: deterministic, host-shardable, restartable.

Generates zipf-distributed token "documents" from a counter-based PRNG, so
any (host, step) batch is reproducible without materializing a dataset —
the pipeline state checkpoint is just ``(seed, step)`` (a few bytes), which
the EC checkpoint store treats as one tiny always-rewritten block.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self.step = 0

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step}

    def restore(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = int(state["step"])

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, self.cfg.host_id, step])
        )

    def next_batch(self) -> dict:
        cfg = self.cfg
        rng = self._batch_rng(self.step)
        self.step += 1
        # zipf tokens clipped into vocab; shift-by-one LM objective
        toks = rng.zipf(cfg.zipf_a, size=(self.local_batch, cfg.seq_len + 1))
        toks = (toks - 1) % cfg.vocab
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
