"""Elastic scaling: mesh resize as an ARES reconfiguration.

Scale-up/down procedure (DESIGN.md §6):
  1. quorum-checkpoint current state to the EC store (cheap: CDC blocks);
  2. recon the store onto the new host set (ARES recon per block — the
     service stays readable during the move);
  3. restore into the new mesh layout (jax.device_put with new shardings).

On this CPU container step 3 reshards within the host meshes; on a real
cluster the same code runs over jax.distributed with per-host addressable
shards.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.train.checkpoint import ECCheckpointStore

Pytree = Any


def reshard_state(state: Pytree, spec_tree: Pytree) -> Pytree:
    """Reshard a pytree onto new NamedShardings (elastic mesh change)."""
    return jax.tree.map(jax.device_put, state, spec_tree)


def elastic_resize(
    store: ECCheckpointStore,
    state: Pytree,
    step: int,
    *,
    new_hosts: int,
    new_parity: int | None = None,
    shard_id: str = "shard0",
) -> tuple[int, Pytree, int]:
    """Checkpoint -> recon to the resized host set -> restore.

    Returns (restored step, restored state, blocks moved)."""
    st = store.save(step, state, shard_id)
    assert st.success, "elastic resize requires a successful checkpoint"
    moved = store.reconfigure(shard_id, n_hosts=new_hosts, parity=new_parity)
    restored = store.restore(shard_id)
    assert restored is not None
    rstep, rstate = restored
    return rstep, rstate, moved
