"""AdamW (pure pytree, no optax dependency) with ZeRO-1 style sharding.

Moments are f32 and sharded like their parameter PLUS the data axes on the
first still-unsharded divisible dim (optimizer-state sharding over DP — the
XLA partitioner derives the reduce-scatter/all-gather pattern from the
in/out shardings).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.sharding import MeshCtx, shard_map_compat

Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Pytree) -> Pytree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_init_shapes(param_shapes: Pytree) -> Pytree:
    sd = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(sd, param_shapes),
        "v": jax.tree.map(sd, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 param_specs=None, zero_specs=None):
    """ZeRO-1 style: when spec trees are given, grads and params are
    constrained to the optimizer (data-sharded) layout BEFORE any f32 math —
    otherwise XLA materializes f32 copies of whole bf16 weight tensors
    (2.4 GB/leaf for the 30B MoE experts). Updated params are constrained
    back to their compute sharding (the partitioner emits the ZeRO
    all-gather)."""
    step = state["step"] + 1
    # Re-shard grads to the ZeRO (optimizer) layout FIRST; every f32 temp
    # below (norm, moments, update) then lives at 1/n_data size. The barrier
    # stops XLA hoisting f32 converts above the resharding dynamic-slice.
    if zero_specs is not None:
        grads = jax.tree.map(jax.lax.with_sharding_constraint, grads, zero_specs)
        grads = jax.lax.optimization_barrier(grads)
    # global-norm clip (f32)
    gnorm2 = sum(
        jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / (jnp.sqrt(gnorm2) + 1e-9))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, pspec=None, zspec=None):
        # ZeRO-1 storage layout: p enters AND leaves at the zero (data-
        # sharded) spec; the bf16 all-gather to compute layout happens once
        # at the top of train_step (see make_train_step). No f32 cast of
        # ``p`` anywhere — XLA (CPU emulation of bf16) otherwise materializes
        # full f32 copies / f32 all-gathers of every weight tensor.
        del pspec
        p_l = jax.lax.with_sharding_constraint(p, zspec) if zspec is not None else p
        g32 = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        step_term = (cfg.lr * delta).astype(p.dtype)
        decay = (1.0 - cfg.lr * cfg.weight_decay) if p.ndim >= 2 else 1.0
        p2 = p_l * decay - step_term
        if zspec is not None:
            p2 = jax.lax.with_sharding_constraint(p2, zspec)
        return p2, m2, v2

    if param_specs is not None:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"],
                           param_specs, zero_specs)
    else:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    p2 = jax.tree.unflatten(treedef, [t[0] for t in flat])
    m2 = jax.tree.unflatten(treedef, [t[1] for t in flat])
    v2 = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return p2, {"m": m2, "v": v2, "step": step}


def _zero1(spec_sharding, shape, ctx: MeshCtx):
    """Add the batch axes on an unsharded dim divisible by them.

    For stacked per-layer params (ndim >= 3) dim0 is the lax.scan axis —
    XLA sinks the optimizer update into the backward layer scan and slices
    dim0 dynamically, so sharding dim0 would force an all-gather of the f32
    moments every step. Prefer trailing dims there."""
    spec = list(spec_sharding.spec) + [None] * (len(shape) - len(spec_sharding.spec))
    used = {a for s in spec if s is not None for a in ((s,) if isinstance(s, str) else s)}
    if any(a in used for a in ctx.batch_axes):
        return spec_sharding
    nb = ctx.n_batch
    order = list(range(len(shape)))
    if len(shape) >= 3:
        order = order[1:] + [order[0]]
    for i in order:
        if spec[i] is None and shape[i] % nb == 0 and shape[i] >= nb:
            spec[i] = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
            return ctx.ns(*spec)
    return spec_sharding


def adamw_specs(param_specs: Pytree, param_shapes: Pytree, ctx: MeshCtx) -> Pytree:
    mk = lambda ns, sd: _zero1(ns, sd.shape, ctx)
    return {
        "m": jax.tree.map(mk, param_specs, param_shapes),
        "v": jax.tree.map(mk, param_specs, param_shapes),
        "step": ctx.replicated(),
    }


# ---------------------------------------------------------------------------
# Explicit (shard_map) ZeRO-1 update
# ---------------------------------------------------------------------------
def _zero_dim(pspec, zspec) -> int | None:
    """Dim where the zero spec added the batch axes (None if unsharded)."""
    ps = list(pspec.spec) + [None] * 8
    zs = list(zspec.spec) + [None] * 8
    for i, (a, b) in enumerate(zip(ps, zs)):
        if a != b:
            return i
    return None


def adamw_update_sharded(params, grads, state, cfg: AdamWConfig, ctx: MeshCtx,
                         param_specs, zero_specs):
    """AdamW with *explicit* ZeRO-1 via per-leaf shard_map.

    The pure-constraint formulation leaves the partitioner free to all-gather
    the f32 moments back to weight sharding inside the sunk update loop
    (observed: +7 GB/chip of f32 weight-shaped temps on the 30B MoE). Inside
    shard_map shapes are local, so the schedule is pinned: moments and all
    f32 math live at 1/n_dp size; the only cross-chip traffic is the standard
    ZeRO bf16 all-gather of the fresh params.
    """
    from jax.sharding import PartitionSpec as P

    step = state["step"] + 1
    gnorm2 = sum(
        jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / (jnp.sqrt(gnorm2) + 1e-9))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    batch_axes = ctx.batch_axes
    n_dp = ctx.n_batch
    mesh = ctx.mesh

    def upd_leaf(p, g, m, v, pspec, zspec):
        zdim = _zero_dim(pspec, zspec)

        def body(p_l, g_l, m_l, v_l, scale_l):
            if zdim is not None:
                shard = p_l.shape[zdim] // n_dp
                idx = jax.lax.axis_index(batch_axes[-1])
                if len(batch_axes) > 1:
                    idx = idx + jax.lax.axis_index(batch_axes[0]) * mesh.shape[batch_axes[-1]]
                off = idx * shard
                p_s = jax.lax.dynamic_slice_in_dim(p_l, off, shard, zdim)
                g_s = jax.lax.dynamic_slice_in_dim(g_l, off, shard, zdim)
            else:
                p_s, g_s = p_l, g_l
            g32 = g_s.astype(jnp.float32) * scale_l
            m2 = cfg.b1 * m_l + (1 - cfg.b1) * g32
            v2 = cfg.b2 * v_l + (1 - cfg.b2) * g32 * g32
            delta = (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
            decay = (1.0 - cfg.lr * cfg.weight_decay) if p_s.ndim >= 2 else 1.0
            p2_s = p_s * decay - (cfg.lr * delta).astype(p_s.dtype)
            if zdim is not None:
                p2 = jax.lax.all_gather(p2_s, batch_axes, axis=zdim, tiled=True)
            else:
                p2 = p2_s
            return p2, m2, v2

        return shard_map_compat(
            body,
            mesh=mesh,
            in_specs=(pspec.spec, pspec.spec, zspec.spec, zspec.spec, P()),
            out_specs=(pspec.spec, zspec.spec, zspec.spec),
            check_vma=False,
        )(p, g, m, v, scale)

    out = jax.tree.map(upd_leaf, params, grads, state["m"], state["v"],
                       param_specs, zero_specs)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    p2 = jax.tree.unflatten(treedef, [t[0] for t in flat])
    m2 = jax.tree.unflatten(treedef, [t[1] for t in flat])
    v2 = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return p2, {"m": m2, "v": v2, "step": step}
