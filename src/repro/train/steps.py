"""train_step / prefill_step / serve_step builders + their shardings."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.lm import LM
from repro.models.registry import input_specs
from repro.models.sharding import MeshCtx
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init_shapes,
    adamw_specs,
    adamw_update,
)


def batch_shardings(cfg: ArchConfig, shape: ShapeConfig, ctx: MeshCtx,
                    model: LM | None = None) -> dict:
    B = shape.global_batch
    specs = input_specs(cfg, shape)
    out = {}
    bspec = ctx.token_spec(B)  # (batch-ish, seq-ish)
    pure_dp = (model or LM(cfg)).pure_dp
    if pure_dp and B % (ctx.n_batch * ctx.n_model) == 0:
        bspec = ((*ctx.batch_axes, "model"), None)
    for k, sd in specs.items():
        if k in ("tokens", "labels"):
            out[k] = ctx.ns(*bspec)
        elif k == "embeds":
            out[k] = ctx.ns(*bspec, None)
        elif k == "audio_embeds":
            out[k] = ctx.ns(*bspec, None)
        elif k == "positions":
            out[k] = ctx.ns(None, *bspec)
        elif k in ("token", "embed"):
            sp = (ctx.batch_axes,) if B % ctx.n_batch == 0 and B >= ctx.n_batch else (None,)
            out[k] = ctx.ns(*sp, *([None] * (len(sd.shape) - 1)))
        else:  # cur_len
            out[k] = ctx.replicated()
    return out


def make_train_step(model: LM, ctx: MeshCtx | None, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig()
    pspecs = zspecs = None
    if ctx is not None:
        pspecs = model.param_specs(ctx)
        zspecs = adamw_specs(pspecs, model.param_shapes(), ctx)["m"]

    def train_step(params, opt_state, batch):
        if ctx is not None:
            # params are *stored* ZeRO-sharded (zspecs); gather to compute
            # layout once per step (single clean bf16 all-gather per tensor).
            params_c = jax.tree.map(jax.lax.with_sharding_constraint, params, pspecs)
        else:
            params_c = params
        loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch, ctx))(params_c)
        params, opt_state = adamw_update(
            params, grads, opt_state, opt_cfg, param_specs=pspecs, zero_specs=zspecs
        )
        return params, opt_state, loss

    return train_step


def make_prefill_step(model: LM, ctx: MeshCtx):
    def prefill_step(params, batch):
        """Forward only; returns last-position logits (B, V)."""
        cfg = model.cfg
        if cfg.family == "encdec":
            h, _ = model._run_encdec(params, batch, ctx)
        else:
            if cfg.embeddings_input:
                h = batch["embeds"].astype(jnp.bfloat16)
                positions = batch["positions"]
            else:
                tokens = batch["tokens"]
                h = params["embed"][tokens].astype(jnp.bfloat16)
                B, S = tokens.shape
                positions = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
                if cfg.rope_style == "mrope":
                    positions = jnp.stack([positions] * 3, axis=0)
            h = ctx.constrain(h, *model._tok_spec(ctx))
            if cfg.family == "ssm":
                h, _ = model._run_ssm_stack(params, h, ctx)
            elif cfg.family == "hybrid":
                h, _ = model._run_hybrid_stack(params, h, positions=positions, ctx=ctx)
            else:
                h, _ = model._run_decoder_stack(params, h, positions=positions, ctx=ctx)
        logits = model._head(params, h[:, -1:, :])[:, 0]
        return logits.astype(jnp.float32)

    return prefill_step


def make_serve_step(model: LM, ctx: MeshCtx):
    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch, ctx)

    return serve_step


def training_state_shapes(model: LM):
    ps = model.param_shapes()
    return ps, adamw_init_shapes(ps)


def training_state_specs(model: LM, ctx: MeshCtx):
    """(param *storage* specs, optimizer specs). Params are stored in the
    ZeRO (data-sharded) layout between steps; train_step gathers them to the
    compute layout once per step (see make_train_step)."""
    pspecs = model.param_specs(ctx)
    ospecs = adamw_specs(pspecs, model.param_shapes(), ctx)
    return ospecs["m"], ospecs
