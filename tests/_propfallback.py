"""Seeded-random fallback for ``hypothesis`` (satellite of ISSUE 1).

The tier-1 suite uses a small, fixed subset of hypothesis:

    @settings(max_examples=N, deadline=None)
    @given(st.integers(...), st.sampled_from(...), st.binary(...),
           st.lists(...), st.tuples(...))

When the real package is installed (see ``requirements-dev.txt``) the tests
import it unchanged and get true shrinking/coverage. When it is absent — the
default container has no ``hypothesis`` — this module provides API-compatible
decorators that run each property N times on values drawn from a
deterministically-seeded ``numpy`` RNG (seed derived from the test's qualified
name, so failures reproduce across runs and machines).

This is intentionally NOT a re-implementation of hypothesis: no shrinking, no
database, no assume/health checks. It exists so the suite *collects and
passes* on a clean checkout.
"""
from __future__ import annotations

import zlib
from types import SimpleNamespace
from typing import Any, Callable

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A draw rule: ``draw(rng) -> value`` (mirrors hypothesis' objects)."""

    def __init__(self, draw: Callable[[np.random.Generator], Any], label: str = "?"):
        self._draw = draw
        self.label = label

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SearchStrategy({self.label})"


def _integers(min_value: int = 0, max_value: int = 2**16) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})",
    )


def _binary(min_size: int = 0, max_size: int = 64) -> SearchStrategy:
    def draw(rng: np.random.Generator) -> bytes:
        n = int(rng.integers(min_size, max_size + 1))
        return rng.integers(0, 256, n, dtype=np.uint8).tobytes()

    return SearchStrategy(draw, f"binary({min_size}, {max_size})")


def _sampled_from(elements) -> SearchStrategy:
    pool = list(elements)
    return SearchStrategy(
        lambda rng: pool[int(rng.integers(0, len(pool)))],
        f"sampled_from({pool!r})",
    )


def _lists(elements: SearchStrategy, *, min_size: int = 0, max_size: int = 8) -> SearchStrategy:
    def draw(rng: np.random.Generator) -> list:
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]

    return SearchStrategy(draw, f"lists({elements.label})")


def _tuples(*parts: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(p.draw(rng) for p in parts),
        f"tuples({', '.join(p.label for p in parts)})",
    )


strategies = SimpleNamespace(
    integers=_integers,
    binary=_binary,
    sampled_from=_sampled_from,
    lists=_lists,
    tuples=_tuples,
)


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator factory: records ``max_examples`` for the ``given`` wrapper.

    Applied *outside* ``given`` (hypothesis' usual stacking), so it just tags
    the already-wrapped function.
    """

    def apply(fn):
        fn._propfallback_max_examples = max_examples
        return fn

    return apply


def given(*strategies_pos: SearchStrategy):
    """Run the test once per example with values drawn from the strategies."""

    def decorate(fn):
        # NOTE: no ``functools.wraps`` — that copies ``__wrapped__`` and pytest
        # would then introspect the original signature and demand fixtures for
        # the drawn parameters. The wrapper must look zero-argument.
        def wrapper():
            n = getattr(wrapper, "_propfallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            base_seed = zlib.crc32(fn.__qualname__.encode())
            for example in range(n):
                rng = np.random.default_rng((base_seed, example))
                drawn = tuple(s.draw(rng) for s in strategies_pos)
                try:
                    fn(*drawn)
                except Exception as exc:  # annotate with the failing example
                    raise AssertionError(
                        f"falsifying example #{example} for {fn.__qualname__}: "
                        f"args={drawn!r}"
                    ) from exc

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return decorate
