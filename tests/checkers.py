"""History checkers: atomicity (linearizability via tags), coverability
(Definitions 3/4), and fragmented-object connectivity (Lemma 13).

Because tags totally order writes, linearizability of a tagged R/W register
reduces to real-time tag monotonicity — checkable in O(n log n) over the
recorded virtual-time history (this is why we simulate: a live testbed can't
get these guarantees checked deterministically).
"""
from __future__ import annotations

from collections import defaultdict

from repro.core.tags import TAG0, OpRecord


def check_atomicity(history: list[OpRecord]) -> None:
    """Per object: (1) ops that finish before another starts never observe a
    *smaller* tag later (C1 at the op level); (2) every read's tag was
    produced by some chg-write or is t0 (C2); (3) chg-write tags are unique."""
    by_obj: dict[str, list[OpRecord]] = defaultdict(list)
    for r in history:
        if r.kind in ("read", "write") and r.tag is not None:
            by_obj[r.obj].append(r)
    for obj, ops in by_obj.items():
        ops.sort(key=lambda r: r.start)
        # (3) chg-write tag uniqueness (Lemma 6)
        wtags = [r.tag for r in ops if r.kind == "write" and r.flag == "chg"]
        assert len(wtags) == len(set(wtags)), f"{obj}: duplicate write tags"
        # (1) real-time tag monotonicity
        max_completed_tag = TAG0
        events = sorted(
            [(r.start, 1, r) for r in ops] + [(r.end, 0, r) for r in ops],
            key=lambda e: (e[0], e[1]),
        )
        for _t, is_start, r in events:
            if is_start:
                r.extra["_tag_floor"] = max_completed_tag
            else:
                floor = r.extra.get("_tag_floor", TAG0)
                assert r.tag >= floor, (
                    f"{obj}: op {r.kind}@{r.client} returned tag {r.tag} < "
                    f"floor {floor} (violates real-time order)"
                )
                if r.tag > max_completed_tag:
                    max_completed_tag = r.tag
        # (2) reads return written tags
        produced = set(wtags) | {TAG0}
        for r in ops:
            if r.kind == "read":
                assert r.tag in produced or any(
                    w.tag == r.tag for w in ops if w.kind == "write"
                ), f"{obj}: read returned unwritten tag {r.tag}"


def check_coverability(history: list[OpRecord]) -> None:
    """Validity + consolidation/continuity/evolution over chg-writes."""
    by_obj: dict[str, list[OpRecord]] = defaultdict(list)
    for r in history:
        if r.kind == "write":
            by_obj[r.obj].append(r)
    for obj, ops in by_obj.items():
        chg = sorted([r for r in ops if r.flag == "chg"], key=lambda r: r.tag)
        # validity: versions strictly grow along the chain & are unique
        tags = [r.tag for r in chg]
        assert tags == sorted(set(tags)), f"{obj}: versions not strictly ordered"
        # consolidation: real-time precedence implies version order
        for a in chg:
            for b in chg:
                if a.end < b.start:
                    assert a.tag < b.tag, (
                        f"{obj}: consolidation violated {a.tag} !< {b.tag}"
                    )
        # continuity/evolution: timestamps increase by exactly 1 along the
        # winning chain (our tags are (ts, wid) with ts+1 per chg write)
        ts_list = sorted({t[0] for t in tags})
        assert ts_list == list(range(ts_list[0], ts_list[0] + len(ts_list))) if ts_list else True, (
            f"{obj}: version timestamps have gaps: {ts_list}"
        )


def check_unchg_is_read(history: list[OpRecord]) -> None:
    """A write that reports unchg must return a tag some chg write produced
    (the write became a read — §II fragmented coverability)."""
    by_obj: dict[str, list[OpRecord]] = defaultdict(list)
    for r in history:
        if r.kind == "write":
            by_obj[r.obj].append(r)
    for obj, ops in by_obj.items():
        produced = {r.tag for r in ops if r.flag == "chg"} | {TAG0}
        for r in ops:
            if r.flag == "unchg":
                assert r.tag in produced, (
                    f"{obj}: unchg write returned unknown tag {r.tag}"
                )


def check_connected_reads(history: list[OpRecord]) -> None:
    """fm-read must always assemble a connected chain: recorded as n_blocks
    >= 0 and no read aborted mid-chain (FM records only complete walks)."""
    for r in history:
        if r.kind == "fm-read":
            assert "n_blocks" in r.extra


def check_all(history: list[OpRecord]) -> None:
    check_atomicity(history)
    check_coverability(history)
    check_unchg_is_read(history)
    check_connected_reads(history)
