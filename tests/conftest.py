"""Tier-1 end-of-test stuck-op leak assertion (ISSUE 10 satellite).

A quorum-mode fan-out whose replies are lost to crashes or drops used to
strand its ``OpFuture`` forever with no diagnostic. ``Network.stuck_ops()``
now surfaces stranded rounds; this autouse fixture fails any test that ends
with a drained event queue AND a still-waiting quorum round — the silent-leak
signature — unless the test opts out with ``@pytest.mark.allow_stuck``
(tests that deliberately wedge a quorum to pin degraded-mode behavior).

Networks are tracked via a weak registry hooked into ``Network.__init__``;
tracking adds one list append per Network and touches nothing the simulator
schedules, so traces are unaffected.
"""
from __future__ import annotations

import weakref

import pytest

from repro.net import sim as _sim

_tracked: list[weakref.ref] = []

_orig_init = _sim.Network.__init__


def _tracking_init(self, *args, **kw):
    _orig_init(self, *args, **kw)
    _tracked.append(weakref.ref(self))


_sim.Network.__init__ = _tracking_init


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "allow_stuck: test deliberately strands a quorum round "
        "(crash/drop beyond the fault budget); skip the end-of-test "
        "stuck-op leak assertion",
    )


@pytest.fixture(autouse=True)
def _no_stuck_ops(request):
    _tracked.clear()
    yield
    if request.node.get_closest_marker("allow_stuck") is not None:
        return
    for ref in _tracked:
        net = ref()
        if net is None or net._events:
            continue  # gone, or traffic still pending (test stopped early)
        stuck = net.stuck_ops()
        if stuck:
            pytest.fail(
                f"test leaked {len(stuck)} forever-pending quorum round(s) "
                f"on a quiesced network: {stuck!r} — crash/drop beyond the "
                "fault budget without a RetryPolicy? Mark with "
                "@pytest.mark.allow_stuck if deliberate.",
                pytrace=False,
            )
