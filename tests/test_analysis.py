"""Protocol invariant analyzer (ISSUE 8): lint pack + runtime sanitizer.

Three layers:

* the AST lint engine and each rule of the pack, exercised on synthetic
  sources (and a fake mini-repo for the cross-file registry-drift rule);
* the gate itself: ``collect_findings()`` over THIS repo must be empty —
  the same check ``make analyze`` runs in CI;
* the runtime sanitizer: clean over a mixed zipfian + crash-storm workload
  (with the trace bit-identical to an unsanitized run), and loudly failing
  on deliberately seeded violations — a quorum off-by-one and a
  tracked-map-bypassing tag regression — while forgiving the tracked-map
  fault injection the tier-1 suites perform on purpose.
"""
import textwrap

import pytest

from repro.analysis.astlint import Finding, run_rules, waived
from repro.analysis.invariants import (
    MODULE_RULES,
    REPO_RULES,
    AssertBanRule,
    DeterminismRule,
    RegistryDriftRule,
    SetIterationRule,
    StateMapBypassRule,
    collect_findings,
)
from repro.analysis.linearize import LinearizabilityError, check_tag_linearizable
from repro.analysis.sanitizer import ProtocolSanitizer, SanitizerError
from repro.core.store import DSS, DSSParams
from repro.core.tags import TAG0, Config, OpRecord
from repro.core.workload import CrashStorm, WorkloadGen, WorkloadSpec


# --------------------------------------------------------------- lint engine
def _lint(tmp_path, relpath, source, rules):
    # fresh root per call: run_rules walks the whole tree
    root = tmp_path / f"r{len(list(tmp_path.iterdir()))}"
    p = root / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return run_rules(root, rules)


def test_assert_ban_flags_and_scopes(tmp_path):
    src = "def f(x):\n    assert x > 0\n    return x\n"
    found = _lint(tmp_path, "core/mod.py", src, [AssertBanRule()])
    assert [(f.rule, f.line) for f in found] == [("assert-ban", 2)]
    # out of scope: same source under tools/ is not protocol code
    assert _lint(tmp_path, "tools/mod.py", src, [AssertBanRule()]) == []


def test_waiver_is_per_line_and_per_rule(tmp_path):
    src = (
        "def f(x):\n"
        "    assert x  # protocol-lint: allow-assert-ban (test scaffold)\n"
        "    assert x\n"
    )
    found = _lint(tmp_path, "core/mod.py", src, [AssertBanRule()])
    assert [f.line for f in found] == [3]  # line 2 waived, line 3 not
    assert waived(["x  # protocol-lint: allow-r1"], 1, "r1")
    assert not waived(["x  # protocol-lint: allow-r1"], 1, "r2")


def test_stale_waiver_flagged(tmp_path):
    """ISSUE 9 satellite: a waiver whose rule no longer fires on its line
    is itself a finding — a live waiver stays silent, a stale one (or one
    naming an unknown rule) is reported."""
    src = (
        "def f(x):\n"
        "    assert x  # protocol-lint: allow-assert-ban (live: suppresses)\n"
        "    y = 1  # protocol-lint: allow-assert-ban (stale: nothing fires)\n"
        "    z = 2  # protocol-lint: allow-not-a-rule (unknown rule)\n"
    )
    found = _lint(tmp_path, "core/mod.py", src, [AssertBanRule()])
    assert [(f.rule, f.line) for f in found] == [
        ("stale-waiver", 3), ("stale-waiver", 4),
    ]


def test_stale_waiver_ignores_docstring_mentions(tmp_path):
    """Marker text inside a string/docstring is documentation, not a
    waiver — the scan tokenizes and only counts COMMENT tokens."""
    src = (
        '"""Example: use  # protocol-lint: allow-assert-ban  to waive."""\n'
        "def f(x):\n"
        "    return x\n"
    )
    assert _lint(tmp_path, "core/mod.py", src, [AssertBanRule()]) == []


def test_stale_waiver_caught_outside_rule_scope(tmp_path):
    """A waiver in a file no rule even applies to can never suppress
    anything — flagged too."""
    src = "x = 1  # protocol-lint: allow-assert-ban (out of scope)\n"
    found = _lint(tmp_path, "tools/mod.py", src, [AssertBanRule()])
    assert [(f.rule, f.line) for f in found] == [("stale-waiver", 1)]


def test_determinism_rule(tmp_path):
    src = """
        import time
        from random import random
        import numpy as np

        def f(rng):
            a = np.random.random()          # legacy global: flagged
            b = np.random.default_rng(0)    # seeded Generator: allowed
            return a, b, rng.uniform()
    """
    found = _lint(tmp_path, "net/mod.py", src, [DeterminismRule()])
    msgs = [f.message for f in found]
    assert len(found) == 3
    assert any("'time'" in m for m in msgs)
    assert any("'random'" in m for m in msgs)
    assert any("np.random.random" in m for m in msgs)


def test_set_iteration_rule(tmp_path):
    src = """
        def f(items, net):
            s = {x for x in items}
            for x in s:                     # flagged: tracked set name
                pass
            out = [y for y in set(items)]   # flagged: set() in generator
            t = tuple({1, 2})               # flagged: tuple() over a set
            net.rpc(dests=s)                # flagged: dests= from a set
            for x in sorted(s):             # sanctioned idiom
                pass
            ok = 1 in s                     # membership: fine
            return out, t, ok
    """
    found = _lint(tmp_path, "core/mod.py", src, [SetIterationRule()])
    assert len(found) == 4
    # a name REASSIGNED to a non-set is not tracked (no false positive)
    src2 = "def g(a):\n    s = {1}\n    s = sorted(s)\n    return [x for x in s]\n"
    assert _lint(tmp_path, "core/mod2.py", src2, [SetIterationRule()]) == []


def test_statemap_bypass_rule(tmp_path):
    src = """
        class StorageServer:
            def __init__(self):
                self.ec = {}                # allowed nowhere but server.py

            def reset(self):
                self.ec = {}                # flagged: rebinding tracked map
                self.abd = dict()           # flagged
                self.ec[("o", 0)] = {}      # in-place write: fine
    """
    # under the real path the __init__ exemption applies
    found = _lint(tmp_path, "core/server.py", src, [StateMapBypassRule()])
    assert [f.line for f in found] == [7, 8]
    # in any OTHER module even __init__ may not rebind server maps
    found2 = _lint(tmp_path, "core/other.py", src, [StateMapBypassRule()])
    assert [f.line for f in found2] == [4, 7, 8]


# ------------------------------------------------------- registry drift rule
_MINI_SERVER = """
class StorageServer:
    _READ_ONLY = {"get": lambda m: (m[1],)}
    _DISPATCH = {"get": None, "put": None}

    def _h_get(self, sender, msg):
        return ("val", 1)

    def _h_put(self, sender, msg):
        return ("ack",)
"""
_MINI_GATEWAY = """
class GossipListener:
    def handle(self, sender, msg):
        op = msg[0]
        if op == "gossip-configs":
            return ("gossip-ack", 0)
        raise ValueError(op)
"""


def _mini_repo(tmp_path, codec_src):
    (tmp_path / "core").mkdir()
    (tmp_path / "net").mkdir()
    (tmp_path / "core" / "server.py").write_text(_MINI_SERVER)
    (tmp_path / "core" / "gateway.py").write_text(_MINI_GATEWAY)
    (tmp_path / "net" / "codec.py").write_text(textwrap.dedent(codec_src))
    return list(RegistryDriftRule().check_repo(tmp_path))


def test_registry_drift_clean(tmp_path):
    assert _mini_repo(tmp_path, """
        MESSAGE_TYPES = frozenset({"get", "put"})
        REPLY_TYPES = frozenset({"val", "ack"})
        GOSSIP_TYPES = frozenset({"gossip-configs"})
        GOSSIP_REPLY_TYPES = frozenset({"gossip-ack"})
    """) == []


def test_registry_drift_both_directions(tmp_path):
    found = _mini_repo(tmp_path, """
        MESSAGE_TYPES = frozenset({"get", "stale-op"})
        REPLY_TYPES = frozenset({"val", "ack", "ghost"})
        GOSSIP_TYPES = frozenset()
        GOSSIP_REPLY_TYPES = frozenset({"gossip-ack"})
    """)
    msgs = "\n".join(f.message for f in found)
    assert "server handles 'put'" in msgs          # handler w/o registry
    assert "'stale-op'" in msgs                    # registry w/o handler
    assert "'ghost'" in msgs                       # reply registry w/o tag
    assert "'gossip-configs'" in msgs              # gossip asymmetry


def test_registry_drift_missing_registry(tmp_path):
    found = _mini_repo(tmp_path, "MESSAGE_TYPES = frozenset({'get', 'put'})\n")
    assert any("REPLY_TYPES missing" in f.message for f in found)


def test_finding_str_format():
    f = Finding("r", "core/x.py", 7, "boom")
    assert str(f) == "core/x.py:7: [r] boom"


# --------------------------------------------------------------- the CI gate
def test_repo_is_lint_clean():
    """The gate itself: the rule pack over this repo's ``src/repro`` must be
    empty — identical to what ``make analyze`` enforces in CI."""
    findings = collect_findings()
    assert findings == [], "\n".join(str(f) for f in findings)
    # 4 module rules + 1 repo rule in the pack, plus the engine-level
    # stale-waiver check (ISSUE 9) which collect_findings always applies —
    # an empty result also proves every waiver in the repo is live.
    assert len(MODULE_RULES) == 4 and len(REPO_RULES) == 1


# ---------------------------------------------------------- linearize (unit)
def _rec(kind, obj, client, start, end, tag, flag="chg"):
    return OpRecord(kind=kind, obj=obj, client=client, start=start, end=end,
                    tag=tag, flag=flag)


def test_linearize_accepts_legal_history():
    h = [
        _rec("write", "o", "w1", 0.0, 1.0, (1, "w1")),
        _rec("read", "o", "r1", 1.5, 2.0, (1, "w1")),
        _rec("write", "o", "w2", 1.8, 2.5, (2, "w2")),
        _rec("read", "o", "r2", 3.0, 3.5, (2, "w2")),
        _rec("recon", "o", "c", 0.0, 4.0, (2, "w2")),  # non-register: skipped
    ]
    assert check_tag_linearizable(h) == {"objects": 1, "ops": 4}


def test_linearize_rejects_stale_read():
    h = [
        _rec("write", "o", "w1", 0.0, 1.0, (1, "w1")),
        _rec("write", "o", "w2", 1.5, 2.0, (2, "w2")),
        _rec("read", "o", "r1", 2.5, 3.0, (1, "w1")),  # after w2 completed
    ]
    with pytest.raises(LinearizabilityError, match="real-time order"):
        check_tag_linearizable(h)


def test_linearize_rejects_duplicate_write_tags():
    h = [
        _rec("write", "o", "w1", 0.0, 1.0, (1, "x")),
        _rec("write", "o", "w2", 2.0, 3.0, (1, "x")),
    ]
    with pytest.raises(LinearizabilityError, match="duplicate"):
        check_tag_linearizable(h)


def test_linearize_reads_from_strictness():
    h = [
        _rec("write", "o", "w1", 0.0, 1.0, (1, "w1")),
        _rec("read", "o", "r1", 1.5, 2.0, (2, "crashed")),  # unrecorded write
    ]
    with pytest.raises(LinearizabilityError, match="no recorded write"):
        check_tag_linearizable(h, strict_reads=True)
    # under crash storms the producer may have died before recording itself
    assert check_tag_linearizable(h, strict_reads=False)["ops"] == 2


def test_linearize_concurrent_ops_any_order():
    # reads overlapping each other AND an in-flight write may resolve in
    # either tag order (linearization: w1, r2, w2, r1)
    h = [
        _rec("write", "o", "w1", 0.0, 1.0, (1, "w1")),
        _rec("write", "o", "w2", 0.5, 3.0, (2, "w2")),  # still in flight
        _rec("read", "o", "r1", 1.6, 2.6, (2, "w2")),
        _rec("read", "o", "r2", 1.7, 2.5, (1, "w1")),  # overlaps r1: legal
    ]
    assert check_tag_linearizable(h)["ops"] == 4


# ------------------------------------------------------------ sanitizer: unit
def test_sanitizer_quorum_intersection_unit():
    class _Rpc:
        def __init__(self, dests, msg):
            self.dests, self.msg, self.per_dest = dests, msg, None

    san = ProtocolSanitizer()
    five = tuple(f"s{i}" for i in range(5))
    san.on_rpc(_Rpc(five, ("abd-get", "o", 0, None)), 3)   # majority: ok
    with pytest.raises(SanitizerError, match="majority"):
        san.on_rpc(_Rpc(five, ("abd-get", "o", 0, None)), 2)
    # EC quorum ceil((n+k)/2): n=5, k=3 -> 4; majority alone is too weak
    san.register_config(Config("c1", five, dap="ec_opt", k=3, delta=8))
    with pytest.raises(SanitizerError, match=r"ceil"):
        san.on_rpc(_Rpc(five, ("ec-query", "o", 0, None)), 3)
    san.on_rpc(_Rpc(five, ("ec-query", "o", 0, None)), 4)  # ok
    # alive-addressed fan-outs are not quorum rounds
    san.on_rpc(_Rpc(five, ("margin-batch", ("o",), 0)), None)
    with pytest.raises(SanitizerError, match="unknown message"):
        san.on_rpc(_Rpc(five, ("not-a-real-op", 1)), 3)


def test_sanitizer_tag_monotonicity_unit():
    san = ProtocolSanitizer()
    t1, t2 = (1, "w"), (2, "w")
    san.on_reply("s0", ("abd-get", "o", 0, None), ("abd-val", t2, b"v"))
    with pytest.raises(SanitizerError, match="monotonicity"):
        san.on_reply("s0", ("abd-get", "o", 0, None), ("abd-val", t1, b"v"))
    # forget (external fault injection) resets the floor
    san.forget("s0", "o")
    san.on_reply("s0", ("abd-get", "o", 0, None), ("abd-val", t1, b"v"))
    assert san.forgets == 1
    with pytest.raises(SanitizerError, match="unknown reply"):
        san.on_reply("s0", ("abd-get", "o", 0, None), ("not-a-reply", 1))


def test_sanitizer_finalized_next_config_is_sticky():
    san = ProtocolSanitizer()
    cfg1 = Config("c1", ("s0",), dap="abd", k=1, delta=8)
    cfg2 = Config("c2", ("s0",), dap="abd", k=1, delta=8)
    san.on_reply("s0", ("read-next", "o", 0), ("next-c", (cfg1, "F")))
    with pytest.raises(SanitizerError, match="regressed"):
        san.on_reply("s0", ("read-next", "o", 0), ("next-c", (cfg2, "P")))
    with pytest.raises(SanitizerError, match="uniqueness"):
        san.on_reply("s0", ("write-next", "o", 0, cfg2, "F"), ("ack",))


# ------------------------------------------------- sanitizer: live (seeded)
def test_sanitized_workload_clean_and_trace_identical():
    """Mixed zipfian reads/writes + a crash storm, EC fragmented: sanitizer
    stays silent, the post-run Wing–Gong pass holds, and the virtual-time
    trace is bit-identical to the unsanitized run (pure-observer contract)."""
    spec = WorkloadSpec(sessions=120, files=12, file_size=512,
                        read_fraction=0.8,
                        storms=(CrashStorm(at=0.05, frac=0.25, duration=0.03),))
    rep = WorkloadGen(spec, seed=7).run(
        DSS(DSSParams(algorithm="coaresecf", sanitize=True, seed=7))
    )
    base = WorkloadGen(spec, seed=7).run(
        DSS(DSSParams(algorithm="coaresecf", seed=7))
    )
    assert rep["sanitizer"]["checks"] > 1000
    assert rep["sanitizer"]["linearized_ops"] > 0
    for key in ("rpc_rounds", "msg_count", "bytes_sent", "events",
                "virtual_makespan", "ops_done", "ops_failed"):
        assert rep[key] == base[key], key


def test_sanitizer_catches_seeded_quorum_off_by_one(monkeypatch):
    """The acceptance scenario: shrink ``Config.quorum`` below majority and
    the very first EC fan-out must die with SanitizerError."""
    monkeypatch.setattr(Config, "quorum", lambda self: len(self.servers) // 2)
    dss = DSS(DSSParams(algorithm="coaresecf", sanitize=True))
    sess = dss.session("c1")
    sess.write("f", b"x" * 256)
    with pytest.raises(SanitizerError, match="majority|quorum"):
        dss.run()


@pytest.mark.allow_stuck
def test_sanitizer_catches_bypassing_tag_regression():
    """A buggy server losing its register WITHOUT the tracked-map
    invalidation (dict.__setitem__ bypass — exactly what statemap-bypass
    lints against) is caught on the next recomputed reply."""
    dss = DSS(DSSParams(algorithm="coaresabd", sanitize=True))
    sess = dss.session("c1")
    sess.write("f", b"v1")
    dss.run()
    sess.read("f")
    dss.run()  # sanitizer has proven every server's tag
    srv = dss.net.servers["s0"]
    dict.__setitem__(srv.abd, ("f", 0), (TAG0, None))
    dict.clear(srv._rcache)  # buggy server recomputes instead of caching
    dict.clear(srv._rkeys)
    sess.read("f")
    with pytest.raises(SanitizerError, match="monotonicity"):
        dss.run()


def test_sanitizer_forgives_tracked_fault_injection():
    """The SAME state surgery through the tracked maps (what the tier-1
    suites do: ``del lst[tag]``, ``wipe_servers``) fires the
    external-mutation observer and is NOT a violation."""
    dss = DSS(DSSParams(algorithm="coaresabd", sanitize=True))
    sess = dss.session("c1")
    sess.write("f", b"v1")
    dss.run()
    sess.read("f")
    dss.run()
    srv = dss.net.servers["s0"]
    srv.abd[("f", 0)] = (TAG0, None)  # tracked: invalidates + forgets
    sess.read("f")
    dss.run()
    assert dss.net.sanitizer.forgets >= 1
    rep = dss.net.sanitizer.report()
    assert rep["checks"] > 0 and rep["known_server_sets"] == 0  # abd-only


def test_sanitized_recon_and_gateway_paths():
    """Reconfiguration (ABD -> EC, fresh servers) and the gateway gossip
    tier under the sanitizer: new configs are registered with the EC-quorum
    registry and the run stays clean end to end."""
    dss = DSS(DSSParams(algorithm="coaresecf", n_servers=5, parity_m=1,
                        sanitize=True))
    gw = dss.gateway("gw")
    s1, s2 = gw.session("c1"), gw.session("c2")
    s1.write("f", b"a" * 512)
    s2.write("g", b"b" * 512)
    dss.run()
    target = dss.make_config(n_servers=5, parity_m=2, fresh_servers=True)
    s1.recon("f", target)
    dss.run()
    s2.read("f")
    s1.read("g")
    dss.run()
    gw.stop()
    dss.run()
    san = dss.net.sanitizer
    assert san.known_k[frozenset(target.servers)] == target.k
    assert dss.check_history()["ops"] >= 4
