"""Session/future client API (ISSUE 3 tentpole): cross-file coalescing,
uniform OpStats, multi-client Workload runs under the linearizability/
coverability checkers, the reliability stat, margin-ordered repair
scheduling, daemon auto-retarget, and the ``created`` bugfix — plus the
ISSUE 4 scheduler/accounting fixes (drain re-arm, cross-network gather,
recon payloads, the ``_groups`` invariants)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seeded fallback shim — see tests/_propfallback.py
    from _propfallback import given, settings
    from _propfallback import strategies as st

from checkers import check_all
from repro.core import DSS, DSSParams, TAG0, Workload, gather
from repro.core.fragment import genesis_id
from repro.net.sim import Sleep


def _blob(seed, size):
    return np.random.default_rng(seed).integers(0, 256, size, dtype=np.uint8).tobytes()


def _dss(alg="coaresecf", n=6, m=2, seed=0, **kw):
    return DSS(DSSParams(algorithm=alg, n_servers=n, parity_m=m, seed=seed,
                         min_block=256, avg_block=512, max_block=2048, **kw))


# ------------------------------------------------------------ basic session
def test_session_write_read_roundtrip_with_stats():
    dss = _dss(indexed=True)
    docs = {f"f{i}": _blob(i, 3000 + 100 * i) for i in range(4)}
    w = dss.session("w")
    wfuts = [w.write(f, d) for f, d in docs.items()]
    wres = gather(*wfuts)
    assert all(s["success"] for s in wres)
    r = dss.session("r")
    rfuts = [r.read(f) for f in docs]
    got = gather(*rfuts)
    assert got == list(docs.values())
    for fut in wfuts + rfuts:
        st = fut.stats
        assert st is not None and st.batched_with == 4
        assert st.rounds > 0 and st.msgs > 0 and st.bytes > 0
        assert st.latency > 0 and st.blocks >= 1
    check_all(dss.history)


def test_session_coalesces_cross_file_rounds():
    """The acceptance bar: an F-file read/write fan-out through one Session
    costs the SAME quorum rounds as a 2-file one (flat in F), while the
    legacy one-generator-per-file pattern scales O(F)."""
    rounds = {}
    legacy_rounds = {}
    for F in (2, 8):
        dss = _dss(indexed=True, seed=7)
        docs = {f"f{i}": _blob(10 + i, 4000) for i in range(F)}
        boot = dss.session("boot")
        gather(*[boot.write(f, d) for f, d in docs.items()])
        # session fan-out: all F reads coalesce into one batched pass
        r = dss.session("r")
        r0 = dss.net.client_totals("r")[0]
        assert gather(*[r.read(f) for f in docs]) == list(docs.values())
        rounds[F] = dss.net.client_totals("r")[0] - r0
        # legacy fan-out: F independent generator ops (deprecation shim)
        h = dss.client("x")
        x0 = dss.net.client_totals("x")[0]
        futs = [dss.net.spawn(h.read(f), client="x") for f in docs]
        dss.net.run()
        assert all(f.done for f in futs)
        legacy_rounds[F] = dss.net.client_totals("x")[0] - x0
    assert rounds[8] == rounds[2], rounds          # flat in F
    assert legacy_rounds[8] >= 3 * legacy_rounds[2] / 2  # legacy scales up
    assert rounds[8] < legacy_rounds[8] / 2, (rounds, legacy_rounds)


def test_session_program_order_within_client():
    """write(f) then read(f) submitted in one window: the read must observe
    the write (groups keep program order across kind changes)."""
    dss = _dss(indexed=True)
    s = dss.session("s")
    doc = _blob(3, 5000)
    wfut = s.write("f", doc)
    rfut = s.read("f")
    assert rfut.result() == doc
    assert wfut.stats.latency > 0


def test_session_submit_raw_generator():
    dss = _dss(indexed=True)
    s = dss.session("s")
    doc = _blob(4, 2000)

    def loop():
        st = yield from s.handle.update("f", doc)
        got = yield from s.handle.read("f")
        yield Sleep(1e-4)
        return st["success"] and got == doc

    fut = s.submit(loop(), kind="rmw", fid="f")
    assert fut.result() is True
    assert fut.stats.rounds > 0 and fut.stats.batched_with == 1


def test_session_error_delivered_via_future():
    dss = _dss(alg="coabdf", indexed=True)  # static: recon unsupported
    s = dss.session("s")
    s.write("f", b"x" * 500).result()
    fut = s.recon("f", dss.make_config())
    with pytest.raises(NotImplementedError):
        fut.result()


# ------------------------------------------------------- multi-client mixes
def test_workload_mixed_ops_checkers():
    """≥8 files, 3 writers / 2 readers / 1 reconfigurer, mixed read / write /
    recon through the Workload combinator; histories must stay atomic and
    coverable and contents must match the last winning writes."""
    dss = _dss(n=7, m=3, seed=21, indexed=True)
    files = [f"f{i}" for i in range(8)]
    docs = {f: _blob(30 + i, 2500 + 137 * i) for i, f in enumerate(files)}
    boot = Workload(dss)
    for f, d in docs.items():
        boot.write("boot", f, d)
    assert all(s["success"] for s in boot.run())

    wl = Workload(dss)
    edits = {}
    for i, f in enumerate(files):
        cid = f"w{i % 3}"
        edited = bytearray(docs[f])
        edited[i * 11 % len(edited)] ^= 0xFF
        edits[f] = bytes(edited)
        wl.write(cid, f, edits[f])
        wl.read(f"r{i % 2}", f)
    cfg1 = dss.make_config(n_servers=7)
    for f in files[:3]:
        wl.recon("admin", f, cfg1)
    results = wl.run()
    assert len(results) == 8 + 8 + 3
    # quiesce any recon-spawned repair traffic before final verification
    dss.net.run()
    final = dss.session("check")
    got = gather(*[final.read(f) for f in files])
    for f, content in zip(files, got):
        assert content in (docs[f], edits[f]), f"{f}: unknown content"
        assert gather(*[final.read(f)])[0] == content or True
    check_all(dss.history)


def test_workload_concurrent_sessions_interleave():
    """Two sessions' fan-outs run concurrently on the virtual-time net and
    per-client OpStats stay separated."""
    dss = _dss(indexed=True, seed=5)
    docs = {f"f{i}": _blob(50 + i, 3000) for i in range(6)}
    boot = dss.session("boot")
    gather(*[boot.write(f, d) for f, d in docs.items()])
    a, b = dss.session("a"), dss.session("b")
    fa = [a.read(f) for f in list(docs)[:3]]
    fb = [b.read(f) for f in list(docs)[3:]]
    got = gather(*(fa + fb))
    assert got == list(docs.values())
    assert all(f.stats.batched_with == 3 for f in fa + fb)
    ra, rb = dss.net.client_totals("a"), dss.net.client_totals("b")
    assert ra[0] > 0 and rb[0] > 0
    assert ra[0] + rb[0] <= 2 * max(ra[0], rb[0])


# ------------------------------------------------------------ created bugfix
@pytest.mark.parametrize("alg", ["coaresec", "coabd"])
def test_created_reported_on_first_whole_object_write(alg):
    """Bugfix: the non-fragmented path used to hardwire ``created: 0``."""
    dss = _dss(alg=alg, n=5, m=1)
    s = dss.session("w")
    st1 = s.write("f", b"first").result()
    assert st1["created"] == 1 and st1["written"] == 1, st1
    st2 = s.write("f", b"second").result()
    assert st2["created"] == 0 and st2["written"] == 1, st2
    # legacy handle path reports the same
    h = dss.client("w2")
    st3 = dss.net.run_op(h.update("g", b"x"), client="w2")
    assert st3["created"] == 1, st3


# ------------------------------------------------------------------- stat
def test_session_stat_margin_tracks_crashes():
    dss = _dss(n=6, m=2, indexed=True, seed=9)  # k=4
    s = dss.session("w")
    s.write("f", _blob(60, 6000)).result()
    dss.net.run()  # let stragglers land so every server holds its fragment
    st0 = s.stat("f").result()
    assert st0["margin"] == 6 - 4 and st0["blocks"] >= 2, st0
    assert st0["tag"] > TAG0
    dss.crash_servers(["s0"])
    st1 = s.stat("f").result()
    assert st1["margin"] == 5 - 4, st1
    assert genesis_id("f") in st1["per_object"]


def test_stat_whole_object_and_abd():
    dss = _dss(alg="coaresabd", n=5, m=1)
    s = dss.session("w")
    s.write("f", b"v" * 200).result()
    dss.net.run()
    st = s.stat("f").result()
    assert st["blocks"] == 1 and st["margin"] == 5 - 1  # all replicas hold it


# --------------------------------------------- margin-ordered repair daemon
def test_repair_daemon_prioritizes_smallest_margin():
    """Two objects degraded unevenly: the daemon (1 obj/cycle) must repair
    the most endangered one FIRST (D-Rex ordering), not round-robin order."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=31))
    w = dss.client("w")
    dss.net.run_op(w.update("a", _blob(70, 2000)), client="w")
    dss.net.run_op(w.update("b", _blob(71, 2000)), client="w")
    dss.net.run()

    def drop(obj, sids):
        for sid in sids:
            lst = dss.net.servers[sid].ec[(obj, 0)]
            t_star = max(t for t, e in lst.items() if e is not None)
            del lst[t_star]

    drop("a", ["s0", "s1"])   # margin 4 - 2 = 2  (more endangered)
    drop("b", ["s5"])          # margin 5 - 2 = 3
    daemon = dss.start_repair_daemon(period=0.01, objs_per_cycle=1, max_cycles=1)
    dss.net.run()
    repaired = [r.obj for r in dss.history if r.kind == "repair"]
    assert repaired == ["a"], repaired          # worst margin first
    assert daemon.stats["probed"] >= 2
    daemon2 = dss.start_repair_daemon(period=0.01, objs_per_cycle=4,
                                      max_cycles=2, client_id="repaird2")
    dss.net.run()
    # everything healthy now: later cycles probe but push nothing
    assert daemon2.stats["pushed"] == daemon2.stats["applied"]
    for obj in ("a", "b"):
        for sid in dss.net.alive():
            lst = dss.net.servers[sid].ec[(obj, 0)]
            assert max(t for t, e in lst.items() if e is not None) > TAG0


def test_repair_daemon_round_robin_ablation_still_works():
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=33))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(72, 1500)), client="w")
    dss.crash_servers(["s0"])
    dss.net.run_op(w.update("f", _blob(73, 1500)), client="w")
    dss.recover_servers(["s0"])
    dss.start_repair_daemon(period=0.01, objs_per_cycle=2, max_cycles=3,
                            order="rr", auto_retarget=False)
    dss.net.run()
    t_star = max(
        t for t, e in dss.net.servers["s1"].ec[("f", 0)].items() if e is not None
    )
    assert dss.net.servers["s0"].ec[("f", 0)].get(t_star) is not None


def test_repair_daemon_auto_retargets_after_recon():
    """The daemon follows a reconfiguration it observes (recon-finalization
    callback) without anyone calling ``retarget`` — and heals a server of
    the NEW configuration that missed the transfer."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=35,
                        recon_repair=False))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(74, 3000)), client="w")
    daemon = dss.start_repair_daemon(period=0.02, objs_per_cycle=2)
    assert daemon.cfg_idx == 0
    dss.crash_servers(["s5"])
    cfg1 = dss.make_config()  # same server set, new configuration index 1
    g = dss.client("g")
    fut = dss.net.spawn(g.recon("f", cfg1), client="g")
    dss.net.schedule(0.05, lambda: dss.net.recover("s5"))
    dss.net.run(until=dss.net.now + 0.5)
    assert fut.done
    assert daemon.cfg_idx == 1 and daemon.config.cfg_id == cfg1.cfg_id
    assert daemon.stats["retargets"] == 1
    dss.net.run(until=dss.net.now + 0.5)
    dss.stop_repair_daemon()
    dss.net.run()
    t_star = max(
        t for t, e in dss.net.servers["s0"].ec[("f", 1)].items() if e is not None
    )
    assert t_star > TAG0
    assert dss.net.servers["s5"].ec[("f", 1)].get(t_star) is not None, (
        "auto-retargeted daemon must heal the new configuration"
    )
    check_all(dss.history)


# ------------------------------------------------------ review regressions
def _legacy_genesis(dss, w, fid):
    """Rewrite a file's genesis to the pre-unification raw-count schema."""
    from repro.core.fragment import decode_block_value, encode_block_value

    g = genesis_id(fid)
    wdsm = w.fm.dsm if hasattr(w, "fm") else w.handle.fm.dsm
    _t, graw = dss.net.run_op(wdsm.cvr_read(g), client="w")
    head, _meta = decode_block_value(graw)
    legacy = encode_block_value(head, (99).to_bytes(4, "big"))
    (_tag, _v), flag = dss.net.run_op(wdsm.cvr_write(g, legacy), client="w")
    assert flag == "chg"


@pytest.mark.parametrize("via_batch", [False, True])
def test_indexed_update_upgrades_legacy_genesis(via_batch):
    """Regression: an indexed update of a legacy count-only-genesis file that
    keeps the block index UNCHANGED must still upgrade the genesis to the
    indexed schema — its data blocks are rewritten with ptr=None, so leaving
    the legacy genesis in place would sever the chain (silent truncation)."""
    dss = _dss(indexed=False, seed=51)
    w = dss.client("w")
    blob = _blob(80, 16_000)
    assert dss.net.run_op(w.update("f", blob), client="w")["success"]
    _legacy_genesis(dss, w, "f")
    # in-place one-byte flip in the middle of a block: CDC boundaries (and
    # hence block ids / the index) stay identical
    edit = bytearray(blob)
    edit[8_000] ^= 0xFF
    edit = bytes(edit)
    dss2 = dss  # same store, new INDEXED client
    from repro.core.fragment import FragmentationModule
    from repro.core.coares import CoAresClient

    dsm = CoAresClient(dss2.net, "iw", dss2.c0, history=dss2.history)
    fm = FragmentationModule(dss2.net, dsm, min_block=256, avg_block=512,
                             max_block=2048, history=dss2.history, indexed=True)
    if via_batch:
        stats = dss2.net.run_op(fm.fm_update_batch({"f": edit}), client="iw")["f"]
    else:
        stats = dss2.net.run_op(fm.fm_update("f", edit), client="iw")
    assert stats["success"]
    # the genesis must now carry the INDEXED schema (upgraded), so indexed
    # readers — single-file and batched — see the full edited content
    # (indexed writes null block pointers, so a leftover legacy genesis
    # would force the walk fallback and silently truncate the read)
    rfm = FragmentationModule(
        dss2.net, CoAresClient(dss2.net, "ri", dss2.c0, history=dss2.history),
        min_block=256, avg_block=512, max_block=2048,
        history=dss2.history, indexed=True,
    )
    got, blocks = dss2.net.run_op(rfm.fm_read("f"), client="ri")
    assert got == edit, "legacy-genesis update truncated the file"
    assert all(nxt is None for _b, nxt, _d in blocks) or len(blocks) > 1
    s2 = dss2.session("ri2")
    s2.handle.fm.indexed = True
    assert s2.read("f").result() == edit


@pytest.mark.allow_stuck
def test_opfuture_result_raises_instead_of_spinning():
    """Regression: with an unbounded daemon keeping the event queue busy and
    a lost quorum, result() must blow its virtual-time deadline and raise the
    typed DeadlineExceeded (ISSUE 10 — was a magic event budget), carrying
    stuck_ops() diagnostics that name the stranded round."""
    from repro.net.sim import DeadlineExceeded

    dss = _dss(n=6, m=2, seed=53, indexed=True)
    s = dss.session("w")
    s.write("f", _blob(90, 2000)).result()
    dss.start_repair_daemon(period=0.001)
    dss.crash_servers([f"s{i}" for i in range(4)])  # beyond the fault budget
    fut = s.read("f")
    try:
        with pytest.raises(DeadlineExceeded, match="deadline"):
            fut.result(deadline=0.5)
        assert dss.net.stuck_ops(), "the stranded round must be diagnosable"
    finally:
        dss.stop_repair_daemon()


def test_repair_daemon_keeps_covering_unreconfigured_objects():
    """Review regression: after a PARTIAL recon (one object moved to cfg 1,
    another left on cfg 0) the auto-retargeting daemon must keep repairing
    the object still on the old configuration — coverage is additive."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=61,
                        recon_repair=False))
    w = dss.client("w")
    dss.net.run_op(w.update("a", _blob(76, 2000)), client="w")
    dss.net.run_op(w.update("b", _blob(77, 2000)), client="w")
    dss.net.run()
    daemon = dss.start_repair_daemon(period=0.02, objs_per_cycle=4)
    cfg1 = dss.make_config()
    fut = dss.net.spawn(dss.client("g").recon("a", cfg1), client="g")
    dss.net.run(until=dss.net.now + 0.3)
    assert fut.done and daemon.cfg_idx == 1
    assert daemon.covered_indices() == [0, 1], "old config must stay covered"
    # damage 'b' (still at cfg 0): drop its newest fragments on two servers
    for sid in ("s0", "s1"):
        lst = dss.net.servers[sid].ec[("b", 0)]
        t_star = max(t for t, e in lst.items() if e is not None)
        del lst[t_star]
    dss.net.run(until=dss.net.now + 0.3)
    dss.stop_repair_daemon()
    dss.net.run()
    t_star = max(
        t for t, e in dss.net.servers["s2"].ec[("b", 0)].items() if e is not None
    )
    for sid in ("s0", "s1"):
        assert dss.net.servers[sid].ec[("b", 0)].get(t_star) is not None, (
            f"{sid}: object left on the old configuration was abandoned"
        )


def test_daemon_covers_different_configs_at_same_index_and_prunes():
    """Review regression: two files reconfigured to DIFFERENT configurations
    at the same sequence index must BOTH stay covered (targets are keyed by
    index AND config id), and a target whose objects all moved to finalized
    successors is pruned so probe traffic stays bounded."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=8, parity_m=6, seed=67,
                        recon_repair=False))
    w = dss.client("w")
    dss.net.run_op(w.update("a", _blob(81, 1500)), client="w")
    dss.net.run_op(w.update("b", _blob(82, 1500)), client="w")
    dss.net.run()
    daemon = dss.start_repair_daemon(period=0.02, objs_per_cycle=4)
    cfg_x = dss.make_config(n_servers=6)          # s0..s5
    cfg_y = dss.make_config(n_servers=8)          # s0..s7
    g = dss.client("g")
    f1 = dss.net.spawn(g.recon("a", cfg_x), client="g")
    f2 = dss.net.spawn(g.recon("b", cfg_y), client="g")
    dss.net.run(until=dss.net.now + 0.3)
    assert f1.done and f2.done
    assert len([k for k in daemon.targets if k[0] == 1]) == 2, daemon.targets
    # damage 'b' under cfg_y: the daemon must find it via cfg_y's probe
    lst = dss.net.servers["s6"].ec[("b", 1)]
    t_star = max(t for t, e in lst.items() if e is not None)
    del lst[t_star]
    dss.net.run(until=dss.net.now + 0.4)
    assert dss.net.servers["s6"].ec[("b", 1)].get(t_star) is not None, (
        "same-index second configuration was not covered"
    )
    # cfg 0 holds only superseded state now -> its target gets pruned
    dss.net.run(until=dss.net.now + 0.2)
    dss.stop_repair_daemon()
    dss.net.run()
    assert daemon.stats["pruned"] >= 1, daemon.stats
    assert 0 not in daemon.covered_indices(), daemon.targets


def test_probe_health_reports_unreadable_not_healthy():
    """Review regression: data that WAS written but no longer reaches k live
    holders must report a negative margin + unreadable, never full health."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=63))
    s = dss.session("w")
    s.write("f", _blob(78, 2000)).result()
    dss.net.run()
    # destroy all but one live copy of every real tag (k=2 -> undecodable)
    for sid in [f"s{i}" for i in range(1, 6)]:
        lst = dss.net.servers[sid].ec[("f", 0)]
        for t in [t for t in lst if t > TAG0]:
            del lst[t]
    st = s.stat("f").result()
    assert st["unreadable"] is True
    assert st["margin"] == 1 - 2, st  # one holder, k=2 -> margin -1
    # the margin-ordered daemon must NOT spin on it (nothing rebuildable)
    daemon = dss.start_repair_daemon(period=0.01, max_cycles=3)
    dss.net.run()
    assert daemon.stats["objects"] == 0, "unrepairable object must be skipped"


def test_stale_daemon_subscription_is_inert():
    """Review regression: a daemon that finished via max_cycles must ignore
    recon notifications, and starting a replacement unsubscribes it."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=65,
                        recon_repair=False))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(79, 1000)), client="w")
    d1 = dss.start_repair_daemon(period=0.005, max_cycles=1)
    dss.net.run()
    assert d1._fut.done
    cfg1 = dss.make_config()
    dss.net.run_op(dss.client("g").recon("f", cfg1), client="g")
    assert d1.stats["retargets"] == 0 and d1.covered_indices() == [0], (
        "completed daemon must not be retargeted by stale notifications"
    )
    d2 = dss.start_repair_daemon(period=0.005, max_cycles=1, client_id="d2")
    assert d1.observe_recon not in dss._recon_subs
    assert d2.observe_recon in dss._recon_subs
    dss.net.run()


# ------------------------------------------------- ISSUE 4 satellite fixes
def test_drain_rearm_preserves_order_for_mid_flight_enqueues():
    """Reschedule-hazard regression: ops enqueued while the session drain is
    MID-FLIGHT must (a) never spawn a concurrent drain that races ahead of
    the drain's remaining groups, and (b) always be picked up by a re-armed
    drain once the running one exits."""
    dss = _dss(indexed=True, seed=71)
    s = dss.session("s")
    va, vb = _blob(91, 3000), _blob(92, 3000)
    wfut = s.write("a", va)
    rfut = s.read("a")          # drain: [write a] then [read a]
    mid = {}

    def inject():
        # the drain started (flag stays armed, batch already taken) and is
        # still mid-flight working its first group
        assert s._drain_scheduled and not s._pending
        assert not rfut.done()
        mid["w"] = s.write("a", vb)
        mid["r"] = s.read("a")
        # no concurrent drain spawned: the intents wait for the re-arm
        assert len(s._pending) == 2

    dss.net.schedule(1e-3, inject)
    # the pre-enqueued read must see ONLY the first write — with the old
    # reset-on-entry flag a second drain could run the mid-flight write
    # concurrently with this read and race it
    assert rfut.result() == va
    assert "w" in mid, "injection must have fired mid-drain"
    assert mid["r"].result() == vb, "re-armed drain must run the late ops"
    assert wfut.result()["success"] and mid["w"].result()["success"]
    check_all(dss.history)


def test_gather_across_networks_raises_valueerror():
    """Futures of different DSS/Network instances must be rejected up front
    instead of spinning one store's loop on the other's operation."""
    dss1 = _dss(seed=73, indexed=True)
    dss2 = _dss(seed=74, indexed=True)
    f1 = dss1.session("a").write("f", b"x" * 300)
    f2 = dss2.session("b").write("f", b"y" * 300)
    with pytest.raises(ValueError, match="multiple DSS/Network"):
        gather(f1, f2)
    # each is still individually drivable on its own network
    assert f1.result()["success"] and f2.result()["success"]


def test_recon_future_resolves_to_payload_dict():
    """Accounting regression: recon futures used to resolve to the bare
    per-file block count that also fed OpStats.blocks (aliased). They now
    carry a real payload dict, and stats keep the correct count."""
    dss = _dss(indexed=True, seed=77)
    s = dss.session("s")
    assert s.write("f", _blob(93, 5000)).result()["success"]
    cfg1 = dss.make_config()
    res = s.recon("f", cfg1)
    payload = res.result()
    assert isinstance(payload, dict) and payload["success"]
    assert payload["config"] == cfg1.cfg_id
    assert payload["blocks"] >= 2  # genesis + at least one data block
    assert res.stats.blocks == payload["blocks"]
    dss.net.run()  # quiesce recon-spawned repair


# ---------------------------------------------------- _groups property test
_KINDS = ["read", "write", "recon", "stat"]
_FIDS = ["f0", "f1", "f2"]


@settings(max_examples=60, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(_KINDS), st.sampled_from(_FIDS),
              st.integers(0, 2)),
    min_size=0, max_size=12,
))
def test_groups_preserve_program_order_and_never_mix_recon_targets(ops):
    """ISSUE 4 satellite: for ANY intent sequence, ``Session._groups`` must
    (1) keep global program order (concatenation identity) — hence per-fid
    program order across kind changes, (2) group only same-kind runs,
    (3) never put one fid twice in a group, and (4) never merge two recons
    with different target cfg_ids."""
    from types import SimpleNamespace

    from repro.core.api import Session, _Intent

    batch = [
        _Intent(kind, fid, SimpleNamespace(cfg_id=f"c{cfg}"), None)
        for kind, fid, cfg in ops
    ]
    groups = Session._groups(object.__new__(Session), batch)
    flat = [it for g in groups for it in g]
    assert flat == batch, "groups must concatenate back to program order"
    for g in groups:
        assert g, "no empty groups"
        assert len({it.kind for it in g}) == 1
        fids = [it.fid for it in g]
        assert len(fids) == len(set(fids)), "duplicate fid within a group"
        if g[0].kind == "recon":
            assert len({it.arg.cfg_id for it in g}) == 1, (
                "recons with different targets merged"
            )


def test_repair_daemon_idles_on_abd_config_after_retarget():
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=37,
                        recon_repair=False))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(75, 1000)), client="w")
    daemon = dss.start_repair_daemon(period=0.02)
    cfg1 = dss.make_config(dap="abd")
    fut = dss.net.spawn(dss.client("g").recon("f", cfg1), client="g")
    dss.net.run(until=dss.net.now + 0.2)
    assert fut.done
    assert daemon.config.dap == "abd"  # followed the flip, idling safely
    dss.stop_repair_daemon()
    dss.net.run()
