"""Per-architecture smoke tests: REDUCED configs, one forward/train step and
one decode step on CPU (1 device), asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.configs.base import ShapeConfig
from repro.models.registry import build_model, make_inputs

ARCHS = sorted(all_archs().keys())
SMOKE_SHAPE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
SMOKE_DECODE = ShapeConfig("smoke_dec", seq_len=64, global_batch=2, kind="decode")


@pytest.fixture(scope="module")
def reduced():
    out = {}
    for name, cfg in all_archs().items():
        out[name] = cfg.reduced()
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_forward_and_grad(arch, reduced):
    cfg = reduced[arch]
    model = build_model(cfg, max_pos=SMOKE_SHAPE.seq_len)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, SMOKE_SHAPE, seed=1)
    # clamp labels/tokens into the reduced vocab
    for k in ("tokens", "labels", "token"):
        if k in batch:
            batch[k] = batch[k] % cfg.vocab

    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss {loss}"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat), (
        f"{arch}: non-finite grads"
    )
    # a train step moves the loss: SGD step
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    loss2 = model.loss_fn(params2, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, reduced):
    cfg = reduced[arch]
    model = build_model(cfg, max_pos=SMOKE_DECODE.seq_len)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = SMOKE_DECODE.global_batch, SMOKE_DECODE.seq_len
    tmpl = model.cache_template(B, S)
    cache = {k: jnp.zeros(shape, dtype) for k, (shape, dtype) in tmpl.items()}
    batch = make_inputs(cfg, SMOKE_DECODE, seed=2)
    if "token" in batch:
        batch["token"] = batch["token"] % cfg.vocab
    logits, cache2 = model.decode_step(params, cache, batch)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    # cache was updated in place-of: same structure, same shapes
    for k in tmpl:
        assert cache2[k].shape == tmpl[k][0], (k, cache2[k].shape, tmpl[k][0])
    # a second step at the next position also works
    batch["cur_len"] = batch["cur_len"] + 1
    logits3, _ = model.decode_step(params, cache2, batch)
    assert bool(jnp.all(jnp.isfinite(logits3)))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive_and_active(arch, reduced):
    cfg = reduced[arch]
    model = build_model(cfg)
    n = model.n_params()
    na = model.n_active_params()
    assert n > 0 and 0 < na <= n
    if cfg.moe_experts:
        assert na < n  # MoE: active < total


def test_full_config_param_counts_sane():
    """FULL configs: parameter totals are in the advertised ballpark.
    (Template-only — no arrays are allocated.)"""
    expected = {
        "qwen2_vl_7b": (6e9, 9e9),
        "olmoe_1b_7b": (5e9, 8e9),
        "qwen3_moe_30b_a3b": (25e9, 33e9),
        "gemma3_1b": (0.7e9, 1.6e9),
        "chatglm3_6b": (5e9, 8e9),
        "qwen3_0_6b": (0.4e9, 0.9e9),
        "qwen2_0_5b": (0.3e9, 0.7e9),
        "mamba2_2_7b": (2e9, 3.5e9),
        "whisper_base": (0.04e9, 0.12e9),
        "zamba2_7b": (5.5e9, 9e9),
    }
    for name, cfg in all_archs().items():
        n = build_model(cfg).n_params()
        lo, hi = expected[name]
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]B"


def test_gemma3_local_global_masking():
    """Local layers must not attend beyond the sliding window."""
    cfg = all_archs()["gemma3_1b"].reduced()
    assert cfg.sliding_window == 32 and cfg.global_every == 2
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    shape = ShapeConfig("s", 64, 2, "train")
    b1 = make_inputs(cfg, shape, seed=3)
    b1["tokens"] = b1["tokens"] % cfg.vocab
    b1["labels"] = b1["labels"] % cfg.vocab
    l1 = model.loss_fn(params, b1)
    # perturb tokens far outside every local window of the final position;
    # with only local layers this would not change the last-token logits, but
    # global layers exist, so the loss must change (sanity that global path on)
    b2 = {k: v.copy() for k, v in b1.items()}
    b2["tokens"] = b2["tokens"].at[:, 0].set((b2["tokens"][:, 0] + 1) % cfg.vocab)
    l2 = model.loss_fn(params, b2)
    assert bool(jnp.isfinite(l1)) and bool(jnp.isfinite(l2))


def test_mamba2_ssd_matches_sequential_recurrence():
    """SSD chunked scan == naive per-token recurrence (oracle)."""
    from repro.models import ssd

    cfg = all_archs()["mamba2_2_7b"].reduced()
    B, L = 2, 32
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L, 1, N)), jnp.float32) * 0.3
    Cm = jnp.asarray(rng.standard_normal((B, L, 1, N)), jnp.float32) * 0.3
    y_chunk = ssd._ssd_chunked(x, dt, A, Bm, Cm, Q=8)
    # naive recurrence
    state = np.zeros((B, H, N, P))
    ys = []
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, Bm, Cm))
    An = np.asarray(A)
    for t in range(L):
        decay = np.exp(dtn[:, t] * An[None, :])             # (B, H)
        upd = np.einsum("bn,bh,bhp->bhnp", Bn[:, t, 0], dtn[:, t], xn[:, t])
        state = decay[:, :, None, None] * state + upd
        ys.append(np.einsum("bn,bhnp->bhp", Cn[:, t, 0], state))
    y_ref = np.stack(ys, axis=1)  # (B, L, H, P)
    np.testing.assert_allclose(np.asarray(y_chunk), y_ref, rtol=2e-4, atol=2e-4)
