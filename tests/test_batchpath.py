"""Batched multi-object state transfer (ISSUE 2): bit-identity with the
per-object path, O(1) quorum rounds on the indexed FM read, crash/recover
during batched reads, recon-triggered repair, the repair daemon, the EC
get-tag local-state fix, and legacy-genesis tolerance."""
import numpy as np
import pytest

from checkers import check_all
from repro.core import DSS, DSSParams, FragmentationModule, TAG0, genesis_id
from repro.core.coares import CoAresClient
from repro.core.dap.base import make_dap
from repro.core.fragment import decode_block_value, encode_block_value
from repro.core.server import StorageServer
from repro.core.tags import Config
from repro.net.sim import Network


def _blob(seed, size):
    return np.random.default_rng(seed).integers(0, 256, size, dtype=np.uint8).tobytes()


def _net(n, seed, dap, k):
    net = Network(seed=seed)
    sids = tuple(f"s{i}" for i in range(n))
    for s in sids:
        net.add_server(StorageServer(s))
    return net, Config("c0", sids, dap=dap, k=k, delta=8)


def _frag_dss(alg="coaresecf", n=6, m=2, seed=3, **kw):
    kw.setdefault("min_block", 64)
    kw.setdefault("avg_block", 128)
    kw.setdefault("max_block", 512)
    return DSS(DSSParams(algorithm=alg, n_servers=n, parity_m=m, seed=seed, **kw))


# ------------------------------------------------- DAP-level bit-identity
@pytest.mark.parametrize("dap", ["abd", "ec", "ec_opt"])
def test_get_data_batch_matches_get_data(dap):
    """get_data_batch(objs) returns exactly what per-object get_data would."""
    k = 3 if dap != "abd" else 1
    net, cfg = _net(5, 7, dap, k)
    w = make_dap(net, "w", cfg, 0, {})
    objs = [f"o{i}" for i in range(6)]
    want = {}
    for i, o in enumerate(objs[:-1]):  # leave o5 unwritten: (TAG0, None)
        val = _blob(i, 40 + 17 * i)
        net.run_op(w.put_data(o, (i + 1, "w"), val), client="w")
        want[o] = ((i + 1, "w"), val)
    want[objs[-1]] = (TAG0, None)
    singles = {}
    r1 = make_dap(net, "r1", cfg, 0, {})
    for o in objs:
        singles[o] = net.run_op(r1.get_data(o), client="r1")
    r2 = make_dap(net, "r2", cfg, 0, {})
    batched = net.run_op(r2.get_data_batch(objs), client="r2")
    assert singles == batched == want


@pytest.mark.parametrize("dap", ["abd", "ec", "ec_opt"])
def test_put_data_batch_server_state_identical(dap):
    """A put_data_batch leaves servers bit-identical to per-object put_data."""
    k = 3 if dap != "abd" else 1
    items = [
        (f"o{i}", (i + 1, "w"), _blob(20 + i, 33 + 29 * i)) for i in range(5)
    ]
    net_a, cfg_a = _net(5, 9, dap, k)
    w_a = make_dap(net_a, "w", cfg_a, 0, {})
    for o, t, v in items:
        net_a.run_op(w_a.put_data(o, t, v), client="w")
    net_b, cfg_b = _net(5, 9, dap, k)
    w_b = make_dap(net_b, "w", cfg_b, 0, {})
    net_b.run_op(w_b.put_data_batch(items), client="w")
    for sid in net_a.servers:
        sa, sb = net_a.servers[sid], net_b.servers[sid]
        assert sa.abd == sb.abd
        assert sa.ec == sb.ec


# --------------------------------------------- client-level bit-identity
@pytest.mark.parametrize("alg", ["coaresecf", "coaresecf-noopt", "coaresabdf", "coabdf"])
def test_cvr_read_batch_matches_cvr_read(alg):
    dss = _frag_dss(alg=alg, indexed=True)
    blob = _blob(1, 12_000)
    w = dss.client("w")
    assert dss.net.run_op(w.update("f", blob), client="w")["success"]
    # recover the block index straight from the genesis block
    r0 = dss.client("r0")
    _tag, graw = dss.net.run_op(r0.dsm.cvr_read(genesis_id("f")), client="r0")
    from repro.core import parse_genesis_meta

    index = parse_genesis_meta(decode_block_value(graw)[1])
    assert index and len(index) > 5
    r1, r2 = dss.client("r1"), dss.client("r2")
    singles = {
        b: dss.net.run_op(r1.dsm.cvr_read(b), client="r1") for b in index
    }
    batched = dss.net.run_op(r2.dsm.cvr_read_batch(index), client="r2")
    assert singles == batched
    check_all(dss.history)


def test_batched_and_unbatched_stores_serve_same_content():
    blob = _blob(2, 20_000)
    edit = bytearray(blob)
    edit[5_000] ^= 0xFF
    edit[15_000:15_000] = _blob(3, 400)  # structural insert
    contents = {}
    for batched in (False, True):
        dss = _frag_dss(indexed=True, batched=batched, seed=11)
        w, r = dss.client("w"), dss.client("r")
        assert dss.net.run_op(w.update("f", blob), client="w")["success"]
        assert dss.net.run_op(w.update("f", bytes(edit)), client="w")["success"]
        contents[batched] = dss.net.run_op(r.read("f"), client="r")
        check_all(dss.history)
    assert contents[False] == contents[True] == bytes(edit)


# --------------------------------------------------- round/message counts
def test_indexed_read_is_O1_quorum_rounds():
    """The acceptance bar: a B-block indexed EC read issues O(1) quorum
    rounds (genesis read + one batched sweep), not O(B)."""
    counts = {}
    for B_seed, size in ((4, 6_000), (5, 48_000)):  # ~25 vs ~200 blocks
        dss = _frag_dss(indexed=True, seed=13)
        blob = _blob(B_seed, size)
        w = dss.client("w")
        stats = dss.net.run_op(w.update("f", blob), client="w")
        r = dss.client("r")
        before = dss.net.rpc_rounds
        assert dss.net.run_op(r.read("f"), client="r") == blob
        counts[size] = (stats["blocks"], dss.net.rpc_rounds - before)
    (b_small, rounds_small), (b_big, rounds_big) = counts.values()
    assert b_big > 4 * b_small
    assert rounds_small <= 10 and rounds_big <= 10, counts
    assert rounds_big == rounds_small, "round count must not scale with B"


def test_batched_read_moves_fewer_messages():
    stats = {}
    for batched in (False, True):
        dss = _frag_dss(indexed=True, batched=batched, seed=17)
        blob = _blob(6, 24_000)
        w = dss.client("w")
        dss.net.run_op(w.update("f", blob), client="w")
        r = dss.client("r")
        m0, t0 = dss.net.msg_count, dss.net.now
        assert dss.net.run_op(r.read("f"), client="r") == blob
        stats[batched] = (dss.net.msg_count - m0, dss.net.now - t0)
    assert stats[True][0] < stats[False][0] / 10, stats
    assert stats[True][1] < stats[False][1], stats  # virtual-time latency too


# ------------------------------------------------ fault tolerance / safety
def test_crash_during_batched_read():
    """Crash f servers while a batched multi-block read is in flight; the
    read must complete with the correct content, and after recover+repair a
    DIFFERENT f may fail. History stays atomic/coverable."""
    # n=6, parity_m=4 -> k=2, f = (n-k)/2 = 2
    dss = _frag_dss(n=6, m=4, indexed=True, seed=19)
    blob = _blob(7, 10_000)
    w = dss.client("w")
    assert dss.net.run_op(w.update("f", blob), client="w")["success"]
    r = dss.client("r")
    fut = dss.net.spawn(r.read("f"), client="r")
    dss.net.run(until=dss.net.now + 0.0004)  # mid first fan-out
    assert not fut.done
    dss.crash_servers(["s0", "s1"])
    dss.net.run()
    assert fut.done and fut.result == blob
    # recover stale, repair, then a different f crashes: reads still serve
    dss.recover_servers(["s0", "s1"])
    dss.repair()
    dss.crash_servers(["s4", "s5"])
    r2 = dss.client("r2")
    assert dss.net.run_op(r2.read("f"), client="r2") == blob
    check_all(dss.history)


def _max_decodable(dss, obj, k, idx, servers):
    counts = {}
    for sid in servers:
        lst = dss.net.servers[sid].ec.get((obj, idx), {})
        for t, e in lst.items():
            if e is not None:
                counts[t] = counts.get(t, 0) + 1
    good = [t for t, c in counts.items() if c >= k]
    return max(good, default=TAG0)


@pytest.mark.parametrize("recon_repair", [False, True])
def test_recon_finalization_triggers_repair(recon_repair):
    """A server of the new configuration that missed the recon's transfer put
    (crashed, recovered later) is healed by the recon-triggered repair pass —
    and stays stale when recon_repair is off."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=23,
                        recon_repair=recon_repair, recon_repair_delay=0.2))
    w = dss.client("w")
    blob = _blob(8, 4_000)
    assert dss.net.run_op(w.update("f", blob), client="w")["success"]
    dss.crash_servers(["s5"])
    cfg1 = dss.make_config()  # same 6-server set, new configuration c1
    assert "s5" in cfg1.servers
    g = dss.client("g")
    fut = dss.net.spawn(g.recon("f", cfg1), client="g")
    dss.net.schedule(0.05, lambda: dss.net.recover("s5"))  # before repair fires
    dss.net.run()
    assert fut.done
    t_star = _max_decodable(dss, "f", cfg1.k, 1, [f"s{i}" for i in range(5)])
    assert t_star > TAG0
    s5_list = dss.net.servers["s5"].ec.get(("f", 1), {})
    if recon_repair:
        assert s5_list.get(t_star) is not None, "recon repair must heal s5"
    else:
        assert s5_list.get(t_star) is None, "control: s5 stays stale"
    check_all(dss.history)


def test_repair_daemon_heals_and_stops():
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=29))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(9, 3_000)), client="w")
    dss.crash_servers(["s0", "s1"])
    dss.net.run_op(w.update("f", _blob(10, 3_000)), client="w")  # they fall behind
    dss.wipe_servers(["s0"])
    dss.recover_servers(["s0", "s1"])
    daemon = dss.start_repair_daemon(period=0.02, objs_per_cycle=1, max_cycles=8)
    dss.net.run()
    assert daemon._fut.done  # bounded cycles -> quiescence
    assert daemon.stats["applied"] >= 2, daemon.stats
    t_star = _max_decodable(dss, "f", dss.c0.k, 0, dss.net.alive())
    for sid in dss.net.alive():
        assert dss.net.servers[sid].ec[("f", 0)].get(t_star) is not None
    # unbounded daemon: stop() lets the loop drain
    d2 = dss.start_repair_daemon(period=0.02, client_id="repaird2")
    dss.net.run(until=dss.net.now + 0.1)
    d2.stop()
    dss.net.run()
    assert d2._fut.done


# ----------------------------------------------------- EC get-tag (Alg 4)
def test_ec_get_tag_accounts_for_local_state():
    """EC-DAPopt get_tag must never return a tag older than the value the
    client already holds (consistent with get_data's Alg 4:10 shortcut)."""
    net, cfg = _net(5, 31, "ec_opt", k=3)
    w = make_dap(net, "w", cfg, 0, {})
    net.run_op(w.put_data("obj", (3, "x"), b"server-state" * 3), client="w")
    state = {("ec", "obj", cfg.cfg_id): ((5, "z"), b"newer-local" * 3)}
    c = make_dap(net, "c", cfg, 0, state)
    assert net.run_op(c.get_tag("obj"), client="c") == (5, "z")
    # and with no local state it still reports the servers' tag
    c2 = make_dap(net, "c2", cfg, 0, {})
    assert net.run_op(c2.get_tag("obj"), client="c2") == (3, "x")


@pytest.mark.parametrize("dap", ["ec", "ec_opt"])
def test_ec_get_tag_geq_completed_put(dap):
    net, cfg = _net(5, 37, dap, k=3)
    state = {}
    w = make_dap(net, "w", cfg, 0, state)
    for i in range(3):
        net.run_op(w.put_data("obj", (i + 1, "w"), _blob(40 + i, 64)), client="w")
        assert net.run_op(w.get_tag("obj"), client="w") >= (i + 1, "w")


# ------------------------------------------------- genesis schema (FM §V)
def _manual_fm(dss, cid, *, indexed, batched=True):
    dsm = CoAresClient(dss.net, cid, dss.c0, history=dss.history)
    return FragmentationModule(
        dss.net, dsm, min_block=64, avg_block=128, max_block=512,
        history=dss.history, indexed=indexed, batched=batched,
    )


def test_unified_genesis_lets_indexed_clients_read_walked_files():
    """A file written by the NON-indexed FM now carries the index in its
    genesis block, so an indexed reader batch-reads it in O(1) rounds."""
    dss = _frag_dss(indexed=False, seed=41)
    blob = _blob(11, 9_000)
    w = dss.client("w")
    assert dss.net.run_op(w.update("f", blob), client="w")["success"]
    fm = _manual_fm(dss, "ri", indexed=True)
    before = dss.net.rpc_rounds
    content, blocks = dss.net.run_op(fm.fm_read("f"), client="ri")
    assert content == blob and len(blocks) > 5
    assert dss.net.rpc_rounds - before <= 10  # index found -> batched sweep


def test_legacy_count_genesis_falls_back_to_walk():
    """fm_read and fm_reconfig stay correct on the legacy genesis schema (a
    raw block count instead of a pickled index)."""
    dss = _frag_dss(indexed=False, seed=43)
    blob = _blob(12, 6_000)
    w = dss.client("w")
    assert dss.net.run_op(w.update("f", blob), client="w")["success"]
    # rewrite the genesis with the legacy schema (same head pointer)
    g = genesis_id("f")
    wdsm = w.fm.dsm  # holds the current genesis version from the fm_update
    _t, graw = dss.net.run_op(wdsm.cvr_read(g), client="w")
    head, _meta = decode_block_value(graw)
    legacy = encode_block_value(head, (99).to_bytes(4, "big"))
    (_tag, _v), flag = dss.net.run_op(wdsm.cvr_write(g, legacy), client="w")
    assert flag == "chg"
    # an INDEXED client tolerates it: falls back to the linked-list walk
    fm = _manual_fm(dss, "ri", indexed=True)
    content, _ = dss.net.run_op(fm.fm_read("f"), client="ri")
    assert content == blob
    # and so does reconfiguration (walk without per-block re-reads)
    recfm = _manual_fm(dss, "rg", indexed=True)
    cfg1 = dss.make_config(n_servers=7)
    n = dss.net.run_op(recfm.fm_reconfig("f", cfg1), client="rg")
    assert n > 5  # genesis + every data block walked and reconfigured
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == blob
    check_all(dss.history)
