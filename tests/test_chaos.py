"""Failure-survival layer (ISSUE 10): RPC deadlines + retransmission with
backoff/jitter, hedged sends, the richer fault surface (asymmetric
partitions, gray latency, duplication, crash-recovery), typed
``QuorumUnavailableError`` liveness failures, and the beyond-quorum
chaos-storm acceptance gate.

Layers:

* ablation — with ``retry=None`` (the default) NO retry machinery runs:
  zero retransmits/timeouts and fast/legacy traces stay identical;
* RPC tier — deadline timers retransmit to the laggards, ride out
  transient crashes, and surface a typed ``RpcTimeout`` (a
  ``QuorumUnavailableError``) when the budget is exhausted;
* fault surface — partitions (asymmetric / bidirectional / wildcard /
  heal), gray slowdowns, message duplication, crash-recovery wipes,
  all deterministic and engine-identical;
* protocol/API tier — phase retries surface ``QuorumUnavailableError``
  on Session futures instead of hanging;
* acceptance — a seeded beyond-quorum ``CrashStorm`` under sanitizer +
  race tracker: 0 stuck ops, >= 99% availability after recovery, every
  unrecoverable op failing typed within its deadline.
"""
import pytest

from repro.core import (
    DSS,
    DSSParams,
    CrashStorm,
    QuorumUnavailableError,
    RetryPolicy,
    WorkloadGen,
    WorkloadSpec,
)
from repro.net.sim import (
    RPC,
    FaultEvent,
    FaultPlan,
    LatencyModel,
    Network,
    RpcTimeout,
    Server,
)


class Echo(Server):
    def __init__(self, sid):
        super().__init__(sid)
        self.count = 0

    def handle(self, sender, msg):
        self.count += 1
        return ("echo", self.sid, msg)


def _mknet(fast=True, n=3, seed=2, retry=None, **lat):
    net = Network(seed=seed, latency=LatencyModel(**lat), fast=fast)
    net.retry = retry
    for i in range(n):
        net.add_server(Echo(f"s{i}"))
    return net


def _fingerprint(net):
    return (
        round(net.now, 12),
        net.events_processed,
        net.rpc_rounds,
        net.msg_count,
        net.bytes_sent,
        net.client_counters,
        net.retransmits,
        net.rpc_timeouts,
        net.hedges,
    )


# ------------------------------------------------------------- ablation
def _workload_report(fast, *, retry=None, storms=(), seed=11):
    dss = DSS(DSSParams(
        algorithm="coaresecf", n_servers=6, parity_m=2, seed=5,
        min_block=256, avg_block=512, max_block=2048,
        indexed=True, batched=True, fast_net=fast, retry=retry,
    ))
    spec = WorkloadSpec(sessions=30, files=8, file_size=512,
                        read_fraction=0.7, ops_per_session=2, storms=storms)
    rep = WorkloadGen(spec, seed=seed).run(dss)
    return rep, _fingerprint(dss.net)


def test_retry_disabled_consumes_nothing():
    """The ablation contract: ``retry=None`` arms no timers, draws no RNG,
    reserves no sequence numbers — the retry counters stay exactly zero
    and fast/legacy traces agree (byte-identity with pre-feature HEAD is
    pinned by the untouched bench-smoke baselines)."""
    a = _workload_report(True)
    b = _workload_report(False)
    assert a == b
    rep, fp = a
    assert rep["retries"] == {"retransmits": 0, "rpc_timeouts": 0,
                              "hedges": 0, "op_retries": 0}
    assert fp[-3:] == (0, 0, 0)


def test_trace_identity_with_retries_enabled():
    """Stronger than the ISSUE asks: even WITH the retry machinery armed
    and a beyond-quorum storm landing, both engines replay the identical
    trace — timers, retransmits and jitter draws are engine-independent."""
    storms = (CrashStorm(at=0.05, frac=1.0, duration=0.05,
                         beyond_quorum=True),)
    a = _workload_report(True, retry=RetryPolicy(), storms=storms, seed=13)
    b = _workload_report(False, retry=RetryPolicy(), storms=storms, seed=13)
    assert a == b


# ------------------------------------------------------------- RPC tier
def _timeout_trial(fast):
    net = _mknet(fast, n=3, retry=RetryPolicy(
        rpc_timeout=5e-3, backoff=2.0, jitter=0.25, max_attempts=3))
    for s in list(net.servers):
        net.crash(s)

    def op():
        try:
            yield RPC(dests=tuple(net.servers), msg=("ping",), need=2)
        except RpcTimeout as e:
            return ("timed-out", net.now, str(e))
        return "completed"

    fut = net.spawn(op(), client="c")
    net.run()
    assert fut.done
    return fut.result, net.retransmits, net.rpc_timeouts


def test_rpc_timeout_is_typed_and_engine_identical():
    a, b = _timeout_trial(True), _timeout_trial(False)
    assert a == b
    (kind, t, msg), retransmits, timeouts = a
    assert kind == "timed-out"
    assert retransmits == 2 and timeouts == 1  # 3 attempts, then the throw
    # cumulative backoff: 5 + 10 + 20 ms, plus <= 25% jitter per attempt
    assert 0.035 <= t <= 0.035 * 1.25
    assert "0/2" in msg or "need" in msg


def test_rpc_timeout_is_a_quorum_unavailable_error():
    assert issubclass(RpcTimeout, QuorumUnavailableError)


def _transient_crash_trial(fast):
    net = _mknet(fast, n=3, retry=RetryPolicy(
        rpc_timeout=10e-3, jitter=0.0, max_attempts=4))
    net.crash("s1")
    net.crash("s2")

    def op():
        replies = yield RPC(dests=("s0", "s1", "s2"), msg=("ping",), need=3)
        return sorted(replies)

    fut = net.spawn(op(), client="c")
    # recovery lands between attempt 2 (~10ms) and attempt 3 (~30ms): the
    # round must ride it out via retransmission instead of wedging
    net.schedule(0.02, lambda: (net.recover("s1"), net.recover("s2")))
    net.run()
    assert fut.done
    return fut.result, net.retransmits, _fingerprint(net)


def test_retransmit_rides_out_transient_crash():
    a, b = _transient_crash_trial(True), _transient_crash_trial(False)
    assert a == b
    result, retransmits, _ = a
    assert result == ["s0", "s1", "s2"]
    assert retransmits >= 2  # the laggards were re-sent to after recovery


def test_retransmit_goes_only_to_laggards():
    net = _mknet(True, n=3, retry=RetryPolicy(
        rpc_timeout=10e-3, jitter=0.0, max_attempts=4))
    net.crash("s2")

    def op():
        replies = yield RPC(dests=("s0", "s1", "s2"), msg=("ping",), need=3)
        return sorted(replies)

    net.spawn(op(), client="c")
    net.schedule(0.02, lambda: net.recover("s2"))
    net.run()
    # s0/s1 answered attempt 1; their handlers never saw a duplicate
    assert net.servers["s0"].count == 1
    assert net.servers["s1"].count == 1
    assert net.servers["s2"].count == 1  # only the post-recovery retransmit


def _hedge_trial(fast):
    net = _mknet(fast, n=3, retry=RetryPolicy(
        rpc_timeout=50e-3, jitter=0.0, max_attempts=2, hedge_after=5e-3))
    # gray straggler: 0.015 each way lags the reply past hedge_after but
    # inside rpc_timeout, so the hedge fires and no retransmit does
    net.slow("s2", 0.015)

    def op():
        replies = yield RPC(dests=("s0", "s1", "s2"), msg=("ping",), need=3)
        return sorted(replies)

    fut = net.spawn(op(), client="c")
    net.run()
    assert fut.done
    return fut.result, net.hedges, net.retransmits, _fingerprint(net)


def test_hedged_send_fires_once_without_burning_attempts():
    a, b = _hedge_trial(True), _hedge_trial(False)
    assert a == b
    result, hedges, retransmits, _ = a
    assert result == ["s0", "s1", "s2"]
    assert hedges == 1 and retransmits == 0


# --------------------------------------------------------- fault surface
def test_partition_asymmetric_request_vs_reply_path():
    for fast in (True, False):
        # request path: c -> s0 blocked; the round completes on s1/s2
        net = _mknet(fast, n=3)
        net.partition("c", "s0")

        def op(net=net):
            replies = yield RPC(dests=tuple(net.servers),
                                msg=("ping",), need=2)
            return sorted(replies)

        fut = net.spawn(op(), client="c")
        net.run()
        assert fut.result == ["s1", "s2"]
        assert net.servers["s0"].count == 0  # request never arrived

        # reply path: s0 handled the message but its reply is blocked
        net2 = _mknet(fast, n=3)
        net2.partition("s0", "c")
        fut2 = net2.spawn(op(net2), client="c")
        net2.run()
        assert fut2.result == ["s1", "s2"]
        assert net2.servers["s0"].count == 1  # handled, reply lost


def test_partition_bidir_wildcard_and_heal():
    net = _mknet(True, n=3, retry=RetryPolicy(rpc_timeout=10e-3, jitter=0.0,
                                              max_attempts=6))
    net.partition("c", "s1", bidir=True)
    assert net._blocked("c", "s1") and net._blocked("s1", "c")
    net.partition("s2", "*")  # s2 cannot send to anyone
    assert net._blocked("s2", "c") and net._blocked("s2", "s0")
    assert not net._blocked("c", "s2")  # requests still reach it

    def op():
        replies = yield RPC(dests=tuple(net.servers), msg=("ping",), need=3)
        return sorted(replies)

    fut = net.spawn(op(), client="c")
    net.schedule(0.025, net.heal)  # no args: clear every rule
    net.run()
    assert fut.result == ["s0", "s1", "s2"]
    assert not net._partitions
    assert net.retransmits > 0  # the healed round finished via retransmit


def test_partition_heal_single_rule():
    net = Network(seed=0)
    net.partition("a", "b")
    net.partition("a", "c")
    net.heal("a", "b")
    assert not net._blocked("a", "b") and net._blocked("a", "c")


def _gray_trial(fast):
    net = _mknet(fast, n=3, seed=7)
    net.slow("s1", 0.25)

    def op():
        replies = yield RPC(dests=tuple(net.servers), msg=("ping",), need=3)
        return len(replies)

    net.spawn(op(), client="c")
    net.run()
    return _fingerprint(net)


def test_gray_slowdown_deterministic_and_engine_identical():
    a = _gray_trial(True)
    assert a == _gray_trial(True) == _gray_trial(False)
    assert a[0] > 0.25  # the straggler's reply bounds the need=3 round
    net = _mknet(True, n=3, seed=7)
    net.slow("s1", 0.25)
    net.unslow("s1")
    assert not net._gray


def _dup_trial(fast):
    net = _mknet(fast, n=3, seed=4, dup_prob=1.0)

    def op(k):
        replies = yield RPC(dests=tuple(net.servers), msg=("ping", k), need=3)
        return sorted(replies)

    futs = [net.spawn(op(k), client="c") for k in range(5)]
    net.run()
    return [f.result for f in futs], [s.count for s in net.servers.values()], \
        _fingerprint(net)


def test_duplication_reaches_handlers_but_never_double_counts():
    a, b = _dup_trial(True), _dup_trial(False)
    assert a == b
    results, counts, _ = a
    assert all(r == ["s0", "s1", "s2"] for r in results)
    assert counts == [10, 10, 10]  # every message handled exactly twice


def test_crash_recovery_wipes_volatile_reply_cache():
    """Satellite (b): ``recover(wipe=True)`` must clear the identity reply
    cache — a recovered replica serving a reply memoized before the crash
    is the gray failure this pins. State is mutated through raw
    ``dict.__setitem__`` (bypassing the tracked-map invalidation hook) to
    model divergence the cache cannot observe across the crash."""
    from repro.core.server import StorageServer

    def primed():
        net = Network(seed=0)
        srv = StorageServer("s0")
        net.add_server(srv)
        srv.handle("w", ("ec-put", "obj", 0, (1, "w"), b"frag-a", 8))
        stale = srv.handle("c", ("ec-query", "obj", 0, None))
        assert srv.handle("c", ("ec-query", "obj", 0, None)) is stale
        dict.__setitem__(srv.ec, ("obj", 0), {(2, "w"): (b"frag-b", 8)})
        net.crash("s0")
        return net, srv, stale

    # crash-stop semantics preserved: wipe=False keeps the (stale) cache
    net, srv, stale = primed()
    net.recover("s0", wipe=False)
    assert srv.handle("c", ("ec-query", "obj", 0, None)) is stale

    # crash-recovery: the wipe guarantees a fresh answer post-recovery
    net, srv, stale = primed()
    net.recover("s0")  # wipe=True is the default
    fresh = srv.handle("c", ("ec-query", "obj", 0, None))
    assert fresh is not stale
    assert (2, "w") in dict(fresh[1])


def test_storage_recover_keeps_durable_state():
    from repro.core.server import StorageServer

    srv = StorageServer("s0")
    srv.handle("w", ("abd-put", "f", 0, (3, "w"), b"v"))
    srv.on_recover()
    assert srv.abd[("f", 0)] == ((3, "w"), b"v")  # durable, survives


def test_fault_plan_applies_and_unwinds():
    net = _mknet(True, n=3)
    FaultPlan(events=(
        FaultEvent(at=0.01, kind="crash", target="s0"),
        FaultEvent(at=0.02, kind="slow", target="s1", extra=0.25),
        FaultEvent(at=0.03, kind="partition", target="c", peer="s2"),
        FaultEvent(at=0.04, kind="recover", target="s0"),
        FaultEvent(at=0.05, kind="unslow", target="s1"),
        FaultEvent(at=0.06, kind="heal-all"),
    )).apply(net)
    seen = []
    net.schedule(0.035, lambda: seen.append((
        net.servers["s0"].crashed, dict(net._gray), set(net._partitions))))
    net.run()
    assert seen == [(True, {"s1": 0.25}, {("c", "s2")})]
    assert not net.servers["s0"].crashed
    assert not net._gray and not net._partitions


def test_fault_plan_rejects_unknown_kind():
    net = Network(seed=0)
    FaultPlan(events=(FaultEvent(at=0.0, kind="meteor"),)).apply(net)
    with pytest.raises(ValueError, match="unknown fault kind"):
        net.run()


@pytest.mark.allow_stuck
def test_stuck_ops_diagnostics_shape():
    """Satellite (a): a wedged quorum round is visible — op id, kind,
    client, the need, and exactly which servers did reply."""
    net = _mknet(True, n=3)  # no retry: the round wedges
    net.crash("s1")
    net.crash("s2")

    def op():
        yield RPC(dests=tuple(net.servers), msg=("ping",), need=2)

    net.spawn(op(), kind="probe", client="c9")
    net.run()
    [stuck] = net.stuck_ops()
    assert stuck["kind"] == "probe" and stuck["client"] == "c9"
    assert stuck["need"] == 2 and stuck["have"] == ["s0"]
    assert stuck["alive_mode"] is False


def test_retry_clears_stuck_ops():
    net = _mknet(True, n=3, retry=RetryPolicy(rpc_timeout=5e-3,
                                              max_attempts=2))
    net.crash("s1")
    net.crash("s2")

    def op():
        try:
            yield RPC(dests=tuple(net.servers), msg=("ping",), need=2)
        except RpcTimeout:
            return "failed-typed"
        return "ok"

    fut = net.spawn(op(), client="c")
    net.run()
    assert fut.result == "failed-typed"
    assert net.stuck_ops() == []  # timed-out rounds are not leaks


# ------------------------------------------------------ protocol/API tier
def test_session_write_fails_typed_when_quorum_gone():
    """Phase retries exhaust against a permanently lost quorum and the
    Session future carries ``QuorumUnavailableError`` — never a hang, and
    never an untyped exception."""
    dss = DSS(DSSParams(
        algorithm="coaresabd", n_servers=3, seed=2,
        retry=RetryPolicy(rpc_timeout=5e-3, jitter=0.0, max_attempts=2,
                          phase_retries=1, phase_backoff=1e-3,
                          op_deadline=5.0),
    ))
    sess = dss.session("c1")
    sess.write("f", b"v1").result()
    dss.crash_servers(["s0", "s1", "s2"])
    fut = sess.write("f", b"v2")
    with pytest.raises(QuorumUnavailableError):
        fut.result()
    assert fut.exception() is not None
    assert dss.net.now < 5.0  # failed within the deadline, not at it
    assert dss.net.op_retries >= 1  # the phase tier did re-issue


def test_session_recovers_after_transient_beyond_quorum_crash():
    dss = DSS(DSSParams(
        algorithm="coaresecf", n_servers=5, parity_m=2, seed=3,
        retry=RetryPolicy(jitter=0.0),
    ))
    sess = dss.session("c1")
    sess.write("f", b"x" * 256).result()
    dss.crash_servers([f"s{i}" for i in range(5)])
    dss.net.schedule(0.03, lambda: dss.recover_servers(
        [f"s{i}" for i in range(5)]))
    fut = sess.read("f")
    assert fut.result() == b"x" * 256  # rode out the full blackout
    assert fut.stats.retries > 0


# ------------------------------------------------------------- acceptance
def test_beyond_quorum_storm_acceptance():
    """The ISSUE 10 acceptance gate, as a test: a seeded beyond-quorum
    storm (every server crashes, then recovers) under sanitizer + race
    tracker. Zero stuck ops, zero stuck RPC rounds, >= 99% availability
    after recovery, and every failure typed ``QuorumUnavailableError``."""
    dss = DSS(DSSParams(
        algorithm="coaresecf", n_servers=5, parity_m=2, seed=7,
        min_block=256, avg_block=512, max_block=2048,
        indexed=True, batched=True, sanitize=True, racecheck=True,
        retry=RetryPolicy(),
    ))
    spec = WorkloadSpec(
        sessions=40, files=8, file_size=512, read_fraction=0.6,
        ops_per_session=2,
        storms=(CrashStorm(at=0.05, frac=1.0, duration=0.05,
                           beyond_quorum=True),),
    )
    rep = WorkloadGen(spec, seed=23).run(dss)
    assert rep["ops"] == 80
    assert rep["ops_stuck"] == 0
    assert rep["stuck_rpcs"] == 0
    assert rep["ops_failed"] == rep["quorum_unavailable"]  # all typed
    assert rep["availability_after_recovery"] >= 0.99
    assert rep["availability"] >= 0.9
    assert rep["retries"]["retransmits"] > 0  # the storm was survived, not dodged
    # the sanitizer raises on any violation, so a populated report here
    # means every fan-out/reply passed the live checks
    assert rep["sanitizer"]["checks"] > 0
    assert rep["races"]["checks"] > 0
