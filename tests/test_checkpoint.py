"""EC checkpoint store: save/restore, faults, coverability, elasticity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import (
    ECCheckpointStore,
    deserialize_tree,
    serialize_tree,
)
from repro.train.data import DataConfig, SyntheticLM
from repro.train.elastic import elastic_resize


def _state(seed=0, n=4096):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((n,)), jnp.float32),
        "emb": jnp.asarray(rng.standard_normal((64, 16)), jnp.bfloat16),
        "step_count": jnp.asarray(7, jnp.int32),
        "nested": {"b": jnp.asarray(rng.standard_normal((33,)), jnp.float32)},
    }


def _trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


def test_serialize_roundtrip():
    s = _state()
    blob = serialize_tree(s)
    s2 = deserialize_tree(blob)
    assert _trees_equal(s, s2)
    assert jax.tree.structure(s) == jax.tree.structure(jax.tree.map(lambda x: x, s2))


def test_save_restore():
    store = ECCheckpointStore(n_hosts=6, parity=2, seed=1)
    st = store.save(10, _state(0))
    assert st.success and st.bytes_written > 0
    step, got = store.restore()
    assert step == 10
    assert _trees_equal(_state(0), got["state"] if "state" in got else got)


def test_restore_after_host_crashes_within_budget():
    store = ECCheckpointStore(n_hosts=8, parity=4, seed=2)
    store.save(5, _state(1))
    budget = store.fault_budget()
    assert budget >= 1
    store.crash_hosts([f"s{i}" for i in range(budget)])
    step, got = store.restore()
    assert step == 5 and _trees_equal(_state(1), got)


def test_incremental_checkpoint_rewrites_few_blocks():
    """CDC fragmentation: step-to-step saves where only part of the state
    changed rewrite only the affected blocks (paper's FM win)."""
    store = ECCheckpointStore(n_hosts=6, parity=1, seed=3,
                              min_block=4096, avg_block=8192, max_block=32768)
    base = _state(4, n=200_000)
    s1 = store.save(1, base)
    assert s1.blocks_total > 4
    # change ONLY the tiny counter leaf; big arrays identical
    base2 = dict(base)
    base2["step_count"] = jnp.asarray(8, jnp.int32)
    s2 = store.save(2, base2)
    assert s2.success
    assert s2.blocks_written <= max(4, s2.blocks_total // 4), (
        f"rewrote {s2.blocks_written}/{s2.blocks_total} blocks for a "
        f"4-byte state change"
    )
    step, got = store.restore()
    assert step == 2 and _trees_equal(base2, got)


def test_coverable_saves_stale_trainer_degrades():
    """A resurrected pre-empted trainer saving an OLD step cannot clobber
    (meta-pointer flip is coverable + step-monotonic)."""
    store = ECCheckpointStore(n_hosts=6, parity=2, seed=5)
    t2 = store.new_trainer("trainer1")
    assert store.save(5, _state(10)).success
    assert store.save(8, _state(11)).success
    # trainer1 resurrects with stale progress (step 6 < 8): degrades to no-op
    st = t2.save(6, _state(99))
    assert not st.success
    step, got = store.restore()
    assert step == 8 and _trees_equal(_state(11), got)
    # after catching up it may write newer steps
    assert t2.save(9, _state(12)).success
    step, got = store.restore()
    assert step == 9 and _trees_equal(_state(12), got)


def test_concurrent_meta_flips_one_wins():
    """Two live trainers checkpointing the same step range concurrently:
    the coverable meta write arbitrates — no torn pointer."""
    store = ECCheckpointStore(n_hosts=6, parity=2, seed=8)
    t2 = store.new_trainer("trainer1")
    store.save(1, _state(0))
    t2.restore()
    net = store.dss.net
    import pickle as _p

    # race two meta flips for step 2 pointing at different fids
    blob_a = serialize_tree({"step": 2, "state": _state(1)})
    blob_b = serialize_tree({"step": 2, "state": _state(2)})
    fa = net.spawn(store.client.update("ckpt/shard0/trainer0", blob_a), client="trainer0")
    fb = net.spawn(t2.client.update("ckpt/shard0/trainer1", blob_b), client="trainer1")
    net.run()
    meta_a = _p.dumps({"step": 2, "fid": "ckpt/shard0/trainer0"})
    meta_b = _p.dumps({"step": 2, "fid": "ckpt/shard0/trainer1"})
    ma = net.spawn(store.client.dsm.cvr_write("ckptmeta/shard0", meta_a), client="trainer0")
    mb = net.spawn(t2.client.dsm.cvr_write("ckptmeta/shard0", meta_b), client="trainer1")
    net.run()
    flags = [ma.result[1], mb.result[1]]
    assert "chg" in flags  # at least one flip landed
    step, got = store.restore()
    assert step == 2
    # the restored state is exactly ONE of the two candidates (never torn)
    assert _trees_equal(got, _state(1)) or _trees_equal(got, _state(2))


def test_elastic_resize_preserves_state():
    store = ECCheckpointStore(n_hosts=5, parity=1, seed=6)
    state = _state(20, n=50_000)
    rstep, rstate, moved = elastic_resize(store, state, 42, new_hosts=9, new_parity=3)
    assert rstep == 42 and moved >= 1
    assert _trees_equal(state, rstate)
    # and the resized deployment keeps working
    assert store.save(43, state).success


def test_data_pipeline_checkpointable():
    d = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=4, seed=9))
    b1 = d.next_batch()
    snap = d.state()
    b2 = d.next_batch()
    d2 = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=4, seed=9))
    d2.restore(snap)
    b2r = d2.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])


def test_data_pipeline_host_sharding():
    full = SyntheticLM(DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1))
    h0 = SyntheticLM(DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1,
                                n_hosts=2, host_id=0))
    h1 = SyntheticLM(DataConfig(vocab=50, seq_len=8, global_batch=8, seed=1,
                                n_hosts=2, host_id=1))
    assert h0.next_batch()["tokens"].shape == (4, 8)
    assert not np.array_equal(h0._batch_rng(0).integers(0, 9, 4),
                              h1._batch_rng(0).integers(0, 9, 4))


def test_grad_compression_error_feedback():
    from repro.train.compress import (
        compress_tree, compressed_bytes, decompress_tree, init_residuals,
    )

    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((1000,)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    res = init_residuals(grads)
    # accumulated EF error stays bounded; mean signal preserved over steps
    acc_true = jax.tree.map(jnp.zeros_like, grads)
    acc_comp = jax.tree.map(jnp.zeros_like, grads)
    for step in range(20):
        qs, scales, res = compress_tree(grads, res)
        dec = decompress_tree(qs, scales, grads)
        acc_true = jax.tree.map(lambda a, g: a + g, acc_true, grads)
        acc_comp = jax.tree.map(lambda a, g: a + g, acc_comp, dec)
    for k in grads:
        err = np.abs(np.asarray(acc_true[k] - acc_comp[k])).max()
        scale = np.abs(np.asarray(acc_true[k])).max()
        assert err < 0.05 * scale, f"{k}: EF error {err} vs {scale}"
    raw, comp = compressed_bytes(grads)
    assert comp < raw / 3.5
