"""CoARES (Alg 1) behaviour: coverability, DAP Property 1, reconfiguration."""
import pytest

from checkers import check_all
from repro.core import DSS, DSSParams

WHOLE_ALGS = ["coabd", "coaresabd", "coaresec", "coaresec-noopt"]


def _dss(alg, n=5, seed=0, **kw):
    return DSS(DSSParams(algorithm=alg, n_servers=n, seed=seed, **kw))


# --------------------------------------------------------------- basic R/W
@pytest.mark.parametrize("alg", WHOLE_ALGS)
def test_write_then_read(alg):
    dss = _dss(alg)
    w = dss.client("w1")
    r = dss.client("r1")
    stats = dss.net.run_op(w.update("f", b"hello world"), client="w1")
    assert stats["success"]
    got = dss.net.run_op(r.read("f"), client="r1")
    assert got == b"hello world"
    check_all(dss.history)


@pytest.mark.parametrize("alg", WHOLE_ALGS)
def test_sequential_overwrites(alg):
    dss = _dss(alg)
    w = dss.client("w1")
    for i in range(5):
        stats = dss.net.run_op(w.update("f", f"v{i}".encode()), client="w1")
        assert stats["success"], f"write {i} collided unexpectedly"
    r = dss.client("r1")
    assert dss.net.run_op(r.read("f"), client="r1") == b"v4"
    check_all(dss.history)


@pytest.mark.parametrize("alg", WHOLE_ALGS)
def test_stale_writer_degrades_to_read(alg):
    """Coverability: a writer without the current version gets unchg and the
    value is NOT clobbered (§IV)."""
    dss = _dss(alg)
    w1, w2 = dss.client("w1"), dss.client("w2")
    assert dss.net.run_op(w1.update("f", b"first"), client="w1")["success"]
    assert dss.net.run_op(w1.update("f", b"second"), client="w1")["success"]
    # w2 has never read: its version is (0,"") but current is (2,...) -> unchg
    stats = dss.net.run_op(w2.update("f", b"usurper"), client="w2")
    assert not stats["success"]
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == b"second"
    # after reading, w2 can write
    dss.net.run_op(w2.read("f"), client="w2")
    assert dss.net.run_op(w2.update("f", b"legit"), client="w2")["success"]
    assert dss.net.run_op(r.read("f"), client="r") == b"legit"
    check_all(dss.history)


@pytest.mark.parametrize("alg", WHOLE_ALGS)
def test_concurrent_writers_one_wins(alg):
    dss = _dss(alg, seed=7)
    w1, w2, r = dss.client("w1"), dss.client("w2"), dss.client("r")
    dss.net.run_op(w1.update("f", b"base"), client="w1")
    dss.net.run_op(w2.read("f"), client="w2")
    dss.net.run_op(w1.read("f"), client="w1")
    # both writers now hold the same version; race them
    f1 = dss.net.spawn(w1.update("f", b"A" * 100), client="w1")
    f2 = dss.net.spawn(w2.update("f", b"B" * 100), client="w2")
    dss.net.run()
    assert f1.done and f2.done
    # Per coverability (Def. 4 + Lemma 6 case b): *ordered* writes cannot both
    # prevail, but truly concurrent ones may — with distinct versions, the
    # higher (tie on ts broken by writer id, so w2 > w1) winning.
    wins = int(f1.result["success"]) + int(f2.result["success"])
    assert wins >= 1
    got = dss.net.run_op(r.read("f"), client="r")
    if f2.result["success"]:
        assert got == b"B" * 100  # w2 holds the max version either way
    else:
        assert got == b"A" * 100
    check_all(dss.history)


# ------------------------------------------------------------ fault tolerance
def test_abd_tolerates_minority_crashes():
    dss = _dss("coaresabd", n=5)
    w, r = dss.client("w"), dss.client("r")
    dss.net.run_op(w.update("f", b"durable"), client="w")
    dss.crash_servers(["s0", "s1"])  # minority of 5
    assert dss.net.run_op(r.read("f"), client="r") == b"durable"


def test_ec_tolerates_floor_n_minus_k_over_2():
    # n=6, m=2 -> k=4, tolerates (n-k)/2 = 1 crash
    dss = _dss("coaresec", n=6, parity_m=2)
    w, r = dss.client("w"), dss.client("r")
    dss.net.run_op(w.update("f", b"durable" * 50), client="w")
    dss.crash_servers(["s5"])
    assert dss.net.run_op(r.read("f"), client="r") == b"durable" * 50


@pytest.mark.allow_stuck
def test_ec_blocks_beyond_tolerance():
    dss = _dss("coaresec", n=6, parity_m=2)
    w, r = dss.client("w"), dss.client("r")
    dss.net.run_op(w.update("f", b"x" * 64), client="w")
    dss.crash_servers(["s3", "s4", "s5"])  # > (n-k)/2
    fut = dss.net.spawn(r.read("f"), client="r")
    dss.net.run(until=dss.net.now + 5.0)
    assert not fut.done  # cannot gather an EC quorum


# ------------------------------------------------------------- reconfiguration
@pytest.mark.parametrize("alg", ["coaresabd", "coaresec"])
def test_recon_preserves_value(alg):
    dss = _dss(alg, n=5)
    w, g, r = dss.client("w"), dss.client("g"), dss.client("r")
    dss.net.run_op(w.update("f", b"payload-123"), client="w")
    new_cfg = dss.make_config(fresh_servers=True)  # brand-new server set
    dss.net.run_op(g.recon("f", new_cfg), client="g")
    # a client that never heard of the new config still needs an old-config
    # quorum for the traversal (paper's Claim-10 liveness note): crash only a
    # minority of the old servers first...
    dss.crash_servers(["s0", "s1"])
    assert dss.net.run_op(r.read("f"), client="r") == b"payload-123"
    # ...after which r knows the finalized new config and the *entire* old
    # configuration may die: data must survive on the new servers alone.
    dss.crash_servers([f"s{i}" for i in range(5)])
    assert dss.net.run_op(r.read("f"), client="r") == b"payload-123"
    check_all(dss.history)


def test_recon_switches_dap_abd_to_ec_and_back():
    dss = _dss("coaresabd", n=6)
    w, g, r = dss.client("w"), dss.client("g"), dss.client("r")
    dss.net.run_op(w.update("f", b"v1" * 40), client="w")
    cfg_ec = dss.make_config(dap="ec_opt", parity_m=2)
    dss.net.run_op(g.recon("f", cfg_ec), client="g")
    assert dss.net.run_op(r.read("f"), client="r") == b"v1" * 40
    dss.net.run_op(w.read("f"), client="w")
    dss.net.run_op(w.update("f", b"v2" * 40), client="w")
    cfg_abd = dss.make_config(dap="abd")
    dss.net.run_op(g.recon("f", cfg_abd), client="g")
    assert dss.net.run_op(r.read("f"), client="r") == b"v2" * 40
    check_all(dss.history)


def test_write_concurrent_with_recon():
    dss = _dss("coaresec", n=5, seed=11)
    w, g, r = dss.client("w"), dss.client("g"), dss.client("r")
    dss.net.run_op(w.update("f", b"base"), client="w")
    dss.net.run_op(w.read("f"), client="w")
    new_cfg = dss.make_config(fresh_servers=True)
    fg = dss.net.spawn(g.recon("f", new_cfg), client="g")
    fw = dss.net.spawn(w.update("f", b"during-recon"), client="w", delay=0.0005)
    dss.net.run()
    assert fg.done and fw.done
    got = dss.net.run_op(r.read("f"), client="r")
    if fw.result["success"]:
        assert got == b"during-recon"
    else:
        assert got == b"base"
    check_all(dss.history)


def test_multiple_recons_in_sequence():
    dss = _dss("coaresec", n=5, seed=2)
    w, g, r = dss.client("w"), dss.client("g"), dss.client("r")
    dss.net.run_op(w.update("f", b"v0"), client="w")
    for i in range(4):
        cfg = dss.make_config(
            dap=["abd", "ec_opt"][i % 2], n_servers=[5, 7, 9, 5][i]
        )
        dss.net.run_op(g.recon("f", cfg), client="g")
        assert dss.net.run_op(r.read("f"), client="r") == b"v0"
    # a writer that last read pre-recon can still write (sequence prefix)
    dss.net.run_op(w.read("f"), client="w")
    assert dss.net.run_op(w.update("f", b"v1"), client="w")["success"]
    assert dss.net.run_op(r.read("f"), client="r") == b"v1"
    check_all(dss.history)


def test_concurrent_recon_proposals_agree():
    """Two reconfigurers proposing different configs for the same index must
    agree via consensus (configuration uniqueness)."""
    dss = _dss("coaresabd", n=5, seed=5)
    w = dss.client("w")
    dss.net.run_op(w.update("f", b"x"), client="w")
    g1, g2 = dss.client("g1"), dss.client("g2")
    c1 = dss.make_config(n_servers=7)
    c2 = dss.make_config(dap="ec_opt", parity_m=1)
    f1 = dss.net.spawn(g1.recon("f", c1), client="g1")
    f2 = dss.net.spawn(g2.recon("f", c2), client="g2")
    dss.net.run()
    assert f1.done and f2.done
    # index-1 config must be identical in both clients' sequences
    s1 = g1.dsm.cseq["f"]
    s2 = g2.dsm.cseq["f"]
    common = min(len(s1), len(s2))
    for i in range(common):
        assert s1[i].config.cfg_id == s2[i].config.cfg_id, "uniqueness violated"
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == b"x"
    check_all(dss.history)


# ------------------------------------------------------- EC-DAPopt specifics
def test_ec_opt_fewer_bytes_on_repeat_reads():
    """§VI: servers omit pairs older than the client's tag, so repeat reads
    of an unchanged object move far fewer bytes."""

    def bytes_for(alg):
        dss = _dss(alg, n=6, parity_m=1, seed=9)
        w, r = dss.client("w"), dss.client("r")
        dss.net.run_op(w.update("f", b"Z" * 100_000), client="w")
        dss.net.run_op(r.read("f"), client="r")  # first read pays decode
        before = dss.net.bytes_sent
        for _ in range(5):
            dss.net.run_op(r.read("f"), client="r")
        return dss.net.bytes_sent - before

    opt = bytes_for("coaresec")
    noopt = bytes_for("coaresec-noopt")
    assert opt < noopt / 3, (opt, noopt)


def test_ec_opt_read_latency_lower():
    def lat_for(alg):
        dss = _dss(alg, n=6, parity_m=1, seed=9)
        w, r = dss.client("w"), dss.client("r")
        dss.net.run_op(w.update("f", b"Z" * 200_000), client="w")
        dss.net.run_op(r.read("f"), client="r")
        fut = dss.net.spawn(r.read("f"), client="r")
        dss.net.run()
        return fut.latency

    assert lat_for("coaresec") < lat_for("coaresec-noopt")


def test_ec_delta_garbage_collection():
    """Servers keep <= δ+1 coded values per object (Alg 5:12-18)."""
    dss = _dss("coaresec", n=5, parity_m=1, delta=2)
    w = dss.client("w")
    for i in range(8):
        dss.net.run_op(w.update("f", f"v{i}".encode() * 10), client="w")
    srv = dss.net.servers["s0"]
    lst = srv.ec[("f", 0)]
    full = [t for t, e in lst.items() if e is not None]
    assert len(full) <= 3  # δ+1
    # trimmed tags remain as (tag, ⊥) placeholders
    assert len(lst) >= len(full)
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == b"v7" * 10
