"""Wire codec (ISSUE 3): round-trip identity, size accounting, fallback.

Property-based via hypothesis when installed, the seeded shim otherwise
(tests/_propfallback.py) — same pattern as the DAP property suites.
"""
import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seeded fallback shim — see tests/_propfallback.py
    from _propfallback import given, settings
    from _propfallback import strategies as st

from repro.core.tags import TAG0, Config
from repro.net import codec
from repro.net.sim import RPC, LatencyModel, Network, Server, msg_wire_size, nbytes


def _rt(msg):
    frame = codec.encode_frame(msg)
    assert codec.wire_size(msg) == len(frame), msg
    got = codec.decode_frame(frame)
    assert got == msg, (got, msg)
    return len(frame)


# ------------------------------------------------------------ protocol msgs
CFG = Config("c1", ("s0", "s1", "s2", "s3", "s4"), dap="ec_opt", k=3, delta=8)


def test_roundtrip_protocol_messages():
    """Every message shape the storage servers actually exchange."""
    tag = (3, "w0")
    elem = (b"\x00\x01" * 40, 77)
    msgs = [
        ("abd-get", "obj", 0, tag),
        ("abd-val", tag, None),
        ("abd-get-batch", (("a", tag), ("b", TAG0)), 0),
        ("ec-query-batch", (("a", tag), ("b", None)), 1),
        ("ec-list", [(tag, elem), ((4, "w1"), None)]),
        ("ec-put", "obj", 0, tag, elem, 8),
        ("ec-put-batch", (("a", tag, elem),), 0, 8),
        ("read-next-batch", (("a", 0), ("b", 2))),
        ("next-c", (CFG, "P")),
        ("next-c-batch", ((CFG, "F"), None)),
        ("write-next-batch", (("a", 0, CFG, "P"),)),
        ("cons-p1-batch", ("a", "b"), 0, (2, "g")),
        ("p1-ok", None, None),
        ("p1-batch", (("p1-ok", (1, "g"), CFG), ("p1-nack", (3, "h")))),
        ("cons-p2-batch", (("a", CFG),), 0, (2, "g")),
        ("margin-batch", ("a", "b"), 0),
        ("margin-batch", ((tag, ((tag, True), (TAG0, False)), "F"),
                          (None, None, None))),
        ("ec-repair-pull", "obj", 0),
        ("ec-repair-list", [(tag, elem), (TAG0, None)]),
        ("ack", 3),
    ]
    for m in msgs:
        _rt(m)


def test_roundtrip_scalars_and_containers():
    for m in (None, True, False, 0, -1, 127, -128, 2**70, -(2**70), 0.0, -2.5,
              "", "héllo", b"", b"\xff" * 300, (), (1, (2, (3,))), [],
              [1, "x", None], {"k": b"v", ("t", 1): [True]}, CFG):
        _rt(m)


def test_roundtrip_ndarray():
    a = np.arange(24, dtype=np.uint8).reshape(2, 3, 4)
    frame = codec.encode_frame(a)
    assert codec.wire_size(a) == len(frame)
    got = codec.decode_frame(frame)
    assert got.dtype == a.dtype and got.shape == a.shape and (got == a).all()


def test_length_prefix_framing():
    """The frame really is uvarint(len(body)) || body."""
    frame = codec.encode_frame(b"x" * 200)
    n, pos = codec._read_uvarint(frame, 0)
    assert n == len(frame) - pos
    assert codec.decode(frame[pos:]) == b"x" * 200
    # big payloads cost ~len + framing, not the old 16-per-tuple heuristic
    payload = ("ec-put", "o", 0, (1, "w"), (b"z" * 10_000, 10_000), 8)
    assert abs(codec.wire_size(payload) - 10_000) < 100


def test_memoryview_wire_size_counts_bytes_not_elements():
    """Regression: len() of a non-byte-format memoryview counts ELEMENTS;
    wire_size must match the encoded byte length."""
    import array

    mv = memoryview(array.array("H", [1, 2, 3, 4]))  # 4 elements, 8 bytes
    assert codec.wire_size(mv) == len(codec.encode_frame(mv))
    assert codec.decode_frame(codec.encode_frame(mv)) == bytes(mv)


def test_unencodable_raises_and_try_returns_none():
    class Weird:
        pass

    import pytest

    with pytest.raises(codec.CodecError):
        codec.encode(Weird())
    assert codec.try_wire_size(Weird()) is None
    assert codec.try_wire_size({1, 2}) is None  # sets are outside the vocab
    # and the sim falls back to the nbytes heuristic for those
    assert msg_wire_size(Weird()) == nbytes(Weird())


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(min_value=-(2**40), max_value=2**40),
            st.binary(min_size=0, max_size=64),
            st.sampled_from(["a", "", "héllo", "s0"]),
        ),
        min_size=0,
        max_size=6,
    )
)
def test_roundtrip_property(tree):
    """Random nested (int, bytes, str) trees round-trip exactly and
    wire_size always equals the materialised frame length."""
    msg = ("env", tuple(tree), {"n": len(tree)}, [TAG0, None, True])
    _rt(msg)


# ---------------------------------------------------- network integration
class Echo(Server):
    def handle(self, sender, msg):
        return ("echo", msg)


def test_network_charges_framed_bytes():
    """bytes_sent now counts codec frames: a request/reply pair's cost is
    the two frame lengths, not the python-structure heuristic."""
    net = Network(seed=0, latency=LatencyModel())
    net.add_server(Echo("s0"))
    msg = ("ec-put", "obj", 0, (1, "w"), (b"q" * 1000, 1000), 8)

    def op():
        yield RPC(dests=("s0",), msg=msg, need=1)
        return None

    net.run_op(op(), client="c")
    expect = codec.wire_size(msg) + codec.wire_size(("echo", msg))
    assert net.bytes_sent == expect
    assert net.client_totals("c") == (1, 2, expect)


# ----------------------------------------------- fallback nesting (ISSUE 4)
def test_fallback_container_charges_codec_framing_for_nested_ndarrays():
    """A payload OUTSIDE the wire vocabulary (here: it nests a set) falls
    back to the ``nbytes`` heuristic for its structure — but any ndarray
    inside it must be charged the codec's real ndarray frame, not the
    legacy ``16 + nbytes`` guess. Pin the exact charged size."""
    arr = np.arange(256, dtype=np.uint8)
    msg = ("train-push", {"step"}, arr)
    assert codec.try_wire_size(msg) is None, "set must be un-frameable"
    # codec framing of the array itself, pinned byte by byte:
    #   1 ('a') + [1+1+3 dtype '|u1'] + [1+1+(1+2) shape (256,)]
    #   + 2 (uvarint 256) + 256 payload = 269 body, +2 frame prefix = 271
    assert codec.wire_size(arr) == 271
    assert nbytes(arr) == 271
    # whole fallback container: 16 (tuple) + 10 ("train-push")
    #   + 20 (set: 16 + "step") + 271 (framed array)
    assert msg_wire_size(msg) == 16 + 10 + 20 + 271 == 317


def test_object_dtype_ndarray_stays_outside_the_vocabulary():
    """Pointer bytes must never be framed (they cannot round-trip): an
    object-dtype array falls back to the heuristic instead."""
    arr = np.array([b"x", ("nested",)], dtype=object)
    assert codec.try_wire_size(arr) is None
    assert nbytes(arr) == 16 + int(arr.nbytes)


# --------------------------------------- registry coverage (ISSUE 8, sat. c)
def test_every_server_message_type_round_trips():
    """Auto-enumerated registry coverage: ONE exemplar per op the storage
    server dispatches, asserted to cover ``StorageServer._DISPATCH`` and
    ``codec.MESSAGE_TYPES`` exactly — adding a handler without extending
    this table (or the registry) fails here, adding a registry entry
    without a handler fails too. Every exemplar AND the live reply the
    server produces for it must round-trip through the wire codec, and the
    replies must cover ``codec.REPLY_TYPES`` exactly."""
    from repro.core.server import StorageServer

    tag, tag2 = (3, "w0"), (4, "w1")
    elem = (b"\x07" * 24, 99)
    ballot_hi, ballot_lo = (5, "z"), (1, "a")
    EXEMPLARS = {
        "ec-query-batch": ("ec-query-batch", (("a", tag), ("b", None)), 0),
        "ec-put-batch": ("ec-put-batch", (("a", tag, elem),), 0, 8),
        "abd-get-batch": ("abd-get-batch", (("a", tag), ("b", None)), 0),
        "abd-put-batch": ("abd-put-batch", (("a", tag, b"v"),), 0),
        "read-next-batch": ("read-next-batch", (("a", 0), ("b", 1))),
        "write-next-batch": ("write-next-batch", (("a", 0, CFG, "P"),)),
        "cons-p1-batch": ("cons-p1-batch", ("a", "b"), 0, ballot_hi),
        "cons-p2-batch": ("cons-p2-batch", (("a", CFG),), 0, ballot_hi),
        "margin-batch": ("margin-batch", ("a", "b"), 0),
        "abd-get": ("abd-get", "a", 0, None),
        "abd-get-tag": ("abd-get-tag", "a", 0),
        "abd-put": ("abd-put", "a", 0, tag2, b"v2"),
        "ec-query": ("ec-query", "a", 0, None),
        "ec-put": ("ec-put", "a", 0, tag2, elem, 8),
        "ec-repair-pull": ("ec-repair-pull", "a", 0),
        "ec-repair-push": ("ec-repair-push", "a", 0, (5, "w2"), elem, 8),
        "read-next": ("read-next", "a", 0),
        "write-next": ("write-next", "a", 0, CFG, "F"),
        "cons-p1": ("cons-p1", "a", 1, ballot_hi),
        "cons-p2": ("cons-p2", "a", 1, ballot_hi, CFG),
    }
    assert set(EXEMPLARS) == set(StorageServer._DISPATCH) == codec.MESSAGE_TYPES
    assert set(StorageServer._READ_ONLY) <= set(StorageServer._DISPATCH)
    # extra probes eliciting the nack replies (lower ballot after higher)
    script = [EXEMPLARS[op] for op in sorted(EXEMPLARS)] + [
        ("cons-p1", "a", 1, ballot_lo),
        ("cons-p2", "a", 1, ballot_lo, CFG),
    ]
    srv = StorageServer("s0")
    seen = set()
    for msg in script:
        _rt(msg)
        reply = srv.handle("c", msg)
        assert isinstance(reply, tuple) and reply[0] in codec.REPLY_TYPES, msg
        seen.add(reply[0])
        _rt(reply)
    assert seen == codec.REPLY_TYPES


def test_gossip_registry_round_trips():
    """The gateway tier's anti-entropy pair, pinned to its registry."""
    assert codec.GOSSIP_TYPES == {"gossip-configs"}
    assert codec.GOSSIP_REPLY_TYPES == {"gossip-ack"}
    _rt(("gossip-configs", ((0, "c0", CFG), (1, "c1", CFG))))
    _rt(("gossip-ack", 2, ((0, "c0", CFG),)))
