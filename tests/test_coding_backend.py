"""Coding-backend routing (ISSUE 6): kernel <-> numpy bit-identity, auto
dispatch, fused decode launches, CRC integrity end-to-end.

The "kernel" backend must be a drop-in for the byte-LUT "numpy" backend at
every layer: raw RSCode byte paths (property test), the EC DAP data path
under a full read/update/recon/repair cycle (e2e test), and the repair
loop's bit-rot healing (corruption test).
"""
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seeded fallback shim — see tests/_propfallback.py
    from _propfallback import given, settings
    from _propfallback import strategies as st

from repro.core import DSS, DSSParams
from repro.erasure.rs import AUTO_KERNEL_MIN_BYTES, RSCode, element_crc_ok
from repro.kernels.gf256_matmul import ops as gf_ops


def _blob(seed, size):
    return np.random.default_rng(seed).integers(0, 256, size, dtype=np.uint8).tobytes()


# ---------------------------------------------------------- property tests
@settings(max_examples=8, deadline=None)
@given(
    st.lists(st.binary(min_size=0, max_size=400), min_size=1, max_size=5),
    st.integers(2, 5),
    st.integers(0, 3),
    st.integers(0, 2**31 - 1),
)
def test_kernel_numpy_bit_identity(values, k, m, seed):
    """encode_bytes_batch / decode_bytes_batch / reconstruct_fragments are
    bit-identical across backends: ragged lengths, empty values, mixed index
    subsets, and m == 0 codes."""
    n = k + m
    c_np = RSCode(n=n, k=k, backend="numpy")
    c_kr = RSCode(n=n, k=k, backend="kernel")
    enc_np = c_np.encode_bytes_batch(values, with_crc=True)
    enc_kr = c_kr.encode_bytes_batch(values, with_crc=True)
    assert enc_np == enc_kr
    rng = np.random.default_rng(seed)
    items = []
    for frags, orig, crcs in enc_np:
        if rng.random() < 0.7:
            idxs = sorted(rng.permutation(n)[:k].tolist())  # mixed data+parity
        else:
            idxs = list(range(min(n, k + 1)))  # systematic (+1 spare)
        sub = {i: frags[i] for i in idxs}
        items.append((sub, orig, {i: crcs[i] for i in idxs}))
    assert c_np.decode_bytes_batch(items) == values
    assert c_kr.decode_bytes_batch(items) == values
    if m:
        data = rng.integers(0, 256, (k, 64), dtype=np.uint8)
        coded = c_np.encode(data)
        keep = sorted(rng.permutation(n)[:k].tolist())
        targets = [i for i in range(n) if i not in keep][:m]
        np.testing.assert_array_equal(
            c_np.reconstruct_fragments(targets, coded[keep], keep),
            c_kr.reconstruct_fragments(targets, coded[keep], keep),
        )


def test_backend_validation():
    with pytest.raises(ValueError):
        RSCode(n=6, k=4, backend="cuda")
    with pytest.raises(ValueError):
        DSS(DSSParams(coding_backend="fpga"))


# ---------------------------------------------------------- auto dispatch
def _counting(monkeypatch):
    calls = []
    real = gf_ops.gf256_coding_matmul

    def wrapper(A, B, **kw):
        calls.append(np.asarray(B).shape)
        return real(A, B, **kw)

    monkeypatch.setattr(gf_ops, "gf256_coding_matmul", wrapper)
    return calls


def test_auto_backend_size_crossover(monkeypatch):
    calls = _counting(monkeypatch)
    code = RSCode(n=6, k=4, backend="auto")
    small = np.ones((4, 64), dtype=np.uint8)  # 256 B operand: LUT territory
    big_l = AUTO_KERNEL_MIN_BYTES // 4
    big = np.ones((4, big_l), dtype=np.uint8)  # exactly at the crossover
    code.encode(small)
    assert calls == [], "tiny operand must stay on the LUT path"
    code.encode(big)
    assert len(calls) == 1, "large operand must take the kernel path"
    np.testing.assert_array_equal(
        code.encode(big), RSCode(n=6, k=4).encode(big)
    )


def test_fused_group_decode_single_launch(monkeypatch):
    """decode_bytes_batch with SEVERAL distinct index-set groups and ragged
    lengths issues ONE kernel launch when group fusion is on (the TPU
    block-diagonal path; forced on here to pin correctness on CPU)."""
    vals = [_blob(i, 200 + 37 * i) for i in range(6)]
    subsets = [(1, 2, 3, 4), (0, 2, 3, 5), (1, 2, 3, 4), (0, 1, 2, 4),
               (2, 3, 4, 5), (0, 2, 3, 5)]
    enc = RSCode(n=6, k=4).encode_bytes_batch(vals)
    items = [
        ({i: frags[i] for i in sub}, orig)
        for (frags, orig), sub in zip(enc, subsets)
    ]
    want = RSCode(n=6, k=4, backend="numpy").decode_bytes_batch(items)
    assert want == vals
    calls = _counting(monkeypatch)
    fused = RSCode(n=6, k=4, backend="kernel", fuse_groups=True)
    assert fused.decode_bytes_batch(items) == vals
    assert len(calls) == 1, f"expected ONE fused launch, saw {len(calls)}"
    calls.clear()
    unfused = RSCode(n=6, k=4, backend="kernel", fuse_groups=False)
    assert unfused.decode_bytes_batch(items) == vals
    assert len(calls) == len(set(subsets)), "one launch per index-set group"


# ------------------------------------------------------------- e2e cycles
def _cycle(backend: str):
    """Full EC life cycle on one backend; returns every byte the store ever
    handed back plus the final server-side element map."""
    dss = DSS(DSSParams(algorithm="coaresecf", n_servers=6, parity_m=2,
                        seed=21, min_block=512, avg_block=1024, max_block=4096,
                        coding_backend=backend))
    w = dss.client("w")
    r = dss.client("r")
    outs = []
    blob = _blob(50, 20_000)
    dss.net.run_op(w.update("f", blob), client="w")
    outs.append(dss.net.run_op(r.read("f"), client="r"))
    blob2 = blob[:8000] + _blob(51, 1500) + blob[9000:]
    dss.net.run_op(w.update("f", blob2), client="w")
    outs.append(dss.net.run_op(r.read("f"), client="r"))
    # recon to a fresh server set (state transfer re-encodes on the backend)
    cfg = dss.make_config(fresh_servers=True)
    dss.net.run_op(w.recon("f", cfg), client="w")
    outs.append(dss.net.run_op(r.read("f"), client="r"))
    # crash + wipe + recover two servers, then repair
    down = list(cfg.servers[:1])
    dss.crash_servers(down)
    dss.wipe_servers(down)
    dss.recover_servers(down)
    dss.repair()
    outs.append(dss.net.run_op(r.read("f"), client="r"))
    elems = {
        (sid, key, t): e
        for sid, srv in sorted(dss.net.servers.items())
        for key, lst in sorted(srv.ec.items())
        for t, e in sorted(lst.items())
    }
    return [bytes(o) for o in outs], elems, blob, blob2


def test_e2e_kernel_bit_identical_to_numpy():
    """Acceptance (ISSUE 6): read/update/recon/repair under
    coding_backend="kernel" returns bytes identical to the numpy run — and
    leaves bit-identical coded elements on every server."""
    outs_np, elems_np, blob, blob2 = _cycle("numpy")
    outs_kr, elems_kr, _, _ = _cycle("kernel")
    assert outs_np[0] == blob and outs_np[1] == outs_np[2] == outs_np[3] == blob2
    assert outs_kr == outs_np
    assert elems_kr == elems_np


def test_checkpoint_coding_backend_plumbs():
    from repro.train.checkpoint import ECCheckpointStore

    store = ECCheckpointStore(n_hosts=5, parity=1, coding_backend="kernel")
    assert store.dss.net.coding_backend == "kernel"
    assert store.dss.params.coding_backend == "kernel"
    state = {"w": np.arange(4096, dtype=np.float32)}
    assert store.save(1, state).success
    step, got = store.restore()
    assert step == 1
    np.testing.assert_array_equal(got["w"], state["w"])


# ------------------------------------------------------- corruption / CRC
def _find_full_element(dss, obj="f", idx=0):
    for sid, srv in sorted(dss.net.servers.items()):
        lst = srv.ec.get((obj, idx), {})
        for t, e in lst.items():
            if e is not None and len(e) >= 3 and e[0]:
                return sid, t, e
    raise AssertionError("no checksummed element stored")


def test_put_elements_carry_crc():
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=7))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(1, 3000)), client="w")
    _sid, _t, e = _find_full_element(dss)
    assert e[2] == zlib.crc32(e[0]) and element_crc_ok(e)


def test_read_drops_corrupt_fragment():
    """A bit-rotted stored element fails its CRC at collection: the read
    treats it as absent and still returns the written bytes."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=8))
    w = dss.client("w")
    blob = _blob(2, 5000)
    dss.net.run_op(w.update("f", blob), client="w")
    sid, t, e = _find_full_element(dss)
    rotted = bytes([e[0][0] ^ 0xFF]) + e[0][1:]
    dss.net.servers[sid].ec[("f", 0)][t] = (rotted, e[1], e[2])
    assert not element_crc_ok(dss.net.servers[sid].ec[("f", 0)][t])
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == blob


def test_repair_heals_corrupt_element():
    """The repair scan counts a corrupt holder as missing, and the server
    overwrites an element that fails its own stored checksum — and ONLY
    such an element (healthy elements keep their no-overwrite guarantee)."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4, seed=9))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(3, 4000)), client="w")
    sid, t, e = _find_full_element(dss)
    rotted = bytes([e[0][0] ^ 0xFF]) + e[0][1:]
    dss.net.servers[sid].ec[("f", 0)][t] = (rotted, e[1], e[2])
    stats = dss.repair()
    assert stats[0]["missing"] >= 1 and stats[0]["applied"] >= 1
    healed = dss.net.servers[sid].ec[("f", 0)][t]
    assert element_crc_ok(healed) and healed[0] == e[0]
    # a second pass finds nothing to do
    stats2 = dss.repair()
    assert stats2[0]["missing"] == 0
    # direct push against a HEALTHY element is still refused
    srv = dss.net.servers[sid]
    kind, applied = srv.handle(
        "rc", ("ec-repair-push", "f", 0, t, (b"Z" * len(e[0]), e[1], 0), 8)
    )
    assert kind == "repair-ack" and not applied
    assert srv.ec[("f", 0)][t][0] == e[0]
