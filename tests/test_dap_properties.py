"""Property-based validation of DAP Property 1 (C1/C2) under random
concurrent schedules — the safety contract every ARES variant depends on."""
import numpy as np
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seeded fallback shim — see tests/_propfallback.py
    from _propfallback import given, settings
    from _propfallback import strategies as st

from checkers import check_atomicity, check_coverability
from repro.core import DSS, DSSParams
from repro.core.dap.base import make_dap
from repro.core.server import StorageServer
from repro.core.tags import Config
from repro.net.sim import Network


def _net(n, seed, dap, k):
    net = Network(seed=seed)
    sids = tuple(f"s{i}" for i in range(n))
    for s in sids:
        net.add_server(StorageServer(s))
    cfg = Config("c0", sids, dap=dap, k=k, delta=8)
    return net, cfg


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16), st.sampled_from(["abd", "ec", "ec_opt"]))
def test_c1_put_then_get_sees_tag(seed, dap):
    """C1: a get-data after a completed put-data returns tag >= put tag."""
    net, cfg = _net(5, seed, dap, k=3 if dap != "abd" else 1)
    state = {}
    w = make_dap(net, "w", cfg, 0, state)
    rng = np.random.default_rng(seed)
    tag = (0, "")
    for i in range(4):
        tag = (tag[0] + 1, "w")
        val = rng.integers(0, 256, rng.integers(1, 200), dtype=np.uint8).tobytes()
        net.run_op(w.put_data("obj", tag, val), client="w")
        r = make_dap(net, f"r{i}", cfg, 0, {})
        got_tag, got_val = net.run_op(r.get_data("obj"), client=f"r{i}")
        assert got_tag >= tag
        if got_tag == tag:
            assert got_val == val  # C2: value was actually written


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**16), st.sampled_from(["ec", "ec_opt"]))
def test_c1_under_concurrent_puts(seed, dap):
    """Concurrent put-data racers: any subsequent get-data returns a tag at
    least as large as every COMPLETED put (C1), and a written value (C2)."""
    net, cfg = _net(6, seed, dap, k=4)
    rng = np.random.default_rng(seed)
    values = {}
    futs = []
    for i in range(4):
        st_ = {}
        w = make_dap(net, f"w{i}", cfg, 0, st_)
        tag = (i + 1, f"w{i}")
        val = rng.integers(0, 256, 64, dtype=np.uint8).tobytes()
        values[tag] = val
        futs.append(net.spawn(w.put_data("obj", tag, val), client=f"w{i}",
                              delay=float(rng.uniform(0, 1e-3))))
    net.run()
    assert all(f.done for f in futs)
    r = make_dap(net, "r", cfg, 0, {})
    got_tag, got_val = net.run_op(r.get_data("obj"), client="r")
    assert got_tag >= max(values)        # all puts completed before the read
    assert got_val == values[got_tag]    # C2


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**16),
       st.sampled_from(["coabd", "coaresabd", "coaresec", "coaresecf"]),
       st.lists(st.tuples(st.integers(0, 2), st.integers(0, 3)), min_size=3,
                max_size=10))
def test_random_schedules_atomic_and_coverable(seed, alg, script):
    """Random interleavings of reads/writes from 3 clients: the recorded
    history must satisfy atomicity + coverability (checkers)."""
    dss = DSS(DSSParams(algorithm=alg, n_servers=5, parity_m=1, seed=seed,
                        min_block=64, avg_block=128, max_block=512))
    clients = [dss.client(f"c{i}") for i in range(3)]
    rng = np.random.default_rng(seed)
    # WELL-FORMEDNESS (§II): each client runs ONE op at a time — chain each
    # client's ops into a single sequential generator; clients race each
    # other, never themselves (Lemma 6 case (a) depends on this).
    per_client: dict[int, list] = {0: [], 1: [], 2: []}
    for ci, kind in script:
        per_client[ci].append(kind)

    from repro.net.sim import Sleep

    def client_loop(ci, kinds):
        c = clients[ci]
        for kind in kinds:
            yield Sleep(float(rng.uniform(0, 5e-3)))
            if kind == 0:
                yield from c.read("f")
            else:
                blob = rng.integers(0, 256, 64 * kind, dtype=np.uint8).tobytes()
                yield from c.read("f")
                yield from c.update("f", blob)
        return True

    futs = [dss.net.spawn(client_loop(ci, kinds), client=f"c{ci}")
            for ci, kinds in per_client.items() if kinds]
    dss.net.run()
    assert all(f.done for f in futs)
    check_atomicity(dss.history)
    check_coverability(dss.history)
