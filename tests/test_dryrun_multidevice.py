"""Multi-device sharding machinery tests (subprocess: 16 fake host devices,
scaled-down mesh (2, 4, 2) exercising the same code paths as production;
keeps the main test process at 1 device per the assignment note)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.models.registry import build_model, make_inputs
    from repro.models.sharding import MeshCtx
    from repro.train.steps import (batch_shardings, make_train_step,
                                   training_state_specs)
    from repro.train.optimizer import adamw_init

    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    ctx = MeshCtx(mesh)
    cfg = get_arch("{arch}").reduced()
    model = build_model(cfg, max_pos=32)
    shape = ShapeConfig("t", 32, 8, "train")
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = make_train_step(model, ctx)
    pstore, ospecs = training_state_specs(model, ctx)
    bshard = batch_shardings(cfg, shape, ctx)
    jitted = jax.jit(step, in_shardings=(pstore, ospecs, bshard),
                     out_shardings=(pstore, ospecs, ctx.replicated()))
    batch = make_inputs(cfg, shape, seed=1)
    for k in ("tokens", "labels"):
        if k in batch:
            batch[k] = batch[k] % cfg.vocab
    # run distributed AND single-device; losses must agree
    p2, o2, loss_dist = jitted(params, opt, batch)
    from repro.train.steps import make_train_step as mts
    step1 = jax.jit(mts(model, None))
    p1, o1, loss_1dev = step1(params, opt, batch)
    print(json.dumps({{
        "loss_dist": float(loss_dist),
        "loss_1dev": float(loss_1dev),
        "params_close": bool(all(
            np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        rtol=3e-2, atol=3e-2)
            for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p1)))),
    }}))
    """
)


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "olmoe_1b_7b", "mamba2_2_7b"])
def test_distributed_train_step_matches_single_device(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(arch=arch)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=420,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert abs(res["loss_dist"] - res["loss_1dev"]) < 0.05, res
    assert res["params_close"], res
