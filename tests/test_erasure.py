"""GF(256) field + RS code correctness (unit + hypothesis property tests)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seeded fallback shim — see tests/_propfallback.py
    from _propfallback import given, settings
    from _propfallback import strategies as st

from repro.erasure import (
    RSCode,
    bytes_to_rows,
    cauchy_parity_matrix,
    gf_inv,
    gf_invert_matrix,
    gf_matmul_np,
    gf_mul,
    rows_to_bytes,
    vandermonde_matrix,
)
from repro.erasure.gf import (
    bits_to_bytes_np,
    bytes_to_bits_np,
    gf_const_to_bitmatrix,
    gf_matrix_to_bitmatrix,
)

els = st.integers(min_value=0, max_value=255)
nz_els = st.integers(min_value=1, max_value=255)


# ---------------------------------------------------------------- field axioms
@given(els, els, els)
def test_gf_mul_associative(a, b, c):
    assert gf_mul(gf_mul(a, b), c) == gf_mul(a, gf_mul(b, c))


@given(els, els)
def test_gf_mul_commutative(a, b):
    assert gf_mul(a, b) == gf_mul(b, a)


@given(els, els, els)
def test_gf_distributive(a, b, c):
    assert gf_mul(a, b ^ c) == gf_mul(a, b) ^ gf_mul(a, c)


@given(nz_els)
def test_gf_inverse(a):
    assert gf_mul(a, gf_inv(a)) == 1


@given(els)
def test_gf_identity_and_zero(a):
    assert gf_mul(a, 1) == a
    assert gf_mul(a, 0) == 0


# ------------------------------------------------------------- bitslice algebra
@given(els, els)
def test_bitmatrix_multiplication(c, d):
    """bits(c*d) == M_c @ bits(d) mod 2 — the core bitslicing identity."""
    M = gf_const_to_bitmatrix(c)
    dbits = np.array([(d >> j) & 1 for j in range(8)], dtype=np.uint8)
    pbits = (M @ dbits) % 2
    p = sum(int(pbits[i]) << i for i in range(8))
    assert p == gf_mul(c, d)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8), st.integers(2, 8), st.integers(1, 64), st.integers(0, 2**32 - 1))
def test_bitsliced_matmul_matches_lut(m, k, L, seed):
    rng = np.random.default_rng(seed)
    A = rng.integers(0, 256, (m, k), dtype=np.uint8)
    B = rng.integers(0, 256, (k, L), dtype=np.uint8)
    want = gf_matmul_np(A, B)
    Abits = gf_matrix_to_bitmatrix(A).astype(np.int64)
    Bbits = bytes_to_bits_np(B).astype(np.int64)
    got = bits_to_bytes_np(((Abits @ Bbits) % 2).astype(np.uint8))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------- matrix layer
def test_gf_matrix_inverse_roundtrip():
    rng = np.random.default_rng(0)
    for k in (1, 2, 5, 10):
        # Cauchy-derived square matrices are always invertible
        A = cauchy_parity_matrix(2 * k, k)[:k]
        Ainv = gf_invert_matrix(A)
        np.testing.assert_array_equal(gf_matmul_np(A, Ainv), np.eye(k, dtype=np.uint8))


def test_singular_matrix_raises():
    A = np.zeros((3, 3), dtype=np.uint8)
    with pytest.raises(np.linalg.LinAlgError):
        gf_invert_matrix(A)


def test_vandermonde_systematic_mds_small():
    # every k-subset of generator rows of [I; P] must be invertible
    import itertools

    n, k = 7, 4
    P = vandermonde_matrix(n, k)
    G = np.concatenate([np.eye(k, dtype=np.uint8), P], axis=0)
    for rows in itertools.combinations(range(n), k):
        gf_invert_matrix(G[list(rows)])  # must not raise


def test_cauchy_mds_small():
    import itertools

    n, k = 8, 5
    P = cauchy_parity_matrix(n, k)
    G = np.concatenate([np.eye(k, dtype=np.uint8), P], axis=0)
    for rows in itertools.combinations(range(n), k):
        gf_invert_matrix(G[list(rows)])


# ------------------------------------------------------------------- RS codes
@settings(max_examples=20, deadline=None)
@given(
    st.integers(2, 10),      # k
    st.integers(0, 6),       # m
    st.integers(1, 200),     # L
    st.integers(0, 2**32 - 1),
)
def test_rs_roundtrip_random_erasures(k, m, L, seed):
    n = k + m
    rng = np.random.default_rng(seed)
    code = RSCode(n=n, k=k)
    data = rng.integers(0, 256, (k, L), dtype=np.uint8)
    coded = code.encode(data)
    assert coded.shape == (n, L)
    np.testing.assert_array_equal(coded[:k], data)  # systematic
    keep = rng.permutation(n)[:k]
    got = code.decode(coded[keep], list(keep))
    np.testing.assert_array_equal(got, data)


def test_rs_decode_insufficient_fragments():
    code = RSCode(n=6, k=4)
    data = np.arange(4 * 8, dtype=np.uint8).reshape(4, 8)
    coded = code.encode(data)
    with pytest.raises(ValueError):
        code.decode(coded[:3], [0, 1, 2])


def test_rs_reconstruct_single_fragment():
    rng = np.random.default_rng(7)
    code = RSCode(n=8, k=5)
    data = rng.integers(0, 256, (5, 33), dtype=np.uint8)
    coded = code.encode(data)
    for lost in range(8):
        keep = [i for i in range(8) if i != lost][:5]
        rebuilt = code.reconstruct_fragment(lost, coded[keep], keep)
        np.testing.assert_array_equal(rebuilt, coded[lost])


@settings(max_examples=20, deadline=None)
@given(st.binary(min_size=0, max_size=4096), st.integers(2, 9), st.integers(1, 4))
def test_rs_bytes_roundtrip(blob, k, m):
    code = RSCode(n=k + m, k=k)
    frags, orig = code.encode_bytes(blob)
    assert len(frags) == k + m
    # drop the m largest-index fragments, decode from an arbitrary k-subset
    rng = np.random.default_rng(len(blob))
    keep = sorted(rng.permutation(k + m)[:k].tolist())
    got = code.decode_bytes({i: frags[i] for i in keep}, orig)
    assert got == blob


def test_rs_decode_duplicate_indices_raises():
    code = RSCode(n=6, k=3)
    data = np.arange(3 * 8, dtype=np.uint8).reshape(3, 8)
    coded = code.encode(data)
    with pytest.raises(ValueError):
        code.decode(np.stack([coded[0], coded[0], coded[1]]), [0, 0, 1])
    with pytest.raises(ValueError):
        code.decode_batch(coded[None, [0, 0, 1]], [0, 0, 1])


def test_rs_reconstruct_systematic_and_parity_targets():
    rng = np.random.default_rng(21)
    code = RSCode(n=7, k=4)
    data = rng.integers(0, 256, (4, 19), dtype=np.uint8)
    coded = code.encode(data)
    keep = [1, 3, 4, 6]  # mixed systematic + parity survivors
    # single-target: one systematic (0, 2) and one parity (5) rebuild
    for lost in (0, 2, 5):
        got = code.reconstruct_fragment(lost, coded[keep], keep)
        np.testing.assert_array_equal(got, coded[lost])
    # multi-target fused path matches, in target order
    multi = code.reconstruct_fragments([5, 0, 2], coded[keep], keep)
    np.testing.assert_array_equal(multi, coded[[5, 0, 2]])
    assert code.reconstruct_fragments([], coded[keep], keep).shape == (0, 19)


# ------------------------------------------------------- batched coding
@settings(max_examples=15, deadline=None)
@given(st.integers(2, 8), st.integers(0, 4), st.integers(1, 40),
       st.integers(1, 12), st.integers(0, 2**32 - 1))
def test_encode_decode_batch_bit_identical_to_per_block(k, m, L, B, seed):
    n = k + m
    rng = np.random.default_rng(seed)
    code = RSCode(n=n, k=k)
    data = rng.integers(0, 256, (B, k, L), dtype=np.uint8)
    batch = code.encode_batch(data)
    per = np.stack([code.encode(data[b]) for b in range(B)])
    np.testing.assert_array_equal(batch, per)
    keep = sorted(rng.permutation(n)[:k].tolist())
    got = code.decode_batch(batch[:, keep, :], keep)
    np.testing.assert_array_equal(got, data)
    per_dec = np.stack([code.decode(batch[b][keep], keep) for b in range(B)])
    np.testing.assert_array_equal(got, per_dec)


def test_encode_batch_shape_and_insufficient_checks():
    code = RSCode(n=6, k=4)
    with pytest.raises(ValueError):
        code.encode_batch(np.zeros((2, 3, 8), dtype=np.uint8))  # wrong k
    with pytest.raises(ValueError):
        code.encode_batch(np.zeros((4, 8), dtype=np.uint8))     # not 3-D
    with pytest.raises(ValueError):
        code.decode_batch(np.zeros((2, 3, 8), dtype=np.uint8), [0, 1, 2])
    assert code.encode_batch(np.zeros((0, 4, 8), dtype=np.uint8)).shape == (0, 6, 8)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=600), min_size=1, max_size=6),
       st.integers(2, 6), st.integers(1, 3))
def test_encode_bytes_batch_matches_encode_bytes(values, k, m):
    code = RSCode(n=k + m, k=k)
    got = code.encode_bytes_batch(values)
    assert len(got) == len(values)
    for v, (frags, orig) in zip(values, got):
        f_ref, o_ref = code.encode_bytes(v)
        assert frags == f_ref and orig == o_ref
    assert code.encode_bytes_batch([]) == []


def test_encode_batch_single_kernel_call(monkeypatch):
    """Acceptance (ISSUE 1): >= 32 blocks on the kernel backend issue exactly
    ONE kernel matmul, bit-identical to per-block numpy encode. The kernel
    backend dispatches through ``gf256_coding_matmul`` (ISSUE 6), so that is
    the seam counted here."""
    from repro.kernels.gf256_matmul import ops as gf_ops

    calls = []
    real = gf_ops.gf256_coding_matmul

    def counting(A, B, **kw):
        calls.append(np.asarray(B).shape)
        return real(A, B, **kw)

    monkeypatch.setattr(gf_ops, "gf256_coding_matmul", counting)
    rng = np.random.default_rng(3)
    code = RSCode(n=6, k=4, backend="kernel")
    data = rng.integers(0, 256, (32, 4, 16), dtype=np.uint8)
    coded = code.encode_batch(data)
    assert len(calls) == 1, f"expected one fused kernel call, saw {len(calls)}"
    ref = np.stack([RSCode(n=6, k=4).encode(data[b]) for b in range(32)])
    np.testing.assert_array_equal(coded, ref)


def test_bytes_rows_padding():
    rows, orig = bytes_to_rows(b"hello world", 4)
    assert rows.shape[0] == 4 and orig == 11
    assert rows_to_bytes(rows, orig) == b"hello world"
    rows0, o0 = bytes_to_rows(b"", 3)
    assert rows0.shape == (3, 1) and rows_to_bytes(rows0, o0) == b""


# --------------------------------------------------- ISSUE 6 regressions
def test_decode_bytes_rejects_truncated_fragment():
    """Regression (ISSUE 6): a short/truncated fragment used to be silently
    zero-padded into the decode operand and produce garbage bytes; a length
    mismatch within an item's chosen fragments must raise."""
    code = RSCode(n=6, k=4)
    frags, orig = code.encode_bytes(b"x" * 4000)
    good = {i: frags[i] for i in (0, 1, 2, 4)}
    bad = dict(good)
    bad[1] = bad[1][:-3]
    with pytest.raises(ValueError, match="length mismatch"):
        code.decode_bytes_batch([(bad, orig)])
    with pytest.raises(ValueError, match="length mismatch"):
        code.decode_bytes(bad, orig)
    assert code.decode_bytes_batch([(good, orig)]) == [b"x" * 4000]


def test_decode_prefers_systematic_subset(monkeypatch):
    """Regression (ISSUE 6): when the k systematic fragments are all present
    — in any order, or alongside parity fragments — every decode path must
    take the copy fast path and perform NO GF matmul."""
    import repro.erasure.rs as rs_mod

    code = RSCode(n=6, k=4)
    rng = np.random.default_rng(5)
    data = rng.integers(0, 256, (4, 96), dtype=np.uint8)
    coded = code.encode(data)
    frags, orig = code.encode_bytes(b"hello" * 100)

    calls = []
    real = rs_mod.gf_matmul_np

    def counting(A, B):
        calls.append((np.asarray(A).shape, np.asarray(B).shape))
        return real(A, B)

    monkeypatch.setattr(rs_mod, "gf_matmul_np", counting)
    # shuffled systematic indices, plus a parity row riding along
    keep = [3, 0, 2, 1, 5]
    np.testing.assert_array_equal(code.decode(coded[keep], keep), data)
    batch = np.stack([coded[keep], coded[keep]])
    np.testing.assert_array_equal(
        code.decode_batch(batch, keep), np.stack([data, data])
    )
    # bytes form: all systematic present + a parity fragment in the reply
    sub = {i: frags[i] for i in (0, 1, 2, 3, 5)}
    assert code.decode_bytes_batch([(sub, orig)]) == [b"hello" * 100]
    assert code.decode_bytes(sub, orig) == b"hello" * 100
    assert calls == [], f"systematic replies must not matmul: {calls}"
