"""Schedule explorer + happens-before race detector (ISSUE 9).

Layers:

* pinning — the controller hooks in ``net/sim.py`` are pure pass-throughs:
  with no controller the instrumented engines stay trace-identical to each
  other, and a ``fifo``-policy controller replays the exact uncontrolled
  trace on BOTH engines (the "explorer off ⇒ bit-identical" contract);
* scheduler hygiene — equal-timestamp events fire in schedule order
  (the shared seq counter's FIFO tie-break) on both engines;
* the explorer — bounded exhaustive DFS with sleep-set pruning runs the
  tiny config clean (with crash/drop injections schedulable), and the
  seeded positive-control faults are all FOUND within budget, each with a
  repro bundle that replays byte-identically through the JSON round-trip;
* the race tracker — clean-run counters, the unguarded-put write-write
  race as a live positive control, and unit-level ordered-vs-unordered
  classification of a summary regression.
"""
import json

import pytest

from repro.analysis.explore import (
    ExploreConfig,
    Outcome,
    ScheduleController,
    ScheduleDivergence,
    _fingerprint,
    conflicts,
    explore,
    load_bundle,
    replay_bundle,
    run_schedule,
    write_bundle,
    SCENARIOS,
)
from repro.analysis.races import RaceError, RaceTracker
from repro.analysis.sanitizer import SanitizerError
from repro.core.server import StorageServer
from repro.core.store import DSS, DSSParams
from repro.net.sim import Network


# ------------------------------------------------------------------ pinning
def _uncontrolled(fast: bool) -> dict:
    p = DSSParams(algorithm="coabd", n_servers=3, seed=0, fast_net=fast,
                  sanitize=True, racecheck=True)
    dss = DSS(p)
    futs = [
        dss.net.spawn(gen, kind=kind, client=cid)
        for cid, kind, gen in SCENARIOS["wr"](dss)
    ]
    dss.net.run()
    assert all(f.done for f in futs)
    return _fingerprint(dss)


def test_fifo_controller_replays_uncontrolled_trace_both_engines():
    """The tentpole's no-regression contract: controller off = today's
    trace, and the fifo policy (always the earliest ``(t, seq)``) replays
    it byte-for-byte — virtual makespan, event/message/byte counters and
    the recorded history — on the fast AND the legacy engine."""
    fps = []
    for fast in (True, False):
        fp0 = _uncontrolled(fast)
        out = run_schedule(ExploreConfig.for_scenario("wr", fast_net=fast))
        assert out.violation is None
        assert out.fingerprint == fp0, ("fast" if fast else "legacy")
        fps.append(fp0)
    assert fps[0] == fps[1]  # fast/legacy trace identity, race-checked


def test_equal_timestamp_events_fire_in_schedule_order():
    """Satellite: the shared seq counter's FIFO tie-break. Same-timestamp
    events must fire in the order they were scheduled, on both engines —
    heap tie-breaking is what makes every trace replayable at all."""
    for fast in (True, False):
        net = Network(seed=0, fast=fast)
        ran: list[str] = []
        for name in ("a", "b", "c", "d"):
            net.schedule(0.0, lambda n=name: ran.append(n))
        net.schedule(0.0, lambda: ran.append("e"))
        net.run()
        assert ran == ["a", "b", "c", "d", "e"]


def test_fifo_controller_equal_timestamp_order_matches():
    net = Network(seed=0)
    ran: list[str] = []
    net.controller = ScheduleController()  # fifo, no plan
    for name in ("a", "b", "c"):
        net.schedule(0.0, lambda n=name: ran.append(n), ("cli", None, name))
    net.run()
    assert ran == ["a", "b", "c"]


# ------------------------------------------------------------- controller
def test_schedule_divergence_raises():
    with pytest.raises(ScheduleDivergence, match="does not match"):
        run_schedule(ExploreConfig.for_scenario("wr"), plan=[("ev", 10**9)])


def test_conflict_relation():
    srv0 = ("srv", "s0", "c1")
    assert conflicts(None, srv0)                        # unkeyed: everything
    assert conflicts(("snd", None, "c1"), ("srv", "s2", "c9"))  # RNG draw
    assert conflicts(srv0, ("srv", "s0", "c2"))         # same server
    assert conflicts(srv0, ("rpl", None, "c1"))         # same client endpoint
    assert not conflicts(srv0, ("srv", "s1", "c2"))     # disjoint: commutes


# --------------------------------------------------------------- explorer
@pytest.mark.allow_stuck
def test_dfs_exhausts_tiny_config_clean():
    """Bounded exhaustive DFS over the 3-server/2-client/1-block scenario
    with crash AND drop as schedulable choices: no violation anywhere, and
    the sleep-set pruning actually fires."""
    cfg = ExploreConfig.for_scenario(
        "wr", budget=1500, branch_depth=6, crash_budget=1, drop_budget=1,
        stop_on_first=False,
    )
    res = explore(cfg)
    assert not res.violations
    assert res.schedules > 100
    assert res.pruned > 0


def test_dfs_without_injections_exhausts_frontier():
    res = explore(ExploreConfig.for_scenario("wr", budget=500, branch_depth=6))
    assert res.exhausted and not res.violations
    assert res.schedules > 10


def test_pct_sweep_on_larger_ec_recon_config_is_clean():
    """Seeded PCT priority schedules on the 5-server EC + concurrent-recon
    scenario (too big to exhaust): sanitizer + race tracker + Wing–Gong
    stay silent across the sweep."""
    cfg = ExploreConfig.for_scenario(
        "ec-recon", mode="pct", budget=60, stop_on_first=False
    )
    res = explore(cfg)
    assert res.schedules == 60 and not res.violations


# ------------------------------------------------- positive-control faults
def _assert_found_and_replays(cfg: ExploreConfig, expect_type: str) -> dict:
    res = explore(cfg)
    assert res.found, (
        f"fault {cfg.fault!r} NOT found in {res.schedules} schedules"
    )
    bundle = res.violations[0]
    assert bundle["violation"]["type"] == expect_type, bundle["violation"]
    # satellite: every bundle is stamped with (seed, params, engine)
    assert bundle["seed_params"]["seed"] == cfg.seed
    assert bundle["seed_params"]["algorithm"] == cfg.algorithm
    assert bundle["engine"] == "fast"
    rep = replay_bundle(bundle)
    assert rep["reproduced"], rep
    return bundle


def test_explorer_finds_early_read_resume_quorum_bug():
    """PR-7's seeded quorum off-by-one, reintroduced client-side where the
    static ``on_rpc`` check can't see it: most schedules still read fresh
    data; the explorer must steer a lagging server's reply first and catch
    the stale read via Wing–Gong."""
    cfg = ExploreConfig.for_scenario(
        "wr", fault="early-read-resume", mode="pct", budget=500
    )
    _assert_found_and_replays(cfg, "LinearizabilityError")


def test_explorer_finds_dropped_ack_rollback():
    """The dropped-ack tag regression: only schedules that (a) drop an
    abd-put ack in flight and (b) later route a get through that server
    violate — found via the sanitizer's reply-monotonicity floor."""
    cfg = ExploreConfig.for_scenario(
        "wr", fault="ack-rollback", mode="pct", drop_budget=1, budget=500
    )
    b = _assert_found_and_replays(cfg, "SanitizerError")
    assert "monotonicity" in b["violation"]["message"]


def test_dfs_finds_unguarded_put_write_write_race():
    """Dropping the ``tag > cur`` guard turns concurrent writers into a
    genuine write-write race; the bounded DFS finds the interleaving and
    the vector clocks classify it as UNORDERED."""
    cfg = ExploreConfig.for_scenario(
        "ww", fault="unguarded-put", mode="dfs", budget=200, branch_depth=6
    )
    b = _assert_found_and_replays(cfg, "RaceError")
    assert "regressed abd state" in b["violation"]["message"]


def test_explorer_finds_retry_duplicate_write_regression():
    """ISSUE 10: a retransmitted abd-put applied without duplicate
    suppression. Needs the retry machinery armed (cfg.retry=True) plus a
    crash (thins the quorum) and a dropped ack (forces the retransmit);
    the duplicate's blind re-apply can land after a rival writer's newer
    tag and regress the register — an UNORDERED write-write race."""
    cfg = ExploreConfig.for_scenario(
        "ww", fault="retry-dup-write", mode="pct", crash_budget=1,
        drop_budget=1, retry=True, budget=500,
    )
    b = _assert_found_and_replays(cfg, "RaceError")
    assert "regressed abd state" in b["violation"]["message"]


def test_retry_duplicates_suppressed_on_head():
    """The flip side of the control: with the SAME retry config but no
    fault, the real servers' tag guard suppresses every retransmitted
    duplicate — the sweep stays clean even while retransmits fire."""
    cfg = ExploreConfig.for_scenario(
        "ww", mode="pct", crash_budget=1, drop_budget=1, retry=True,
        budget=120, stop_on_first=False,
    )
    res = explore(cfg)
    assert not res.violations, res.violations[:1]
    assert res.schedules == 120


def test_fault_hooks_restore_handlers():
    before_put = StorageServer._DISPATCH["abd-put"]
    before_putb = StorageServer._DISPATCH["abd-put-batch"]
    for fault, kw in (
        ("early-read-resume", {}),
        ("ack-rollback", {"drop_budget": 1}),
        ("unguarded-put", {}),
        ("retry-dup-write", {"crash_budget": 1, "drop_budget": 1,
                             "retry": True}),
    ):
        run_schedule(ExploreConfig.for_scenario("wr", fault=fault, **kw))
        assert StorageServer._DISPATCH["abd-put"] is before_put
        assert StorageServer._DISPATCH["abd-put-batch"] is before_putb


# ----------------------------------------------------------------- bundles
def test_bundle_json_roundtrip_replays_byte_identically(tmp_path):
    cfg = ExploreConfig.for_scenario(
        "ww", fault="unguarded-put", mode="dfs", budget=200, branch_depth=6
    )
    res = explore(cfg)
    assert res.found
    path = write_bundle(res.violations[0], str(tmp_path))
    loaded = load_bundle(path)
    assert loaded == json.loads(json.dumps(loaded))  # JSON-stable
    rep = replay_bundle(loaded)
    assert rep["reproduced"] and rep["fingerprint_matches"]


def test_bundle_version_gate(tmp_path):
    p = tmp_path / "b.json"
    p.write_text('{"version": 99}')
    with pytest.raises(ValueError, match="bundle version"):
        load_bundle(str(p))


# ------------------------------------------------------------ race tracker
def test_racecheck_param_and_env_attach_tracker(monkeypatch):
    dss = DSS(DSSParams(algorithm="coabd", n_servers=3, racecheck=True))
    assert dss.net.race_tracker is not None
    assert dss.net.servers["s0"]._race_observer is not None
    monkeypatch.setenv("REPRO_RACECHECK", "1")
    dss2 = DSS(DSSParams(algorithm="coabd", n_servers=3))
    assert dss2.net.race_tracker is not None


def test_race_tracker_clean_run_counters():
    dss = DSS(DSSParams(algorithm="coaresabd", n_servers=3, racecheck=True))
    sess = dss.session("c1")
    sess.write("f", b"v1")
    sess.write("f", b"v2")
    dss.run()
    sess.read("f")
    dss.run()
    rep = dss.net.race_tracker.report()
    assert rep["mutations"] > 0 and rep["checks"] > 0
    assert rep["ops"] >= 2 and rep["tracked"] >= 1


def test_race_tracker_forgives_external_surgery():
    from repro.core.tags import TAG0

    dss = DSS(DSSParams(algorithm="coaresabd", n_servers=3, racecheck=True))
    sess = dss.session("c1")
    sess.write("f", b"v1")
    dss.run()
    srv = dss.net.servers["s0"]
    srv.abd[("f", 0)] = (TAG0, None)  # tracked map, outside handle: forgiven
    sess.read("f")
    dss.run()
    assert dss.net.race_tracker.forgets >= 1


class _FakeFut:
    def __init__(self, op_id):
        self.op_id = op_id
        self.client = f"c{op_id}"
        self.kind = "t"


class _FakeState:
    def __init__(self, op_id):
        self.fut = _FakeFut(op_id)


def _tracker_with_server():
    class _Net:
        pass

    net = _Net()
    srv = StorageServer("s0")
    net.servers = {"s0": srv}
    net.race_tracker = None
    rt = RaceTracker()
    rt.net = net
    return rt, srv


def _handled_put(rt, srv, state, tag):
    rt.before_handle("s0", state)
    srv.abd[("f", 0)] = (tag, b"v")
    rt.on_mutation("s0", "f", True)
    rt.after_handle("s0")


def test_race_tracker_classifies_unordered_regression():
    """Two ops with NO happens-before edge both write; the second lands a
    lower tag: UNORDERED write-write race."""
    rt, srv = _tracker_with_server()
    s1, s2 = _FakeState(1), _FakeState(2)
    rt.on_issue(s1, None)
    rt.on_issue(s2, None)  # snapshots taken before any reply: concurrent
    _handled_put(rt, srv, s1, (2, "c1"))
    rt.before_handle("s0", s2)
    srv.abd[("f", 0)] = ((1, "c2"), b"w")
    rt.on_mutation("s0", "f", True)
    with pytest.raises(RaceError, match="UNORDERED"):
        rt.after_handle("s0")


def test_race_tracker_classifies_ordered_lost_update():
    """The second op ISSUES after receiving a reply from the server that
    handled the first (a real happens-before path): the same regression is
    a plain lost-update bug, not a race."""
    rt, srv = _tracker_with_server()
    s1 = _FakeState(1)
    rt.on_issue(s1, None)
    _handled_put(rt, srv, s1, (2, "c1"))
    # op 2's query round touches s0 and its reply is counted...
    s2q = _FakeState(2)
    rt.on_issue(s2q, None)
    rt.before_handle("s0", s2q)
    rt.after_handle("s0")
    rt.on_reply("s0", s2q)
    # ...so its put round's snapshot contains op 1's issue event
    s2p = _FakeState(2)
    rt.on_issue(s2p, None)
    rt.before_handle("s0", s2p)
    srv.abd[("f", 0)] = ((1, "c2"), b"w")
    rt.on_mutation("s0", "f", True)
    with pytest.raises(RaceError, match="ordered AFTER"):
        rt.after_handle("s0")


def test_race_tracker_benign_concurrent_writes_counted():
    rt, srv = _tracker_with_server()
    s1, s2 = _FakeState(1), _FakeState(2)
    rt.on_issue(s1, None)
    rt.on_issue(s2, None)
    _handled_put(rt, srv, s1, (1, "c1"))
    _handled_put(rt, srv, s2, (1, "c2"))  # higher tag: monotone, no raise
    assert rt.concurrent_writes == 1
    assert rt.report()["checks"] == 2


def test_workload_report_surfaces_race_counters():
    from repro.core.workload import WorkloadGen, WorkloadSpec

    spec = WorkloadSpec(sessions=20, files=4, file_size=256)
    rep = WorkloadGen(spec, seed=3).run(
        DSS(DSSParams(algorithm="coabd", n_servers=3, seed=3,
                      sanitize=True, racecheck=True))
    )
    assert rep["ops_done"] == 20
    assert rep["races"]["mutations"] > 0
    assert rep["races"]["checks"] > 0


# -------------------------------------------------- outcome report plumbing
def test_run_schedule_reports_counters():
    out = run_schedule(ExploreConfig.for_scenario("wr"))
    assert isinstance(out, Outcome)
    assert out.report["ops"] == 2 and out.report["ops_incomplete"] == 0
    assert out.report["sanitizer"]["checks"] > 0
    assert out.report["races"]["checks"] > 0
    assert len(out.trace) == out.fingerprint["events"]
