"""CoARESF / fragmented-object behaviour (§V): BI, connectivity, concurrency."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seeded fallback shim — see tests/_propfallback.py
    from _propfallback import given, settings
    from _propfallback import strategies as st

from checkers import check_all
from repro.core import DSS, DSSParams

FRAG_ALGS = ["coabdf", "coaresabdf", "coaresecf", "coaresecf-noopt"]


def _dss(alg, n=5, seed=0, **kw):
    kw.setdefault("min_block", 64)
    kw.setdefault("avg_block", 128)
    kw.setdefault("max_block", 512)
    return DSS(DSSParams(algorithm=alg, n_servers=n, seed=seed, **kw))


def _blob(seed, size):
    return np.random.default_rng(seed).integers(0, 256, size, dtype=np.uint8).tobytes()


# ------------------------------------------------------------------ basics
@pytest.mark.parametrize("alg", FRAG_ALGS)
def test_roundtrip(alg):
    dss = _dss(alg)
    w, r = dss.client("w"), dss.client("r")
    blob = _blob(0, 4000)
    stats = dss.net.run_op(w.update("f", blob), client="w")
    assert stats["success"] and stats["blocks"] > 1
    assert dss.net.run_op(r.read("f"), client="r") == blob
    check_all(dss.history)


@pytest.mark.parametrize("alg", FRAG_ALGS)
def test_incremental_update_touches_few_blocks(alg):
    """The FM's raison d'être: a local edit rewrites O(1) blocks, not O(n)."""
    dss = _dss(alg)
    w = dss.client("w")
    blob = bytearray(_blob(1, 16_000))
    s0 = dss.net.run_op(w.update("f", bytes(blob)), client="w")
    n_blocks = s0["blocks"]
    assert n_blocks >= 8
    blob[5000] ^= 0xAA  # single-byte edit
    s1 = dss.net.run_op(w.update("f", bytes(blob)), client="w")
    assert s1["success"]
    assert s1["written"] <= 4, f"local edit rewrote {s1['written']} blocks"
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == bytes(blob)


@pytest.mark.parametrize("alg", ["coaresecf"])
def test_append_grow_shrink(alg):
    dss = _dss(alg)
    w, r = dss.client("w"), dss.client("r")
    a = _blob(2, 3000)
    b = a + _blob(3, 2000)          # append
    c = b[:1500]                     # shrink
    for blob in (a, b, c):
        stats = dss.net.run_op(w.update("f", blob), client="w")
        assert stats["success"]
        assert dss.net.run_op(r.read("f"), client="r") == blob
    check_all(dss.history)


@pytest.mark.parametrize("alg", ["coaresecf", "coaresabdf"])
def test_insert_in_middle(alg):
    dss = _dss(alg)
    w, r = dss.client("w"), dss.client("r")
    blob = _blob(4, 8000)
    dss.net.run_op(w.update("f", blob), client="w")
    edited = blob[:4000] + _blob(5, 600) + blob[4000:]
    stats = dss.net.run_op(w.update("f", edited), client="w")
    assert stats["success"] and stats["created"] >= 1
    assert dss.net.run_op(r.read("f"), client="r") == edited
    check_all(dss.history)


# ---------------------------------------------------- concurrency semantics
def test_concurrent_writers_different_regions_both_prevail():
    """Fragmented coverability: concurrent updates on *different* blocks all
    succeed — the paper's headline concurrency win (§II, §V)."""
    dss = _dss("coaresecf", n=5, seed=13, min_block=64, avg_block=128, max_block=256)
    w1, w2 = dss.client("w1"), dss.client("w2")
    blob = _blob(6, 8000)
    dss.net.run_op(w1.update("f", blob), client="w1")
    dss.net.run_op(w2.read("f"), client="w2")  # w2 learns current versions
    # edit disjoint, far-apart regions
    e1 = bytearray(blob); e1[100] ^= 0xFF
    e2 = bytearray(blob); e2[7800] ^= 0xFF
    f1 = dss.net.spawn(w1.update("f", bytes(e1)), client="w1")
    f2 = dss.net.spawn(w2.update("f", bytes(e2)), client="w2")
    dss.net.run()
    assert f1.done and f2.done
    assert f1.result["success"] and f2.result["success"], (
        f1.result, f2.result,
    )
    r = dss.client("r")
    got = dss.net.run_op(r.read("f"), client="r")
    want = bytearray(blob); want[100] ^= 0xFF; want[7800] ^= 0xFF
    assert got == bytes(want), "both disjoint edits must survive"
    check_all(dss.history)


def test_concurrent_writers_same_block_one_prevails():
    dss = _dss("coaresecf", n=5, seed=17)
    w1, w2 = dss.client("w1"), dss.client("w2")
    blob = _blob(7, 2000)
    dss.net.run_op(w1.update("f", blob), client="w1")
    dss.net.run_op(w2.read("f"), client="w2")
    e1 = bytearray(blob); e1[500] ^= 0x01
    e2 = bytearray(blob); e2[500] ^= 0x02   # same block
    f1 = dss.net.spawn(w1.update("f", bytes(e1)), client="w1")
    f2 = dss.net.spawn(w2.update("f", bytes(e2)), client="w2")
    dss.net.run()
    r = dss.client("r")
    got = dss.net.run_op(r.read("f"), client="r")
    assert got in (bytes(e1), bytes(e2))  # no Frankenstein value on one block
    check_all(dss.history)


def test_reader_sees_connected_chain_during_update():
    """Lemma 13 / Thm 14: reads concurrent with updates never observe a
    broken list — every read assembles a coherent file."""
    dss = _dss("coaresecf", n=5, seed=23)
    w, r = dss.client("w"), dss.client("r")
    blob = _blob(8, 12_000)
    dss.net.run_op(w.update("f", blob), client="w")
    edited = blob[:2000] + _blob(9, 3000) + blob[6000:]
    fw = dss.net.spawn(w.update("f", edited), client="w")
    reads = [
        dss.net.spawn(r.read("f"), client="r", delay=0.002 * i) for i in range(6)
    ]
    dss.net.run()
    assert fw.done and all(f.done for f in reads)
    for f in reads:
        got = f.result
        # every concurrent read returns a *prefix-consistent* mix: all-old,
        # all-new, or a connected combination — never a torn/dangling chain
        assert isinstance(got, bytes) and len(got) > 0
    final = dss.net.run_op(r.read("f"), client="r")
    assert final == edited
    check_all(dss.history)


# ----------------------------------------------------------- recon on files
def test_fm_reconfig_walks_all_blocks():
    dss = _dss("coaresecf", n=5, seed=29)
    w, g, r = dss.client("w"), dss.client("g"), dss.client("r")
    blob = _blob(10, 6000)
    stats = dss.net.run_op(w.update("f", blob), client="w")
    cfg = dss.make_config(dap="abd")
    nblocks = dss.net.run_op(g.recon("f", cfg), client="g")
    assert nblocks == stats["blocks"] + 1  # every data block + genesis
    assert dss.net.run_op(r.read("f"), client="r") == blob
    check_all(dss.history)


def test_fm_reconfig_to_fresh_servers_preserves_file():
    dss = _dss("coaresecf", n=5, seed=31)
    w, g, r = dss.client("w"), dss.client("g"), dss.client("r")
    blob = _blob(11, 5000)
    dss.net.run_op(w.update("f", blob), client="w")
    cfg = dss.make_config(fresh_servers=True)
    dss.net.run_op(g.recon("f", cfg), client="g")
    dss.crash_servers(["s0", "s1"])  # minority of old: traversal still live
    assert dss.net.run_op(r.read("f"), client="r") == blob
    dss.crash_servers([f"s{i}" for i in range(5)])
    assert dss.net.run_op(r.read("f"), client="r") == blob
    check_all(dss.history)


def test_update_concurrent_with_fm_reconfig():
    dss = _dss("coaresecf", n=5, seed=37)
    w, g, r = dss.client("w"), dss.client("g"), dss.client("r")
    blob = _blob(12, 6000)
    dss.net.run_op(w.update("f", blob), client="w")
    edited = bytearray(blob); edited[3000] ^= 0x55
    cfg = dss.make_config(dap="abd", n_servers=7)
    fg = dss.net.spawn(g.recon("f", cfg), client="g")
    fw = dss.net.spawn(w.update("f", bytes(edited)), client="w", delay=0.003)
    dss.net.run()
    assert fg.done and fw.done
    got = dss.net.run_op(r.read("f"), client="r")
    assert got == (bytes(edited) if fw.result["success"] else blob)
    check_all(dss.history)


# --------------------------------------------------------- property-based
@settings(max_examples=10, deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=3000), min_size=1, max_size=4),
       st.integers(0, 2**16))
def test_sequential_update_read_any_contents(contents, seed):
    dss = _dss("coaresecf", n=5, seed=seed)
    w, r = dss.client("w"), dss.client("r")
    for blob in contents:
        stats = dss.net.run_op(w.update("f", blob), client="w")
        assert stats["success"]
        assert dss.net.run_op(r.read("f"), client="r") == blob
    check_all(dss.history)


# ----------------------------------------------- beyond-paper: indexed FM
@pytest.mark.parametrize("alg", ["coaresecf", "coaresabdf"])
def test_indexed_mode_roundtrip_and_speedup(alg):
    """Indexed genesis (parallel block I/O) returns identical content and is
    strictly faster in virtual time than the linked-list walk."""
    blob = _blob(50, 24_000)
    times = {}
    for indexed in (False, True):
        dss = DSS(DSSParams(algorithm=alg, n_servers=6, parity_m=2, seed=41,
                            min_block=64, avg_block=128, max_block=512,
                            indexed=indexed))
        w, r = dss.client("w"), dss.client("r")
        stats = dss.net.run_op(w.update("f", blob), client="w")
        assert stats["success"]
        t0 = dss.net.now
        got = dss.net.run_op(r.read("f"), client="r")
        times[indexed] = dss.net.now - t0
        assert got == blob
        # incremental edit works in both modes
        e = bytearray(blob); e[12_000] ^= 0xFF
        s2 = dss.net.run_op(w.update("f", bytes(e)), client="w")
        assert s2["success"] and s2["written"] <= 6
        assert dss.net.run_op(r.read("f"), client="r") == bytes(e)
    assert times[True] < times[False] / 3, times


def test_indexed_concurrent_writers_disjoint_edits():
    dss = DSS(DSSParams(algorithm="coaresecf", n_servers=6, parity_m=2,
                        seed=43, min_block=64, avg_block=128, max_block=512,
                        indexed=True))
    w1, w2 = dss.client("w1"), dss.client("w2")
    blob = _blob(51, 8000)
    dss.net.run_op(w1.update("f", blob), client="w1")
    dss.net.run_op(w2.read("f"), client="w2")
    e1 = bytearray(blob); e1[100] ^= 0xFF
    e2 = bytearray(blob); e2[7900] ^= 0xFF
    f1 = dss.net.spawn(w1.update("f", bytes(e1)), client="w1")
    f2 = dss.net.spawn(w2.update("f", bytes(e2)), client="w2")
    dss.net.run()
    assert f1.result["success"] and f2.result["success"]
    got = dss.net.run_op(dss.client("r").read("f"), client="r")
    want = bytearray(blob); want[100] ^= 0xFF; want[7900] ^= 0xFF
    assert got == bytes(want)
