"""Cross-client gateway aggregation tier (ISSUE 4 tentpole): same-file
merge + multicast, per-client attribution, cross-client program order,
merged recons, gossip-fed RepairDaemon coverage, and the two-session /
daemon / recon race stress."""
import numpy as np
import pytest

from checkers import check_all
from repro.core import DSS, DSSParams, gather
from repro.core.gateway import GossipListener


def _blob(seed, size):
    return np.random.default_rng(seed).integers(0, 256, size, dtype=np.uint8).tobytes()


def _dss(alg="coaresecf", n=6, m=2, seed=0, **kw):
    return DSS(DSSParams(algorithm=alg, n_servers=n, parity_m=m, seed=seed,
                         min_block=256, avg_block=512, max_block=2048, **kw))


# ------------------------------------------------------------- merge paths
def test_gateway_merges_same_file_reads_flat_in_clients():
    """The acceptance bar: C clients reading the same hot file through the
    gateway cost ONE quorum fan-out (rounds flat in C, equal to a single
    session's read), with the result multicast to every rider."""
    rounds = {}
    for C in (2, 8):
        dss = _dss(indexed=True, seed=11)
        doc = _blob(1, 5000)
        boot = dss.session("boot")
        assert boot.write("hot", doc).result()["success"]
        gw = dss.gateway()
        sessions = [dss.session(f"c{i}", via=gw) for i in range(C)]
        r0 = dss.net.rpc_rounds
        futs = [s.read("hot") for s in sessions]
        assert gather(*futs) == [doc] * C
        rounds[C] = dss.net.rpc_rounds - r0
        for f in futs:
            assert f.stats.batched_with == C
            assert f.stats.rounds == rounds[C]  # attributed the shared round
        assert gw.stats["dedup_saved"] == C - 1
        # direct ablation: C detached sessions pay C independent fan-outs
        direct = [dss.session(f"d{i}") for i in range(C)]
        d0 = dss.net.rpc_rounds
        assert gather(*[s.read("hot") for s in direct]) == [doc] * C
        assert dss.net.rpc_rounds - d0 == C * rounds[C], "direct path must scale O(C)"
    assert rounds[8] == rounds[2], rounds


def test_gateway_attribution_counters_per_rider():
    """Network.attribute: during a merged round every rider's counters move
    in lockstep with the gateway's, and stop once the round is over."""
    dss = _dss(indexed=True, seed=13)
    boot = dss.session("boot")
    boot.write("f", _blob(2, 4000)).result()
    gw = dss.gateway()
    a, b = gw.session("a"), gw.session("b")
    fa, fb = a.read("f"), b.read("f")
    gather(fa, fb)
    ta, tb = dss.net.client_totals("a"), dss.net.client_totals("b")
    tg = dss.net.client_totals(gw.gid)
    assert ta == tb == tg, (ta, tb, tg)
    assert ta[0] > 0 and ta[2] > 0
    assert not dss.net.client_attribution, "attribution must be cleared"
    # detached traffic after the merge is NOT attributed to the riders
    dss.session("solo").read("f").result()
    assert dss.net.client_totals("a") == ta


def test_gateway_cross_client_program_order():
    """c1's write and c2's read of the same file in one gateway window must
    execute in arrival order (kind change breaks the merged run)."""
    dss = _dss(indexed=True, seed=17)
    doc = _blob(3, 3000)
    gw = dss.gateway()
    c1, c2 = gw.session("c1"), gw.session("c2")
    wfut = c1.write("f", doc)
    rfut = c2.read("f")
    assert rfut.result() == doc
    assert wfut.result()["success"]


def test_gateway_same_fid_writes_never_merge():
    """Two clients writing the SAME file in one window stay two storage
    rounds (the second needs the first one's tag to supersede it)."""
    dss = _dss(indexed=True, seed=19)
    gw = dss.gateway()
    c1, c2 = gw.session("c1"), gw.session("c2")
    va, vb = _blob(4, 2000), _blob(5, 2000)
    f1, f2 = c1.write("f", va), c2.write("f", vb)
    s1, s2 = gather(f1, f2)
    assert s1["success"] and s2["success"]
    assert f1.stats.batched_with == 1 and f2.stats.batched_with == 1
    assert dss.session("check").read("f").result() == vb  # arrival order wins
    check_all(dss.history)


def test_gateway_merged_recon_multicast_and_split_on_target():
    """Same-target recons from two clients merge (and dedupe the shared
    fid); a different target config breaks the run. Recon futures resolve
    to the real payload dict of ISSUE 4's accounting fix."""
    dss = _dss(n=7, m=3, indexed=True, seed=23)
    boot = dss.session("boot")
    gather(boot.write("x", _blob(6, 4000)), boot.write("y", _blob(7, 4000)))
    gw = dss.gateway()
    c1, c2 = gw.session("c1"), gw.session("c2")
    cfg1 = dss.make_config(n_servers=7)
    f1 = c1.recon("x", cfg1)
    f2 = c2.recon("x", cfg1)   # same fid, same target: dedupe + multicast
    f3 = c2.recon("y", cfg1)   # rides the same merged round
    r1, r2, r3 = gather(f1, f2, f3)
    assert r1 == r2 and r1["config"] == cfg1.cfg_id and r1["blocks"] >= 2
    assert r3["blocks"] >= 2 and f1.stats.blocks == r1["blocks"]
    assert f1.stats.batched_with == 3
    dss.net.run()  # quiesce recon-spawned repair
    assert dss.session("check").read("x").result() == _blob(6, 4000)
    check_all(dss.history)


def test_gateway_error_delivered_via_rider_future():
    dss = _dss(alg="coabdf", indexed=True, seed=29)  # static: no recon
    gw = dss.gateway()
    s = gw.session("c1")
    s.write("f", b"x" * 500).result()
    fut = s.recon("f", dss.make_config())
    with pytest.raises(NotImplementedError):
        fut.result()


# ----------------------------------------------------------------- gossip
def test_gossip_daemon_acquires_coverage_and_repairs():
    """A RepairDaemon with NO local recon callback (auto_retarget=False)
    learns a reconfiguration through the gateway's gossip and repairs an
    object of the new configuration — the ROADMAP membership item."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4,
                        seed=31, recon_repair=False))
    gw = dss.gateway()
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(8, 2000)), client="w")
    dss.net.run()
    daemon = dss.start_repair_daemon(period=0.01, objs_per_cycle=2,
                                     auto_retarget=False)
    gw.register_daemon(daemon)
    cfg1 = dss.make_config()
    fut = dss.net.spawn(dss.client("g").recon("f", cfg1), client="g")
    dss.net.run(until=dss.net.now + 0.2)
    assert fut.done
    assert (1, cfg1.cfg_id) in daemon.targets, "gossip must add coverage"
    assert daemon.stats["gossip"] == 1
    lst = dss.net.servers["s3"].ec[("f", 1)]
    t_star = max(t for t, e in lst.items() if e is not None)
    del lst[t_star]
    dss.net.run(until=dss.net.now + 0.3)
    dss.stop_repair_daemon()
    gw.stop()
    dss.net.run()
    assert dss.net.servers["s3"].ec[("f", 1)].get(t_star) is not None, (
        "daemon must repair the gossiped configuration"
    )
    # retired (fully superseded) targets are never re-ingested from gossip
    assert daemon.stats["gossip"] == 1, daemon.stats


def test_gossip_is_symmetric_anti_entropy():
    """The gossip ack carries the daemon's own coverage, so the gateway
    learns configurations it never observed locally."""
    dss = DSS(DSSParams(algorithm="coaresec", n_servers=6, parity_m=4,
                        seed=37, recon_repair=False))
    gw = dss.gateway()
    dss.net.run_op(dss.client("w").update("f", _blob(9, 1000)), client="w")
    daemon = dss.start_repair_daemon(period=0.01, objs_per_cycle=1)
    gw.register_daemon(daemon)
    # the daemon privately learns a config the gateway never saw
    cfg9 = dss.make_config()
    daemon.observe_recon(cfg9, 3)
    dss.net.run(until=dss.net.now + 0.1)
    dss.stop_repair_daemon()
    gw.stop()
    dss.net.run()
    assert (3, cfg9.cfg_id) in gw.coverage, "ack must teach the gateway"
    assert gw.stats["gossip_learned"] >= 1


def test_gossip_listener_is_not_a_storage_target():
    """Listener endpoints must never be drafted as storage servers by
    make_config, and unknown messages to them fail loudly."""
    dss = _dss(indexed=True, seed=41)
    gw = dss.gateway()
    daemon = dss.start_repair_daemon(period=0.01, max_cycles=1)
    sid = gw.register_daemon(daemon)
    assert sid in dss.net.servers
    cfg = dss.make_config(n_servers=6)
    assert sid not in cfg.servers
    with pytest.raises(ValueError):
        dss.net.servers[sid].handle("x", ("margin-batch", ("f",), 0))
    with pytest.raises(ValueError):
        gw.register_daemon(daemon)  # duplicate registration
    gw.stop()
    dss.net.run()
    assert isinstance(dss.net.servers[sid], GossipListener)


def test_rider_stats_unpolluted_by_gossip_and_recon_repair():
    """Review regression (ISSUE 4): background traffic under the gateway —
    the gossip loop, and the repair pass a merged recon spawns — runs under
    its OWN client ids, so rider OpStats show ONLY the merged round even
    when a gossip wake-up or repair lands inside it."""
    dss = _dss(n=7, m=3, indexed=True, seed=53)
    doc = _blob(11, 5000)
    boot = dss.session("boot")
    assert boot.write("hot", doc).result()["success"]
    # reference: merged 2-client read with NO daemon registered
    gw0 = dss.gateway("gw0")
    futs = [s.read("hot") for s in (gw0.session("x1"), gw0.session("x2"))]
    clean_rounds = gather(*futs) and futs[0].stats.rounds
    gw0.stop()
    # now with an aggressive gossip loop running through the same window
    gw = dss.gateway("gw1", gossip_period=0.0005)
    daemon = dss.start_repair_daemon(period=0.01, objs_per_cycle=1,
                                     auto_retarget=False)
    gw.register_daemon(daemon)
    a, b = gw.session("a"), gw.session("b")
    fa, fb = a.read("hot"), b.read("hot")
    assert gather(fa, fb) == [doc, doc]
    assert fa.stats.rounds == fb.stats.rounds == clean_rounds, (
        fa.stats, clean_rounds
    )
    assert dss.net.client_totals("gw1:gossip")[0] > 0, (
        "gossip must actually have run during the window"
    )
    # a merged recon spawns recon-repair under its own id too: riders' stats
    # equal each other and exclude the background repair's rounds
    cfg1 = dss.make_config(n_servers=7)
    f1, f2 = a.recon("hot", cfg1), b.recon("hot", cfg1)
    gather(f1, f2)
    assert f1.stats.rounds == f2.stats.rounds
    dss.net.run(until=dss.net.now + 0.1)
    assert dss.net.client_totals("gw1:recon-repair")[0] > 0, (
        "recon-repair must run under its own client id"
    )
    dss.stop_repair_daemon()
    gw.stop()
    dss.net.run()


# ------------------------------------------------------------------ stress
def test_stress_two_gateway_sessions_race_daemon_through_recon():
    """ISSUE 4 satellite: two gateway-attached sessions keep reading and
    writing while a gossip-fed RepairDaemon runs and a reconfiguration
    moves the files — histories must stay atomic/coverable and contents
    must match a write that actually happened."""
    dss = _dss(n=7, m=3, indexed=True, seed=43)
    files = ["f0", "f1", "f2"]
    docs = {f: _blob(50 + i, 2500) for i, f in enumerate(files)}
    boot = dss.session("boot")
    assert all(s["success"] for s in
               gather(*[boot.write(f, d) for f, d in docs.items()]))
    gw = dss.gateway()
    daemon = dss.start_repair_daemon(period=0.01, objs_per_cycle=3,
                                     auto_retarget=False)
    gw.register_daemon(daemon)
    a, b = gw.session("a"), gw.session("b")
    edits = {f: _blob(60 + i, 2500) for i, f in enumerate(files)}
    cfg1 = dss.make_config(n_servers=7)
    futs = [
        a.write("f0", edits["f0"]),
        b.read("f0"),
        a.recon("f1", cfg1),
        b.write("f2", edits["f2"]),
        a.read("f2"),
        b.recon("f2", cfg1),
    ]
    results = gather(*futs)
    assert results[2]["config"] == cfg1.cfg_id
    assert (1, cfg1.cfg_id) in gw.coverage
    dss.net.run(until=dss.net.now + 0.1)   # a few daemon/gossip cycles
    assert daemon.stats["gossip"] >= 1, "daemon must learn cfg1 via gossip"
    dss.stop_repair_daemon()
    gw.stop()
    dss.net.run()
    final = dss.session("check")
    got = gather(*[final.read(f) for f in files])
    for f, content in zip(files, got):
        assert content in (docs[f], edits.get(f)), f"{f}: unknown content"
    assert got[0] == edits["f0"] and got[2] == edits["f2"]
    check_all(dss.history)


# --------------------------------------------- merged-batch dedupe guards
def test_empty_file_rides_the_merged_batch():
    """Regression (fragment.py, ISSUE 4): an indexed file whose block index
    is EMPTY (empty-content write) must still resolve through the batched
    multi-file read instead of vanishing from the merged result."""
    dss = _dss(indexed=True, seed=47)
    s = dss.session("s")
    assert s.write("empty", b"").result()["success"]
    assert s.read("empty").result() == b""
    # merged with a non-empty file it still round-trips
    doc = _blob(10, 3000)
    s.write("full", doc)
    f1, f2 = s.read("empty"), s.read("full")
    assert gather(f1, f2) == [b"", doc]


# ------------------------------------------- mid-flight crash survival
def test_gateway_stat_rider_survives_mid_flight_crash(monkeypatch):
    """ISSUE 10 satellite (c): alive-mode x gateway under a mid-flight
    crash. Two riders' stats merge into one round whose final phase is the
    alive-mode ``margin-batch`` probe; a counted destination crashing
    between the gateway's merged issue and its reply must be abandoned
    (ISSUE 7 semantics THROUGH the gateway tier) so both riders still
    resolve — with the probe's ``alive`` count reflecting the survivors."""
    from repro.core.server import StorageServer

    dss = _dss(indexed=True, seed=17)
    net = dss.net
    boot = dss.session("boot")
    assert boot.write("f", _blob(3, 2000)).result()["success"]
    gw = dss.gateway()
    a, b = gw.session("a"), gw.session("b")

    crashed: list[str] = []
    handled: list[str] = []
    real = StorageServer.handle

    def spy(self, sender, msg):
        # on the FIRST probe arrival, crash a counted destination that has
        # not replied yet: its arrival is now mid-flight on a dead server
        if msg and msg[0] == "margin-batch":
            handled.append(self.sid)
            if not crashed:
                victim = next(s for s in net.servers
                              if s != self.sid and s not in handled)
                crashed.append(victim)
                net.crash(victim)
        return real(self, sender, msg)

    monkeypatch.setattr(StorageServer, "handle", spy)
    fa, fb = a.stat("f"), b.stat("f")
    ra, rb = gather(fa, fb)
    assert crashed and crashed[0] not in handled  # it really was mid-flight
    assert ra == rb  # multicast from the one merged round
    assert ra["margin"] >= 0  # 6 servers, m=2 parity: one loss survivable
    assert fa.stats.batched_with == 2
    assert net.stuck_ops() == []
