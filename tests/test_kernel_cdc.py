"""Pallas cdc_gearhash kernel vs pure-jnp oracle + chunking invariants."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seeded fallback shim — see tests/_propfallback.py
    from _propfallback import given, settings
    from _propfallback import strategies as st

from repro.kernels.cdc_gearhash.ops import boundary_bitmap, gearhash, split_chunks
from repro.kernels.cdc_gearhash.ref import gearhash_ref


@pytest.mark.parametrize("L", [32, 128, 4096, 5000, 12288])
@pytest.mark.parametrize("mask", [0xFF, 0xFFF])
def test_kernel_matches_ref(L, mask):
    rng = np.random.default_rng(L + mask)
    data = rng.integers(0, 256, L, dtype=np.uint8)
    h_k, b_k = gearhash(data, mask=mask, block_l=1024, interpret=True)
    import jax.numpy as jnp

    h_r, b_r = gearhash_ref(jnp.asarray(data), mask=mask)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))
    np.testing.assert_array_equal(np.asarray(b_k), np.asarray(b_r))


def test_locality_of_hash():
    """Hash at position i depends only on bytes (i-31..i) — the CDC property
    that makes chunk boundaries stable under local edits."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, 2048, dtype=np.uint8)
    b = a.copy()
    b[100] ^= 0xFF  # flip one byte
    ha, _ = gearhash(a, interpret=True)
    hb, _ = gearhash(b, interpret=True)
    diff = np.nonzero(np.asarray(ha) != np.asarray(hb))[0]
    assert diff.min() >= 100 and diff.max() <= 100 + 31


@settings(max_examples=15, deadline=None)
@given(st.binary(min_size=1, max_size=8192), st.integers(0, 3))
def test_split_chunks_partition(blob, sz):
    mins, avgs, maxs = [64, 128, 256, 512][sz], [128, 256, 512, 1024][sz], [512, 1024, 2048, 4096][sz]
    chunks = split_chunks(blob, min_size=mins, avg_size=avgs, max_size=maxs, interpret=True)
    assert b"".join(chunks) == blob            # partition: lossless
    for i, c in enumerate(chunks[:-1]):
        assert mins <= len(c) <= maxs or i == len(chunks) - 1
    assert all(len(c) <= maxs for c in chunks)


def test_split_chunks_stability_under_edit():
    """Editing bytes in one region must not move far-away chunk boundaries
    (rsync insight the paper's FM builds on)."""
    rng = np.random.default_rng(5)
    blob = rng.integers(0, 256, 64 * 1024, dtype=np.uint8).tobytes()
    edited = bytearray(blob)
    edited[1000:1100] = rng.integers(0, 256, 100, dtype=np.uint8).tobytes()
    kw = dict(min_size=512, avg_size=1024, max_size=4096, interpret=True)
    c1 = split_chunks(blob, **kw)
    c2 = split_chunks(bytes(edited), **kw)
    # the chunking re-synchronizes after the edit: suffix chunk lists match
    s1 = [bytes(c) for c in c1[-5:]]
    s2 = [bytes(c) for c in c2[-5:]]
    assert s1 == s2
    # and most chunks are shared overall (rsync-style dedup works)
    shared = len(set(c1) & set(c2))
    assert shared >= len(c1) - 4


def test_boundary_density_tracks_avg():
    rng = np.random.default_rng(11)
    data = rng.integers(0, 256, 1 << 18, dtype=np.uint8).tobytes()
    bm = boundary_bitmap(data, avg_size=1024, interpret=True)
    density = bm.mean()
    assert 0.3 / 1024 < density < 3.0 / 1024  # ~1/avg within 3x


def test_empty_and_tiny_inputs():
    assert split_chunks(b"", min_size=4, avg_size=8, max_size=16, interpret=True) == [b""]
    out = split_chunks(b"abc", min_size=4, avg_size=8, max_size=16, interpret=True)
    assert b"".join(out) == b"abc"
