"""Flash attention Pallas kernel vs oracle: shape/dtype/mask sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

CASES = [
    # (B, H, Sq, Sk, hd, causal, window, dtype)
    (1, 2, 128, 128, 32, True, 0, jnp.float32),
    (2, 4, 256, 256, 64, True, 0, jnp.float32),
    (1, 2, 256, 256, 64, False, 0, jnp.float32),
    (1, 2, 256, 256, 64, True, 64, jnp.float32),   # sliding window
    (2, 2, 512, 512, 128, True, 0, jnp.bfloat16),
    (1, 1, 128, 512, 64, True, 0, jnp.float32),    # decode-ish Sq < Sk
]


@pytest.mark.parametrize("B,H,Sq,Sk,hd,causal,window,dtype", CASES)
def test_flash_matches_ref(B, H, Sq, Sk, hd, causal, window, dtype):
    rng = np.random.default_rng(Sq + Sk + hd)
    q = jnp.asarray(rng.standard_normal((B, H, Sq, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, H, Sk, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, H, Sk, hd)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_block_size_invariance():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    a = flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    b = flash_attention(q, k, v, bq=128, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_online_softmax_extreme_values():
    """Online rescaling must not overflow with large logits."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 64, 32)) * 30, jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 64, 32)) * 30, jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
    got = flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    want = flash_attention_ref(q, k, v)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
