"""Pallas gf256_matmul kernel vs pure-jnp oracle: shape/dtype sweeps."""
import numpy as np
import pytest

from repro.erasure import RSCode, gf_matmul_np
from repro.kernels.gf256_matmul.ops import (
    gf256_coding_matmul,
    gf256_matmul,
    rs_encode_parity,
)
from repro.kernels.gf256_matmul.ref import gf256_matmul_ref

SHAPES = [
    (1, 2, 8),
    (2, 4, 128),
    (4, 10, 1000),     # unaligned L -> pad path
    (3, 16, 2048),
    (8, 24, 4096),     # multi-block grid
    (16, 32, 2048),
    (2, 2, 1),         # degenerate L
    (12, 20, 8192),
]


@pytest.mark.parametrize("m,k,L", SHAPES)
def test_kernel_matches_ref(m, k, L):
    rng = np.random.default_rng(m * 1000 + k * 10 + L)
    A = rng.integers(0, 256, (m, k), dtype=np.uint8)
    B = rng.integers(0, 256, (k, L), dtype=np.uint8)
    got = np.asarray(gf256_matmul(A, B, interpret=True))
    want = np.asarray(gf256_matmul_ref(A, B))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m,k,L", [(4, 8, 512), (5, 11, 777)])
def test_ref_matches_numpy_lut(m, k, L):
    rng = np.random.default_rng(0)
    A = rng.integers(0, 256, (m, k), dtype=np.uint8)
    B = rng.integers(0, 256, (k, L), dtype=np.uint8)
    np.testing.assert_array_equal(np.asarray(gf256_matmul_ref(A, B)), gf_matmul_np(A, B))


def test_kernel_edge_values():
    """All-zero, all-ones, and identity corners."""
    k, L = 6, 256
    A = np.eye(k, dtype=np.uint8)
    B = np.arange(k * L, dtype=np.uint8).reshape(k, L)
    np.testing.assert_array_equal(np.asarray(gf256_matmul(A, B, interpret=True)), B)
    Z = np.zeros((3, k), dtype=np.uint8)
    np.testing.assert_array_equal(
        np.asarray(gf256_matmul(Z, B, interpret=True)), np.zeros((3, L), np.uint8)
    )
    F = np.full((2, k), 255, dtype=np.uint8)
    np.testing.assert_array_equal(
        np.asarray(gf256_matmul(F, B, interpret=True)), np.asarray(gf256_matmul_ref(F, B))
    )


def test_block_size_sweep():
    """Same result for every VMEM block size (tiling invariance)."""
    rng = np.random.default_rng(42)
    A = rng.integers(0, 256, (4, 10), dtype=np.uint8)
    B = rng.integers(0, 256, (10, 4096), dtype=np.uint8)
    want = np.asarray(gf256_matmul_ref(A, B))
    for bl in (128, 256, 512, 1024, 2048, 4096):
        got = np.asarray(gf256_matmul(A, B, block_l=bl, interpret=True))
        np.testing.assert_array_equal(got, want, err_msg=f"block_l={bl}")


def test_rs_kernel_backend_matches_numpy_backend():
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, (10, 2048), dtype=np.uint8)
    c_np = RSCode(n=14, k=10, backend="numpy")
    c_kr = RSCode(n=14, k=10, backend="kernel")
    np.testing.assert_array_equal(c_np.encode(data), c_kr.encode(data))
    coded = c_kr.encode(data)
    keep = [1, 3, 5, 7, 9, 10, 11, 12, 13, 0]
    np.testing.assert_array_equal(c_kr.decode(coded[keep], keep), data)


def test_shape_validation_raises_valueerror():
    """Regression (ISSUE 6): shape mismatches must raise ValueError — an
    ``assert`` disappears under ``python -O`` and the mismatch would surface
    as wrong-shaped kernel output."""
    A = np.zeros((2, 4), dtype=np.uint8)
    with pytest.raises(ValueError):
        gf256_matmul(A, np.zeros((5, 16), dtype=np.uint8), interpret=True)
    with pytest.raises(ValueError):
        gf256_matmul(A, np.zeros(16, dtype=np.uint8), interpret=True)
    with pytest.raises(ValueError):
        gf256_coding_matmul(A, np.zeros((5, 16), dtype=np.uint8))
    with pytest.raises(ValueError):
        gf256_coding_matmul(np.zeros(4, dtype=np.uint8), np.zeros((4, 16), dtype=np.uint8))


def test_degenerate_shapes():
    """m == 0 / L == 0 / k == 0 products the storage path can produce
    (parity-free codes, empty values) return empty matrices, not crashes."""
    for ma, ka, L in [(0, 4, 16), (2, 4, 0), (0, 0, 0), (2, 0, 5)]:
        A = np.zeros((ma, ka), dtype=np.uint8)
        B = np.zeros((ka, L), dtype=np.uint8)
        for fn in (
            lambda a, b: gf256_matmul(a, b, interpret=True),
            gf256_coding_matmul,
        ):
            out = np.asarray(fn(A, B))
            assert out.shape == (ma, L) and out.dtype == np.uint8


def test_coding_matmul_matches_lut():
    """The production dispatcher (whatever backend it picks on this host) is
    bit-identical to the numpy LUT reference across L sizes."""
    rng = np.random.default_rng(11)
    A = rng.integers(0, 256, (3, 7), dtype=np.uint8)
    for L in (1, 7, 128, 1000, 5000):
        B = rng.integers(0, 256, (7, L), dtype=np.uint8)
        np.testing.assert_array_equal(
            np.asarray(gf256_coding_matmul(A, B)), gf_matmul_np(A, B)
        )


def test_rs_encode_parity_wrapper():
    rng = np.random.default_rng(9)
    code = RSCode(n=12, k=8)
    data = rng.integers(0, 256, (8, 1024), dtype=np.uint8)
    par = np.asarray(rs_encode_parity(code.parity_matrix, data, interpret=True))
    np.testing.assert_array_equal(par, code.encode(data)[8:])
