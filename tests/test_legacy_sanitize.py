"""Sanitizer + race-tracker hooks on the LEGACY network engine (ISSUE 9).

The PR-7/8 observer hooks were exercised almost exclusively through the
fast ``_FanOut`` path (``DSSParams.fast_net=True``, the default); the
legacy per-destination engine carries its own copies of the ``on_rpc`` /
``on_reply`` / drop / race brackets inside ``_legacy_send``. This module
runs a representative sanitized subset of the tier-1 surface with
``fast_net=False`` so those hooks are tested — and pins the legacy
sanitized trace bit-identical to the fast sanitized trace, which is the
strongest statement that both engines drive the same observer sequence.
"""
import pytest

from repro.analysis.sanitizer import SanitizerError
from repro.core.store import DSS, DSSParams
from repro.core.tags import TAG0
from repro.core.workload import CrashStorm, WorkloadGen, WorkloadSpec

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _params(**kw) -> DSSParams:
    kw.setdefault("fast_net", False)
    kw.setdefault("sanitize", True)
    return DSSParams(**kw)


def test_legacy_sanitized_workload_matches_fast_trace():
    """Mixed zipfian reads/writes + a crash storm on the legacy engine with
    the sanitizer AND race tracker live: clean, and every trace counter is
    bit-identical to the fast engine's sanitized run."""
    spec = WorkloadSpec(sessions=80, files=8, file_size=512,
                        read_fraction=0.8,
                        storms=(CrashStorm(at=0.05, frac=0.25, duration=0.03),))
    legacy = WorkloadGen(spec, seed=11).run(
        DSS(_params(algorithm="coaresecf", seed=11, racecheck=True))
    )
    fast = WorkloadGen(spec, seed=11).run(
        DSS(_params(algorithm="coaresecf", seed=11, racecheck=True,
                    fast_net=True))
    )
    assert legacy["sanitizer"]["checks"] > 100
    assert legacy["races"]["checks"] > 0
    for key in ("rpc_rounds", "msg_count", "bytes_sent", "events",
                "virtual_makespan", "ops_done", "ops_failed"):
        assert legacy[key] == fast[key], key


def test_legacy_sanitized_recon_path():
    """ABD -> EC reconfiguration with fresh servers through the legacy
    engine: config registration and the per-reply checks stay clean."""
    dss = DSS(_params(algorithm="coaresec", n_servers=5, parity_m=1, seed=2))
    sess = dss.session("c1")
    sess.write("f", b"a" * 512)
    dss.run()
    target = dss.make_config(n_servers=5, parity_m=2, fresh_servers=True)
    sess.recon("f", target)
    dss.run()
    sess.read("f")
    dss.run()
    san = dss.net.sanitizer
    assert san.known_k[frozenset(target.servers)] == target.k
    assert dss.check_history()["ops"] >= 2


def test_legacy_sanitizer_catches_tag_regression():
    """The bypassing-regression control from the fast-engine suite, on the
    legacy reply path: ``on_reply`` inside ``_legacy_send``'s arrive
    closure must catch it."""
    dss = DSS(_params(algorithm="coaresabd", n_servers=3, seed=0))
    sess = dss.session("c1")
    sess.write("f", b"v1")
    dss.run()
    sess.read("f")
    dss.run()
    srv = dss.net.servers["s0"]
    dict.__setitem__(srv.abd, ("f", 0), (TAG0, None))
    dict.clear(srv._rcache)
    dict.clear(srv._rkeys)
    sess.read("f")
    with pytest.raises(SanitizerError, match="monotonicity"):
        dss.run()


def test_legacy_sanitized_fragmented_write_read():
    """Fragmented store (genesis + blocks, batched RPCs) sanitized on the
    legacy engine, closing with the strict Wing–Gong pass."""
    dss = DSS(_params(algorithm="coaresecf", n_servers=4, seed=5,
                      racecheck=True))
    sess = dss.session("c1")
    sess.write("f", bytes(range(256)) * 24)
    dss.run()
    fut = sess.read("f")
    dss.run()
    assert fut.result() == bytes(range(256)) * 24
    assert dss.check_history()["ops"] >= 2
    assert dss.net.race_tracker.report()["checks"] > 0
