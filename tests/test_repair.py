"""Self-healing fragment repair (ISSUE 1 tentpole).

Scenarios: crash up to f = ⌊(n-k)/2⌋ servers mid-workload, recover them with
stale (or wiped) Lists, run the RepairController, and check that

* every live server again holds a decodable coded element at the max tag,
* a subsequent crash of a *different* f servers still allows reads,
* recorded histories still pass the atomicity/coverability checkers,
* repair never regresses server state under concurrent writes.
"""
import numpy as np
import pytest

from checkers import check_all, check_atomicity, check_coverability
from repro.core import DSS, DSSParams, RepairController, TAG0
from repro.core.repair import RepairController as _RC  # module import path
from repro.erasure import RSCode
from repro.net.sim import Sleep


def _blob(seed, size):
    return np.random.default_rng(seed).integers(0, 256, size, dtype=np.uint8).tobytes()


def _full_tags(dss, sid, obj, idx=0):
    """Tags for which server ``sid`` still holds a coded element."""
    lst = dss.net.servers[sid].ec.get((obj, idx), {})
    return {t for t, e in lst.items() if e is not None}


def _max_decodable_tag(dss, obj, k, idx=0, servers=None):
    """Max tag with >= k coded elements across the given (default live) servers."""
    servers = servers if servers is not None else dss.net.alive()
    counts = {}
    for sid in servers:
        for t in _full_tags(dss, sid, obj, idx):
            counts[t] = counts.get(t, 0) + 1
    good = [t for t, c in counts.items() if c >= k]
    return max(good, default=TAG0)


def _assert_all_live_decodable(dss, obj, cfg, idx=0):
    """Every live server holds an element at the max decodable tag, and the
    elements really decode (MDS bit-identity, not just presence)."""
    t_star = _max_decodable_tag(dss, obj, cfg.k, idx)
    frags = {}
    for sid in dss.net.alive():
        tags = _full_tags(dss, sid, obj, idx)
        assert t_star in tags, f"{sid} missing element at max tag {t_star} for {obj}"
        elem = dss.net.servers[sid].ec[(obj, idx)][t_star]
        frags[cfg.frag_index(sid)] = elem
    # decode from an arbitrary k-subset that includes a repaired server
    code = RSCode(n=cfg.n, k=cfg.k)
    idxs = sorted(frags)[: cfg.k]
    orig = frags[idxs[0]][1]
    got = code.decode_bytes({i: frags[i][0] for i in idxs}, orig)
    idxs2 = sorted(frags)[-cfg.k:]
    got2 = code.decode_bytes({i: frags[i][0] for i in idxs2}, frags[idxs2[0]][1])
    assert got == got2, "different k-subsets decode to different values"
    return t_star, got


# n=6, parity_m=4 -> k=2, f = (n-k)/2 = 2
_PARAMS = dict(algorithm="coaresec", n_servers=6, parity_m=4, seed=11)


def test_repair_restores_stale_recovered_servers():
    dss = DSS(DSSParams(**_PARAMS))
    cfg = dss.c0
    f = (cfg.n - cfg.k) // 2
    w = dss.client("w")
    v1 = _blob(1, 4000)
    dss.net.run_op(w.update("f", v1), client="w")
    # crash f servers mid-workload; writes keep completing via the quorum
    down1 = ["s0", "s1"]
    assert len(down1) == f
    dss.crash_servers(down1)
    v2, v3 = _blob(2, 4000), _blob(3, 4100)
    dss.net.run_op(w.update("f", v2), client="w")
    dss.net.run_op(w.update("f", v3), client="w")
    # crash-recover: they come back with STALE Lists (missed v2, v3)
    dss.recover_servers(down1)
    t_star = _max_decodable_tag(dss, "f", cfg.k)
    for sid in down1:
        assert t_star not in _full_tags(dss, sid, "f"), "precondition: stale"
    stats = dss.repair()
    assert stats[0]["applied"] == len(down1)
    t_after, decoded = _assert_all_live_decodable(dss, "f", cfg)
    assert t_after == t_star and decoded == v3
    # a DIFFERENT f crashes: reads must still complete and return v3
    dss.crash_servers(["s2", "s3"])
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == v3
    check_all(dss.history)


def test_repair_restores_wiped_servers():
    """Disk-loss recovery: the rejoining servers lost ALL coded fragments."""
    dss = DSS(DSSParams(**_PARAMS))
    cfg = dss.c0
    w = dss.client("w")
    v = _blob(4, 6000)
    dss.net.run_op(w.update("f", v), client="w")
    dss.crash_servers(["s4", "s5"])
    dss.wipe_servers(["s4", "s5"])
    dss.recover_servers(["s4", "s5"])
    assert _full_tags(dss, "s4", "f") == set()
    dss.repair()
    _, decoded = _assert_all_live_decodable(dss, "f", cfg)
    assert decoded == v
    check_all(dss.history)


def test_repair_noop_when_healthy():
    dss = DSS(DSSParams(**_PARAMS))
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(5, 1000)), client="w")
    stats = dss.repair()
    assert stats[0]["missing"] == 0 and stats[0]["pushed"] == 0
    # and on a never-written store the pass is a clean no-op at TAG0
    fresh = DSS(DSSParams(**_PARAMS))
    assert fresh.repair(objs=["ghost"])[0]["tag"] == TAG0


def test_repair_fragmented_file_all_blocks():
    """coaresecf: repair every block object of a fragmented file."""
    dss = DSS(DSSParams(algorithm="coaresecf", n_servers=6, parity_m=4, seed=13,
                        min_block=256, avg_block=512, max_block=2048))
    cfg = dss.c0
    w = dss.client("w")
    blob = _blob(6, 10_000)
    dss.net.run_op(w.update("f", blob), client="w")
    dss.crash_servers(["s0", "s1"])
    blob2 = blob[:4000] + _blob(7, 800) + blob[4000:]
    dss.net.run_op(w.update("f", blob2), client="w")
    dss.recover_servers(["s0", "s1"])
    stats = dss.repair()
    assert len(stats) == len(dss.ec_objects())
    for obj in dss.ec_objects():
        _assert_all_live_decodable(dss, obj, cfg)
    dss.crash_servers(["s2", "s3"])
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == blob2
    check_all(dss.history)


def test_repair_safe_under_concurrent_writes():
    """Repair racing foreground writers must never regress server Lists or
    break atomicity/coverability; the final read returns the last write."""
    dss = DSS(DSSParams(**_PARAMS, delta=4))
    cfg = dss.c0
    w = dss.client("w")
    dss.net.run_op(w.update("f", _blob(8, 3000)), client="w")
    dss.crash_servers(["s0", "s1"])
    dss.net.run_op(w.update("f", _blob(9, 3000)), client="w")
    dss.recover_servers(["s0", "s1"])

    last = {}

    def writer_loop():
        for i in range(6):
            yield Sleep(float(dss.net.rng.uniform(0, 1e-3)))
            blob = _blob(100 + i, 2500 + 17 * i)
            (tag, _v), flag = yield from w.dsm.cvr_write("f", blob)
            if flag == "chg":
                last[tag] = blob
        return True

    rc = RepairController(dss.net, cfg, 0, history=dss.history)
    futs = [
        dss.net.spawn(writer_loop(), client="w"),
        dss.net.spawn(rc.scan_and_repair(["f"]), client="repair"),
        dss.net.spawn(rc.scan_and_repair(["f"]), client="repair", delay=2e-3),
    ]
    dss.net.run()
    assert all(f.done for f in futs)
    # no regression: a final repair pass leaves every live server decodable
    dss.repair()
    _t, decoded = _assert_all_live_decodable(dss, "f", cfg)
    assert decoded == last[max(last)]
    r = dss.client("r")
    assert dss.net.run_op(r.read("f"), client="r") == last[max(last)]
    check_atomicity(dss.history)
    check_coverability(dss.history)


def test_repair_push_never_regresses_or_resurrects():
    """Server-level safety: a pushed tag never overwrites existing elements,
    and a trimmed (tag, ⊥) placeholder stays trimmed."""
    dss = DSS(DSSParams(**_PARAMS, delta=1))
    srv = dss.net.servers["s0"]
    # simulate a List that advanced and trimmed tag (1, 'w')
    for ts in (1, 2, 3):
        srv.handle("w", ("ec-put", "f", 0, (ts, "w"), (bytes([ts]), 1), 1))
    lst = srv.ec[("f", 0)]
    assert lst[(1, "w")] is None  # trimmed
    # push for the trimmed tag: must NOT resurrect
    kind, applied = srv.handle("rc", ("ec-repair-push", "f", 0, (1, "w"), (b"Z", 1), 1))
    assert kind == "repair-ack" and not applied and lst[(1, "w")] is None
    # push for an existing full tag: must NOT overwrite
    kind, applied = srv.handle("rc", ("ec-repair-push", "f", 0, (3, "w"), (b"Z", 1), 1))
    assert not applied and lst[(3, "w")] == (bytes([3]), 1)
    # push for an unseen tag: applied, and the δ+1 trim still holds
    kind, applied = srv.handle("rc", ("ec-repair-push", "f", 0, (4, "w"), (b"Q", 1), 1))
    assert applied
    full = [t for t, e in lst.items() if e is not None]
    assert len(full) <= 2 and max(full) == (4, "w")


def test_repair_requires_ec_config():
    dss = DSS(DSSParams(algorithm="coaresabd", n_servers=5, seed=1))
    with pytest.raises(ValueError):
        _RC(dss.net, dss.c0)


def test_repair_skips_undecodable_tag():
    """With fewer than k surviving elements at the newest tag, repair falls
    back to the newest still-decodable tag instead of fabricating data."""
    dss = DSS(DSSParams(**_PARAMS))  # k=2
    cfg = dss.c0
    w = dss.client("w")
    v1 = _blob(20, 2000)
    dss.net.run_op(w.update("f", v1), client="w")
    # fabricate a half-written newer tag on ONE server only (k=2 needed)
    srv = dss.net.servers["s5"]
    lst = srv.ec[("f", 0)]
    newest = max(t for t, e in lst.items() if e is not None)
    orphan = (newest[0] + 7, "ghost")
    srv.handle("w", ("ec-put", "f", 0, orphan, (b"\x00" * 1000, 1000), cfg.delta))
    stats = dss.repair()
    assert stats[0]["tag"] != orphan  # repaired the decodable tag, not the orphan
    t_star, decoded = _assert_all_live_decodable(dss, "f", cfg)
    assert decoded == v1
