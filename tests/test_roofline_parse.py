"""Unit tests for the scan-weighted HLO analyzer (roofline/hlo_parse.py)."""

from repro.roofline.analysis import roofline_report
from repro.roofline.hlo_parse import _shape_bytes, analyze, parse_blocks

HLO = """\
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  ROOT %lt = pred[] constant(true)
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (in: f32[8,16]) -> f32[8,16] {
  %in = f32[8,16]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%c0, %in)
  %wh = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  %g = f32[8,16]{1,0} get-tuple-element(%wh), index=1
  %ag = f32[16,16]{1,0} all-gather(%g), dimensions={0}
  %dot.2 = f32[8,16]{1,0} dot(%g, %ag), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[8,16]{1,0} bitcast(%dot.2)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert _shape_bytes("bf16[4,4]") == 32
    assert _shape_bytes("(s32[2], f32[3])") == 8 + 12
    assert _shape_bytes("pred[]") == 1


def test_parse_blocks_finds_computations():
    blocks, entry = parse_blocks(HLO)
    assert entry == "main"
    assert "body" in blocks and "cond" in blocks
    assert any(i.op == "while" for i in blocks["main"].instrs)


def test_scan_weighted_flops_and_collectives():
    r = analyze(HLO)
    # dot.1 inside the trip-12 while: 2*8*16*16 flops * 12; dot.2 once.
    per_dot = 2 * 8 * 16 * 16
    assert r["flops"] == per_dot * 12 + per_dot
    # all-reduce inside loop: 2x result bytes x 12; all-gather once: result
    ar = 2 * (8 * 16 * 4) * 12
    ag = 16 * 16 * 4
    assert r["collective_bytes"]["all-reduce"] == ar
    assert r["collective_bytes"]["all-gather"] == ag
    assert r["unknown_trip_whiles"] == 0


def test_roofline_report_terms_and_dominance():
    rep = roofline_report(
        flops=197e12, bytes_accessed=819e9 * 2, collective_bytes=50e9,
        n_chips=256, model_flops=197e12 * 256 * 0.5,
    )
    assert abs(rep["compute"] - 1.0) < 1e-6
    assert abs(rep["memory"] - 2.0) < 1e-6
    assert rep["dominant"] == "memory"
    assert abs(rep["mfu_upper_bound"] - 0.25) < 1e-6
    assert abs(rep["model_flops_ratio"] - 0.5) < 1e-6
