"""Scale-out hot path (ISSUE 7): fast/legacy trace identity, the sizing
memo's exactness, the server reply cache, the workload harness, and the
satellite fixes (nbytes UTF-8, latency-nan, schedule clamp, drop-stream
isolation, alive-mode abandonment)."""
import math

import numpy as np
import pytest

from repro.core import DSS, DSSParams, CrashStorm, WorkloadGen, WorkloadSpec
from repro.net.codec import SizingMemo, wire_size
from repro.net.sim import RPC, LatencyModel, Network, OpFuture, Server, nbytes


class Echo(Server):
    def handle(self, sender, msg):
        return ("echo", self.sid, msg)


def _mknet(fast, n=5, seed=3, **lat):
    net = Network(seed=seed, latency=LatencyModel(**lat), fast=fast)
    for i in range(n):
        net.add_server(Echo(f"s{i}"))
    return net


def _net_fingerprint(net):
    return (
        round(net.now, 12),
        net.events_processed,
        net.rpc_rounds,
        net.msg_count,
        net.bytes_sent,
        net.client_counters,
    )


# --------------------------------------------------- fast == legacy traces
def _workload_report(fast, *, sessions=40, seed=11, gateway=False, storms=()):
    dss = DSS(DSSParams(
        algorithm="coaresecf", n_servers=6, parity_m=2, seed=5,
        min_block=256, avg_block=512, max_block=2048,
        indexed=True, batched=True, fast_net=fast,
    ))
    spec = WorkloadSpec(sessions=sessions, files=8, file_size=512,
                        read_fraction=0.7, ops_per_session=2, storms=storms)
    via = dss.gateway() if gateway else None
    rep = WorkloadGen(spec, seed=seed).run(dss, via=via)
    if via is not None:
        via.stop()
    return rep, _net_fingerprint(dss.net)


def test_trace_identity_mixed_workload():
    # reads + writes + churn through the Session tier: every counter, every
    # virtual timestamp, and the per-client accounting must match exactly.
    a = _workload_report(True)
    b = _workload_report(False)
    assert a == b


def test_trace_identity_under_crash_storm():
    storms = (CrashStorm(at=0.02, frac=0.4, duration=0.03),)
    a = _workload_report(True, storms=storms, seed=13)
    b = _workload_report(False, storms=storms, seed=13)
    assert a == b


def test_trace_identity_via_gateway():
    a = _workload_report(True, sessions=20, gateway=True)
    b = _workload_report(False, sessions=20, gateway=True)
    assert a == b


def _drop_trial(fast):
    net = _mknet(fast, n=5, seed=9, drop_prob=0.25)
    dests = tuple(net.servers)

    def op(k):
        if k % 3 == 0:  # per-dest payloads exercise the non-shared sizing
            per = {s: ("ping", k, s) for s in dests}
            replies = yield RPC(dests=dests, msg=("ping", k, "*"),
                                need=2, per_dest=per)
        else:
            replies = yield RPC(dests=dests, msg=("ping", k), need=2)
        return sorted(replies)

    futs = [net.spawn(op(k), client=f"c{k % 3}") for k in range(30)]
    net.run()
    return ([(f.done, f.result) for f in futs], _net_fingerprint(net))


@pytest.mark.allow_stuck
def test_trace_identity_with_drops():
    # drop_prob > 0: both engines burn the same drop stream and lose the
    # same messages; stuck-vs-done status per op must agree too.
    assert _drop_trial(True) == _drop_trial(False)


def _alive_crash_trial(fast):
    net = _mknet(fast, n=3, seed=5)

    def op():
        replies = yield RPC(dests=("s0", "s1", "s2"), msg=("ping",),
                            need="alive")
        return set(replies)

    fut = net.spawn(op())
    # s1 was live at issue time (counted into need) but crashes before the
    # message lands: the op must resume with the survivors, not hang.
    net.schedule(0.0, lambda: net.crash("s1"))
    net.run()
    assert fut.done, "alive-mode op hung on a crash between issue and reply"
    return fut.result


def test_alive_need_crash_between_issue_and_reply():
    assert _alive_crash_trial(True) == _alive_crash_trial(False) == {"s0", "s2"}


# ------------------------------------------------------------- satellites
def test_nbytes_utf8_length():
    s = "héllo"  # 6 UTF-8 bytes, 5 code points
    assert nbytes(s) == len(s.encode("utf-8")) == 6
    assert nbytes("plain") == 5


def test_opfuture_latency_nan_until_done():
    fut = OpFuture(op_id=0)
    fut.start = 5.0
    assert math.isnan(fut.latency)
    fut.done = True
    fut.end = 7.5
    assert fut.latency == 2.5


def test_schedule_negative_delay_clamped():
    net = Network(seed=0)
    order = []
    net.schedule(0.001, lambda: order.append(("late", net.now)))
    net.schedule(-5.0, lambda: order.append(("clamped", net.now)))
    net.run()
    # the negative delay fires NOW (no time travel), before the later event
    assert order == [("clamped", 0.0), ("late", 0.001)]


def _latency_trace(fast, burn):
    net = _mknet(fast, n=4, seed=21)  # drop_prob = 0
    if burn:
        net._drop_rng.random(1000)  # advance the drop stream arbitrarily

    def op(k):
        replies = yield RPC(dests=tuple(net.servers), msg=("ping", k), need=3)
        return len(replies)

    for k in range(10):
        net.spawn(op(k), client="c")
    net.run()
    return [f.latency for f in net.futures], net.now


def test_drop_stream_isolated_when_prob_zero():
    # satellite (c): with drop_prob == 0 no drop draw is consumed per
    # message, so the drop stream's position cannot affect any latency.
    for fast in (True, False):
        assert _latency_trace(fast, False) == _latency_trace(fast, True)


# ------------------------------------------------------------ sizing memo
def _rand_obj(rng, depth=0):
    kinds = 8 if depth < 3 else 6
    r = int(rng.integers(0, kinds))
    if r == 0:
        return None
    if r == 1:
        return int(rng.integers(-(2 ** 40), 2 ** 40))
    if r == 2:
        return float(rng.normal())
    if r == 3:
        return rng.bytes(int(rng.integers(0, 40)))
    if r == 4:
        return "".join(chr(int(c))
                       for c in rng.integers(32, 1500, int(rng.integers(0, 8))))
    if r == 5:
        return bool(rng.integers(0, 2))
    if r == 6:
        return tuple(_rand_obj(rng, depth + 1)
                     for _ in range(int(rng.integers(0, 5))))
    return [_rand_obj(rng, depth + 1) for _ in range(int(rng.integers(0, 4)))]


def test_sizing_memo_matches_plain_walk():
    rng = np.random.default_rng(42)
    memo = SizingMemo()
    objs = [_rand_obj(rng) for _ in range(300)]
    for obj in objs:
        assert memo.wire_size(obj) == wire_size(obj)
    for obj in objs:  # second pass: identity/content hits, same answers
        assert memo.wire_size(obj) == wire_size(obj)


def test_sizing_memo_numeric_aliasing_guard():
    # 0 == False == 0.0 and 1 == True == 1.0, yet the three frame
    # differently — the content cache must never cross-contaminate them.
    memo = SizingMemo()
    variants = [("x", 0), ("x", False), ("x", 0.0),
                ("x", 1), ("x", True), ("x", 1.0)]
    for _ in range(3):
        for v in variants:
            assert memo.wire_size(v) == wire_size(v)
    # fresh-but-equal objects (new ids, same values) must be exact too
    for v in variants:
        clone = (v[0], v[1])
        assert memo.wire_size(clone) == wire_size(v)


def test_sizing_memo_mutation_safe():
    memo = SizingMemo()
    lst = [1, b"ab"]
    before = memo.wire_size(lst)
    lst.append("grown")
    after = memo.wire_size(lst)
    assert after == wire_size(lst) != before
    nested = (7, [1, 2])  # unhashable content: never cached by value
    first = memo.wire_size(nested)
    nested[1].append(3)
    assert memo.wire_size(nested) == wire_size(nested) != first


# ------------------------------------------------------ server reply cache
def test_server_reply_cache_identity_and_invalidation():
    from repro.core.server import StorageServer

    srv = StorageServer("s0")
    srv.handle("w", ("ec-put", "obj", 0, (1, "w"), b"frag-a", 8))
    r1 = srv.handle("c", ("ec-query", "obj", 0, None))
    r2 = srv.handle("c", ("ec-query", "obj", 0, None))
    assert r2 is r1  # cache hit returns the SAME reply object (memo-friendly)
    srv.handle("w", ("ec-put", "obj", 0, (2, "w"), b"frag-b", 8))
    r3 = srv.handle("c", ("ec-query", "obj", 0, None))
    assert r3 is not r1
    assert (2, "w") in dict(r3[1])
    # a write to one object must not evict another object's cached reply
    srv.handle("w", ("ec-put", "other", 0, (1, "w"), b"frag-o", 8))
    o1 = srv.handle("c", ("ec-query", "other", 0, None))
    srv.handle("w", ("ec-put", "obj", 0, (3, "w"), b"frag-c", 8))
    assert srv.handle("c", ("ec-query", "other", 0, None)) is o1


# -------------------------------------------------------------- harness
def test_workloadgen_plan_is_deterministic():
    spec = WorkloadSpec(sessions=50, files=16)
    p1 = WorkloadGen(spec, seed=3).plan()
    p2 = WorkloadGen(spec, seed=3).plan()
    assert p1.keys() == p2.keys()
    for k in ("fids", "is_read", "arrivals", "thinks"):
        assert np.array_equal(p1[k], p2[k])
    assert p1["payloads_seed"] == p2["payloads_seed"]


def test_workloadgen_zipf_skew():
    w = WorkloadGen(WorkloadSpec(files=32, zipf_s=0.99)).zipf_weights()
    assert len(w) == 32 and abs(w.sum() - 1.0) < 1e-12
    assert all(w[i] >= w[i + 1] for i in range(31))  # rank-ordered popularity
    assert w[0] > 5 * w[-1]


def test_workloadgen_storm_capped_at_tolerable():
    dss = DSS(DSSParams(algorithm="coaresecf", n_servers=6, parity_m=2))
    spec = WorkloadSpec(sessions=4, storms=(CrashStorm(at=0.01, frac=1.0),))
    gen = WorkloadGen(spec, seed=1)
    [(storm, crash_ids)] = gen._storm_plan(dss)
    tolerable = dss.params.n_servers - dss.c0.quorum()
    assert 0 < len(crash_ids) <= tolerable  # a full-fleet storm is capped
