"""Network simulator behaviour tests."""
import numpy as np
import pytest

from repro.net.sim import RPC, LatencyModel, Network, Server, Sleep, nbytes


class Echo(Server):
    def __init__(self, sid):
        super().__init__(sid)
        self.count = 0

    def handle(self, sender, msg):
        self.count += 1
        return ("echo", self.sid, msg)


def _mknet(n=5, seed=0, **lat):
    net = Network(seed=seed, latency=LatencyModel(**lat))
    for i in range(n):
        net.add_server(Echo(f"s{i}"))
    return net


def test_quorum_rpc_resumes_at_need():
    net = _mknet(5)

    def op():
        replies = yield RPC(dests=tuple(net.servers), msg=("ping",), need=3)
        return replies

    replies = net.run_op(op())
    assert len(replies) == 3


def test_crashed_servers_do_not_reply():
    net = _mknet(5)
    net.crash("s0")
    net.crash("s1")

    def op():
        replies = yield RPC(dests=tuple(net.servers), msg=("ping",), need=3)
        return sorted(replies)

    assert net.run_op(op()) == ["s2", "s3", "s4"]


@pytest.mark.allow_stuck
def test_op_blocks_without_quorum():
    net = _mknet(3)
    net.crash("s0")
    net.crash("s1")

    def op():
        yield RPC(dests=tuple(net.servers), msg=("ping",), need=2)
        return "done"

    fut = net.spawn(op())
    net.run()
    assert not fut.done  # liveness requires a quorum


def test_latency_depends_on_size():
    lat = LatencyModel(base_lo=1e-3, base_hi=1e-3, bandwidth=1e6)

    def run_one(payload):
        net = Network(seed=1, latency=lat)
        for i in range(3):
            net.add_server(Echo(f"s{i}"))

        def op():
            yield RPC(dests=tuple(net.servers), msg=payload, need=3)
            return net.now

        return net.run_op(op())

    t_small = run_one(b"x")
    t_big = run_one(b"x" * 1_000_000)
    assert t_big > t_small + 0.5  # 1 MB at 1 MB/s adds ~1s each way


def test_determinism_same_seed():
    def run(seed):
        net = _mknet(5, seed=seed)

        def op():
            yield RPC(dests=tuple(net.servers), msg=("a",), need=4)
            yield Sleep(0.01)
            replies = yield RPC(dests=tuple(net.servers), msg=("b",), need=2)
            return (net.now, sorted(replies))

        return net.run_op(op())

    assert run(42) == run(42)
    assert run(42) != run(43)


def test_nested_generators_compose():
    net = _mknet(4)

    def inner():
        r = yield RPC(dests=("s0", "s1"), msg=("inner",), need=2)
        return len(r)

    def outer():
        a = yield from inner()
        b = yield from inner()
        return a + b

    assert net.run_op(outer()) == 4


def test_late_replies_ignored():
    net = _mknet(5)

    def op():
        r1 = yield RPC(dests=tuple(net.servers), msg=("x",), need=1)
        r2 = yield RPC(dests=tuple(net.servers), msg=("y",), need=5)
        return (len(r1), len(r2))

    assert net.run_op(op()) == (1, 5)
    # every server handled both rounds despite the early resume
    assert all(s.count == 2 for s in net.servers.values())


def test_nbytes_accounting():
    assert nbytes(b"abcd") == 4
    assert nbytes(("t", b"abcd", 7)) == 16 + 1 + 4 + 8
    assert nbytes(None) == 1
    assert nbytes({"k": b"xy"}) == 16 + 1 + 2


def test_nbytes_ndarray_payloads():
    """np.ndarray must be sized by its buffer — since ISSUE 4 via the codec's
    real ndarray framing (dtype + shape + payload frame), not the legacy
    ``16 + nbytes`` guess and never the generic 64-byte default."""
    from repro.net import codec

    a = np.zeros((4, 8), dtype=np.uint8)
    assert nbytes(a) == codec.wire_size(a)
    assert a.nbytes < nbytes(a) <= a.nbytes + 16
    big = np.zeros(1 << 16, dtype=np.float32)
    assert nbytes(big) == codec.wire_size(big)
    assert big.nbytes < nbytes(big) <= big.nbytes + 20
    # arrays nested in heuristic containers carry their framed size
    assert nbytes(("frag", a)) == 16 + 4 + codec.wire_size(a)
    # numpy scalars: their own itemsize, not 64
    assert nbytes(np.uint8(3)) == 1
    assert nbytes(np.float64(1.5)) == 8


def test_ndarray_payload_drives_latency():
    """A large array message must take longer than a tiny one (bandwidth term)."""
    times = {}
    for name, payload in [("small", np.zeros(8, np.uint8)),
                          ("large", np.zeros(1 << 20, np.uint8))]:
        net = _mknet(1, base_lo=1e-4, base_hi=1e-4, bandwidth=125e6)

        def op(p=payload):
            yield RPC(dests=("s0",), msg=("data", p), need=1)
            return None

        net.run_op(op())
        times[name] = net.now
    assert times["large"] > times["small"] * 10


def test_rpc_need_alive_counts_live_destinations():
    net = _mknet(5)
    net.crash("s0")
    net.crash("s1")

    def op():
        replies = yield RPC(dests=tuple(net.servers), msg=("ping",), need="alive")
        return sorted(replies)

    assert net.run_op(op()) == ["s2", "s3", "s4"]


def test_rpc_need_alive_all_crashed_resumes_empty():
    net = _mknet(3)
    for s in list(net.servers):
        net.crash(s)

    def op():
        replies = yield RPC(dests=tuple(net.servers), msg=("ping",), need="alive")
        return replies

    assert net.run_op(op()) == {}


def test_message_drops_still_quorum():
    net = _mknet(5, seed=3, drop_prob=0.1)

    def op():
        r = yield RPC(dests=tuple(net.servers), msg=("p",), need=2)
        return len(r)

    assert net.run_op(op()) == 2
