"""§VII-E style stress: concurrent readers/writers/reconfigurer with random
DAP switches and server-set churn — service must stay live and safe."""
import numpy as np
import pytest

from checkers import check_all
from repro.core import DSS, DSSParams


@pytest.mark.parametrize("alg", ["coaresabdf", "coaresecf"])
def test_mixed_workload_with_recons(alg):
    dss = DSS(DSSParams(algorithm=alg, n_servers=5, seed=101,
                        min_block=64, avg_block=128, max_block=512))
    rng = np.random.default_rng(7)
    base = rng.integers(0, 256, 4000, dtype=np.uint8).tobytes()
    boot = dss.client("boot")
    dss.net.run_op(boot.update("f", base), client="boot")

    writers = [dss.client(f"w{i}") for i in range(2)]
    readers = [dss.client(f"r{i}") for i in range(2)]
    recfg = dss.client("g")

    futs = []
    # writers: read-then-edit loops, staggered
    for wi, w in enumerate(writers):
        def wloop(w=w, wi=wi):
            for round_ in range(3):
                cur0 = yield from w.read("f")
                cur = bytearray(cur0)
                pos = (wi * 1931 + round_ * 653) % max(1, len(cur))
                cur[pos] ^= 0xFF
                yield from w.update("f", bytes(cur))
            return "w-done"
        futs.append(dss.net.spawn(wloop(), client=f"w{wi}", delay=0.001 * wi))
    # readers
    for ri, r in enumerate(readers):
        def rloop(r=r):
            out = []
            for _ in range(4):
                c = yield from r.read("f")
                out.append(len(c))
            return out
        futs.append(dss.net.spawn(rloop(), client=f"r{ri}", delay=0.0007 * ri))
    # reconfigurer: 3 recons switching DAP and server count (§VII-E scenario 3)
    def gloop():
        for i in range(3):
            cfg = dss.make_config(
                dap=["abd", "ec_opt", "abd"][i],
                n_servers=[7, 5, 9][i],
            )
            yield from recfg.recon("f", cfg)
        return "g-done"
    futs.append(dss.net.spawn(gloop(), client="g", delay=0.002))

    dss.net.run()
    assert all(f.done for f in futs), "service interrupted by reconfiguration"
    # Final read is a coherent, connected file. NOTE: fragmented coverability
    # is per-block — concurrent *structural* edits may partially apply (one
    # writer's ptr write can lose its block race), so content may interleave;
    # what the model guarantees is connectivity + per-block atomicity +
    # coverability, all asserted by check_all. Size stays within a few blocks
    # of the base.
    r = dss.client("rf")
    final = dss.net.run_op(r.read("f"), client="rf")
    assert abs(len(final) - len(base)) <= 3 * 512
    check_all(dss.history)


def test_crash_during_mixed_workload():
    """Crashing within the fault envelope mid-run must not wedge anything."""
    dss = DSS(DSSParams(algorithm="coaresecf", n_servers=6, parity_m=2, seed=55,
                        min_block=64, avg_block=128, max_block=512))
    rng = np.random.default_rng(3)
    blob = rng.integers(0, 256, 3000, dtype=np.uint8).tobytes()
    boot = dss.client("boot")
    dss.net.run_op(boot.update("f", blob), client="boot")
    w, r = dss.client("w"), dss.client("r")

    def wloop():
        for i in range(3):
            yield from w.read("f")
            cur = bytearray(blob); cur[i * 97] ^= 1
            yield from w.update("f", bytes(cur))
        return True

    fw = dss.net.spawn(wloop(), client="w")
    fr = [dss.net.spawn(r.read("f"), client="r", delay=0.004 * i) for i in range(3)]
    # (n-k)/2 = 1 crash tolerated
    dss.net.schedule(0.005, lambda: dss.net.crash("s5"))
    dss.net.run()
    assert fw.done and all(f.done for f in fr)
    check_all(dss.history)
