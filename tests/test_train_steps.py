"""Train/serve step integration on CPU (reduced configs, 1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.registry import build_model
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "olmoe_1b_7b", "mamba2_2_7b"])
def test_train_loop_reduces_loss(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, max_pos=64)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=0))
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, None, AdamWConfig(lr=2e-3)))
    losses = []
    for _ in range(12):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-4:]) < np.mean(losses[:4]), losses


def test_train_driver_with_crash_restore():
    from repro.launch.train import main

    out = main(["--arch", "qwen2_0_5b", "--steps", "12", "--ckpt-every", "5",
                "--crash-at", "8", "--kill-hosts", "1", "--ckpt-hosts", "6",
                "--ckpt-parity", "2", "--batch", "2", "--seq", "32"])
    assert len(out["ckpts"]) >= 2
    assert all(np.isfinite(out["losses"]))


def test_serve_driver():
    from repro.launch.serve import main

    out = main(["--arch", "gemma3_1b", "--batch", "2", "--cache-len", "64",
                "--tokens", "8"])
    assert out["tokens"].shape == (2, 8)


def test_optimizer_matches_reference_math():
    """adamw_update == hand-rolled AdamW on a toy problem."""
    from repro.train.optimizer import adamw_update

    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0]], jnp.float32)}
    st = adamw_init(p)
    g = {"w": jnp.asarray([[0.5, 0.5]], jnp.float32)}
    p1, st1 = adamw_update(p, g, st, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    want = 1.0 - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"])[0, 0], want, rtol=1e-5)
    assert int(st1["step"]) == 1


def test_grad_clip_bounds_update():
    from repro.train.optimizer import adamw_update

    cfg = AdamWConfig(lr=1.0, weight_decay=0.0, grad_clip=0.001)
    p = {"w": jnp.ones((4,), jnp.float32)}
    st = adamw_init(p)
    g = {"w": jnp.full((4,), 1e6, jnp.float32)}
    p1, _ = adamw_update(p, g, st, cfg)
    assert np.all(np.isfinite(np.asarray(p1["w"])))
